//! Repo-local task runner (`cargo xtask <command>`): machine-enforced
//! soundness contracts for the unsafe/atomics surface of `rust/src`.
//!
//! Commands:
//!
//! - `audit-unsafe`: every `unsafe` site (block, `unsafe impl`,
//!   `unsafe fn`) must carry a written contract — a `// SAFETY:` comment
//!   within [`SAFETY_WINDOW`] lines above (or on the same line), or a
//!   `# Safety` doc section for `unsafe fn` declarations — and the
//!   per-file site counts must match `ci/unsafe_budget.toml` exactly.
//!   A file with unsafe that is not in the budget fails (unsafe stays
//!   confined to the reviewed module set); a budget entry whose file
//!   lost its sites also fails (dead budget = dead unsafe somewhere).
//! - `audit-atomics`: `Ordering::Relaxed` is allowed wholesale only in
//!   the pure-counter files listed under `[atomics].allow_relaxed_files`.
//!   Everywhere else each `Relaxed` site needs an `// ORDERING:`
//!   justification comment within the same window plus an exact
//!   per-file count in the `[relaxed]` budget table. Publication flags
//!   (drain/abort/generation handoffs) must use Release/Acquire — those
//!   never qualify for a Relaxed waiver.
//! - `audit`: both, in order. `audit --write-budget` regenerates the
//!   budget tables from the current tree (for intentional, reviewed
//!   changes; CI only ever reads).
//!
//! The scanner is deliberately textual (no syn/proc-macro deps in the
//! offline crate set): it strips `//` line comments and tracks string
//! literals per line, skips each file's trailing `#[cfg(test)] mod …`
//! block (the repo convention keeps unit tests last), and matches the
//! `unsafe` / `Relaxed` keywords on word boundaries. That is exact for
//! this codebase's idioms; the budget tables keep it honest if an idiom
//! ever drifts.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// A SAFETY/ORDERING comment must sit within this many lines above the
/// site it documents (same-line trailing comments also count).
const SAFETY_WINDOW: usize = 6;

/// Budget file, relative to the repository root.
const BUDGET_PATH: &str = "ci/unsafe_budget.toml";

/// Audited source root, relative to the repository root.
const SRC_ROOT: &str = "rust/src";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let write_budget = args.iter().any(|a| a == "--write-budget");
    let cmd = args.iter().find(|a| !a.starts_with("--")).map(String::as_str);
    let root = repo_root();
    let result = match cmd {
        Some("audit-unsafe") => audit(&root, true, false, write_budget),
        Some("audit-atomics") => audit(&root, false, true, write_budget),
        Some("audit") | None => audit(&root, true, true, write_budget),
        Some(other) => {
            eprintln!("unknown command {other:?}");
            eprintln!("usage: cargo xtask [audit|audit-unsafe|audit-atomics] [--write-budget]");
            return ExitCode::from(2);
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(violations) => {
            for v in &violations {
                eprintln!("{v}");
            }
            eprintln!(
                "\naudit failed with {} violation(s). New or moved unsafe/Relaxed sites need \
                 a written SAFETY/ORDERING contract and a reviewed budget bump in {BUDGET_PATH} \
                 (regenerate counts with `cargo xtask audit --write-budget` after review).",
                violations.len()
            );
            ExitCode::FAILURE
        }
    }
}

/// Repository root: the parent of this crate's manifest directory.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives one level below the repo root")
        .to_path_buf()
}

// ---------------------------------------------------------------------
// Budget file
// ---------------------------------------------------------------------

#[derive(Default)]
struct Budget {
    /// Files where `Ordering::Relaxed` is allowed without per-site
    /// justification (pure counter/histogram modules).
    allow_relaxed_files: Vec<String>,
    /// Exact per-file `unsafe` site counts (non-test code).
    unsafe_counts: BTreeMap<String, usize>,
    /// Exact per-file `Relaxed` site counts outside the allowlist.
    relaxed_counts: BTreeMap<String, usize>,
}

fn parse_budget(text: &str) -> Result<Budget, String> {
    let mut budget = Budget::default();
    let mut section = String::new();
    let mut pending = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_hash_comment(raw);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if pending.is_empty() && line.starts_with('[') {
            section = line.trim_matches(|c| c == '[' || c == ']').to_string();
            continue;
        }
        pending.push_str(line);
        pending.push(' ');
        // arrays may span lines; wait for the brackets to balance
        let opens = pending.matches('[').count();
        let closes = pending.matches(']').count();
        if opens > closes {
            continue;
        }
        let kv = std::mem::take(&mut pending);
        let (key, value) = kv
            .split_once('=')
            .ok_or_else(|| format!("{BUDGET_PATH}:{}: expected `key = value`", ln + 1))?;
        let key = key.trim().trim_matches('"').to_string();
        let value = value.trim();
        match section.as_str() {
            "atomics" if key == "allow_relaxed_files" => {
                budget.allow_relaxed_files = parse_string_array(value)
                    .ok_or_else(|| format!("{BUDGET_PATH}:{}: bad string array", ln + 1))?;
            }
            "unsafe" | "relaxed" => {
                let n: usize = value
                    .parse()
                    .map_err(|_| format!("{BUDGET_PATH}:{}: bad count {value:?}", ln + 1))?;
                let table = if section == "unsafe" {
                    &mut budget.unsafe_counts
                } else {
                    &mut budget.relaxed_counts
                };
                if table.insert(key.clone(), n).is_some() {
                    return Err(format!("{BUDGET_PATH}:{}: duplicate key {key:?}", ln + 1));
                }
            }
            _ => {
                return Err(format!(
                    "{BUDGET_PATH}:{}: unexpected key {key:?} in section [{section}]",
                    ln + 1
                ));
            }
        }
    }
    Ok(budget)
}

/// Drop a `#`-to-EOL comment, respecting double-quoted strings.
fn strip_hash_comment(line: &str) -> String {
    let mut out = String::new();
    let mut in_str = false;
    for c in line.chars() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => break,
            _ => {}
        }
        out.push(c);
    }
    out
}

fn parse_string_array(value: &str) -> Option<Vec<String>> {
    let inner = value.trim().strip_prefix('[')?.strip_suffix(']')?;
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(part.strip_prefix('"')?.strip_suffix('"')?.to_string());
    }
    Some(out)
}

fn render_budget(budget: &Budget) -> String {
    let mut out = String::new();
    out.push_str(
        "# ci/unsafe_budget.toml — the machine-enforced unsafe/atomics budget.\n\
         #\n\
         # Checked by `cargo xtask audit` (CI lint job): the per-file counts\n\
         # below must EXACTLY match the non-test `unsafe` / `Ordering::Relaxed`\n\
         # sites in rust/src. Adding a site without bumping its budget fails\n\
         # the build, as does a stale entry after removing one — the budget is\n\
         # a two-sided ratchet, not a ceiling. Regenerate the counts after a\n\
         # reviewed change with `cargo xtask audit --write-budget`.\n\
         #\n\
         # Policy (DESIGN.md §11): unsafe stays confined to the scatter/pool\n\
         # modules listed here; Relaxed is for pure counters only — state\n\
         # handoffs (drain flags, abort flags, generations) use\n\
         # Release/Acquire and never get a Relaxed waiver.\n\n",
    );
    out.push_str("[atomics]\n");
    out.push_str("# Pure-counter files: Relaxed allowed wholesale, no per-site waivers.\n");
    out.push_str("allow_relaxed_files = [\n");
    for f in &budget.allow_relaxed_files {
        out.push_str(&format!("    \"{f}\",\n"));
    }
    out.push_str("]\n\n[unsafe]\n");
    out.push_str("# file = exact count of non-test `unsafe` sites (blocks, impls, fns).\n");
    for (f, n) in &budget.unsafe_counts {
        out.push_str(&format!("\"{f}\" = {n}\n"));
    }
    out.push_str("\n[relaxed]\n");
    out.push_str("# file = exact count of ORDERING-justified Relaxed sites outside the\n");
    out.push_str("# allowlist (each site also needs its `// ORDERING:` comment).\n");
    for (f, n) in &budget.relaxed_counts {
        out.push_str(&format!("\"{f}\" = {n}\n"));
    }
    out
}

// ---------------------------------------------------------------------
// Source scanning
// ---------------------------------------------------------------------

#[derive(Default)]
struct FileScan {
    /// Non-test `unsafe` sites (keyword occurrences).
    unsafe_count: usize,
    /// Non-test `Relaxed` sites.
    relaxed_count: usize,
    /// Undocumented-unsafe violations (missing SAFETY contract).
    unsafe_violations: Vec<String>,
    /// Unjustified-Relaxed violations (missing ORDERING contract).
    relaxed_violations: Vec<String>,
}

fn scan_file(rel: &str, text: &str, relaxed_allowlisted: bool) -> FileScan {
    let lines: Vec<&str> = text.lines().collect();
    let cut = test_mod_start(&lines).unwrap_or(lines.len());
    let mut scan = FileScan::default();
    for (i, raw) in lines.iter().enumerate().take(cut) {
        let code = strip_rust_comment(raw);
        for _ in word_occurrences(&code, "unsafe") {
            let is_unsafe_fn = code.contains("unsafe fn");
            let documented = if is_unsafe_fn {
                has_safety_doc(&lines, i) || has_marker_comment(&lines, i, "SAFETY:")
            } else {
                has_marker_comment(&lines, i, "SAFETY:")
            };
            if !documented {
                let want =
                    if is_unsafe_fn { "`# Safety` doc section" } else { "// SAFETY: comment" };
                scan.unsafe_violations.push(format!(
                    "{rel}:{}: unsafe site without a {want} within {SAFETY_WINDOW} lines",
                    i + 1
                ));
            }
            scan.unsafe_count += 1;
        }
        for _ in word_occurrences(&code, "Relaxed") {
            if !relaxed_allowlisted && !has_marker_comment(&lines, i, "ORDERING:") {
                scan.relaxed_violations.push(format!(
                    "{rel}:{}: Ordering::Relaxed outside the pure-counter allowlist without an \
                     // ORDERING: justification within {SAFETY_WINDOW} lines — if this atomic \
                     publishes state (not a counter), use Release/Acquire instead",
                    i + 1
                ));
            }
            scan.relaxed_count += 1;
        }
    }
    scan
}

/// Start of the trailing `#[cfg(test)] mod …` block, if any. Repo
/// convention (checked by eye, enforced by review): unit tests are the
/// last item of a file, so everything from that attribute on is test
/// code and exempt from the budget (Miri runs it instead).
fn test_mod_start(lines: &[&str]) -> Option<usize> {
    for (i, l) in lines.iter().enumerate() {
        if l.trim() == "#[cfg(test)]" {
            let next = lines[i + 1..].iter().find(|n| !n.trim().is_empty());
            if next.is_some_and(|n| n.trim_start().starts_with("mod ")) {
                return Some(i);
            }
        }
    }
    None
}

/// Strip a `//` comment (respecting string literals) so commented-out
/// code and prose mentioning `unsafe` are not counted as sites.
fn strip_rust_comment(line: &str) -> String {
    let mut out = String::new();
    let mut chars = line.chars().peekable();
    let mut in_str = false;
    let mut escaped = false;
    while let Some(c) = chars.next() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
            out.push(c);
            continue;
        }
        match c {
            '"' => {
                in_str = true;
                out.push(c);
            }
            '/' if chars.peek() == Some(&'/') => break,
            _ => out.push(c),
        }
    }
    out
}

/// Word-boundary occurrences of `word` in `haystack` (so
/// `unsafe_op_in_unsafe_fn` and `unsafe_code` never match `unsafe`).
fn word_occurrences(haystack: &str, word: &str) -> Vec<usize> {
    let bytes = haystack.as_bytes();
    let is_word = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = haystack[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let left_ok = start == 0 || !is_word(bytes[start - 1]);
        let right_ok = end == bytes.len() || !is_word(bytes[end]);
        if left_ok && right_ok {
            out.push(start);
        }
        from = end;
    }
    out
}

/// A `// <marker> …` comment on the site's line or within the window
/// above it (multi-line contract comments count via their lead line).
fn has_marker_comment(lines: &[&str], i: usize, marker: &str) -> bool {
    let lo = i.saturating_sub(SAFETY_WINDOW);
    lines[lo..=i].iter().any(|l| {
        l.find("//").is_some_and(|pos| l[pos..].contains(marker))
    })
}

/// `# Safety` section in the doc comment directly above an `unsafe fn`
/// declaration (attributes and visibility lines may intervene).
fn has_safety_doc(lines: &[&str], i: usize) -> bool {
    let mut j = i;
    while j > 0 {
        j -= 1;
        let t = lines[j].trim_start();
        if t.starts_with("///") {
            if t.contains("# Safety") {
                return true;
            }
        } else if !(t.starts_with("#[") || t.is_empty()) {
            break;
        }
    }
    false
}

fn walk_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut entries: Vec<PathBuf> = entries.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk_rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

// ---------------------------------------------------------------------
// The audits
// ---------------------------------------------------------------------

fn audit(
    root: &Path,
    check_unsafe: bool,
    check_atomics: bool,
    write_budget: bool,
) -> Result<(), Vec<String>> {
    let budget_path = root.join(BUDGET_PATH);
    let budget_text = std::fs::read_to_string(&budget_path)
        .map_err(|e| vec![format!("cannot read {BUDGET_PATH}: {e}")])?;
    let mut budget = parse_budget(&budget_text).map_err(|e| vec![e])?;

    let src = root.join(SRC_ROOT);
    let mut files = Vec::new();
    walk_rs_files(&src, &mut files);
    if files.is_empty() {
        return Err(vec![format!("no .rs files under {SRC_ROOT} — wrong working directory?")]);
    }

    let mut violations = Vec::new();
    let mut actual_unsafe: BTreeMap<String, usize> = BTreeMap::new();
    let mut actual_relaxed: BTreeMap<String, usize> = BTreeMap::new();
    let mut n_unsafe = 0usize;
    let mut n_relaxed = 0usize;

    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = std::fs::read_to_string(path)
            .map_err(|e| vec![format!("cannot read {rel}: {e}")])?;
        let allowlisted = budget.allow_relaxed_files.iter().any(|f| f == &rel);
        let scan = scan_file(&rel, &text, allowlisted);
        if check_unsafe {
            violations.extend(scan.unsafe_violations);
            if scan.unsafe_count > 0 {
                n_unsafe += scan.unsafe_count;
                actual_unsafe.insert(rel.clone(), scan.unsafe_count);
            }
        }
        if check_atomics {
            violations.extend(scan.relaxed_violations);
            if scan.relaxed_count > 0 && !allowlisted {
                n_relaxed += scan.relaxed_count;
                actual_relaxed.insert(rel.clone(), scan.relaxed_count);
            }
        }
    }

    if write_budget {
        if check_unsafe {
            budget.unsafe_counts = actual_unsafe.clone();
        }
        if check_atomics {
            budget.relaxed_counts = actual_relaxed.clone();
        }
        std::fs::write(&budget_path, render_budget(&budget))
            .map_err(|e| vec![format!("cannot write {BUDGET_PATH}: {e}")])?;
        println!("wrote {BUDGET_PATH}");
    }

    if check_unsafe {
        diff_counts(&actual_unsafe, &budget.unsafe_counts, "unsafe", "[unsafe]", &mut violations);
    }
    if check_atomics {
        diff_counts(&actual_relaxed, &budget.relaxed_counts, "Relaxed", "[relaxed]", &mut violations);
    }

    if violations.is_empty() {
        if check_unsafe {
            println!(
                "audit-unsafe: {} site(s) across {} file(s) — all documented, budget exact.",
                n_unsafe,
                actual_unsafe.len()
            );
        }
        if check_atomics {
            println!(
                "audit-atomics: {} justified Relaxed site(s) across {} file(s) outside the \
                 {}-file counter allowlist — budget exact.",
                n_relaxed,
                actual_relaxed.len(),
                budget.allow_relaxed_files.len()
            );
        }
        Ok(())
    } else {
        Err(violations)
    }
}

fn diff_counts(
    actual: &BTreeMap<String, usize>,
    budgeted: &BTreeMap<String, usize>,
    what: &str,
    table: &str,
    violations: &mut Vec<String>,
) {
    for (file, &n) in actual {
        match budgeted.get(file) {
            None => violations.push(format!(
                "{file}: {n} {what} site(s) but the file is not in {BUDGET_PATH} {table} — \
                 {what} is confined to the reviewed module set"
            )),
            Some(&b) if b != n => violations.push(format!(
                "{file}: {n} {what} site(s) but {BUDGET_PATH} {table} budgets {b} — \
                 review the change and update the budget"
            )),
            Some(_) => {}
        }
    }
    for (file, &b) in budgeted {
        if !actual.contains_key(file) {
            violations.push(format!(
                "{BUDGET_PATH}: {table} entry \"{file}\" = {b} is stale (no {what} sites remain) \
                 — remove it so the budget ratchets down"
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_boundaries_exclude_lint_names() {
        assert_eq!(word_occurrences("#![deny(unsafe_op_in_unsafe_fn)]", "unsafe").len(), 0);
        assert_eq!(word_occurrences("#![forbid(unsafe_code)]", "unsafe").len(), 0);
        assert_eq!(word_occurrences("unsafe { x() }", "unsafe").len(), 1);
        assert_eq!(word_occurrences("unsafe impl Send for T {}", "unsafe").len(), 1);
        assert_eq!(word_occurrences("Ordering::Relaxed", "Relaxed").len(), 1);
    }

    #[test]
    fn comments_and_strings_are_stripped() {
        assert_eq!(strip_rust_comment("let x = 1; // unsafe prose"), "let x = 1; ");
        assert_eq!(strip_rust_comment("// SAFETY: all of it"), "");
        let kept = strip_rust_comment("let s = \"a // b\"; call()");
        assert!(kept.contains("call()"));
    }

    #[test]
    fn safety_window_accepts_lead_line_of_multiline_comment() {
        let lines = vec![
            "// SAFETY: children were reduced in a completed deeper level and",
            "// have exactly one consumer (this parent), so taking ownership",
            "// here is race-free.",
            "let a = unsafe { take(l) };",
            "let b = unsafe { take(r) };",
        ];
        assert!(has_marker_comment(&lines, 3, "SAFETY:"));
        assert!(has_marker_comment(&lines, 4, "SAFETY:"));
        assert!(!has_marker_comment(&["let a = unsafe { f() };"], 0, "SAFETY:"));
    }

    #[test]
    fn unsafe_fn_doc_section_detected() {
        let lines = vec![
            "/// Mutable view.",
            "///",
            "/// # Safety",
            "/// Callers claim disjoint ranges.",
            "#[allow(clippy::mut_from_ref)]",
            "pub unsafe fn slice(&self) {}",
        ];
        assert!(has_safety_doc(&lines, 5));
        assert!(!has_safety_doc(&["/// docs without section", "pub unsafe fn f() {}"], 1));
    }

    #[test]
    fn trailing_test_mod_is_exempt() {
        let lines = vec![
            "fn real() {}",
            "",
            "#[cfg(test)]",
            "mod tests {",
            "    fn t() { unsafe { x() } }",
            "}",
        ];
        assert_eq!(test_mod_start(&lines), Some(2));
        // mid-file cfg(test) on a use item does not cut the file
        let mid = vec!["#[cfg(test)]", "use crate::linalg::Mat;", "fn real() {}"];
        assert_eq!(test_mod_start(&mid), None);
    }

    #[test]
    fn budget_roundtrip() {
        let b = Budget {
            allow_relaxed_files: vec!["rust/src/server/stats.rs".into()],
            unsafe_counts: BTreeMap::from([("rust/src/a.rs".to_string(), 3usize)]),
            relaxed_counts: BTreeMap::from([("rust/src/b.rs".to_string(), 2usize)]),
        };
        let rendered = render_budget(&b);
        let parsed = parse_budget(&rendered).unwrap();
        assert_eq!(parsed.allow_relaxed_files, b.allow_relaxed_files);
        assert_eq!(parsed.unsafe_counts, b.unsafe_counts);
        assert_eq!(parsed.relaxed_counts, b.relaxed_counts);
    }

    #[test]
    fn scan_flags_undocumented_and_counts_documented() {
        let text = "\
fn f() {
    // SAFETY: disjoint indices.
    unsafe { g() };
    unsafe { h() };
}
";
        let scan = scan_file("x.rs", text, false);
        assert_eq!(scan.unsafe_count, 2);
        // the second site still sits within the window of the first
        // comment (line 2 of 4) — move it further to lose coverage
        assert!(scan.unsafe_violations.is_empty());
        let far = format!(
            "fn f() {{\n    // SAFETY: ok.\n    unsafe {{ g() }};\n{}    unsafe {{ h() }};\n}}\n",
            "    g();\n".repeat(SAFETY_WINDOW)
        );
        let scan = scan_file("x.rs", &far, false);
        assert_eq!(scan.unsafe_violations.len(), 1);
    }

    #[test]
    fn relaxed_needs_justification_unless_allowlisted() {
        let text = "fn f(c: &AtomicU64) { c.fetch_add(1, Ordering::Relaxed); }\n";
        assert_eq!(scan_file("x.rs", text, false).relaxed_violations.len(), 1);
        assert!(scan_file("x.rs", text, true).relaxed_violations.is_empty());
        let ok = "fn f(c: &AtomicU64) {\n    // ORDERING: Relaxed — pure counter.\n    \
                  c.fetch_add(1, Ordering::Relaxed);\n}\n";
        assert!(scan_file("x.rs", ok, false).relaxed_violations.is_empty());
    }
}
