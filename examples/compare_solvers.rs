//! Head-to-head on one workload: HSS+ADMM (the paper) vs SMO
//! (LIBSVM-style, Table 2) vs RACQP-style multi-block ADMM (Table 3) vs
//! Nyström+ADMM (the §1.1 global-low-rank alternative).
//!
//! Run with: cargo run --release --example compare_solvers

use hss_svm::admm::AdmmParams;
use hss_svm::baselines::{racqp, smo, train_nystrom};
use hss_svm::coordinator::suite::prepare_dataset;
use hss_svm::data::synth;
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::svm::{predict, train::train_hss_svm};
use hss_svm::util::threadpool;
use hss_svm::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let threads = threadpool::default_threads();
    let spec = synth::table1_spec("cod.rna").unwrap();
    let (train, test) = prepare_dataset(spec, 0.03, 2021); // ≈1800 points
    println!(
        "cod.rna-like workload: {} train / {} test, {} features\n",
        train.len(),
        test.len(),
        train.dim()
    );

    let kernel = Kernel::Gaussian { h: 1.0 };
    let c = 1.0;
    let mut rows: Vec<(String, f64, f64)> = Vec::new();

    // --- HSS + ADMM (the paper) ---
    let t = Timer::start();
    let (model, stats) = train_hss_svm(
        &train,
        kernel,
        &HssParams::low_accuracy(),
        &AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 },
        c,
        threads,
    )?;
    let secs = t.secs();
    let acc = predict::accuracy(&model, &test, threads);
    println!(
        "HSS+ADMM     : compress {:.3}s + factor {:.3}s + admm {:.3}s",
        stats.compress_secs, stats.factor_secs, stats.admm_secs
    );
    rows.push(("HSS+ADMM (paper)".into(), secs, acc));

    // --- SMO (LIBSVM) ---
    let t = Timer::start();
    let (model, st) = smo::train_smo(&train, kernel, c, &smo::SmoParams::default());
    let secs = t.secs();
    let acc = predict::accuracy(&model, &test, threads);
    println!("SMO          : {} iterations, {} kernel rows", st.iterations, st.kernel_rows_computed);
    rows.push(("SMO (LIBSVM-style)".into(), secs, acc));

    // --- RACQP-style multi-block ADMM ---
    let t = Timer::start();
    let (model, st) = racqp::train_racqp(
        &train,
        kernel,
        c,
        &racqp::RacqpParams { block_size: 300, beta: 1.0, sweeps: 20, seed: 1 },
    )?;
    let secs = t.secs();
    let acc = predict::accuracy(&model, &test, threads);
    println!("RACQP        : {} sweeps, {:.1}M kernel evals", st.sweeps, st.kernel_evals as f64 / 1e6);
    rows.push(("RACQP-style".into(), secs, acc));

    // --- Nyström + ADMM ---
    let t = Timer::start();
    let (model, mem) = train_nystrom(
        &train,
        kernel,
        c,
        256,
        &AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 },
        7,
    )?;
    let secs = t.secs();
    let acc = predict::accuracy(&model, &test, threads);
    println!("Nystrom      : 256 landmarks, {:.2} MB factor", mem as f64 / 1e6);
    rows.push(("Nystrom+ADMM".into(), secs, acc));

    println!("\n{:<22} {:>12} {:>14}", "solver", "runtime [s]", "accuracy [%]");
    println!("{}", "-".repeat(50));
    for (name, secs, acc) in &rows {
        println!("{name:<22} {secs:>12.3} {:>14.3}", acc * 100.0);
    }
    Ok(())
}
