//! Quickstart: train a nonlinear SVM with ADMM + HSS on a toy problem.
//!
//! Run with: cargo run --release --example quickstart

use hss_svm::admm::AdmmParams;
use hss_svm::data::synth;
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::svm::{predict, train::train_hss_svm};
use hss_svm::util::prng::Rng;
use hss_svm::util::threadpool;

fn main() -> anyhow::Result<()> {
    let threads = threadpool::default_threads();

    // 1. data: the classic two-moons toy (a genuinely nonlinear boundary)
    let mut rng = Rng::new(42);
    let train = synth::two_moons(2000, 0.1, &mut rng);
    let test = synth::two_moons(1000, 0.1, &mut rng);
    println!("train: {train:?}");

    // 2. train: Gaussian kernel, HSS-compressed, 10 ADMM iterations
    //    (Algorithm 3 of the paper with MaxIt = 10)
    let kernel = Kernel::Gaussian { h: 0.3 };
    let hss = HssParams::low_accuracy();
    let admm = AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 };
    let (model, stats) = train_hss_svm(&train, kernel, &hss, &admm, 10.0, threads)?;

    println!("\npipeline timing (the paper's Table 4/5 columns):");
    println!(
        "  compression   : {:.3} s  ({:.2} MB, max rank {})",
        stats.compress_secs,
        stats.hss_memory_bytes as f64 / 1e6,
        stats.hss_max_rank
    );
    println!("  factorization : {:.3} s", stats.factor_secs);
    println!("  ADMM (10 it)  : {:.3} s", stats.admm_secs);

    // 3. predict
    let acc = predict::accuracy(&model, &test, threads);
    println!("\nmodel: {model:?}");
    println!("test accuracy: {:.2}%", acc * 100.0);
    assert!(acc > 0.9, "quickstart accuracy should exceed 90%");
    Ok(())
}
