//! END-TO-END VALIDATION DRIVER (DESIGN.md E9 / EXPERIMENTS.md §E2E).
//!
//! Exercises every layer of the system on a real sizeable workload:
//!   data generation (Table-1 skin.nonskin profile) → scaling →
//!   cluster tree → ANN → HSS-ANN compression → ULV factorization →
//!   grid search over C with cached factorization → bias via HSS
//!   matvec → prediction through BOTH the native path and the
//!   AOT-compiled PJRT artifacts (L1 Pallas kernel inside) →
//!   SMO baseline for the paper's headline speed comparison.
//!
//! Run with: cargo run --release --example large_scale
//! Environment: HSS_SVM_SCALE (default 0.1 → ≈17k training points),
//!              HSS_SVM_THREADS.

use hss_svm::admm::{AdmmParams, AdmmSolver};
use hss_svm::baselines::smo;
use hss_svm::coordinator::suite::prepare_dataset;
use hss_svm::data::synth;
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::runtime::PjrtRuntime;
use hss_svm::svm::{predict, HssSvmTrainer};
use hss_svm::util::threadpool;
use hss_svm::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let threads = threadpool::default_threads();
    let scale: f64 = std::env::var("HSS_SVM_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.1);

    let spec = synth::table1_spec("skin.nonskin").unwrap();
    let (train, test) = prepare_dataset(spec, scale, 2021);
    let beta = synth::Table1Spec::beta_for(train.len());
    println!(
        "=== large-scale E2E: skin.nonskin-like at scale {scale} ===\n\
         train {} x {} feats ({} positive) | test {} | beta {beta} | {} threads\n",
        train.len(),
        train.dim(),
        train.positives(),
        test.len(),
        threads
    );

    // ---- stage 1: HSS-ANN compression (once per h) ----
    let h = 1.0; // grid-selected width for the synthetic skin profile
                 // (the paper picked h=10 on the real LIBSVM file)
    let t = Timer::start();
    let trainer = HssSvmTrainer::compress(&train, Kernel::Gaussian { h }, &HssParams::low_accuracy(), threads);
    let compress_secs = t.secs();
    let stats = &trainer.compressed.stats;
    println!(
        "compression   {compress_secs:>8.3} s | memory {:>8.3} MB | max rank {} | {:.1}M kernel evals ({:.1}% of full K)",
        stats.memory_bytes as f64 / 1e6,
        stats.max_rank,
        stats.kernel_evals as f64 / 1e6,
        100.0 * stats.kernel_evals as f64 / (train.len() as f64).powi(2),
    );

    // ---- stage 2: ULV factorization (once per beta) ----
    let t = Timer::start();
    let ulv = trainer.factor(beta)?;
    let factor_secs = t.secs();
    println!("factorization {factor_secs:>8.3} s | factor memory {:.3} MB", ulv.memory_bytes() as f64 / 1e6);

    // ---- stage 3: grid over C, reusing the factorization ----
    let admm = AdmmParams { beta, max_it: 10, relax: 1.0, tol: 0.0 };
    let solver = AdmmSolver::new(&ulv, &trainer.y, admm);
    let mut best = (f64::NEG_INFINITY, 0.0, None);
    let mut admm_total = 0.0;
    for c in [0.1, 1.0, 10.0] {
        let t = Timer::start();
        let (model, out) = trainer.train_c_with_solver(&solver, c);
        let admm_secs = t.secs();
        admm_total += admm_secs;
        let acc = predict::accuracy(&model, &test, threads);
        println!(
            "  C = {c:<5} ADMM {admm_secs:>7.3} s | primal residual {:.2e} | {} SVs | accuracy {:.3}%",
            out.primal.last().unwrap(),
            model.n_sv(),
            acc * 100.0
        );
        if acc > best.0 {
            best = (acc, c, Some(model));
        }
    }
    let (best_acc, best_c, model) = (best.0, best.1, best.2.unwrap());
    println!(
        "grid over 3 C values: {admm_total:.3} s of ADMM vs {:.3} s setup -> the paper's reuse claim\n",
        compress_secs + factor_secs
    );

    // ---- stage 4: prediction through the PJRT artifacts (L1/L2) ----
    match PjrtRuntime::try_default() {
        Some(rt) => {
            let t = Timer::start();
            let pj = hss_svm::runtime::predict_pjrt(&rt, &model, &test.x)?;
            let pjrt_secs = t.secs();
            let hits = pj.iter().zip(test.y.iter()).filter(|(p, y)| p == y).count();
            let t = Timer::start();
            let _native = predict::predict(&model, &test.x, threads);
            let native_secs = t.secs();
            println!(
                "prediction: PJRT path {pjrt_secs:.3} s vs native {native_secs:.3} s | PJRT accuracy {:.3}%",
                100.0 * hits as f64 / test.len() as f64
            );
        }
        None => println!("prediction: artifacts not built, skipping PJRT path (run `make artifacts`)"),
    }

    // ---- stage 5: SMO baseline at the same (h, C) ----
    let cap = 40_000;
    if train.len() <= cap {
        let t = Timer::start();
        let (smo_model, st) =
            smo::train_smo(&train, Kernel::Gaussian { h }, best_c, &smo::SmoParams::default());
        let smo_secs = t.secs();
        let smo_acc = predict::accuracy(&smo_model, &test, threads);
        println!(
            "\nSMO baseline: {smo_secs:.3} s ({} iterations) | accuracy {:.3}%",
            st.iterations,
            smo_acc * 100.0
        );
        let ours = compress_secs + factor_secs + admm_total / 3.0;
        println!(
            "headline: HSS+ADMM {ours:.3} s vs SMO {smo_secs:.3} s -> {:.1}x {}",
            (smo_secs / ours).max(ours / smo_secs),
            if smo_secs > ours { "speedup" } else { "slowdown (small-n regime)" }
        );
        println!(
            "accuracy: ours {:.3}% vs SMO {:.3}% (paper: comparable within ~1 pt on skin.nonskin)",
            best_acc * 100.0,
            smo_acc * 100.0
        );
    }

    println!("\nE2E complete: all layers exercised.");
    Ok(())
}
