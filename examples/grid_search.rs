//! The paper's headline workflow: hyperparameter grid search where the
//! HSS compression and ULV factorization are computed ONCE per kernel
//! width h and reused for every penalty C (§3.2: "the approximation K̃
//! and the factorization ULV of K̃_β are computed just once and then
//! reused for all the values C in the grid search").
//!
//! Run with: cargo run --release --example grid_search

use hss_svm::admm::AdmmParams;
use hss_svm::coordinator::grid::ascii_heatmap;
use hss_svm::coordinator::suite::prepare_dataset;
use hss_svm::coordinator::GridSearch;
use hss_svm::data::synth;
use hss_svm::hss::HssParams;
use hss_svm::util::threadpool;
use hss_svm::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let threads = threadpool::default_threads();

    // ijcnn1-like workload at 2% of the paper's size (≈1000 points)
    let spec = synth::table1_spec("ijcnn1").unwrap();
    let (train, test) = prepare_dataset(spec, 0.02, 2021);
    println!("dataset: {} pts x {} feats (test {})", train.len(), train.dim(), test.len());

    let h_values = vec![0.1, 1.0, 10.0];
    let c_values = vec![0.1, 1.0, 10.0];
    let grid = GridSearch {
        h_values: h_values.clone(),
        c_values: c_values.clone(),
        hss: HssParams::low_accuracy(),
        admm: AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 },
        threads,
    };

    let t = Timer::start();
    let res = grid.run(&train, &test)?;
    let total = t.secs();

    println!("\naccuracy heatmap (Figure-2 style):");
    println!("{}", ascii_heatmap(&res, &h_values, &c_values));

    println!("cost breakdown over {} grid cells:", res.cells.len());
    println!("  compression (once per h) : {:.3} s", res.compress_secs);
    println!("  factorization (once per h): {:.3} s", res.factor_secs);
    println!("  all ADMM runs combined   : {:.3} s", res.total_admm_secs);
    println!("  total                    : {total:.3} s");
    println!(
        "\nthe paper's claim, visible above: ADMM-per-C ({:.4} s avg) is \
         negligible next to compression; a finer C grid is almost free.",
        res.total_admm_secs / res.cells.len() as f64
    );
    println!(
        "best: h = {}, C = {:?} -> {:.2}%",
        res.best_h,
        res.best_cs,
        res.best_accuracy * 100.0
    );
    Ok(())
}
