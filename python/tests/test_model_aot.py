"""L2 model + AOT pipeline tests: shapes, lowering, HLO-text round-trip."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_model_entry_points_shapes():
    f = 8
    x = jnp.zeros((model.TILE_M, f), jnp.float32)
    y = jnp.ones((model.TILE_N, f), jnp.float32)
    (k,) = model.kernel_tile(x, y, jnp.float32(0.5))
    assert k.shape == (model.TILE_M, model.TILE_N)
    sv = jnp.zeros((model.SV_CHUNK, f), jnp.float32)
    a = jnp.zeros((model.SV_CHUNK,), jnp.float32)
    (d,) = model.decision_tile(x, sv, a, jnp.float32(0.5))
    assert d.shape == (model.TILE_M,)


def test_model_matches_ref_entry_points():
    key = jax.random.PRNGKey(0)
    kx, ky = jax.random.split(key)
    f = 8
    x = jax.random.normal(kx, (model.TILE_M, f), jnp.float32)
    y = jax.random.normal(ky, (model.TILE_N, f), jnp.float32)
    g = jnp.float32(0.31)
    (got,) = model.kernel_tile(x, y, g)
    (want,) = model.kernel_tile_ref(x, y, g)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_lowering_produces_hlo_text():
    text = aot.lower_kernel_tile(8)
    assert "HloModule" in text
    # must NOT contain an unresolvable custom-call (Mosaic would break CPU)
    assert "mosaic" not in text.lower()
    text2 = aot.lower_decision_tile(8)
    assert "HloModule" in text2
    assert "mosaic" not in text2.lower()


def test_hlo_text_reparses_and_executes():
    """Round-trip the artifact through the same XLA client the Rust side
    uses (CPU PJRT): parse HLO text, compile, execute, compare to jnp."""
    from jax._src.lib import xla_client as xc

    f = 8
    text = aot.lower_kernel_tile(f)
    # parse from text (this is HloModuleProto::from_text on the Rust side)
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


def test_aot_main_writes_manifest(monkeypatch):
    with tempfile.TemporaryDirectory() as td:
        monkeypatch.setattr(
            "sys.argv", ["aot", "--out", td]
        )
        # restrict dims for test speed
        monkeypatch.setattr(model, "FEATURE_DIMS", (8,))
        aot.main()
        files = sorted(os.listdir(td))
        assert "manifest.txt" in files
        assert "gaussian_tile_f8.hlo.txt" in files
        assert "decision_tile_f8.hlo.txt" in files
        manifest = open(os.path.join(td, "manifest.txt")).read()
        assert "kind=kernel_tile f=8" in manifest
        assert "kind=decision_tile f=8" in manifest
