"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps tile shapes, feature dims, dtypes and kernel widths;
assert_allclose against ref.py is THE correctness signal for everything
the Rust runtime later executes.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import gaussian_tile, ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


def rand(key, shape, dtype, scale=2.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


@given(
    mi=st.integers(1, 3),
    ni=st.integers(1, 3),
    f=st.integers(1, 40),
    bm=st.sampled_from([8, 16]),
    bn=st.sampled_from([8, 16]),
    h=st.floats(0.2, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_gaussian_block_matches_ref(mi, ni, f, bm, bn, h, seed):
    m, n = mi * bm, ni * bn
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = rand(kx, (m, f), jnp.float32)
    y = rand(ky, (n, f), jnp.float32)
    gamma = 1.0 / (2.0 * h * h)
    got = gaussian_tile.gaussian_block(x, y, gamma, bm=bm, bn=bn)
    want = ref.gaussian_block(x, y, gamma)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@given(
    t=st.sampled_from([8, 16, 32]),
    si=st.integers(1, 4),
    f=st.integers(1, 24),
    h=st.floats(0.3, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_decision_tile_matches_ref(t, si, f, h, seed):
    bs = 16
    s = si * bs
    kx, ks, ka = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(kx, (t, f), jnp.float32)
    sv = rand(ks, (s, f), jnp.float32)
    alpha = rand(ka, (s,), jnp.float32, scale=1.0)
    gamma = 1.0 / (2.0 * h * h)
    got = gaussian_tile.decision_tile(x, sv, alpha, gamma, bs=bs)
    want = ref.decision_tile(x, sv, alpha, gamma, 0.0)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_gaussian_diag_is_one():
    x = rand(jax.random.PRNGKey(0), (16, 5), jnp.float32)
    k = gaussian_tile.gaussian_block(x, x, 0.5, bm=16, bn=16)
    np.testing.assert_allclose(np.diag(k), np.ones(16), rtol=1e-5, atol=1e-5)


def test_gaussian_symmetric():
    x = rand(jax.random.PRNGKey(1), (32, 7), jnp.float32)
    k = gaussian_tile.gaussian_block(x, x, 0.3, bm=16, bn=16)
    np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-7)


def test_gamma_is_runtime_operand():
    """One compiled kernel must serve different h values (the paper's
    grid-search reuse story depends on this)."""
    x = rand(jax.random.PRNGKey(2), (8, 4), jnp.float32)
    y = rand(jax.random.PRNGKey(3), (8, 4), jnp.float32)
    for h in (0.1, 1.0, 10.0):
        gamma = 1.0 / (2.0 * h * h)
        got = gaussian_tile.gaussian_block(x, y, gamma, bm=8, bn=8)
        want = ref.gaussian_block(x, y, gamma)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_zero_feature_padding_is_exact():
    """Padding features with zeros must not change kernel values —
    the property the Rust runtime's shape adapter relies on."""
    x = rand(jax.random.PRNGKey(4), (8, 5), jnp.float32)
    y = rand(jax.random.PRNGKey(5), (8, 5), jnp.float32)
    xp = jnp.pad(x, ((0, 0), (0, 11)))
    yp = jnp.pad(y, ((0, 0), (0, 11)))
    a = gaussian_tile.gaussian_block(x, y, 0.7, bm=8, bn=8)
    b = gaussian_tile.gaussian_block(xp, yp, 0.7, bm=8, bn=8)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_zero_alpha_sv_padding_is_exact():
    """Padding the SV chunk with alpha_y = 0 rows adds exactly nothing."""
    x = rand(jax.random.PRNGKey(6), (8, 3), jnp.float32)
    sv = rand(jax.random.PRNGKey(7), (16, 3), jnp.float32)
    a = rand(jax.random.PRNGKey(8), (16,), jnp.float32)
    f1 = gaussian_tile.decision_tile(x, sv, a, 0.5, bs=16)
    svp = jnp.pad(sv, ((0, 16), (0, 0)))
    ap = jnp.pad(a, (0, 16))
    f2 = gaussian_tile.decision_tile(x, svp, ap, 0.5, bs=16)
    np.testing.assert_allclose(f1, f2, rtol=1e-5, atol=1e-6)


def test_non_divisible_shapes_rejected():
    x = rand(jax.random.PRNGKey(9), (9, 4), jnp.float32)
    with pytest.raises(AssertionError):
        gaussian_tile.gaussian_block(x, x, 1.0, bm=8, bn=8)
