"""AOT lowering: JAX/Pallas → HLO *text* artifacts for the Rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  python -m compile.aot --out ../artifacts
Writes  gaussian_tile_f{F}.hlo.txt, decision_tile_f{F}.hlo.txt and a
manifest.txt the Rust side reads to discover shapes.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_kernel_tile(f: int) -> str:
    x = jax.ShapeDtypeStruct((model.TILE_M, f), jnp.float32)
    y = jax.ShapeDtypeStruct((model.TILE_N, f), jnp.float32)
    g = jax.ShapeDtypeStruct((), jnp.float32)
    return to_hlo_text(jax.jit(model.kernel_tile).lower(x, y, g))


def lower_decision_tile(f: int) -> str:
    x = jax.ShapeDtypeStruct((model.TILE_M, f), jnp.float32)
    sv = jax.ShapeDtypeStruct((model.SV_CHUNK, f), jnp.float32)
    a = jax.ShapeDtypeStruct((model.SV_CHUNK,), jnp.float32)
    g = jax.ShapeDtypeStruct((), jnp.float32)
    return to_hlo_text(jax.jit(model.decision_tile).lower(x, sv, a, g))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for f in model.FEATURE_DIMS:
        name = f"gaussian_tile_f{f}"
        text = lower_kernel_tile(f)
        with open(os.path.join(args.out, f"{name}.hlo.txt"), "w") as fh:
            fh.write(text)
        manifest.append(
            f"{name} kind=kernel_tile f={f} m={model.TILE_M} n={model.TILE_N}"
        )
        print(f"wrote {name}.hlo.txt ({len(text)} chars)")

        name = f"decision_tile_f{f}"
        text = lower_decision_tile(f)
        with open(os.path.join(args.out, f"{name}.hlo.txt"), "w") as fh:
            fh.write(text)
        manifest.append(
            f"{name} kind=decision_tile f={f} t={model.TILE_M} s={model.SV_CHUNK}"
        )
        print(f"wrote {name}.hlo.txt ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as fh:
        fh.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
