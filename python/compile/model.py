"""L2 JAX model: the compute graphs Rust executes through PJRT.

Two entry points, both built on the L1 Pallas kernel so everything
lowers into a single HLO module per artifact:

* ``kernel_tile``   — one 128×128 Gaussian kernel tile (compression
                      probes, kernel-row services);
* ``decision_tile`` — fused SVM decision function for a 128-row tile of
                      test points against a zero-padded SV chunk
                      (Algorithm 3 lines 18–20, the prediction hot loop).

Rust pads feature dimensions up to the artifact's f (zero features do
not change Gaussian distances) and pads SV chunks with alpha_y = 0
(exactly no contribution), so a handful of fixed-shape artifacts serve
every dataset.
"""

import jax.numpy as jnp

from compile.kernels import gaussian_tile

#: Tile geometry shared with rust/src/runtime (see manifest).
TILE_M = 128
TILE_N = 128
SV_CHUNK = 1024

#: Feature dims we AOT-compile for; Rust picks the smallest that fits.
FEATURE_DIMS = (8, 32, 128, 512)


def kernel_tile(x, y, gamma):
    """K(x, y) for one (TILE_M × TILE_N) tile. x,y: (128, f)."""
    return (gaussian_tile.gaussian_block(x, y, gamma, bm=TILE_M, bn=TILE_N),)


def decision_tile(x, sv, alpha_y, gamma):
    """Decision values (bias added Rust-side) for one test tile.

    x: (TILE_M, f), sv: (SV_CHUNK, f), alpha_y: (SV_CHUNK,) -> (TILE_M,).
    """
    return (gaussian_tile.decision_tile(x, sv, alpha_y, gamma, bs=128),)


def kernel_tile_ref(x, y, gamma):
    """jnp reference of kernel_tile (used by shape tests)."""
    from compile.kernels import ref

    return (ref.gaussian_block(x, y, gamma).astype(jnp.float32),)


def decision_tile_ref(x, sv, alpha_y, gamma):
    from compile.kernels import ref

    return (ref.decision_tile(x, sv, alpha_y, gamma, 0.0).astype(jnp.float32),)
