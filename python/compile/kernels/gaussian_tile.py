"""L1 Pallas kernel: tiled Gaussian kernel block evaluation.

The dense hot-spot of the whole system (HSS compression probes, SMO
cache rows, test-time prediction) is K(X_I, X_J) for modest tiles. The
paper computes it with OpenMP loops on a Xeon; the TPU formulation here
(see DESIGN.md §Hardware-Adaptation):

* grid over (M/bm, N/bn) output tiles; BlockSpec stages an X tile
  (bm × f), a Y tile (bn × f) and the output (bm × bn) through VMEM;
* the −2·X·Yᵀ term is a (bm×f)·(f×bn) matmul → MXU systolic array;
* squared norms + exp are rank-1/elementwise → VPU;
* gamma = 1/(2h²) rides along as a (1,1) scalar operand so ONE compiled
  artifact serves every kernel width h in the hyperparameter grid.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that XLA-CPU runs
at full fusion quality (this is the artifact Rust loads).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gaussian_tile_kernel(x_ref, y_ref, g_ref, o_ref):
    """One (bm × bn) output tile. All refs live in VMEM."""
    x = x_ref[...]  # (bm, f)
    y = y_ref[...]  # (bn, f)
    gamma = g_ref[0, 0]
    nx = jnp.sum(x * x, axis=1)[:, None]  # VPU
    ny = jnp.sum(y * y, axis=1)[None, :]  # VPU
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)  # MXU
    d2 = jnp.maximum(nx + ny - 2.0 * xy, 0.0)
    o_ref[...] = jnp.exp(-gamma * d2)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def gaussian_block(x, y, gamma, *, bm=128, bn=128):
    """K(x, y) via the Pallas tile kernel.

    x: (m, f), y: (n, f) with m % bm == 0 and n % bn == 0,
    gamma: scalar -> (m, n).
    """
    m, f = x.shape
    n, _ = y.shape
    assert m % bm == 0 and n % bn == 0, f"shape ({m},{n}) not tiled by ({bm},{bn})"
    g = jnp.reshape(gamma.astype(jnp.float32) if hasattr(gamma, "astype")
                    else jnp.float32(gamma), (1, 1))
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _gaussian_tile_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, f), lambda i, j: (i, 0)),  # X row-tile
            pl.BlockSpec((bn, f), lambda i, j: (j, 0)),  # Y row-tile
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),   # gamma (scalar)
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32), g)


def _decision_tile_kernel(x_ref, sv_ref, a_ref, g_ref, o_ref):
    """Fused decision-function tile: accumulate K(x, sv_chunk) @ a_chunk.

    Grid dimension walks SV chunks; every program adds its partial
    matvec into the same output block (sequential grid in interpret
    mode ⇒ safe accumulation; on real TPU the grid is sequential too).
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]       # (t, f)
    sv = sv_ref[...]     # (bs, f)
    a = a_ref[...]       # (bs,)
    gamma = g_ref[0, 0]
    nx = jnp.sum(x * x, axis=1)[:, None]
    ns = jnp.sum(sv * sv, axis=1)[None, :]
    xs = jnp.dot(x, sv.T, preferred_element_type=jnp.float32)  # MXU
    d2 = jnp.maximum(nx + ns - 2.0 * xs, 0.0)
    k = jnp.exp(-gamma * d2)  # (t, bs)
    o_ref[...] += k @ a       # second MXU-friendly contraction


@functools.partial(jax.jit, static_argnames=("bs",))
def decision_tile(x, sv, alpha_y, gamma, *, bs=128):
    """f = K(x, sv) @ alpha_y for one tile of test points.

    x: (t, f), sv: (s, f) with s % bs == 0, alpha_y: (s,) -> (t,).
    Zero-padding the SV set with alpha_y = 0 rows is exact.
    """
    t, f = x.shape
    s, _ = sv.shape
    assert s % bs == 0, f"SV count {s} not a multiple of chunk {bs}"
    g = jnp.reshape(jnp.asarray(gamma, dtype=jnp.float32), (1, 1))
    grid = (s // bs,)
    return pl.pallas_call(
        _decision_tile_kernel,
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, f), lambda j: (0, 0)),
            pl.BlockSpec((bs, f), lambda j: (j, 0)),
            pl.BlockSpec((bs,), lambda j: (j,)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t,), lambda j: (0,)),
        interpret=True,
    )(x.astype(jnp.float32), sv.astype(jnp.float32),
      alpha_y.astype(jnp.float32), g)
