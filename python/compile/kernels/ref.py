"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the Pallas implementations are tested against
(pytest + hypothesis in python/tests/), and the shape/semantics contract
for the Rust native fallback in rust/src/kernel/block.rs.
"""

import jax.numpy as jnp


def gaussian_block(x, y, gamma):
    """K(x, y) with K_ij = exp(-gamma * ||x_i - y_j||^2).

    x: (m, f), y: (n, f), gamma: scalar -> (m, n).
    gamma = 1 / (2 h^2) for the paper's kernel width h.
    """
    nx = jnp.sum(x * x, axis=1)[:, None]
    ny = jnp.sum(y * y, axis=1)[None, :]
    d2 = jnp.maximum(nx + ny - 2.0 * (x @ y.T), 0.0)
    return jnp.exp(-gamma * d2)


def decision_tile(x, sv, alpha_y, gamma, bias):
    """SVM decision values for a tile of test points.

    f_j = sum_i alpha_y[i] * K(x_j, sv_i) + bias.
    x: (t, f), sv: (s, f), alpha_y: (s,) -> (t,).
    """
    k = gaussian_block(x, sv, gamma)
    return k @ alpha_y + bias
