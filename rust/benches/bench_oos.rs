//! Out-of-core consensus-ADMM benchmark and contract check — the
//! `oos-smoke` CI lane drives this. It proves three things about
//! `hss_svm::admm::consensus` on one synthetic workload:
//!
//! 1. **Memory**: peak RSS (`VmHWM`) of the sharded training phase
//!    stays under half the dense-kernel footprint n²·8 bytes (the
//!    sharded phase runs FIRST, before anything else can inflate the
//!    high-water mark).
//! 2. **Determinism**: the persisted model is bitwise identical across
//!    thread counts {1, 2} and across a full re-shard + re-train of
//!    the same source file (the FNV-64 `model_hash` in the JSON lets
//!    CI also compare across separate processes).
//! 3. **Speed**: `consensus_shard_speedup` = in-memory train time /
//!    sharded train time, gated against `ci/bench_baseline.toml` with
//!    the house −25% tolerance.
//!
//! Flags (same conventions as bench_hss):
//!   --smoke              reduced problem size for PR gating
//!   --json <path>        write headline metrics as JSON (artifact)
//!   --baseline <path>    TOML with committed floors; exit nonzero on
//!                        a >25% regression

use hss_svm::admm::{AdmmParams, ConsensusTrainer};
use hss_svm::config::Config;
use hss_svm::data::libsvm::{self, Repr};
use hss_svm::data::{synth, ShardSet};
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::svm::{persist, predict, train::train_hss_svm};
use hss_svm::util::bench;
use hss_svm::util::prng::Rng;
use hss_svm::util::threadpool;
use hss_svm::util::timer::Timer;
use std::path::{Path, PathBuf};

struct Opts {
    smoke: bool,
    json: Option<String>,
    baseline: Option<String>,
}

/// Cargo runs bench binaries with cwd = the package dir (`rust/`), not
/// the workspace root; resolve relative paths against the repository
/// root so CI and the README can both say `ci/bench_baseline.toml`.
fn from_repo_root(p: &str) -> PathBuf {
    let path = Path::new(p);
    if path.is_absolute() {
        path.to_path_buf()
    } else {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(path)
    }
}

fn parse_opts() -> Opts {
    let mut opts = Opts { smoke: false, json: None, baseline: None };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => opts.smoke = true,
            "--json" => opts.json = args.next(),
            "--baseline" => opts.baseline = args.next(),
            other => eprintln!("[oos] ignoring unknown flag {other:?}"),
        }
    }
    opts
}

/// FNV-1a 64 over a byte slice — a stable fingerprint for the model
/// file that CI can compare across runs without uploading the file.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// One full sharded train: build engines (one shard resident at a
/// time), run the consensus ADMM, assemble, persist. Returns the
/// persisted model bytes and the wall time.
fn train_sharded(
    shards: &ShardSet,
    hss: &HssParams,
    admm: AdmmParams,
    c: f64,
    threads: usize,
    out: &Path,
) -> (Vec<u8>, f64) {
    let t = Timer::start();
    let (trainer, _stats) = ConsensusTrainer::build(
        shards,
        Repr::Auto,
        Kernel::Gaussian { h: 1.5 },
        hss,
        admm,
        threads,
    )
    .expect("consensus build");
    let (model, _) = trainer.train_c(shards, c).expect("consensus train");
    let secs = t.secs();
    persist::save(&model, out).expect("persist sharded model");
    (std::fs::read(out).expect("read model bytes"), secs)
}

fn main() {
    let opts = parse_opts();
    let (n, shards_k) = if opts.smoke { (4000, 4) } else { (8000, 4) };
    let dim = 8;
    // ambient count (honors HSS_SVM_THREADS): the oos-smoke CI lane
    // runs the whole binary at 1 and 2 and compares model hashes, so
    // the primary train must follow the env
    let threads = threadpool::default_threads();
    let work = std::env::temp_dir().join(format!("hss_svm_bench_oos_{}", std::process::id()));
    std::fs::create_dir_all(&work).expect("create work dir");
    println!(
        "[oos] n = {n}, dim = {dim}, shards = {shards_k}, threads = {threads}, smoke = {}",
        opts.smoke
    );

    // ---- stage the source file (small: n rows of dim features) ----
    let mut rng = Rng::new(2021);
    let ds = synth::blobs(n + n / 4, dim, 6, 0.4, &mut rng);
    let (train, test) = ds.split_at(n);
    let src = work.join("train.libsvm");
    libsvm::write_file(&train, &src).expect("write source file");
    drop(ds);
    drop(train);

    let mut hss = HssParams::low_accuracy();
    hss.leaf_size = 128;
    let admm = AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 };
    let c = 1.0;

    // ---- sharded training FIRST: VmHWM is a high-water mark, so the
    //      phase under the memory contract must run before anything
    //      bigger touches the heap ----
    let shard_dir = work.join("shards");
    let t = Timer::start();
    let set = ShardSet::open_or_create(&src, &shard_dir, shards_k).expect("shard source");
    let shard_secs = t.secs();
    let (bytes_main, sharded_secs) =
        train_sharded(&set, &hss, admm, c, threads, &work.join("oos_main.model"));
    let model_hash = fnv1a(&bytes_main);
    println!(
        "[oos] shard pass {shard_secs:.3} s, sharded train ({threads} threads) \
         {sharded_secs:.3} s, model hash {model_hash:016x}"
    );

    // ---- memory contract: peak RSS < 1/2 of the dense footprint ----
    let dense_bytes = (n as u64) * (n as u64) * 8;
    let rss_bound = dense_bytes / 2;
    let peak = bench::peak_rss_bytes();
    let rss_fraction = match peak {
        Some(p) => {
            println!(
                "[oos] peak RSS {:.1} MB vs dense kernel {:.1} MB (bound {:.1} MB)",
                p as f64 / 1e6,
                dense_bytes as f64 / 1e6,
                rss_bound as f64 / 1e6
            );
            assert!(
                p < rss_bound,
                "[oos] MEMORY CONTRACT VIOLATED: peak RSS {p} B >= {rss_bound} B \
                 (half the dense kernel footprint)"
            );
            p as f64 / dense_bytes as f64
        }
        None => {
            eprintln!("[oos] no /proc/self/status — peak-RSS contract skipped (non-Linux)");
            f64::NAN
        }
    };

    // ---- determinism: bitwise-equal model across thread counts ----
    let (bytes_t1, _) = train_sharded(&set, &hss, admm, c, 1, &work.join("oos_t1.model"));
    assert_eq!(
        bytes_t1, bytes_main,
        "[oos] DETERMINISM VIOLATED: 1-thread and {threads}-thread sharded models differ"
    );
    println!("[oos] thread invariance: 1-thread model is bitwise identical");

    // ---- determinism: re-shard the same source, retrain ----
    std::fs::remove_dir_all(&shard_dir).expect("drop shard dir");
    let set2 = ShardSet::open_or_create(&src, &shard_dir, shards_k).expect("re-shard source");
    let (bytes_rerun, _) =
        train_sharded(&set2, &hss, admm, c, threads, &work.join("oos_rerun.model"));
    assert_eq!(
        bytes_rerun, bytes_main,
        "[oos] DETERMINISM VIOLATED: re-shard + re-train changed the model"
    );
    println!("[oos] re-shard invariance: re-run model is bitwise identical");

    // ---- speed: in-memory trainer on the same (raw) data ----
    let inmem_ds = libsvm::read_file_with(&src, None, Repr::Auto).expect("read source");
    let t = Timer::start();
    let (inmem_model, _) =
        train_hss_svm(&inmem_ds, Kernel::Gaussian { h: 1.5 }, &hss, &admm, c, threads)
            .expect("in-memory train");
    let inmem_secs = t.secs();
    let consensus_shard_speedup = inmem_secs / sharded_secs.max(1e-12);
    println!(
        "[oos] in-memory train {inmem_secs:.3} s -> consensus_shard_speedup \
         {consensus_shard_speedup:.2}x"
    );

    // sanity: both models actually classify (block-diagonal drop is an
    // approximation, not a lobotomy)
    let sharded_model = persist::load(work.join("oos_main.model")).expect("reload model");
    let acc_sharded = predict::accuracy(&sharded_model, &test, threads);
    let acc_inmem = predict::accuracy(&inmem_model, &test, threads);
    println!("[oos] accuracy: sharded {acc_sharded:.3}, in-memory {acc_inmem:.3}");
    assert!(acc_sharded > 0.75, "[oos] sharded accuracy collapsed: {acc_sharded}");

    if let Some(path) = &opts.json {
        let mut json = String::from("{\n");
        json.push_str(&bench::provenance_json("  "));
        json.push_str(&format!("  \"smoke\": {},\n", opts.smoke));
        json.push_str(&format!("  \"n\": {n},\n"));
        json.push_str(&format!("  \"dim\": {dim},\n"));
        json.push_str(&format!("  \"shards\": {shards_k},\n"));
        json.push_str(&format!("  \"shard_secs\": {shard_secs:.6},\n"));
        json.push_str(&format!("  \"sharded_train_secs\": {sharded_secs:.6},\n"));
        json.push_str(&format!("  \"inmem_train_secs\": {inmem_secs:.6},\n"));
        json.push_str(&format!(
            "  \"consensus_shard_speedup\": {consensus_shard_speedup:.4},\n"
        ));
        json.push_str(&format!("  \"dense_bytes\": {dense_bytes},\n"));
        json.push_str(&format!("  \"peak_rss_bytes\": {},\n", peak.unwrap_or(0)));
        json.push_str(&format!("  \"rss_fraction\": {rss_fraction:.4},\n"));
        json.push_str(&format!("  \"acc_sharded\": {acc_sharded:.4},\n"));
        json.push_str(&format!("  \"acc_inmem\": {acc_inmem:.4},\n"));
        json.push_str(&format!("  \"model_hash\": \"{model_hash:016x}\"\n"));
        json.push_str("}\n");
        let out = from_repo_root(path);
        std::fs::write(&out, json).expect("write bench JSON");
        println!("[oos] wrote {}", out.display());
    }

    if let Some(path) = &opts.baseline {
        let base = Config::load(from_repo_root(path)).expect("read bench baseline");
        // a typoed/missing key must fail loudly, not quietly weaken the gate
        let baseline_key = |key: &str| -> f64 {
            base.get("", key)
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("baseline {path} is missing numeric key {key:?}"))
        };
        let floor = 0.75 * baseline_key("consensus_shard_speedup");
        println!(
            "[oos] baseline gate: consensus_shard_speedup {consensus_shard_speedup:.2}x \
             (floor {floor:.2}x)"
        );
        if consensus_shard_speedup < floor {
            eprintln!(
                "[oos] REGRESSION: consensus_shard_speedup {consensus_shard_speedup:.2}x \
                 fell >25% below the committed baseline"
            );
            std::process::exit(1);
        }
    }

    std::fs::remove_dir_all(&work).ok();
}
