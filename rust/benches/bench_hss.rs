//! HSS scaling benchmarks: compression / factorization / solve versus n,
//! validating the paper's complexity claims (O(r²d) construction, O(rd)
//! memory, O(rd)-ish solves), the two batching/parallelism tentpoles
//! (batched C-grid vs sequential runs; level-scheduled parallel tree
//! engine vs the serial sweeps) plus two ablations the DESIGN.md calls
//! out: ANN-guided vs pure-random column sampling, and kmeans vs PCA
//! splits.
//!
//! Flags (CI uses all three — see `.github/workflows/ci.yml`):
//!   --smoke              reduced problem sizes / budgets for PR gating
//!   --json <path>        write the headline metrics as JSON (artifact)
//!   --baseline <path>    TOML (key = value) with the committed speedup
//!                        floors; exit nonzero on a >25% regression

use hss_svm::admm::{AdmmParams, AdmmSolver};
use hss_svm::cluster::SplitMethod;
use hss_svm::config::Config;
use hss_svm::coordinator::GridSearch;
use hss_svm::data::{synth, CsrMat, Points};
use hss_svm::hss::compress::compress;
use hss_svm::hss::matvec;
use hss_svm::hss::ulv::UlvFactor;
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::svm::MultilevelParams;
use hss_svm::util::bench::Bench;
use hss_svm::util::prng::Rng;
use hss_svm::util::threadpool;
use hss_svm::util::timer::Timer;
use std::time::Duration;

struct Opts {
    smoke: bool,
    json: Option<String>,
    baseline: Option<String>,
}

/// Cargo runs bench binaries with cwd = the package dir (`rust/`), not
/// the workspace root; resolve relative paths against the repository
/// root so CI and the README can both say `ci/bench_baseline.toml`.
fn from_repo_root(p: &str) -> std::path::PathBuf {
    let path = std::path::Path::new(p);
    if path.is_absolute() {
        path.to_path_buf()
    } else {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(path)
    }
}

fn parse_opts() -> Opts {
    let mut opts = Opts { smoke: false, json: None, baseline: None };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => opts.smoke = true,
            "--json" => opts.json = args.next(),
            "--baseline" => opts.baseline = args.next(),
            other => eprintln!("[hss] ignoring unknown flag {other:?}"),
        }
    }
    opts
}

fn main() {
    let opts = parse_opts();
    let threads = threadpool::default_threads();
    let mut rng = Rng::new(7);
    let budget = if opts.smoke { Duration::from_millis(200) } else { Duration::from_secs(1) };
    let mut b = Bench::new(budget);
    println!("[hss] threads = {threads}, smoke = {}\n", opts.smoke);

    let kernel = Kernel::Gaussian { h: 1.5 };

    // --- scaling in n (near-linear is the paper's claim) ---
    println!("-- scaling (low-accuracy params, blobs dim 8) --");
    let scaling_ns: &[usize] = if opts.smoke { &[1000, 2000] } else { &[1000, 2000, 4000, 8000] };
    for &n in scaling_ns {
        let ds = synth::blobs(n, 8, 6, 0.3, &mut rng);
        let p = HssParams::low_accuracy();

        let t = Timer::start();
        let c = compress(&ds, &kernel, &p, threads);
        b.record_once(&format!("compress n={n}"), t.elapsed());
        println!(
            "    -> memory {:.2} MB ({:.1} KB/point), max rank {}, {:.1}% of K evaluated",
            c.stats.memory_bytes as f64 / 1e6,
            c.stats.memory_bytes as f64 / 1e3 / n as f64,
            c.stats.max_rank,
            100.0 * c.stats.kernel_evals as f64 / (n as f64 * n as f64),
        );

        let t = Timer::start();
        let ulv = UlvFactor::new(&c.hss, 100.0).unwrap();
        b.record_once(&format!("ulv factor n={n}"), t.elapsed());

        let x: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        b.run(&format!("hss matvec n={n}"), || {
            std::hint::black_box(matvec::matvec(&c.hss, &x));
        });
        b.run(&format!("ulv solve n={n}"), || {
            std::hint::black_box(ulv.solve(&x));
        });

        // full ADMM train for one C (the paper's "ADMM Time" column)
        let admm = AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 };
        let solver = AdmmSolver::new(&ulv, &c.pds.y, admm);
        b.run(&format!("admm 10 iters n={n}"), || {
            std::hint::black_box(solver.run(1.0));
        });
    }

    // --- batched C-grid: run_grid vs k sequential runs ---
    // The tentpole reuse claim: with the compression + factorization
    // amortized, advancing all k values of C in lockstep through one
    // blocked multi-RHS ULV sweep per iteration beats k scalar ADMM
    // runs. Verified to agree within 1e-10 (bitwise at relax = 1).
    let n_grid = if opts.smoke { 1000 } else { 2000 };
    println!("\n-- batched C-grid vs sequential runs (n={n_grid}, near_exact, 1 thread) --");
    let dsg = synth::blobs(n_grid, 6, 5, 0.3, &mut rng);
    let mut pg = HssParams::near_exact();
    pg.leaf_size = 64;
    let t = Timer::start();
    let comp = compress(&dsg, &kernel, &pg, 1);
    b.record_once(&format!("grid: compress n={n_grid} near_exact"), t.elapsed());
    let beta = 100.0;
    let t = Timer::start();
    let ulv_g = UlvFactor::new(&comp.hss, beta).unwrap();
    b.record_once("grid: ulv factor", t.elapsed());
    let admm_g = AdmmParams { beta, max_it: 10, relax: 1.0, tol: 0.0 };
    let solver_g = AdmmSolver::new(&ulv_g, &comp.pds.y, admm_g);
    let cs: Vec<f64> = (0..8).map(|i| 0.05 * 2.0f64.powi(i)).collect();

    let t = Timer::start();
    let seq: Vec<_> = cs.iter().map(|&cv| solver_g.run(cv)).collect();
    let seq_secs = t.secs();
    let t = Timer::start();
    let batched = solver_g.run_grid(&cs);
    let batch_secs = t.secs();

    let mut max_dev = 0.0f64;
    for (s, bt) in seq.iter().zip(batched.iter()) {
        for (a, z) in s.z.iter().zip(bt.z.iter()) {
            max_dev = max_dev.max((a - z).abs());
        }
    }
    assert!(
        max_dev <= 1e-10,
        "batched C-grid deviates from the sequential path: max |Δz| = {max_dev:.3e}"
    );
    let batched_speedup = seq_secs / batch_secs;
    println!(
        "    8 × run       {seq_secs:>8.3} s\n    1 × run_grid  {batch_secs:>8.3} s   \
         ({batched_speedup:.2}x speedup, max |Δz| = {max_dev:.1e})"
    );

    // --- level-scheduled tree engine: serial vs parallel factor +
    //     grid-train (the ISSUE-2 tentpole's headline numbers) ---
    let par_threads = threads.clamp(2, 8);
    let n_par = if opts.smoke { 2000 } else { 8000 };
    println!(
        "\n-- tree-parallel engine: factor + C-grid train, 1 vs {par_threads} threads \
         (n={n_par}) --"
    );
    let dsp = synth::blobs(n_par, 8, 6, 0.3, &mut rng);
    let pp = HssParams::low_accuracy();
    let compp = compress(&dsp, &kernel, &pp, par_threads);
    let beta_p = 100.0;
    let admm_p = AdmmParams { beta: beta_p, max_it: 10, relax: 1.0, tol: 0.0 };
    let cs_p: Vec<f64> = (0..8).map(|i| 0.05 * 2.0f64.powi(i)).collect();

    let t = Timer::start();
    let ulv_serial = UlvFactor::new_threaded(&compp.hss, beta_p, 1).unwrap();
    let serial_factor = t.secs();
    let solver_serial = AdmmSolver::new(&ulv_serial, &compp.pds.y, admm_p).with_threads(1);
    let t = Timer::start();
    let outs_serial = solver_serial.run_grid(&cs_p);
    let serial_grid = t.secs();

    let t = Timer::start();
    let ulv_par = UlvFactor::new_threaded(&compp.hss, beta_p, par_threads).unwrap();
    let par_factor = t.secs();
    let solver_par =
        AdmmSolver::new(&ulv_par, &compp.pds.y, admm_p).with_threads(par_threads);
    let t = Timer::start();
    let outs_par = solver_par.run_grid(&cs_p);
    let par_grid = t.secs();

    // the thread-invariance contract: AdmmOutput must be bitwise equal
    for (s, p) in outs_serial.iter().zip(outs_par.iter()) {
        assert_eq!(s.z, p.z, "parallel C-grid z deviates from serial");
        assert_eq!(s.x, p.x, "parallel C-grid x deviates from serial");
        assert_eq!(s.mu, p.mu, "parallel C-grid mu deviates from serial");
    }
    let parallel_speedup = (serial_factor + serial_grid) / (par_factor + par_grid).max(1e-12);
    b.record_once(
        "engine: factor+grid 1 thread",
        Duration::from_secs_f64(serial_factor + serial_grid),
    );
    b.record_once(
        &format!("engine: factor+grid {par_threads} threads"),
        Duration::from_secs_f64(par_factor + par_grid),
    );
    println!(
        "    factor   {serial_factor:>8.3} s → {par_factor:>8.3} s\n    \
         grid     {serial_grid:>8.3} s → {par_grid:>8.3} s\n    \
         combined {parallel_speedup:.2}x speedup at {par_threads} threads \
         (bitwise-identical outputs)"
    );

    // --- sparse data plane: CSR vs dense kernel blocks + predict ---
    // The paper's sparse Table-1 inputs (a8a/w7a/rcv1-like): wide rows,
    // ~2% density. The xᵀy term of the kernel block is where sparsity
    // pays; the gate below keeps the CSR path from regressing to (or
    // below) dense speed.
    let (n_sp, dim_sp) = if opts.smoke { (384, 768) } else { (1024, 2048) };
    let density = 0.02;
    println!("\n-- sparse data plane: CSR vs dense ({n_sp}x{dim_sp}, {density} density) --");
    let mut sp_rng = Rng::new(11);
    let sp_rows: Vec<Vec<(usize, f64)>> = (0..n_sp)
        .map(|_| {
            (0..dim_sp)
                .filter(|_| sp_rng.f64() < density)
                .map(|c| (c, sp_rng.gauss()))
                .collect()
        })
        .collect();
    let csr = CsrMat::from_rows(dim_sp, &sp_rows);
    let sparse_mem_ratio = (n_sp * dim_sp * 8) as f64 / csr.bytes() as f64;
    let xd = Points::Dense(csr.to_dense());
    let xs = Points::Sparse(csr);
    let t = Timer::start();
    let kb_dense = hss_svm::kernel::kernel_block_pts(&kernel, &xd, &xd);
    let dense_block_secs = t.secs();
    let t = Timer::start();
    let kb_sparse = hss_svm::kernel::kernel_block_pts(&kernel, &xs, &xs);
    let sparse_block_secs = t.secs();
    let mut block_dev = 0.0f64;
    for (a, b) in kb_dense.data().iter().zip(kb_sparse.data().iter()) {
        block_dev = block_dev.max((a - b).abs());
    }
    assert!(block_dev <= 1e-12, "sparse kernel block deviates: {block_dev:.3e}");
    let sparse_block_speedup = dense_block_secs / sparse_block_secs.max(1e-12);
    b.record_once("sparse: dense kernel block", Duration::from_secs_f64(dense_block_secs));
    b.record_once("sparse: CSR kernel block", Duration::from_secs_f64(sparse_block_secs));

    // predict over a CSR-SV model vs its dense twin
    let n_sv_sp = n_sp / 4;
    let sv_idx: Vec<usize> = (0..n_sv_sp).collect();
    let alpha: Vec<f64> = (0..n_sv_sp).map(|_| sp_rng.gauss()).collect();
    let mk_model = |sv: Points| hss_svm::svm::SvmModel {
        sv,
        alpha_y: alpha.clone(),
        bias: 0.1,
        kernel,
        c: 1.0,
        labels: hss_svm::data::DEFAULT_LABEL_PAIR,
    };
    let model_d = mk_model(xd.select_rows(&sv_idx));
    let model_s = mk_model(xs.select_rows(&sv_idx));
    let t = Timer::start();
    let fd = hss_svm::svm::predict::decision_function(&model_d, &xd, threads);
    let dense_predict_secs = t.secs();
    let t = Timer::start();
    let fs = hss_svm::svm::predict::decision_function(&model_s, &xs, threads);
    let sparse_predict_secs = t.secs();
    let mut predict_dev = 0.0f64;
    for (a, bb) in fd.iter().zip(fs.iter()) {
        predict_dev = predict_dev.max((a - bb).abs());
    }
    assert!(predict_dev <= 1e-12, "sparse predict deviates: {predict_dev:.3e}");
    let sparse_predict_speedup = dense_predict_secs / sparse_predict_secs.max(1e-12);
    b.record_once("sparse: dense predict", Duration::from_secs_f64(dense_predict_secs));
    b.record_once("sparse: CSR predict", Duration::from_secs_f64(sparse_predict_secs));
    println!(
        "    kernel block  {dense_block_secs:>8.3} s → {sparse_block_secs:>8.3} s \
         ({sparse_block_speedup:.2}x, max |Δ| = {block_dev:.1e})\n    \
         predict       {dense_predict_secs:>8.3} s → {sparse_predict_secs:>8.3} s \
         ({sparse_predict_speedup:.2}x, max |Δ| = {predict_dev:.1e})\n    \
         memory        {sparse_mem_ratio:.1}x smaller in CSR"
    );

    // --- OvO multiclass: shared-SV engine vs naive per-pair predict ---
    // A 5-class blob set → 10 pairwise models whose SVs overlap heavily
    // (every training point sits in 4 subproblems). The naive path pays
    // one kernel block per pair per tile; the engine dedups the SVs
    // into one pool and pays ONE block per tile, reducing each pair as
    // a sparse gather. The gate keeps that structural advantage from
    // regressing (engine falling to or below naive speed).
    let (n_ovo, n_ovo_test) = if opts.smoke { (600, 1200) } else { (1500, 6000) };
    println!(
        "\n-- OvO multiclass: shared-SV engine vs naive per-pair \
         (5 classes, train {n_ovo}, test {n_ovo_test}) --"
    );
    let mut ovo_rng = Rng::new(13);
    let ds_ovo = synth::multiclass_blobs(n_ovo, 4, 5, 0.45, &mut ovo_rng);
    let test_ovo = synth::multiclass_blobs(n_ovo_test, 4, 5, 0.45, &mut ovo_rng);
    let mut hp_ovo = HssParams::near_exact();
    hp_ovo.leaf_size = 64;
    let admm_ovo = AdmmParams { beta: 10.0, max_it: 10, relax: 1.0, tol: 0.0 };
    let t = Timer::start();
    let (ovo_model, _) = hss_svm::svm::multiclass::train_ovo(
        &ds_ovo,
        kernel,
        &hp_ovo,
        &admm_ovo,
        5.0,
        threads,
    )
    .expect("ovo training");
    b.record_once("ovo: train 10 pairs", t.elapsed());
    let sv_ratio = ovo_model.n_sv_total() as f64 / ovo_model.n_sv_unique().max(1) as f64;
    let t = Timer::start();
    let f_naive = ovo_model.decisions_naive(&test_ovo.x, threads);
    let naive_predict_secs = t.secs();
    let t = Timer::start();
    let f_shared = ovo_model.decisions(&test_ovo.x, threads);
    let shared_predict_secs = t.secs();
    let mut ovo_dev = 0.0f64;
    for (a, bb) in f_shared.data().iter().zip(f_naive.data().iter()) {
        ovo_dev = ovo_dev.max((a - bb).abs() / (1.0 + bb.abs()));
    }
    assert!(ovo_dev <= 1e-12, "shared-SV engine deviates from per-pair path: {ovo_dev:.3e}");
    let ovo_shared_sv_speedup = naive_predict_secs / shared_predict_secs.max(1e-12);
    b.record_once("ovo: naive per-pair predict", Duration::from_secs_f64(naive_predict_secs));
    b.record_once("ovo: shared-SV predict", Duration::from_secs_f64(shared_predict_secs));
    println!(
        "    SVs           {} total → {} unique ({sv_ratio:.2}x shared)\n    \
         predict       {naive_predict_secs:>8.3} s → {shared_predict_secs:>8.3} s \
         ({ovo_shared_sv_speedup:.2}x, max rel |Δ| = {ovo_dev:.1e})",
        ovo_model.n_sv_total(),
        ovo_model.n_sv_unique()
    );

    // --- observability: traced vs untraced train (DESIGN.md §14) ---
    // The passivity contract has a cost clause: a fully traced train
    // (file-backed JSONL sink, every event on) must stay within the
    // committed overhead ceiling (`obs_overhead_pct` in
    // ci/bench_baseline.toml, a CEILING — not a speedup floor).
    // Off/on runs interleave and each side takes its best-of, so
    // thermal drift hits both sides equally.
    let n_obs = if opts.smoke { 1200 } else { 4000 };
    println!("\n-- observability: traced vs untraced train (n={n_obs}) --");
    let ds_obs = synth::blobs(n_obs, 8, 6, 0.3, &mut rng);
    let hp_obs = HssParams::low_accuracy();
    let admm_obs = AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 };
    let trace_path =
        std::env::temp_dir().join(format!("hss_bench_trace_{}.jsonl", std::process::id()));
    let obs_reps = if opts.smoke { 3 } else { 5 };
    let mut obs_off_secs = f64::INFINITY;
    let mut obs_on_secs = f64::INFINITY;
    let mut phases_obs: Vec<(String, f64, u64)> = Vec::new();
    for _ in 0..obs_reps {
        assert!(!hss_svm::obs::enabled());
        let t = Timer::start();
        let (_m, stats) =
            hss_svm::svm::train::train_hss_svm(&ds_obs, kernel, &hp_obs, &admm_obs, 1.0, threads)
                .expect("untraced train");
        let secs = t.secs();
        if secs < obs_off_secs {
            obs_off_secs = secs;
            phases_obs = stats.phases.clone();
        }

        hss_svm::obs::trace::init_path(trace_path.to_str().unwrap()).expect("trace sink");
        let t = Timer::start();
        let (_m, _stats) =
            hss_svm::svm::train::train_hss_svm(&ds_obs, kernel, &hp_obs, &admm_obs, 1.0, threads)
                .expect("traced train");
        let secs = t.secs();
        hss_svm::obs::trace::disable();
        obs_on_secs = obs_on_secs.min(secs);
    }
    let trace_bytes = std::fs::metadata(&trace_path).map(|m| m.len()).unwrap_or(0);
    std::fs::remove_file(&trace_path).ok();
    let obs_overhead_pct = 100.0 * (obs_on_secs - obs_off_secs) / obs_off_secs.max(1e-12);
    b.record_once("obs: untraced train", Duration::from_secs_f64(obs_off_secs));
    b.record_once("obs: traced train", Duration::from_secs_f64(obs_on_secs));
    println!(
        "    untraced  {obs_off_secs:>8.3} s\n    traced    {obs_on_secs:>8.3} s   \
         ({obs_overhead_pct:+.2}% overhead, {:.1} KB trace)",
        trace_bytes as f64 / 1e3
    );

    // --- multilevel coarse-to-fine vs flat grid (DESIGN.md §15) ---
    // Equal-accuracy contract checked right here: the coarse-to-fine
    // schedule must match the flat grid's best accuracy within half a
    // point on the fixed-center XOR-blob layout (whose separability is
    // seed-independent, unlike `synth::blobs`), while training only SV
    // neighborhoods past the coarse level. The wall-clock ratio gates
    // against `multilevel_speedup` in ci/bench_baseline.toml below.
    let (n_ml, n_ml_test) = if opts.smoke { (2000, 800) } else { (6000, 2000) };
    println!("\n-- multilevel coarse-to-fine vs flat grid (n={n_ml}, 3 h values, 8 C values) --");
    let mut ml_rng = Rng::new(23);
    let ds_ml = synth::xor_blobs(n_ml + n_ml_test, 4, 0.35, &mut ml_rng);
    let (train_ml, test_ml) = ds_ml.split_at(n_ml);
    let mut hp_ml = HssParams::low_accuracy();
    hp_ml.leaf_size = 48;
    let grid_ml = GridSearch {
        h_values: vec![0.8, 1.2, 2.0],
        c_values: (0..8).map(|i| 0.05 * 2.0f64.powi(i)).collect(),
        hss: hp_ml,
        admm: AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 },
        threads,
    };
    let t = Timer::start();
    let flat_res = grid_ml.run(&train_ml, &test_ml).expect("flat grid");
    let ml_flat_secs = t.secs();
    let t = Timer::start();
    let (ml_res, ml_per_h) = grid_ml
        .run_multilevel(&train_ml, &test_ml, &MultilevelParams::default())
        .expect("multilevel grid");
    let ml_secs = t.secs();
    let ml_acc_delta = (flat_res.best_accuracy - ml_res.best_accuracy).abs();
    assert!(
        ml_acc_delta <= 0.005,
        "multilevel best accuracy {:.4} deviates from flat {:.4} beyond 0.5 pt",
        ml_res.best_accuracy,
        flat_res.best_accuracy
    );
    let ml_points_trained: usize =
        ml_per_h.iter().flat_map(|(_, ls)| ls.iter().map(|l| l.n_points)).sum();
    let ml_levels: usize = ml_per_h.first().map(|(_, ls)| ls.len()).unwrap_or(0);
    let multilevel_speedup = ml_flat_secs / ml_secs.max(1e-12);
    b.record_once("multilevel: flat grid", Duration::from_secs_f64(ml_flat_secs));
    b.record_once("multilevel: coarse-to-fine grid", Duration::from_secs_f64(ml_secs));
    println!(
        "    flat grid       {ml_flat_secs:>8.3} s   (best acc {:.4})\n    \
         coarse-to-fine  {ml_secs:>8.3} s   ({multilevel_speedup:.2}x speedup, best acc {:.4}, \
         {ml_levels} levels, {ml_points_trained} pts trained vs {} flat)",
        flat_res.best_accuracy,
        ml_res.best_accuracy,
        grid_ml.h_values.len() * n_ml,
    );

    // --- simd-f32 backend: f32 kernel block + predict tile vs the f64
    //     reference (DESIGN.md §13). Asserts the documented ≤1e-4
    //     relative tolerance on every run; the speedup is gated against
    //     the committed baseline only when the AVX2+FMA path is active
    //     (the scalar-f32 fallback has no speed contract).
    #[cfg(feature = "simd-f32")]
    let simd_metrics: Option<(f64, bool, f64)> = {
        use hss_svm::compute::{ComputeBackend, SimdF32Backend};
        let (m_b, sv_b, d_b) = if opts.smoke { (256, 128, 64) } else { (512, 256, 128) };
        let reps = if opts.smoke { 10 } else { 40 };
        let simd = SimdF32Backend::new();
        println!(
            "\n-- simd-f32 backend: kernel block + predict tile ({m_b}x{sv_b}, dim {d_b}, \
             avx2 {}) --",
            simd.avx2_active()
        );
        let mut srng = Rng::new(17);
        let xq = Points::Dense(hss_svm::linalg::Mat::gauss(m_b, d_b, &mut srng));
        let svp = Points::Dense(hss_svm::linalg::Mat::gauss(sv_b, d_b, &mut srng));
        let cpu_b = hss_svm::compute::cpu();
        let model_f32 = hss_svm::svm::SvmModel {
            sv: svp.clone(),
            alpha_y: (0..sv_b).map(|_| srng.gauss()).collect(),
            bias: 0.05,
            kernel,
            c: 1.0,
            labels: hss_svm::data::DEFAULT_LABEL_PAIR,
        };

        let t = Timer::start();
        for _ in 0..reps {
            std::hint::black_box(cpu_b.kernel_block(&kernel, &xq, &svp));
        }
        let f64_block_secs = t.secs();
        let t = Timer::start();
        for _ in 0..reps {
            std::hint::black_box(simd.kernel_block(&kernel, &xq, &svp));
        }
        let f32_block_secs = t.secs();

        let t = Timer::start();
        for _ in 0..reps {
            std::hint::black_box(hss_svm::svm::predict::decision_function(&model_f32, &xq, 1));
        }
        let f64_predict_secs = t.secs();
        let t = Timer::start();
        for _ in 0..reps {
            std::hint::black_box(hss_svm::svm::predict::decision_function_with(
                &simd, &model_f32, &xq, 1,
            ));
        }
        let f32_predict_secs = t.secs();

        // tolerance contract, checked on the benched shapes themselves
        let kb64 = cpu_b.kernel_block(&kernel, &xq, &svp);
        let kb32 = simd.kernel_block(&kernel, &xq, &svp);
        let f64_dec = hss_svm::svm::predict::decision_function(&model_f32, &xq, 1);
        let f32_dec = hss_svm::svm::predict::decision_function_with(&simd, &model_f32, &xq, 1);
        let mut simd_err = 0.0f64;
        for (a, z) in kb64.data().iter().zip(kb32.data().iter()) {
            simd_err = simd_err.max((a - z).abs() / (1.0 + z.abs()));
        }
        for (a, z) in f64_dec.iter().zip(f32_dec.iter()) {
            simd_err = simd_err.max((a - z).abs() / (1.0 + z.abs()));
        }
        assert!(
            simd_err <= 1e-4,
            "simd-f32 backend deviates beyond the documented tolerance: {simd_err:.3e}"
        );
        let backend_simd_f32_speedup =
            (f64_block_secs + f64_predict_secs) / (f32_block_secs + f32_predict_secs).max(1e-12);
        b.record_once(
            "simd-f32: f64 block+predict",
            Duration::from_secs_f64(f64_block_secs + f64_predict_secs),
        );
        b.record_once(
            "simd-f32: f32 block+predict",
            Duration::from_secs_f64(f32_block_secs + f32_predict_secs),
        );
        println!(
            "    kernel block  {f64_block_secs:>8.3} s → {f32_block_secs:>8.3} s\n    \
             predict       {f64_predict_secs:>8.3} s → {f32_predict_secs:>8.3} s\n    \
             combined      {backend_simd_f32_speedup:.2}x speedup \
             (max rel |Δ| = {simd_err:.1e}, avx2 {})",
            simd.avx2_active()
        );
        Some((backend_simd_f32_speedup, simd.avx2_active(), simd_err))
    };
    #[cfg(not(feature = "simd-f32"))]
    let simd_metrics: Option<(f64, bool, f64)> = None;

    if !opts.smoke {
        // --- ablation: ANN sampling vs pure random ---
        println!("\n-- ablation: column sampling strategy (n=3000) --");
        let ds = synth::blobs(3000, 8, 6, 0.25, &mut rng);
        for (label, ann, oversample) in
            [("ann-guided (paper)", 64usize, 32usize), ("pure-random", 0, 96)]
        {
            let p = HssParams {
                ann_neighbors: ann,
                oversample,
                ..HssParams::low_accuracy()
            };
            let t = Timer::start();
            let c = compress(&ds, &kernel, &p, threads);
            b.record_once(&format!("compress {label}"), t.elapsed());
            let mut err_rng = Rng::new(1);
            let err = matvec::rel_error_probes(&c.hss, &kernel, &c.pds, 3, &mut err_rng);
            println!("    -> rel matvec error {err:.3e}, max rank {}", c.stats.max_rank);
        }

        // --- ablation: split method ---
        println!("\n-- ablation: cluster split method (n=3000) --");
        for (label, split) in [("kmeans", SplitMethod::TwoMeans), ("pca", SplitMethod::Pca)] {
            let p = HssParams { split, ..HssParams::low_accuracy() };
            let t = Timer::start();
            let c = compress(&ds, &kernel, &p, threads);
            b.record_once(&format!("compress split={label}"), t.elapsed());
            println!(
                "    -> memory {:.2} MB, max rank {}",
                c.stats.memory_bytes as f64 / 1e6,
                c.stats.max_rank
            );
        }
    }

    // --- machine-readable artifact + committed-baseline regression gate ---
    if let Some(path) = &opts.json {
        let mut json = String::from("{\n");
        json.push_str(&hss_svm::util::bench::provenance_json("  "));
        json.push_str(&format!("  \"smoke\": {},\n", opts.smoke));
        json.push_str(&format!("  \"threads\": {par_threads},\n"));
        json.push_str(&format!("  \"n_grid\": {n_grid},\n"));
        json.push_str(&format!("  \"n_parallel\": {n_par},\n"));
        json.push_str(&format!("  \"batched_seq_secs\": {seq_secs:.6},\n"));
        json.push_str(&format!("  \"batched_grid_secs\": {batch_secs:.6},\n"));
        json.push_str(&format!("  \"batched_speedup\": {batched_speedup:.4},\n"));
        json.push_str(&format!("  \"serial_factor_secs\": {serial_factor:.6},\n"));
        json.push_str(&format!("  \"serial_grid_secs\": {serial_grid:.6},\n"));
        json.push_str(&format!("  \"parallel_factor_secs\": {par_factor:.6},\n"));
        json.push_str(&format!("  \"parallel_grid_secs\": {par_grid:.6},\n"));
        json.push_str(&format!("  \"parallel_speedup\": {parallel_speedup:.4},\n"));
        json.push_str(&format!("  \"sparse_n\": {n_sp},\n"));
        json.push_str(&format!("  \"sparse_dim\": {dim_sp},\n"));
        json.push_str(&format!("  \"sparse_block_secs\": {sparse_block_secs:.6},\n"));
        json.push_str(&format!("  \"dense_block_secs\": {dense_block_secs:.6},\n"));
        json.push_str(&format!("  \"sparse_block_speedup\": {sparse_block_speedup:.4},\n"));
        json.push_str(&format!("  \"sparse_predict_speedup\": {sparse_predict_speedup:.4},\n"));
        json.push_str(&format!("  \"sparse_mem_ratio\": {sparse_mem_ratio:.2},\n"));
        json.push_str(&format!("  \"ovo_n_train\": {n_ovo},\n"));
        json.push_str(&format!("  \"ovo_n_test\": {n_ovo_test},\n"));
        json.push_str(&format!("  \"ovo_sv_total\": {},\n", ovo_model.n_sv_total()));
        json.push_str(&format!("  \"ovo_sv_unique\": {},\n", ovo_model.n_sv_unique()));
        json.push_str(&format!("  \"ovo_naive_predict_secs\": {naive_predict_secs:.6},\n"));
        json.push_str(&format!("  \"ovo_shared_predict_secs\": {shared_predict_secs:.6},\n"));
        json.push_str(&format!("  \"ovo_shared_sv_speedup\": {ovo_shared_sv_speedup:.4},\n"));
        json.push_str(&format!("  \"ovo_max_rel_dev\": {ovo_dev:.3e},\n"));
        json.push_str(&format!("  \"obs_untraced_secs\": {obs_off_secs:.6},\n"));
        json.push_str(&format!("  \"obs_traced_secs\": {obs_on_secs:.6},\n"));
        json.push_str(&format!("  \"obs_overhead_pct\": {obs_overhead_pct:.4},\n"));
        json.push_str(&format!("  \"obs_trace_bytes\": {trace_bytes},\n"));
        json.push_str(&format!("  \"multilevel_n\": {n_ml},\n"));
        json.push_str(&format!("  \"multilevel_flat_secs\": {ml_flat_secs:.6},\n"));
        json.push_str(&format!("  \"multilevel_ml_secs\": {ml_secs:.6},\n"));
        json.push_str(&format!("  \"multilevel_speedup\": {multilevel_speedup:.4},\n"));
        json.push_str(&format!("  \"multilevel_flat_acc\": {:.6},\n", flat_res.best_accuracy));
        json.push_str(&format!("  \"multilevel_acc\": {:.6},\n", ml_res.best_accuracy));
        json.push_str(&format!("  \"multilevel_acc_delta\": {ml_acc_delta:.6},\n"));
        json.push_str(&format!("  \"multilevel_levels\": {ml_levels},\n"));
        json.push_str(&format!("  \"multilevel_points_trained\": {ml_points_trained},\n"));
        // phase breakdown of the best untraced train (PhaseTimer rows)
        for (name, secs, _count) in &phases_obs {
            json.push_str(&format!("  \"phase_{name}_secs\": {secs:.6},\n"));
        }
        if let Some((sp, avx2, err)) = simd_metrics {
            json.push_str(&format!("  \"backend_simd_f32_speedup\": {sp:.4},\n"));
            json.push_str(&format!("  \"backend_simd_f32_avx2\": {avx2},\n"));
            json.push_str(&format!("  \"backend_simd_f32_max_rel_err\": {err:.3e},\n"));
        }
        json.push_str(&format!("  \"max_dev\": {max_dev:.3e}\n"));
        json.push_str("}\n");
        let out = from_repo_root(path);
        std::fs::write(&out, json).expect("write bench JSON");
        println!("\n[hss] wrote {}", out.display());
    }
    if let Some(path) = &opts.baseline {
        let base = Config::load(from_repo_root(path)).expect("read bench baseline");
        // a typoed/missing key must fail loudly, not quietly weaken the gate
        let baseline_key = |key: &str| -> f64 {
            base.get("", key)
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("baseline {path} is missing numeric key {key:?}"))
        };
        let floor_batched = 0.75 * baseline_key("batched_speedup");
        let floor_parallel = 0.75 * baseline_key("parallel_speedup");
        let floor_sparse = 0.75 * baseline_key("sparse_block_speedup");
        let floor_ovo = 0.75 * baseline_key("ovo_shared_sv_speedup");
        let floor_multilevel = 0.75 * baseline_key("multilevel_speedup");
        println!(
            "\n[hss] baseline gate: batched {batched_speedup:.2}x (floor {floor_batched:.2}x), \
             parallel {parallel_speedup:.2}x (floor {floor_parallel:.2}x), \
             sparse block {sparse_block_speedup:.2}x (floor {floor_sparse:.2}x), \
             ovo shared-SV {ovo_shared_sv_speedup:.2}x (floor {floor_ovo:.2}x), \
             multilevel {multilevel_speedup:.2}x (floor {floor_multilevel:.2}x)"
        );
        let mut failed = false;
        if multilevel_speedup < floor_multilevel {
            eprintln!(
                "[hss] REGRESSION: multilevel coarse-to-fine speedup {multilevel_speedup:.2}x \
                 fell >25% below the committed baseline"
            );
            failed = true;
        }
        if ovo_shared_sv_speedup < floor_ovo {
            eprintln!(
                "[hss] REGRESSION: OvO shared-SV predict speedup {ovo_shared_sv_speedup:.2}x \
                 fell >25% below the committed baseline"
            );
            failed = true;
        }
        if sparse_block_speedup < floor_sparse {
            eprintln!(
                "[hss] REGRESSION: CSR kernel-block speedup {sparse_block_speedup:.2}x fell >25% \
                 below the committed baseline"
            );
            failed = true;
        }
        if batched_speedup < floor_batched {
            eprintln!(
                "[hss] REGRESSION: batched C-grid speedup {batched_speedup:.2}x fell >25% below \
                 the committed baseline"
            );
            failed = true;
        }
        if parallel_speedup < floor_parallel {
            eprintln!(
                "[hss] REGRESSION: tree-parallel speedup {parallel_speedup:.2}x fell >25% below \
                 the committed baseline"
            );
            failed = true;
        }
        // `_pct`-suffixed baseline keys are CEILINGS: the measured value
        // must not exceed the committed number (no 0.75 slack — the
        // ceiling itself already holds the tolerance).
        let ceil_obs = baseline_key("obs_overhead_pct");
        println!(
            "[hss] obs gate: tracing overhead {obs_overhead_pct:+.2}% \
             (ceiling {ceil_obs:.2}%)"
        );
        if obs_overhead_pct > ceil_obs {
            eprintln!(
                "[hss] REGRESSION: tracing overhead {obs_overhead_pct:.2}% exceeds the \
                 committed {ceil_obs:.2}% ceiling"
            );
            failed = true;
        }
        if let Some((sp, avx2, _)) = simd_metrics {
            // Enforced only on AVX2 hosts: the scalar-f32 fallback
            // keeps the tolerance contract (asserted above) but has no
            // speed contract over the f64 gemm path.
            let floor_simd = 0.75 * baseline_key("backend_simd_f32_speedup");
            if avx2 && sp < floor_simd {
                eprintln!(
                    "[hss] REGRESSION: simd-f32 backend speedup {sp:.2}x fell >25% below the \
                     committed baseline"
                );
                failed = true;
            } else if !avx2 {
                println!(
                    "[hss] simd-f32 gate skipped: AVX2+FMA not detected \
                     (scalar fallback, speedup {sp:.2}x)"
                );
            }
        }
        if failed {
            std::process::exit(1);
        }
    }
}
