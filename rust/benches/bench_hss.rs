//! HSS scaling benchmarks: compression / factorization / solve versus n,
//! validating the paper's complexity claims (O(r²d) construction, O(rd)
//! memory, O(rd)-ish solves) plus two ablations the DESIGN.md calls out:
//! ANN-guided vs pure-random column sampling, and kmeans vs PCA splits.

use hss_svm::admm::{AdmmParams, AdmmSolver};
use hss_svm::cluster::SplitMethod;
use hss_svm::data::synth;
use hss_svm::hss::compress::compress;
use hss_svm::hss::matvec;
use hss_svm::hss::ulv::UlvFactor;
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::util::bench::Bench;
use hss_svm::util::prng::Rng;
use hss_svm::util::threadpool;
use hss_svm::util::timer::Timer;
use std::time::Duration;

fn main() {
    let threads = threadpool::default_threads();
    let mut rng = Rng::new(7);
    let mut b = Bench::new(Duration::from_secs(1));
    println!("[hss] threads = {threads}\n");

    let kernel = Kernel::Gaussian { h: 1.5 };

    // --- scaling in n (near-linear is the paper's claim) ---
    println!("-- scaling (low-accuracy params, blobs dim 8) --");
    for &n in &[1000usize, 2000, 4000, 8000] {
        let ds = synth::blobs(n, 8, 6, 0.3, &mut rng);
        let p = HssParams::low_accuracy();

        let t = Timer::start();
        let c = compress(&ds, &kernel, &p, threads);
        b.record_once(&format!("compress n={n}"), t.elapsed());
        println!(
            "    -> memory {:.2} MB ({:.1} KB/point), max rank {}, {:.1}% of K evaluated",
            c.stats.memory_bytes as f64 / 1e6,
            c.stats.memory_bytes as f64 / 1e3 / n as f64,
            c.stats.max_rank,
            100.0 * c.stats.kernel_evals as f64 / (n as f64 * n as f64),
        );

        let t = Timer::start();
        let ulv = UlvFactor::new(&c.hss, 100.0).unwrap();
        b.record_once(&format!("ulv factor n={n}"), t.elapsed());

        let x: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        b.run(&format!("hss matvec n={n}"), || {
            std::hint::black_box(matvec::matvec(&c.hss, &x));
        });
        b.run(&format!("ulv solve n={n}"), || {
            std::hint::black_box(ulv.solve(&x));
        });

        // full ADMM train for one C (the paper's "ADMM Time" column)
        let admm = AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 };
        let solver = AdmmSolver::new(&ulv, &c.pds.y, admm);
        b.run(&format!("admm 10 iters n={n}"), || {
            std::hint::black_box(solver.run(1.0));
        });
    }

    // --- batched C-grid: run_grid vs k sequential runs ---
    // The tentpole reuse claim: with the compression + factorization
    // amortized, advancing all k values of C in lockstep through one
    // blocked multi-RHS ULV sweep per iteration beats k scalar ADMM
    // runs. Verified to agree within 1e-10 (bitwise at relax = 1).
    println!("\n-- batched C-grid vs sequential runs (n=2000, near_exact, 1 thread) --");
    let dsg = synth::blobs(2000, 6, 5, 0.3, &mut rng);
    let mut pg = HssParams::near_exact();
    pg.leaf_size = 64;
    let t = Timer::start();
    let comp = compress(&dsg, &kernel, &pg, 1);
    b.record_once("grid: compress n=2000 near_exact", t.elapsed());
    let beta = 100.0;
    let t = Timer::start();
    let ulv_g = UlvFactor::new(&comp.hss, beta).unwrap();
    b.record_once("grid: ulv factor", t.elapsed());
    let admm_g = AdmmParams { beta, max_it: 10, relax: 1.0, tol: 0.0 };
    let solver_g = AdmmSolver::new(&ulv_g, &comp.pds.y, admm_g);
    let cs: Vec<f64> = (0..8).map(|i| 0.05 * 2.0f64.powi(i)).collect();

    let t = Timer::start();
    let seq: Vec<_> = cs.iter().map(|&cv| solver_g.run(cv)).collect();
    let seq_secs = t.secs();
    let t = Timer::start();
    let batched = solver_g.run_grid(&cs);
    let batch_secs = t.secs();

    let mut max_dev = 0.0f64;
    for (s, bt) in seq.iter().zip(batched.iter()) {
        for (a, z) in s.z.iter().zip(bt.z.iter()) {
            max_dev = max_dev.max((a - z).abs());
        }
    }
    assert!(
        max_dev <= 1e-10,
        "batched C-grid deviates from the sequential path: max |Δz| = {max_dev:.3e}"
    );
    println!(
        "    8 × run       {seq_secs:>8.3} s\n    1 × run_grid  {batch_secs:>8.3} s   \
         ({:.2}x speedup, max |Δz| = {max_dev:.1e})",
        seq_secs / batch_secs
    );

    // --- ablation: ANN sampling vs pure random ---
    println!("\n-- ablation: column sampling strategy (n=3000) --");
    let ds = synth::blobs(3000, 8, 6, 0.25, &mut rng);
    for (label, ann, oversample) in
        [("ann-guided (paper)", 64usize, 32usize), ("pure-random", 0, 96)]
    {
        let p = HssParams {
            ann_neighbors: ann,
            oversample,
            ..HssParams::low_accuracy()
        };
        let t = Timer::start();
        let c = compress(&ds, &kernel, &p, threads);
        b.record_once(&format!("compress {label}"), t.elapsed());
        let mut err_rng = Rng::new(1);
        let err = matvec::rel_error_probes(&c.hss, &kernel, &c.pds, 3, &mut err_rng);
        println!("    -> rel matvec error {err:.3e}, max rank {}", c.stats.max_rank);
    }

    // --- ablation: split method ---
    println!("\n-- ablation: cluster split method (n=3000) --");
    for (label, split) in [("kmeans", SplitMethod::TwoMeans), ("pca", SplitMethod::Pca)] {
        let p = HssParams { split, ..HssParams::low_accuracy() };
        let t = Timer::start();
        let c = compress(&ds, &kernel, &p, threads);
        b.record_once(&format!("compress split={label}"), t.elapsed());
        println!(
            "    -> memory {:.2} MB, max rank {}",
            c.stats.memory_bytes as f64 / 1e6,
            c.stats.max_rank
        );
    }
}
