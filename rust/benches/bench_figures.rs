//! Regenerates the paper's FIGURES at bench scale.
//!
//! Figure 1 left  — singular-value decay of the Gaussian kernel vs h,
//! Figure 1 right — off-diagonal block rank with/without clustering,
//! Figure 2       — accuracy heatmaps over the (h, C) grid for a9a-like
//!                  and ijcnn1-like workloads.

use hss_svm::eval::figures;
use hss_svm::util::threadpool;
use hss_svm::util::timer::Timer;

fn main() {
    let threads = threadpool::default_threads();
    let scale: f64 = std::env::var("HSS_SVM_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.005);
    println!("[figures] scale={scale} threads={threads}\n");

    let t = Timer::start();
    let (decay, ranks) = figures::fig1(2021);
    println!("{}", decay.render());
    println!("{}", ranks.render());
    println!("[fig1 wall time: {:.1}s]\n", t.secs());

    let t = Timer::start();
    match figures::fig2(scale, 2021, threads) {
        Ok(heatmaps) => {
            for (name, heat, table) in heatmaps {
                println!("--- Figure 2: {name}-like ---");
                println!("{heat}");
                std::fs::create_dir_all("results/bench").ok();
                table.write_csv(format!("results/bench/fig2_{name}.csv")).ok();
            }
        }
        Err(e) => eprintln!("fig2 failed: {e:#}"),
    }
    println!("[fig2 wall time: {:.1}s]", t.secs());

    std::fs::create_dir_all("results/bench").ok();
    decay.write_csv("results/bench/fig1_decay.csv").ok();
    ranks.write_csv("results/bench/fig1_ranks.csv").ok();
    println!("\nCSV written to results/bench/");
}
