//! Micro-benchmarks of the substrate hot paths: gemm, QR, kernel block
//! evaluation (native vs PJRT), ADMM vector ops. These are the pieces
//! the §Perf pass optimizes; EXPERIMENTS.md records before/after.

use hss_svm::kernel::{kernel_block, kernel_block_par, Kernel};
use hss_svm::linalg::qr::Qr;
use hss_svm::linalg::{matmul, matmul_par, Mat, Trans};
use hss_svm::runtime::PjrtRuntime;
use hss_svm::util::bench::Bench;
use hss_svm::util::prng::Rng;
use hss_svm::util::threadpool;
use std::time::Duration;

fn main() {
    let mut rng = Rng::new(1);
    let threads = threadpool::default_threads();
    let mut b = Bench::new(Duration::from_secs(1));
    println!("[micro] threads = {threads}\n");

    // --- gemm ---
    for &n in &[128usize, 512] {
        let a = Mat::gauss(n, n, &mut rng);
        let c = Mat::gauss(n, n, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);
        let r = b.run(&format!("gemm {n}x{n}x{n}"), || {
            std::hint::black_box(matmul(&a, Trans::No, &c, Trans::No));
        });
        println!(
            "    -> {:.2} GFLOP/s single-thread",
            flops / r.median.as_secs_f64() / 1e9
        );
    }
    {
        let n = 512;
        let a = Mat::gauss(n, n, &mut rng);
        let c = Mat::gauss(n, n, &mut rng);
        b.run(&format!("gemm_par {n}x{n}x{n} ({threads}t)"), || {
            std::hint::black_box(matmul_par(threads, &a, Trans::No, &c, Trans::No));
        });
    }

    // --- QR (ULV building block) ---
    let a = Mat::gauss(256, 64, &mut rng);
    b.run("qr 256x64 (factor+thinQ)", || {
        let qr = Qr::new(&a);
        std::hint::black_box(qr.thin_q());
    });

    // --- kernel block: native vs PJRT artifact (L1 Pallas inside) ---
    let kern = Kernel::Gaussian { h: 1.0 };
    for &f in &[8usize, 122] {
        let x = Mat::gauss(128, f, &mut rng);
        let y = Mat::gauss(128, f, &mut rng);
        b.run(&format!("kernel_block native 128x128 f={f}"), || {
            std::hint::black_box(kernel_block(&kern, &x, &y));
        });
    }
    {
        let x = Mat::gauss(2048, 122, &mut rng);
        let y = Mat::gauss(2048, 122, &mut rng);
        b.run(&format!("kernel_block_par 2048x2048 f=122 ({threads}t)"), || {
            std::hint::black_box(kernel_block_par(threads, &kern, &x, &y));
        });
    }
    match PjrtRuntime::try_default() {
        Some(rt) => {
            for &f in &[8usize, 122] {
                let x = Mat::gauss(128, f, &mut rng);
                let y = Mat::gauss(128, f, &mut rng);
                b.run(&format!("kernel_tile PJRT 128x128 f={f}"), || {
                    std::hint::black_box(rt.kernel_tile(&x, &y, kern.gamma()).unwrap());
                });
            }
            let sv = Mat::gauss(1024, 122, &mut rng);
            let ay: Vec<f64> = (0..1024).map(|_| rng.gauss()).collect();
            let x = Mat::gauss(128, 122, &mut rng);
            b.run("decision_tile PJRT 128t x 1024sv f=122", || {
                std::hint::black_box(rt.decision_tile(&x, &sv, &ay, kern.gamma()).unwrap());
            });
        }
        None => println!("(PJRT artifacts missing — run `make artifacts` for the PJRT rows)"),
    }
}
