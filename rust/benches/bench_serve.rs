//! Serving throughput/latency benchmark: an in-process load generator
//! (K TCP connections × M LIBSVM lines each) against `server::Server`,
//! reporting lines/s and server-side p50/p99 enqueue→response latency,
//! plus the cross-connection batching speedup (default tile size vs a
//! forced tile of 1). Every response is asserted bitwise-equal to the
//! offline prediction path, so the bench doubles as a correctness
//! smoke under real concurrency.
//!
//! Flags (CI uses all three — see `.github/workflows/ci.yml`):
//!   --smoke              reduced line counts for PR gating
//!   --json <path>        write the headline metrics as JSON (artifact)
//!   --baseline <path>    TOML (key = value) with the committed speedup
//!                        floors; exit nonzero on a >25% regression

use hss_svm::config::Config;
use hss_svm::data::{libsvm, DEFAULT_LABEL_PAIR};
use hss_svm::kernel::Kernel;
use hss_svm::linalg::Mat;
use hss_svm::serve;
use hss_svm::server::{ModelRegistry, Server, ServerConfig};
use hss_svm::svm::{predict, SvmModel};
use hss_svm::util::prng::Rng;
use hss_svm::util::threadpool;
use hss_svm::util::timer::Timer;
use std::io::{BufRead, BufReader, Cursor, Write};
use std::net::TcpStream;
use std::time::Duration;

const DIM: usize = 24; // < 32: Repr::Auto stays dense on every path
const CONNS: usize = 8;

struct Opts {
    smoke: bool,
    json: Option<String>,
    baseline: Option<String>,
}

fn parse_opts() -> Opts {
    let mut opts = Opts { smoke: false, json: None, baseline: None };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => opts.smoke = true,
            "--json" => opts.json = args.next(),
            "--baseline" => opts.baseline = args.next(),
            other => eprintln!("[serve] ignoring unknown flag {other:?}"),
        }
    }
    opts
}

/// Cargo runs bench binaries with cwd = the package dir (`rust/`);
/// resolve relative paths against the repository root.
fn from_repo_root(p: &str) -> std::path::PathBuf {
    let path = std::path::Path::new(p);
    if path.is_absolute() {
        path.to_path_buf()
    } else {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(path)
    }
}

fn toy_model(rng: &mut Rng, n_sv: usize) -> SvmModel {
    SvmModel {
        sv: Mat::gauss(n_sv, DIM, rng).into(),
        alpha_y: (0..n_sv).map(|_| rng.gauss()).collect(),
        bias: rng.gauss(),
        kernel: Kernel::Gaussian { h: 0.9 },
        c: 1.0,
        labels: DEFAULT_LABEL_PAIR,
    }
}

fn feature_line(rng: &mut Rng) -> String {
    let a = 1 + rng.below(DIM / 2);
    // b stays strictly below the fixed third index DIM (ascending,
    // duplicate-free — libsvm's contract)
    let b = a + 1 + rng.below(DIM - a - 1);
    format!("{a}:{:.3} {b}:{:.3} {DIM}:{:.3}", rng.gauss(), rng.gauss(), rng.gauss())
}

fn offline(model: &SvmModel, lines: &[String]) -> Vec<String> {
    let (x, _) = libsvm::read_features(Cursor::new(lines.join("\n")), Some(DIM)).unwrap();
    predict::decision_function(model, &x, 1)
        .into_iter()
        .map(|v| serve::format_prediction(model, v))
        .collect()
}

/// Drive K connections × M lines; returns (lines/s, p50_us, p99_us).
fn run_load(
    model: &SvmModel,
    threads: usize,
    batch_max: usize,
    lines_per_conn: usize,
    workloads: &[(Vec<String>, Vec<String>)],
) -> (f64, f64, f64) {
    let cfg = ServerConfig {
        batch_max,
        batch_wait: Duration::from_millis(2),
        // the load generator blasts everything up front; sizing the
        // queue to the workload keeps backpressure out of the measurement
        max_inflight: CONNS * lines_per_conn + 1,
        threads,
        ..Default::default()
    };
    let server =
        Server::bind("127.0.0.1:0", ModelRegistry::single(model.clone()), cfg).expect("bind");
    let handle = server.handle();
    let jh = std::thread::spawn(move || server.run());

    let t = Timer::start();
    std::thread::scope(|s| {
        for (lines, want) in workloads {
            let addr = handle.local_addr();
            s.spawn(move || {
                let stream = TcpStream::connect(addr).expect("connect");
                let mut r = BufReader::new(stream.try_clone().expect("clone"));
                let mut w = stream;
                for l in lines {
                    writeln!(w, "{l}").expect("send");
                }
                let mut got = String::new();
                for (i, want_line) in want.iter().enumerate() {
                    got.clear();
                    assert!(r.read_line(&mut got).expect("read") > 0, "EOF at line {i}");
                    assert_eq!(
                        got.trim_end(),
                        want_line,
                        "line {i}: served != offline (batch_max={batch_max})"
                    );
                }
            });
        }
    });
    let secs = t.secs();

    let stats = handle.stats_line();
    let p50 = parse_stat(&stats, "p50_us=");
    let p99 = parse_stat(&stats, "p99_us=");
    handle.shutdown();
    jh.join().unwrap().expect("server run");
    let total = (CONNS * lines_per_conn) as f64;
    (total / secs.max(1e-9), p50, p99)
}

fn parse_stat(stats: &str, key: &str) -> f64 {
    stats
        .split_ascii_whitespace()
        .find_map(|kv| kv.strip_prefix(key))
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("stats line missing {key:?}: {stats}"))
}

fn main() {
    let opts = parse_opts();
    let threads = threadpool::default_threads();
    let lines_per_conn = if opts.smoke { 400 } else { 2000 };
    let mut rng = Rng::new(17);
    let model = toy_model(&mut rng, 300);
    println!(
        "[serve] threads = {threads}, smoke = {}, {CONNS} connections x {lines_per_conn} lines, \
         model {} SVs x dim {DIM}",
        opts.smoke,
        model.n_sv()
    );

    // per-connection workloads + offline (cmd_predict-path) expectations
    let workloads: Vec<(Vec<String>, Vec<String>)> = (0..CONNS)
        .map(|c| {
            let mut rng = Rng::new(1000 + c as u64);
            let lines: Vec<String> = (0..lines_per_conn).map(|_| feature_line(&mut rng)).collect();
            let want = offline(&model, &lines);
            (lines, want)
        })
        .collect();

    // batched: cross-connection tiles at the default size
    let (batched_lps, p50, p99) =
        run_load(&model, threads, serve::BATCH, lines_per_conn, &workloads);
    println!(
        "[serve] batched   (tile {}): {:>9.0} lines/s   p50 {p50:.0} us   p99 {p99:.0} us",
        serve::BATCH,
        batched_lps
    );

    // unbatched: tile of 1 — what per-line dispatch would cost
    let (unbatched_lps, up50, up99) = run_load(&model, threads, 1, lines_per_conn, &workloads);
    println!(
        "[serve] unbatched (tile   1): {:>9.0} lines/s   p50 {up50:.0} us   p99 {up99:.0} us",
        unbatched_lps
    );

    let serve_batch_speedup = batched_lps / unbatched_lps.max(1e-9);
    println!("[serve] cross-connection batching speedup: {serve_batch_speedup:.2}x");

    if let Some(path) = &opts.json {
        let mut json = String::from("{\n");
        json.push_str(&hss_svm::util::bench::provenance_json("  "));
        json.push_str(&format!("  \"smoke\": {},\n", opts.smoke));
        json.push_str(&format!("  \"threads\": {threads},\n"));
        json.push_str(&format!("  \"connections\": {CONNS},\n"));
        json.push_str(&format!("  \"lines_per_conn\": {lines_per_conn},\n"));
        json.push_str(&format!("  \"n_sv\": {},\n", model.n_sv()));
        json.push_str(&format!("  \"dim\": {DIM},\n"));
        json.push_str(&format!("  \"batched_lines_per_sec\": {batched_lps:.1},\n"));
        json.push_str(&format!("  \"unbatched_lines_per_sec\": {unbatched_lps:.1},\n"));
        json.push_str(&format!("  \"serve_batch_speedup\": {serve_batch_speedup:.4},\n"));
        json.push_str(&format!("  \"p50_us\": {p50:.1},\n"));
        json.push_str(&format!("  \"p99_us\": {p99:.1}\n"));
        json.push_str("}\n");
        let out = from_repo_root(path);
        std::fs::write(&out, json).expect("write bench JSON");
        println!("[serve] wrote {}", out.display());
    }

    if let Some(path) = &opts.baseline {
        let base = Config::load(from_repo_root(path)).expect("read bench baseline");
        // a typoed/missing key must fail loudly, not quietly weaken the gate
        let floor = 0.75
            * base
                .get("", "serve_batch_speedup")
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| {
                    panic!("baseline {path} is missing numeric key \"serve_batch_speedup\"")
                });
        println!(
            "[serve] baseline gate: batching speedup {serve_batch_speedup:.2}x (floor {floor:.2}x)"
        );
        if serve_batch_speedup < floor {
            eprintln!(
                "[serve] REGRESSION: cross-connection batching speedup \
                 {serve_batch_speedup:.2}x fell >25% below the committed baseline"
            );
            std::process::exit(1);
        }
    }
}
