//! Regenerates the paper's evaluation TABLES end to end at bench scale.
//!
//! Table 1 — problem set (printed),
//! Tables 4/5 — Strumpack&ADMM at low/high HSS accuracy,
//! Tables 2/3 — SMO / RACQP baselines at the grid-selected (h, C),
//! plus the grid-reuse summary (§3.3 headline).
//!
//! Scale: HSS_SVM_BENCH_SCALE of the paper's sizes (default 0.005) over
//! HSS_SVM_BENCH_DATASETS (default a fast four-dataset subset covering
//! both regimes: small-f/large-d where HSS wins, and high-f where SMO
//! is competitive). CSVs land in results/bench/.

use hss_svm::coordinator::{run_suite, SuiteConfig};
use hss_svm::eval::tables;
use hss_svm::hss::HssParams;
use hss_svm::util::threadpool;
use hss_svm::util::timer::Timer;

fn main() {
    let threads = threadpool::default_threads();
    let scale: f64 = std::env::var("HSS_SVM_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.005);
    let datasets: Vec<String> = std::env::var("HSS_SVM_BENCH_DATASETS")
        .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
        .unwrap_or_else(|_| {
            vec!["a8a".into(), "ijcnn1".into(), "cod.rna".into(), "skin.nonskin".into()]
        });
    println!("[tables] scale={scale} datasets={datasets:?} threads={threads}\n");

    println!("{}", tables::table1(scale, 2021).render());

    // Table 4: low-accuracy HSS
    let t = Timer::start();
    let cfg4 = SuiteConfig {
        datasets: datasets.clone(),
        scale,
        hss: HssParams::low_accuracy(),
        threads,
        ..Default::default()
    };
    let rows4 = run_suite(&cfg4).expect("table4 suite");
    println!("{}", tables::hss_table("Table 4: Strumpack&ADMM (low accuracy HSS)", &rows4).render());
    println!("[table4 wall time: {:.1}s]\n", t.secs());

    // Table 5 + baselines (Tables 2/3 share the grid-selected params)
    let t = Timer::start();
    let cfg5 = SuiteConfig {
        datasets: datasets.clone(),
        scale,
        hss: HssParams::high_accuracy(),
        run_smo: true,
        run_racqp: true,
        threads,
        ..Default::default()
    };
    let rows5 = run_suite(&cfg5).expect("table5 suite");
    println!("{}", tables::hss_table("Table 5: Strumpack&ADMM (high accuracy HSS)", &rows5).render());
    println!("{}", tables::baseline_table("Table 2: LIBSVM-style SMO", &rows5, |r| r.smo).render());
    println!(
        "{}",
        tables::baseline_table("Table 3: RACQP-style multi-block ADMM", &rows5, |r| r.racqp)
            .render()
    );
    println!("{}", tables::grid_reuse_table(&rows5, 3).render());
    println!("[table5+baselines wall time: {:.1}s]", t.secs());

    std::fs::create_dir_all("results/bench").ok();
    tables::hss_table("table4", &rows4).write_csv("results/bench/table4.csv").ok();
    tables::hss_table("table5", &rows5).write_csv("results/bench/table5.csv").ok();
    tables::baseline_table("table2", &rows5, |r| r.smo)
        .write_csv("results/bench/table2.csv")
        .ok();
    tables::baseline_table("table3", &rows5, |r| r.racqp)
        .write_csv("results/bench/table3.csv")
        .ok();
    println!("\nCSV written to results/bench/");
}
