//! Observability: structured tracing, convergence reports, Prometheus
//! text exposition (DESIGN.md §14).
//!
//! Everything in this module is **passive** by contract: enabling any
//! of it must not change a single bit of any model, prediction or
//! residual. Concretely that means no RNG draws, no float arithmetic
//! on training data, and no reordering of reductions — events are
//! emitted *after* parallel sections join, from already-computed
//! values, and the only shared resource they touch is the sink mutex.
//! `tests/obs_invariance.rs` pins the contract (bitwise model and
//! prediction equality, tracing on vs. off, threads ∈ {1, 2, 8}) and a
//! `bench_hss` section gates the tracing-disabled overhead at < 2%.
//!
//! Layout:
//! - [`trace`]: the JSONL event sink behind a static atomic enable
//!   gate (`--trace PATH` / `HSS_SVM_TRACE`). With tracing off, a call
//!   site is one relaxed atomic load.
//! - [`report`]: the `report.json` convergence report (phase
//!   breakdown + per-column residual curves — the paper's
//!   Compression / Factorization / ADMM tables from real runs).
//! - [`prom`]: Prometheus text-exposition rendering (the TCP server's
//!   `METRICS` admin command).
//! - [`json`]: a dependency-free JSON value parser, used to validate
//!   and round-trip the traces in tests.

pub mod json;
pub mod prom;
pub mod report;
pub mod trace;

pub use report::{ConvergenceReport, ReportColumn};
pub use trace::{emit, enabled, TraceEvent};
