//! Prometheus text-exposition rendering (the `METRICS` admin command).
//!
//! Naming conventions (DESIGN.md §14): every metric is prefixed
//! `hss_svm_`, counters end in `_total`, gauges are bare nouns,
//! histograms use base units (`_seconds`) with cumulative `le` buckets,
//! `+Inf`, `_sum` and `_count` — the standard client-library surface,
//! so a stock Prometheus scraper parses it unmodified. The rendered
//! block ends with a literal `# EOF` line (OpenMetrics terminator),
//! which doubles as the end-of-response marker for the TCP line
//! protocol: a client reads lines until `# EOF`.

/// Escape a label *value* (the only position needing escapes).
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Format a sample value: integers render bare, floats via shortest
/// round-trip, infinities as `+Inf`/`-Inf` (bucket bounds need it).
pub fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else if v.fract() == 0.0 && v.abs() < 9.007_199_254_740_992e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:?}")
    }
}

/// Incremental builder for one exposition block.
#[derive(Default)]
pub struct PromText {
    buf: String,
}

impl PromText {
    pub fn new() -> PromText {
        PromText::default()
    }

    /// `# HELP` + `# TYPE` header. `typ` ∈ {"counter","gauge","histogram"}.
    pub fn header(&mut self, name: &str, typ: &str, help: &str) {
        self.buf.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {typ}\n"));
    }

    /// One sample line, optionally labeled.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.buf.push_str(name);
        if !labels.is_empty() {
            self.buf.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.buf.push(',');
                }
                self.buf.push_str(&format!("{k}=\"{}\"", escape_label(v)));
            }
            self.buf.push('}');
        }
        self.buf.push_str(&format!(" {}\n", fmt_value(value)));
    }

    /// Header + single unlabeled sample (the common case).
    pub fn scalar(&mut self, name: &str, typ: &str, help: &str, value: f64) {
        self.header(name, typ, help);
        self.sample(name, &[], value);
    }

    /// A full histogram family from cumulative buckets
    /// `(upper_bound, cumulative_count)`. Callers pass bounds already
    /// in base units (seconds); the `+Inf` bucket and `_sum`/`_count`
    /// are appended from `count`/`sum`.
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        buckets: &[(f64, u64)],
        count: u64,
        sum: f64,
    ) {
        self.header(name, "histogram", help);
        let bucket_name = format!("{name}_bucket");
        for &(le, cum) in buckets {
            self.sample(&bucket_name, &[("le", &fmt_value(le))], cum as f64);
        }
        self.sample(&bucket_name, &[("le", "+Inf")], count as f64);
        self.sample(&format!("{name}_sum"), &[], sum);
        self.sample(&format!("{name}_count"), &[], count as f64);
    }

    /// Finish the block with the `# EOF` terminator line.
    pub fn finish(mut self) -> String {
        self.buf.push_str("# EOF");
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_counters_gauges_and_labels() {
        let mut p = PromText::new();
        p.scalar("hss_svm_lines_total", "counter", "Request lines received.", 42.0);
        p.header("hss_svm_model_generation", "gauge", "Registry generation per model.");
        p.sample("hss_svm_model_generation", &[("model", "a\"b\\c")], 3.0);
        let text = p.finish();
        assert!(text.contains("# HELP hss_svm_lines_total Request lines received.\n"));
        assert!(text.contains("# TYPE hss_svm_lines_total counter\n"));
        assert!(text.contains("hss_svm_lines_total 42\n"));
        assert!(
            text.contains("hss_svm_model_generation{model=\"a\\\"b\\\\c\"} 3\n"),
            "label escaping: {text}"
        );
        assert!(text.ends_with("# EOF"), "terminator: {text:?}");
    }

    #[test]
    fn histogram_renders_cumulative_buckets_inf_sum_count() {
        let mut p = PromText::new();
        p.histogram(
            "hss_svm_request_latency_seconds",
            "Latency.",
            &[(0.001, 3), (0.01, 7), (0.1, 7)],
            9,
            0.5,
        );
        let text = p.finish();
        let les: Vec<f64> = text
            .lines()
            .filter(|l| l.starts_with("hss_svm_request_latency_seconds_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse::<f64>().unwrap())
            .collect();
        assert_eq!(les, vec![3.0, 7.0, 7.0, 9.0], "cumulative + +Inf==count: {text}");
        assert!(text.contains("{le=\"0.001\"}"));
        assert!(text.contains("{le=\"+Inf\"} 9\n"));
        assert!(text.contains("hss_svm_request_latency_seconds_sum 0.5\n"));
        assert!(text.contains("hss_svm_request_latency_seconds_count 9\n"));
    }

    #[test]
    fn value_formatting_covers_integers_floats_and_inf() {
        assert_eq!(fmt_value(0.0), "0");
        assert_eq!(fmt_value(12345.0), "12345");
        assert_eq!(fmt_value(0.25), "0.25");
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(fmt_value(1e16), "1e16");
    }
}
