//! `report.json` — the paper-style convergence report of a train/grid
//! run: per-phase wall-clock breakdown (Compression / Factorization /
//! ADMM, plus SV extraction where it applies) and the per-C-column
//! primal/dual residual curves the solver used to discard.
//!
//! The phase breakdown must account for the run: the CI `obs-smoke`
//! job asserts `Σ phases.secs` lands within 10% of `wall_secs`
//! (`wall_secs` is measured around training proper, not data loading).

use crate::obs::trace::{self, TraceEvent};
use std::io::Write;

/// Residual history of one trained (h, C) column.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReportColumn {
    pub h: f64,
    pub c: f64,
    /// ADMM iterations actually run (== `primal.len()`).
    pub iters: usize,
    /// Primal residual ‖z − x‖∞-style curve, one entry per iteration.
    pub primal: Vec<f64>,
    /// Dual residual curve, one entry per iteration.
    pub dual: Vec<f64>,
}

/// The whole report. Build with the struct literal, then [`write`].
#[derive(Clone, Debug, Default)]
pub struct ConvergenceReport {
    /// Subcommand that produced the report ("train", "grid", ...).
    pub command: String,
    pub dataset: String,
    /// Training rows.
    pub n: usize,
    pub threads: usize,
    /// End-to-end training wall clock (excludes data loading).
    pub wall_secs: f64,
    /// `(name, secs, count)` rows, `PhaseTimer::report()` shape.
    pub phases: Vec<(String, f64, u64)>,
    pub columns: Vec<ReportColumn>,
    /// Extra scalar facts, pre-rendered as JSON values (numbers or
    /// quoted strings) — e.g. `("hss_max_rank", "31")`.
    pub extra: Vec<(String, String)>,
}

fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:?}")
    } else {
        "null".to_string()
    }
}

fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn num_list(vs: &[f64]) -> String {
    let items: Vec<String> = vs.iter().map(|v| num(*v)).collect();
    format!("[{}]", items.join(","))
}

impl ConvergenceReport {
    /// Σ of the phase breakdown (the 10%-of-wall acceptance quantity).
    pub fn phase_total(&self) -> f64 {
        self.phases.iter().map(|(_, s, _)| *s).sum()
    }

    /// Serialize as human-readable JSON.
    pub fn to_json(&self) -> String {
        let mut j = String::from("{\n");
        j.push_str(&format!("  \"command\": {},\n", quote(&self.command)));
        j.push_str(&format!("  \"dataset\": {},\n", quote(&self.dataset)));
        j.push_str(&format!("  \"n\": {},\n", self.n));
        j.push_str(&format!("  \"threads\": {},\n", self.threads));
        j.push_str(&format!("  \"wall_secs\": {},\n", num(self.wall_secs)));
        j.push_str(&format!("  \"phase_total_secs\": {},\n", num(self.phase_total())));
        j.push_str("  \"phases\": [\n");
        for (i, (name, secs, count)) in self.phases.iter().enumerate() {
            j.push_str(&format!(
                "    {{\"name\": {}, \"secs\": {}, \"count\": {}}}{}\n",
                quote(name),
                num(*secs),
                count,
                if i + 1 < self.phases.len() { "," } else { "" }
            ));
        }
        j.push_str("  ],\n");
        j.push_str("  \"columns\": [\n");
        for (i, col) in self.columns.iter().enumerate() {
            j.push_str(&format!(
                "    {{\"h\": {}, \"c\": {}, \"iters\": {}, \"primal\": {}, \"dual\": {}}}{}\n",
                num(col.h),
                num(col.c),
                col.iters,
                num_list(&col.primal),
                num_list(&col.dual),
                if i + 1 < self.columns.len() { "," } else { "" }
            ));
        }
        j.push_str("  ]");
        for (k, v) in &self.extra {
            j.push_str(&format!(",\n  {}: {}", quote(k), v));
        }
        j.push_str("\n}\n");
        j
    }

    /// Write the report and mirror the phase rows onto the trace (so a
    /// traced run carries its own breakdown).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        if trace::enabled() {
            for (name, secs, _) in &self.phases {
                trace::emit(&TraceEvent::Phase { name: name.clone(), secs: *secs });
            }
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(self.to_json().as_bytes())?;
        f.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::json;

    fn sample() -> ConvergenceReport {
        ConvergenceReport {
            command: "train".to_string(),
            dataset: "blobs".to_string(),
            n: 2000,
            threads: 2,
            wall_secs: 1.0,
            phases: vec![
                ("compression".to_string(), 0.50, 1),
                ("factorization".to_string(), 0.25, 1),
                ("admm".to_string(), 0.20, 1),
            ],
            columns: vec![ReportColumn {
                h: 1.0,
                c: 0.5,
                iters: 2,
                primal: vec![1e-1, 1e-3],
                dual: vec![2e-1, 2e-3],
            }],
            extra: vec![("hss_max_rank".to_string(), "31".to_string())],
        }
    }

    #[test]
    fn report_serializes_to_valid_json_with_phase_total() {
        let r = sample();
        assert!((r.phase_total() - 0.95).abs() < 1e-12);
        let j = json::parse(&r.to_json()).expect("report is valid JSON");
        assert_eq!(j.get("command").unwrap().as_str(), Some("train"));
        assert_eq!(j.get("phase_total_secs").unwrap().as_f64(), Some(0.95));
        let phases = j.get("phases").unwrap().as_array().unwrap();
        assert_eq!(phases.len(), 3);
        assert_eq!(phases[0].get("name").unwrap().as_str(), Some("compression"));
        let cols = j.get("columns").unwrap().as_array().unwrap();
        assert_eq!(cols[0].get("iters").unwrap().as_u64(), Some(2));
        assert_eq!(
            cols[0].get("primal").unwrap().as_array().unwrap()[1].as_f64(),
            Some(1e-3)
        );
        assert_eq!(j.get("hss_max_rank").unwrap().as_u64(), Some(31));
    }

    #[test]
    fn empty_report_is_still_valid_json() {
        let r = ConvergenceReport::default();
        let j = json::parse(&r.to_json()).expect("empty report is valid JSON");
        assert_eq!(j.get("phases").unwrap().as_array().unwrap().len(), 0);
        assert_eq!(j.get("columns").unwrap().as_array().unwrap().len(), 0);
    }
}
