//! JSONL trace events behind a static atomic enable gate.
//!
//! The gate is the whole cost model: every instrumented site is
//!
//! ```ignore
//! if trace::enabled() {                 // one relaxed atomic load
//!     trace::emit(&TraceEvent::AdmmIter { .. });
//! }
//! ```
//!
//! so with `HSS_SVM_TRACE` unset the hot paths pay a single
//! predictable-not-taken branch. When enabled, `emit` serializes the
//! event to one JSON line and writes it under the sink mutex — one
//! lock acquisition per event, one complete line per `write_all`, so
//! concurrent emitters never interleave bytes.
//!
//! Events are deliberately flat (no nesting, no spans-with-ids): each
//! line is `{"ev":"<type>", ...fields}` and the whole trace is
//! greppable/`jq`-able. The schema is the [`TraceEvent`] enum itself;
//! `from_json` is the validator (used by the round-trip tests and the
//! CI `obs-smoke` job).

use crate::obs::json::{self, Json};
use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// The fast-path gate. False until `init_writer` installs a sink.
static TRACE_ENABLED: AtomicBool = AtomicBool::new(false);

/// The installed sink. Publication of the writer happens-before any
/// `emit` use of it via this mutex, not via the gate.
static SINK: Mutex<Option<Box<dyn Write + Send>>> = Mutex::new(None);

/// Is tracing on? This is the *entire* disabled-path cost of every
/// instrumented site.
#[inline(always)]
pub fn enabled() -> bool {
    // ORDERING: the gate is an advisory fast-path hint, not a
    // synchronization point: a stale `false` skips one event around the
    // enable race, and a stale `true` falls through to `emit`, whose
    // SINK lock acquisition is what actually orders this thread against
    // the writer installed by `init_writer`.
    TRACE_ENABLED.load(Ordering::Relaxed)
}

/// Install `HSS_SVM_TRACE` (a file path) as the sink, if set.
/// Call once at process start; a bad path warns and leaves tracing off.
pub fn init_from_env() {
    if let Ok(path) = std::env::var("HSS_SVM_TRACE") {
        if !path.is_empty() {
            if let Err(e) = init_path(&path) {
                eprintln!("obs: cannot open trace file {path:?}: {e}");
            }
        }
    }
}

/// Start tracing into a JSONL file at `path` (truncates).
pub fn init_path(path: &str) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    init_writer(Box::new(std::io::BufWriter::new(f)));
    Ok(())
}

/// Start tracing into an arbitrary writer (tests use a shared buffer).
pub fn init_writer(w: Box<dyn Write + Send>) {
    let mut sink = SINK.lock().unwrap_or_else(|e| e.into_inner());
    *sink = Some(w);
    // The gate flips only after the sink is installed, and with Release
    // so a racing `enabled()` that observes `true` cannot be reordered
    // before the store of the sink (belt — the emit-side mutex is the
    // suspenders).
    TRACE_ENABLED.store(true, Ordering::Release);
    drop(sink);
}

/// Stop tracing, flush and drop the sink.
pub fn disable() {
    TRACE_ENABLED.store(false, Ordering::Release);
    let prev = SINK.lock().unwrap_or_else(|e| e.into_inner()).take();
    if let Some(mut w) = prev {
        let _ = w.flush();
    }
}

/// Flush the sink (end of a command, before reporting file paths).
pub fn flush() {
    if let Some(w) = SINK.lock().unwrap_or_else(|e| e.into_inner()).as_mut() {
        let _ = w.flush();
    }
}

/// Serialize and write one event as one JSONL line. Safe to call with
/// tracing off (no sink → no-op); call sites still guard with
/// [`enabled`] so the disabled path never formats anything.
pub fn emit(ev: &TraceEvent) {
    let mut sink = SINK.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(w) = sink.as_mut() {
        let mut line = ev.to_json();
        line.push('\n');
        let _ = w.write_all(line.as_bytes());
    }
}

/// One structured trace event — the JSONL schema, one variant per
/// `"ev"` tag. Field meanings are documented per variant; every float
/// serializes via shortest-round-trip `{:?}` (non-finite → `null`).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// One level of the HSS compression sweep finished (`level` 0 =
    /// leaves).
    CompressLevel { level: usize, nodes: usize },
    /// One compressed HSS node: its sampled off-diagonal `rank` and
    /// the ID block dimensions (`rows` × `cols` of the node's span).
    CompressNode { node: usize, level: usize, leaf: bool, rank: usize, rows: usize, cols: usize },
    /// Compression finished (mirrors `HssStats`).
    CompressDone { max_rank: usize, memory_bytes: u64, kernel_evals: u64, secs: f64 },
    /// ULV factorization of the β-shifted HSS matrix finished.
    UlvFactor { n: usize, beta: f64, secs: f64 },
    /// One (multi-)RHS ULV solve through the `ShiftedSolve` trait.
    UlvSolve { n: usize, rhs: usize },
    /// One ADMM iteration for one C column: residuals after the step.
    AdmmIter { c: f64, iter: usize, primal: f64, dual: f64 },
    /// A C column froze early in `run_grid` (tolerance met; its
    /// iterate stops advancing while the batch continues).
    AdmmFreeze { c: f64, iter: usize },
    /// A C column finished: final iteration count and residuals.
    AdmmDone { c: f64, iters: usize, primal: f64, dual: f64 },
    /// One out-of-core shard engine built (consensus training).
    ShardBuild { shard: usize, rows: usize, compress_secs: f64, factor_secs: f64, rss_bytes: u64 },
    /// One consensus-ADMM iteration: the global coupling ratio
    /// Σ shard parts / w₁ for one C column.
    ConsensusIter { iter: usize, c: f64, ratio: f64 },
    /// One evaluated grid-search cell.
    GridCell { h: f64, c: f64, accuracy: f64, iters: usize, n_sv: usize },
    /// One phase of a train/grid run finished (PhaseTimer breakdown).
    Phase { name: String, secs: f64 },
    /// The TCP server flushed one prediction tile. `reason` ∈
    /// {"full", "model-switch", "deadline", "drain"}.
    ServerBatch { size: usize, model: String, generation: u64, reason: String, queue_depth: usize },
    /// A model hot-swap (RELOAD admin command or mtime poll).
    ServerReload { model: String, generation: u64 },
}

/// JSON number from a float: shortest round-trip form, `null` when not
/// finite (JSON has no NaN/Inf).
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:?}")
    } else {
        "null".to_string()
    }
}

/// JSON string literal with the mandatory escapes.
fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

impl TraceEvent {
    /// The `"ev"` tag of this variant.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::CompressLevel { .. } => "compress_level",
            TraceEvent::CompressNode { .. } => "compress_node",
            TraceEvent::CompressDone { .. } => "compress_done",
            TraceEvent::UlvFactor { .. } => "ulv_factor",
            TraceEvent::UlvSolve { .. } => "ulv_solve",
            TraceEvent::AdmmIter { .. } => "admm_iter",
            TraceEvent::AdmmFreeze { .. } => "admm_freeze",
            TraceEvent::AdmmDone { .. } => "admm_done",
            TraceEvent::ShardBuild { .. } => "shard_build",
            TraceEvent::ConsensusIter { .. } => "consensus_iter",
            TraceEvent::GridCell { .. } => "grid_cell",
            TraceEvent::Phase { .. } => "phase",
            TraceEvent::ServerBatch { .. } => "server_batch",
            TraceEvent::ServerReload { .. } => "server_reload",
        }
    }

    /// One compact JSON object, `"ev"` first, fields in declaration
    /// order.
    pub fn to_json(&self) -> String {
        let tag = self.kind();
        match self {
            TraceEvent::CompressLevel { level, nodes } => {
                format!("{{\"ev\":\"{tag}\",\"level\":{level},\"nodes\":{nodes}}}")
            }
            TraceEvent::CompressNode { node, level, leaf, rank, rows, cols } => format!(
                "{{\"ev\":\"{tag}\",\"node\":{node},\"level\":{level},\"leaf\":{leaf},\
                 \"rank\":{rank},\"rows\":{rows},\"cols\":{cols}}}"
            ),
            TraceEvent::CompressDone { max_rank, memory_bytes, kernel_evals, secs } => format!(
                "{{\"ev\":\"{tag}\",\"max_rank\":{max_rank},\"memory_bytes\":{memory_bytes},\
                 \"kernel_evals\":{kernel_evals},\"secs\":{}}}",
                num(*secs)
            ),
            TraceEvent::UlvFactor { n, beta, secs } => format!(
                "{{\"ev\":\"{tag}\",\"n\":{n},\"beta\":{},\"secs\":{}}}",
                num(*beta),
                num(*secs)
            ),
            TraceEvent::UlvSolve { n, rhs } => {
                format!("{{\"ev\":\"{tag}\",\"n\":{n},\"rhs\":{rhs}}}")
            }
            TraceEvent::AdmmIter { c, iter, primal, dual } => format!(
                "{{\"ev\":\"{tag}\",\"c\":{},\"iter\":{iter},\"primal\":{},\"dual\":{}}}",
                num(*c),
                num(*primal),
                num(*dual)
            ),
            TraceEvent::AdmmFreeze { c, iter } => {
                format!("{{\"ev\":\"{tag}\",\"c\":{},\"iter\":{iter}}}", num(*c))
            }
            TraceEvent::AdmmDone { c, iters, primal, dual } => format!(
                "{{\"ev\":\"{tag}\",\"c\":{},\"iters\":{iters},\"primal\":{},\"dual\":{}}}",
                num(*c),
                num(*primal),
                num(*dual)
            ),
            TraceEvent::ShardBuild { shard, rows, compress_secs, factor_secs, rss_bytes } => {
                format!(
                    "{{\"ev\":\"{tag}\",\"shard\":{shard},\"rows\":{rows},\
                     \"compress_secs\":{},\"factor_secs\":{},\"rss_bytes\":{rss_bytes}}}",
                    num(*compress_secs),
                    num(*factor_secs)
                )
            }
            TraceEvent::ConsensusIter { iter, c, ratio } => format!(
                "{{\"ev\":\"{tag}\",\"iter\":{iter},\"c\":{},\"ratio\":{}}}",
                num(*c),
                num(*ratio)
            ),
            TraceEvent::GridCell { h, c, accuracy, iters, n_sv } => format!(
                "{{\"ev\":\"{tag}\",\"h\":{},\"c\":{},\"accuracy\":{},\"iters\":{iters},\
                 \"n_sv\":{n_sv}}}",
                num(*h),
                num(*c),
                num(*accuracy)
            ),
            TraceEvent::Phase { name, secs } => format!(
                "{{\"ev\":\"{tag}\",\"name\":{},\"secs\":{}}}",
                quote(name),
                num(*secs)
            ),
            TraceEvent::ServerBatch { size, model, generation, reason, queue_depth } => format!(
                "{{\"ev\":\"{tag}\",\"size\":{size},\"model\":{},\"generation\":{generation},\
                 \"reason\":{},\"queue_depth\":{queue_depth}}}",
                quote(model),
                quote(reason)
            ),
            TraceEvent::ServerReload { model, generation } => format!(
                "{{\"ev\":\"{tag}\",\"model\":{},\"generation\":{generation}}}",
                quote(model)
            ),
        }
    }

    /// Parse one JSONL line back into an event — the schema validator.
    /// Unknown tags and missing/mistyped fields are errors.
    pub fn from_json(line: &str) -> Result<TraceEvent, String> {
        let j = json::parse(line)?;
        let tag = j.get("ev").and_then(Json::as_str).ok_or("missing \"ev\" tag")?.to_string();
        let f = |k: &str| -> Result<f64, String> {
            j.get(k).and_then(Json::as_f64).ok_or(format!("{tag}: missing number {k:?}"))
        };
        let u = |k: &str| -> Result<usize, String> {
            j.get(k).and_then(Json::as_usize).ok_or(format!("{tag}: missing integer {k:?}"))
        };
        let u64f = |k: &str| -> Result<u64, String> {
            j.get(k).and_then(Json::as_u64).ok_or(format!("{tag}: missing integer {k:?}"))
        };
        let s = |k: &str| -> Result<String, String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or(format!("{tag}: missing string {k:?}"))
        };
        let b = |k: &str| -> Result<bool, String> {
            j.get(k).and_then(Json::as_bool).ok_or(format!("{tag}: missing bool {k:?}"))
        };
        Ok(match tag.as_str() {
            "compress_level" => {
                TraceEvent::CompressLevel { level: u("level")?, nodes: u("nodes")? }
            }
            "compress_node" => TraceEvent::CompressNode {
                node: u("node")?,
                level: u("level")?,
                leaf: b("leaf")?,
                rank: u("rank")?,
                rows: u("rows")?,
                cols: u("cols")?,
            },
            "compress_done" => TraceEvent::CompressDone {
                max_rank: u("max_rank")?,
                memory_bytes: u64f("memory_bytes")?,
                kernel_evals: u64f("kernel_evals")?,
                secs: f("secs")?,
            },
            "ulv_factor" => {
                TraceEvent::UlvFactor { n: u("n")?, beta: f("beta")?, secs: f("secs")? }
            }
            "ulv_solve" => TraceEvent::UlvSolve { n: u("n")?, rhs: u("rhs")? },
            "admm_iter" => TraceEvent::AdmmIter {
                c: f("c")?,
                iter: u("iter")?,
                primal: f("primal")?,
                dual: f("dual")?,
            },
            "admm_freeze" => TraceEvent::AdmmFreeze { c: f("c")?, iter: u("iter")? },
            "admm_done" => TraceEvent::AdmmDone {
                c: f("c")?,
                iters: u("iters")?,
                primal: f("primal")?,
                dual: f("dual")?,
            },
            "shard_build" => TraceEvent::ShardBuild {
                shard: u("shard")?,
                rows: u("rows")?,
                compress_secs: f("compress_secs")?,
                factor_secs: f("factor_secs")?,
                rss_bytes: u64f("rss_bytes")?,
            },
            "consensus_iter" => TraceEvent::ConsensusIter {
                iter: u("iter")?,
                c: f("c")?,
                ratio: f("ratio")?,
            },
            "grid_cell" => TraceEvent::GridCell {
                h: f("h")?,
                c: f("c")?,
                accuracy: f("accuracy")?,
                iters: u("iters")?,
                n_sv: u("n_sv")?,
            },
            "phase" => TraceEvent::Phase { name: s("name")?, secs: f("secs")? },
            "server_batch" => TraceEvent::ServerBatch {
                size: u("size")?,
                model: s("model")?,
                generation: u64f("generation")?,
                reason: s("reason")?,
                queue_depth: u("queue_depth")?,
            },
            "server_reload" => TraceEvent::ServerReload {
                model: s("model")?,
                generation: u64f("generation")?,
            },
            other => return Err(format!("unknown event tag {other:?}")),
        })
    }

    /// One exemplar of every variant (round-trip tests, schema docs).
    pub fn exemplars() -> Vec<TraceEvent> {
        vec![
            TraceEvent::CompressLevel { level: 0, nodes: 16 },
            TraceEvent::CompressNode {
                node: 3,
                level: 1,
                leaf: false,
                rank: 12,
                rows: 128,
                cols: 36,
            },
            TraceEvent::CompressDone {
                max_rank: 31,
                memory_bytes: 1_234_567,
                kernel_evals: 99_000,
                secs: 0.125,
            },
            TraceEvent::UlvFactor { n: 2000, beta: 100.0, secs: 0.5 },
            TraceEvent::UlvSolve { n: 2000, rhs: 8 },
            TraceEvent::AdmmIter { c: 1.0, iter: 3, primal: 1.5e-3, dual: 2.5e-4 },
            TraceEvent::AdmmFreeze { c: 0.1, iter: 7 },
            TraceEvent::AdmmDone { c: 1.0, iters: 10, primal: 9.9e-7, dual: 1.1e-8 },
            TraceEvent::ShardBuild {
                shard: 2,
                rows: 50_000,
                compress_secs: 1.25,
                factor_secs: 0.75,
                rss_bytes: 123_456_789,
            },
            TraceEvent::ConsensusIter { iter: 4, c: 1.0, ratio: 0.125 },
            TraceEvent::GridCell { h: 1.0, c: 10.0, accuracy: 0.9875, iters: 10, n_sv: 420 },
            TraceEvent::Phase { name: "compression".to_string(), secs: 1.5 },
            TraceEvent::ServerBatch {
                size: 128,
                model: "default".to_string(),
                generation: 2,
                reason: "full".to_string(),
                queue_depth: 17,
            },
            TraceEvent::ServerReload { model: "a\"b".to_string(), generation: 3 },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, OnceLock};

    /// The sink is process-global; tests that install one serialize on
    /// this lock so parallel test threads cannot steal each other's
    /// writer.
    fn sink_lock() -> &'static Mutex<()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(()))
    }

    #[derive(Clone)]
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);
    impl std::io::Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn every_event_type_round_trips_through_json() {
        for ev in TraceEvent::exemplars() {
            let line = ev.to_json();
            let back = TraceEvent::from_json(&line)
                .unwrap_or_else(|e| panic!("{line} failed to parse: {e}"));
            assert_eq!(back, ev, "round-trip mismatch for {line}");
        }
    }

    #[test]
    fn non_finite_floats_serialize_as_null_and_fail_validation() {
        let ev = TraceEvent::AdmmIter { c: 1.0, iter: 0, primal: f64::NAN, dual: 0.0 };
        let line = ev.to_json();
        assert!(line.contains("\"primal\":null"), "{line}");
        // null is not a number: the validator rejects it, which is the
        // honest outcome for a non-finite residual
        assert!(TraceEvent::from_json(&line).is_err());
    }

    #[test]
    fn unknown_tags_and_missing_fields_are_rejected() {
        assert!(TraceEvent::from_json("{\"ev\":\"no_such_event\"}").is_err());
        assert!(TraceEvent::from_json("{\"ev\":\"admm_iter\",\"c\":1.0}").is_err());
        assert!(TraceEvent::from_json("not json at all").is_err());
        assert!(TraceEvent::from_json("{\"iter\":3}").is_err(), "missing ev tag");
    }

    #[test]
    fn emit_writes_one_line_per_event_and_disable_stops_the_stream() {
        let _guard = sink_lock().lock().unwrap_or_else(|e| e.into_inner());
        let buf = SharedBuf(Arc::new(Mutex::new(Vec::new())));
        assert!(!enabled());
        init_writer(Box::new(buf.clone()));
        assert!(enabled());
        let marker = TraceEvent::UlvSolve { n: 777_001, rhs: 13 };
        emit(&marker);
        emit(&TraceEvent::UlvSolve { n: 777_002, rhs: 14 });
        flush();
        disable();
        assert!(!enabled());
        emit(&TraceEvent::UlvSolve { n: 777_003, rhs: 15 }); // after disable: dropped
        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        // other tests may interleave their own events through the
        // global sink; filter on our marker values
        let mine: Vec<TraceEvent> = text
            .lines()
            .map(|l| TraceEvent::from_json(l).expect("sink lines parse"))
            .filter(|e| matches!(e, TraceEvent::UlvSolve { n, .. } if *n >= 777_000))
            .collect();
        assert_eq!(
            mine,
            vec![marker, TraceEvent::UlvSolve { n: 777_002, rhs: 14 }],
            "exactly the two pre-disable events"
        );
    }

    #[test]
    fn strings_with_quotes_and_newlines_escape_cleanly() {
        let ev = TraceEvent::Phase { name: "a\"b\\c\nd\te".to_string(), secs: 0.0 };
        let line = ev.to_json();
        assert_eq!(line.matches('\n').count(), 0, "escaped event stays on one line");
        assert_eq!(TraceEvent::from_json(&line).unwrap(), ev);
    }
}
