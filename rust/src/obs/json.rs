//! A minimal dependency-free JSON parser.
//!
//! Exists so traces, reports and bench artifacts can be *validated*
//! in-tree (round-trip tests, the `obs-smoke` assertions) without
//! pulling a serde stack into a crate whose only runtime dependency is
//! `anyhow`. It parses the full JSON value grammar into [`Json`];
//! numbers land in `f64` (every value this repo emits is either an
//! integer well under 2⁵³ or a float that came from an `f64`, so the
//! round trip is exact for our own output).

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered key/value pairs (duplicate keys keep the
    /// first occurrence on `get`).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Numbers that are exactly a nonnegative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= 9.007_199_254_740_992e15 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object pairs, in document order.
    pub fn as_object(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse one complete JSON document (trailing non-whitespace is an
/// error — a concatenated line is a bug we want caught).
pub fn parse(src: &str) -> Result<Json, String> {
    let mut p = Parser { b: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.pos) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected {:?} at byte {}", c as char, self.pos)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            // fast path: run of plain bytes
            while let Some(&c) = self.b.get(self.pos) {
                if c == b'"' || c == b'\\' || c < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.b[start..self.pos])
                    .map_err(|_| "invalid utf-8 in string".to_string())?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.pos..self.pos + 4)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            self.pos += 4;
                            // surrogate pairs are not emitted by this
                            // repo; map lone surrogates to U+FFFD
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => {
                            return Err(format!("bad escape \\{:?}", other as char));
                        }
                    }
                }
                Some(c) if c < 0x20 => {
                    return Err(format!("raw control byte {c:#04x} in string"));
                }
                _ => return Err("unterminated string".to_string()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(&c) = self.b.get(self.pos) {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-'
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("invalid number {text:?} at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_value_grammar() {
        let j = parse(
            "{\"a\": 1, \"b\": [true, false, null, -2.5e-3], \"c\": {\"nested\": \"x\\ny\"}, \
             \"d\": \"\"}",
        )
        .unwrap();
        assert_eq!(j.get("a").unwrap().as_u64(), Some(1));
        let arr = j.get("b").unwrap().as_array().unwrap();
        assert_eq!(arr[0].as_bool(), Some(true));
        assert_eq!(arr[2], Json::Null);
        assert_eq!(arr[3].as_f64(), Some(-2.5e-3));
        assert_eq!(j.get("c").unwrap().get("nested").unwrap().as_str(), Some("x\ny"));
        assert_eq!(j.get("d").unwrap().as_str(), Some(""));
        assert_eq!(j.get("missing"), None);
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "{\"a\":}", "[1,]", "{\"a\" 1}", "tru", "1 2", "\"unterminated",
            "{\"a\":1}x", "nul",
        ] {
            assert!(parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn float_round_trip_is_exact_for_shortest_repr() {
        for v in [0.0, 1.0, -1.5, 1e-12, 3.141592653589793, 2.2250738585072014e-308] {
            let j = parse(&format!("{v:?}")).unwrap();
            assert_eq!(j.as_f64(), Some(v), "{v:?} must round-trip bit-exactly");
        }
    }

    #[test]
    fn integer_accessors_guard_range_and_fraction() {
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        assert_eq!(parse("1e16").unwrap().as_u64(), None, "beyond exact f64 integers");
    }
}
