//! On-disk CSR shards for out-of-core consensus training.
//!
//! [`write_shards`] splits a LIBSVM file into `K` shard files in ONE
//! streaming pass: each data line is validated with the same
//! [`libsvm`](crate::data::libsvm) line parser as the in-memory reader,
//! assigned round-robin (row `i` → shard `i mod K`, so class balance and
//! a ragged last shard fall out naturally) and appended to that shard's
//! file immediately — no dense matrix, no full CSR, O(K) open writers
//! and O(1) rows resident. Shard rows store the raw label and nonzero
//! values as 16-digit hex f64 bit patterns (the model-persistence
//! encoding, [`svm::persist`](crate::svm::persist)), so a
//! write→[`ShardSet::load_shard`] round-trip is bit-exact — the
//! foundation of the "sharded training is a pure function of (K,
//! content)" contract.
//!
//! Global facts a shard cannot know locally — the feature dimension
//! (max index over ALL rows), the total nnz (the [`Repr::Auto`]
//! density rule must pick ONE representation for every shard), and the
//! binary label mapping (the greater-label-is-positive convention needs
//! the global label set) — are accumulated during the pass and written
//! to a `manifest` file at the end. [`ShardSet::load_shard`] applies
//! them so that, for `K = 1`, the loaded shard is bitwise identical to
//! what [`libsvm::read_file`](crate::data::libsvm::read_file) returns.
//!
//! Disk layout under the shard directory:
//!
//! ```text
//!   manifest        header: counts, dim, label mapping, per-shard rows
//!   shard-0.csr     "<label-hex> <col>:<val-hex> ..." per row (0-based cols)
//!   ...
//!   shard-<K-1>.csr
//! ```

use crate::data::dataset::{Dataset, DEFAULT_LABEL_PAIR};
use crate::data::libsvm::{self, Repr};
use crate::data::sparse::{CsrMat, Points};
use crate::svm::persist::{hexf, unhexf};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

/// Magic first line of the manifest; bump on format changes.
const MANIFEST_MAGIC: &str = "hss-svm-shards v1";

/// How raw labels map to ±1 — the global binary-label rule of
/// [`libsvm::read`](crate::data::libsvm::read), decided once over the
/// whole file and applied identically by every shard load.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LabelMap {
    /// File had literal {−1, +1} labels: kept verbatim.
    Pm1,
    /// Single-class file: positive raw labels ↦ +1, others ↦ −1.
    Single,
    /// Two classes: rounded labels greater than `lo` ↦ +1.
    Greater {
        /// The smaller rounded class (the negative one).
        lo: i64,
    },
    /// Empty file: nothing to map.
    Empty,
}

impl LabelMap {
    fn apply(self, raw: f64) -> f64 {
        match self {
            LabelMap::Pm1 | LabelMap::Empty => raw,
            LabelMap::Single => {
                if raw > 0.0 {
                    1.0
                } else {
                    -1.0
                }
            }
            LabelMap::Greater { lo } => {
                if (raw.round() as i64) > lo {
                    1.0
                } else {
                    -1.0
                }
            }
        }
    }

    fn tag(self) -> String {
        match self {
            LabelMap::Pm1 => "pm1".to_string(),
            LabelMap::Single => "single".to_string(),
            LabelMap::Greater { lo } => format!("greater {lo}"),
            LabelMap::Empty => "empty".to_string(),
        }
    }

    fn from_tag(s: &str) -> Result<LabelMap> {
        let mut p = s.split_ascii_whitespace();
        match p.next() {
            Some("pm1") => Ok(LabelMap::Pm1),
            Some("single") => Ok(LabelMap::Single),
            Some("greater") => {
                let lo = p
                    .next()
                    .context("manifest: mapping 'greater' missing class")?
                    .parse()
                    .context("manifest: bad 'greater' class")?;
                Ok(LabelMap::Greater { lo })
            }
            Some("empty") => Ok(LabelMap::Empty),
            other => bail!("manifest: unknown label mapping {other:?}"),
        }
    }
}

/// Global metadata for a shard directory, written at the end of the
/// single streaming pass and required to load any shard.
#[derive(Clone, Debug)]
pub struct ShardManifest {
    /// Source dataset name (file stem of the sharded libsvm file).
    pub name: String,
    /// Number of shards `K`.
    pub shards: usize,
    /// Total data rows across all shards.
    pub rows: usize,
    /// Feature dimension = max 1-based index over the whole file.
    pub dim: usize,
    /// Total nonzero entries (explicit zeros dropped, as in-memory).
    pub nnz: usize,
    /// Raw→±1 label rule (global, see [`LabelMap`]).
    pub mapping: LabelMap,
    /// Original label encoding, `[negative, positive]` — what trained
    /// models answer in (same convention as `Dataset::labels`).
    pub label_pair: [f64; 2],
    /// Rows per shard, indexed by shard id.
    pub shard_rows: Vec<usize>,
    /// Nonzeros per shard, indexed by shard id.
    pub shard_nnz: Vec<usize>,
}

impl ShardManifest {
    /// The shared [`Repr::Auto`] decision, made from GLOBAL counts so
    /// all shards agree with each other and with the in-memory reader.
    pub fn is_sparse_under(&self, repr: Repr) -> bool {
        match repr {
            Repr::Sparse => true,
            Repr::Dense => false,
            Repr::Auto => {
                let slots = (self.rows * self.dim).max(1);
                self.dim >= libsvm::AUTO_MIN_DIM
                    && (self.nnz as f64) <= libsvm::AUTO_MAX_DENSITY * slots as f64
            }
        }
    }

    fn save(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("cannot create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        writeln!(w, "{MANIFEST_MAGIC}")?;
        writeln!(w, "name {}", self.name)?;
        writeln!(w, "shards {}", self.shards)?;
        writeln!(w, "rows {}", self.rows)?;
        writeln!(w, "dim {}", self.dim)?;
        writeln!(w, "nnz {}", self.nnz)?;
        writeln!(w, "mapping {}", self.mapping.tag())?;
        writeln!(w, "pair {} {}", hexf(self.label_pair[0]), hexf(self.label_pair[1]))?;
        for k in 0..self.shards {
            writeln!(w, "shard {k} {} {}", self.shard_rows[k], self.shard_nnz[k])?;
        }
        w.flush()?;
        Ok(())
    }

    fn load(path: &Path) -> Result<ShardManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("cannot open {}", path.display()))?;
        let mut lines = text.lines();
        let magic = lines.next().unwrap_or("");
        if magic != MANIFEST_MAGIC {
            bail!("{}: not a shard manifest (got {magic:?})", path.display());
        }
        let mut name = String::new();
        let mut shards = None;
        let mut rows = None;
        let mut dim = None;
        let mut nnz = None;
        let mut mapping = None;
        let mut pair = DEFAULT_LABEL_PAIR;
        let mut shard_rows = Vec::new();
        let mut shard_nnz = Vec::new();
        for line in lines {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (key, rest) = line.split_once(' ').unwrap_or((line, ""));
            match key {
                "name" => name = rest.to_string(),
                "shards" => shards = Some(rest.parse().context("manifest: bad shards")?),
                "rows" => rows = Some(rest.parse().context("manifest: bad rows")?),
                "dim" => dim = Some(rest.parse().context("manifest: bad dim")?),
                "nnz" => nnz = Some(rest.parse().context("manifest: bad nnz")?),
                "mapping" => mapping = Some(LabelMap::from_tag(rest)?),
                "pair" => {
                    let mut p = rest.split_ascii_whitespace();
                    let lo = unhexf(p.next().context("manifest: pair missing lo")?)?;
                    let hi = unhexf(p.next().context("manifest: pair missing hi")?)?;
                    pair = [lo, hi];
                }
                "shard" => {
                    let mut p = rest.split_ascii_whitespace();
                    let k: usize =
                        p.next().context("manifest: shard id")?.parse().context("shard id")?;
                    if k != shard_rows.len() {
                        bail!("manifest: shard lines out of order at {k}");
                    }
                    shard_rows
                        .push(p.next().context("manifest: shard rows")?.parse().context("rows")?);
                    shard_nnz
                        .push(p.next().context("manifest: shard nnz")?.parse().context("nnz")?);
                }
                other => bail!("manifest: unknown key {other:?}"),
            }
        }
        let m = ShardManifest {
            name,
            shards: shards.context("manifest: missing shards")?,
            rows: rows.context("manifest: missing rows")?,
            dim: dim.context("manifest: missing dim")?,
            nnz: nnz.context("manifest: missing nnz")?,
            mapping: mapping.context("manifest: missing mapping")?,
            label_pair: pair,
            shard_rows,
            shard_nnz,
        };
        if m.shard_rows.len() != m.shards {
            bail!(
                "manifest: {} shard lines for {} shards",
                m.shard_rows.len(),
                m.shards
            );
        }
        if m.shard_rows.iter().sum::<usize>() != m.rows
            || m.shard_nnz.iter().sum::<usize>() != m.nnz
        {
            bail!("manifest: per-shard counts do not sum to totals");
        }
        Ok(m)
    }
}

fn shard_path(dir: &Path, k: usize) -> PathBuf {
    dir.join(format!("shard-{k}.csr"))
}

fn manifest_path(dir: &Path) -> PathBuf {
    dir.join("manifest")
}

/// Split a LIBSVM file into `k` on-disk shards in one streaming pass
/// (see the module docs for the format and invariants). Returns the
/// manifest it wrote. Existing shard files in `dir` are overwritten.
pub fn write_shards(
    src: impl AsRef<Path>,
    dir: impl AsRef<Path>,
    k: usize,
) -> Result<ShardManifest> {
    let (src, dir) = (src.as_ref(), dir.as_ref());
    if k == 0 {
        bail!("--shards must be at least 1");
    }
    std::fs::create_dir_all(dir)
        .with_context(|| format!("cannot create shard dir {}", dir.display()))?;
    let f = std::fs::File::open(src).with_context(|| format!("cannot open {}", src.display()))?;
    let reader = BufReader::new(f);
    let mut writers: Vec<BufWriter<std::fs::File>> = (0..k)
        .map(|i| {
            let p = shard_path(dir, i);
            let f = std::fs::File::create(&p)
                .with_context(|| format!("cannot create {}", p.display()))?;
            Ok(BufWriter::new(f))
        })
        .collect::<Result<_>>()?;

    let mut rows = 0usize;
    let mut dim = 0usize;
    let mut nnz = 0usize;
    let mut shard_rows = vec![0usize; k];
    let mut shard_nnz = vec![0usize; k];
    // label statistics for the end-of-pass global mapping, mirroring
    // the in-memory reader: rounded classes with the FIRST raw value of
    // each (so non-integer encodings round-trip verbatim), plus whether
    // every raw label is literally ±1 (the verbatim branch)
    let mut first_raw: BTreeMap<i64, f64> = BTreeMap::new();
    let mut all_pm1 = true;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("I/O error reading libsvm data")?;
        let Some(row) = libsvm::parse_data_line(&line, lineno, false)? else {
            continue;
        };
        let s = rows % k;
        let w = &mut writers[s];
        write!(w, "{}", hexf(row.label)).context("shard write")?;
        for &(col, val) in &row.entries {
            write!(w, " {col}:{}", hexf(val)).context("shard write")?;
        }
        writeln!(w).context("shard write")?;
        dim = dim.max(row.max_idx);
        nnz += row.entries.len();
        shard_nnz[s] += row.entries.len();
        shard_rows[s] += 1;
        rows += 1;
        all_pm1 &= row.label == 1.0 || row.label == -1.0;
        first_raw.entry(row.label.round() as i64).or_insert(row.label);
    }
    for w in &mut writers {
        w.flush().context("shard flush")?;
    }

    let distinct: BTreeSet<i64> = first_raw.keys().copied().collect();
    let verbatim_pm1 = rows > 0 && all_pm1 && distinct.len() == 2;
    let mapping = if rows == 0 {
        LabelMap::Empty
    } else if verbatim_pm1 {
        LabelMap::Pm1
    } else if distinct.len() == 1 {
        LabelMap::Single
    } else if distinct.len() == 2 {
        LabelMap::Greater { lo: *distinct.iter().next().expect("two labels") }
    } else {
        bail!("not a binary dataset: labels {distinct:?}");
    };
    let label_pair = if distinct.len() == 2 && !verbatim_pm1 {
        let mut it = distinct.iter();
        let (lo, hi) = (*it.next().expect("two labels"), *it.next().expect("two labels"));
        [first_raw[&lo], first_raw[&hi]]
    } else {
        DEFAULT_LABEL_PAIR
    };

    let name = src
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("shards")
        .to_string();
    let manifest = ShardManifest {
        name,
        shards: k,
        rows,
        dim,
        nnz,
        mapping,
        label_pair,
        shard_rows,
        shard_nnz,
    };
    manifest.save(&manifest_path(dir))?;
    Ok(manifest)
}

/// An opened shard directory: the manifest plus the ability to load any
/// single shard as an in-memory [`Dataset`] (the only part of the
/// training set ever resident at once on the out-of-core path).
#[derive(Clone, Debug)]
pub struct ShardSet {
    dir: PathBuf,
    manifest: ShardManifest,
}

impl ShardSet {
    /// Open an existing shard directory (validates the manifest and the
    /// presence of every shard file).
    pub fn open(dir: impl AsRef<Path>) -> Result<ShardSet> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ShardManifest::load(&manifest_path(&dir))?;
        for k in 0..manifest.shards {
            let p = shard_path(&dir, k);
            if !p.is_file() {
                bail!("shard dir {}: missing {}", dir.display(), p.display());
            }
        }
        Ok(ShardSet { dir, manifest })
    }

    /// Open `dir` if it already holds a valid manifest for `k` shards of
    /// `src` (same file stem), else (re)shard `src` into it. Reuse keys
    /// on (name, K) only — point `--shard-dir` at a dedicated directory
    /// per dataset, or delete it after changing the file in place.
    pub fn open_or_create(
        src: impl AsRef<Path>,
        dir: impl AsRef<Path>,
        k: usize,
    ) -> Result<ShardSet> {
        let stem = src.as_ref().file_stem().and_then(|s| s.to_str()).unwrap_or("shards");
        if let Ok(set) = ShardSet::open(dir.as_ref()) {
            if set.manifest.shards == k && set.manifest.name == stem {
                return Ok(set);
            }
        }
        let manifest = write_shards(src, dir.as_ref(), k)?;
        Ok(ShardSet { dir: dir.as_ref().to_path_buf(), manifest })
    }

    /// Global metadata (counts, dimension, label rule).
    pub fn manifest(&self) -> &ShardManifest {
        &self.manifest
    }

    /// Number of shards `K`.
    pub fn shards(&self) -> usize {
        self.manifest.shards
    }

    /// The shard directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Load shard `k` as a Dataset: hex rows decoded bit-exactly, the
    /// manifest's global label mapping and the global [`Repr`] decision
    /// applied (every shard of a set shares one representation).
    pub fn load_shard(&self, k: usize, repr: Repr) -> Result<Dataset> {
        let m = &self.manifest;
        if k >= m.shards {
            bail!("shard {k} out of range (K = {})", m.shards);
        }
        let p = shard_path(&self.dir, k);
        let f =
            std::fs::File::open(&p).with_context(|| format!("cannot open {}", p.display()))?;
        let rows = m.shard_rows[k];
        let mut labels = Vec::with_capacity(rows);
        let mut indptr = Vec::with_capacity(rows + 1);
        indptr.push(0usize);
        let mut indices = Vec::with_capacity(m.shard_nnz[k]);
        let mut vals = Vec::with_capacity(m.shard_nnz[k]);
        for (i, line) in BufReader::new(f).lines().enumerate() {
            let line = line.with_context(|| format!("I/O error reading {}", p.display()))?;
            let mut toks = line.split_ascii_whitespace();
            let raw = unhexf(toks.next().with_context(|| {
                format!("{} row {}: empty shard row", p.display(), i + 1)
            })?)?;
            labels.push(m.mapping.apply(raw));
            for tok in toks {
                let (c, v) = tok.split_once(':').with_context(|| {
                    format!("{} row {}: bad entry {tok:?}", p.display(), i + 1)
                })?;
                let col: usize = c
                    .parse()
                    .with_context(|| format!("{} row {}: bad column {c:?}", p.display(), i + 1))?;
                if col >= m.dim {
                    bail!("{} row {}: column {col} ≥ dim {}", p.display(), i + 1, m.dim);
                }
                indices.push(col);
                vals.push(unhexf(v)?);
            }
            indptr.push(indices.len());
        }
        if labels.len() != rows {
            bail!("{}: {} rows, manifest says {rows}", p.display(), labels.len());
        }
        let csr = CsrMat::new(rows, m.dim, indptr, indices, vals);
        let x = if m.is_sparse_under(repr) {
            Points::Sparse(csr)
        } else {
            Points::Dense(csr.to_dense())
        };
        let name = format!("{}-s{k}", m.name);
        Ok(Dataset::new(name, x, labels).with_labels(m.label_pair))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::libsvm::{read_file_with, write_file};
    use crate::data::synth;
    use crate::util::prng::Rng;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hss_svm_shard_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Write a small synthetic dataset to a libsvm file; returns paths.
    fn synth_file(dir: &Path, n: usize, dim: usize) -> PathBuf {
        let mut rng = Rng::new(7);
        let ds = synth::blobs(n, dim, 4, 0.6, &mut rng);
        let path = dir.join("ds.libsvm");
        write_file(&ds, &path).unwrap();
        path
    }

    #[test]
    fn round_robin_split_and_exact_reload() {
        let dir = tmpdir("rr");
        let src = synth_file(&dir, 53, 5);
        let full = read_file_with(&src, None, Repr::Auto).unwrap();
        let m = write_shards(&src, dir.join("s4"), 4).unwrap();
        assert_eq!(m.rows, 53);
        assert_eq!(m.shard_rows, vec![14, 13, 13, 13], "ragged last shards");
        assert_eq!(m.dim, full.dim());
        let set = ShardSet::open(dir.join("s4")).unwrap();
        // row i of the file lands in shard i % 4 at position i / 4, with
        // bit-exact values and the same ±1 labels as the in-memory read
        for k in 0..4 {
            let sh = set.load_shard(k, Repr::Auto).unwrap();
            assert_eq!(sh.len(), m.shard_rows[k]);
            for i in 0..sh.len() {
                let gi = i * 4 + k;
                assert_eq!(sh.y[i], full.y[gi], "label row {gi}");
                assert_eq!(sh.point(i), full.point(gi), "features row {gi}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn k1_shard_equals_in_memory_read() {
        let dir = tmpdir("k1");
        let src = synth_file(&dir, 31, 4);
        let full = read_file_with(&src, None, Repr::Auto).unwrap();
        write_shards(&src, dir.join("s1"), 1).unwrap();
        let set = ShardSet::open(dir.join("s1")).unwrap();
        let sh = set.load_shard(0, Repr::Auto).unwrap();
        assert_eq!(sh.y, full.y);
        assert_eq!(sh.labels, full.labels);
        assert_eq!(sh.is_sparse(), full.is_sparse());
        for i in 0..full.len() {
            assert_eq!(sh.point(i), full.point(i));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn label_mappings_match_reader() {
        let dir = tmpdir("lab");
        for (tag, text, want_y, want_pair) in [
            ("zero_one", "0 1:1.0\n1 1:2.0\n", vec![-1.0, 1.0], [0.0, 1.0]),
            ("one_two", "1 1:1.0\n2 1:2.0\n", vec![-1.0, 1.0], [1.0, 2.0]),
            ("pm1", "-1 1:1.0\n+1 1:2.0\n", vec![-1.0, 1.0], DEFAULT_LABEL_PAIR),
            ("single", "2 1:1.0\n2 1:2.0\n", vec![1.0, 1.0], DEFAULT_LABEL_PAIR),
            ("halves", "-0.5 1:1.0\n0.5 1:2.0\n", vec![-1.0, 1.0], [-0.5, 0.5]),
        ] {
            let src = dir.join(format!("{tag}.libsvm"));
            std::fs::write(&src, text).unwrap();
            let sdir = dir.join(format!("{tag}.shards"));
            write_shards(&src, &sdir, 2).unwrap();
            let set = ShardSet::open(&sdir).unwrap();
            let a = set.load_shard(0, Repr::Auto).unwrap();
            let b = set.load_shard(1, Repr::Auto).unwrap();
            assert_eq!(vec![a.y[0], b.y[0]], want_y, "{tag}");
            assert_eq!(a.labels, want_pair, "{tag}");
            assert_eq!(b.labels, want_pair, "{tag}");
        }
        // three classes is rejected at shard time, like the reader
        let src = dir.join("tri.libsvm");
        std::fs::write(&src, "1 1:1\n2 1:1\n3 1:1\n").unwrap();
        assert!(write_shards(&src, dir.join("tri.shards"), 2).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn global_auto_repr_rule() {
        let dir = tmpdir("repr");
        // wide + sparse globally → every shard CSR, even a shard whose
        // local density would round the other way
        let text = "+1 1:1 100:2\n-1 50:1\n+1 7:3\n-1 99:1\n";
        let src = dir.join("wide.libsvm");
        std::fs::write(&src, text).unwrap();
        write_shards(&src, dir.join("w"), 2).unwrap();
        let set = ShardSet::open(dir.join("w")).unwrap();
        assert!(set.manifest().is_sparse_under(Repr::Auto));
        assert!(set.load_shard(0, Repr::Auto).unwrap().is_sparse());
        assert!(set.load_shard(1, Repr::Auto).unwrap().is_sparse());
        assert!(!set.load_shard(0, Repr::Dense).unwrap().is_sparse());
        // narrow data stays dense under Auto
        let src2 = dir.join("narrow.libsvm");
        std::fs::write(&src2, "+1 8:1\n-1 2:1\n").unwrap();
        write_shards(&src2, dir.join("n"), 2).unwrap();
        let set2 = ShardSet::open(dir.join("n")).unwrap();
        assert!(!set2.load_shard(0, Repr::Auto).unwrap().is_sparse());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_shards_when_k_exceeds_rows() {
        let dir = tmpdir("empty");
        let src = dir.join("two.libsvm");
        std::fs::write(&src, "+1 1:1.0\n-1 2:1.0\n").unwrap();
        let m = write_shards(&src, dir.join("s5"), 5).unwrap();
        assert_eq!(m.shard_rows, vec![1, 1, 0, 0, 0]);
        let set = ShardSet::open(dir.join("s5")).unwrap();
        let e = set.load_shard(4, Repr::Auto).unwrap();
        assert_eq!(e.len(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_or_create_reuses_matching_manifest() {
        let dir = tmpdir("reuse");
        let src = synth_file(&dir, 20, 3);
        let sdir = dir.join("s");
        let a = ShardSet::open_or_create(&src, &sdir, 3).unwrap();
        let stamp = std::fs::metadata(manifest_path(&sdir)).unwrap().modified().unwrap();
        // same K: reused, manifest untouched
        let b = ShardSet::open_or_create(&src, &sdir, 3).unwrap();
        assert_eq!(stamp, std::fs::metadata(manifest_path(&sdir)).unwrap().modified().unwrap());
        assert_eq!(a.manifest().rows, b.manifest().rows);
        // different K: re-sharded
        let c = ShardSet::open_or_create(&src, &sdir, 2).unwrap();
        assert_eq!(c.shards(), 2);
        assert_eq!(c.manifest().rows, 20);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn validation_errors_carry_line_numbers() {
        let dir = tmpdir("err");
        let src = dir.join("bad.libsvm");
        std::fs::write(&src, "+1 1:1.0\n-1 5:1 3:2\n").unwrap();
        let e = write_shards(&src, dir.join("s"), 2).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("line 2") && msg.contains("ascending"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
