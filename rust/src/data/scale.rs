//! Feature scaling. LIBSVM's `svm-scale` normalizes features to [-1, 1]
//! or [0, 1]; accuracy and kernel-width grids in the paper assume scaled
//! inputs, so the same transform is applied to synthetic data before
//! training (fit on train, apply to test — never the other way).
//!
//! Sparse (CSR) datasets follow `svm-scale`'s implicit-zero convention:
//! fitting counts an implicit 0 toward a feature's min/max whenever the
//! feature is absent from at least one row, and the affine transform is
//! applied to **stored entries only** — absent features stay absent
//! (zero), exactly as `svm-scale` leaves them out of its output. This
//! preserves sparsity (the whole point of CSR storage) at the cost of
//! zeros not being shifted, which is the established LIBSVM behaviour
//! for sparse data.

use crate::data::dataset::Dataset;
use crate::data::sparse::Points;

/// Per-feature affine transform x ← (x − shift) * factor.
#[derive(Clone, Debug)]
pub struct Scaler {
    shift: Vec<f64>,
    factor: Vec<f64>,
}

/// Per-feature (min, max) over a [`Points`] container; sparse features
/// include an implicit 0 whenever any row omits them.
fn minmax(x: &Points) -> (Vec<f64>, Vec<f64>) {
    let dim = x.cols();
    let mut min = vec![f64::INFINITY; dim];
    let mut max = vec![f64::NEG_INFINITY; dim];
    match x {
        Points::Dense(m) => {
            for i in 0..m.rows() {
                for (j, &v) in m.row(i).iter().enumerate() {
                    min[j] = min[j].min(v);
                    max[j] = max[j].max(v);
                }
            }
        }
        Points::Sparse(s) => {
            let mut count = vec![0usize; dim];
            for i in 0..s.rows() {
                let (ci, vi) = s.row(i);
                for (&c, &v) in ci.iter().zip(vi.iter()) {
                    min[c] = min[c].min(v);
                    max[c] = max[c].max(v);
                    count[c] += 1;
                }
            }
            for j in 0..dim {
                if count[j] < s.rows() {
                    // at least one implicit zero participates
                    min[j] = min[j].min(0.0);
                    max[j] = max[j].max(0.0);
                }
            }
        }
    }
    (min, max)
}

impl Scaler {
    /// Fit a min-max scaler mapping each feature to [lo, hi].
    pub fn fit_minmax(ds: &Dataset, lo: f64, hi: f64) -> Scaler {
        Self::fit_minmax_points(&ds.x, lo, hi)
    }

    /// [`Scaler::fit_minmax`] over a bare [`Points`] container — the
    /// multiclass path fits here (a [`Dataset`] carries ±1 labels the
    /// scaler never looks at).
    pub fn fit_minmax_points(x: &Points, lo: f64, hi: f64) -> Scaler {
        let dim = x.cols();
        let (min, max) = minmax(x);
        let mut shift = vec![0.0; dim];
        let mut factor = vec![1.0; dim];
        for j in 0..dim {
            if max[j] > min[j] {
                shift[j] = min[j] - lo * (max[j] - min[j]) / (hi - lo);
                factor[j] = (hi - lo) / (max[j] - min[j]);
            } else if min[j].is_finite() {
                // constant feature → map to lo
                shift[j] = min[j] - lo;
                factor[j] = 1.0;
            }
            // else: feature never observed (empty dataset) → identity
        }
        Scaler { shift, factor }
    }

    /// Fit a z-score scaler (mean 0, std 1). Implicit zeros of sparse
    /// data count toward the mean and variance.
    pub fn fit_standard(ds: &Dataset) -> Scaler {
        let dim = ds.dim();
        let n = ds.len().max(1) as f64;
        let mut mean = vec![0.0; dim];
        let mut var = vec![0.0; dim];
        match &ds.x {
            Points::Dense(m) => {
                for i in 0..m.rows() {
                    for (j, &v) in m.row(i).iter().enumerate() {
                        mean[j] += v;
                    }
                }
                for mj in &mut mean {
                    *mj /= n;
                }
                for i in 0..m.rows() {
                    for (j, &v) in m.row(i).iter().enumerate() {
                        let d = v - mean[j];
                        var[j] += d * d;
                    }
                }
            }
            Points::Sparse(s) => {
                let mut count = vec![0usize; dim];
                for i in 0..s.rows() {
                    let (ci, vi) = s.row(i);
                    for (&c, &v) in ci.iter().zip(vi.iter()) {
                        mean[c] += v;
                        count[c] += 1;
                    }
                }
                for mj in &mut mean {
                    *mj /= n;
                }
                for i in 0..s.rows() {
                    let (ci, vi) = s.row(i);
                    for (&c, &v) in ci.iter().zip(vi.iter()) {
                        let d = v - mean[c];
                        var[c] += d * d;
                    }
                }
                // implicit zeros: (n − nnz_col) copies of (0 − mean)²
                for j in 0..dim {
                    let zeros = ds.len() - count[j];
                    var[j] += zeros as f64 * mean[j] * mean[j];
                }
            }
        }
        let factor = var
            .iter()
            .map(|&v| {
                let s = (v / n).sqrt();
                if s > 1e-300 {
                    1.0 / s
                } else {
                    1.0
                }
            })
            .collect();
        Scaler { shift: mean, factor }
    }

    /// Apply in place. Sparse rows scale their stored entries only
    /// (implicit zeros stay zero — the `svm-scale` convention).
    pub fn apply(&self, ds: &mut Dataset) {
        self.apply_points(&mut ds.x)
    }

    /// [`Scaler::apply`] over a bare [`Points`] container.
    pub fn apply_points(&self, x: &mut Points) {
        assert_eq!(x.cols(), self.shift.len(), "scaler dimension mismatch");
        match x {
            Points::Dense(m) => {
                for i in 0..m.rows() {
                    let row = m.row_mut(i);
                    for (j, v) in row.iter_mut().enumerate() {
                        *v = (*v - self.shift[j]) * self.factor[j];
                    }
                }
            }
            Points::Sparse(s) => {
                for i in 0..s.rows() {
                    let (cols, vals) = s.row_mut(i);
                    for (v, &c) in vals.iter_mut().zip(cols.iter()) {
                        *v = (*v - self.shift[c]) * self.factor[c];
                    }
                }
            }
        }
    }
}

/// Fit min-max [-1,1] on train and apply to both train and test.
pub fn scale_pair(train: &mut Dataset, test: &mut Dataset) {
    let sc = Scaler::fit_minmax(train, -1.0, 1.0);
    sc.apply(train);
    sc.apply(test);
}

/// [`scale_pair`] over bare feature containers — the multiclass
/// train/test path (fit on train only, like the binary path).
pub fn scale_points_pair(train: &mut Points, test: &mut Points) {
    let sc = Scaler::fit_minmax_points(train, -1.0, 1.0);
    sc.apply_points(train);
    sc.apply_points(test);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::CsrMat;
    use crate::linalg::Mat;

    fn ds(vals: Vec<f64>, rows: usize, cols: usize) -> Dataset {
        let y = (0..rows).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        Dataset::new("t", Mat::from_vec(rows, cols, vals), y)
    }

    #[test]
    fn minmax_maps_to_range() {
        let mut d = ds(vec![0.0, 10.0, 5.0, 20.0, 10.0, 0.0], 3, 2);
        let sc = Scaler::fit_minmax(&d, -1.0, 1.0);
        sc.apply(&mut d);
        for j in 0..2 {
            let col: Vec<f64> = (0..3).map(|i| d.x[(i, j)]).collect();
            let mn = col.iter().cloned().fold(f64::INFINITY, f64::min);
            let mx = col.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert!((mn + 1.0).abs() < 1e-12, "min {mn}");
            assert!((mx - 1.0).abs() < 1e-12, "max {mx}");
        }
    }

    #[test]
    fn constant_feature_is_safe() {
        let mut d = ds(vec![3.0, 1.0, 3.0, 2.0], 2, 2);
        let sc = Scaler::fit_minmax(&d, 0.0, 1.0);
        sc.apply(&mut d);
        assert!((d.x[(0, 0)] - 0.0).abs() < 1e-12);
        assert!(d.x.dense().data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn standard_scaler_zero_mean_unit_var() {
        let mut rng = crate::util::prng::Rng::new(3);
        let x = Mat::gauss(500, 4, &mut rng);
        let mut d = Dataset::new("g", x, vec![1.0; 500].iter().enumerate().map(|(i, _)| if i % 2 == 0 { 1.0 } else { -1.0 }).collect());
        let sc = Scaler::fit_standard(&d);
        sc.apply(&mut d);
        for j in 0..4 {
            let col: Vec<f64> = (0..500).map(|i| d.x[(i, j)]).collect();
            let mean: f64 = col.iter().sum::<f64>() / 500.0;
            let var: f64 = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 500.0;
            assert!(mean.abs() < 1e-10);
            assert!((var - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn pair_scaling_uses_train_statistics() {
        let mut tr = ds(vec![0.0, 0.0, 10.0, 10.0], 2, 2);
        let mut te = ds(vec![20.0, 20.0], 1, 2);
        scale_pair(&mut tr, &mut te);
        // test point outside train range maps beyond 1
        assert!((te.x[(0, 0)] - 3.0).abs() < 1e-12);
    }

    fn sparse_ds() -> Dataset {
        // col 0: {4, _, 2} (has implicit zero) → min 0, max 4
        // col 1: {2, -2, 6} (fully stored)     → min −2, max 6
        // col 2: never stored                  → constant 0
        let x = CsrMat::from_rows(
            3,
            &[vec![(0, 4.0), (1, 2.0)], vec![(1, -2.0)], vec![(0, 2.0), (1, 6.0)]],
        );
        Dataset::new("sp", x, vec![1.0, -1.0, 1.0])
    }

    #[test]
    fn sparse_minmax_counts_implicit_zeros_and_keeps_sparsity() {
        let mut d = sparse_ds();
        let sc = Scaler::fit_minmax(&d, 0.0, 1.0);
        sc.apply(&mut d);
        assert!(d.is_sparse());
        // col 0 range [0,4]: stored 4→1.0, 2→0.5; implicit zero stays 0
        assert!((d.x.get(0, 0) - 1.0).abs() < 1e-12);
        assert!((d.x.get(2, 0) - 0.5).abs() < 1e-12);
        assert_eq!(d.x.get(1, 0), 0.0);
        // col 1 range [−2,6]: 2→0.5, −2→0, 6→1
        assert!((d.x.get(0, 1) - 0.5).abs() < 1e-12);
        assert!(d.x.get(1, 1).abs() < 1e-12);
        assert!((d.x.get(2, 1) - 1.0).abs() < 1e-12);
        // never-stored column untouched
        assert_eq!(d.x.get(0, 2), 0.0);
        // representation and structure preserved
        assert_eq!(d.x.nnz(), 5);
    }

    #[test]
    fn sparse_fit_matches_dense_fit_on_same_data() {
        // when every implicit zero is also the column min/max candidate,
        // sparse and dense fits agree on the stored entries
        let sp = sparse_ds();
        let dense = Dataset::new("dn", sp.x.to_dense(), sp.y.clone());
        let mut a = sp.clone();
        let mut b = dense.clone();
        Scaler::fit_minmax(&sp, -1.0, 1.0).apply(&mut a);
        Scaler::fit_minmax(&dense, -1.0, 1.0).apply(&mut b);
        // stored entries transform identically (zeros differ by design:
        // dense shifts them, svm-scale leaves them)
        for (i, j) in [(0usize, 0usize), (0, 1), (1, 1), (2, 0), (2, 1)] {
            assert!(
                (a.x.get(i, j) - b.x.get(i, j)).abs() < 1e-12,
                "entry ({i},{j}): sparse {} vs dense {}",
                a.x.get(i, j),
                b.x.get(i, j)
            );
        }
    }

    #[test]
    fn sparse_standard_scaler_accounts_for_zeros() {
        let mut d = sparse_ds();
        let sc = Scaler::fit_standard(&d);
        sc.apply(&mut d);
        // col 1 is fully stored: mean/var must match the dense formula →
        // scaled entries have zero mean, unit variance
        let col: Vec<f64> = (0..3).map(|i| d.x.get(i, 1)).collect();
        let mean: f64 = col.iter().sum::<f64>() / 3.0;
        let var: f64 = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 3.0;
        assert!(mean.abs() < 1e-12, "mean {mean}");
        assert!((var - 1.0).abs() < 1e-12, "var {var}");
    }
}
