//! Feature scaling. LIBSVM's `svm-scale` normalizes features to [-1, 1]
//! or [0, 1]; accuracy and kernel-width grids in the paper assume scaled
//! inputs, so the same transform is applied to synthetic data before
//! training (fit on train, apply to test — never the other way).

use crate::data::dataset::Dataset;

/// Per-feature affine transform x ← (x − shift) * factor.
#[derive(Clone, Debug)]
pub struct Scaler {
    shift: Vec<f64>,
    factor: Vec<f64>,
}

impl Scaler {
    /// Fit a min-max scaler mapping each feature to [lo, hi].
    pub fn fit_minmax(ds: &Dataset, lo: f64, hi: f64) -> Scaler {
        let dim = ds.dim();
        let mut min = vec![f64::INFINITY; dim];
        let mut max = vec![f64::NEG_INFINITY; dim];
        for i in 0..ds.len() {
            for (j, &v) in ds.point(i).iter().enumerate() {
                min[j] = min[j].min(v);
                max[j] = max[j].max(v);
            }
        }
        let mut shift = vec![0.0; dim];
        let mut factor = vec![1.0; dim];
        for j in 0..dim {
            if max[j] > min[j] {
                shift[j] = min[j] - lo * (max[j] - min[j]) / (hi - lo);
                factor[j] = (hi - lo) / (max[j] - min[j]);
            } else {
                // constant feature → map to lo
                shift[j] = min[j] - lo;
                factor[j] = 1.0;
            }
        }
        Scaler { shift, factor }
    }

    /// Fit a z-score scaler (mean 0, std 1).
    pub fn fit_standard(ds: &Dataset) -> Scaler {
        let dim = ds.dim();
        let n = ds.len().max(1) as f64;
        let mut mean = vec![0.0; dim];
        for i in 0..ds.len() {
            for (j, &v) in ds.point(i).iter().enumerate() {
                mean[j] += v;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut var = vec![0.0; dim];
        for i in 0..ds.len() {
            for (j, &v) in ds.point(i).iter().enumerate() {
                let d = v - mean[j];
                var[j] += d * d;
            }
        }
        let factor = var
            .iter()
            .map(|&v| {
                let s = (v / n).sqrt();
                if s > 1e-300 {
                    1.0 / s
                } else {
                    1.0
                }
            })
            .collect();
        Scaler { shift: mean, factor }
    }

    /// Apply in place.
    pub fn apply(&self, ds: &mut Dataset) {
        assert_eq!(ds.dim(), self.shift.len(), "scaler dimension mismatch");
        for i in 0..ds.len() {
            let row = ds.x.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v = (*v - self.shift[j]) * self.factor[j];
            }
        }
    }
}

/// Fit min-max [-1,1] on train and apply to both train and test.
pub fn scale_pair(train: &mut Dataset, test: &mut Dataset) {
    let sc = Scaler::fit_minmax(train, -1.0, 1.0);
    sc.apply(train);
    sc.apply(test);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn ds(vals: Vec<f64>, rows: usize, cols: usize) -> Dataset {
        let y = (0..rows).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        Dataset::new("t", Mat::from_vec(rows, cols, vals), y)
    }

    #[test]
    fn minmax_maps_to_range() {
        let mut d = ds(vec![0.0, 10.0, 5.0, 20.0, 10.0, 0.0], 3, 2);
        let sc = Scaler::fit_minmax(&d, -1.0, 1.0);
        sc.apply(&mut d);
        for j in 0..2 {
            let col: Vec<f64> = (0..3).map(|i| d.x[(i, j)]).collect();
            let mn = col.iter().cloned().fold(f64::INFINITY, f64::min);
            let mx = col.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert!((mn + 1.0).abs() < 1e-12, "min {mn}");
            assert!((mx - 1.0).abs() < 1e-12, "max {mx}");
        }
    }

    #[test]
    fn constant_feature_is_safe() {
        let mut d = ds(vec![3.0, 1.0, 3.0, 2.0], 2, 2);
        let sc = Scaler::fit_minmax(&d, 0.0, 1.0);
        sc.apply(&mut d);
        assert!((d.x[(0, 0)] - 0.0).abs() < 1e-12);
        assert!(d.x.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn standard_scaler_zero_mean_unit_var() {
        let mut rng = crate::util::prng::Rng::new(3);
        let x = Mat::gauss(500, 4, &mut rng);
        let mut d = Dataset::new("g", x, vec![1.0; 500].iter().enumerate().map(|(i, _)| if i % 2 == 0 { 1.0 } else { -1.0 }).collect());
        let sc = Scaler::fit_standard(&d);
        sc.apply(&mut d);
        for j in 0..4 {
            let col: Vec<f64> = (0..500).map(|i| d.x[(i, j)]).collect();
            let mean: f64 = col.iter().sum::<f64>() / 500.0;
            let var: f64 = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 500.0;
            assert!(mean.abs() < 1e-10);
            assert!((var - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn pair_scaling_uses_train_statistics() {
        let mut tr = ds(vec![0.0, 0.0, 10.0, 10.0], 2, 2);
        let mut te = ds(vec![20.0, 20.0], 1, 2);
        scale_pair(&mut tr, &mut te);
        // test point outside train range maps beyond 1
        assert!((te.x[(0, 0)] - 3.0).abs() < 1e-12);
    }
}
