//! Sparse feature storage: CSR matrix + the dense-or-sparse [`Points`]
//! container the whole data plane is generic over.
//!
//! The paper's Table-1 benchmarks (a8a, w7a, rcv1.binary, webspam.uni)
//! ship as sparse LIBSVM files; rcv1.binary alone is 20k × 47,236 with
//! ~0.16% density, so densifying on load costs ~7.6 GB before training
//! even starts. [`CsrMat`] stores exactly the nonzeros (row pointers /
//! column indices / values, indices strictly ascending per row) and
//! [`Points`] lets every consumer — kernel blocks, cluster splits, ANN
//! distances, scaling, prediction tiles — run on either representation.
//! The dense arm of every operation delegates to the exact same
//! slice-level code paths the data plane used before `Points` existed,
//! so dense results are bit-for-bit unchanged.

use crate::linalg::blas;
use crate::linalg::Mat;

/// Compressed sparse row matrix (f64 values, strictly ascending column
/// indices within each row).
#[derive(Clone, PartialEq)]
pub struct CsrMat {
    rows: usize,
    cols: usize,
    /// Row pointers, length `rows + 1`; row i's entries live in
    /// `indices[indptr[i]..indptr[i+1]]` / `vals[..]`.
    indptr: Vec<usize>,
    indices: Vec<usize>,
    vals: Vec<f64>,
}

impl CsrMat {
    /// Build from raw CSR arrays (validated).
    pub fn new(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        vals: Vec<f64>,
    ) -> CsrMat {
        assert_eq!(indptr.len(), rows + 1, "indptr length mismatch");
        assert_eq!(indptr[0], 0, "indptr must start at 0");
        assert_eq!(*indptr.last().unwrap(), indices.len(), "indptr/indices mismatch");
        assert_eq!(indices.len(), vals.len(), "indices/vals length mismatch");
        for i in 0..rows {
            assert!(indptr[i] <= indptr[i + 1], "indptr must be monotone");
            let r = &indices[indptr[i]..indptr[i + 1]];
            for w in r.windows(2) {
                assert!(w[0] < w[1], "row {i}: column indices must be strictly ascending");
            }
            if let Some(&last) = r.last() {
                assert!(last < cols, "row {i}: column index {last} out of range {cols}");
            }
        }
        CsrMat { rows, cols, indptr, indices, vals }
    }

    /// Build from per-row (column, value) lists (each strictly ascending).
    pub fn from_rows(cols: usize, rows: &[Vec<(usize, f64)>]) -> CsrMat {
        let nnz = rows.iter().map(Vec::len).sum();
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        indptr.push(0);
        for r in rows {
            for &(c, v) in r {
                indices.push(c);
                vals.push(v);
            }
            indptr.push(indices.len());
        }
        CsrMat::new(rows.len(), cols, indptr, indices, vals)
    }

    /// Convert a dense matrix, dropping exact zeros.
    pub fn from_dense(m: &Mat) -> CsrMat {
        let mut indptr = Vec::with_capacity(m.rows() + 1);
        let mut indices = Vec::new();
        let mut vals = Vec::new();
        indptr.push(0);
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j);
                    vals.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CsrMat { rows: m.rows(), cols: m.cols(), indptr, indices, vals }
    }

    /// Materialize as a dense matrix.
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (ci, vi) = self.row(i);
            let r = m.row_mut(i);
            for (&c, &v) in ci.iter().zip(vi.iter()) {
                r[c] = v;
            }
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// (column indices, values) of row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        debug_assert!(i < self.rows);
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.vals[lo..hi])
    }

    /// Row i with mutable values (indices stay fixed — used by scaling).
    /// The two slices borrow disjoint fields, so no copying is needed.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> (&[usize], &mut [f64]) {
        debug_assert!(i < self.rows);
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &mut self.vals[lo..hi])
    }

    /// Entry (i, j), implicit zeros included.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (ci, vi) = self.row(i);
        match ci.binary_search(&j) {
            Ok(k) => vi[k],
            Err(_) => 0.0,
        }
    }

    /// Copy of the rows selected by `idx` (in that order).
    pub fn select_rows(&self, idx: &[usize]) -> CsrMat {
        let nnz: usize = idx.iter().map(|&i| self.indptr[i + 1] - self.indptr[i]).sum();
        let mut indptr = Vec::with_capacity(idx.len() + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        indptr.push(0);
        for &i in idx {
            let (ci, vi) = self.row(i);
            indices.extend_from_slice(ci);
            vals.extend_from_slice(vi);
            indptr.push(indices.len());
        }
        CsrMat { rows: idx.len(), cols: self.cols, indptr, indices, vals }
    }

    /// Squared norms of all rows.
    pub fn self_norms(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|i| {
                let (_, v) = self.row(i);
                v.iter().map(|x| x * x).sum()
            })
            .collect()
    }

    /// Heap bytes held (values + indices + row pointers).
    pub fn bytes(&self) -> usize {
        self.vals.len() * std::mem::size_of::<f64>()
            + self.indices.len() * std::mem::size_of::<usize>()
            + self.indptr.len() * std::mem::size_of::<usize>()
    }
}

impl std::fmt::Debug for CsrMat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CsrMat {}x{} ({} nnz, {:.3}% dense)",
            self.rows,
            self.cols,
            self.nnz(),
            100.0 * self.nnz() as f64 / (self.rows.max(1) * self.cols.max(1)) as f64
        )
    }
}

/// Merge-join dot product of two sparse rows (ascending indices).
fn dot_ss(ai: &[usize], av: &[f64], bi: &[usize], bv: &[f64]) -> f64 {
    let (mut p, mut q) = (0usize, 0usize);
    let mut acc = 0.0;
    while p < ai.len() && q < bi.len() {
        match ai[p].cmp(&bi[q]) {
            std::cmp::Ordering::Less => p += 1,
            std::cmp::Ordering::Greater => q += 1,
            std::cmp::Ordering::Equal => {
                acc += av[p] * bv[q];
                p += 1;
                q += 1;
            }
        }
    }
    acc
}

/// Dot of a sparse row with a dense vector.
#[inline]
fn dot_sd(ci: &[usize], vi: &[f64], dense: &[f64]) -> f64 {
    let mut acc = 0.0;
    for (&c, &v) in ci.iter().zip(vi.iter()) {
        acc += v * dense[c];
    }
    acc
}

/// Exact squared distance between a sparse row and a dense vector
/// (walks the full dense vector, O(dim)).
fn dist2_sd(ci: &[usize], vi: &[f64], dense: &[f64]) -> f64 {
    let mut acc = 0.0;
    let mut p = 0usize;
    for (j, &b) in dense.iter().enumerate() {
        let a = if p < ci.len() && ci[p] == j {
            let v = vi[p];
            p += 1;
            v
        } else {
            0.0
        };
        let d = a - b;
        acc += d * d;
    }
    acc
}

/// Exact squared distance between two sparse rows (merge over the union
/// of their index sets, O(nnz_a + nnz_b)).
fn dist2_ss(ai: &[usize], av: &[f64], bi: &[usize], bv: &[f64]) -> f64 {
    let (mut p, mut q) = (0usize, 0usize);
    let mut acc = 0.0;
    while p < ai.len() || q < bi.len() {
        let d = if q >= bi.len() || (p < ai.len() && ai[p] < bi[q]) {
            let v = av[p];
            p += 1;
            v
        } else if p >= ai.len() || bi[q] < ai[p] {
            let v = -bv[q];
            q += 1;
            v
        } else {
            let v = av[p] - bv[q];
            p += 1;
            q += 1;
            v
        };
        acc += d * d;
    }
    acc
}

/// Feature rows in either dense or CSR representation.
///
/// Every accessor's `Dense` arm runs the identical slice-level code the
/// pre-`Points` data plane ran (same `blas` calls, same loop order), so
/// introducing the enum changes no dense result bit.
#[derive(Clone, PartialEq)]
pub enum Points {
    Dense(Mat),
    Sparse(CsrMat),
}

impl From<Mat> for Points {
    fn from(m: Mat) -> Points {
        Points::Dense(m)
    }
}

impl From<CsrMat> for Points {
    fn from(m: CsrMat) -> Points {
        Points::Sparse(m)
    }
}

static ZERO: f64 = 0.0;

impl Points {
    #[inline]
    pub fn rows(&self) -> usize {
        match self {
            Points::Dense(m) => m.rows(),
            Points::Sparse(m) => m.rows(),
        }
    }

    #[inline]
    pub fn cols(&self) -> usize {
        match self {
            Points::Dense(m) => m.cols(),
            Points::Sparse(m) => m.cols(),
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows() == 0
    }

    #[inline]
    pub fn is_sparse(&self) -> bool {
        matches!(self, Points::Sparse(_))
    }

    /// Stored entries (dense counts every slot).
    pub fn nnz(&self) -> usize {
        match self {
            Points::Dense(m) => m.rows() * m.cols(),
            Points::Sparse(m) => m.nnz(),
        }
    }

    /// Heap bytes held by the representation.
    pub fn bytes(&self) -> usize {
        match self {
            Points::Dense(m) => m.bytes(),
            Points::Sparse(m) => m.bytes(),
        }
    }

    /// Borrow the dense matrix; panics on sparse points. Reserved for
    /// the few dense-only numeric paths (PJRT tiles, dense baselines) —
    /// everything on the serve/train path must use the sparse-aware ops.
    pub fn dense(&self) -> &Mat {
        match self {
            Points::Dense(m) => m,
            Points::Sparse(m) => panic!(
                "dense-only path reached sparse points ({m:?}); use the Points/kernel sparse ops"
            ),
        }
    }

    /// Dense row slice; panics on sparse points (see [`Points::dense`]).
    pub fn dense_row(&self, i: usize) -> &[f64] {
        self.dense().row(i)
    }

    /// Materialize a dense copy (cheap move for `Dense`).
    pub fn into_dense(self) -> Mat {
        match self {
            Points::Dense(m) => m,
            Points::Sparse(m) => m.to_dense(),
        }
    }

    /// Dense copy without consuming.
    pub fn to_dense(&self) -> Mat {
        match self {
            Points::Dense(m) => m.clone(),
            Points::Sparse(m) => m.to_dense(),
        }
    }

    /// Entry (i, j), implicit zeros included.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        match self {
            Points::Dense(m) => m[(i, j)],
            Points::Sparse(m) => m.get(i, j),
        }
    }

    /// Copy of the rows selected by `idx`, keeping the representation.
    pub fn select_rows(&self, idx: &[usize]) -> Points {
        match self {
            Points::Dense(m) => Points::Dense(m.select_rows(idx)),
            Points::Sparse(m) => Points::Sparse(m.select_rows(idx)),
        }
    }

    /// Squared norms of all rows (the ‖x‖² terms of the kernel-block
    /// expansion).
    pub fn self_norms(&self) -> Vec<f64> {
        match self {
            Points::Dense(m) => (0..m.rows()).map(|i| blas::dot(m.row(i), m.row(i))).collect(),
            Points::Sparse(m) => m.self_norms(),
        }
    }

    /// Inner product of row `i` of `self` with row `j` of `other`
    /// (any representation pairing).
    pub fn dot_row(&self, i: usize, other: &Points, j: usize) -> f64 {
        debug_assert_eq!(self.cols(), other.cols(), "feature dimension mismatch");
        match (self, other) {
            (Points::Dense(a), Points::Dense(b)) => blas::dot(a.row(i), b.row(j)),
            (Points::Sparse(a), Points::Dense(b)) => {
                let (ci, vi) = a.row(i);
                dot_sd(ci, vi, b.row(j))
            }
            (Points::Dense(a), Points::Sparse(b)) => {
                let (cj, vj) = b.row(j);
                dot_sd(cj, vj, a.row(i))
            }
            (Points::Sparse(a), Points::Sparse(b)) => {
                let (ci, vi) = a.row(i);
                let (cj, vj) = b.row(j);
                dot_ss(ci, vi, cj, vj)
            }
        }
    }

    /// Inner product of row `i` with a dense vector.
    #[inline]
    pub fn dot_dense_vec(&self, i: usize, v: &[f64]) -> f64 {
        match self {
            Points::Dense(m) => blas::dot(m.row(i), v),
            Points::Sparse(m) => {
                let (ci, vi) = m.row(i);
                dot_sd(ci, vi, v)
            }
        }
    }

    /// Exact squared distance between row `i` of `self` and row `j` of
    /// `other`.
    pub fn dist2_rows(&self, i: usize, other: &Points, j: usize) -> f64 {
        debug_assert_eq!(self.cols(), other.cols(), "feature dimension mismatch");
        match (self, other) {
            (Points::Dense(a), Points::Dense(b)) => blas::dist2(a.row(i), b.row(j)),
            (Points::Sparse(a), Points::Dense(b)) => {
                let (ci, vi) = a.row(i);
                dist2_sd(ci, vi, b.row(j))
            }
            (Points::Dense(a), Points::Sparse(b)) => {
                let (cj, vj) = b.row(j);
                dist2_sd(cj, vj, a.row(i))
            }
            (Points::Sparse(a), Points::Sparse(b)) => {
                let (ci, vi) = a.row(i);
                let (cj, vj) = b.row(j);
                dist2_ss(ci, vi, cj, vj)
            }
        }
    }

    /// Exact squared distance between row `i` and a dense vector.
    #[inline]
    pub fn dist2_dense_vec(&self, i: usize, v: &[f64]) -> f64 {
        match self {
            Points::Dense(m) => blas::dist2(m.row(i), v),
            Points::Sparse(m) => {
                let (ci, vi) = m.row(i);
                dist2_sd(ci, vi, v)
            }
        }
    }

    /// acc += a · row(i) (dense accumulator — centroid/mean sweeps).
    #[inline]
    pub fn add_row_scaled(&self, i: usize, a: f64, acc: &mut [f64]) {
        match self {
            Points::Dense(m) => blas::axpy(a, m.row(i), acc),
            Points::Sparse(m) => {
                let (ci, vi) = m.row(i);
                for (&c, &v) in ci.iter().zip(vi.iter()) {
                    acc[c] += a * v;
                }
            }
        }
    }

    /// Inner product of row `i` with a dense slice, written into `out`
    /// for every row of `other`: out[j] = ⟨self[i], other[j]⟩.
    pub fn row_dots(&self, i: usize, other: &Points, out: &mut [f64]) {
        debug_assert_eq!(other.rows(), out.len());
        match (self, other) {
            // dense×dense: same per-pair blas::dot the old kernel_row used
            (Points::Dense(a), Points::Dense(b)) => {
                let xi = a.row(i);
                for (j, o) in out.iter_mut().enumerate() {
                    *o = blas::dot(xi, b.row(j));
                }
            }
            (Points::Sparse(a), Points::Dense(b)) => {
                let (ci, vi) = a.row(i);
                for (j, o) in out.iter_mut().enumerate() {
                    *o = dot_sd(ci, vi, b.row(j));
                }
            }
            (Points::Dense(a), Points::Sparse(b)) => {
                let xi = a.row(i);
                for (j, o) in out.iter_mut().enumerate() {
                    let (cj, vj) = b.row(j);
                    *o = dot_sd(cj, vj, xi);
                }
            }
            (Points::Sparse(a), Points::Sparse(b)) => {
                let (ci, vi) = a.row(i);
                for (j, o) in out.iter_mut().enumerate() {
                    let (cj, vj) = b.row(j);
                    *o = dot_ss(ci, vi, cj, vj);
                }
            }
        }
    }
}

impl std::ops::Index<(usize, usize)> for Points {
    type Output = f64;

    /// Read-only entry access; sparse implicit zeros yield `&0.0`.
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        match self {
            Points::Dense(m) => &m[(i, j)],
            Points::Sparse(m) => {
                let (ci, vi) = m.row(i);
                match ci.binary_search(&j) {
                    Ok(k) => &vi[k],
                    Err(_) => &ZERO,
                }
            }
        }
    }
}

impl std::fmt::Debug for Points {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Points::Dense(m) => write!(f, "Points::Dense({}x{})", m.rows(), m.cols()),
            Points::Sparse(m) => write!(f, "Points::Sparse({m:?})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::testkit;
    use crate::util::testkit::random_csr;

    #[test]
    fn dense_roundtrip_preserves_entries() {
        let mut rng = Rng::new(901);
        let s = random_csr(17, 9, 0.3, &mut rng);
        let d = s.to_dense();
        assert_eq!(CsrMat::from_dense(&d), s);
        for i in 0..17 {
            for j in 0..9 {
                assert_eq!(s.get(i, j), d[(i, j)]);
            }
        }
    }

    #[test]
    fn row_ops_match_dense_oracle() {
        let mut rng = Rng::new(902);
        for _case in 0..20 {
            let cols = 1 + rng.below(24);
            let a = random_csr(6, cols, 0.4, &mut rng);
            let b = random_csr(5, cols, 0.2, &mut rng);
            let ad = Points::Dense(a.to_dense());
            let bd = Points::Dense(b.to_dense());
            let asp = Points::Sparse(a);
            let bsp = Points::Sparse(b);
            let v: Vec<f64> = (0..cols).map(|_| rng.gauss()).collect();
            for i in 0..6 {
                testkit::assert_close(
                    asp.dot_dense_vec(i, &v),
                    ad.dot_dense_vec(i, &v),
                    1e-12,
                );
                testkit::assert_close(
                    asp.dist2_dense_vec(i, &v),
                    ad.dist2_dense_vec(i, &v),
                    1e-12,
                );
                for j in 0..5 {
                    testkit::assert_close(
                        asp.dot_row(i, &bsp, j),
                        ad.dot_row(i, &bd, j),
                        1e-12,
                    );
                    testkit::assert_close(
                        asp.dot_row(i, &bd, j),
                        ad.dot_row(i, &bsp, j),
                        1e-12,
                    );
                    testkit::assert_close(
                        asp.dist2_rows(i, &bsp, j),
                        ad.dist2_rows(i, &bd, j),
                        1e-12,
                    );
                    testkit::assert_close(
                        asp.dist2_rows(i, &bd, j),
                        ad.dist2_rows(i, &bsp, j),
                        1e-12,
                    );
                }
            }
            let ns = asp.self_norms();
            let nd = ad.self_norms();
            testkit::assert_allclose(&ns, &nd, 1e-12);
        }
    }

    #[test]
    fn empty_rows_and_all_zero_columns() {
        // row 1 empty; column 2 never referenced
        let s = CsrMat::from_rows(
            4,
            &[vec![(0, 1.0), (3, -2.0)], vec![], vec![(1, 0.5)], vec![(3, 4.0)]],
        );
        assert_eq!(s.nnz(), 4);
        let p = Points::Sparse(s);
        assert_eq!(p.self_norms(), vec![5.0, 0.0, 0.25, 16.0]);
        assert_eq!(p.dot_row(1, &p, 0), 0.0);
        assert_eq!(p.dist2_rows(1, &p, 2), 0.25);
        assert_eq!(p.get(0, 2), 0.0);
        assert_eq!(p[(1, 3)], 0.0);
        assert_eq!(p[(0, 3)], -2.0);
    }

    #[test]
    fn select_rows_keeps_representation() {
        let mut rng = Rng::new(903);
        let s = random_csr(10, 6, 0.3, &mut rng);
        let d = s.to_dense();
        let idx = [7usize, 0, 7, 3];
        let ss = Points::Sparse(s).select_rows(&idx);
        let ds = Points::Dense(d).select_rows(&idx);
        assert!(ss.is_sparse() && !ds.is_sparse());
        assert_eq!(ss.to_dense(), ds.to_dense());
    }

    #[test]
    fn add_row_scaled_accumulates() {
        let s = CsrMat::from_rows(3, &[vec![(0, 2.0), (2, 3.0)]]);
        let p = Points::Sparse(s);
        let mut acc = vec![1.0, 1.0, 1.0];
        p.add_row_scaled(0, 0.5, &mut acc);
        assert_eq!(acc, vec![2.0, 1.0, 2.5]);
    }

    #[test]
    fn row_dots_matches_pairwise() {
        let mut rng = Rng::new(904);
        let a = random_csr(4, 12, 0.35, &mut rng);
        let b = random_csr(7, 12, 0.35, &mut rng);
        let (ap, bp) = (Points::Sparse(a), Points::Sparse(b));
        let mut out = vec![0.0; 7];
        for i in 0..4 {
            ap.row_dots(i, &bp, &mut out);
            for j in 0..7 {
                testkit::assert_close(out[j], ap.dot_row(i, &bp, j), 1e-14);
            }
        }
    }

    #[test]
    #[should_panic(expected = "strictly ascending")]
    fn rejects_unsorted_indices() {
        CsrMat::from_rows(4, &[vec![(2, 1.0), (1, 2.0)]]);
    }

    #[test]
    #[should_panic(expected = "dense-only path")]
    fn dense_accessor_panics_on_sparse() {
        Points::Sparse(CsrMat::from_rows(2, &[vec![(0, 1.0)]])).dense();
    }
}
