//! LIBSVM sparse text format reader/writer.
//!
//! Format: one point per line, `<label> <index>:<value> ...` with 1-based
//! **strictly ascending** indices (duplicate or out-of-order indices and
//! non-finite values are rejected with line-numbered errors, matching
//! LIBSVM's contract). All of the paper's datasets ship in this format,
//! so a user with the real a8a/w7a/rcv1.binary/... files can run the
//! exact experiments; our synthetic generators write the same format for
//! parity.
//!
//! The parser is streaming: it accumulates CSR arrays directly and never
//! materializes a dense matrix. The returned representation is chosen by
//! [`Repr`]: `Auto` keeps wide, sparse data (dim ≥ 32 and density ≤ 25%)
//! in CSR form and densifies the rest, so rcv1-class inputs load in
//! O(nnz) memory while small dense test fixtures behave exactly as
//! before.
//!
//! Label convention ([`read`]): `{−1, +1}` files are read verbatim; any
//! other two-label encoding maps the numerically greater label to `+1`
//! and the smaller to `−1` (`{0,1}`: 1 is positive; `{1,2}`: 2 is
//! positive). A single-class file maps positive labels to `+1` and
//! non-positive ones to `−1`. [`write_file`] always emits `{−1, +1}`, so
//! write→read round-trips preserve labels exactly.
//!
//! The predict/serve paths use [`read_features`] instead: it skips the
//! binary-label normalization entirely (a serving batch legitimately
//! mixes labeled and unlabeled lines), accepts bare feature lines (first
//! token contains `:`) as unlabeled (label = NaN), and never fails on
//! "not a binary dataset".

use crate::data::dataset::Dataset;
use crate::data::sparse::{CsrMat, Points};
use crate::svm::MulticlassDataset;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Requested in-memory representation for parsed features.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Repr {
    /// CSR when the data is wide and sparse (dim ≥ 32, density ≤ 25%),
    /// dense otherwise.
    #[default]
    Auto,
    Dense,
    Sparse,
}

/// Auto-representation thresholds (see [`Repr::Auto`]). Shared with the
/// shard loader (`data/shard`), which applies the same rule using the
/// *global* manifest counts so every shard of a dataset picks the same
/// representation the in-memory reader would.
pub(crate) const AUTO_MIN_DIM: usize = 32;
pub(crate) const AUTO_MAX_DENSITY: f64 = 0.25;

/// Streaming parse result: CSR triplets + raw labels (NaN = unlabeled).
struct Parsed {
    labels: Vec<f64>,
    indptr: Vec<usize>,
    indices: Vec<usize>,
    vals: Vec<f64>,
    max_idx: usize,
    /// 1-based (offset-adjusted) number of the line where `max_idx` was
    /// seen — so a forced-dimension overflow names the offending line.
    max_idx_line: usize,
}

/// One validated LIBSVM data line ([`parse_data_line`]): the raw label,
/// the **nonzero** entries in 0-based column order, and the largest
/// 1-based index seen on the line (zero-valued entries included — the
/// dimension of a dataset counts explicit zeros).
pub(crate) struct ParsedLine {
    pub label: f64,
    pub entries: Vec<(usize, f64)>,
    pub max_idx: usize,
}

/// Validate and split a single LIBSVM line; `Ok(None)` for blank lines
/// and `#` comments. This is the one copy of the format contract
/// (1-based strictly ascending indices, finite values, zeros dropped):
/// the in-memory reader below and the out-of-core shard writer
/// (`data/shard`) both go through it, so a file either parses
/// identically on both paths or fails with the same line-numbered error.
/// `allow_bare` accepts label-less lines whose first token is an
/// `index:value` pair (label recorded as NaN); `lineno` is the 0-based
/// line number used in error messages.
pub(crate) fn parse_data_line(
    line: &str,
    lineno: usize,
    allow_bare: bool,
) -> Result<Option<ParsedLine>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut parts = line.split_ascii_whitespace().peekable();
    let first = *parts.peek().unwrap();
    let label = if allow_bare && first.contains(':') {
        // bare feature line: no label token to consume
        f64::NAN
    } else {
        let lab_tok = parts.next().unwrap();
        let label: f64 = lab_tok
            .parse()
            .with_context(|| format!("line {}: bad label {lab_tok:?}", lineno + 1))?;
        if !label.is_finite() {
            bail!("line {}: non-finite label {lab_tok:?}", lineno + 1);
        }
        label
    };
    let mut out = ParsedLine { label, entries: Vec::new(), max_idx: 0 };
    let mut last_idx: Option<usize> = None;
    for tok in parts {
        let (i_str, v_str) = tok
            .split_once(':')
            .with_context(|| format!("line {}: bad pair {tok:?}", lineno + 1))?;
        let idx: usize = i_str
            .parse()
            .with_context(|| format!("line {}: bad index {i_str:?}", lineno + 1))?;
        if idx == 0 {
            bail!("line {}: libsvm indices are 1-based, got 0", lineno + 1);
        }
        if let Some(prev) = last_idx {
            if idx <= prev {
                bail!(
                    "line {}: feature index {idx} is not strictly ascending \
                     (previous index {prev}; libsvm requires ascending, duplicate-free indices)",
                    lineno + 1
                );
            }
        }
        last_idx = Some(idx);
        let val: f64 = v_str
            .parse()
            .with_context(|| format!("line {}: bad value {v_str:?}", lineno + 1))?;
        if !val.is_finite() {
            bail!("line {}: non-finite value {v_str:?} for index {idx}", lineno + 1);
        }
        out.max_idx = out.max_idx.max(idx);
        if val != 0.0 {
            out.entries.push((idx - 1, val));
        }
    }
    Ok(Some(out))
}

/// Parse LIBSVM lines into CSR arrays without ever building a dense
/// matrix. `allow_bare` additionally accepts label-less lines whose
/// first token is an `index:value` pair (label recorded as NaN).
/// `line_offset` shifts every reported line number: serving paths that
/// re-parse a single line `n` of a longer stream pass `n − 1` so errors
/// carry the correct global number natively.
fn parse_stream(r: impl BufRead, allow_bare: bool, line_offset: usize) -> Result<Parsed> {
    let mut p = Parsed {
        labels: Vec::new(),
        indptr: vec![0],
        indices: Vec::new(),
        vals: Vec::new(),
        max_idx: 0,
        max_idx_line: 0,
    };
    for (rel, line) in r.lines().enumerate() {
        let lineno = rel + line_offset;
        let line = line.context("I/O error reading libsvm data")?;
        let Some(row) = parse_data_line(&line, lineno, allow_bare)? else {
            continue;
        };
        if row.max_idx > p.max_idx {
            p.max_idx = row.max_idx;
            p.max_idx_line = lineno + 1;
        }
        for (col, val) in row.entries {
            p.indices.push(col);
            p.vals.push(val);
        }
        p.labels.push(row.label);
        p.indptr.push(p.indices.len());
    }
    Ok(p)
}

/// Resolve the feature dimension against a forced value.
fn resolve_dim(parsed: &Parsed, dim: Option<usize>) -> Result<usize> {
    match dim {
        Some(d) => {
            if parsed.max_idx > d {
                bail!(
                    "line {}: feature index {} exceeds forced dimension {d}",
                    parsed.max_idx_line,
                    parsed.max_idx
                );
            }
            Ok(d)
        }
        None => Ok(parsed.max_idx),
    }
}

/// Pick dense or CSR per `repr` and materialize the [`Points`]
/// (consumes the streamed CSR arrays — no second copy).
fn build_points(parsed: Parsed, dim: usize, repr: Repr) -> (Points, Vec<f64>) {
    let Parsed { labels, indptr, indices, vals, .. } = parsed;
    let rows = labels.len();
    let csr = CsrMat::new(rows, dim, indptr, indices, vals);
    let sparse = match repr {
        Repr::Sparse => true,
        Repr::Dense => false,
        Repr::Auto => {
            let slots = (rows * dim).max(1);
            dim >= AUTO_MIN_DIM && (csr.nnz() as f64) <= AUTO_MAX_DENSITY * slots as f64
        }
    };
    let x = if sparse {
        Points::Sparse(csr)
    } else {
        Points::Dense(csr.to_dense())
    };
    (x, labels)
}

/// Parse LIBSVM text from a reader with binary-label normalization.
/// `dim` forces the feature dimension (use `None` to infer from the max
/// index seen).
pub fn read(r: impl BufRead, dim: Option<usize>) -> Result<Dataset> {
    read_with(r, dim, Repr::Auto)
}

/// [`read`] with an explicit representation request.
pub fn read_with(r: impl BufRead, dim: Option<usize>, repr: Repr) -> Result<Dataset> {
    let parsed = parse_stream(r, false, 0)?;
    binary_from_parsed(parsed, dim, repr)
}

/// A parsed LIBSVM file of either arity ([`read_any`]).
pub enum LibsvmData {
    /// ≤ 2 distinct labels: the historical binary path (±1-normalized
    /// labels, original pair recorded).
    Binary(Dataset),
    /// > 2 distinct labels: a multiclass dataset with integer classes.
    Multi(MulticlassDataset),
}

/// Parse LIBSVM text, auto-detecting the label arity: files with more
/// than two distinct (rounded) labels load as a [`MulticlassDataset`]
/// whose classes are the rounded integer labels; everything else goes
/// through the binary path exactly as [`read_with`] (same ±1
/// normalization, same recorded label pair). The `train`/`grid` CLI
/// front-ends use this to route multiclass files onto the one-vs-one
/// trainer (`--binary` forces the old strict path).
pub fn read_any(r: impl BufRead, dim: Option<usize>, repr: Repr) -> Result<LibsvmData> {
    let parsed = parse_stream(r, false, 0)?;
    let distinct: std::collections::BTreeSet<i64> =
        parsed.labels.iter().map(|&l| l.round() as i64).collect();
    if distinct.len() > 2 {
        Ok(LibsvmData::Multi(multiclass_from_parsed(parsed, dim, repr)?))
    } else {
        Ok(LibsvmData::Binary(binary_from_parsed(parsed, dim, repr)?))
    }
}

/// [`read_any`] from a file path (the dataset name is the file stem).
pub fn read_file_any(path: impl AsRef<Path>, dim: Option<usize>, repr: Repr) -> Result<LibsvmData> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("cannot open {}", path.as_ref().display()))?;
    let mut data = read_any(std::io::BufReader::new(f), dim, repr)?;
    if let Some(stem) = path.as_ref().file_stem().and_then(|s| s.to_str()) {
        match &mut data {
            LibsvmData::Binary(ds) => ds.name = stem.to_string(),
            LibsvmData::Multi(ds) => ds.name = stem.to_string(),
        }
    }
    Ok(data)
}

/// Strict multiclass parse: labels are required on every line (no bare
/// feature lists) and become rounded integer classes verbatim — no
/// ±1 normalization, any number of classes ≥ 1. Used for multiclass
/// TEST files, whose arity must follow the training file rather than
/// be re-detected from whichever classes happen to appear.
pub fn read_multiclass(r: impl BufRead, dim: Option<usize>, repr: Repr) -> Result<MulticlassDataset> {
    let parsed = parse_stream(r, false, 0)?;
    multiclass_from_parsed(parsed, dim, repr)
}

/// [`read_multiclass`] from a file path.
pub fn read_multiclass_file(
    path: impl AsRef<Path>,
    dim: Option<usize>,
    repr: Repr,
) -> Result<MulticlassDataset> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("cannot open {}", path.as_ref().display()))?;
    let mut ds = read_multiclass(std::io::BufReader::new(f), dim, repr)?;
    if let Some(stem) = path.as_ref().file_stem().and_then(|s| s.to_str()) {
        ds.name = stem.to_string();
    }
    Ok(ds)
}

fn multiclass_from_parsed(
    parsed: Parsed,
    dim: Option<usize>,
    repr: Repr,
) -> Result<MulticlassDataset> {
    let dim = resolve_dim(&parsed, dim)?;
    let (x, labels) = build_points(parsed, dim, repr);
    let classes: Vec<i64> = labels.iter().map(|&l| l.round() as i64).collect();
    Ok(MulticlassDataset::new("libsvm", x, classes))
}

fn binary_from_parsed(parsed: Parsed, dim: Option<usize>, repr: Repr) -> Result<Dataset> {
    let dim = resolve_dim(&parsed, dim)?;

    // Map labels to ±1. Convention (applies to every two-label
    // encoding): {−1, +1} is preserved verbatim; otherwise the
    // numerically GREATER label maps to +1 and the smaller to −1, so
    // {0,1} → 0↦−1 1↦+1 and {1,2} → 1↦−1 2↦+1. (The {1,2} case used to
    // map the *lower* label to +1 while the generic fallback mapped the
    // *higher* one — the polarity now matches across all encodings.)
    let distinct: std::collections::BTreeSet<i64> =
        parsed.labels.iter().map(|&l| l.round() as i64).collect();
    // the identity branch requires the raw labels to be LITERALLY ±1:
    // classes are formed by rounding, so e.g. {−0.5, 0.5} also lands on
    // distinct == {−1, 1} but must go through the greater-maps-to-+1
    // rule (the identity map would hand Dataset::new non-±1 labels)
    let verbatim_pm1 = !parsed.labels.is_empty()
        && parsed.labels.iter().all(|&l| l == 1.0 || l == -1.0)
        && distinct.len() == 2;
    let to_pm1: Box<dyn Fn(f64) -> f64> = if distinct.is_empty() {
        Box::new(|l| l) // empty file: nothing to map
    } else if verbatim_pm1 {
        Box::new(|l| l)
    } else if distinct.len() == 1 {
        // single-class file: positive labels ↦ +1, non-positive ↦ −1 —
        // consistent with the two-label rule ({1} is the positive of
        // {0,1}, {2} of {1,2}) and keeps write→read round-trips of
        // one-class subsets label-preserving
        Box::new(|l| if l > 0.0 { 1.0 } else { -1.0 })
    } else if distinct.len() == 2 {
        let lo = *distinct.iter().next().expect("two labels");
        Box::new(move |l| if (l.round() as i64) > lo { 1.0 } else { -1.0 })
    } else {
        bail!("not a binary dataset: labels {distinct:?}");
    };

    // record the original encoding so models answer in it: for any
    // two-label file other than literal {−1,+1}, [smaller, greater] —
    // the same orientation as the ±1 mapping above. Use the first RAW
    // value of each rounded class, so non-integer encodings (e.g.
    // {0.5, 1.5}, {−0.5, 0.5}) round-trip verbatim instead of as their
    // rounded stand-ins.
    let label_pair = if distinct.len() == 2 && !verbatim_pm1 {
        let raw_of = |cls: i64| {
            parsed
                .labels
                .iter()
                .copied()
                .find(|l| l.round() as i64 == cls)
                .unwrap_or(cls as f64)
        };
        let mut it = distinct.iter();
        let (lo, hi) = (*it.next().expect("two labels"), *it.next().expect("two labels"));
        [raw_of(lo), raw_of(hi)]
    } else {
        crate::data::dataset::DEFAULT_LABEL_PAIR
    };

    let (x, labels) = build_points(parsed, dim, repr);
    let y: Vec<f64> = labels.iter().map(|&l| to_pm1(l)).collect();
    Ok(Dataset::new("libsvm", x, y).with_labels(label_pair))
}

/// Label-agnostic parse for the predict/serve paths: returns the feature
/// rows plus the **raw** labels (NaN for bare feature lines), with no
/// binary-label normalization and no "not a binary dataset" failure —
/// a serving batch mixing `±1`-labeled lines with unlabeled ones parses
/// cleanly. Index/value validation is identical to [`read`].
pub fn read_features(r: impl BufRead, dim: Option<usize>) -> Result<(Points, Vec<f64>)> {
    read_features_offset(r, dim, 0)
}

/// [`read_features`] with a line-number offset: every error reports
/// `line (k + line_offset)` for the k-th line of `r`. The serve paths
/// re-parse a single failing request line `n` with offset `n − 1`, so
/// the error carries the client-visible line number natively (no
/// post-hoc message rewriting).
pub fn read_features_offset(
    r: impl BufRead,
    dim: Option<usize>,
    line_offset: usize,
) -> Result<(Points, Vec<f64>)> {
    let parsed = parse_stream(r, true, line_offset)?;
    let dim = resolve_dim(&parsed, dim)?;
    Ok(build_points(parsed, dim, Repr::Auto))
}

/// [`read_features`] with an explicit representation request.
pub fn read_features_with(
    r: impl BufRead,
    dim: Option<usize>,
    repr: Repr,
) -> Result<(Points, Vec<f64>)> {
    let parsed = parse_stream(r, true, 0)?;
    let dim = resolve_dim(&parsed, dim)?;
    Ok(build_points(parsed, dim, repr))
}

/// [`read_features`] from a file path.
pub fn read_features_file(
    path: impl AsRef<Path>,
    dim: Option<usize>,
    repr: Repr,
) -> Result<(Points, Vec<f64>)> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("cannot open {}", path.as_ref().display()))?;
    read_features_with(std::io::BufReader::new(f), dim, repr)
}

/// Map raw evaluation labels (from [`read_features`]) onto {−1, +1, NaN}:
/// when exactly two label classes appear and neither is `0` (e.g.
/// `{1,2}`, even with unlabeled lines mixed in), the greater label maps
/// to +1 — the same polarity rule as [`read`] — and unlabeled lines stay
/// NaN. Otherwise `±1` labels are kept and everything else — explicit
/// `0` placeholders (the serving convention for "no label", even in an
/// otherwise `{0,+1}` file), extra classes in a mixed batch — becomes
/// NaN = unlabeled and is excluded from accuracy.
pub fn normalize_eval_labels(labels: &[f64]) -> Vec<f64> {
    let distinct: std::collections::BTreeSet<i64> = labels
        .iter()
        .filter(|l| l.is_finite())
        .map(|&l| l.round() as i64)
        .collect();
    let pm1: std::collections::BTreeSet<i64> = [-1, 1].into_iter().collect();
    if distinct.len() == 2 && distinct != pm1 && !distinct.contains(&0) {
        // two-class renormalization (greater ↦ +1); applies with or
        // without unlabeled lines — a {1,2}-coded file must not have its
        // '1' (negative) lines mistaken for literal +1 labels
        let lo = *distinct.iter().next().expect("two labels");
        return labels
            .iter()
            .map(|&l| {
                if l.is_finite() {
                    if (l.round() as i64) > lo {
                        1.0
                    } else {
                        -1.0
                    }
                } else {
                    f64::NAN
                }
            })
            .collect();
    }
    labels
        .iter()
        .map(|&l| if l == 1.0 || l == -1.0 { l } else { f64::NAN })
        .collect()
}

/// Read a dataset from a file path.
pub fn read_file(path: impl AsRef<Path>, dim: Option<usize>) -> Result<Dataset> {
    read_file_with(path, dim, Repr::Auto)
}

/// [`read_file`] with an explicit representation request.
pub fn read_file_with(path: impl AsRef<Path>, dim: Option<usize>, repr: Repr) -> Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("cannot open {}", path.as_ref().display()))?;
    let mut ds = read_with(std::io::BufReader::new(f), dim, repr)?;
    if let Some(stem) = path.as_ref().file_stem().and_then(|s| s.to_str()) {
        ds.name = stem.to_string();
    }
    Ok(ds)
}

/// Write a dataset in LIBSVM format (zeros skipped, works for both
/// representations).
pub fn write_file(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("cannot create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    for i in 0..ds.len() {
        write!(w, "{}", if ds.y[i] > 0.0 { "+1" } else { "-1" })?;
        match &ds.x {
            Points::Dense(m) => {
                for (j, &v) in m.row(i).iter().enumerate() {
                    if v != 0.0 {
                        write!(w, " {}:{}", j + 1, v)?;
                    }
                }
            }
            Points::Sparse(s) => {
                let (ci, vi) = s.row(i);
                for (&c, &v) in ci.iter().zip(vi.iter()) {
                    write!(w, " {}:{}", c + 1, v)?;
                }
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use std::io::Cursor;

    #[test]
    fn parses_basic_file() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n# comment\n\n+1 1:1.0\n";
        let ds = read(Cursor::new(text), None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.point(0), &[0.5, 0.0, 1.5]);
        assert_eq!(ds.point(1), &[0.0, 2.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn label_mappings() {
        // unified polarity: the greater label is always the positive class
        let ds = read(Cursor::new("0 1:1\n1 1:2\n"), None).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
        let ds2 = read(Cursor::new("1 1:1\n2 1:2\n"), None).unwrap();
        assert_eq!(ds2.y, vec![-1.0, 1.0]);
        let ds3 = read(Cursor::new("-1 1:1\n+1 1:2\n"), None).unwrap();
        assert_eq!(ds3.y, vec![-1.0, 1.0]);
        let ds4 = read(Cursor::new("7 1:1\n3 1:2\n"), None).unwrap();
        assert_eq!(ds4.y, vec![1.0, -1.0]);
    }

    #[test]
    fn single_class_files_keep_their_polarity() {
        let pos = read(Cursor::new("+1 1:1.0\n1 2:2.0\n"), None).unwrap();
        assert_eq!(pos.y, vec![1.0, 1.0]);
        let two = read(Cursor::new("2 1:1.0\n2 2:2.0\n"), None).unwrap();
        assert_eq!(two.y, vec![1.0, 1.0]);
        let neg = read(Cursor::new("-1 1:1.0\n"), None).unwrap();
        assert_eq!(neg.y, vec![-1.0]);
        let zero = read(Cursor::new("0 1:1.0\n"), None).unwrap();
        assert_eq!(zero.y, vec![-1.0]);
        // empty input parses to an empty dataset, not an error
        assert_eq!(read(Cursor::new("# nothing\n"), None).unwrap().len(), 0);
    }

    #[test]
    fn all_two_label_encodings_roundtrip() {
        // read → write → read must preserve the ±1 labels for every
        // supported input encoding (unique dir: concurrent `cargo test`
        // processes must not race on a shared temp path)
        let dir = std::env::temp_dir()
            .join(format!("hss_svm_test_libsvm_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for (name, text) in [
            ("zero_one", "0 1:1.0\n1 1:2.0\n1 2:0.5\n0 2:1.5\n"),
            ("one_two", "1 1:1.0\n2 1:2.0\n2 2:0.5\n1 2:1.5\n"),
            ("pm_one", "-1 1:1.0\n+1 1:2.0\n1 2:0.5\n-1 2:1.5\n"),
            ("arbitrary", "3 1:1.0\n7 1:2.0\n7 2:0.5\n3 2:1.5\n"),
        ] {
            let ds = read(Cursor::new(text), None).unwrap();
            // greater raw label ⇒ +1, in every encoding
            assert_eq!(ds.y, vec![-1.0, 1.0, 1.0, -1.0], "polarity for {name}");
            let path = dir.join(format!("{name}.libsvm"));
            write_file(&ds, &path).unwrap();
            let back = read_file(&path, Some(ds.dim())).unwrap();
            assert_eq!(back.y, ds.y, "labels changed across round-trip for {name}");
            for i in 0..ds.len() {
                assert_eq!(back.point(i), ds.point(i), "features changed for {name} row {i}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn forced_dim_and_errors() {
        let ds = read(Cursor::new("+1 2:1\n"), Some(5)).unwrap();
        assert_eq!(ds.dim(), 5);
        assert!(read(Cursor::new("+1 9:1\n"), Some(3)).is_err());
        assert!(read(Cursor::new("+1 0:1\n"), None).is_err());
        assert!(read(Cursor::new("x 1:1\n"), None).is_err());
        assert!(read(Cursor::new("1 1:1\n2 1:1\n3 1:1\n"), None).is_err()); // 3 classes
    }

    #[test]
    fn rejects_duplicate_and_descending_indices() {
        let dup = read(Cursor::new("+1 1:1 1:2\n"), None);
        let msg = format!("{:#}", dup.unwrap_err());
        assert!(msg.contains("line 1") && msg.contains("ascending"), "{msg}");
        let desc = read(Cursor::new("+1 1:1\n-1 5:1 3:2\n"), None);
        let msg = format!("{:#}", desc.unwrap_err());
        assert!(msg.contains("line 2") && msg.contains("ascending"), "{msg}");
        // ascending stays fine, and the check resets between rows
        assert!(read(Cursor::new("+1 5:1\n-1 1:1 2:1\n"), None).is_ok());
        // read_features applies the same contract
        assert!(read_features(Cursor::new("3:1 2:1\n"), None).is_err());
    }

    #[test]
    fn rejects_non_finite_values_and_labels() {
        for text in ["+1 1:nan\n", "+1 1:inf\n", "-1 2:-inf\n"] {
            let e = read(Cursor::new(text), None);
            let msg = format!("{:#}", e.unwrap_err());
            assert!(msg.contains("non-finite value"), "{msg}");
        }
        let e = read(Cursor::new("nan 1:1\n"), None);
        assert!(format!("{:#}", e.unwrap_err()).contains("non-finite label"));
    }

    #[test]
    fn read_features_accepts_mixed_and_bare_lines() {
        // the serve-path crash case: ±1 labels mixed with 0-labeled and
        // bare feature lines — strict read() sees ≥3 classes and bails,
        // read_features must parse all of it
        let text = "+1 1:0.5 3:1.5\n0 2:2.0\n-1 1:1.0\n2:0.25 3:0.5\n";
        assert!(read(Cursor::new(text), None).is_err());
        let (x, labels) = read_features(Cursor::new(text), None).unwrap();
        assert_eq!(x.rows(), 4);
        assert_eq!(x.cols(), 3);
        assert_eq!(labels[0], 1.0);
        assert_eq!(labels[1], 0.0);
        assert_eq!(labels[2], -1.0);
        assert!(labels[3].is_nan());
        assert_eq!(x.get(3, 1), 0.25);
        assert_eq!(x.get(3, 0), 0.0);
    }

    #[test]
    fn records_original_label_pair() {
        use crate::data::dataset::DEFAULT_LABEL_PAIR;
        let ds = read(Cursor::new("1 1:1\n2 1:2\n"), None).unwrap();
        assert_eq!(ds.labels, [1.0, 2.0]);
        let ds = read(Cursor::new("0 1:1\n1 1:2\n"), None).unwrap();
        assert_eq!(ds.labels, [0.0, 1.0]);
        // non-integer encodings keep their raw values (classes are
        // formed by rounding, but the recorded pair is verbatim)
        let ds = read(Cursor::new("1.5 1:1\n0.5 1:2\n"), None).unwrap();
        assert_eq!(ds.labels, [0.5, 1.5]);
        assert_eq!(ds.y, vec![1.0, -1.0]);
        // {−0.5, 0.5} rounds to {−1, 1} but is NOT the verbatim ±1
        // encoding: y still normalizes (no panic) and the raw pair is
        // recorded
        let ds = read(Cursor::new("-0.5 1:1\n0.5 1:2\n"), None).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
        assert_eq!(ds.labels, [-0.5, 0.5]);
        // ±1, single-class and empty files keep the default pair
        assert_eq!(read(Cursor::new("-1 1:1\n+1 1:2\n"), None).unwrap().labels, DEFAULT_LABEL_PAIR);
        assert_eq!(read(Cursor::new("2 1:1\n"), None).unwrap().labels, DEFAULT_LABEL_PAIR);
        assert_eq!(read(Cursor::new(""), None).unwrap().labels, DEFAULT_LABEL_PAIR);
    }

    #[test]
    fn line_offset_shifts_error_numbers() {
        // the serve per-line re-parse case: line 42 of the input stream,
        // parsed alone with offset 41, reports "line 42" natively
        let e = read_features_offset(Cursor::new("+1 3:1 2:1\n"), None, 41);
        let msg = format!("{:#}", e.unwrap_err());
        assert!(msg.contains("line 42"), "{msg}");
        let e = read_features_offset(Cursor::new("1:abc\n"), None, 41);
        assert!(format!("{:#}", e.unwrap_err()).contains("line 42"));
        // forced-dimension overflow also names its line
        let e = read_features_offset(Cursor::new("9:1.0\n"), Some(3), 41);
        let msg = format!("{:#}", e.unwrap_err());
        assert!(msg.contains("line 42") && msg.contains("exceeds"), "{msg}");
        // offset 0 keeps the historical numbering
        let e = read_features(Cursor::new("1:1\n0:1\n"), None);
        assert!(format!("{:#}", e.unwrap_err()).contains("line 2"));
    }

    #[test]
    fn eval_label_normalization() {
        // ±1 with unlabeled holes: kept as-is
        let n = normalize_eval_labels(&[1.0, -1.0, f64::NAN, 0.0]);
        assert_eq!(n[0], 1.0);
        assert_eq!(n[1], -1.0);
        assert!(n[2].is_nan() && n[3].is_nan());
        // 0 is always the "no label" placeholder, never a class — a
        // {0,+1} file scores only its +1 lines
        let n = normalize_eval_labels(&[0.0, 1.0, 0.0]);
        assert!(n[0].is_nan() && n[2].is_nan());
        assert_eq!(n[1], 1.0);
        // two-class {1,2}: normalized like read() — including when
        // unlabeled lines are mixed in ('1' is the NEGATIVE class here)
        assert_eq!(normalize_eval_labels(&[1.0, 2.0]), vec![-1.0, 1.0]);
        let n = normalize_eval_labels(&[1.0, f64::NAN, 2.0]);
        assert_eq!(n[0], -1.0);
        assert!(n[1].is_nan());
        assert_eq!(n[2], 1.0);
    }

    #[test]
    fn read_any_detects_label_arity() {
        // > 2 distinct labels → multiclass, classes sorted on query
        let text = "3 1:1.0\n1 2:2.0\n7 1:0.5 3:1.5\n1 3:1.0\n";
        let LibsvmData::Multi(ds) = read_any(Cursor::new(text), None, Repr::Auto).unwrap() else {
            panic!("4-line 3-class file must detect as multiclass");
        };
        assert_eq!(ds.classes(), vec![1, 3, 7]);
        assert_eq!(ds.labels, vec![3, 1, 7, 1]);
        assert_eq!(ds.dim(), 3);
        // ≤ 2 labels keeps the exact binary behavior (pair recorded)
        let LibsvmData::Binary(ds) =
            read_any(Cursor::new("1 1:1\n2 1:2\n"), None, Repr::Auto).unwrap()
        else {
            panic!("2-class file must stay binary");
        };
        assert_eq!(ds.y, vec![-1.0, 1.0]);
        assert_eq!(ds.labels, [1.0, 2.0]);
        // strict multiclass read keeps any arity, including 2 classes
        let m = read_multiclass(Cursor::new("1 1:1\n2 1:2\n"), None, Repr::Auto).unwrap();
        assert_eq!(m.labels, vec![1, 2]);
        // bare feature lines are rejected on the strict paths
        assert!(read_multiclass(Cursor::new("1:0.5\n"), None, Repr::Auto).is_err());
        assert!(read_any(Cursor::new("1:0.5\n"), None, Repr::Auto).is_err());
    }

    #[test]
    fn multiclass_respects_representation_request() {
        let text = "0 1:1 100:2\n1 50:1\n2 7:3\n";
        let LibsvmData::Multi(auto) = read_any(Cursor::new(text), None, Repr::Auto).unwrap()
        else {
            panic!("multiclass expected");
        };
        assert!(auto.is_sparse(), "wide sparse multiclass stays CSR under Auto");
        let dense = read_multiclass(Cursor::new(text), None, Repr::Dense).unwrap();
        assert!(!dense.is_sparse());
        assert_eq!(auto.x.to_dense(), dense.x.to_dense());
        assert_eq!(auto.labels, dense.labels);
    }

    #[test]
    fn auto_repr_picks_csr_for_wide_sparse_data() {
        // 3 rows over 100 features, 4 nnz → sparse under Auto
        let text = "+1 1:1 100:2\n-1 50:1\n+1 7:3\n";
        let ds = read(Cursor::new(text), None).unwrap();
        assert!(ds.is_sparse(), "{:?}", ds);
        assert_eq!(ds.x.get(0, 99), 2.0);
        // forcing dense gives identical entries
        let dd = read_with(Cursor::new(text), None, Repr::Dense).unwrap();
        assert!(!dd.is_sparse());
        assert_eq!(ds.x.to_dense(), dd.x.to_dense());
        // narrow data stays dense under Auto even when mostly zero
        let narrow = read(Cursor::new("+1 8:1\n-1 2:1\n"), None).unwrap();
        assert!(!narrow.is_sparse());
        // forcing sparse works on anything
        let fs = read_with(Cursor::new("+1 1:1\n-1 2:1\n"), None, Repr::Sparse).unwrap();
        assert!(fs.is_sparse());
    }

    #[test]
    fn sparse_roundtrip_through_file() {
        let dir = std::env::temp_dir()
            .join(format!("hss_svm_test_libsvm_sp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = "+1 1:0.5 64:1.25\n-1 33:2.0\n+1 2:1.0 63:3.5\n";
        let ds = read(Cursor::new(text), None).unwrap();
        assert!(ds.is_sparse());
        let path = dir.join("sp.libsvm");
        write_file(&ds, &path).unwrap();
        let back = read_file(&path, Some(ds.dim())).unwrap();
        assert_eq!(back.y, ds.y);
        assert_eq!(back.x.to_dense(), ds.x.to_dense());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_through_file() {
        let x = Mat::from_fn(3, 4, |i, j| if (i + j) % 2 == 0 { (i + j) as f64 * 0.25 } else { 0.0 });
        let ds = Dataset::new("rt", x, vec![1.0, -1.0, 1.0]);
        let dir = std::env::temp_dir().join("hss_svm_test_libsvm");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.libsvm");
        write_file(&ds, &path).unwrap();
        let back = read_file(&path, Some(4)).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.y, ds.y);
        for i in 0..3 {
            assert_eq!(back.point(i), ds.point(i));
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
