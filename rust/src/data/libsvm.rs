//! LIBSVM sparse text format reader/writer.
//!
//! Format: one point per line, `<label> <index>:<value> ...` with 1-based
//! ascending indices. All of the paper's datasets ship in this format, so
//! a user with the real a8a/w7a/... files can run the exact experiments;
//! our synthetic generators write the same format for parity.
//!
//! Label convention: `{−1, +1}` files are read verbatim; any other
//! two-label encoding maps the numerically greater label to `+1` and the
//! smaller to `−1` (`{0,1}`: 1 is positive; `{1,2}`: 2 is positive). A
//! single-class file maps positive labels to `+1` and non-positive ones
//! to `−1`. [`write_file`] always emits `{−1, +1}`, so write→read
//! round-trips preserve labels exactly.

use crate::data::dataset::Dataset;
use crate::linalg::Mat;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Parse LIBSVM text from a reader. `dim` forces the feature dimension
/// (use `None` to infer from the max index seen).
pub fn read(r: impl BufRead, dim: Option<usize>) -> Result<Dataset> {
    let mut labels: Vec<f64> = Vec::new();
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut max_idx = 0usize;

    for (lineno, line) in r.lines().enumerate() {
        let line = line.context("I/O error reading libsvm data")?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let lab_tok = parts.next().unwrap();
        let label: f64 = lab_tok
            .parse()
            .with_context(|| format!("line {}: bad label {lab_tok:?}", lineno + 1))?;
        // normalize common encodings: {0,1} → {-1,+1}, {1,2} → {-1,+1}
        let mut feats = Vec::new();
        for tok in parts {
            let (i_str, v_str) = tok
                .split_once(':')
                .with_context(|| format!("line {}: bad pair {tok:?}", lineno + 1))?;
            let idx: usize = i_str
                .parse()
                .with_context(|| format!("line {}: bad index {i_str:?}", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: libsvm indices are 1-based, got 0", lineno + 1);
            }
            let val: f64 = v_str
                .parse()
                .with_context(|| format!("line {}: bad value {v_str:?}", lineno + 1))?;
            max_idx = max_idx.max(idx);
            feats.push((idx - 1, val));
        }
        labels.push(label);
        rows.push(feats);
    }

    let dim = match dim {
        Some(d) => {
            if max_idx > d {
                bail!("feature index {max_idx} exceeds forced dimension {d}");
            }
            d
        }
        None => max_idx,
    };

    // Map labels to ±1. Convention (applies to every two-label
    // encoding): {−1, +1} is preserved verbatim; otherwise the
    // numerically GREATER label maps to +1 and the smaller to −1, so
    // {0,1} → 0↦−1 1↦+1 and {1,2} → 1↦−1 2↦+1. (The {1,2} case used to
    // map the *lower* label to +1 while the generic fallback mapped the
    // *higher* one — the polarity now matches across all encodings.)
    let distinct: std::collections::BTreeSet<i64> =
        labels.iter().map(|&l| l.round() as i64).collect();
    let to_pm1: Box<dyn Fn(f64) -> f64> = if distinct.is_empty() {
        Box::new(|l| l) // empty file: nothing to map
    } else if distinct == [(-1), 1].into_iter().collect() {
        Box::new(|l| l)
    } else if distinct.len() == 1 {
        // single-class file: positive labels ↦ +1, non-positive ↦ −1 —
        // consistent with the two-label rule ({1} is the positive of
        // {0,1}, {2} of {1,2}) and keeps write→read round-trips of
        // one-class subsets label-preserving
        Box::new(|l| if l > 0.0 { 1.0 } else { -1.0 })
    } else if distinct.len() == 2 {
        let lo = *distinct.iter().next().expect("two labels");
        Box::new(move |l| if (l.round() as i64) > lo { 1.0 } else { -1.0 })
    } else {
        bail!("not a binary dataset: labels {distinct:?}");
    };

    let mut x = Mat::zeros(rows.len(), dim);
    for (i, feats) in rows.iter().enumerate() {
        let row = x.row_mut(i);
        for &(j, v) in feats {
            row[j] = v;
        }
    }
    let y: Vec<f64> = labels.iter().map(|&l| to_pm1(l)).collect();
    Ok(Dataset::new("libsvm", x, y))
}

/// Read a dataset from a file path.
pub fn read_file(path: impl AsRef<Path>, dim: Option<usize>) -> Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("cannot open {}", path.as_ref().display()))?;
    let mut ds = read(std::io::BufReader::new(f), dim)?;
    if let Some(stem) = path.as_ref().file_stem().and_then(|s| s.to_str()) {
        ds.name = stem.to_string();
    }
    Ok(ds)
}

/// Write a dataset in LIBSVM format (zeros skipped).
pub fn write_file(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("cannot create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    for i in 0..ds.len() {
        write!(w, "{}", if ds.y[i] > 0.0 { "+1" } else { "-1" })?;
        for (j, &v) in ds.point(i).iter().enumerate() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, v)?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_basic_file() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n# comment\n\n+1 1:1.0\n";
        let ds = read(Cursor::new(text), None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.point(0), &[0.5, 0.0, 1.5]);
        assert_eq!(ds.point(1), &[0.0, 2.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn label_mappings() {
        // unified polarity: the greater label is always the positive class
        let ds = read(Cursor::new("0 1:1\n1 1:2\n"), None).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
        let ds2 = read(Cursor::new("1 1:1\n2 1:2\n"), None).unwrap();
        assert_eq!(ds2.y, vec![-1.0, 1.0]);
        let ds3 = read(Cursor::new("-1 1:1\n+1 1:2\n"), None).unwrap();
        assert_eq!(ds3.y, vec![-1.0, 1.0]);
        let ds4 = read(Cursor::new("7 1:1\n3 1:2\n"), None).unwrap();
        assert_eq!(ds4.y, vec![1.0, -1.0]);
    }

    #[test]
    fn single_class_files_keep_their_polarity() {
        let pos = read(Cursor::new("+1 1:1.0\n1 2:2.0\n"), None).unwrap();
        assert_eq!(pos.y, vec![1.0, 1.0]);
        let two = read(Cursor::new("2 1:1.0\n2 2:2.0\n"), None).unwrap();
        assert_eq!(two.y, vec![1.0, 1.0]);
        let neg = read(Cursor::new("-1 1:1.0\n"), None).unwrap();
        assert_eq!(neg.y, vec![-1.0]);
        let zero = read(Cursor::new("0 1:1.0\n"), None).unwrap();
        assert_eq!(zero.y, vec![-1.0]);
        // empty input parses to an empty dataset, not an error
        assert_eq!(read(Cursor::new("# nothing\n"), None).unwrap().len(), 0);
    }

    #[test]
    fn all_two_label_encodings_roundtrip() {
        // read → write → read must preserve the ±1 labels for every
        // supported input encoding (unique dir: concurrent `cargo test`
        // processes must not race on a shared temp path)
        let dir = std::env::temp_dir()
            .join(format!("hss_svm_test_libsvm_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for (name, text) in [
            ("zero_one", "0 1:1.0\n1 1:2.0\n1 2:0.5\n0 2:1.5\n"),
            ("one_two", "1 1:1.0\n2 1:2.0\n2 2:0.5\n1 2:1.5\n"),
            ("pm_one", "-1 1:1.0\n+1 1:2.0\n1 2:0.5\n-1 2:1.5\n"),
            ("arbitrary", "3 1:1.0\n7 1:2.0\n7 2:0.5\n3 2:1.5\n"),
        ] {
            let ds = read(Cursor::new(text), None).unwrap();
            // greater raw label ⇒ +1, in every encoding
            assert_eq!(ds.y, vec![-1.0, 1.0, 1.0, -1.0], "polarity for {name}");
            let path = dir.join(format!("{name}.libsvm"));
            write_file(&ds, &path).unwrap();
            let back = read_file(&path, Some(ds.dim())).unwrap();
            assert_eq!(back.y, ds.y, "labels changed across round-trip for {name}");
            for i in 0..ds.len() {
                assert_eq!(back.point(i), ds.point(i), "features changed for {name} row {i}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn forced_dim_and_errors() {
        let ds = read(Cursor::new("+1 2:1\n"), Some(5)).unwrap();
        assert_eq!(ds.dim(), 5);
        assert!(read(Cursor::new("+1 9:1\n"), Some(3)).is_err());
        assert!(read(Cursor::new("+1 0:1\n"), None).is_err());
        assert!(read(Cursor::new("x 1:1\n"), None).is_err());
        assert!(read(Cursor::new("1 1:1\n2 1:1\n3 1:1\n"), None).is_err()); // 3 classes
    }

    #[test]
    fn roundtrip_through_file() {
        let x = Mat::from_fn(3, 4, |i, j| if (i + j) % 2 == 0 { (i + j) as f64 * 0.25 } else { 0.0 });
        let ds = Dataset::new("rt", x, vec![1.0, -1.0, 1.0]);
        let dir = std::env::temp_dir().join("hss_svm_test_libsvm");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.libsvm");
        write_file(&ds, &path).unwrap();
        let back = read_file(&path, Some(4)).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.y, ds.y);
        for i in 0..3 {
            assert_eq!(back.point(i), ds.point(i));
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
