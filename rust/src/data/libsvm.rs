//! LIBSVM sparse text format reader/writer.
//!
//! Format: one point per line, `<label> <index>:<value> ...` with 1-based
//! ascending indices. All of the paper's datasets ship in this format, so
//! a user with the real a8a/w7a/... files can run the exact experiments;
//! our synthetic generators write the same format for parity.

use crate::data::dataset::Dataset;
use crate::linalg::Mat;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Parse LIBSVM text from a reader. `dim` forces the feature dimension
/// (use `None` to infer from the max index seen).
pub fn read(r: impl BufRead, dim: Option<usize>) -> Result<Dataset> {
    let mut labels: Vec<f64> = Vec::new();
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut max_idx = 0usize;

    for (lineno, line) in r.lines().enumerate() {
        let line = line.context("I/O error reading libsvm data")?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let lab_tok = parts.next().unwrap();
        let label: f64 = lab_tok
            .parse()
            .with_context(|| format!("line {}: bad label {lab_tok:?}", lineno + 1))?;
        // normalize common encodings: {0,1} → {-1,+1}, {1,2} → {-1,+1}
        let mut feats = Vec::new();
        for tok in parts {
            let (i_str, v_str) = tok
                .split_once(':')
                .with_context(|| format!("line {}: bad pair {tok:?}", lineno + 1))?;
            let idx: usize = i_str
                .parse()
                .with_context(|| format!("line {}: bad index {i_str:?}", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: libsvm indices are 1-based, got 0", lineno + 1);
            }
            let val: f64 = v_str
                .parse()
                .with_context(|| format!("line {}: bad value {v_str:?}", lineno + 1))?;
            max_idx = max_idx.max(idx);
            feats.push((idx - 1, val));
        }
        labels.push(label);
        rows.push(feats);
    }

    let dim = match dim {
        Some(d) => {
            if max_idx > d {
                bail!("feature index {max_idx} exceeds forced dimension {d}");
            }
            d
        }
        None => max_idx,
    };

    // map labels to ±1
    let distinct: std::collections::BTreeSet<i64> =
        labels.iter().map(|&l| l.round() as i64).collect();
    let to_pm1: Box<dyn Fn(f64) -> f64> = if distinct == [(-1), 1].into_iter().collect() {
        Box::new(|l| l)
    } else if distinct == [0, 1].into_iter().collect() {
        Box::new(|l| if l > 0.5 { 1.0 } else { -1.0 })
    } else if distinct == [1, 2].into_iter().collect() {
        Box::new(|l| if l < 1.5 { 1.0 } else { -1.0 })
    } else if distinct.len() <= 2 {
        let lo = *distinct.iter().next().unwrap() as f64;
        Box::new(move |l| if l > lo { 1.0 } else { -1.0 })
    } else {
        bail!("not a binary dataset: labels {distinct:?}");
    };

    let mut x = Mat::zeros(rows.len(), dim);
    for (i, feats) in rows.iter().enumerate() {
        let row = x.row_mut(i);
        for &(j, v) in feats {
            row[j] = v;
        }
    }
    let y: Vec<f64> = labels.iter().map(|&l| to_pm1(l)).collect();
    Ok(Dataset::new("libsvm", x, y))
}

/// Read a dataset from a file path.
pub fn read_file(path: impl AsRef<Path>, dim: Option<usize>) -> Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("cannot open {}", path.as_ref().display()))?;
    let mut ds = read(std::io::BufReader::new(f), dim)?;
    if let Some(stem) = path.as_ref().file_stem().and_then(|s| s.to_str()) {
        ds.name = stem.to_string();
    }
    Ok(ds)
}

/// Write a dataset in LIBSVM format (zeros skipped).
pub fn write_file(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("cannot create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    for i in 0..ds.len() {
        write!(w, "{}", if ds.y[i] > 0.0 { "+1" } else { "-1" })?;
        for (j, &v) in ds.point(i).iter().enumerate() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, v)?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_basic_file() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n# comment\n\n+1 1:1.0\n";
        let ds = read(Cursor::new(text), None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.point(0), &[0.5, 0.0, 1.5]);
        assert_eq!(ds.point(1), &[0.0, 2.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn label_mappings() {
        let ds = read(Cursor::new("0 1:1\n1 1:2\n"), None).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
        let ds2 = read(Cursor::new("1 1:1\n2 1:2\n"), None).unwrap();
        assert_eq!(ds2.y, vec![1.0, -1.0]); // 1 → +1, 2 → −1 (cod-rna style)
    }

    #[test]
    fn forced_dim_and_errors() {
        let ds = read(Cursor::new("+1 2:1\n"), Some(5)).unwrap();
        assert_eq!(ds.dim(), 5);
        assert!(read(Cursor::new("+1 9:1\n"), Some(3)).is_err());
        assert!(read(Cursor::new("+1 0:1\n"), None).is_err());
        assert!(read(Cursor::new("x 1:1\n"), None).is_err());
        assert!(read(Cursor::new("1 1:1\n2 1:1\n3 1:1\n"), None).is_err()); // 3 classes
    }

    #[test]
    fn roundtrip_through_file() {
        let x = Mat::from_fn(3, 4, |i, j| if (i + j) % 2 == 0 { (i + j) as f64 * 0.25 } else { 0.0 });
        let ds = Dataset::new("rt", x, vec![1.0, -1.0, 1.0]);
        let dir = std::env::temp_dir().join("hss_svm_test_libsvm");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.libsvm");
        write_file(&ds, &path).unwrap();
        let back = read_file(&path, Some(4)).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.y, ds.y);
        for i in 0..3 {
            assert_eq!(back.point(i), ds.point(i));
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
