//! Synthetic workload generators.
//!
//! The paper evaluates on ten LIBSVM datasets (Table 1). Those files are
//! not redistributable inside this offline environment, so each dataset is
//! **simulated**: a seeded class-conditional Gaussian-mixture generator
//! matched to Table 1 on feature count, train/test sizes and class
//! balance, with a per-dataset separation parameter calibrated so the
//! achievable accuracy lands near the paper's reported figures (99%+ for
//! skin-like, ~72% for susy-like, ...). Mixture data is exactly the
//! regime HSS-ANN exploits (clusterable geometry ⇒ low-rank off-diagonal
//! kernel blocks), which is the behaviour the substitution must preserve
//! — see DESIGN.md §4.
//!
//! Toy generators (moons / circles / checkerboard / blobs) back the unit
//! and integration tests: they have known difficulty and force a genuinely
//! nonlinear decision boundary.

use crate::data::dataset::Dataset;
use crate::linalg::Mat;
use crate::svm::MulticlassDataset;
use crate::util::prng::Rng;

/// Gaussian blobs: `clusters` centers in [-1,1]^dim, alternating labels.
pub fn blobs(n: usize, dim: usize, clusters: usize, std: f64, rng: &mut Rng) -> Dataset {
    assert!(clusters >= 2);
    let centers: Vec<Vec<f64>> = (0..clusters)
        .map(|_| (0..dim).map(|_| rng.range(-1.0, 1.0)).collect())
        .collect();
    let mut x = Mat::zeros(n, dim);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let c = rng.below(clusters);
        let row = x.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            *v = centers[c][j] + rng.gauss() * std;
        }
        y[i] = if c % 2 == 0 { 1.0 } else { -1.0 };
    }
    Dataset::new("blobs", x, y)
}

/// Four wide-margin Gaussian blobs in an XOR layout: class +1 at
/// (+2.5, +2.5) and (−2.5, −2.5) in the first two coordinates, class −1
/// at (+2.5, −2.5) and (−2.5, +2.5); remaining coordinates are pure
/// noise. Unlike [`blobs`] the centers are FIXED (not drawn from the
/// RNG), so separability does not depend on the seed: nearest
/// opposite-class centers sit 5.0 apart, which at `std ≲ 0.5` makes the
/// Bayes accuracy ≈ 1 while still forcing a genuinely nonlinear
/// boundary. The multilevel equal-accuracy bench and tests generate
/// here — they assert tight accuracy agreement between two training
/// paths, which is only meaningful on a stable plateau.
pub fn xor_blobs(n: usize, dim: usize, std: f64, rng: &mut Rng) -> Dataset {
    assert!(dim >= 2);
    let mut x = Mat::zeros(n, dim);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let q = rng.below(4);
        let (sx, sy) = match q {
            0 => (1.0, 1.0),
            1 => (-1.0, -1.0),
            2 => (1.0, -1.0),
            _ => (-1.0, 1.0),
        };
        let row = x.row_mut(i);
        for v in row.iter_mut() {
            *v = rng.gauss() * std;
        }
        row[0] += 2.5 * sx;
        row[1] += 2.5 * sy;
        y[i] = if q < 2 { 1.0 } else { -1.0 };
    }
    Dataset::new("xor_blobs", x, y)
}

/// Multiclass Gaussian blobs: `classes` well-separated centers (one per
/// class, labels `0..classes`), points assigned round-robin so every
/// class is populated. Centers sit on scaled coordinate axes (center c
/// at `4·(1 + c/dim)` along axis `c % dim`), which keeps them pairwise
/// separated for any `classes`/`dim` combination — the one-vs-one
/// tests and the `ovo_shared_sv_speedup` bench both generate here.
pub fn multiclass_blobs(
    n: usize,
    dim: usize,
    classes: usize,
    std: f64,
    rng: &mut Rng,
) -> MulticlassDataset {
    assert!(classes >= 2 && dim >= 1);
    let mut x = Mat::zeros(n, dim);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % classes;
        let axis = c % dim;
        let radius = 4.0 * (1.0 + (c / dim) as f64);
        let row = x.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            *v = if j == axis { radius } else { 0.0 } + rng.gauss() * std;
        }
        labels.push(c as i64);
    }
    MulticlassDataset::new("multiclass_blobs", x, labels)
}

/// The two-moons toy (2-D, intrinsically nonlinear boundary).
pub fn two_moons(n: usize, noise: f64, rng: &mut Rng) -> Dataset {
    let mut x = Mat::zeros(n, 2);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let upper = i % 2 == 0;
        let t = rng.f64() * std::f64::consts::PI;
        let (cx, cy, lab) = if upper {
            (t.cos(), t.sin(), 1.0)
        } else {
            (1.0 - t.cos(), 0.5 - t.sin(), -1.0)
        };
        x[(i, 0)] = cx + rng.gauss() * noise;
        x[(i, 1)] = cy + rng.gauss() * noise;
        y[i] = lab;
    }
    Dataset::new("moons", x, y)
}

/// Concentric circles (2-D): inner = +1, outer = −1.
pub fn circles(n: usize, noise: f64, rng: &mut Rng) -> Dataset {
    let mut x = Mat::zeros(n, 2);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let inner = i % 2 == 0;
        let r = if inner { 0.5 } else { 1.0 };
        let t = rng.f64() * 2.0 * std::f64::consts::PI;
        x[(i, 0)] = r * t.cos() + rng.gauss() * noise;
        x[(i, 1)] = r * t.sin() + rng.gauss() * noise;
        y[i] = if inner { 1.0 } else { -1.0 };
    }
    Dataset::new("circles", x, y)
}

/// 2-D checkerboard with `cells`×`cells` alternating squares on [0,1]².
pub fn checkerboard(n: usize, cells: usize, rng: &mut Rng) -> Dataset {
    let mut x = Mat::zeros(n, 2);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let a = rng.f64();
        let b = rng.f64();
        x[(i, 0)] = a;
        x[(i, 1)] = b;
        let ca = (a * cells as f64) as usize;
        let cb = (b * cells as f64) as usize;
        y[i] = if (ca + cb) % 2 == 0 { 1.0 } else { -1.0 };
    }
    Dataset::new("checkerboard", x, y)
}

/// Class-conditional Gaussian mixture with controlled separation.
///
/// `sep` ≳ 3 ⇒ nearly separable (99%+ achievable); `sep` ≲ 1 ⇒ heavy
/// overlap (susy-like ~72%). `label_noise` flips that fraction of labels.
pub struct GmmSpec {
    pub dim: usize,
    /// Dims that actually vary (the rest are exactly 0) — mimics the
    /// sparse high-dim LIBSVM sets (a8a has ~14 active features per row
    /// out of 122), keeping ‖x−y‖² at O(active) instead of O(dim) so the
    /// paper's h ∈ {0.1, 1, 10} grid stays meaningful.
    pub active_dims: usize,
    pub clusters_per_class: usize,
    pub sep: f64,
    pub cluster_std: f64,
    pub label_noise: f64,
}

impl GmmSpec {
    /// Draw `n` points with exactly `n_pos` positives.
    pub fn sample(&self, name: &str, n: usize, n_pos: usize, rng: &mut Rng) -> Dataset {
        assert!(n_pos <= n);
        let k = self.clusters_per_class.max(1);
        let active = self.active_dims.clamp(1, self.dim);
        // Centers: each cluster center i.i.d. N(0, sep² I) on the active
        // dims per class, with the two classes sharing the sampling
        // distribution — separation comes from `sep` vs `cluster_std`.
        let center = |rng: &mut Rng| -> Vec<f64> {
            (0..active).map(|_| rng.gauss() * self.sep).collect()
        };
        let pos_centers: Vec<Vec<f64>> = (0..k).map(|_| center(rng)).collect();
        let neg_centers: Vec<Vec<f64>> = (0..k).map(|_| center(rng)).collect();

        let mut x = Mat::zeros(n, self.dim);
        let mut y = vec![0.0; n];
        // interleave positives/negatives deterministically then shuffle rows
        let mut labels: Vec<bool> = (0..n).map(|i| i < n_pos).collect();
        rng.shuffle(&mut labels);
        for i in 0..n {
            let pos = labels[i];
            let centers = if pos { &pos_centers } else { &neg_centers };
            let c = &centers[rng.below(k)];
            let row = x.row_mut(i);
            for (j, v) in row.iter_mut().enumerate().take(active) {
                *v = c[j] + rng.gauss() * self.cluster_std;
            }
            let mut lab = if pos { 1.0 } else { -1.0 };
            if self.label_noise > 0.0 && rng.chance(self.label_noise) {
                lab = -lab;
            }
            y[i] = lab;
        }
        Dataset::new(name, x, y)
    }
}

/// One row of the paper's Table 1, plus simulation parameters.
#[derive(Clone, Copy)]
pub struct Table1Spec {
    pub name: &'static str,
    /// Feature count in the paper.
    pub features: usize,
    /// Feature count actually generated (dense simulator cap; only
    /// rcv1's 47k text features are capped — see DESIGN.md §4).
    pub gen_features: usize,
    pub train: usize,
    pub train_pos: usize,
    pub test: usize,
    pub test_pos: usize,
    /// Mixture separation (calibrated to the paper's accuracy regime).
    pub sep: f64,
    /// Label-flip noise.
    pub noise: f64,
    /// β chosen per the paper's rule (1e2 / 1e3 / 1e4 by train size).
    pub beta: f64,
}

/// The ten Table-1 datasets. `sep`/`noise` calibrated so the best
/// achievable accuracy is in the neighbourhood of the paper's Tables 2-5.
pub const TABLE1: &[Table1Spec] = &[
    Table1Spec { name: "a8a", features: 122, gen_features: 122, train: 22696, train_pos: 5506, test: 9865, test_pos: 2335, sep: 1.8, noise: 0.12, beta: 1e2 },
    Table1Spec { name: "w7a", features: 300, gen_features: 300, train: 24692, train_pos: 740, test: 25057, test_pos: 739, sep: 2.0, noise: 0.012, beta: 1e2 },
    Table1Spec { name: "rcv1.binary", features: 47236, gen_features: 512, train: 20242, train_pos: 10491, test: 135480, test_pos: 71326, sep: 1.3, noise: 0.05, beta: 1e2 },
    Table1Spec { name: "a9a", features: 122, gen_features: 122, train: 32561, train_pos: 7841, test: 16281, test_pos: 3846, sep: 1.8, noise: 0.12, beta: 1e2 },
    Table1Spec { name: "w8a", features: 300, gen_features: 300, train: 49749, train_pos: 1479, test: 14951, test_pos: 454, sep: 2.0, noise: 0.012, beta: 1e2 },
    Table1Spec { name: "ijcnn1", features: 22, gen_features: 22, train: 49990, train_pos: 4853, test: 91701, test_pos: 8712, sep: 1.2, noise: 0.05, beta: 1e2 },
    Table1Spec { name: "cod.rna", features: 8, gen_features: 8, train: 59535, train_pos: 19845, test: 271617, test_pos: 90539, sep: 1.1, noise: 0.08, beta: 1e2 },
    Table1Spec { name: "skin.nonskin", features: 3, gen_features: 3, train: 171540, train_pos: 135986, test: 73517, test_pos: 58212, sep: 6.0, noise: 0.001, beta: 1e3 },
    Table1Spec { name: "webspam.uni", features: 254, gen_features: 254, train: 245000, train_pos: 148717, test: 105000, test_pos: 63472, sep: 2.2, noise: 0.03, beta: 1e3 },
    Table1Spec { name: "susy", features: 18, gen_features: 18, train: 3500000, train_pos: 1601659, test: 1500000, test_pos: 686168, sep: 0.55, noise: 0.18, beta: 1e4 },
];

/// Look up a Table-1 spec by (case-insensitive) name.
pub fn table1_spec(name: &str) -> Option<&'static Table1Spec> {
    TABLE1.iter().find(|s| s.name.eq_ignore_ascii_case(name))
}

impl Table1Spec {
    /// β per the paper's staging rule, applied to the *scaled* train size.
    pub fn beta_for(train: usize) -> f64 {
        if train >= 1_000_000 {
            1e4
        } else if train >= 100_000 {
            1e3
        } else {
            1e2
        }
    }

    /// Generate the (train, test) pair at `scale` ∈ (0, 1] of the paper's
    /// sizes. Deterministic in (spec, scale, seed).
    pub fn generate(&self, scale: f64, seed: u64) -> (Dataset, Dataset) {
        assert!(scale > 0.0 && scale <= 1.0);
        let sc = |v: usize| ((v as f64 * scale).round() as usize).max(2);
        let train = sc(self.train);
        let test = sc(self.test);
        let train_pos = sc(self.train_pos).min(train - 1).max(1);
        let test_pos = sc(self.test_pos).min(test - 1).max(1);
        let mut rng = Rng::new(seed ^ fxhash(self.name));
        let spec = GmmSpec {
            dim: self.gen_features,
            active_dims: active_count(self.gen_features),
            clusters_per_class: cluster_count(self.gen_features),
            sep: self.sep,
            cluster_std: 1.0,
            label_noise: self.noise,
        };
        // Sample train and test from the SAME mixture: a single spec
        // instance reused so centers match.
        let all = spec.sample(self.name, train + test, train_pos + test_pos, &mut rng);
        // Re-assort so that train gets exactly train_pos positives.
        let (mut pos_idx, mut neg_idx): (Vec<usize>, Vec<usize>) =
            (0..all.len()).partition(|&i| all.y[i] > 0.0);
        // label noise can shift counts slightly; take what we have
        let tp = train_pos.min(pos_idx.len());
        let tn = (train - tp).min(neg_idx.len());
        let mut train_idx: Vec<usize> = pos_idx.drain(..tp).collect();
        train_idx.extend(neg_idx.drain(..tn));
        let mut test_idx: Vec<usize> = pos_idx;
        test_idx.extend(neg_idx);
        rng.shuffle(&mut train_idx);
        rng.shuffle(&mut test_idx);
        test_idx.truncate(test);
        (all.select(&train_idx), all.select(&test_idx))
    }
}

fn cluster_count(dim: usize) -> usize {
    (2 + dim / 16).min(12)
}

/// Effective (varying) dimension: full for low-dim sets, capped for the
/// sparse high-dim profiles (see GmmSpec::active_dims).
fn active_count(dim: usize) -> usize {
    dim.min(14 + dim / 20)
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toys_have_expected_shapes_and_balance() {
        let mut rng = Rng::new(1);
        let m = two_moons(200, 0.05, &mut rng);
        assert_eq!(m.len(), 200);
        assert_eq!(m.dim(), 2);
        assert_eq!(m.positives(), 100);

        let c = circles(100, 0.01, &mut rng);
        assert_eq!(c.positives(), 50);

        let b = blobs(300, 5, 4, 0.1, &mut rng);
        assert_eq!(b.dim(), 5);
        assert!(b.positives() > 75 && b.positives() < 225);

        let ch = checkerboard(400, 4, &mut rng);
        assert_eq!(ch.len(), 400);
        let pos = ch.positives();
        assert!(pos > 120 && pos < 280, "checkerboard balance {pos}");
    }

    #[test]
    fn gmm_exact_positive_count_without_noise() {
        let spec = GmmSpec { dim: 10, active_dims: 10, clusters_per_class: 3, sep: 2.0, cluster_std: 1.0, label_noise: 0.0 };
        let mut rng = Rng::new(2);
        let ds = spec.sample("g", 500, 123, &mut rng);
        assert_eq!(ds.positives(), 123);
        assert_eq!(ds.dim(), 10);
    }

    #[test]
    fn table1_covers_all_ten_datasets() {
        assert_eq!(TABLE1.len(), 10);
        assert!(table1_spec("ijcnn1").is_some());
        assert!(table1_spec("IJCNN1").is_some());
        assert!(table1_spec("nope").is_none());
        // spot-check the paper numbers
        let susy = table1_spec("susy").unwrap();
        assert_eq!(susy.train, 3_500_000);
        assert_eq!(susy.features, 18);
        let rcv = table1_spec("rcv1.binary").unwrap();
        assert_eq!(rcv.features, 47236);
        assert!(rcv.gen_features <= 512);
    }

    #[test]
    fn generate_scales_sizes_and_balance() {
        let spec = table1_spec("a8a").unwrap();
        let (tr, te) = spec.generate(0.01, 7);
        // 1% of 22696 ≈ 227
        assert!((tr.len() as i64 - 227).abs() <= 2, "train {}", tr.len());
        assert!((te.len() as i64 - 99).abs() <= 2, "test {}", te.len());
        assert_eq!(tr.dim(), 122);
        // ±1 labels, at least roughly the right balance (noise shifts some)
        let frac = tr.positives() as f64 / tr.len() as f64;
        assert!(frac > 0.1 && frac < 0.45, "positive fraction {frac}");
    }

    #[test]
    fn generate_is_deterministic() {
        let spec = table1_spec("ijcnn1").unwrap();
        let (a, _) = spec.generate(0.005, 42);
        let (b, _) = spec.generate(0.005, 42);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let (c, _) = spec.generate(0.005, 43);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn beta_staging_rule() {
        assert_eq!(Table1Spec::beta_for(50_000), 1e2);
        assert_eq!(Table1Spec::beta_for(200_000), 1e3);
        assert_eq!(Table1Spec::beta_for(2_000_000), 1e4);
    }
}
