//! Dataset substrate: dense/CSR representation, LIBSVM-format I/O,
//! synthetic Table-1-matched workload generators, and feature scaling.

// No raw-pointer tricks belong in this module tree (see DESIGN.md §11).
#![forbid(unsafe_code)]

pub mod dataset;
pub mod libsvm;
pub mod scale;
pub mod shard;
pub mod sparse;
pub mod synth;

pub use dataset::{Dataset, DEFAULT_LABEL_PAIR};
pub use shard::{ShardManifest, ShardSet};
pub use sparse::{CsrMat, Points};
