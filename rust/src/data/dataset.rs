//! Core dataset representation: feature rows + ±1 labels.
//!
//! The paper's datasets (Table 1) range from 3 to 47k features. Storage
//! is a [`Points`] container: a dense row-major [`Mat`] for the
//! synthetic/low-dimensional workloads, or a CSR [`crate::data::CsrMat`]
//! for the sparse LIBSVM benchmarks (rcv1.binary, webspam.uni, ...)
//! where densifying would cost rows × dim instead of nnz.

use crate::data::sparse::Points;

/// The implied original label pair when none was recorded: `y = −1`
/// came from a literal `−1`, `y = +1` from a literal `+1`.
pub const DEFAULT_LABEL_PAIR: [f64; 2] = [-1.0, 1.0];

/// A labelled binary-classification dataset.
#[derive(Clone)]
pub struct Dataset {
    /// One feature row per point (dense or CSR).
    pub x: Points,
    /// Labels in {-1, +1}, length = number of points.
    pub y: Vec<f64>,
    /// Human-readable name (dataset table key).
    pub name: String,
    /// Original label encoding `[negative, positive]` before the ±1
    /// normalization (e.g. `[1, 2]` for a {1,2}-coded LIBSVM file).
    /// Carried into trained models so predictions map back to the
    /// dataset's own labels; [`DEFAULT_LABEL_PAIR`] when the input was
    /// already ±1 (or synthetic).
    pub labels: [f64; 2],
}

impl Dataset {
    pub fn new(name: impl Into<String>, x: impl Into<Points>, y: Vec<f64>) -> Self {
        let x = x.into();
        assert_eq!(x.rows(), y.len(), "points/labels length mismatch");
        assert!(
            y.iter().all(|&v| v == 1.0 || v == -1.0),
            "labels must be in {{-1, +1}}"
        );
        Dataset { x, y, name: name.into(), labels: DEFAULT_LABEL_PAIR }
    }

    /// Record the original (pre-normalization) label pair.
    pub fn with_labels(mut self, labels: [f64; 2]) -> Self {
        self.labels = labels;
        self
    }

    /// Number of points.
    pub fn len(&self) -> usize {
        self.x.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// True when the features are CSR-stored.
    pub fn is_sparse(&self) -> bool {
        self.x.is_sparse()
    }

    /// Number of positive labels (the |Train₊| column of Table 1).
    pub fn positives(&self) -> usize {
        self.y.iter().filter(|&&v| v > 0.0).count()
    }

    /// Feature row of point i as a dense slice. Panics on sparse
    /// storage — sparse-aware consumers go through [`Points`] ops
    /// (`dot_row`, `dist2_rows`, `add_row_scaled`, ...).
    pub fn point(&self, i: usize) -> &[f64] {
        self.x.dense_row(i)
    }

    /// Subset by index list (in that order).
    pub fn select(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.select_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
            name: self.name.clone(),
            labels: self.labels,
        }
    }

    /// Apply a permutation: point `perm[i]` of `self` becomes point `i`.
    pub fn permute(&self, perm: &[usize]) -> Dataset {
        assert_eq!(perm.len(), self.len());
        self.select(perm)
    }

    /// Split into (train, test) at `train_len` (no shuffling — callers
    /// shuffle explicitly for determinism).
    pub fn split_at(&self, train_len: usize) -> (Dataset, Dataset) {
        assert!(train_len <= self.len());
        let train_idx: Vec<usize> = (0..train_len).collect();
        let test_idx: Vec<usize> = (train_len..self.len()).collect();
        (self.select(&train_idx), self.select(&test_idx))
    }
}

impl std::fmt::Debug for Dataset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Dataset({}: {} pts × {} feats, {} positive{})",
            self.name,
            self.len(),
            self.dim(),
            self.positives(),
            if self.is_sparse() {
                format!(", sparse {} nnz", self.x.nnz())
            } else {
                String::new()
            }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::CsrMat;
    use crate::linalg::Mat;

    fn tiny() -> Dataset {
        let x = Mat::from_fn(4, 2, |i, j| (i * 2 + j) as f64);
        Dataset::new("tiny", x, vec![1.0, -1.0, 1.0, -1.0])
    }

    #[test]
    fn accessors() {
        let d = tiny();
        assert_eq!(d.len(), 4);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.positives(), 2);
        assert_eq!(d.point(2), &[4.0, 5.0]);
        assert!(!d.is_sparse());
    }

    #[test]
    fn select_and_permute() {
        let d = tiny();
        let s = d.select(&[3, 1]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.y, vec![-1.0, -1.0]);
        assert_eq!(s.point(0), &[6.0, 7.0]);

        let p = d.permute(&[1, 0, 3, 2]);
        assert_eq!(p.point(0), &[2.0, 3.0]);
        assert_eq!(p.y[0], -1.0);
    }

    #[test]
    fn split() {
        let d = tiny();
        let (tr, te) = d.split_at(3);
        assert_eq!(tr.len(), 3);
        assert_eq!(te.len(), 1);
        assert_eq!(te.point(0), &[6.0, 7.0]);
    }

    #[test]
    fn sparse_datasets_select_and_split() {
        let x = CsrMat::from_rows(3, &[vec![(0, 1.0)], vec![], vec![(2, 5.0)], vec![(1, -1.0)]]);
        let d = Dataset::new("sp", x, vec![1.0, -1.0, 1.0, -1.0]);
        assert!(d.is_sparse());
        assert_eq!(d.dim(), 3);
        let s = d.select(&[2, 0]);
        assert!(s.is_sparse());
        assert_eq!(s.x.get(0, 2), 5.0);
        assert_eq!(s.y, vec![1.0, 1.0]);
        let (tr, te) = d.split_at(1);
        assert_eq!(tr.len(), 1);
        assert_eq!(te.len(), 3);
    }

    #[test]
    #[should_panic(expected = "labels must be")]
    fn rejects_bad_labels() {
        Dataset::new("bad", Mat::zeros(1, 1), vec![0.5]);
    }

    #[test]
    fn label_pair_defaults_and_propagates() {
        let d = tiny();
        assert_eq!(d.labels, DEFAULT_LABEL_PAIR);
        let d = d.with_labels([1.0, 2.0]);
        assert_eq!(d.select(&[0, 2]).labels, [1.0, 2.0]);
        let (tr, te) = d.split_at(2);
        assert_eq!(tr.labels, [1.0, 2.0]);
        assert_eq!(te.labels, [1.0, 2.0]);
    }
}
