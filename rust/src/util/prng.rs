//! Deterministic pseudo-random number generation.
//!
//! The environment is fully offline (no `rand` crate), and reproducibility
//! of every synthetic workload / randomized sketch matters for the paper
//! reproduction, so we implement SplitMix64 (seeding) + xoshiro256**
//! (stream) from the reference constants. Both are tiny, fast and pass
//! BigCrush-level batteries, which is more than sufficient for Gaussian
//! sketching and workload generation.

/// SplitMix64 step — used to expand a single `u64` seed into the
/// xoshiro256** state, and as a standalone cheap generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator. Deterministic, seedable, `Clone` for replay.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from the Box–Muller pair.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Construct from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits → exactly representable uniform dyadic in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean / std.
    #[inline]
    pub fn gauss_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (Floyd's algorithm for
    /// small k/n ratios, full shuffle otherwise).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        if k * 3 > n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below(j + 1);
                let v = if chosen.contains(&t) { j } else { t };
                chosen.insert(v);
                out.push(v);
            }
            out
        }
    }

    /// Derive an independent child stream (for per-thread determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut seed = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let mut sm = splitmix64(&mut seed);
        Rng::new(splitmix64(&mut sm))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        let mut buckets = [0usize; 10];
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            buckets[(x * 10.0) as usize] += 1;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
        for b in buckets {
            assert!((b as f64 - n as f64 / 10.0).abs() < n as f64 * 0.01);
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 17];
        for _ in 0..2000 {
            let v = r.below(17);
            assert!(v < 17);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gauss();
            m1 += g;
            m2 += g * g;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.01, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var {m2}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(100usize, 10usize), (50, 50), (1000, 3), (10, 0)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k.min(n));
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), s.len());
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..256).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..256).collect::<Vec<_>>());
        assert_ne!(v, (0..256).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1234);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }
}
