//! Shared utilities: deterministic PRNG, timers, scoped parallelism, the
//! property-test harness, and human-readable size formatting.

pub mod bench;
pub mod prng;
pub mod testkit;
pub mod threadpool;
pub mod timer;

/// Format a byte count as MB with 3 decimals (paper tables report MB).
pub fn fmt_mb(bytes: usize) -> String {
    format!("{:.3}", bytes as f64 / 1e6)
}

/// Bytes of an `f64` buffer with `n` entries.
pub const fn f64_bytes(n: usize) -> usize {
    n * std::mem::size_of::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mb_formatting() {
        assert_eq!(fmt_mb(1_500_000), "1.500");
        assert_eq!(f64_bytes(10), 80);
    }
}
