//! Minimal property-based testing harness (offline substitute for
//! `proptest`). Runs a property over many seeded random cases and, on
//! failure, reports the seed so the case replays deterministically.

use crate::util::prng::Rng;

/// Run `prop(rng, case_index)` for `cases` seeded cases. Panics with the
/// replay seed on the first failing case (a property fails by panicking).
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Rng, usize)) {
    let base = env_seed().unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case);
        }));
        if let Err(e) = result {
            let msg = panic_msg(&e);
            panic!(
                "property `{name}` failed on case {case}/{cases} \
                 (replay: HSS_SVM_TEST_SEED={base}): {msg}"
            );
        }
    }
}

fn env_seed() -> Option<u64> {
    std::env::var("HSS_SVM_TEST_SEED").ok()?.parse().ok()
}

/// Random CSR matrix at the given density — the shared generator for
/// sparse-vs-dense property tests. Guarantees at least one empty row
/// and one all-zero column (when the shape allows it), so the
/// degenerate cases are always exercised.
pub fn random_csr(rows: usize, cols: usize, density: f64, rng: &mut Rng) -> crate::data::CsrMat {
    let dead_row = if rows > 0 { rng.below(rows) } else { 0 };
    let dead_col = if cols > 0 { rng.below(cols) } else { 0 };
    let rs: Vec<Vec<(usize, f64)>> = (0..rows)
        .map(|i| {
            if i == dead_row {
                return Vec::new();
            }
            (0..cols)
                .filter(|&c| c != dead_col && rng.f64() < density)
                .map(|c| (c, rng.gauss()))
                .collect()
        })
        .collect();
    crate::data::CsrMat::from_rows(cols, &rs)
}

fn panic_msg(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".into()
    }
}

/// Assert two floats are close in the `max(abs, rel)` sense.
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64) {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    assert!(
        (a - b).abs() <= tol * scale,
        "assert_close failed: {a} vs {b} (tol {tol}, |diff| {})",
        (a - b).abs()
    );
}

/// Assert two slices are element-wise close.
#[track_caller]
pub fn assert_allclose(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0f64.max(x.abs()).max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "assert_allclose failed at index {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("unit-interval", 50, |rng, _| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn check_reports_failures() {
        check("always-fails", 5, |_, _| panic!("boom"));
    }

    #[test]
    fn close_helpers() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9);
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9);
    }

    #[test]
    #[should_panic]
    fn close_rejects_far() {
        assert_close(1.0, 2.0, 1e-6);
    }
}
