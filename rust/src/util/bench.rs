//! Micro/macro benchmark harness (offline substitute for criterion)
//! plus a lock-free latency [`Histogram`] shared by the serving stats
//! (`server::stats`, the `STATS` admin command) and `bench_serve`.
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! adaptive iteration count targeting a wall-time budget, then report
//! median / p10 / p90 per-iteration times.

use crate::util::timer::Timer;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// One measured benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}   x{}",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.p10),
            fmt_dur(self.p90),
            self.iters
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} us", s * 1e6)
    }
}

/// Quarter-octave histogram buckets: enough range for 1 µs .. ~2 h.
const HIST_BUCKETS: usize = 256;

/// Concurrent latency histogram: quarter-octave (≈ +19% wide)
/// log-spaced buckets over microseconds, one atomic add per `record`,
/// no locks on the hot path. Percentiles resolve to the geometric
/// midpoint of the containing bucket — well within the fidelity needed
/// for p50/p99 serving latency and throughput reports.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: f64) -> usize {
        if us <= 1.0 {
            return 0;
        }
        ((us.log2() * 4.0) as usize).min(HIST_BUCKETS - 1)
    }

    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us as u64, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Total of all recorded samples in microseconds.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Cumulative buckets for Prometheus exposition: `(upper_bound_us,
    /// cumulative_count)` per bucket, trimmed after the last non-empty
    /// bucket (the `+Inf` bucket is the caller's `count()`). Bucket `i`
    /// spans `(2^(i/4), 2^((i+1)/4)]` µs, so the upper bound is
    /// `2^((i+1)/4)`; empty histograms yield an empty vec.
    ///
    /// Reads race concurrent `record` calls benignly: each bucket is
    /// loaded once, so a sample landing mid-scan appears in at most one
    /// bucket and the cumulative counts stay monotone.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let Some(last) = counts.iter().rposition(|&c| c > 0) else {
            return Vec::new();
        };
        let mut cum = 0u64;
        counts[..=last]
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                cum += c;
                (((i as f64 + 1.0) / 4.0).exp2(), cum)
            })
            .collect()
    }

    /// `q`-quantile (`0 < q ≤ 1`) in microseconds, resolved to the
    /// geometric midpoint of the containing bucket; 0 when empty.
    pub fn percentile_us(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                if i == 0 {
                    return 1.0;
                }
                return ((i as f64 + 0.5) / 4.0).exp2();
            }
        }
        ((HIST_BUCKETS as f64 - 0.5) / 4.0).exp2()
    }
}

/// Peak resident-set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`), `None` off Linux. This is the high-water mark
/// since process start — measure the phase under test FIRST, before
/// anything else inflates it. The `oos-smoke` CI lane uses it to prove
/// the out-of-core trainer never goes near the dense-kernel footprint.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Commit SHA the benchmark binary was built from: `GITHUB_SHA` in CI,
/// `git rev-parse HEAD` locally, `"unknown"` when neither resolves.
pub fn git_sha() -> String {
    if let Ok(s) = std::env::var("GITHUB_SHA") {
        if !s.is_empty() {
            return s;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Comma-joined compile-time feature set (`"default"` when none).
pub fn feature_set() -> String {
    let mut f = Vec::new();
    if cfg!(feature = "pjrt") {
        f.push("pjrt");
    }
    if cfg!(feature = "xla-client") {
        f.push("xla-client");
    }
    if f.is_empty() {
        "default".to_string()
    } else {
        f.join(",")
    }
}

/// Provenance fields every BENCH_*.json artifact carries, as pre-quoted
/// JSON member lines (no surrounding braces): the commit, the machine's
/// default thread count and the feature set — enough to tell two
/// artifacts apart without the workflow-run context.
pub fn provenance_json(indent: &str) -> String {
    format!(
        "{indent}\"git_sha\": \"{}\",\n{indent}\"features\": \"{}\",\n\
         {indent}\"default_threads\": {},\n",
        git_sha(),
        feature_set(),
        crate::util::threadpool::default_threads()
    )
}

/// Benchmark runner with a per-case time budget.
pub struct Bench {
    budget: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new(Duration::from_secs(2))
    }
}

impl Bench {
    pub fn new(budget: Duration) -> Self {
        println!(
            "{:<44} {:>12} {:>12} {:>12}   iters",
            "benchmark", "median", "p10", "p90"
        );
        println!("{}", "-".repeat(92));
        Bench { budget, results: Vec::new() }
    }

    /// Measure `f` (called once per iteration). A warmup call estimates
    /// the single-shot cost; heavy cases run at least 3 iterations.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        // warmup + cost estimate
        let t = Timer::start();
        f();
        let once = t.elapsed();
        let iters = (self.budget.as_secs_f64() / once.as_secs_f64().max(1e-9)) as usize;
        let iters = iters.clamp(3, 10_000);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Timer::start();
            f();
            samples.push(t.elapsed());
        }
        samples.sort();
        let result = BenchResult {
            name: name.to_string(),
            iters,
            median: samples[samples.len() / 2],
            p10: samples[samples.len() / 10],
            p90: samples[samples.len() * 9 / 10],
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Record an externally measured one-shot duration (for multi-minute
    /// macro benchmarks where repetition is pointless).
    pub fn record_once(&mut self, name: &str, d: Duration) {
        let result = BenchResult { name: name.to_string(), iters: 1, median: d, p10: d, p90: d };
        println!("{}", result.line());
        self.results.push(result);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let mut b = Bench::new(Duration::from_millis(50));
        let r = b.run("noop-ish", || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(r.iters >= 3);
        assert!(r.p10 <= r.median && r.median <= r.p90);
        b.record_once("macro", Duration::from_secs(1));
        assert_eq!(b.results().len(), 2);
    }

    #[test]
    fn histogram_percentiles_are_log_accurate() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_us(0.5), 0.0);
        // 99 samples at ~1 ms, 1 at ~100 ms: p50 near 1e3 µs, p99+ near 1e5
        for _ in 0..99 {
            h.record(Duration::from_micros(1000));
        }
        h.record(Duration::from_micros(100_000));
        assert_eq!(h.count(), 100);
        let p50 = h.percentile_us(0.5);
        assert!((800.0..1300.0).contains(&p50), "{p50}");
        let p999 = h.percentile_us(0.999);
        assert!((80_000.0..130_000.0).contains(&p999), "{p999}");
        assert!(h.mean_us() > 1000.0 && h.mean_us() < 3000.0, "{}", h.mean_us());
        // concurrent recording is just atomic adds
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        h.record(Duration::from_micros(10));
                    }
                });
            }
        });
        assert_eq!(h.count(), 4100);
    }

    #[test]
    fn cumulative_buckets_are_monotone_and_account_for_every_sample() {
        let h = Histogram::new();
        assert!(h.cumulative_buckets().is_empty(), "empty histogram, no buckets");
        for us in [1u64, 50, 50, 1000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        let cum = h.cumulative_buckets();
        assert!(!cum.is_empty());
        assert_eq!(cum.last().unwrap().1, h.count(), "trimmed tail covers all samples");
        for w in cum.windows(2) {
            assert!(w[0].0 < w[1].0, "bucket bounds strictly increase");
            assert!(w[0].1 <= w[1].1, "cumulative counts never decrease");
        }
        // every sample sits in a bucket whose bound is >= the sample
        let at_least_1ms = cum.iter().find(|(ub, _)| *ub >= 1000.0).unwrap();
        assert!(at_least_1ms.1 >= 4, "the four <=1ms samples are under the 1ms bound");
        // the µs sum is truncated per sample; allow 1 µs of slack each
        let sum = h.sum_us() as i64;
        assert!((sum - 101_101).abs() <= 5, "sum_us {sum}");
    }

    #[test]
    fn provenance_and_rss_are_well_formed() {
        let p = provenance_json("  ");
        assert!(p.contains("\"git_sha\": \""));
        assert!(p.contains("\"features\": \""));
        assert!(p.contains("\"default_threads\": "));
        #[cfg(target_os = "linux")]
        {
            let rss = peak_rss_bytes().expect("VmHWM is present on Linux");
            assert!(rss > 1 << 20, "peak RSS {rss} implausibly small");
        }
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_secs(2)), "2.000 s");
        assert_eq!(fmt_dur(Duration::from_millis(5)), "5.000 ms");
        assert_eq!(fmt_dur(Duration::from_micros(7)), "7.000 us");
    }
}
