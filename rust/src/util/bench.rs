//! Micro/macro benchmark harness (offline substitute for criterion).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! adaptive iteration count targeting a wall-time budget, then report
//! median / p10 / p90 per-iteration times.

use crate::util::timer::Timer;
use std::time::Duration;

/// One measured benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}   x{}",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.p10),
            fmt_dur(self.p90),
            self.iters
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} us", s * 1e6)
    }
}

/// Benchmark runner with a per-case time budget.
pub struct Bench {
    budget: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new(Duration::from_secs(2))
    }
}

impl Bench {
    pub fn new(budget: Duration) -> Self {
        println!(
            "{:<44} {:>12} {:>12} {:>12}   iters",
            "benchmark", "median", "p10", "p90"
        );
        println!("{}", "-".repeat(92));
        Bench { budget, results: Vec::new() }
    }

    /// Measure `f` (called once per iteration). A warmup call estimates
    /// the single-shot cost; heavy cases run at least 3 iterations.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        // warmup + cost estimate
        let t = Timer::start();
        f();
        let once = t.elapsed();
        let iters = (self.budget.as_secs_f64() / once.as_secs_f64().max(1e-9)) as usize;
        let iters = iters.clamp(3, 10_000);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Timer::start();
            f();
            samples.push(t.elapsed());
        }
        samples.sort();
        let result = BenchResult {
            name: name.to_string(),
            iters,
            median: samples[samples.len() / 2],
            p10: samples[samples.len() / 10],
            p90: samples[samples.len() * 9 / 10],
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Record an externally measured one-shot duration (for multi-minute
    /// macro benchmarks where repetition is pointless).
    pub fn record_once(&mut self, name: &str, d: Duration) {
        let result = BenchResult { name: name.to_string(), iters: 1, median: d, p10: d, p90: d };
        println!("{}", result.line());
        self.results.push(result);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let mut b = Bench::new(Duration::from_millis(50));
        let r = b.run("noop-ish", || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(r.iters >= 3);
        assert!(r.p10 <= r.median && r.median <= r.p90);
        b.record_once("macro", Duration::from_secs(1));
        assert_eq!(b.results().len(), 2);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_secs(2)), "2.000 s");
        assert_eq!(fmt_dur(Duration::from_millis(5)), "5.000 ms");
        assert_eq!(fmt_dur(Duration::from_micros(7)), "7.000 us");
    }
}
