//! A small scoped-parallelism layer over `std::thread`.
//!
//! No tokio/rayon in the offline crate set, and the workloads here are
//! CPU-bound data parallel loops (kernel block evaluation, per-node HSS
//! compression, per-dataset experiments), so `std::thread::scope` with a
//! shared atomic work counter covers everything we need while staying
//! deterministic when `threads == 1`.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default: `HSS_SVM_THREADS` env var,
/// else available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("HSS_SVM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(i)` for every `i in 0..n` across `threads` workers using atomic
/// chunk self-scheduling. `f` must be `Sync` (called concurrently).
pub fn parallel_for(threads: usize, n: usize, chunk: usize, f: impl Fn(usize) + Sync) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= chunk {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = chunk.max(1);
    let counter = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let start = counter.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
pub fn parallel_map<T: Send>(threads: usize, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = as_send_cells(&mut out);
        parallel_for(threads, n, 1, |i| {
            // SAFETY: each index is written by exactly one task.
            unsafe { *slots.get(i) = Some(f(i)) };
        });
    }
    out.into_iter().map(|o| o.expect("slot filled")).collect()
}

/// Helper: expose disjoint-index mutable access to a slice across threads.
pub struct SendCells<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut T>,
}

unsafe impl<T: Send> Sync for SendCells<'_, T> {}
unsafe impl<T: Send> Send for SendCells<'_, T> {}

impl<'a, T> SendCells<'a, T> {
    /// # Safety contract (enforced by callers)
    /// Concurrent callers must access disjoint indices.
    pub fn get(&self, i: usize) -> *mut T {
        assert!(i < self.len);
        unsafe { self.ptr.add(i) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Wrap a mutable slice for disjoint-index parallel writes.
pub fn as_send_cells<T>(xs: &mut [T]) -> SendCells<'_, T> {
    SendCells { ptr: xs.as_mut_ptr(), len: xs.len(), _marker: std::marker::PhantomData }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(4, n, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_single_thread_sequential() {
        let n = 100;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1, n, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(4, 1000, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<usize> = parallel_map(4, 0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn default_threads_at_least_one() {
        assert!(default_threads() >= 1);
    }
}
