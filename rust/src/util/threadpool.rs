//! A small scoped-parallelism layer over `std::thread`.
//!
//! No tokio/rayon in the offline crate set, and the workloads here are
//! CPU-bound data parallel loops (kernel block evaluation, per-node HSS
//! compression, per-dataset experiments), so `std::thread::scope` with a
//! shared atomic work counter covers everything we need while staying
//! deterministic when `threads == 1`.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};

/// Number of worker threads to use by default: `HSS_SVM_THREADS` env var,
/// else available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("HSS_SVM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(i)` for every `i in 0..n` across `threads` workers using atomic
/// chunk self-scheduling. `f` must be `Sync` (called concurrently).
pub fn parallel_for(threads: usize, n: usize, chunk: usize, f: impl Fn(usize) + Sync) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= chunk {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = chunk.max(1);
    let counter = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                // ORDERING: Relaxed — pure index-claiming counter; it only
                // partitions 0..n among workers. Data written by the tasks
                // is published by the scope join, not by this counter.
                let start = counter.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
/// `chunk` is the self-scheduling granularity: 1 for coarse per-item work
/// (tree nodes, row tiles), larger for cheap per-item work so each atomic
/// fetch amortizes over many items.
pub fn parallel_map<T: Send>(
    threads: usize,
    n: usize,
    chunk: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = as_send_cells(&mut out);
        parallel_for(threads, n, chunk, |i| {
            // SAFETY: each index is written by exactly one task.
            unsafe { *slots.get(i) = Some(f(i)) };
        });
    }
    out.into_iter().map(|o| o.expect("slot filled")).collect()
}

/// Level-scheduled tree traversal: the levels of `levels` run strictly in
/// order with a barrier between consecutive levels, and the nodes of one
/// level are self-scheduled across a worker pool spawned ONCE for the
/// whole traversal (a per-level spawn would pay thread startup at every
/// level of every sweep). `f(id)` runs exactly once per id; it may read
/// state produced by earlier levels (the barrier publishes it) and must
/// confine writes to state owned by `id` — use [`disjoint`] for the
/// scatter. With `threads <= 1` this degrades to plain nested loops, and
/// because per-node work is identical either way, results are bit-for-bit
/// independent of the thread count.
pub fn run_levels(threads: usize, levels: &[&[usize]], f: impl Fn(usize) + Sync) {
    let widest = levels.iter().map(|l| l.len()).max().unwrap_or(0);
    let threads = threads.max(1).min(widest.max(1));
    if threads <= 1 {
        for level in levels {
            for &id in *level {
                f(id);
            }
        }
        return;
    }
    let counters: Vec<AtomicUsize> = levels.iter().map(|_| AtomicUsize::new(0)).collect();
    let barrier = Barrier::new(threads);
    // A panicking task must not strand its siblings at the barrier:
    // capture the payload, drain the remaining levels (every worker hits
    // every barrier exactly once), then re-throw after the join.
    let abort = AtomicBool::new(false);
    let payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                for (li, level) in levels.iter().enumerate() {
                    // Acquire pairs with the Release store below: a worker
                    // that observes the abort flag must also observe the
                    // captured panic payload (it is re-thrown after join).
                    while !abort.load(Ordering::Acquire) {
                        // ORDERING: Relaxed — pure index-claiming counter
                        // partitioning this level's nodes among workers;
                        // cross-level data is published by the barrier.
                        let t = counters[li].fetch_add(1, Ordering::Relaxed);
                        if t >= level.len() {
                            break;
                        }
                        let id = level[t];
                        if let Err(p) =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(id)))
                        {
                            *payload.lock().unwrap() = Some(p);
                            abort.store(true, Ordering::Release);
                        }
                    }
                    // barrier publishes this level's writes to the next
                    barrier.wait();
                }
            });
        }
    });
    if let Some(p) = payload.into_inner().unwrap() {
        std::panic::resume_unwind(p);
    }
}

/// Helper: expose disjoint-index mutable access to a slice across threads.
///
/// The buffer is held as `&[UnsafeCell<T>]` rather than a raw base pointer
/// so every write keeps aliasing-model provenance routed through
/// `UnsafeCell` (shared-read-write under Stacked/Tree Borrows — the form
/// Miri accepts for cross-thread scatter into one allocation). In debug
/// builds, [`SendCells::slice`] additionally records every claimed range
/// in a ledger and panics on overlap; ranges are never released, so each
/// range must be claimed at most once per `SendCells` lifetime (all tree
/// sweeps rebuild the wrapper per pass, so this holds by construction).
pub struct SendCells<'a, T> {
    cells: &'a [UnsafeCell<T>],
    #[cfg(debug_assertions)]
    claims: Mutex<Vec<(usize, usize)>>,
}

// SAFETY: SendCells only hands out raw pointers / `&mut` ranges under the
// documented disjointness contract of `get`/`slice`; with disjoint indices
// per thread there is no shared mutable state, so sharing the wrapper
// across threads is sound whenever `T: Send` (values are mutated from
// whichever thread claims the index).
unsafe impl<T: Send> Sync for SendCells<'_, T> {}
// SAFETY: same argument as `Sync`; the wrapper owns no thread-affine
// state, it only borrows the buffer, and `T: Send` lets the borrowed
// values be written from another thread.
unsafe impl<T: Send> Send for SendCells<'_, T> {}

impl<'a, T> SendCells<'a, T> {
    /// Pointer to element `i` (bounds-checked). Writing through it is
    /// `unsafe`; concurrent callers must access disjoint indices.
    pub fn get(&self, i: usize) -> *mut T {
        self.cells[i].get()
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Mutable view of `start..start + len`.
    ///
    /// # Safety
    /// Concurrent callers must access disjoint ranges, and a caller must
    /// not hold two overlapping slices at once. Debug builds enforce this
    /// with a claims ledger (claimed ranges are never released — claim
    /// each range at most once per `SendCells` lifetime).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        let end = start.checked_add(len).expect("SendCells::slice range overflows usize");
        assert!(end <= self.cells.len(), "SendCells::slice out of bounds");
        if len == 0 {
            return &mut [];
        }
        #[cfg(debug_assertions)]
        self.claim(start, end);
        // Derive from the whole-slice pointer, not `self.cells[start]`:
        // an element reference would carry single-element provenance and
        // the `len`-wide view would be out of range under Stacked Borrows.
        let base = self.cells.as_ptr() as *mut T;
        // SAFETY: `start + len <= self.cells.len()` was asserted above;
        // `UnsafeCell<T>` has the same in-memory layout as `T`, so the
        // cast base pointer addresses the same contiguous buffer, and the
        // caller contract guarantees no overlapping views exist.
        unsafe { std::slice::from_raw_parts_mut(base.add(start), len) }
    }

    #[cfg(debug_assertions)]
    fn claim(&self, start: usize, end: usize) {
        let mut claims = self.claims.lock().unwrap();
        for &(s, e) in claims.iter() {
            assert!(
                end <= s || e <= start,
                "SendCells::slice overlap: {start}..{end} vs existing claim {s}..{e}"
            );
        }
        claims.push((start, end));
    }
}

/// Wrap a mutable slice for disjoint-index parallel writes.
pub fn as_send_cells<T>(xs: &mut [T]) -> SendCells<'_, T> {
    let len = xs.len();
    let ptr = xs.as_mut_ptr() as *const UnsafeCell<T>;
    // SAFETY: `UnsafeCell<T>` has the same in-memory layout as `T`, and
    // the exclusive borrow of `xs` is transferred into the returned
    // wrapper's lifetime, so viewing the buffer as shared cells cannot
    // alias any other live reference.
    let cells = unsafe { std::slice::from_raw_parts(ptr, len) };
    SendCells {
        cells,
        #[cfg(debug_assertions)]
        claims: Mutex::new(Vec::new()),
    }
}

/// Alias of [`as_send_cells`] that reads better at call sites scattering
/// into disjoint per-node slots or row ranges (the level-scheduled tree
/// sweeps in `hss::{compress, ulv, matvec}`).
pub fn disjoint<T>(xs: &mut [T]) -> SendCells<'_, T> {
    as_send_cells(xs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    // Miri runs the same suites ~100-1000x slower; shrink the index
    // spaces so the lane stays fast while still crossing the parallel
    // (multi-chunk, multi-thread) code paths.
    const N_LARGE: usize = if cfg!(miri) { 128 } else { 10_000 };
    const N_MAP: usize = if cfg!(miri) { 96 } else { 1000 };

    #[test]
    fn parallel_for_covers_all_indices_once() {
        let n = N_LARGE;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(4, n, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_single_thread_sequential() {
        let n = 100;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1, n, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_ordered() {
        for chunk in [1, 16, 64] {
            let out = parallel_map(4, N_MAP, chunk, |i| i * i);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * i);
            }
        }
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<usize> = parallel_map(4, 0, 1, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn run_levels_respects_level_order_and_covers_once() {
        // ragged levels; every id must run once, and nobody may run
        // before all ids of the previous level finished
        let levels_owned: Vec<Vec<usize>> = vec![vec![0, 1, 2, 3, 4], vec![5, 6], vec![7]];
        let levels: Vec<&[usize]> = levels_owned.iter().map(|l| l.as_slice()).collect();
        for threads in [1, 2, 8] {
            let hits: Vec<AtomicU64> = (0..8).map(|_| AtomicU64::new(0)).collect();
            let done_below: Vec<AtomicUsize> =
                levels_owned.iter().map(|_| AtomicUsize::new(0)).collect();
            let level_of = |id: usize| match id {
                0..=4 => 0usize,
                5 | 6 => 1,
                _ => 2,
            };
            run_levels(threads, &levels, |id| {
                let li = level_of(id);
                if li > 0 {
                    assert_eq!(
                        done_below[li - 1].load(Ordering::SeqCst),
                        levels_owned[li - 1].len(),
                        "node {id} ran before its level's barrier"
                    );
                }
                hits[id].fetch_add(1, Ordering::SeqCst);
                done_below[li].fetch_add(1, Ordering::SeqCst);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        }
    }

    #[test]
    fn run_levels_propagates_panics_without_deadlock() {
        let levels_owned: Vec<Vec<usize>> = vec![vec![0, 1, 2, 3], vec![4]];
        let levels: Vec<&[usize]> = levels_owned.iter().map(|l| l.as_slice()).collect();
        for threads in [1, 4] {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run_levels(threads, &levels, |id| {
                    if id == 2 {
                        panic!("boom at node {id}");
                    }
                });
            }));
            assert!(result.is_err(), "panic must propagate at threads={threads}");
        }
    }

    #[test]
    fn run_levels_empty_and_single() {
        run_levels(4, &[], |_| panic!("no work"));
        let level: Vec<usize> = vec![0];
        let hit = AtomicU64::new(0);
        run_levels(4, &[level.as_slice()], |id| {
            assert_eq!(id, 0);
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn disjoint_slice_ranges() {
        let mut xs = vec![0u64; 256];
        {
            let cells = disjoint(&mut xs);
            parallel_for(4, 8, 1, |t| {
                // SAFETY: each task owns rows t*32..(t+1)*32.
                let range = unsafe { cells.slice(t * 32, 32) };
                for (o, v) in range.iter_mut().enumerate() {
                    *v = (t * 32 + o) as u64;
                }
            });
        }
        for (i, v) in xs.iter().enumerate() {
            assert_eq!(*v, i as u64);
        }
    }

    #[test]
    fn miri_sendcells_disjoint_get_across_threads() {
        // Every index written through a raw `get` pointer by exactly one
        // task, from multiple real threads — the core scatter primitive
        // Miri checks for provenance/data-race violations.
        let n = if cfg!(miri) { 64 } else { 4096 };
        let mut xs = vec![0usize; n];
        {
            let cells = as_send_cells(&mut xs);
            parallel_for(4, n, 8, |i| {
                // SAFETY: each index is written by exactly one task.
                unsafe { *cells.get(i) = i + 1 };
            });
        }
        for (i, v) in xs.iter().enumerate() {
            assert_eq!(*v, i + 1);
        }
    }

    #[test]
    fn miri_sendcells_adjacent_slices_disjoint() {
        // Adjacent (touching, non-overlapping) ranges must coexist across
        // threads: this is the exact shape of the HSS row scatters.
        let mut xs = vec![0u32; 48];
        {
            let cells = disjoint(&mut xs);
            parallel_for(3, 3, 1, |t| {
                // SAFETY: tasks claim disjoint adjacent ranges 16t..16t+16.
                let range = unsafe { cells.slice(t * 16, 16) };
                for v in range.iter_mut() {
                    *v = t as u32 + 1;
                }
            });
        }
        for (i, v) in xs.iter().enumerate() {
            assert_eq!(*v, (i / 16) as u32 + 1);
        }
    }

    #[test]
    fn miri_sendcells_zero_len_slice() {
        let mut xs = vec![0u8; 4];
        let cells = as_send_cells(&mut xs);
        // SAFETY: zero-length views alias nothing; the end-of-buffer
        // start position is in bounds for an empty range.
        let empty = unsafe { cells.slice(4, 0) };
        assert!(empty.is_empty());
        // SAFETY: zero-length view, then a full-width disjoint claim.
        let empty2 = unsafe { cells.slice(2, 0) };
        assert!(empty2.is_empty());
        // SAFETY: sole non-empty claim over the whole buffer.
        let all = unsafe { cells.slice(0, 4) };
        all.fill(7);
        drop(cells);
        assert_eq!(xs, vec![7u8; 4]);
    }

    #[test]
    #[should_panic]
    fn sendcells_get_out_of_bounds_panics() {
        let mut xs = vec![0u8; 3];
        let cells = as_send_cells(&mut xs);
        let _ = cells.get(3);
    }

    #[test]
    #[should_panic]
    fn sendcells_slice_out_of_bounds_panics() {
        let mut xs = vec![0u8; 3];
        let cells = as_send_cells(&mut xs);
        // SAFETY: trips the bounds assert before any pointer is formed.
        let _ = unsafe { cells.slice(1, 3) };
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "SendCells::slice overlap")]
    fn sendcells_overlapping_slices_debug_panic() {
        let mut xs = vec![0u8; 8];
        let cells = as_send_cells(&mut xs);
        // SAFETY: first claim is the sole live view when created; the
        // second, overlapping claim is the contract violation under test
        // and must be caught by the debug ledger before a view is formed.
        let _a = unsafe { cells.slice(0, 5) };
        let _b = unsafe { cells.slice(4, 2) };
    }

    #[test]
    fn default_threads_at_least_one() {
        assert!(default_threads() >= 1);
    }
}
