//! A small scoped-parallelism layer over `std::thread`.
//!
//! No tokio/rayon in the offline crate set, and the workloads here are
//! CPU-bound data parallel loops (kernel block evaluation, per-node HSS
//! compression, per-dataset experiments), so `std::thread::scope` with a
//! shared atomic work counter covers everything we need while staying
//! deterministic when `threads == 1`.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};

/// Number of worker threads to use by default: `HSS_SVM_THREADS` env var,
/// else available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("HSS_SVM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(i)` for every `i in 0..n` across `threads` workers using atomic
/// chunk self-scheduling. `f` must be `Sync` (called concurrently).
pub fn parallel_for(threads: usize, n: usize, chunk: usize, f: impl Fn(usize) + Sync) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= chunk {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = chunk.max(1);
    let counter = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let start = counter.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
/// `chunk` is the self-scheduling granularity: 1 for coarse per-item work
/// (tree nodes, row tiles), larger for cheap per-item work so each atomic
/// fetch amortizes over many items.
pub fn parallel_map<T: Send>(
    threads: usize,
    n: usize,
    chunk: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = as_send_cells(&mut out);
        parallel_for(threads, n, chunk, |i| {
            // SAFETY: each index is written by exactly one task.
            unsafe { *slots.get(i) = Some(f(i)) };
        });
    }
    out.into_iter().map(|o| o.expect("slot filled")).collect()
}

/// Level-scheduled tree traversal: the levels of `levels` run strictly in
/// order with a barrier between consecutive levels, and the nodes of one
/// level are self-scheduled across a worker pool spawned ONCE for the
/// whole traversal (a per-level spawn would pay thread startup at every
/// level of every sweep). `f(id)` runs exactly once per id; it may read
/// state produced by earlier levels (the barrier publishes it) and must
/// confine writes to state owned by `id` — use [`disjoint`] for the
/// scatter. With `threads <= 1` this degrades to plain nested loops, and
/// because per-node work is identical either way, results are bit-for-bit
/// independent of the thread count.
pub fn run_levels(threads: usize, levels: &[&[usize]], f: impl Fn(usize) + Sync) {
    let widest = levels.iter().map(|l| l.len()).max().unwrap_or(0);
    let threads = threads.max(1).min(widest.max(1));
    if threads <= 1 {
        for level in levels {
            for &id in *level {
                f(id);
            }
        }
        return;
    }
    let counters: Vec<AtomicUsize> = levels.iter().map(|_| AtomicUsize::new(0)).collect();
    let barrier = Barrier::new(threads);
    // A panicking task must not strand its siblings at the barrier:
    // capture the payload, drain the remaining levels (every worker hits
    // every barrier exactly once), then re-throw after the join.
    let abort = AtomicBool::new(false);
    let payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                for (li, level) in levels.iter().enumerate() {
                    while !abort.load(Ordering::Relaxed) {
                        let t = counters[li].fetch_add(1, Ordering::Relaxed);
                        if t >= level.len() {
                            break;
                        }
                        let id = level[t];
                        if let Err(p) =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(id)))
                        {
                            *payload.lock().unwrap() = Some(p);
                            abort.store(true, Ordering::Relaxed);
                        }
                    }
                    // barrier publishes this level's writes to the next
                    barrier.wait();
                }
            });
        }
    });
    if let Some(p) = payload.into_inner().unwrap() {
        std::panic::resume_unwind(p);
    }
}

/// Helper: expose disjoint-index mutable access to a slice across threads.
pub struct SendCells<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut T>,
}

unsafe impl<T: Send> Sync for SendCells<'_, T> {}
unsafe impl<T: Send> Send for SendCells<'_, T> {}

impl<'a, T> SendCells<'a, T> {
    /// # Safety contract (enforced by callers)
    /// Concurrent callers must access disjoint indices.
    pub fn get(&self, i: usize) -> *mut T {
        assert!(i < self.len);
        unsafe { self.ptr.add(i) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `start..start + len`.
    ///
    /// # Safety
    /// Concurrent callers must access disjoint ranges, and a caller must
    /// not hold two overlapping slices at once.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        assert!(start.checked_add(len).is_some_and(|end| end <= self.len));
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// Wrap a mutable slice for disjoint-index parallel writes.
pub fn as_send_cells<T>(xs: &mut [T]) -> SendCells<'_, T> {
    SendCells { ptr: xs.as_mut_ptr(), len: xs.len(), _marker: std::marker::PhantomData }
}

/// Alias of [`as_send_cells`] that reads better at call sites scattering
/// into disjoint per-node slots or row ranges (the level-scheduled tree
/// sweeps in `hss::{compress, ulv, matvec}`).
pub fn disjoint<T>(xs: &mut [T]) -> SendCells<'_, T> {
    as_send_cells(xs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(4, n, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_single_thread_sequential() {
        let n = 100;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1, n, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_ordered() {
        for chunk in [1, 16, 64] {
            let out = parallel_map(4, 1000, chunk, |i| i * i);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * i);
            }
        }
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<usize> = parallel_map(4, 0, 1, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn run_levels_respects_level_order_and_covers_once() {
        // ragged levels; every id must run once, and nobody may run
        // before all ids of the previous level finished
        let levels_owned: Vec<Vec<usize>> = vec![vec![0, 1, 2, 3, 4], vec![5, 6], vec![7]];
        let levels: Vec<&[usize]> = levels_owned.iter().map(|l| l.as_slice()).collect();
        for threads in [1, 2, 8] {
            let hits: Vec<AtomicU64> = (0..8).map(|_| AtomicU64::new(0)).collect();
            let done_below: Vec<AtomicUsize> =
                levels_owned.iter().map(|_| AtomicUsize::new(0)).collect();
            let level_of = |id: usize| match id {
                0..=4 => 0usize,
                5 | 6 => 1,
                _ => 2,
            };
            run_levels(threads, &levels, |id| {
                let li = level_of(id);
                if li > 0 {
                    assert_eq!(
                        done_below[li - 1].load(Ordering::SeqCst),
                        levels_owned[li - 1].len(),
                        "node {id} ran before its level's barrier"
                    );
                }
                hits[id].fetch_add(1, Ordering::SeqCst);
                done_below[li].fetch_add(1, Ordering::SeqCst);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        }
    }

    #[test]
    fn run_levels_propagates_panics_without_deadlock() {
        let levels_owned: Vec<Vec<usize>> = vec![vec![0, 1, 2, 3], vec![4]];
        let levels: Vec<&[usize]> = levels_owned.iter().map(|l| l.as_slice()).collect();
        for threads in [1, 4] {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run_levels(threads, &levels, |id| {
                    if id == 2 {
                        panic!("boom at node {id}");
                    }
                });
            }));
            assert!(result.is_err(), "panic must propagate at threads={threads}");
        }
    }

    #[test]
    fn run_levels_empty_and_single() {
        run_levels(4, &[], |_| panic!("no work"));
        let level: Vec<usize> = vec![0];
        let hit = AtomicU64::new(0);
        run_levels(4, &[level.as_slice()], |id| {
            assert_eq!(id, 0);
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn disjoint_slice_ranges() {
        let mut xs = vec![0u64; 256];
        {
            let cells = disjoint(&mut xs);
            parallel_for(4, 8, 1, |t| {
                // SAFETY: each task owns rows t*32..(t+1)*32.
                let range = unsafe { cells.slice(t * 32, 32) };
                for (o, v) in range.iter_mut().enumerate() {
                    *v = (t * 32 + o) as u64;
                }
            });
        }
        for (i, v) in xs.iter().enumerate() {
            assert_eq!(*v, i as u64);
        }
    }

    #[test]
    fn default_threads_at_least_one() {
        assert!(default_threads() >= 1);
    }
}
