//! Wall-clock timing and a hierarchical phase profiler.
//!
//! The paper reports per-phase times (Compression / Factorization / ADMM)
//! — `PhaseTimer` collects exactly those, and the bench harness reuses the
//! same machinery.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

/// Accumulating named-phase timer (thread-safe). Phases are reported in
/// insertion order so tables come out in pipeline order.
#[derive(Default)]
pub struct PhaseTimer {
    inner: Mutex<PhaseInner>,
}

#[derive(Default)]
struct PhaseInner {
    order: Vec<String>,
    totals: HashMap<String, (Duration, u64)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one timed execution of `phase`.
    pub fn record(&self, phase: &str, f: impl FnOnce()) {
        let t = Instant::now();
        f();
        self.add(phase, t.elapsed());
    }

    /// Record one timed execution returning a value.
    pub fn record_val<T>(&self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(phase, t.elapsed());
        out
    }

    /// Add a pre-measured duration.
    pub fn add(&self, phase: &str, d: Duration) {
        let mut g = self.inner.lock().unwrap();
        if !g.totals.contains_key(phase) {
            g.order.push(phase.to_string());
        }
        let e = g.totals.entry(phase.to_string()).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Total seconds for a phase (0 when never recorded).
    pub fn secs(&self, phase: &str) -> f64 {
        let g = self.inner.lock().unwrap();
        g.totals.get(phase).map(|(d, _)| d.as_secs_f64()).unwrap_or(0.0)
    }

    /// Number of recordings for a phase.
    pub fn count(&self, phase: &str) -> u64 {
        let g = self.inner.lock().unwrap();
        g.totals.get(phase).map(|&(_, c)| c).unwrap_or(0)
    }

    /// (phase, total seconds, count) in insertion order.
    pub fn report(&self) -> Vec<(String, f64, u64)> {
        let g = self.inner.lock().unwrap();
        g.order
            .iter()
            .map(|p| {
                let (d, c) = g.totals[p];
                (p.clone(), d.as_secs_f64(), c)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.secs() >= 0.004);
    }

    #[test]
    fn phase_timer_accumulates_in_order() {
        let pt = PhaseTimer::new();
        pt.record("compress", || {});
        pt.record("factor", || {});
        pt.record("compress", || {});
        let rep = pt.report();
        assert_eq!(rep.len(), 2);
        assert_eq!(rep[0].0, "compress");
        assert_eq!(rep[0].2, 2);
        assert_eq!(rep[1].0, "factor");
        assert_eq!(pt.count("compress"), 2);
        assert_eq!(pt.count("missing"), 0);
        assert_eq!(pt.secs("missing"), 0.0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
