//! `SimdF32Backend` — opt-in f32 kernel-block / prediction path with
//! runtime SIMD dispatch.
//!
//! The xᵀy inner products of the kernel block are computed in f32 —
//! 8-wide AVX2+FMA when the host CPU has it (detected once at backend
//! construction with `is_x86_feature_detected!`), a scalar f32 loop
//! otherwise (and always under Miri / on non-x86_64 targets). Squared
//! row norms stay in f64 and `Kernel::eval_from_parts` runs in f64, so
//! the only precision loss is the inner product itself.
//!
//! Error model (DESIGN.md §13): an f32 dot over d features carries
//! absolute error ≲ d·ε₃₂·‖x‖‖y‖ (ε₃₂ ≈ 1.2e-7). For the scaled
//! features this repo trains on (O(1) entries, d ≤ a few hundred) that
//! keeps kernel entries and decision values within **1e-4 relative** of
//! the f64 oracle — the documented tolerance, asserted by
//! `tests/backend_oracle.rs` and re-checked inside `bench_hss`.
//!
//! Only the kernel-block family is overridden; gemm, ULV solves and
//! matvec probes inherit the f64 reference path (training through this
//! backend therefore only changes kernel-block numerics, and the
//! default prediction tile accelerates automatically because it is
//! composed from `kernel_block_with_norms`). Sparse operands always
//! delegate to the f64 reference — f32 pays off on the dense gemm-like
//! shape, not on gather/merge accumulation.

use super::ComputeBackend;
use crate::data::sparse::Points;
use crate::kernel::Kernel;
use crate::linalg::Mat;

/// f32 kernel-block backend with runtime AVX2+FMA dispatch.
#[derive(Clone, Copy, Debug)]
pub struct SimdF32Backend {
    use_avx2: bool,
}

impl SimdF32Backend {
    /// Detect the SIMD tier once; the choice is fixed for the lifetime
    /// of the backend so results are reproducible within a process.
    pub fn new() -> Self {
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        let use_avx2 = is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma");
        #[cfg(not(all(target_arch = "x86_64", not(miri))))]
        let use_avx2 = false;
        SimdF32Backend { use_avx2 }
    }

    /// Whether the 8-wide AVX2+FMA path is active (false = scalar f32
    /// fallback; bench and CLI echoes report this).
    pub fn avx2_active(&self) -> bool {
        self.use_avx2
    }

    fn dot_f32(&self, x: &[f32], y: &[f32]) -> f32 {
        #[cfg(target_arch = "x86_64")]
        if self.use_avx2 {
            // SAFETY: `use_avx2` is set only when `is_x86_feature_detected!`
            // confirmed both AVX2 and FMA on this CPU at construction, which
            // is exactly the target-feature contract of `dot_f32_avx2`; the
            // slices come from rows of matrices with equal column counts.
            return unsafe { dot_f32_avx2(x, y) };
        }
        dot_f32_scalar(x, y)
    }
}

impl Default for SimdF32Backend {
    fn default() -> Self {
        Self::new()
    }
}

impl ComputeBackend for SimdF32Backend {
    fn name(&self) -> &'static str {
        "simd-f32"
    }

    fn kernel_block(&self, k: &Kernel, x: &Points, y: &Points) -> Mat {
        let nx = x.self_norms();
        let ny = y.self_norms();
        self.kernel_block_with_norms(k, x, &nx, y, &ny)
    }

    fn kernel_block_with_norms(
        &self,
        k: &Kernel,
        x: &Points,
        nx: &[f64],
        y: &Points,
        ny: &[f64],
    ) -> Mat {
        let (Points::Dense(xm), Points::Dense(ym)) = (x, y) else {
            // Sparse pairings: gather/merge accumulation stays f64.
            return crate::kernel::kernel_block_pts_with_norms(k, x, nx, y, ny);
        };
        assert_eq!(xm.cols(), ym.cols(), "feature dimension mismatch");
        let (m, n, d) = (xm.rows(), ym.rows(), xm.cols());
        assert_eq!(nx.len(), m);
        assert_eq!(ny.len(), n);
        let xf = to_f32(xm);
        let yf = to_f32(ym);
        let mut g = Mat::zeros(m, n);
        for i in 0..m {
            let xi = &xf[i * d..(i + 1) * d];
            let nxi = nx[i];
            let row = g.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                let ab = self.dot_f32(xi, &yf[j * d..(j + 1) * d]);
                *v = k.eval_from_parts(nxi, ny[j], f64::from(ab));
            }
        }
        g
    }
}

fn to_f32(m: &Mat) -> Vec<f32> {
    m.data().iter().map(|&v| v as f32).collect()
}

fn dot_f32_scalar(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y.iter()).map(|(a, b)| a * b).sum()
}

/// 8-lane AVX2+FMA f32 dot product with a scalar tail.
///
/// # Safety
///
/// The caller must guarantee the running CPU supports AVX2 and FMA
/// (checked once via `is_x86_feature_detected!` at backend
/// construction). `x` and `y` must have equal length.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_f32_avx2(x: &[f32], y: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(x.len(), y.len());
    let n8 = x.len() / 8 * 8;
    // SAFETY: every `loadu` reads lanes i..i+8 with i + 8 ≤ n8 ≤ len of
    // both slices, so the unaligned loads stay in bounds (`loadu` has no
    // alignment requirement); the remaining intrinsics are register-only
    // and covered by the enabled avx2+fma target features.
    unsafe {
        let mut acc = _mm256_setzero_ps();
        let (px, py) = (x.as_ptr(), y.as_ptr());
        let mut i = 0;
        while i < n8 {
            let xv = _mm256_loadu_ps(px.add(i));
            let yv = _mm256_loadu_ps(py.add(i));
            acc = _mm256_fmadd_ps(xv, yv, acc);
            i += 8;
        }
        // horizontal sum: 256 → 128 → 64 → 32 bits
        let hi = _mm256_extractf128_ps(acc, 1);
        let s = _mm_add_ps(_mm256_castps256_ps128(acc), hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        let mut dot = _mm_cvtss_f32(s);
        for t in n8..x.len() {
            dot += x[t] * y[t];
        }
        dot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute;
    use crate::data::sparse::CsrMat;
    use crate::util::prng::Rng;

    fn max_rel_err(got: &[f64], want: &[f64]) -> f64 {
        got.iter()
            .zip(want.iter())
            .map(|(g, w)| (g - w).abs() / (1.0 + w.abs()))
            .fold(0.0, f64::max)
    }

    #[test]
    fn f32_block_within_tolerance_of_f64_oracle() {
        let mut rng = Rng::new(51);
        let x = Points::Dense(Mat::gauss(60, 33, &mut rng));
        let y = Points::Dense(Mat::gauss(45, 33, &mut rng));
        let b = SimdF32Backend::new();
        for k in [Kernel::Gaussian { h: 0.9 }, Kernel::Linear] {
            let got = b.kernel_block(&k, &x, &y);
            let want = compute::cpu().kernel_block(&k, &x, &y);
            let err = max_rel_err(got.data(), want.data());
            assert!(err <= 1e-4, "f32 kernel block err {err:e} above documented 1e-4");
        }
    }

    #[test]
    fn scalar_and_dispatched_paths_agree() {
        // On AVX2 hosts this compares 8-wide FMA against the scalar f32
        // loop (different summation order, same f32 data); on other
        // hosts both sides are the scalar path and agree exactly.
        let mut rng = Rng::new(52);
        let x = Points::Dense(Mat::gauss(30, 19, &mut rng));
        let y = Points::Dense(Mat::gauss(21, 19, &mut rng));
        let k = Kernel::Gaussian { h: 1.1 };
        let auto = SimdF32Backend::new();
        let scalar = SimdF32Backend { use_avx2: false };
        let a = auto.kernel_block(&k, &x, &y);
        let s = scalar.kernel_block(&k, &x, &y);
        let err = max_rel_err(a.data(), s.data());
        assert!(err <= 1e-5, "scalar vs dispatched drift {err:e}");
    }

    #[test]
    fn sparse_operands_delegate_to_f64_reference_bitwise() {
        let mut rng = Rng::new(53);
        let xm = Mat::gauss(12, 40, &mut rng);
        let xs = Points::Sparse(CsrMat::from_dense(&xm));
        let yd = Points::Dense(Mat::gauss(9, 40, &mut rng));
        let k = Kernel::Gaussian { h: 0.8 };
        let b = SimdF32Backend::new();
        assert_eq!(b.kernel_block(&k, &xs, &yd), compute::cpu().kernel_block(&k, &xs, &yd));
    }

    #[test]
    fn miri_simd_scalar_fallback_matches_oracle() {
        // Miri drill: under Miri `new()` always picks the scalar f32
        // path (no intrinsics execute), so this validates the fallback
        // every non-AVX2 host takes, plus the f32 buffer indexing.
        let mut rng = Rng::new(54);
        let x = Points::Dense(Mat::gauss(8, 5, &mut rng));
        let y = Points::Dense(Mat::gauss(6, 5, &mut rng));
        let k = Kernel::Gaussian { h: 1.0 };
        let got = SimdF32Backend { use_avx2: false }.kernel_block(&k, &x, &y);
        let want = compute::cpu().kernel_block(&k, &x, &y);
        let err = max_rel_err(got.data(), want.data());
        assert!(err <= 1e-4, "scalar f32 fallback err {err:e}");
    }
}
