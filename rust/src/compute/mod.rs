//! Pluggable compute backends for the hot-path primitives.
//!
//! The paper's cost model concentrates in four dense kernels — the
//! kernel-block evaluation (‖x‖² + ‖y‖² − 2xᵀy with a gemm/gather/merge
//! xᵀy term), the BLAS-3 multi-RHS ULV solve sweeps, the matvec probes
//! used during compression, and raw gemm. [`ComputeBackend`] names those
//! primitives once so accelerator and reduced-precision paths are
//! drop-in implementations instead of per-call-site surgery
//! (DESIGN.md §13).
//!
//! Three implementations ship today:
//!
//! - [`CpuBackend`] — the reference. Every method is the trait default,
//!   which delegates to the exact pre-refactor free function, so its
//!   output is **bit-for-bit identical** to the historical CPU path by
//!   construction (pinned by `tests/backend_oracle.rs` and every
//!   existing thread-invariance/bitwise suite).
//! - [`SimdF32Backend`] (feature `simd-f32`) — opt-in f32 kernel-block /
//!   prediction path with runtime AVX2+FMA dispatch and a scalar-f32
//!   fallback, ≤1e-4 relative on decision values vs the f64 oracle.
//! - [`crate::runtime::PjrtRuntime`] — the PJRT tile executor implements
//!   the trait directly (accelerated decision tiles, CPU reference for
//!   everything else), replacing the ad-hoc densify glue.
//!
//! Selection is one [`BackendChoice`] enum plumbed through
//! `HssSvmTrainer`, `OvoEngine` entry points, the server registry and
//! the `--backend` CLI flag.

pub mod cpu;
#[cfg(feature = "simd-f32")]
pub mod simd_f32;

pub use cpu::CpuBackend;
#[cfg(feature = "simd-f32")]
pub use simd_f32::SimdF32Backend;

use crate::data::sparse::Points;
use crate::hss::matvec;
use crate::hss::ulv::UlvFactor;
use crate::hss::Hss;
use crate::kernel::Kernel;
use crate::linalg::blas::{self, Trans};
use crate::linalg::Mat;
use anyhow::{bail, Result};
use std::sync::Arc;

/// The four hot compute primitives behind one seam.
///
/// Every method has a default implementation that calls the pre-refactor
/// free function, so [`CpuBackend`] (which overrides nothing) is the
/// bitwise reference; other backends override only the primitives they
/// accelerate and inherit the reference path for the rest.
pub trait ComputeBackend: Send + Sync {
    /// Short id for logs / CLI echoes ("cpu", "simd-f32", "pjrt").
    fn name(&self) -> &'static str;

    // --- primitive 1: gemm (with transpose flags) ---

    /// C = op(A)·op(B).
    fn gemm(&self, a: &Mat, ta: Trans, b: &Mat, tb: Trans) -> Mat {
        blas::matmul(a, ta, b, tb)
    }

    /// Row-banded parallel C = op(A)·op(B).
    fn gemm_par(&self, threads: usize, a: &Mat, ta: Trans, b: &Mat, tb: Trans) -> Mat {
        blas::matmul_par(threads, a, ta, b, tb)
    }

    // --- primitive 2: kernel block over `Points` pairings ---

    /// K(X, Y) over any dense/CSR pairing (gemm | sparse-dense gather |
    /// sparse-sparse merge).
    fn kernel_block(&self, k: &Kernel, x: &Points, y: &Points) -> Mat {
        crate::kernel::kernel_block_pts(k, x, y)
    }

    /// [`Self::kernel_block`] with caller-provided squared row norms
    /// (the tiled-prediction hot path).
    fn kernel_block_with_norms(
        &self,
        k: &Kernel,
        x: &Points,
        nx: &[f64],
        y: &Points,
        ny: &[f64],
    ) -> Mat {
        crate::kernel::kernel_block_pts_with_norms(k, x, nx, y, ny)
    }

    /// Parallel kernel block, banding the rows of X across threads.
    fn kernel_block_par(&self, threads: usize, k: &Kernel, x: &Points, y: &Points) -> Mat {
        crate::kernel::kernel_block_pts_par(threads, k, x, y)
    }

    /// Single kernel row K(x_i, Y) (SMO hot path).
    fn kernel_row(
        &self,
        k: &Kernel,
        x: &Points,
        i: usize,
        ni: f64,
        y: &Points,
        ny: &[f64],
        out: &mut [f64],
    ) {
        crate::kernel::kernel_row_pts(k, x, i, ni, y, ny, out)
    }

    // --- primitive 3: shifted solve apply (blocked Chol/LU + ULV) ---

    /// (K̃ + βI)⁻¹ b through the ULV up/downsweep.
    fn ulv_solve(&self, f: &UlvFactor, b: &[f64]) -> Vec<f64> {
        f.solve(b)
    }

    /// Multi-RHS (K̃ + βI)⁻¹ B — the blocked sweep the batched C-grid
    /// rides on.
    fn ulv_solve_mat(&self, f: &UlvFactor, b: &Mat) -> Mat {
        f.solve_mat(b)
    }

    // --- primitive 4: matvec probes ---

    /// K̃x through the compressed HSS form (compression probes,
    /// residual checks, model assembly).
    fn hss_matvec(&self, h: &Hss, x: &[f64], threads: usize) -> Vec<f64> {
        matvec::matvec_threads(h, x, threads)
    }

    // --- fused prediction tile (composed from the primitives) ---

    /// One prediction tile: K(tile, SV)·αy, bias excluded (the caller
    /// adds it). The default composes [`Self::kernel_block_with_norms`]
    /// with the reference gemv, so a backend that overrides the kernel
    /// block accelerates prediction for free.
    fn decision_tile(
        &self,
        k: &Kernel,
        xb: &Points,
        xb_norms: &[f64],
        sv: &Points,
        sv_norms: &[f64],
        alpha_y: &[f64],
    ) -> Vec<f64> {
        let kb = self.kernel_block_with_norms(k, xb, xb_norms, sv, sv_norms);
        let mut f = vec![0.0; xb.rows()];
        blas::gemv(&kb, alpha_y, &mut f);
        f
    }
}

/// The reference (f64, CPU) prediction tile as a free function — the
/// fallback target for accelerated backends that must degrade to the
/// oracle path (e.g. PJRT on CSR operands or artifact failure).
pub fn reference_decision_tile(
    k: &Kernel,
    xb: &Points,
    xb_norms: &[f64],
    sv: &Points,
    sv_norms: &[f64],
    alpha_y: &[f64],
) -> Vec<f64> {
    let kb = crate::kernel::kernel_block_pts_with_norms(k, xb, xb_norms, sv, sv_norms);
    let mut f = vec![0.0; xb.rows()];
    blas::gemv(&kb, alpha_y, &mut f);
    f
}

static CPU_BACKEND: CpuBackend = CpuBackend;

/// The shared reference backend (zero-sized; `&'static` so call sites
/// can default to it without allocation).
pub fn cpu() -> &'static CpuBackend {
    &CPU_BACKEND
}

/// The reference backend as an owning handle (for struct fields).
pub fn cpu_arc() -> Arc<dyn ComputeBackend> {
    Arc::new(CpuBackend)
}

/// Backend selection — one enum plumbed from the CLI through the
/// trainer, the OvO engine and the server registry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// f64 reference path (the default; bitwise-pinned).
    Cpu,
    /// f32 kernel-block/prediction path with runtime AVX2+FMA dispatch.
    SimdF32,
    /// PJRT decision-tile executor (requires compiled artifacts).
    Pjrt,
}

impl BackendChoice {
    /// Parse a `--backend` flag value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "cpu" => Ok(BackendChoice::Cpu),
            "simd-f32" | "simd_f32" => Ok(BackendChoice::SimdF32),
            "pjrt" => Ok(BackendChoice::Pjrt),
            other => bail!("unknown backend {other:?} (expected cpu | simd-f32 | pjrt)"),
        }
    }

    /// The flag spelling (inverse of [`Self::parse`]).
    pub fn label(&self) -> &'static str {
        match self {
            BackendChoice::Cpu => "cpu",
            BackendChoice::SimdF32 => "simd-f32",
            BackendChoice::Pjrt => "pjrt",
        }
    }

    /// Instantiate the backend, failing cleanly when the build or host
    /// cannot provide it (missing cargo feature, missing PJRT
    /// artifacts). `Cpu` always succeeds.
    pub fn resolve(self) -> Result<Arc<dyn ComputeBackend>> {
        match self {
            BackendChoice::Cpu => Ok(cpu_arc()),
            #[cfg(feature = "simd-f32")]
            BackendChoice::SimdF32 => Ok(Arc::new(SimdF32Backend::new())),
            #[cfg(not(feature = "simd-f32"))]
            BackendChoice::SimdF32 => {
                bail!("backend simd-f32 unavailable: built without the `simd-f32` cargo feature")
            }
            BackendChoice::Pjrt => {
                let dir = crate::runtime::PjrtRuntime::default_dir();
                let rt = crate::runtime::PjrtRuntime::load(dir)?;
                Ok(Arc::new(rt))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::CsrMat;
    use crate::util::prng::Rng;

    fn fixtures(rng: &mut Rng) -> (Kernel, Points, Points, Points, Points) {
        let xm = Mat::gauss(40, 12, rng);
        let ym = Mat::gauss(25, 12, rng);
        let xs = Points::Sparse(CsrMat::from_dense(&xm));
        let ys = Points::Sparse(CsrMat::from_dense(&ym));
        (Kernel::Gaussian { h: 0.9 }, Points::Dense(xm), Points::Dense(ym), xs, ys)
    }

    #[test]
    fn cpu_backend_is_bitwise_the_free_functions() {
        let mut rng = Rng::new(42);
        let (k, xd, yd, xs, ys) = fixtures(&mut rng);
        let b = cpu();
        for (x, y) in [(&xd, &yd), (&xs, &ys), (&xs, &yd), (&xd, &ys)] {
            assert_eq!(b.kernel_block(&k, x, y), crate::kernel::kernel_block_pts(&k, x, y));
            for threads in [1, 2, 8] {
                assert_eq!(
                    b.kernel_block_par(threads, &k, x, y),
                    crate::kernel::kernel_block_pts_par(threads, &k, x, y)
                );
            }
        }
        let a = Mat::gauss(9, 7, &mut rng);
        let c = Mat::gauss(9, 7, &mut rng);
        assert_eq!(b.gemm(&a, Trans::No, &c, Trans::Yes), blas::matmul(&a, Trans::No, &c, Trans::Yes));
        assert_eq!(
            b.gemm_par(3, &a, Trans::Yes, &c, Trans::No),
            blas::matmul_par(3, &a, Trans::Yes, &c, Trans::No)
        );
    }

    #[test]
    fn choice_parse_roundtrip_and_errors() {
        for c in [BackendChoice::Cpu, BackendChoice::SimdF32, BackendChoice::Pjrt] {
            assert_eq!(BackendChoice::parse(c.label()).unwrap(), c);
        }
        assert_eq!(BackendChoice::parse("simd_f32").unwrap(), BackendChoice::SimdF32);
        assert!(BackendChoice::parse("gpu").is_err());
        assert_eq!(BackendChoice::Cpu.resolve().unwrap().name(), "cpu");
    }

    #[test]
    fn reference_tile_matches_default_tile() {
        let mut rng = Rng::new(43);
        let (k, xd, yd, _, _) = fixtures(&mut rng);
        let (nx, ny) = (xd.self_norms(), yd.self_norms());
        let ay: Vec<f64> = (0..yd.rows()).map(|_| rng.gauss()).collect();
        assert_eq!(
            cpu().decision_tile(&k, &xd, &nx, &yd, &ny, &ay),
            reference_decision_tile(&k, &xd, &nx, &yd, &ny, &ay)
        );
    }
}
