//! The f64 reference backend.
//!
//! Deliberately empty: every [`ComputeBackend`] method keeps its default
//! body, and the defaults delegate to the exact free functions the
//! call sites used before the trait existed. That makes the bitwise
//! contract (`CpuBackend` output ≡ pre-refactor output) hold **by
//! construction**, not by re-verification — the existing
//! thread-invariance, grid-vs-sequential, multiclass and consensus
//! suites keep pinning the same code they always pinned.

#![forbid(unsafe_code)]

use super::ComputeBackend;

/// The reference (f64, exact pre-refactor) compute path.
#[derive(Clone, Copy, Debug, Default)]
pub struct CpuBackend;

impl ComputeBackend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }
}
