//! Stub PJRT runtime, compiled when the `pjrt` cargo feature is off.
//!
//! The real client (`pjrt.rs`) needs an external `xla` crate plus AOT
//! artifacts, neither of which exists in the offline build environment —
//! so by default this stub serves the identical public API: loading
//! always fails with a clear message, `try_default` returns `None`, and
//! every call site's artifact-absent fallback path (native prediction)
//! takes over. Enabling the `pjrt` feature requires vendoring the `xla`
//! dependency; see `rust/Cargo.toml`.

use crate::linalg::Mat;
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicUsize;

/// Tile geometry — must match python/compile/model.py.
pub const TILE_M: usize = 128;
pub const TILE_N: usize = 128;
pub const SV_CHUNK: usize = 1024;

/// Execution counters (observability for the perf pass).
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub kernel_tile_calls: AtomicUsize,
    pub decision_tile_calls: AtomicUsize,
}

/// Stand-in for the compiled-once PJRT executables. Never constructible
/// without the `pjrt` feature: [`PjrtRuntime::load`] always errors.
pub struct PjrtRuntime {
    pub stats: RuntimeStats,
}

impl PjrtRuntime {
    /// Default artifact directory: $HSS_SVM_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("HSS_SVM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Always fails: the PJRT client is not compiled in.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        bail!(
            "PJRT runtime unavailable: built without the `pjrt` cargo feature \
             (artifact dir requested: {})",
            dir.as_ref().display()
        )
    }

    /// `None` — the PJRT client is not compiled in.
    pub fn try_default() -> Option<Self> {
        None
    }

    /// Unreachable in practice (no constructor succeeds); errors for
    /// API parity with the real client.
    pub fn kernel_tile(&self, _x: &Mat, _y: &Mat, _gamma: f64) -> Result<Mat> {
        bail!("PJRT runtime unavailable: built without the `pjrt` cargo feature")
    }

    /// Unreachable in practice; errors for API parity.
    pub fn decision_tile(
        &self,
        _x: &Mat,
        _sv: &Mat,
        _alpha_y: &[f64],
        _gamma: f64,
    ) -> Result<Vec<f64>> {
        bail!("PJRT runtime unavailable: built without the `pjrt` cargo feature")
    }

    /// Feature dims available per artifact kind (always empty here).
    pub fn dims(&self) -> (Vec<usize>, Vec<usize>) {
        (Vec::new(), Vec::new())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_refuses_to_load_and_try_default_is_none() {
        assert!(PjrtRuntime::try_default().is_none());
        let err = match PjrtRuntime::load("artifacts") {
            Err(e) => e,
            Ok(_) => panic!("stub load must fail"),
        };
        assert!(err.to_string().contains("pjrt"), "unexpected error: {err}");
    }
}
