//! PJRT runtime: load AOT artifacts and execute them from the hot path.
//!
//! The bridge half of the three-layer architecture: `make artifacts`
//! lowers the L2 JAX model (which embeds the L1 Pallas kernel) to HLO
//! text; this module parses each `artifacts/*.hlo.txt` with
//! `HloModuleProto::from_text_file`, compiles it **once** on the CPU
//! PJRT client, and serves tile evaluations to prediction and
//! kernel-probe call sites. Python never runs at request time.
//!
//! Shape adaptation: artifacts exist for a few feature dims (8/32/128/
//! 512); inputs are zero-padded up to the next available dim (exact for
//! the Gaussian kernel — padding adds 0 to every squared distance) and
//! SV chunks are padded with αy = 0 rows (exactly no contribution).

// The real client references an external `xla` crate that the offline
// build environment does not provide, so it needs BOTH features:
// `pjrt` (the runtime surface, checkable everywhere — the CI
// feature-matrix builds it against the stub) and `xla-client` (the
// vendored dependency is actually wired in). With either feature
// missing, the stub serves the same API (load errors, try_default →
// None) and every call site falls back to the native prediction path.
#[cfg(all(feature = "pjrt", feature = "xla-client"))]
pub mod pjrt;
#[cfg(not(all(feature = "pjrt", feature = "xla-client")))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

/// Local API-compatible stand-in for the external `xla` crate, so the
/// real client (`pjrt.rs`) is compile-checked in CI without vendoring
/// the dependency (see `xla_compat.rs` for how to swap the real crate
/// back in).
#[cfg(all(feature = "pjrt", feature = "xla-client"))]
pub(crate) mod xla_compat;

pub use pjrt::{PjrtRuntime, RuntimeStats};

use crate::data::sparse::Points;
use crate::kernel::Kernel;
use crate::svm::SvmModel;
use anyhow::{Context, Result};

/// [`PjrtRuntime`] as a [`crate::compute::ComputeBackend`]: the fused
/// prediction tile runs on the compiled PJRT executable when the
/// operands qualify (dense tile, dense SVs, Gaussian kernel — the only
/// shape the AOT artifacts implement), and degrades **per tile** to the
/// bitwise CPU reference on CSR operands, other kernels, or any
/// execution error. Every other primitive inherits the reference
/// default, so training on this backend is exactly the CPU path.
impl crate::compute::ComputeBackend for PjrtRuntime {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn decision_tile(
        &self,
        k: &Kernel,
        xb: &Points,
        xb_norms: &[f64],
        sv: &Points,
        sv_norms: &[f64],
        alpha_y: &[f64],
    ) -> Vec<f64> {
        if let (Points::Dense(xd), Points::Dense(svd), Kernel::Gaussian { .. }) = (xb, sv, k) {
            if xd.rows() <= pjrt::TILE_M {
                // Inherent method (the raw tile executor), not this
                // trait method — the artifact pads to TILE_M rows, so
                // truncate back to the logical tile height. On error
                // (artifact missing/failed) fall through to the
                // reference path.
                if let Ok(f) = PjrtRuntime::decision_tile(self, xd, svd, alpha_y, k.gamma()) {
                    return f.into_iter().take(xd.rows()).collect();
                }
            }
        }
        crate::compute::reference_decision_tile(k, xb, xb_norms, sv, sv_norms, alpha_y)
    }
}

/// Decision function served by PJRT-executed fused tiles
/// (falls back tile-by-tile is NOT done here: callers choose the native
/// path explicitly when no runtime is available). The artifacts consume
/// dense buffers, so CSR test tiles are densified one 128-row tile at a
/// time (bounded scratch) and CSR models are rejected — the native path
/// serves those.
pub fn decision_function_pjrt(rt: &PjrtRuntime, model: &SvmModel, x: &Points) -> Result<Vec<f64>> {
    let sv = match &model.sv {
        Points::Dense(m) => m,
        Points::Sparse(_) => {
            anyhow::bail!("PJRT artifacts need a dense model; this model stores CSR support vectors (use the native path)")
        }
    };
    let n = x.rows();
    let mut out = Vec::with_capacity(n);
    let tile = pjrt::TILE_M;
    let mut i0 = 0;
    while i0 < n {
        let ib = tile.min(n - i0);
        let rows: Vec<usize> = (i0..i0 + ib).collect();
        let xb = x.select_rows(&rows).into_dense();
        let f = rt
            .decision_tile(&xb, sv, &model.alpha_y, model.kernel.gamma())
            .with_context(|| format!("decision tile at row {i0}"))?;
        out.extend(f.into_iter().take(ib).map(|v| v + model.bias));
        i0 += ib;
    }
    Ok(out)
}

/// Predicted labels via the PJRT path (mapped through the model's
/// original label pair, like [`crate::svm::predict::predict`]).
pub fn predict_pjrt(rt: &PjrtRuntime, model: &SvmModel, x: &Points) -> Result<Vec<f64>> {
    Ok(decision_function_pjrt(rt, model, x)?
        .into_iter()
        .map(|f| model.label_of(f))
        .collect())
}
