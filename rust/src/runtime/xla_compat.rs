//! API-compatible stand-in for the external `xla` crate.
//!
//! `pjrt.rs` was written against <https://github.com/LaurentMazare/xla-rs>,
//! which the offline build environment cannot vendor. This module mirrors
//! the exact slice of that crate's surface the client uses, so the
//! `--features pjrt,xla-client` CI lane **compile-checks** the real
//! client end-to-end (types, error plumbing, literal marshalling) without
//! the dependency. Every executable-path constructor fails at runtime
//! with a clear message — identical observable behavior to the stub
//! (`PjrtRuntime::load` errors, `try_default` → `None`), so no fallback
//! path changes.
//!
//! To wire the real crate back in: add `xla` to `Cargo.toml`, delete this
//! module, and change `use crate::runtime::xla_compat as xla;` in
//! `pjrt.rs` back to `use xla;`.

/// Error type shaped like `xla::Error` (only `Debug` is consumed: the
/// client formats errors with `{e:?}` before wrapping them in `anyhow`).
pub struct XlaError(pub String);

// Manual impl (not derived) so the message prints without struct noise —
// `{e:?}` at the call sites yields the human-readable shim explanation.
impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what}: the `xla-client` feature compiles against a local API shim; \
         vendor the real `xla` crate to execute artifacts"
    )))
}

/// Host-side literal (shape + flat buffer in the real crate; here a
/// marker the marshalling code can construct and thread through).
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    /// Rank-0 literal from a scalar.
    pub fn scalar<T: Copy>(_v: T) -> Literal {
        Literal
    }

    /// Rank-1 literal from a slice.
    pub fn vec1<T: Copy>(_v: &[T]) -> Literal {
        Literal
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Ok(Literal)
    }

    /// Unwrap a single-element tuple literal.
    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        unavailable("Literal::to_tuple1")
    }

    /// Copy the buffer out as host values.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file (`*.hlo.txt` artifact).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation handle wrapping a module proto.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed proto as a compilable computation.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client — always fails in the shim (no PJRT plugin linked).
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with host-literal arguments; one buffer row per device.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shim_constructors_work_and_executors_fail() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
        assert!(PjRtClient::cpu().is_err());
        let comp = XlaComputation::from_proto(&HloModuleProto);
        let _ = Literal::scalar(0.5f32);
        // Compile path is only reachable with a client; the type-level
        // plumbing is what this shim pins down.
        let _ = comp;
    }
}
