//! The PJRT client wrapper: artifact discovery, one-time compilation,
//! literal marshalling, tile execution.

use crate::linalg::Mat;
// The `xla` surface comes from the local API-compat shim so this module
// is compile-checked without vendoring the crate; swap this line for the
// real dependency to execute artifacts (see xla_compat.rs).
use crate::runtime::xla_compat as xla;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Tile geometry — must match python/compile/model.py.
pub const TILE_M: usize = 128;
pub const TILE_N: usize = 128;
pub const SV_CHUNK: usize = 1024;

/// Execution counters (observability for the perf pass).
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub kernel_tile_calls: AtomicUsize,
    pub decision_tile_calls: AtomicUsize,
}

/// Compiled-once PJRT executables keyed by feature dimension.
pub struct PjrtRuntime {
    // PJRT handles are not Sync; all execution goes through this mutex.
    // Tile execution is milliseconds-scale, callers batch work per call.
    inner: Mutex<Inner>,
    /// Feature dims with a compiled kernel-tile artifact.
    kernel_dims: Vec<usize>,
    /// Feature dims with a compiled decision-tile artifact.
    decision_dims: Vec<usize>,
    pub stats: RuntimeStats,
}

struct Inner {
    _client: xla::PjRtClient,
    kernel_tiles: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decision_tiles: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

// SAFETY: `Inner` is not auto-Send because the FFI handle types wrap
// raw pointers into the PJRT C API. Moving it across threads is sound:
// PJRT CPU clients/executables have no thread-affine state (the C API
// permits use from any thread under external synchronization), and every
// access after construction goes through `PjrtRuntime::inner: Mutex`,
// which serializes and orders all handle use. Deliberately NOT `Sync`.
unsafe impl Send for Inner {}

impl PjrtRuntime {
    /// Default artifact directory: $HSS_SVM_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("HSS_SVM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load and compile every artifact listed in `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("no artifact manifest at {} (run `make artifacts`)", manifest.display()))?;

        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut kernel_tiles = BTreeMap::new();
        let mut decision_tiles = BTreeMap::new();

        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_ascii_whitespace();
            let name = parts.next().unwrap();
            let mut kind = "";
            let mut f = 0usize;
            for kv in parts {
                if let Some((k, v)) = kv.split_once('=') {
                    match k {
                        "kind" => kind = if v == "kernel_tile" { "k" } else { "d" },
                        "f" => f = v.parse().context("bad f in manifest")?,
                        _ => {}
                    }
                }
            }
            if f == 0 || kind.is_empty() {
                bail!("malformed manifest line: {line}");
            }
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            if kind == "k" {
                kernel_tiles.insert(f, exe);
            } else {
                decision_tiles.insert(f, exe);
            }
        }
        if kernel_tiles.is_empty() && decision_tiles.is_empty() {
            bail!("manifest {} lists no artifacts", manifest.display());
        }
        let kernel_dims: Vec<usize> = kernel_tiles.keys().copied().collect();
        let decision_dims: Vec<usize> = decision_tiles.keys().copied().collect();
        Ok(PjrtRuntime {
            inner: Mutex::new(Inner { _client: client, kernel_tiles, decision_tiles }),
            kernel_dims,
            decision_dims,
            stats: RuntimeStats::default(),
        })
    }

    /// Try loading from the default dir; `None` when artifacts absent.
    pub fn try_default() -> Option<Self> {
        Self::load(Self::default_dir()).ok()
    }

    /// Smallest compiled feature dim ≥ `f` (zero-padding is exact).
    fn pick_dim(dims: &[usize], f: usize) -> Result<usize> {
        dims.iter()
            .copied()
            .find(|&d| d >= f)
            .ok_or_else(|| anyhow!("feature dim {f} exceeds all compiled artifacts {dims:?}"))
    }

    /// K(x, y) tile: x (m ≤ 128, f), y (n ≤ 128, f) → (m, n).
    /// Rows beyond m/n are zero-padded and sliced away.
    pub fn kernel_tile(&self, x: &Mat, y: &Mat, gamma: f64) -> Result<Mat> {
        assert_eq!(x.cols(), y.cols());
        let (m, n) = (x.rows(), y.rows());
        assert!(m <= TILE_M && n <= TILE_N, "tile too large: {m}x{n}");
        let fdim = Self::pick_dim(&self.kernel_dims, x.cols())?;
        let xl = mat_to_literal(x, TILE_M, fdim)?;
        let yl = mat_to_literal(y, TILE_N, fdim)?;
        let gl = xla::Literal::scalar(gamma as f32);
        // ORDERING: Relaxed — pure observability counter.
        self.stats.kernel_tile_calls.fetch_add(1, Ordering::Relaxed);

        let inner = self.inner.lock().unwrap();
        let exe = inner.kernel_tiles.get(&fdim).unwrap();
        let result = exe
            .execute::<xla::Literal>(&[xl, yl, gl])
            .map_err(|e| anyhow!("kernel_tile execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("kernel_tile fetch: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("kernel_tile tuple: {e:?}"))?;
        let vals: Vec<f32> = out.to_vec().map_err(|e| anyhow!("kernel_tile vec: {e:?}"))?;
        debug_assert_eq!(vals.len(), TILE_M * TILE_N);
        let mut k = Mat::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                k[(i, j)] = vals[i * TILE_N + j] as f64;
            }
        }
        Ok(k)
    }

    /// Fused decision tile: f(x) = Σ_chunks K(x, sv_chunk) @ αy_chunk.
    /// x (t ≤ 128, f), sv (s, f) any s. Bias NOT added here.
    pub fn decision_tile(&self, x: &Mat, sv: &Mat, alpha_y: &[f64], gamma: f64) -> Result<Vec<f64>> {
        assert_eq!(x.cols(), sv.cols());
        assert_eq!(sv.rows(), alpha_y.len());
        let t = x.rows();
        assert!(t <= TILE_M, "tile too large: {t}");
        let fdim = Self::pick_dim(&self.decision_dims, x.cols())?;
        let xl = mat_to_literal(x, TILE_M, fdim)?;
        let gl = xla::Literal::scalar(gamma as f32);

        let mut acc = vec![0.0f64; t];
        let s = sv.rows();
        let mut c0 = 0;
        while c0 < s {
            let cb = SV_CHUNK.min(s - c0);
            let rows: Vec<usize> = (c0..c0 + cb).collect();
            let svb = sv.select_rows(&rows);
            let svl = mat_to_literal(&svb, SV_CHUNK, fdim)?;
            let mut av = vec![0.0f32; SV_CHUNK];
            for (k, &r) in rows.iter().enumerate() {
                av[k] = alpha_y[r] as f32;
            }
            let al = xla::Literal::vec1(&av);
            // ORDERING: Relaxed — pure observability counter.
            self.stats.decision_tile_calls.fetch_add(1, Ordering::Relaxed);

            let inner = self.inner.lock().unwrap();
            let exe = inner.decision_tiles.get(&fdim).unwrap();
            let result = exe
                .execute::<xla::Literal>(&[
                    xl.reshape(&[TILE_M as i64, fdim as i64])
                        .map_err(|e| anyhow!("reshape: {e:?}"))?,
                    svl,
                    al,
                    gl.reshape(&[]).map_err(|e| anyhow!("reshape g: {e:?}"))?,
                ])
                .map_err(|e| anyhow!("decision_tile execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("decision_tile fetch: {e:?}"))?;
            let out = result.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
            let vals: Vec<f32> = out.to_vec().map_err(|e| anyhow!("vec: {e:?}"))?;
            for i in 0..t {
                acc[i] += vals[i] as f64;
            }
            c0 += cb;
        }
        Ok(acc)
    }

    /// Feature dims available per artifact kind (diagnostics).
    pub fn dims(&self) -> (Vec<usize>, Vec<usize>) {
        (self.kernel_dims.clone(), self.decision_dims.clone())
    }
}

/// Pack a Mat (f64) into a zero-padded (rows_pad × cols_pad) f32 literal.
fn mat_to_literal(m: &Mat, rows_pad: usize, cols_pad: usize) -> Result<xla::Literal> {
    assert!(m.rows() <= rows_pad && m.cols() <= cols_pad);
    let mut buf = vec![0.0f32; rows_pad * cols_pad];
    for i in 0..m.rows() {
        let src = m.row(i);
        let dst = &mut buf[i * cols_pad..i * cols_pad + src.len()];
        for (d, &v) in dst.iter_mut().zip(src) {
            *d = v as f32;
        }
    }
    xla::Literal::vec1(&buf)
        .reshape(&[rows_pad as i64, cols_pad as i64])
        .map_err(|e| anyhow!("literal reshape: {e:?}"))
}
