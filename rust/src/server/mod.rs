//! Concurrent TCP prediction server.
//!
//! Architecture (DESIGN.md §8): one accept loop, one reader thread and
//! one writer thread per connection, one shared cross-connection
//! micro-batcher ([`batcher`]), a hot-swappable model registry
//! ([`registry`]) and lock-free counters ([`stats`]). Readers classify
//! lines ([`protocol`]): admin commands are answered synchronously,
//! request lines pin the connection's current model snapshot and enter
//! the bounded batch queue (or are answered with an overload error —
//! backpressure never blocks the socket). Every response carries the
//! reader-assigned sequence number and the writer emits strictly in
//! sequence, so each connection sees exactly one response per input
//! line, in input order, no matter how tiles interleaved connections.
//!
//! Predictions are bitwise-identical to the offline `predict`
//! subcommand on the same lines: tiles go through the same
//! `serve::parse_batch` → `serve::predict_lines` pipeline — generic
//! over `svm::AnyModel`, so binary decision tiles and one-vs-one
//! shared-SV tiles serve identically — and per-row results are
//! independent of tile composition (the `blas::gemm` invariant, and
//! the OvO engine's per-row gathers).
//!
//! Graceful shutdown (`SHUTDOWN` admin command or
//! [`ServerHandle::shutdown`]): stop accepting, half-close every client
//! socket for reading, let readers finish, drain the batcher (queued
//! requests are still answered), then join everything.

// The server coordinates purely through channels, locks and atomics —
// it has no business forming raw pointers.
#![forbid(unsafe_code)]

pub mod batcher;
pub mod protocol;
pub mod registry;
pub mod stats;

pub use registry::{LoadedModel, ModelRegistry};
pub use stats::ServerStats;

use crate::serve;
use anyhow::{Context, Result};
use batcher::{Batcher, Request};
use protocol::Admin;
use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Tunables of the serving loop (CLI flags map 1:1).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Lines per prediction tile (default: [`serve::BATCH`]).
    pub batch_max: usize,
    /// How long the oldest queued request may wait for its tile to fill.
    pub batch_wait: Duration,
    /// Bounded queue size; beyond it lines get an overload error.
    pub max_inflight: usize,
    /// Worker threads for the decision-function tiles.
    pub threads: usize,
    /// Minimum interval between model-file staleness polls.
    pub poll_interval: Duration,
    /// Per-connection write timeout (a client that stops reading cannot
    /// stall shutdown forever).
    pub write_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch_max: serve::BATCH,
            batch_wait: Duration::from_millis(2),
            max_inflight: 1024,
            threads: 1,
            poll_interval: Duration::from_millis(200),
            write_timeout: Duration::from_secs(10),
        }
    }
}

struct Shared {
    registry: ModelRegistry,
    stats: ServerStats,
    batcher: Batcher,
    cfg: ServerConfig,
    shutdown: AtomicBool,
    addr: SocketAddr,
    /// Loopback-reachable form of `addr` (a `0.0.0.0`/`::` bind is not
    /// self-connectable on every platform) — the shutdown wake-up target.
    wake_addr: SocketAddr,
    /// Read-half clones of the live sockets, keyed by connection id so
    /// finished connections reap their entry (no fd growth under
    /// connection churn); the rest are half-closed on shutdown to
    /// unblock their reader threads.
    conns: Mutex<HashMap<u64, TcpStream>>,
}

/// Set the shutdown flag and poke the accept loop awake.
fn trigger_shutdown(shared: &Shared) {
    if !shared.shutdown.swap(true, Ordering::SeqCst) {
        let _ = TcpStream::connect(shared.wake_addr);
    }
}

/// A bound, not-yet-running server. `bind` → `handle` → `run`.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
}

/// Clonable remote control for a running [`Server`].
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// Initiate graceful shutdown (idempotent, non-blocking).
    pub fn shutdown(&self) {
        trigger_shutdown(&self.shared);
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// One-line counter summary (the `STATS` payload).
    pub fn stats_line(&self) -> String {
        self.shared.stats.stats_line()
    }

    /// Prometheus text exposition (the `METRICS` payload).
    pub fn metrics(&self) -> String {
        self.shared.stats.render_prometheus(&self.shared.registry.names())
    }

    /// Human exit banner.
    pub fn summary(&self) -> String {
        self.shared.stats.summary()
    }
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:7878"`; port 0 picks a free port).
    pub fn bind(addr: &str, registry: ModelRegistry, cfg: ServerConfig) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("cannot listen on {addr}"))?;
        let local = listener.local_addr().context("local_addr")?;
        let wake_ip = match local.ip() {
            IpAddr::V4(ip) if ip.is_unspecified() => IpAddr::V4(Ipv4Addr::LOCALHOST),
            IpAddr::V6(ip) if ip.is_unspecified() => IpAddr::V6(Ipv6Addr::LOCALHOST),
            ip => ip,
        };
        let shared = Arc::new(Shared {
            batcher: Batcher::new(cfg.batch_max, cfg.batch_wait, cfg.max_inflight),
            registry,
            stats: ServerStats::new(),
            cfg,
            shutdown: AtomicBool::new(false),
            addr: local,
            wake_addr: SocketAddr::new(wake_ip, local.port()),
            conns: Mutex::new(HashMap::new()),
        });
        Ok(Server { listener, shared })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: Arc::clone(&self.shared) }
    }

    /// Serve until shutdown; returns after every connection, the
    /// batcher and all queued work have drained.
    pub fn run(self) -> Result<()> {
        let shared = self.shared;
        let b = Arc::clone(&shared);
        let batcher_jh = std::thread::Builder::new()
            .name("hss-serve-batcher".into())
            .spawn(move || {
                b.batcher.run(&b.registry, &b.stats, b.cfg.threads, b.cfg.poll_interval)
            })
            .context("spawn batcher thread")?;

        let mut conn_jhs: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut conn_id = 0u64;
        loop {
            let stream = match self.listener.accept() {
                Ok((s, _)) => s,
                Err(_) if shared.shutdown.load(Ordering::SeqCst) => break,
                Err(_) => {
                    // transient (or fd-exhaustion) failure: back off
                    // instead of busy-spinning the accept loop
                    std::thread::sleep(Duration::from_millis(10));
                    continue;
                }
            };
            if shared.shutdown.load(Ordering::SeqCst) {
                break; // the shutdown wake-up connection (or a loser of the race)
            }
            // reap finished connection threads so churn does not grow
            // the handle list for the server's lifetime
            conn_jhs.retain(|jh| !jh.is_finished());
            conn_id += 1;
            let _ = stream.set_nodelay(true);
            let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
            match stream.try_clone() {
                Ok(clone) => shared.conns.lock().unwrap().insert(conn_id, clone),
                // a connection we cannot register cannot be half-closed
                // at shutdown — serving it anyway could hang the drain
                // on its reader thread, so refuse it instead
                Err(_) => continue,
            };
            let sh = Arc::clone(&shared);
            let id = conn_id;
            conn_jhs.push(
                std::thread::Builder::new()
                    .name(format!("hss-serve-conn-{id}"))
                    .spawn(move || handle_conn(id, stream, &sh))
                    .context("spawn connection thread")?,
            );
        }
        drop(self.listener);

        // Drain: half-close every live socket for reading so reader
        // threads see EOF; their queued requests are still flushed by
        // the batcher (which keeps running until told to drain), and
        // each reader joins its writer after the responses went out.
        for c in shared.conns.lock().unwrap().values() {
            let _ = c.shutdown(Shutdown::Read);
        }
        for jh in conn_jhs {
            let _ = jh.join();
        }
        shared.batcher.shutdown();
        let _ = batcher_jh.join();
        Ok(())
    }
}

/// Per-connection reader: classify lines, answer admin synchronously,
/// enqueue requests, and keep the response writer fed.
fn handle_conn(conn: u64, stream: TcpStream, shared: &Shared) {
    ServerStats::bump(&shared.stats.connections);
    ServerStats::bump(&shared.stats.active);
    let reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => {
            shared.conns.lock().unwrap().remove(&conn);
            ServerStats::dec(&shared.stats.active);
            return;
        }
    };
    let (tx, rx) = mpsc::channel::<(u64, String)>();
    let writer_jh = std::thread::Builder::new()
        .name(format!("hss-serve-write-{conn}"))
        .spawn(move || writer_loop(stream, rx));

    let mut cur_model = shared.registry.default_name().to_string();
    let mut seq = 0u64;
    let mut lineno = 0usize;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        lineno += 1;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            ServerStats::bump(&shared.stats.skipped);
            continue;
        }
        match protocol::parse_admin(t) {
            Some(cmd) => {
                ServerStats::bump(&shared.stats.admin);
                let (resp, close) = match cmd {
                    Err(usage) => (usage, false),
                    Ok(a) => run_admin(a, &mut cur_model, shared),
                };
                let _ = tx.send((seq, resp));
                seq += 1;
                if close {
                    break;
                }
            }
            None => {
                ServerStats::bump(&shared.stats.lines);
                let Some(model) = shared.registry.get(&cur_model) else {
                    // unreachable: names are fixed and MODEL validates
                    let _ = tx
                        .send((seq, format!("ERR line {lineno}: model {cur_model:?} is gone")));
                    seq += 1;
                    continue;
                };
                let req = Request {
                    conn,
                    seq,
                    lineno,
                    text: line,
                    model,
                    enqueued: Instant::now(),
                    tx: tx.clone(),
                };
                seq += 1;
                let model_name = req.model.name.clone();
                match shared.batcher.try_push(req) {
                    Ok(()) => {
                        ServerStats::bump(&shared.stats.queue_depth);
                        shared.stats.bump_model(&model_name);
                    }
                    Err(req) => {
                        ServerStats::bump(&shared.stats.rejected);
                        let _ = req.tx.send((
                            req.seq,
                            format!(
                                "ERR line {}: server overloaded ({} requests in flight), \
                                 line dropped",
                                req.lineno, shared.cfg.max_inflight
                            ),
                        ));
                    }
                }
            }
        }
    }
    // EOF (or QUIT/SHUTDOWN): the writer exits once every response —
    // including those of still-queued requests — has been routed.
    drop(tx);
    if let Ok(jh) = writer_jh {
        let _ = jh.join();
    }
    // reap this connection's read-half clone (fd) from the shutdown set
    shared.conns.lock().unwrap().remove(&conn);
    ServerStats::dec(&shared.stats.active);
}

fn run_admin(cmd: Admin, cur_model: &mut String, shared: &Shared) -> (String, bool) {
    match cmd {
        Admin::Model(name) => match shared.registry.get(&name) {
            Some(m) => {
                *cur_model = name;
                (format!("OK model {} gen {}", m.name, m.generation), false)
            }
            None => (format!("ERR unknown model {name:?}"), false),
        },
        Admin::Reload(None) => {
            let (swapped, failed) = shared.registry.reload_all();
            ServerStats::add(&shared.stats.reloads, swapped.len() as u64);
            let resp = if !failed.is_empty() {
                let errs: Vec<String> =
                    failed.iter().map(|(n, e)| format!("{n}: {e}")).collect();
                if swapped.is_empty() {
                    format!("ERR reload failed ({})", errs.join("; "))
                } else {
                    // partial swaps already happened — say so
                    format!(
                        "ERR reload partial (reloaded {}; failed {})",
                        swapped.join(","),
                        errs.join("; ")
                    )
                }
            } else if swapped.is_empty() {
                "ERR reload: no file-backed models".to_string()
            } else {
                format!("OK reloaded {}", swapped.join(","))
            };
            (resp, false)
        }
        Admin::Reload(Some(name)) => match shared.registry.reload(&name) {
            Ok(generation) => {
                ServerStats::bump(&shared.stats.reloads);
                (format!("OK reloaded {name} gen {generation}"), false)
            }
            Err(e) => (format!("ERR reload {name}: {e:#}"), false),
        },
        Admin::Stats => (shared.stats.stats_line(), false),
        // Multi-line response: the writer emits it as one sequenced
        // chunk, ending with the `# EOF` line clients read until.
        Admin::Metrics => {
            (shared.stats.render_prometheus(&shared.registry.names()), false)
        }
        Admin::Shutdown => {
            trigger_shutdown(shared);
            ("OK shutting down".to_string(), true)
        }
        Admin::Quit => ("OK bye".to_string(), true),
    }
}

/// Per-connection writer: responses arrive tagged with the reader's
/// sequence number (from the reader itself and from batcher flushes, in
/// any interleaving) and leave the socket strictly in sequence.
fn writer_loop(stream: TcpStream, rx: mpsc::Receiver<(u64, String)>) {
    let mut w = BufWriter::new(stream);
    let mut pending: BTreeMap<u64, String> = BTreeMap::new();
    let mut next = 0u64;
    'recv: while let Ok((seq, line)) = rx.recv() {
        pending.insert(seq, line);
        while let Ok((seq, line)) = rx.try_recv() {
            pending.insert(seq, line);
        }
        while let Some(line) = pending.remove(&next) {
            if writeln!(w, "{line}").is_err() {
                break 'recv;
            }
            next += 1;
        }
        if w.flush().is_err() {
            break;
        }
    }
    // channel closed: whatever is pending is contiguous — flush it
    while let Some(line) = pending.remove(&next) {
        if writeln!(w, "{line}").is_err() {
            break;
        }
        next += 1;
    }
    let _ = w.flush();
}
