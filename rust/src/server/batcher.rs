//! Cross-connection micro-batcher.
//!
//! Requests from every connection land in one bounded FIFO; a single
//! batcher thread pops the longest front run that shares a model
//! snapshot (never mixing models inside a tile) and flushes it when it
//! reaches `batch_max` lines, when the oldest request has waited
//! `batch_wait`, or when a different-model request is queued right
//! behind it (waiting could not grow the run). The tile goes through
//! the same [`serve::parse_batch`] / [`serve::predict_lines`] core as
//! the stdin loop — generic over model arity, so binary decision tiles
//! and one-vs-one shared-SV tiles batch identically on the shared
//! `util::threadpool` workers — and responses are routed back to each
//! request's connection through its `(seq, line)` channel; the
//! per-connection writer restores input order.
//!
//! Error semantics are per **issuer**: a malformed line fails every
//! line of *its* connection in the tile (mirroring the stdin mode's
//! whole-batch drop), while other connections' lines are re-batched and
//! predicted normally. Backpressure is the bounded queue: when
//! `max_inflight` requests are already queued, `try_push` hands the
//! request back and the reader answers that line with an overload
//! error instead of blocking the socket.

use crate::serve;
use crate::server::registry::{LoadedModel, ModelRegistry};
use crate::server::stats::ServerStats;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How often an idle batcher wakes up to poll model staleness.
const IDLE_TICK: Duration = Duration::from_millis(200);

/// One enqueued prediction request.
pub struct Request {
    /// Connection id (issuer of the line).
    pub conn: u64,
    /// Per-connection response sequence number (writer restores order).
    pub seq: u64,
    /// Per-connection 1-based input line number (error reporting).
    pub lineno: usize,
    /// The raw request line.
    pub text: String,
    /// Model snapshot pinned at enqueue time: a hot-swap after this
    /// point does not affect this request.
    pub model: Arc<LoadedModel>,
    pub enqueued: Instant,
    /// Response channel of the issuing connection.
    pub tx: Sender<(u64, String)>,
}

pub struct Batcher {
    queue: Mutex<VecDeque<Request>>,
    ready: Condvar,
    batch_max: usize,
    batch_wait: Duration,
    max_inflight: usize,
    draining: AtomicBool,
}

impl Batcher {
    pub fn new(batch_max: usize, batch_wait: Duration, max_inflight: usize) -> Batcher {
        Batcher {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            batch_max: batch_max.max(1),
            batch_wait,
            max_inflight: max_inflight.max(1),
            draining: AtomicBool::new(false),
        }
    }

    /// Enqueue a request, or hand it back when the queue is full
    /// (backpressure) or the server is draining.
    pub fn try_push(&self, req: Request) -> Result<(), Request> {
        let mut q = self.queue.lock().unwrap();
        // Acquire pairs with the Release in `shutdown`: the drain flag is
        // a state transition, not a counter, and rejecting readers must
        // happen-after whatever shutdown published before flipping it.
        if q.len() >= self.max_inflight || self.draining.load(Ordering::Acquire) {
            return Err(req);
        }
        q.push_back(req);
        drop(q);
        self.ready.notify_one();
        Ok(())
    }

    /// Queued (not yet flushed) requests.
    pub fn depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Begin draining: no new requests are accepted, `run` flushes what
    /// is queued and returns.
    pub fn shutdown(&self) {
        // Release: publishes the caller's pre-shutdown writes to every
        // thread that observes the flag with Acquire (try_push rejections
        // and the batcher's final drain both consume this transition).
        self.draining.store(true, Ordering::Release);
        self.ready.notify_all();
    }

    /// Length of the front run sharing one model snapshot, capped.
    fn prefix_run(q: &VecDeque<Request>, cap: usize) -> usize {
        let first = &q[0].model;
        q.iter().take(cap).take_while(|r| Arc::ptr_eq(&r.model, first)).count()
    }

    /// Block until a tile is ready (or an idle tick passes — the caller
    /// uses those to poll model staleness). `None` means drained and
    /// shut down. The second element names why the tile flushed
    /// ("full" / "model-switch" / "deadline" / "drain" / "idle") —
    /// reporting only, it feeds the `server_batch` trace event.
    fn next_batch(&self) -> Option<(Vec<Request>, &'static str)> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if !q.is_empty() {
                let deadline = q[0].enqueued + self.batch_wait;
                let run = Self::prefix_run(&q, self.batch_max);
                let now = Instant::now();
                // Acquire pairs with shutdown's Release store.
                let reason = if run >= self.batch_max {
                    "full"
                } else if run < q.len() {
                    "model-switch"
                } else if now >= deadline {
                    "deadline"
                } else if self.draining.load(Ordering::Acquire) {
                    "drain"
                } else {
                    let (guard, _) = self.ready.wait_timeout(q, deadline - now).unwrap();
                    q = guard;
                    continue;
                };
                return Some((q.drain(..run).collect(), reason));
            } else {
                // Acquire pairs with shutdown's Release store: an empty
                // queue plus an observed drain flag means every accepted
                // request was already flushed.
                if self.draining.load(Ordering::Acquire) {
                    return None;
                }
                let (guard, timeout) = self.ready.wait_timeout(q, IDLE_TICK).unwrap();
                q = guard;
                if timeout.timed_out() && q.is_empty() {
                    return Some((Vec::new(), "idle")); // idle tick
                }
            }
        }
    }

    /// Batcher thread body: flush tiles until shut down and drained.
    pub fn run(
        &self,
        registry: &ModelRegistry,
        stats: &ServerStats,
        threads: usize,
        poll_interval: Duration,
    ) {
        while let Some((batch, reason)) = self.next_batch() {
            let swapped = registry.poll_stale(poll_interval);
            if swapped > 0 {
                ServerStats::add(&stats.reloads, swapped as u64);
            }
            if !batch.is_empty() {
                ServerStats::sub(&stats.queue_depth, batch.len() as u64);
                ServerStats::add(&stats.inflight, batch.len() as u64);
                Self::process(&batch, registry.backend(), stats, threads);
                ServerStats::sub(&stats.inflight, batch.len() as u64);
                if crate::obs::enabled() {
                    crate::obs::emit(&crate::obs::TraceEvent::ServerBatch {
                        size: batch.len(),
                        model: batch[0].model.name.clone(),
                        generation: batch[0].model.generation,
                        reason: reason.to_string(),
                        queue_depth: self.depth(),
                    });
                }
            }
        }
    }

    /// Flush one tile (all requests share `batch[0]`'s model snapshot).
    fn process(
        batch: &[Request],
        backend: &dyn crate::compute::ComputeBackend,
        stats: &ServerStats,
        threads: usize,
    ) {
        ServerStats::bump(&stats.batches);
        let model = &batch[0].model.model;
        let refs: Vec<(usize, &str)> = batch.iter().map(|r| (r.lineno, r.text.as_str())).collect();
        match serve::parse_batch(&refs, model.dim(), model.is_sparse()) {
            Ok(x) => {
                let all: Vec<&Request> = batch.iter().collect();
                Self::respond(&all, &x, backend, stats, threads);
            }
            Err(bad) => {
                // per-issuer failure: malformed lines answer with their
                // parse error, their connection's other lines in this
                // tile are dropped (stdin-mode whole-batch semantics,
                // scoped to the issuer), everyone else proceeds
                let mut bad_by_idx: BTreeMap<usize, &str> =
                    bad.iter().map(|(i, m)| (*i, m.as_str())).collect();
                let poisoned: BTreeSet<u64> = bad.iter().map(|(i, _)| batch[*i].conn).collect();
                let mut keep: Vec<&Request> = Vec::new();
                for (i, r) in batch.iter().enumerate() {
                    if let Some(msg) = bad_by_idx.remove(&i) {
                        ServerStats::bump(&stats.failed_lines);
                        let _ = r.tx.send((r.seq, format!("ERR {msg}")));
                    } else if poisoned.contains(&r.conn) {
                        ServerStats::bump(&stats.dropped_lines);
                        let _ = r.tx.send((
                            r.seq,
                            format!(
                                "ERR line {}: dropped (malformed line in this batch \
                                 from this connection)",
                                r.lineno
                            ),
                        ));
                    } else {
                        keep.push(r);
                    }
                }
                if keep.is_empty() {
                    return;
                }
                let refs: Vec<(usize, &str)> =
                    keep.iter().map(|r| (r.lineno, r.text.as_str())).collect();
                match serve::parse_batch(&refs, model.dim(), model.is_sparse()) {
                    Ok(x) => Self::respond(&keep, &x, backend, stats, threads),
                    Err(_) => {
                        // unreachable: every kept line parsed alone above
                        for r in keep {
                            let _ = r.tx.send((
                                r.seq,
                                format!("ERR line {}: internal batch parse failure", r.lineno),
                            ));
                        }
                    }
                }
            }
        }
    }

    fn respond(
        reqs: &[&Request],
        x: &crate::data::Points,
        backend: &dyn crate::compute::ComputeBackend,
        stats: &ServerStats,
        threads: usize,
    ) {
        // on the default CPU backend this is the exact offline path:
        // bitwise-identical to `cmd_predict` on the same lines regardless
        // of how connections were interleaved (per-row independence
        // contract of `blas::gemm`, and of the shared-SV engine's
        // per-row gathers for OvO models)
        let model = &reqs[0].model.model;
        let lines = serve::predict_lines(model, Some(backend), x, threads);
        debug_assert_eq!(lines.len(), reqs.len());
        let now = Instant::now();
        for (r, line) in reqs.iter().zip(lines) {
            let _ = r.tx.send((r.seq, line));
            stats.latency.record(now.duration_since(r.enqueued));
        }
        ServerStats::add(&stats.predicted, reqs.len() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DEFAULT_LABEL_PAIR;
    use crate::kernel::Kernel;
    use crate::linalg::Mat;
    use crate::svm::SvmModel;
    use crate::util::prng::Rng;
    use std::sync::mpsc;

    fn loaded(rng: &mut Rng) -> Arc<LoadedModel> {
        Arc::new(LoadedModel {
            name: "t".into(),
            generation: 1,
            model: SvmModel {
                sv: Mat::gauss(3, 4, rng).into(),
                alpha_y: (0..3).map(|_| rng.gauss()).collect(),
                bias: rng.gauss(),
                kernel: Kernel::Gaussian { h: 1.0 },
                c: 1.0,
                labels: DEFAULT_LABEL_PAIR,
            }
            .into(),
        })
    }

    fn req(conn: u64, seq: u64, model: &Arc<LoadedModel>, tx: &Sender<(u64, String)>) -> Request {
        Request {
            conn,
            seq,
            lineno: seq as usize + 1,
            text: format!("1:{}", seq as f64 * 0.5),
            model: Arc::clone(model),
            enqueued: Instant::now(),
            tx: tx.clone(),
        }
    }

    #[test]
    fn tiles_never_mix_model_snapshots() {
        let mut rng = Rng::new(41);
        let (m1, m2) = (loaded(&mut rng), loaded(&mut rng));
        let (tx, _rx) = mpsc::channel();
        let b = Batcher::new(8, Duration::from_secs(10), 64);
        for (i, m) in [&m1, &m1, &m2, &m2, &m2, &m1].into_iter().enumerate() {
            assert!(b.try_push(req(1, i as u64, m, &tx)).is_ok());
        }
        // deadline far away, but model switches force immediate flushes
        let (t1, why1) = b.next_batch().unwrap();
        assert_eq!(t1.len(), 2);
        assert_eq!(why1, "model-switch");
        assert!(t1.iter().all(|r| Arc::ptr_eq(&r.model, &m1)));
        let (t2, why2) = b.next_batch().unwrap();
        assert_eq!(t2.len(), 3);
        assert_eq!(why2, "model-switch");
        assert!(t2.iter().all(|r| Arc::ptr_eq(&r.model, &m2)));
        // FIFO order is preserved across flushes
        assert_eq!(t1[0].seq, 0);
        assert_eq!(t2[0].seq, 2);
    }

    #[test]
    fn full_queue_hands_the_request_back_and_deadline_flushes() {
        let mut rng = Rng::new(42);
        let m = loaded(&mut rng);
        let (tx, _rx) = mpsc::channel();
        let b = Batcher::new(128, Duration::from_millis(10), 2);
        assert!(b.try_push(req(1, 0, &m, &tx)).is_ok());
        assert!(b.try_push(req(1, 1, &m, &tx)).is_ok());
        let back = b.try_push(req(1, 2, &m, &tx));
        assert_eq!(back.unwrap_err().seq, 2, "backpressure returns the request");
        assert_eq!(b.depth(), 2);
        // under batch_max, flushed once the oldest request ages out
        let t = Instant::now();
        let (tile, why) = b.next_batch().unwrap();
        assert_eq!(tile.len(), 2);
        assert!(why == "deadline" || why == "drain", "unexpected flush reason {why}");
        assert!(t.elapsed() <= Duration::from_secs(5));
        // draining: rejects new pushes, then reports done
        b.shutdown();
        assert!(b.try_push(req(1, 3, &m, &tx)).is_err());
        assert!(b.next_batch().is_none());
    }

    /// TSan-exercised drain race: concurrent producers push while a
    /// consumer pops tiles and `shutdown` fires mid-stream. Every request
    /// the queue *accepted* must come back out of `next_batch` exactly
    /// once (no tile lost to the Release/Acquire drain handoff), and the
    /// queue must be empty once `next_batch` reports drained.
    #[test]
    fn shutdown_drains_queued_requests_under_load() {
        let mut rng = Rng::new(43);
        let m = loaded(&mut rng);
        let (tx, _rx) = mpsc::channel();
        let b = Arc::new(Batcher::new(4, Duration::from_millis(1), 1024));
        let producers: usize = 4;
        let per_producer: u64 = if cfg!(miri) { 8 } else { 50 };
        let accepted = std::thread::scope(|scope| {
            let consumer = {
                let b = Arc::clone(&b);
                scope.spawn(move || {
                    let mut popped = 0u64;
                    while let Some((tile, _why)) = b.next_batch() {
                        popped += tile.len() as u64;
                    }
                    popped
                })
            };
            let mut handles = Vec::new();
            for p in 0..producers {
                let b = Arc::clone(&b);
                let tx = tx.clone();
                let m = Arc::clone(&m);
                handles.push(scope.spawn(move || {
                    let mut ok = 0u64;
                    for s in 0..per_producer {
                        if b.try_push(req(p as u64, s, &m, &tx)).is_ok() {
                            ok += 1;
                        }
                    }
                    ok
                }));
            }
            let accepted: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
            b.shutdown();
            let consumed = consumer.join().unwrap();
            assert_eq!(consumed, accepted, "accepted requests must all be flushed");
            accepted
        });
        assert!(accepted > 0, "the queue should have accepted some load");
        assert_eq!(b.depth(), 0, "drained batcher must leave an empty queue");
        // post-drain pushes are rejected
        assert!(b.try_push(req(9, 0, &m, &tx)).is_err());
    }
}
