//! Server-wide counters and latency percentiles.
//!
//! Everything is a relaxed atomic (or the lock-free
//! [`Histogram`] from `util::bench`), so connection readers, the
//! batcher and the `STATS` admin command never contend. Latency is
//! measured enqueue → response-routed, i.e. the queueing delay the
//! micro-batcher trades against tile efficiency, not socket time.

use crate::util::bench::Histogram;
use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Default)]
pub struct ServerStats {
    /// Connections accepted since startup.
    pub connections: AtomicU64,
    /// Currently open connections.
    pub active: AtomicU64,
    /// Request lines received (admin commands excluded).
    pub lines: AtomicU64,
    /// Blank / comment lines skipped.
    pub skipped: AtomicU64,
    /// Admin commands processed.
    pub admin: AtomicU64,
    /// Predictions emitted.
    pub predicted: AtomicU64,
    /// Prediction tiles flushed.
    pub batches: AtomicU64,
    /// Malformed request lines answered with an error.
    pub failed_lines: AtomicU64,
    /// Well-formed lines dropped because a line from the same
    /// connection poisoned their tile (per-issuer batch failure).
    pub dropped_lines: AtomicU64,
    /// Lines rejected by backpressure (queue full).
    pub rejected: AtomicU64,
    /// Model hot-swaps (RELOAD + mtime poll).
    pub reloads: AtomicU64,
    /// Enqueue → response latency of predicted lines.
    pub latency: Histogram,
}

impl ServerStats {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Decrement a gauge-style counter (e.g. `active` on disconnect).
    #[inline]
    pub fn dec(counter: &AtomicU64) {
        counter.fetch_sub(1, Ordering::Relaxed);
    }

    fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    /// The one-line `STATS` admin response.
    pub fn stats_line(&self, queue_depth: usize) -> String {
        format!(
            "OK stats connections={} active={} lines={} skipped={} admin={} \
             predicted={} batches={} failed={} dropped={} rejected={} reloads={} \
             queue={queue_depth} p50_us={:.0} p99_us={:.0} mean_us={:.0}",
            Self::get(&self.connections),
            Self::get(&self.active),
            Self::get(&self.lines),
            Self::get(&self.skipped),
            Self::get(&self.admin),
            Self::get(&self.predicted),
            Self::get(&self.batches),
            Self::get(&self.failed_lines),
            Self::get(&self.dropped_lines),
            Self::get(&self.rejected),
            Self::get(&self.reloads),
            self.latency.percentile_us(0.5),
            self.latency.percentile_us(0.99),
            self.latency.mean_us(),
        )
    }

    /// Shutdown banner (mirrors the stdin mode's exit line).
    pub fn summary(&self) -> String {
        format!(
            "served {} predictions in {} batches ({} lines, {} failed, {} dropped, \
             {} rejected) over {} connections",
            Self::get(&self.predicted),
            Self::get(&self.batches),
            Self::get(&self.lines),
            Self::get(&self.failed_lines),
            Self::get(&self.dropped_lines),
            Self::get(&self.rejected),
            Self::get(&self.connections),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn stats_line_contains_all_counters() {
        let s = ServerStats::new();
        ServerStats::bump(&s.connections);
        ServerStats::add(&s.lines, 7);
        s.latency.record(Duration::from_micros(500));
        let line = s.stats_line(3);
        assert!(line.starts_with("OK stats "), "{line}");
        for key in [
            "connections=1",
            "lines=7",
            "queue=3",
            "p50_us=",
            "p99_us=",
            "mean_us=",
        ] {
            assert!(line.contains(key), "{line} missing {key}");
        }
        assert!(!line.contains('\n'));
        assert!(s.summary().contains("7 lines"));
    }
}
