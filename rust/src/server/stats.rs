//! Server-wide counters, gauges and latency percentiles.
//!
//! Everything hot is a relaxed atomic (or the lock-free
//! [`Histogram`] from `util::bench`), so connection readers, the
//! batcher and the `STATS` admin command never contend. Latency is
//! measured enqueue → response-routed, i.e. the queueing delay the
//! micro-batcher trades against tile efficiency, not socket time.
//! The per-model request counters sit behind a mutex: they are touched
//! once per enqueued line, and the map is tiny (one entry per model).
//!
//! `METRICS` renders all of it as Prometheus text exposition through
//! [`crate::obs::prom`] (naming conventions in DESIGN.md §14).

use crate::obs::prom::PromText;
use crate::util::bench::Histogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Default)]
pub struct ServerStats {
    /// Connections accepted since startup.
    pub connections: AtomicU64,
    /// Currently open connections.
    pub active: AtomicU64,
    /// Request lines received (admin commands excluded).
    pub lines: AtomicU64,
    /// Blank / comment lines skipped.
    pub skipped: AtomicU64,
    /// Admin commands processed.
    pub admin: AtomicU64,
    /// Predictions emitted.
    pub predicted: AtomicU64,
    /// Prediction tiles flushed.
    pub batches: AtomicU64,
    /// Malformed request lines answered with an error.
    pub failed_lines: AtomicU64,
    /// Well-formed lines dropped because a line from the same
    /// connection poisoned their tile (per-issuer batch failure).
    pub dropped_lines: AtomicU64,
    /// Lines rejected by backpressure (queue full).
    pub rejected: AtomicU64,
    /// Model hot-swaps (RELOAD + mtime poll).
    pub reloads: AtomicU64,
    /// Gauge: requests sitting in the batcher queue right now
    /// (incremented on successful enqueue, decremented when a tile is
    /// popped for processing).
    pub queue_depth: AtomicU64,
    /// Gauge: requests popped from the queue and being predicted.
    pub inflight: AtomicU64,
    /// Enqueue → response latency of predicted lines.
    pub latency: Histogram,
    /// Request lines enqueued per model (BTreeMap → stable render order).
    model_lines: Mutex<BTreeMap<String, u64>>,
}

impl ServerStats {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Decrement a gauge-style counter (e.g. `active` on disconnect).
    #[inline]
    pub fn dec(counter: &AtomicU64) {
        counter.fetch_sub(1, Ordering::Relaxed);
    }

    /// Subtract `n` from a gauge (e.g. `queue_depth` when a whole tile
    /// is popped).
    #[inline]
    pub fn sub(counter: &AtomicU64, n: u64) {
        counter.fetch_sub(n, Ordering::Relaxed);
    }

    fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    /// Count one enqueued request line against `model`.
    pub fn bump_model(&self, model: &str) {
        let mut g = self.model_lines.lock().unwrap_or_else(|e| e.into_inner());
        *g.entry(model.to_string()).or_insert(0) += 1;
    }

    /// Per-model request counts in name order.
    pub fn model_lines(&self) -> Vec<(String, u64)> {
        let g = self.model_lines.lock().unwrap_or_else(|e| e.into_inner());
        g.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// The one-line `STATS` admin response.
    pub fn stats_line(&self) -> String {
        format!(
            "OK stats connections={} active={} lines={} skipped={} admin={} \
             predicted={} batches={} failed={} dropped={} rejected={} reloads={} \
             queue={} inflight={} p50_us={:.0} p99_us={:.0} mean_us={:.0}",
            Self::get(&self.connections),
            Self::get(&self.active),
            Self::get(&self.lines),
            Self::get(&self.skipped),
            Self::get(&self.admin),
            Self::get(&self.predicted),
            Self::get(&self.batches),
            Self::get(&self.failed_lines),
            Self::get(&self.dropped_lines),
            Self::get(&self.rejected),
            Self::get(&self.reloads),
            Self::get(&self.queue_depth),
            Self::get(&self.inflight),
            self.latency.percentile_us(0.5),
            self.latency.percentile_us(0.99),
            self.latency.mean_us(),
        )
    }

    /// The `METRICS` admin response: the whole surface as Prometheus
    /// text exposition. `models` is the registry's `(name, generation)`
    /// snapshot; per-model request counters come from [`Self::model_lines`].
    pub fn render_prometheus(&self, models: &[(String, u64)]) -> String {
        let mut p = PromText::new();
        p.scalar(
            "hss_svm_connections_total",
            "counter",
            "Connections accepted since startup.",
            Self::get(&self.connections) as f64,
        );
        p.scalar(
            "hss_svm_connections_active",
            "gauge",
            "Currently open connections.",
            Self::get(&self.active) as f64,
        );
        p.scalar(
            "hss_svm_request_lines_total",
            "counter",
            "Request lines received (admin commands excluded).",
            Self::get(&self.lines) as f64,
        );
        p.scalar(
            "hss_svm_skipped_lines_total",
            "counter",
            "Blank or comment lines skipped.",
            Self::get(&self.skipped) as f64,
        );
        p.scalar(
            "hss_svm_admin_commands_total",
            "counter",
            "Admin commands processed.",
            Self::get(&self.admin) as f64,
        );
        p.scalar(
            "hss_svm_predictions_total",
            "counter",
            "Predictions emitted.",
            Self::get(&self.predicted) as f64,
        );
        p.scalar(
            "hss_svm_batches_total",
            "counter",
            "Prediction tiles flushed.",
            Self::get(&self.batches) as f64,
        );
        p.scalar(
            "hss_svm_failed_lines_total",
            "counter",
            "Malformed request lines answered with an error.",
            Self::get(&self.failed_lines) as f64,
        );
        p.scalar(
            "hss_svm_dropped_lines_total",
            "counter",
            "Lines dropped because a same-connection line poisoned their tile.",
            Self::get(&self.dropped_lines) as f64,
        );
        p.scalar(
            "hss_svm_rejected_lines_total",
            "counter",
            "Lines rejected by backpressure (queue full).",
            Self::get(&self.rejected) as f64,
        );
        p.scalar(
            "hss_svm_model_reloads_total",
            "counter",
            "Model hot-swaps (RELOAD + mtime poll).",
            Self::get(&self.reloads) as f64,
        );
        p.scalar(
            "hss_svm_queue_depth",
            "gauge",
            "Requests waiting in the batcher queue.",
            Self::get(&self.queue_depth) as f64,
        );
        p.scalar(
            "hss_svm_inflight",
            "gauge",
            "Requests being predicted right now.",
            Self::get(&self.inflight) as f64,
        );
        if !models.is_empty() {
            p.header(
                "hss_svm_model_generation",
                "gauge",
                "Registry generation of each loaded model.",
            );
            for (name, generation) in models {
                p.sample("hss_svm_model_generation", &[("model", name)], *generation as f64);
            }
        }
        let per_model = self.model_lines();
        if !per_model.is_empty() {
            p.header(
                "hss_svm_model_requests_total",
                "counter",
                "Request lines enqueued per model.",
            );
            for (name, count) in &per_model {
                p.sample("hss_svm_model_requests_total", &[("model", name)], *count as f64);
            }
        }
        // Histogram buckets are recorded in microseconds; Prometheus
        // base units are seconds.
        let buckets: Vec<(f64, u64)> = self
            .latency
            .cumulative_buckets()
            .into_iter()
            .map(|(ub_us, cum)| (ub_us / 1e6, cum))
            .collect();
        p.histogram(
            "hss_svm_request_latency_seconds",
            "Enqueue-to-response latency of predicted lines.",
            &buckets,
            self.latency.count(),
            self.latency.sum_us() as f64 / 1e6,
        );
        p.finish()
    }

    /// Shutdown banner (mirrors the stdin mode's exit line).
    pub fn summary(&self) -> String {
        format!(
            "served {} predictions in {} batches ({} lines, {} failed, {} dropped, \
             {} rejected) over {} connections",
            Self::get(&self.predicted),
            Self::get(&self.batches),
            Self::get(&self.lines),
            Self::get(&self.failed_lines),
            Self::get(&self.dropped_lines),
            Self::get(&self.rejected),
            Self::get(&self.connections),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn stats_line_contains_all_counters() {
        let s = ServerStats::new();
        ServerStats::bump(&s.connections);
        ServerStats::add(&s.lines, 7);
        ServerStats::add(&s.queue_depth, 3);
        ServerStats::bump(&s.inflight);
        s.latency.record(Duration::from_micros(500));
        let line = s.stats_line();
        assert!(line.starts_with("OK stats "), "{line}");
        for key in [
            "connections=1",
            "lines=7",
            "queue=3",
            "inflight=1",
            "p50_us=",
            "p99_us=",
            "mean_us=",
        ] {
            assert!(line.contains(key), "{line} missing {key}");
        }
        assert!(!line.contains('\n'));
        assert!(s.summary().contains("7 lines"));
    }

    #[test]
    fn prometheus_exposition_is_complete_and_cumulative() {
        let s = ServerStats::new();
        ServerStats::add(&s.lines, 5);
        ServerStats::add(&s.predicted, 4);
        ServerStats::add(&s.queue_depth, 2);
        s.bump_model("default");
        s.bump_model("default");
        s.bump_model("alt");
        s.latency.record(Duration::from_micros(10));
        s.latency.record(Duration::from_micros(100));
        s.latency.record(Duration::from_micros(100));
        s.latency.record(Duration::from_millis(5));
        let text =
            s.render_prometheus(&[("alt".to_string(), 2), ("default".to_string(), 1)]);
        assert!(text.ends_with("# EOF"), "terminator: {text:?}");
        for needle in [
            "# TYPE hss_svm_request_lines_total counter",
            "hss_svm_request_lines_total 5",
            "# TYPE hss_svm_queue_depth gauge",
            "hss_svm_queue_depth 2",
            "hss_svm_model_generation{model=\"alt\"} 2",
            "hss_svm_model_requests_total{model=\"default\"} 2",
            "hss_svm_model_requests_total{model=\"alt\"} 1",
            "# TYPE hss_svm_request_latency_seconds histogram",
            "hss_svm_request_latency_seconds_count 4",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // bucket lines must be cumulative and end at the total count
        let cums: Vec<f64> = text
            .lines()
            .filter(|l| l.starts_with("hss_svm_request_latency_seconds_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse::<f64>().unwrap())
            .collect();
        assert!(cums.len() >= 2, "expected bucket lines: {text}");
        assert!(cums.windows(2).all(|w| w[0] <= w[1]), "non-cumulative: {cums:?}");
        assert_eq!(*cums.last().unwrap(), 4.0, "+Inf bucket == count");
        // every sample value parses as a float (no stray text)
        for l in text.lines().filter(|l| !l.starts_with('#')) {
            let v = l.rsplit(' ').next().unwrap();
            assert!(v.parse::<f64>().is_ok(), "unparseable sample value {v:?} in {l:?}");
        }
    }
}
