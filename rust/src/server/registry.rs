//! Model registry: named models with atomic hot-swap.
//!
//! Each entry holds the current model behind an `RwLock<Arc<_>>`:
//! readers (connection threads snapshotting a model per request) take a
//! cheap read lock and clone the `Arc`; a reload builds the new
//! [`LoadedModel`] entirely outside the lock and swaps the `Arc` in one
//! write — in-flight batches keep their old `Arc` and finish on the old
//! model, new requests pick up the new generation. Staleness is driven
//! two ways: the `RELOAD` admin command (explicit) and an mtime/size
//! poll ([`ModelRegistry::poll_stale`]) the batcher runs between
//! flushes (implicit — overwrite the model file and the server picks it
//! up).

use crate::svm::{persist, AnyModel, SvmModel};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant, SystemTime};

/// One immutable model snapshot. Requests pin the snapshot they were
/// enqueued with, so a hot-swap never changes a model mid-batch.
pub struct LoadedModel {
    /// Registry name this snapshot was loaded under.
    pub name: String,
    /// Monotonic per-entry reload counter (1 = initial load).
    pub generation: u64,
    /// Binary or one-vs-one multiclass — the serving pipeline
    /// ([`crate::serve::parse_batch`] / [`crate::serve::predict_lines`])
    /// is generic over the arity.
    pub model: AnyModel,
}

/// On-disk identity of a loaded file; a change in either field marks
/// the entry stale (size guards against filesystems with coarse mtime).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
struct FileStamp {
    mtime: Option<SystemTime>,
    len: u64,
}

fn stamp(path: &std::path::Path) -> Option<FileStamp> {
    let meta = std::fs::metadata(path).ok()?;
    Some(FileStamp { mtime: meta.modified().ok(), len: meta.len() })
}

struct ModelEntry {
    /// Backing file; `None` for in-memory models (tests, benches) —
    /// those cannot be reloaded.
    path: Option<PathBuf>,
    stamp: Mutex<Option<FileStamp>>,
    generation: AtomicU64,
    current: RwLock<Arc<LoadedModel>>,
}

/// Named models, hot-swappable individually. The entry *set* is fixed
/// at startup (connections select with `MODEL <name>`); the models
/// behind the names are not.
pub struct ModelRegistry {
    entries: BTreeMap<String, ModelEntry>,
    default_name: String,
    last_poll: Mutex<Instant>,
    /// Compute backend the batcher predicts on (shared by every model;
    /// defaults to the bitwise CPU reference).
    backend: Arc<dyn crate::compute::ComputeBackend>,
}

impl ModelRegistry {
    /// Registry over model files; the first entry is the default model.
    pub fn from_paths(entries: &[(String, PathBuf)]) -> Result<ModelRegistry> {
        if entries.is_empty() {
            bail!("model registry needs at least one model");
        }
        let mut map = BTreeMap::new();
        for (name, path) in entries {
            let model = persist::load_any(path)
                .with_context(|| format!("loading model {name:?} from {}", path.display()))?;
            let loaded = Arc::new(LoadedModel { name: name.clone(), generation: 1, model });
            let prev = map.insert(
                name.clone(),
                ModelEntry {
                    path: Some(path.clone()),
                    stamp: Mutex::new(stamp(path)),
                    generation: AtomicU64::new(1),
                    current: RwLock::new(loaded),
                },
            );
            if prev.is_some() {
                bail!("duplicate model name {name:?}");
            }
        }
        Ok(ModelRegistry {
            entries: map,
            default_name: entries[0].0.clone(),
            last_poll: Mutex::new(Instant::now()),
            backend: crate::compute::cpu_arc(),
        })
    }

    /// Swap the compute backend every prediction batch runs on
    /// (builder style; the default is the bitwise CPU reference).
    pub fn with_backend(mut self, backend: Arc<dyn crate::compute::ComputeBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// The registry-wide prediction backend.
    pub fn backend(&self) -> &dyn crate::compute::ComputeBackend {
        &*self.backend
    }

    /// In-memory registry (tests / benches); first entry is the default.
    pub fn from_models(models: Vec<(String, SvmModel)>) -> ModelRegistry {
        Self::from_any_models(models.into_iter().map(|(n, m)| (n, AnyModel::Binary(m))).collect())
    }

    /// In-memory registry over models of either arity.
    pub fn from_any_models(models: Vec<(String, AnyModel)>) -> ModelRegistry {
        assert!(!models.is_empty(), "model registry needs at least one model");
        let default_name = models[0].0.clone();
        let entries = models
            .into_iter()
            .map(|(name, model)| {
                let loaded = Arc::new(LoadedModel { name: name.clone(), generation: 1, model });
                (
                    name,
                    ModelEntry {
                        path: None,
                        stamp: Mutex::new(None),
                        generation: AtomicU64::new(1),
                        current: RwLock::new(loaded),
                    },
                )
            })
            .collect();
        ModelRegistry {
            entries,
            default_name,
            last_poll: Mutex::new(Instant::now()),
            backend: crate::compute::cpu_arc(),
        }
    }

    /// Single-model convenience wrapper (name `"default"`).
    pub fn single(model: SvmModel) -> ModelRegistry {
        Self::from_models(vec![("default".to_string(), model)])
    }

    pub fn default_name(&self) -> &str {
        &self.default_name
    }

    /// `name -> generation` inventory (for banners / STATS).
    ///
    /// The generation is read from the *visible snapshot*, not the
    /// atomic counter: `reload` bumps the counter before swapping the
    /// `RwLock`, so the counter can briefly run ahead of the model a
    /// reader would actually get. Reporting the snapshot's own stamped
    /// generation keeps the inventory consistent with `get` by
    /// construction.
    pub fn names(&self) -> Vec<(String, u64)> {
        self.entries
            .iter()
            .map(|(n, e)| (n.clone(), e.current.read().unwrap().generation))
            .collect()
    }

    /// Snapshot the current model under `name`.
    pub fn get(&self, name: &str) -> Option<Arc<LoadedModel>> {
        self.entries.get(name).map(|e| e.current.read().unwrap().clone())
    }

    /// Reload `name` from its backing file and swap it in atomically.
    /// Returns the new generation. In-flight batches that already hold
    /// the old `Arc` are unaffected.
    ///
    /// Reloads of one entry are serialized on its stamp mutex (held
    /// across load → stamp → swap), so a RELOAD admin command racing
    /// the staleness poll cannot interleave and pin an older model
    /// under a newer stamp. The stamp is taken *before* reading the
    /// file: if the file is overwritten mid-load, the recorded stamp is
    /// older than the disk state and the next poll reloads again.
    pub fn reload(&self, name: &str) -> Result<u64> {
        let entry = self
            .entries
            .get(name)
            .with_context(|| format!("unknown model {name:?}"))?;
        let Some(path) = &entry.path else {
            bail!("model {name:?} is in-memory and cannot be reloaded");
        };
        let mut stamp_guard = entry.stamp.lock().unwrap();
        let pre = stamp(path);
        let model = persist::load_any(path)
            .with_context(|| format!("reloading model {name:?} from {}", path.display()))?;
        // AcqRel: the bump is a publication event paired with the swap
        // below, not a pure counter — a thread that observes generation
        // g must also observe every write that led to g (the Acquire
        // half orders racing reload attempts against each other; the
        // Release half pairs with any Acquire load of the counter).
        let generation = entry.generation.fetch_add(1, Ordering::AcqRel) + 1;
        let loaded = Arc::new(LoadedModel { name: name.to_string(), generation, model });
        *stamp_guard = pre;
        *entry.current.write().unwrap() = loaded;
        // Both reload drivers (RELOAD admin command and the staleness
        // poll) funnel through here — one emission point covers both.
        if crate::obs::enabled() {
            crate::obs::emit(&crate::obs::TraceEvent::ServerReload {
                model: name.to_string(),
                generation,
            });
        }
        Ok(generation)
    }

    /// Reload every file-backed entry, continuing past failures (a
    /// half-written file must not abort the rest): returns the names
    /// that swapped and `(name, error)` for those that did not — so
    /// callers can report partial success honestly instead of implying
    /// nothing changed.
    pub fn reload_all(&self) -> (Vec<String>, Vec<(String, String)>) {
        let mut swapped = Vec::new();
        let mut failed = Vec::new();
        for (name, e) in &self.entries {
            if e.path.is_some() {
                match self.reload(name) {
                    Ok(_) => swapped.push(name.clone()),
                    Err(e) => failed.push((name.clone(), format!("{e:#}"))),
                }
            }
        }
        (swapped, failed)
    }

    /// Rate-limited staleness poll: at most once per `min_interval`,
    /// compare each file-backed entry's mtime/size stamp and hot-swap
    /// the changed ones. A reload failure (e.g. the file is mid-write)
    /// keeps the old model serving and is reported on stderr; the next
    /// poll retries. Returns how many entries were swapped.
    pub fn poll_stale(&self, min_interval: Duration) -> usize {
        {
            let mut last = self.last_poll.lock().unwrap();
            if last.elapsed() < min_interval {
                return 0;
            }
            *last = Instant::now();
        }
        let mut swapped = 0;
        for (name, e) in &self.entries {
            let Some(path) = &e.path else { continue };
            let now = stamp(path);
            let known = *e.stamp.lock().unwrap();
            if now == known {
                continue;
            }
            match self.reload(name) {
                Ok(generation) => {
                    swapped += 1;
                    eprintln!("serve: model {name:?} changed on disk, now gen {generation}");
                }
                Err(e) => eprintln!("serve: stale model {name:?} failed to reload: {e:#}"),
            }
        }
        swapped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DEFAULT_LABEL_PAIR;
    use crate::kernel::Kernel;
    use crate::linalg::Mat;
    use crate::util::prng::Rng;

    fn bias_of(m: &AnyModel) -> f64 {
        m.as_binary().expect("binary test model").bias
    }

    fn toy(rng: &mut Rng, bias: f64) -> SvmModel {
        SvmModel {
            sv: Mat::gauss(3, 4, rng).into(),
            alpha_y: (0..3).map(|_| rng.gauss()).collect(),
            bias,
            kernel: Kernel::Gaussian { h: 1.0 },
            c: 1.0,
            labels: DEFAULT_LABEL_PAIR,
        }
    }

    #[test]
    fn in_memory_registry_selects_by_name() {
        let mut rng = Rng::new(31);
        let reg = ModelRegistry::from_models(vec![
            ("a".into(), toy(&mut rng, 1.0)),
            ("b".into(), toy(&mut rng, 2.0)),
        ]);
        assert_eq!(reg.default_name(), "a");
        assert_eq!(bias_of(&reg.get("a").unwrap().model), 1.0);
        assert_eq!(bias_of(&reg.get("b").unwrap().model), 2.0);
        assert!(reg.get("c").is_none());
        assert!(reg.reload("a").is_err(), "in-memory entries cannot reload");
        let (swapped, failed) = reg.reload_all();
        assert!(swapped.is_empty() && failed.is_empty(), "in-memory entries are skipped");
        assert_eq!(reg.names().len(), 2);
    }

    #[test]
    fn file_backed_reload_swaps_atomically_and_polls_staleness() {
        let mut rng = Rng::new(32);
        let dir = std::env::temp_dir()
            .join(format!("hss_svm_registry_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.model");
        persist::save(&toy(&mut rng, 10.0), &p).unwrap();
        let reg = ModelRegistry::from_paths(&[("default".to_string(), p.clone())]).unwrap();

        let old = reg.get("default").unwrap();
        assert_eq!(old.generation, 1);
        assert_eq!(bias_of(&old.model), 10.0);

        // different SV count => different file size, so the staleness
        // stamp changes even on coarse-mtime filesystems
        let mut newer = toy(&mut rng, 20.0);
        newer.sv = Mat::gauss(5, 4, &mut rng).into();
        newer.alpha_y = (0..5).map(|_| rng.gauss()).collect();
        persist::save(&newer, &p).unwrap();

        // explicit reload bumps the generation; the old Arc still holds
        // the old model (in-flight batch semantics)
        assert_eq!(reg.reload("default").unwrap(), 2);
        assert_eq!(bias_of(&reg.get("default").unwrap().model), 20.0);
        assert_eq!(bias_of(&old.model), 10.0);

        // mtime/size poll: overwrite again, rate limit respected
        persist::save(&toy(&mut rng, 30.0), &p).unwrap();
        assert_eq!(reg.poll_stale(Duration::from_secs(3600)), 0, "rate-limited");
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(reg.poll_stale(Duration::from_millis(1)), 1);
        assert_eq!(bias_of(&reg.get("default").unwrap().model), 30.0);
        assert_eq!(reg.get("default").unwrap().generation, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Generation/snapshot consistency under racing readers: `names()`
    /// must never report a generation outside the window of visible
    /// snapshots around it. With the old counter-based `names()` the
    /// generation was bumped *before* the `RwLock` swap, so a reader
    /// could see `names()` claim gen g while `get` still returned g-1;
    /// reading the stamped generation off the snapshot closes that gap.
    #[test]
    fn reload_generation_matches_visible_snapshot_under_races() {
        let mut rng = Rng::new(33);
        let dir = std::env::temp_dir()
            .join(format!("hss_svm_registry_gen_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.model");
        persist::save(&toy(&mut rng, 1.0), &p).unwrap();
        let reg = ModelRegistry::from_paths(&[("default".to_string(), p)]).unwrap();
        let reloads: u64 = 20;
        std::thread::scope(|scope| {
            for _ in 0..3 {
                let reg = &reg;
                scope.spawn(move || {
                    let mut last = 0u64;
                    loop {
                        let g1 = reg.get("default").unwrap().generation;
                        let n = reg.names()[0].1;
                        let g2 = reg.get("default").unwrap().generation;
                        assert!(g1 >= last, "generation went backwards: {g1} < {last}");
                        assert!(
                            g1 <= n && n <= g2,
                            "names() gen {n} outside visible snapshot window {g1}..{g2}"
                        );
                        last = g2;
                        if g2 >= reloads + 1 {
                            break;
                        }
                    }
                });
            }
            for i in 0..reloads {
                assert_eq!(reg.reload("default").unwrap(), i + 2);
            }
        });
        assert_eq!(reg.get("default").unwrap().generation, reloads + 1);
        assert_eq!(reg.names(), vec![("default".to_string(), reloads + 1)]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
