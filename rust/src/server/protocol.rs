//! Line protocol of the TCP server.
//!
//! Every non-blank, non-comment input line is either a **request** (a
//! LIBSVM feature line, exactly the stdin serve grammar) or an **admin
//! command** (an all-caps keyword first token). The two cannot collide:
//! a LIBSVM line starts with a numeric label or an `index:value` pair,
//! never with an alphabetic keyword. Each such line gets exactly one
//! response line, in input order:
//!
//! | input                | response                                    |
//! |----------------------|---------------------------------------------|
//! | feature line         | `<label> <decision>`                        |
//! | malformed line       | `ERR line <n>: <why>`                       |
//! | line in a poisoned   | `ERR line <n>: dropped (malformed line in   |
//! | per-connection batch | this batch from this connection)`           |
//! | queue full           | `ERR line <n>: server overloaded (...)`     |
//! | `MODEL <name>`       | `OK model <name> gen <g>` / `ERR ...`       |
//! | `RELOAD [<name>]`    | `OK reloaded ...` / `ERR ...`               |
//! | `STATS`              | `OK stats k=v ...`                          |
//! | `METRICS`            | Prometheus text exposition, `# EOF`-ended   |
//! | `SHUTDOWN`           | `OK shutting down` (then server drains)     |
//! | `QUIT`               | `OK bye` (connection closes after drain)    |

/// A parsed admin command.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Admin {
    /// `MODEL <name>`: switch this connection's model.
    Model(String),
    /// `RELOAD` (all file-backed models) or `RELOAD <name>`.
    Reload(Option<String>),
    /// `STATS`: one-line counters + latency percentiles.
    Stats,
    /// `METRICS`: multi-line Prometheus text exposition, terminated by
    /// a `# EOF` line (the client reads until it).
    Metrics,
    /// `SHUTDOWN`: graceful server shutdown (drain, then exit).
    Shutdown,
    /// `QUIT`: close this connection (after its in-flight lines drain).
    Quit,
}

/// Classify a trimmed, non-empty line: `None` = prediction request,
/// `Some(Ok)` = admin command, `Some(Err(response))` = a recognized
/// keyword with bad arity (answered without touching the batcher).
pub fn parse_admin(line: &str) -> Option<Result<Admin, String>> {
    let mut tok = line.split_ascii_whitespace();
    let head = tok.next()?;
    let arg = tok.next();
    let extra = tok.next().is_some();
    let usage = |u: &str| Some(Err(format!("ERR usage: {u}")));
    match head {
        "MODEL" => match (arg, extra) {
            (Some(name), false) => Some(Ok(Admin::Model(name.to_string()))),
            _ => usage("MODEL <name>"),
        },
        "RELOAD" => match (arg, extra) {
            (None, _) => Some(Ok(Admin::Reload(None))),
            (Some(name), false) => Some(Ok(Admin::Reload(Some(name.to_string())))),
            _ => usage("RELOAD [<name>]"),
        },
        "STATS" if arg.is_none() => Some(Ok(Admin::Stats)),
        "METRICS" if arg.is_none() => Some(Ok(Admin::Metrics)),
        "SHUTDOWN" if arg.is_none() => Some(Ok(Admin::Shutdown)),
        "QUIT" if arg.is_none() => Some(Ok(Admin::Quit)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keywords_parse_and_feature_lines_do_not() {
        assert_eq!(parse_admin("MODEL rcv1"), Some(Ok(Admin::Model("rcv1".into()))));
        assert_eq!(parse_admin("RELOAD"), Some(Ok(Admin::Reload(None))));
        assert_eq!(parse_admin("RELOAD a"), Some(Ok(Admin::Reload(Some("a".into())))));
        assert_eq!(parse_admin("STATS"), Some(Ok(Admin::Stats)));
        assert_eq!(parse_admin("METRICS"), Some(Ok(Admin::Metrics)));
        assert_eq!(parse_admin("SHUTDOWN"), Some(Ok(Admin::Shutdown)));
        assert_eq!(parse_admin("QUIT"), Some(Ok(Admin::Quit)));
        // requests — labeled, 0-labeled and bare feature lines
        assert_eq!(parse_admin("+1 1:0.5 3:2"), None);
        assert_eq!(parse_admin("0 2:1"), None);
        assert_eq!(parse_admin("1:0.5"), None);
        // unknown words are requests too (they fail as parse errors with
        // a line number, like any malformed request)
        assert_eq!(parse_admin("FLUSH"), None);
        assert_eq!(parse_admin("model x"), None, "keywords are case-sensitive");
    }

    #[test]
    fn bad_arity_is_answered_not_enqueued() {
        assert_eq!(parse_admin("MODEL"), Some(Err("ERR usage: MODEL <name>".into())));
        assert_eq!(
            parse_admin("MODEL a b"),
            Some(Err("ERR usage: MODEL <name>".into()))
        );
        assert_eq!(
            parse_admin("RELOAD a b"),
            Some(Err("ERR usage: RELOAD [<name>]".into()))
        );
        // STATS/METRICS with an argument are not recognized admin forms
        assert_eq!(parse_admin("STATS now"), None);
        assert_eq!(parse_admin("METRICS all"), None);
    }
}
