//! Regeneration of the paper's Figures 1 and 2.
//!
//! Figure 1 (left): singular-value decay of Gaussian kernel matrices for
//! several widths h — the reason global low-rank approximation fails for
//! small h. Figure 1 (right): the same kernel matrix with and without
//! cluster reordering — off-diagonal blocks become low-rank only after
//! clustering. Figure 2: accuracy heatmap over the (h, C) grid.

use crate::cluster::{ClusterTree, SplitMethod};
use crate::coordinator::grid::{ascii_heatmap, GridSearch};
use crate::coordinator::suite::prepare_dataset;
use crate::data::{synth, Dataset};
use crate::eval::report::Table;
use crate::kernel::Kernel;
use crate::linalg::cpqr;
use crate::linalg::eig;
use crate::util::prng::Rng;
use anyhow::Result;

/// A heart_scale-like dataset: 270 points, 13 features, mixed scales —
/// the dataset the paper's Figure 1 uses.
pub fn heart_scale_like(rng: &mut Rng) -> Dataset {
    let spec = synth::GmmSpec {
        dim: 13,
        active_dims: 13,
        clusters_per_class: 3,
        sep: 2.2,
        cluster_std: 1.0,
        label_noise: 0.1,
    };
    let mut ds = spec.sample("heart_scale*", 270, 120, rng);
    let sc = crate::data::scale::Scaler::fit_minmax(&ds, -1.0, 1.0);
    sc.apply(&mut ds);
    ds
}

/// Figure 1, left: normalized singular values σ_k/σ_1 for several h.
/// Returns (k values, one decay column per h).
pub fn fig1_decay(ds: &Dataset, h_values: &[f64]) -> (Vec<usize>, Vec<Vec<f64>>) {
    let ks: Vec<usize> = (0..ds.len()).step_by(10.max(ds.len() / 27)).collect();
    let mut cols = Vec::new();
    for &h in h_values {
        let k = Kernel::Gaussian { h };
        let gram = k.gram(&ds.x);
        let sv = eig::psd_singular_values(&gram);
        let s1 = sv[0].max(1e-300);
        cols.push(ks.iter().map(|&i| sv[i.min(sv.len() - 1)] / s1).collect());
    }
    (ks, cols)
}

/// Figure 1, right: numerical ranks (at tol) of the four top-level
/// off-diagonal sub-blocks, in natural vs cluster order. Clustering
/// should cut the off-diagonal ranks sharply.
pub fn fig1_block_ranks(ds: &Dataset, h: f64, tol: f64, rng: &mut Rng) -> Table {
    let kernel = Kernel::Gaussian { h };
    let n = ds.len();
    let half = n / 2;

    let rank_of = |d: &Dataset| -> usize {
        let gram = kernel.gram(&d.x);
        // top-right off-diagonal block
        let block = gram.block(0, half, half, n - half);
        cpqr::cpqr(&block, tol, 0.0, usize::MAX).rank
    };

    let natural = rank_of(ds);
    let tree = ClusterTree::build(ds, 32, SplitMethod::TwoMeans, rng);
    let clustered = rank_of(&ds.permute(&tree.perm));

    let mut t = Table::new(
        format!("Figure 1 (right): off-diagonal block rank, h={h}, tol={tol}"),
        &["ordering", "off-diag numerical rank", "block size"],
    );
    t.row(vec!["natural".into(), natural.to_string(), format!("{half}x{}", n - half)]);
    t.row(vec!["clustered".into(), clustered.to_string(), format!("{half}x{}", n - half)]);
    t
}

/// Figure 1 driver: prints the decay table + block-rank comparison.
pub fn fig1(seed: u64) -> (Table, Table) {
    let mut rng = Rng::new(seed);
    let ds = heart_scale_like(&mut rng);
    let h_values = [0.5, 1.0, 2.0, 4.0];
    let (ks, cols) = fig1_decay(&ds, &h_values);
    let mut headers: Vec<String> = vec!["k".into()];
    headers.extend(h_values.iter().map(|h| format!("sigma_k/sigma_1 (h={h})")));
    let mut t = Table::new(
        "Figure 1 (left): Gaussian kernel singular value decay (heart_scale-like)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (i, &k) in ks.iter().enumerate() {
        let mut row = vec![k.to_string()];
        for col in &cols {
            row.push(format!("{:.3e}", col[i]));
        }
        t.row(row);
    }
    // tol 1e-2 ~ 'visually low rank' (the paper's right panel is a
    // heatmap; we quantify its block structure at plotting precision)
    let ranks = fig1_block_ranks(&ds, 1.0, 1e-2, &mut rng);
    (t, ranks)
}

/// Figure 2: (h, C) accuracy heatmaps for a9a-like and ijcnn1-like.
pub fn fig2(scale: f64, seed: u64, threads: usize) -> Result<Vec<(String, String, Table)>> {
    let mut out = Vec::new();
    for name in ["a9a", "ijcnn1"] {
        let spec = synth::table1_spec(name).unwrap();
        let (train, test) = prepare_dataset(spec, scale, seed);
        let beta = synth::Table1Spec::beta_for(train.len());
        let h_values = vec![0.1, 0.5, 1.0, 5.0, 10.0];
        let c_values = vec![0.1, 0.5, 1.0, 5.0, 10.0];
        let grid = GridSearch {
            h_values: h_values.clone(),
            c_values: c_values.clone(),
            hss: crate::hss::HssParams::low_accuracy(),
            admm: crate::admm::AdmmParams { beta, max_it: 10, relax: 1.0, tol: 0.0 },
            threads,
        };
        let res = grid.run(&train, &test)?;
        let heat = ascii_heatmap(&res, &h_values, &c_values);
        let mut t = Table::new(
            format!("Figure 2 data: accuracy heatmap, {name}-like (scale={scale})"),
            &["h", "C", "accuracy [%]"],
        );
        for cell in &res.cells {
            t.row(vec![
                format!("{}", cell.h),
                format!("{}", cell.c),
                format!("{:.3}", cell.accuracy * 100.0),
            ]);
        }
        out.push((name.to_string(), heat, t));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decay_is_faster_for_larger_h() {
        // the paper's Figure-1 point: larger h ⇒ faster singular decay ⇒
        // closer to globally low-rank
        let mut rng = Rng::new(321);
        let ds = heart_scale_like(&mut rng);
        let (ks, cols) = fig1_decay(&ds, &[0.5, 4.0]);
        // compare the normalized singular value at a mid index
        let mid = ks.len() / 2;
        let small_h = cols[0][mid];
        let large_h = cols[1][mid];
        assert!(
            large_h < small_h,
            "expected faster decay for h=4 ({large_h:.3e}) than h=0.5 ({small_h:.3e})"
        );
    }

    #[test]
    fn clustering_reduces_offdiagonal_rank() {
        let mut rng = Rng::new(322);
        // strongly clustered geometry
        let ds = synth::blobs(200, 4, 4, 0.08, &mut rng);
        let t = fig1_block_ranks(&ds, 0.5, 1e-8, &mut rng);
        let natural: usize = t.rows[0][1].parse().unwrap();
        let clustered: usize = t.rows[1][1].parse().unwrap();
        assert!(
            clustered <= natural,
            "clustering should not increase off-diag rank: {natural} → {clustered}"
        );
    }
}
