//! Text-table and CSV report writers (the paper's tables are regenerated
//! as aligned text on stdout plus machine-readable CSV next to it).

use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (j, c) in row.iter().enumerate() {
                widths[j] = widths[j].max(c.len());
            }
        }
        let sep: String = widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(j, c)| format!(" {:<width$} ", c, width = widths[j]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("cannot create {}", path.as_ref().display()))?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            let escaped: Vec<String> = row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            writeln!(f, "{}", escaped.join(","))?;
        }
        Ok(())
    }
}

/// Format seconds the way the paper does (3 decimals).
pub fn secs(s: f64) -> String {
    format!("{s:.3}")
}

/// Format a percentage with 3 decimals (paper style).
pub fn pct(frac: f64) -> String {
    format!("{:.3}", frac * 100.0)
}

/// Format the best-C set like the paper ("1,10").
pub fn c_set(cs: &[f64]) -> String {
    cs.iter()
        .map(|c| {
            if *c == c.trunc() && c.abs() < 1e6 {
                format!("{}", *c as i64)
            } else {
                format!("{c}")
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["short".into(), "1".into()]);
        t.row(vec!["a-much-longer-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // all data lines equal length
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn csv_roundtrip_with_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "plain".into()]);
        let dir = std::env::temp_dir().join("hss_svm_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n\"x,y\",plain\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(secs(1.23456), "1.235");
        assert_eq!(pct(0.83314), "83.314");
        assert_eq!(c_set(&[1.0, 10.0]), "1,10");
        assert_eq!(c_set(&[0.1]), "0.1");
    }
}
