//! Evaluation harness: regenerates every table and figure of the paper's
//! experimental section (see DESIGN.md §3 for the experiment index).

// No raw-pointer tricks belong in this module tree (see DESIGN.md §11).
#![forbid(unsafe_code)]

pub mod figures;
pub mod report;
pub mod tables;
