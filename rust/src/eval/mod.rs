//! Evaluation harness: regenerates every table and figure of the paper's
//! experimental section (see DESIGN.md §3 for the experiment index).

pub mod figures;
pub mod report;
pub mod tables;
