//! Regeneration of the paper's Tables 1–5 from suite results.

use crate::coordinator::SuiteRow;
use crate::data::synth;
use crate::eval::report::{c_set, pct, secs, Table};

/// Table 1: problem-set details (paper sizes + the generated sizes at
/// the current scale, so the substitution is visible).
pub fn table1(scale: f64, seed: u64) -> Table {
    let mut t = Table::new(
        format!("Table 1: Problem Set Details (scale={scale})"),
        &[
            "Dataset",
            "Features",
            "Train (paper)",
            "|Train+| (paper)",
            "Test (paper)",
            "Train (gen)",
            "|Train+| (gen)",
            "Test (gen)",
        ],
    );
    for spec in synth::TABLE1 {
        let (train, test) = spec.generate(scale, seed);
        t.row(vec![
            spec.name.to_string(),
            spec.features.to_string(),
            spec.train.to_string(),
            spec.train_pos.to_string(),
            spec.test.to_string(),
            train.len().to_string(),
            train.positives().to_string(),
            test.len().to_string(),
        ]);
    }
    t
}

/// Table 2 (LIBSVM/SMO) or Table 3 (RACQP): Runtime + Accuracy per
/// dataset. `pick` selects which baseline column of the row to use.
pub fn baseline_table(
    title: &str,
    rows: &[SuiteRow],
    pick: impl Fn(&SuiteRow) -> Option<(f64, f64)>,
) -> Table {
    let mut t = Table::new(title, &["Dataset", "Runtime [s]", "Accuracy [%]"]);
    for r in rows {
        match pick(r) {
            Some((runtime, acc)) => t.row(vec![r.dataset.clone(), secs(runtime), pct(acc)]),
            // the paper prints †† for runs stopped after 10 h
            None => t.row(vec![r.dataset.clone(), "++".into(), "".into()]),
        }
    }
    t
}

/// Tables 4/5: the Strumpack&ADMM columns.
pub fn hss_table(title: &str, rows: &[SuiteRow]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "Dataset",
            "Compression [s]",
            "Factorization [s]",
            "Memory [MB]",
            "ADMM Time [s]",
            "best h",
            "best C",
            "Accuracy [%]",
            "max rank",
        ],
    );
    for r in rows {
        t.row(vec![
            r.dataset.clone(),
            secs(r.compress_secs),
            secs(r.factor_secs),
            format!("{:.3}", r.memory_mb),
            secs(r.admm_secs),
            format!("{}", r.best_h),
            c_set(&r.best_cs),
            pct(r.accuracy),
            r.hss_max_rank.to_string(),
        ]);
    }
    t
}

/// The §3.3 headline comparison: per dataset, total grid time for our
/// method (1 compression + 1 factorization + #C × ADMM) vs the baseline
/// (#C retrainings from scratch).
pub fn grid_reuse_table(rows: &[SuiteRow], n_c: usize) -> Table {
    let mut t = Table::new(
        "Grid-search cost: HSS reuse vs retrain-per-C",
        &[
            "Dataset",
            "HSS setup [s]",
            "+ grid over C [s]",
            "SMO per C [s]",
            "SMO x #C [s]",
            "speedup",
        ],
    );
    for r in rows {
        let setup = r.compress_secs + r.factor_secs;
        let grid = r.admm_secs * n_c as f64;
        if let Some((smo_secs, _)) = r.smo {
            let smo_total = smo_secs * n_c as f64;
            let speedup = smo_total / (setup + grid).max(1e-9);
            t.row(vec![
                r.dataset.clone(),
                secs(setup),
                secs(grid),
                secs(smo_secs),
                secs(smo_total),
                format!("{speedup:.1}x"),
            ]);
        } else {
            t.row(vec![r.dataset.clone(), secs(setup), secs(grid), "++".into(), "++".into(), "".into()]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lists_all_ten() {
        let t = table1(0.001, 7);
        assert_eq!(t.rows.len(), 10);
        assert!(t.render().contains("susy"));
        // paper numbers present
        assert!(t.rows.iter().any(|r| r[2] == "3500000"));
    }

    #[test]
    fn baseline_table_handles_missing_runs() {
        let t = baseline_table("Table 2", &[], |r| r.smo);
        assert_eq!(t.rows.len(), 0);
        assert!(t.render().contains("Runtime"));
    }
}
