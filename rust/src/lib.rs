//! hss-svm: training very-large-scale nonlinear SVMs with the Alternating
//! Direction Method of Multipliers (ADMM) coupled with Hierarchically
//! Semi-Separable (HSS) kernel approximations.
//!
//! Reproduction of Cipolla & Gondzio (2021). See `DESIGN.md` at the
//! repository root for the module inventory, the reuse structure and the
//! batched multi-RHS solve API that runs the whole C-grid in lockstep.

pub mod ann;
pub mod admm;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod cli;
pub mod eval;
pub mod hodlr;
pub mod hss;
pub mod kernel;
pub mod linalg;
pub mod runtime;
pub mod serve;
pub mod server;
pub mod svm;
pub mod util;
