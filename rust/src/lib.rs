//! hss-svm: training very-large-scale nonlinear SVMs with the Alternating
//! Direction Method of Multipliers (ADMM) coupled with Hierarchically
//! Semi-Separable (HSS) kernel approximations.
//!
//! Reproduction of Cipolla & Gondzio (2021). See `DESIGN.md` at the
//! repository root for the module inventory, the reuse structure and the
//! batched multi-RHS solve API that runs the whole C-grid in lockstep.
//!
//! Memory-safety contract (DESIGN.md §11): every `unsafe` site carries a
//! `// SAFETY:` comment and is budgeted in `ci/unsafe_budget.toml`
//! (enforced by `cargo xtask audit`); modules with no legitimate need
//! carry `#![forbid(unsafe_code)]`.

// Make the safety obligation of every `unsafe fn` body explicit: inner
// operations must sit in their own `unsafe { }` blocks with their own
// SAFETY justification.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod ann;
pub mod admm;
pub mod baselines;
pub mod cluster;
pub mod compute;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod cli;
pub mod eval;
pub mod hodlr;
pub mod hss;
pub mod kernel;
pub mod linalg;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod server;
pub mod svm;
pub mod util;
