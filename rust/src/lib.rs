//! hss-svm: training very-large-scale nonlinear SVMs with the Alternating
//! Direction Method of Multipliers (ADMM) coupled with Hierarchically
//! Semi-Separable (HSS) kernel approximations.
//!
//! Reproduction of Cipolla & Gondzio (2021). See DESIGN.md for the system
//! inventory and EXPERIMENTS.md for the paper-vs-measured record.

pub mod ann;
pub mod admm;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod cli;
pub mod eval;
pub mod hodlr;
pub mod hss;
pub mod kernel;
pub mod linalg;
pub mod runtime;
pub mod svm;
pub mod util;
