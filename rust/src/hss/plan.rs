//! Shared level schedule for cluster-tree / HSS traversals.
//!
//! Every tree pass in this crate — compression, ULV factorization, the
//! blocked multi-RHS solve sweeps and the matvec — walks the same
//! postorder node array either bottom-up (children before parents) or
//! top-down. Nodes of one depth level are mutually independent in all
//! four, so a single precomputed schedule (per-level lists of node ids)
//! drives them all through [`crate::util::threadpool::run_levels`]:
//! levels are barriers, nodes within a level run in parallel, per-node
//! arithmetic is untouched. (Row extents stay on the node arrays
//! themselves — each sweep reads `begin`/`end` from its own nodes when
//! scattering into disjoint output ranges.) That is what makes the
//! parallel paths bit-for-bit identical to the serial ones for every
//! thread count (the thread-invariance contract, pinned by
//! `tests/thread_invariance.rs`).

/// Level schedule of a postorder tree (children precede parents, root
/// last). Construction is O(#nodes); the schedule is immutable and
/// shared by all traversals of the same tree.
#[derive(Clone, Debug)]
pub struct LevelSchedule {
    /// `levels[d]` = node ids at depth d (root = depth 0), ascending
    /// within a level.
    levels: Vec<Vec<usize>>,
}

impl LevelSchedule {
    /// Build from a postorder node array described by an accessor:
    /// `children(i)` returns the (left, right) child ids (None for a
    /// leaf).
    pub fn from_postorder(
        n_nodes: usize,
        children: impl Fn(usize) -> (Option<usize>, Option<usize>),
    ) -> Self {
        assert!(n_nodes > 0, "schedule of an empty tree");
        // parents come after children in postorder, so a reverse sweep
        // sees every node's depth before visiting its children
        let mut depth = vec![0usize; n_nodes];
        for i in (0..n_nodes).rev() {
            let (l, r) = children(i);
            if let Some(l) = l {
                assert!(l < i, "postorder violated: child {l} >= parent {i}");
                depth[l] = depth[i] + 1;
            }
            if let Some(r) = r {
                assert!(r < i, "postorder violated: child {r} >= parent {i}");
                depth[r] = depth[i] + 1;
            }
        }
        let max_depth = depth.iter().copied().max().unwrap_or(0);
        let mut levels = vec![Vec::new(); max_depth + 1];
        for (i, &d) in depth.iter().enumerate() {
            levels[d].push(i);
        }
        LevelSchedule { levels }
    }

    /// Build from a cluster tree (the compression-time source of truth;
    /// the HSS node array mirrors its topology index-for-index).
    pub fn from_cluster_tree(tree: &crate::cluster::ClusterTree) -> Self {
        Self::from_postorder(tree.nodes.len(), |i| (tree.nodes[i].left, tree.nodes[i].right))
    }

    /// Levels deepest-first — the order of upsweeps and bottom-up builds
    /// (compression, ULV elimination, solve upsweep, matvec upsweep).
    pub fn bottom_up(&self) -> Vec<&[usize]> {
        self.levels.iter().rev().map(|v| v.as_slice()).collect()
    }

    /// Levels root-first — the downsweep order (solve back-substitution,
    /// matvec scatter).
    pub fn top_down(&self) -> Vec<&[usize]> {
        self.levels.iter().map(|v| v.as_slice()).collect()
    }

    /// Number of depth levels (≥ 1).
    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }

    /// Number of tree nodes.
    pub fn n_nodes(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterTree, SplitMethod};
    use crate::data::synth;
    use crate::util::prng::Rng;

    fn check_schedule(plan: &LevelSchedule, tree: &ClusterTree) {
        assert_eq!(plan.n_nodes(), tree.nodes.len());
        // every node appears exactly once, at its tree depth
        let mut seen = vec![false; tree.nodes.len()];
        for (d, level) in plan.top_down().iter().enumerate() {
            assert!(!level.is_empty(), "empty level {d}");
            let mut prev = None;
            for &id in *level {
                assert!(!seen[id], "node {id} scheduled twice");
                seen[id] = true;
                assert_eq!(tree.nodes[id].level, d, "depth mismatch for {id}");
                if let Some(p) = prev {
                    assert!(p < id, "ids not ascending within level {d}");
                }
                prev = Some(id);
            }
        }
        assert!(seen.iter().all(|&s| s));
        // bottom_up is exactly top_down reversed
        let bu = plan.bottom_up();
        let td = plan.top_down();
        assert_eq!(bu.len(), td.len());
        for (a, b) in bu.iter().zip(td.iter().rev()) {
            assert_eq!(a, b);
        }
        // children always sit one level deeper than their parent
        for (i, node) in tree.nodes.iter().enumerate() {
            if let (Some(l), Some(r)) = (node.left, node.right) {
                assert_eq!(tree.nodes[l].level, tree.nodes[i].level + 1);
                assert_eq!(tree.nodes[r].level, tree.nodes[i].level + 1);
            }
        }
        assert_eq!(plan.n_levels(), tree.depth());
    }

    #[test]
    fn schedule_matches_tree_levels_on_ragged_trees() {
        crate::util::testkit::check("plan-levels", 8, |rng, case| {
            // non-power-of-two sizes and small leaves → ragged trees
            let n = 11 + rng.below(500);
            let ds = synth::blobs(n, 1 + rng.below(5), 3, 0.3, rng);
            let leaf = 4 + rng.below(40);
            let method = if case % 2 == 0 { SplitMethod::TwoMeans } else { SplitMethod::Pca };
            let tree = ClusterTree::build(&ds, leaf, method, rng);
            let plan = LevelSchedule::from_cluster_tree(&tree);
            check_schedule(&plan, &tree);
        });
    }

    #[test]
    fn single_node_tree() {
        let mut rng = Rng::new(5);
        let ds = synth::blobs(10, 2, 2, 0.3, &mut rng);
        let tree = ClusterTree::build(&ds, 64, SplitMethod::TwoMeans, &mut rng);
        assert_eq!(tree.nodes.len(), 1);
        let plan = LevelSchedule::from_cluster_tree(&tree);
        assert_eq!(plan.n_levels(), 1);
        assert_eq!(plan.n_nodes(), 1);
        assert_eq!(plan.bottom_up(), vec![&[0usize][..]]);
        assert_eq!(plan.top_down(), vec![&[0usize][..]]);
    }
}
