//! Fast HSS matrix–vector product and dense reconstruction.
//!
//! The matvec is the classic two-sweep algorithm: an upsweep compresses
//! the input through the nested bases (x̂ = Uᵀx per node), a downsweep
//! scatters sibling couplings back down (g = B x̂_sibling + R g_parent),
//! leaves finish with the dense diagonal. O(d·r) per product — this is
//! what makes the bias computation (eq. 7 of the paper) a single cheap
//! product instead of d² kernel evaluations.

use crate::hss::Hss;
use crate::linalg::blas;
use crate::linalg::Mat;
use crate::util::threadpool;

/// y = K̃ x, both in tree (permuted) order (serial path).
pub fn matvec(h: &Hss, x: &[f64]) -> Vec<f64> {
    matvec_threads(h, x, 1)
}

/// y = K̃ x with both sweeps level-scheduled over `threads` workers.
///
/// The upsweep compresses bottom-up (x̂_i per node), the downsweep
/// scatters sibling couplings top-down and finishes each leaf's
/// y = D x + U g in place. Nodes of one level touch disjoint per-node
/// state (and disjoint output rows at the leaves), and per-node
/// arithmetic is the serial path's, so the result is bit-for-bit
/// identical for every thread count.
pub fn matvec_threads(h: &Hss, x: &[f64], threads: usize) -> Vec<f64> {
    assert_eq!(x.len(), h.n);
    let nn = h.nodes.len();

    // ---- upsweep: x̂_i = U_iᵀ (leaf slice | stacked child x̂) ----
    let mut xhat: Vec<Vec<f64>> = vec![Vec::new(); nn];
    {
        let xhc = threadpool::disjoint(&mut xhat);
        let bottom_up = h.plan.bottom_up();
        threadpool::run_levels(threads, &bottom_up, |i| {
            let node = &h.nodes[i];
            let Some(u) = &node.u else { return }; // root
            // SAFETY: children x̂ come from completed levels; only node
            // i's own slot is written here.
            let local: Vec<f64> = if node.is_leaf() {
                x[node.begin..node.end].to_vec()
            } else {
                unsafe {
                    let mut v = (*xhc.get(node.left.unwrap())).clone();
                    v.extend_from_slice(&*xhc.get(node.right.unwrap()));
                    v
                }
            };
            let mut out = vec![0.0; u.cols()];
            blas::gemv_t(u, &local, &mut out);
            // SAFETY: x̂ slot i is written only by node i's task.
            unsafe { *xhc.get(i) = out };
        });
    }

    // ---- downsweep: g_i in each node's basis; leaves finish y ----
    let mut g: Vec<Vec<f64>> = vec![Vec::new(); nn];
    let mut y = vec![0.0; h.n];
    {
        let gc = threadpool::disjoint(&mut g);
        let yc = threadpool::disjoint(&mut y);
        let top_down = h.plan.top_down();
        threadpool::run_levels(threads, &top_down, |i| {
            let node = &h.nodes[i];
            if node.is_leaf() {
                // y = D x_local + U g_i (g_i was written by the parent's
                // level; a root leaf has g_i empty).
                // SAFETY: leaf row ranges are disjoint across the tree.
                let d = node.d.as_ref().expect("leaf has D");
                let xl = &x[node.begin..node.end];
                let yl = unsafe { yc.slice(node.begin, node.end - node.begin) };
                blas::gemv(d, xl, yl);
                let gi = unsafe { &*gc.get(i) };
                if let (Some(u), false) = (&node.u, gi.is_empty()) {
                    let mut tmp = vec![0.0; u.rows()];
                    blas::gemv(u, gi, &mut tmp);
                    for (v, t) in yl.iter_mut().zip(tmp.iter()) {
                        *v += t;
                    }
                }
                return;
            }
            let (li, ri) = (node.left.unwrap(), node.right.unwrap());
            let b = node.b.as_ref().expect("internal node has B");
            let rl = h.nodes[li].rank();
            let rr = h.nodes[ri].rank();
            let mut gl = vec![0.0; rl];
            let mut gr = vec![0.0; rr];
            // sibling coupling
            blas::gemv(b, &xhat[ri], &mut gl); // B x̂_r
            blas::gemv_t(b, &xhat[li], &mut gr); // Bᵀ x̂_l
            // parent pass-down: g_child += R_child g_i
            // SAFETY: g_i was written by the parent's completed level;
            // only the two children's slots are written here.
            let gi = unsafe { &*gc.get(i) };
            if !gi.is_empty() {
                let u = h.nodes[i].u.as_ref().expect("non-root internal has U");
                // u = [R_l; R_r] stacked
                let mut tmp = vec![0.0; u.rows()];
                blas::gemv(u, gi, &mut tmp);
                for (k, v) in tmp[..rl].iter().enumerate() {
                    gl[k] += v;
                }
                for (k, v) in tmp[rl..].iter().enumerate() {
                    gr[k] += v;
                }
            }
            // SAFETY: the children's g slots are written only by this
            // parent (one parent per child) and consumed one level later,
            // after the barrier.
            unsafe {
                *gc.get(li) = gl;
                *gc.get(ri) = gr;
            }
        });
    }
    y
}

/// y = (K̃ + shift·I) x.
pub fn matvec_shifted(h: &Hss, shift: f64, x: &[f64]) -> Vec<f64> {
    let mut y = matvec(h, x);
    if shift != 0.0 {
        for (yi, xi) in y.iter_mut().zip(x.iter()) {
            *yi += shift * xi;
        }
    }
    y
}

/// Dense reconstruction of K̃ (tests/diagnostics only — O(n²) memory).
pub fn to_dense(h: &Hss) -> Mat {
    let n = h.n;
    let mut out = Mat::zeros(n, n);
    // column by column via matvec of unit vectors would be O(n² r); for
    // tests that is fine, but assembling blocks directly is ~2× faster
    // and exercises a different code path than matvec — keep matvec-based
    // so the two validate each other.
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = matvec(h, &e);
        e[j] = 0.0;
        for i in 0..n {
            out[(i, j)] = col[i];
        }
    }
    out
}

/// Relative Frobenius error ‖K − K̃‖_F / ‖K‖_F estimated with `probes`
/// random Gaussian probes (never forms either matrix).
pub fn rel_error_probes(
    h: &Hss,
    kernel: &crate::kernel::Kernel,
    pds: &crate::data::Dataset,
    probes: usize,
    rng: &mut crate::util::prng::Rng,
) -> f64 {
    rel_error_probes_with(crate::compute::cpu(), h, kernel, pds, probes, rng)
}

/// [`rel_error_probes`] on an explicit [`crate::compute::ComputeBackend`]:
/// both the HSS matvec probes and the exact blocked kernel rows run on
/// the backend.
pub fn rel_error_probes_with(
    backend: &dyn crate::compute::ComputeBackend,
    h: &Hss,
    kernel: &crate::kernel::Kernel,
    pds: &crate::data::Dataset,
    probes: usize,
    rng: &mut crate::util::prng::Rng,
) -> f64 {
    let n = h.n;
    let mut num = 0.0;
    let mut den = 0.0;
    // exact K x via blocked kernel rows (never storing K)
    let block = 2048.min(n);
    for _ in 0..probes {
        let x: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let approx = backend.hss_matvec(h, &x, 1);
        let mut exact = vec![0.0; n];
        let ny = pds.x.self_norms();
        let mut i0 = 0;
        while i0 < n {
            let ib = block.min(n - i0);
            let rows: Vec<usize> = (i0..i0 + ib).collect();
            let xb = pds.x.select_rows(&rows);
            let kb = backend.kernel_block_with_norms(
                kernel,
                &xb,
                &ny[i0..i0 + ib],
                &pds.x,
                &ny,
            );
            let mut yb = vec![0.0; ib];
            blas::gemv(&kb, &x, &mut yb);
            exact[i0..i0 + ib].copy_from_slice(&yb);
            i0 += ib;
        }
        num += exact.iter().zip(approx.iter()).map(|(a, b)| (a - b) * (a - b)).sum::<f64>();
        den += exact.iter().map(|a| a * a).sum::<f64>();
    }
    (num / den.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::hss::compress::compress;
    use crate::hss::HssParams;
    use crate::kernel::Kernel;
    use crate::util::prng::Rng;
    use crate::util::testkit;

    #[test]
    fn matvec_matches_dense_kernel_near_exact() {
        testkit::check("hss-matvec", 5, |rng, _| {
            let n = 60 + rng.below(200);
            let ds = synth::blobs(n, 1 + rng.below(4), 3, 0.3, rng);
            let kernel = Kernel::Gaussian { h: 0.8 + rng.f64() };
            let c = compress(&ds, &kernel, &HssParams::near_exact(), 1);
            let kd = kernel.gram(&c.pds.x);
            let x: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let mut want = vec![0.0; n];
            blas::gemv(&kd, &x, &mut want);
            let got = matvec(&c.hss, &x);
            testkit::assert_allclose(&got, &want, 1e-6);
        });
    }

    #[test]
    fn shifted_matvec_adds_diagonal() {
        let mut rng = Rng::new(31);
        let ds = synth::blobs(100, 2, 3, 0.2, &mut rng);
        let kernel = Kernel::Gaussian { h: 1.0 };
        let c = compress(&ds, &kernel, &HssParams::near_exact(), 1);
        let x: Vec<f64> = (0..100).map(|_| rng.gauss()).collect();
        let plain = matvec(&c.hss, &x);
        let shifted = matvec_shifted(&c.hss, 2.5, &x);
        for i in 0..100 {
            testkit::assert_close(shifted[i], plain[i] + 2.5 * x[i], 1e-12);
        }
    }

    #[test]
    fn single_leaf_tree_is_dense() {
        let mut rng = Rng::new(32);
        let ds = synth::blobs(20, 2, 2, 0.2, &mut rng);
        let mut p = HssParams::near_exact();
        p.leaf_size = 64; // whole dataset in one leaf → root is a leaf
        let kernel = Kernel::Gaussian { h: 1.0 };
        let c = compress(&ds, &kernel, &p, 1);
        assert_eq!(c.hss.nodes.len(), 1);
        let kd = kernel.gram(&c.pds.x);
        let got = to_dense(&c.hss);
        testkit::assert_allclose(got.data(), kd.data(), 1e-10);
    }

    #[test]
    fn miri_matvec_threaded_scatter_matches_serial() {
        // Tiny instance for the Miri lane: both sweeps run with real
        // worker threads (run_levels caps threads at the widest level,
        // so leaf_size 8 over 24 points gives genuine parallelism) and
        // must reproduce the serial order bit-for-bit.
        let mut rng = Rng::new(34);
        let ds = synth::blobs(24, 2, 2, 0.3, &mut rng);
        let mut p = HssParams::near_exact();
        p.leaf_size = 8;
        let c = compress(&ds, &Kernel::Gaussian { h: 0.9 }, &p, 1);
        let x: Vec<f64> = (0..24).map(|_| rng.gauss()).collect();
        let serial = matvec_threads(&c.hss, &x, 1);
        let par = matvec_threads(&c.hss, &x, 2);
        assert_eq!(serial, par, "thread count must not change bits");
    }

    #[test]
    fn probe_error_estimator_agrees_with_dense_error() {
        let mut rng = Rng::new(33);
        let ds = synth::blobs(250, 3, 4, 0.4, &mut rng);
        let kernel = Kernel::Gaussian { h: 2.0 };
        let mut p = HssParams::low_accuracy();
        p.leaf_size = 32;
        let c = compress(&ds, &kernel, &p, 1);
        let dense_err = {
            let want = kernel.gram(&c.pds.x);
            let got = to_dense(&c.hss);
            let mut d = got;
            d.axpy(-1.0, &want);
            d.fro() / want.fro()
        };
        let probe_err = rel_error_probes(&c.hss, &kernel, &c.pds, 8, &mut rng);
        // probe estimate measures ‖(K−K̃)x‖/‖Kx‖ which is within a small
        // factor of the Frobenius ratio for random x
        assert!(
            probe_err <= dense_err * 10.0 + 1e-12 && probe_err * 100.0 + 1e-12 >= dense_err,
            "probe {probe_err} vs dense {dense_err}"
        );
    }
}
