//! ULV-style factorization and solve of the shifted HSS matrix K̃ + βI.
//!
//! Implements the two-sided orthogonal elimination of
//! Chandrasekaran–Gu–Pals (SIMAX 2006, ref [8] of the paper), adapted to
//! the symmetric skeleton-based representation produced by
//! [`crate::hss::compress`]:
//!
//! * at each node the basis U is QL-compressed — a full orthogonal Q with
//!   QᵀU = [0; Ũ] — so the first m−r rotated rows decouple from all
//!   off-diagonal blocks and can be eliminated with a local LU;
//! * the Schur complement S and reduced basis Ũ are passed to the parent,
//!   which merges its two children into a small (r_l + r_r) block and
//!   recurses;
//! * the root block is factorized densely.
//!
//! Total cost O(d·m²) with m ≤ max(leaf, 2·max_rank); every subsequent
//! solve costs O(d·m) — this is the "one cheap solve per ADMM iteration"
//! that the whole paper turns on. The shift β only touches the diagonal
//! blocks, so re-factorizing for a new β reuses the compression verbatim.
//!
//! Solves are *blocked*: [`UlvFactor::solve_mat`] sweeps an n×k block of
//! right-hand sides through the hierarchy with BLAS-3 per-node matmuls
//! (one O(d·m·k) GEMM-dominated sweep instead of k O(d·m) vector
//! sweeps), which is how the C-grid search batches every ADMM iteration
//! across all penalty values at once.

use crate::hss::plan::LevelSchedule;
use crate::hss::Hss;
use crate::linalg::blas::{matmul, Trans};
use crate::linalg::lu::Lu;
use crate::linalg::qr::Qr;
use crate::linalg::Mat;
use crate::util::threadpool;
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Minimum `n * k` RHS elements before a solve sweep goes parallel:
/// each sweep spawns (and joins) one worker pool, and below ~8k elements
/// the two spawns cost more than the parallel node work saves, so small
/// solves stay on the serial order (bitwise identical either way). Under
/// Miri the threshold drops to 0 so the tiny `miri_*` suites cross the
/// real multi-thread scatter paths.
const SWEEP_PAR_MIN_ELEMS: usize = if cfg!(miri) { 0 } else { 8192 };

/// Factorized (K̃ + shift·I) ready for repeated solves.
pub struct UlvFactor {
    n: usize,
    shift: f64,
    /// Worker threads for the level-scheduled sweeps (results are
    /// bit-for-bit independent of this — see the module docs).
    threads: usize,
    /// Level schedule shared with the source HSS matrix.
    plan: LevelSchedule,
    nodes: Vec<UlvNode>,
}

struct UlvNode {
    begin: usize,
    end: usize,
    left: Option<usize>,
    right: Option<usize>,
    /// Rank surviving after elimination (0 at root).
    rank: usize,
    /// Eliminated rows e = m − rank.
    e: usize,
    /// Orthogonal rotation with Qᵀ U = [0; Ũ]; `None` = identity.
    q: Option<Mat>,
    /// LU of the leading e×e block of the rotated diagonal.
    lu11: Lu,
    /// Rotated off-diagonal blocks of the local diagonal.
    d21: Mat, // rank × e
    /// D11⁻¹ D12 (e × rank), precomputed for the downsweep.
    f: Mat,
}

impl UlvFactor {
    /// Factor K̃ + shift·I serially. Fails only if an elimination block
    /// is numerically singular (cannot happen for PSD K̃ and shift > 0
    /// unless the compression destroyed positive-definiteness badly).
    pub fn new(h: &Hss, shift: f64) -> Result<Self> {
        Self::new_threaded(h, shift, 1)
    }

    /// Factor K̃ + shift·I with a level-scheduled worker pool: the
    /// per-node QR/LU eliminations of one tree level are independent
    /// (each consumes only its children's Schur/basis reductions), so
    /// they run in parallel with a barrier per level. Per-node
    /// arithmetic is exactly the serial path's, so the factor is
    /// bit-for-bit identical for every `threads` value.
    pub fn new_threaded(h: &Hss, shift: f64, threads: usize) -> Result<Self> {
        let nn = h.nodes.len();
        let plan = h.plan.clone();
        let mut slots: Vec<Option<UlvNode>> = (0..nn).map(|_| None).collect();
        // Passed-up reductions: (schur, utilde) per node.
        let mut reduced: Vec<Option<(Mat, Mat)>> = (0..nn).map(|_| None).collect();
        let failed = AtomicBool::new(false);
        let failure: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        {
            let node_cells = threadpool::disjoint(&mut slots);
            let red_cells = threadpool::disjoint(&mut reduced);
            let bottom_up = plan.bottom_up();
            threadpool::run_levels(threads, &bottom_up, |i| {
                // a singular block anywhere aborts the remaining levels;
                // Acquire pairs with the Release store below so a worker
                // observing the flag also observes the captured error
                // (the level barrier additionally publishes both)
                if failed.load(Ordering::Acquire) {
                    return;
                }
                // SAFETY: node i's reduction/slot cells are written only
                // by node i's task (ids are unique within the schedule).
                match factor_node(h, shift, i, i == nn - 1, &red_cells) {
                    Ok((node, red)) => unsafe {
                        *red_cells.get(i) = red;
                        *node_cells.get(i) = Some(node);
                    },
                    Err(e) => {
                        *failure.lock().unwrap() = Some(e);
                        // Release: publish the captured error before the
                        // flag that announces it
                        failed.store(true, Ordering::Release);
                    }
                }
            });
        }
        // Acquire pairs with the workers' Release store (the scope join
        // already synchronizes, but keep the flag's ordering uniform).
        if failed.load(Ordering::Acquire) {
            let err = failure
                .into_inner()
                .unwrap()
                .unwrap_or_else(|| anyhow!("ULV factorization failed"));
            return Err(err);
        }
        let nodes: Vec<UlvNode> = slots.into_iter().map(|s| s.expect("node factored")).collect();
        Ok(UlvFactor { n: h.n, shift, threads: threads.max(1), plan, nodes })
    }

    /// The shift this factorization was built with.
    pub fn shift(&self) -> f64 {
        self.shift
    }

    /// Matrix order.
    pub fn dim(&self) -> usize {
        self.n
    }

    /// Approximate memory held by the factorization.
    pub fn memory_bytes(&self) -> usize {
        let mut total = 0usize;
        for nd in &self.nodes {
            if let Some(q) = &nd.q {
                total += q.bytes();
            }
            total += (nd.e * nd.e + nd.d21.rows() * nd.d21.cols() + nd.f.rows() * nd.f.cols())
                * std::mem::size_of::<f64>();
        }
        total
    }

    /// Solve (K̃ + shift·I) x = b, both in tree (permuted) order.
    ///
    /// Delegates to the blocked multi-RHS path with a one-column block,
    /// so a scalar solve and column j of a batched [`UlvFactor::solve_mat`]
    /// are bit-for-bit identical — the property the batched ADMM C-grid
    /// is validated against.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let bm = Mat::from_vec(b.len(), 1, b.to_vec());
        self.solve_mat(&bm).col(0)
    }

    /// Solve (K̃ + shift·I) X = B for an n×k block of right-hand sides.
    ///
    /// Multi-RHS ULV up/downsweep: the per-node Qᵀ rotations, eliminated-
    /// block LU solves and transfer applications are BLAS-3 matmuls over
    /// the k-wide RHS block, so each node's operators stream through
    /// cache once per sweep instead of once per column. This is the
    /// kernel that lets [`crate::admm::AdmmSolver::run_grid`] advance a
    /// whole C-grid with a single factorization sweep per iteration.
    ///
    /// Column invariance: gemm and the blocked LU substitution compute
    /// column j by an op sequence independent of the other columns, so
    /// `solve_mat(b).col(j)` equals `solve(&b.col(j))` bit-for-bit.
    ///
    /// Both sweeps are level-scheduled: nodes of a level touch disjoint
    /// per-node state (and, in the downsweep, disjoint RHS row ranges of
    /// the output), so they run in parallel over the factor's worker
    /// pool with a barrier per level — bit-for-bit identical to the
    /// serial order for every thread count.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows(), self.n);
        let k = b.cols();
        let nn = self.nodes.len();
        let sweep_threads =
            if self.n * k.max(1) >= SWEEP_PAR_MIN_ELEMS { self.threads } else { 1 };
        // upsweep state: y1 = eliminated unknowns, bred = reduced RHS
        let mut y1: Vec<Mat> = vec![Mat::zeros(0, 0); nn];
        let mut bred: Vec<Mat> = vec![Mat::zeros(0, 0); nn];
        {
            let y1c = threadpool::disjoint(&mut y1);
            let brc = threadpool::disjoint(&mut bred);
            let bottom_up = self.plan.bottom_up();
            threadpool::run_levels(sweep_threads, &bottom_up, |i| {
                let nd = &self.nodes[i];
                // SAFETY: children belong to completed levels; this
                // level's writes go only to node i's own slots.
                let bloc: Mat = match (nd.left, nd.right) {
                    (None, None) => b.block(nd.begin, 0, nd.end - nd.begin, k),
                    (Some(l), Some(r)) => unsafe { (*brc.get(l)).vstack(&*brc.get(r)) },
                    _ => unreachable!("binary tree"),
                };
                // rotate: c = Qᵀ B_loc
                let c = match &nd.q {
                    Some(q) => matmul(q, Trans::Yes, &bloc, Trans::No),
                    None => bloc,
                };
                let c1 = c.block(0, 0, nd.e, k);
                let c2 = c.block(nd.e, 0, nd.rank, k);
                let yl = nd.lu11.solve_mat(&c1);
                // bred = c2 − D21 Y1
                let mut br = c2;
                if nd.e > 0 && nd.rank > 0 {
                    let d21y = matmul(&nd.d21, Trans::No, &yl, Trans::No);
                    br.axpy(-1.0, &d21y);
                }
                // SAFETY: y1[i]/bred[i] are node i's own slots; each id
                // runs exactly once per sweep.
                unsafe {
                    *y1c.get(i) = yl;
                    *brc.get(i) = br;
                }
            });
        }

        // downsweep
        let mut x = Mat::zeros(self.n, k);
        let mut x2: Vec<Mat> = vec![Mat::zeros(0, k); nn];
        {
            let xc = threadpool::disjoint(x.data_mut());
            let x2c = threadpool::disjoint(&mut x2);
            let y1c = threadpool::disjoint(&mut y1);
            let top_down = self.plan.top_down();
            threadpool::run_levels(sweep_threads, &top_down, |i| {
                let nd = &self.nodes[i];
                // SAFETY: x2[i]/y1[i] are node i's own slots (the parent
                // wrote x2[i] in an earlier level); leaf output rows
                // begin..end are disjoint across a level.
                let x2l = unsafe { std::mem::replace(&mut *x2c.get(i), Mat::zeros(0, 0)) };
                debug_assert_eq!(x2l.rows(), nd.rank);
                // X1 = Y1 − F X2
                let mut x1 = unsafe { std::mem::replace(&mut *y1c.get(i), Mat::zeros(0, 0)) };
                if nd.e > 0 && nd.rank > 0 {
                    let fx2 = matmul(&nd.f, Trans::No, &x2l, Trans::No);
                    x1.axpy(-1.0, &fx2);
                }
                // Z = [X1; X2], un-rotate
                let z = x1.vstack(&x2l);
                let xloc = match &nd.q {
                    Some(q) => matmul(q, Trans::No, &z, Trans::No),
                    None => z,
                };
                match (nd.left, nd.right) {
                    (None, None) => {
                        let rows = nd.end - nd.begin;
                        // SAFETY: x is row-major, so leaf rows begin..end
                        // form one contiguous range of length rows·k;
                        // leaf ranges are disjoint across the level.
                        let dst = unsafe { xc.slice(nd.begin * k, rows * k) };
                        dst.copy_from_slice(xloc.data());
                    }
                    (Some(l), Some(r)) => {
                        let rl = self.nodes[l].rank;
                        // SAFETY: the children's x2 slots are written
                        // only by this parent (one parent per child) and
                        // consumed in a later level after the barrier.
                        unsafe {
                            *x2c.get(l) = xloc.block(0, 0, rl, k);
                            *x2c.get(r) = xloc.block(rl, 0, xloc.rows() - rl, k);
                        }
                    }
                    _ => unreachable!(),
                }
            });
        }
        x
    }
}

/// One node's elimination step (shared verbatim by the serial and
/// level-parallel factorization paths): build the local shifted diagonal
/// block and basis (leaf) or merge the children's reductions (internal),
/// QL-rotate, LU-eliminate the decoupled rows, and pass the Schur
/// complement + reduced basis up. Returns the factored node and
/// `Some((schur, utilde))` for non-root nodes.
fn factor_node(
    h: &Hss,
    shift: f64,
    i: usize,
    is_root: bool,
    reduced: &threadpool::SendCells<'_, Option<(Mat, Mat)>>,
) -> Result<(UlvNode, Option<(Mat, Mat)>)> {
    let node = &h.nodes[i];

    // local diagonal block + local basis
    let (dloc, uloc): (Mat, Option<Mat>) = if node.is_leaf() {
        let mut d = node.d.clone().expect("leaf has D");
        d.shift_diag(shift);
        (d, node.u.clone())
    } else {
        let (li, ri) = (node.left.unwrap(), node.right.unwrap());
        // SAFETY: children were reduced in a completed deeper level and
        // have exactly one consumer (this parent), so taking ownership
        // here both is race-free and frees each reduction as soon as it
        // is merged — same peak memory as the serial path.
        let (s1, ut1) = unsafe { (*reduced.get(li)).take() }.expect("left reduced");
        let (s2, ut2) = unsafe { (*reduced.get(ri)).take() }.expect("right reduced");
        let b = node.b.as_ref().expect("internal has B");
        let (r1, r2) = (s1.rows(), s2.rows());
        // off-diagonal coupling in reduced coordinates
        let c12 = if r1 > 0 && r2 > 0 {
            let tb = matmul(&ut1, Trans::No, b, Trans::No);
            matmul(&tb, Trans::No, &ut2, Trans::Yes)
        } else {
            Mat::zeros(r1, r2)
        };
        let mut d = Mat::zeros(r1 + r2, r1 + r2);
        d.set_block(0, 0, &s1);
        d.set_block(r1, r1, &s2);
        d.set_block(0, r1, &c12);
        d.set_block(r1, 0, &c12.transpose());
        // merged basis: [Ũ₁ R₁ ; Ũ₂ R₂]
        let u = node.u.as_ref().map(|u_stack| {
            let top = u_stack.block(0, 0, r1, u_stack.cols());
            let bot = u_stack.block(r1, 0, r2, u_stack.cols());
            let mt = if r1 > 0 { matmul(&ut1, Trans::No, &top, Trans::No) } else { top };
            let mb = if r2 > 0 { matmul(&ut2, Trans::No, &bot, Trans::No) } else { bot };
            mt.vstack(&mb)
        });
        (d, u)
    };

    let m = dloc.rows();
    if is_root {
        // eliminate everything densely
        let lu11 = match Lu::new(&dloc) {
            Ok(f) => f,
            Err(e) => bail!("ULV root block singular: {e}"),
        };
        let root = UlvNode {
            begin: node.begin,
            end: node.end,
            left: node.left,
            right: node.right,
            rank: 0,
            e: m,
            q: None,
            lu11,
            d21: Mat::zeros(0, m),
            f: Mat::zeros(m, 0),
        };
        return Ok((root, None));
    }

    let u = uloc.expect("non-root node has U");
    debug_assert_eq!(u.rows(), m);
    let r = u.cols().min(m);
    let e = m - r;

    // QL compression via QR: full Q = [range | null] → reorder to
    // [null | range] so QᵀU = [0; Ũ].
    let (q, utilde, dtil) = if r == 0 {
        (None, Mat::zeros(0, 0), dloc)
    } else if e == 0 {
        // no elimination possible; Ũ = U unchanged, Q = I
        (None, u.clone(), dloc)
    } else {
        let qr = Qr::new(&u);
        let qf = qr.full_q(); // m×m, first r cols = range
        let order: Vec<usize> = (r..m).chain(0..r).collect();
        let q = qf.select_cols(&order);
        let utilde = qr.r().block(0, 0, r, r); // r×r upper tri
        let tmp = matmul(&q, Trans::Yes, &dloc, Trans::No);
        let dtil = matmul(&tmp, Trans::No, &q, Trans::No);
        (Some(q), utilde, dtil)
    };

    // partition and eliminate the leading e rows
    let d11 = dtil.block(0, 0, e, e);
    let d12 = dtil.block(0, e, e, r);
    let d21 = dtil.block(e, 0, r, e);
    let d22 = dtil.block(e, e, r, r);
    let lu11 = match Lu::new(&d11) {
        Ok(f) => f,
        Err(err) => bail!(
            "ULV elimination block singular at node {i} (size {e}): {err}; \
             increase the shift β or tighten compression tolerances"
        ),
    };
    let f = lu11.solve_mat(&d12); // e×r
    let mut s = d22;
    if e > 0 && r > 0 {
        let d21f = matmul(&d21, Trans::No, &f, Trans::No);
        s.axpy(-1.0, &d21f);
    }
    let un = UlvNode {
        begin: node.begin,
        end: node.end,
        left: node.left,
        right: node.right,
        rank: r,
        e,
        q,
        lu11,
        d21,
        f,
    };
    Ok((un, Some((s, utilde))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::hss::compress::compress;
    use crate::hss::matvec::matvec_shifted;
    use crate::hss::HssParams;
    use crate::kernel::Kernel;
    use crate::linalg::chol::Chol;
    use crate::util::prng::Rng;
    use crate::util::testkit;

    #[test]
    fn solve_inverts_shifted_matvec() {
        testkit::check("ulv-roundtrip", 6, |rng, _| {
            let n = 50 + rng.below(250);
            let ds = synth::blobs(n, 1 + rng.below(4), 3, 0.3, rng);
            let kernel = Kernel::Gaussian { h: 0.7 + rng.f64() };
            let c = compress(&ds, &kernel, &HssParams::near_exact(), 1);
            let beta = 0.5 + 2.0 * rng.f64();
            let ulv = UlvFactor::new(&c.hss, beta).unwrap();
            let want: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let b = matvec_shifted(&c.hss, beta, &want);
            let got = ulv.solve(&b);
            testkit::assert_allclose(&got, &want, 1e-7);
        });
    }

    #[test]
    fn solve_matches_dense_cholesky() {
        let mut rng = Rng::new(41);
        let n = 220;
        let ds = synth::blobs(n, 3, 4, 0.35, &mut rng);
        let kernel = Kernel::Gaussian { h: 1.2 };
        let c = compress(&ds, &kernel, &HssParams::near_exact(), 2);
        let beta = 1.0;
        // dense reference on the *same* (approximated) matrix
        let mut kd = kernel.gram(&c.pds.x);
        kd.shift_diag(beta);
        let chol = Chol::new(&kd).unwrap();
        let ulv = UlvFactor::new(&c.hss, beta).unwrap();
        let b: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let want = chol.solve(&b);
        let got = ulv.solve(&b);
        testkit::assert_allclose(&got, &want, 1e-6);
    }

    #[test]
    fn loose_compression_still_solves_its_own_matrix_exactly() {
        // ULV must invert K̃+βI (the approximation) to machine precision
        // even when K̃ is a rough approximation of K.
        let mut rng = Rng::new(42);
        let n = 300;
        let ds = synth::blobs(n, 4, 5, 0.4, &mut rng);
        let kernel = Kernel::Gaussian { h: 2.0 };
        let mut p = HssParams::low_accuracy();
        p.leaf_size = 48;
        let c = compress(&ds, &kernel, &p, 2);
        let beta = 10.0;
        let ulv = UlvFactor::new(&c.hss, beta).unwrap();
        let want: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let b = matvec_shifted(&c.hss, beta, &want);
        let got = ulv.solve(&b);
        testkit::assert_allclose(&got, &want, 1e-8);
    }

    #[test]
    fn single_leaf_tree_dense_solve() {
        let mut rng = Rng::new(43);
        let ds = synth::blobs(30, 2, 2, 0.3, &mut rng);
        let mut p = HssParams::near_exact();
        p.leaf_size = 100;
        let kernel = Kernel::Gaussian { h: 1.0 };
        let c = compress(&ds, &kernel, &p, 1);
        let ulv = UlvFactor::new(&c.hss, 2.0).unwrap();
        let want: Vec<f64> = (0..30).map(|_| rng.gauss()).collect();
        let b = matvec_shifted(&c.hss, 2.0, &want);
        testkit::assert_allclose(&ulv.solve(&b), &want, 1e-9);
    }

    #[test]
    fn solve_mat_columns_match_vector_solves_bitwise() {
        // the blocked multi-RHS sweep must reproduce each column of the
        // scalar solve exactly — the batched C-grid's correctness proof
        let mut rng = Rng::new(44);
        let ds = synth::blobs(120, 3, 3, 0.3, &mut rng);
        let kernel = Kernel::Gaussian { h: 1.0 };
        let c = compress(&ds, &kernel, &HssParams::near_exact(), 1);
        let ulv = UlvFactor::new(&c.hss, 1.5).unwrap();
        for ncols in [1usize, 3, 8] {
            let b = Mat::gauss(120, ncols, &mut rng);
            let x = ulv.solve_mat(&b);
            for j in 0..ncols {
                let want = ulv.solve(&b.col(j));
                assert_eq!(x.col(j), want, "column {j} of {ncols} not bitwise equal");
            }
        }
    }

    #[test]
    fn miri_ulv_threaded_scatter_matches_serial() {
        // Tiny instance for the Miri lane: SWEEP_PAR_MIN_ELEMS drops to 0
        // under Miri, so both the level-parallel factorization and the
        // up/downsweep row scatter run with real worker threads here, and
        // the result must still be bit-for-bit the serial order's.
        let mut rng = Rng::new(46);
        let ds = synth::blobs(24, 2, 2, 0.3, &mut rng);
        let mut p = HssParams::near_exact();
        p.leaf_size = 8;
        let c = compress(&ds, &Kernel::Gaussian { h: 0.8 }, &p, 1);
        let f1 = UlvFactor::new_threaded(&c.hss, 0.7, 1).unwrap();
        let f2 = UlvFactor::new_threaded(&c.hss, 0.7, 2).unwrap();
        let b = Mat::gauss(24, 3, &mut rng);
        let x1 = f1.solve_mat(&b);
        let x2 = f2.solve_mat(&b);
        assert_eq!(x1.data(), x2.data(), "thread count must not change bits");
    }

    #[test]
    fn memory_accounting_positive() {
        let mut rng = Rng::new(45);
        let ds = synth::blobs(150, 3, 3, 0.3, &mut rng);
        let c = compress(&ds, &Kernel::Gaussian { h: 1.0 }, &HssParams::near_exact(), 1);
        let ulv = UlvFactor::new(&c.hss, 1.0).unwrap();
        assert!(ulv.memory_bytes() > 0);
        assert_eq!(ulv.dim(), 150);
        assert_eq!(ulv.shift(), 1.0);
    }
}
