//! Hierarchically Semi-Separable (HSS) kernel-matrix approximation.
//!
//! Reimplements the STRUMPACK HSS-ANN construction of Chávez et al.
//! (IPDPS 2020, ref [10] of the paper) from scratch:
//!
//! * a binary cluster tree reorders the points ([`crate::cluster`]);
//! * every node's off-diagonal row block is compressed by a **row
//!   interpolative decomposition** of a *sampled* column subset —
//!   columns of approximate nearest neighbours outside the cluster
//!   (the geometry-aware part) plus uniform random columns;
//! * skeleton-based generators: all couplings `B` and diagonal blocks
//!   `D` are *actual kernel entries*, so the construction is partially
//!   matrix-free — the full d×d kernel matrix is never formed;
//! * the shifted matrix K̃ + βI is factorized once in ULV form
//!   ([`ulv`]) and reused for every ADMM iteration and every value of
//!   the penalty C in the grid search (the paper's headline trick).
//!
//! Storage is O(d·r), matvec and solve are O(d·r²) with r the maximum
//! HSS rank.

pub mod compress;
pub mod matvec;
pub mod plan;
pub mod ulv;

use crate::cluster::SplitMethod;
use crate::linalg::Mat;
use self::plan::LevelSchedule;

/// Compression parameters — mirrors the STRUMPACK knobs the paper sweeps
/// (Tables 4 and 5 list `hss_rel_tol`, `hss_abs_tol`, `hss_max_rank`,
/// `hss_approximate_neighbors`).
#[derive(Clone, Copy, Debug)]
pub struct HssParams {
    /// Relative ID truncation tolerance (`hss_rel_tol`).
    pub rel_tol: f64,
    /// Absolute ID truncation tolerance (`hss_abs_tol`).
    pub abs_tol: f64,
    /// Hard cap on any block rank (`hss_max_rank`).
    pub max_rank: usize,
    /// ANN neighbours per point used for column sampling
    /// (`hss_approximate_neighbors`).
    pub ann_neighbors: usize,
    /// Extra uniform random sample columns per node.
    pub oversample: usize,
    /// Cluster-tree leaf size.
    pub leaf_size: usize,
    /// Cluster splitting strategy.
    pub split: SplitMethod,
    /// Seed for sampling/clustering.
    pub seed: u64,
}

impl HssParams {
    /// Table 4 of the paper: the *low accuracy* STRUMPACK setting
    /// (`rel_tol=1, abs_tol=0.1, max_rank=200, neighbors=64`).
    pub fn low_accuracy() -> Self {
        HssParams {
            rel_tol: 1.0,
            abs_tol: 0.1,
            max_rank: 200,
            ann_neighbors: 64,
            oversample: 32,
            leaf_size: 128,
            split: SplitMethod::TwoMeans,
            seed: 0xB10C,
        }
    }

    /// Table 5 of the paper: the *high accuracy* setting
    /// (`rel_tol=0.05, abs_tol=0.5, max_rank=2000, neighbors=512`).
    pub fn high_accuracy() -> Self {
        HssParams {
            rel_tol: 0.05,
            abs_tol: 0.5,
            max_rank: 2000,
            ann_neighbors: 512,
            oversample: 64,
            leaf_size: 128,
            split: SplitMethod::TwoMeans,
            seed: 0xB10C,
        }
    }

    /// Same parameters, different sampling/clustering seed. The sharded
    /// consensus trainer (`admm::consensus`) derives one seed per shard
    /// with this (shard-major deterministic forks; shard 0 keeps the
    /// base seed so a K = 1 run IS the in-memory trainer bit-for-bit).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Tight tolerances for validation tests (near-exact compression).
    pub fn near_exact() -> Self {
        HssParams {
            rel_tol: 1e-10,
            abs_tol: 1e-12,
            max_rank: usize::MAX,
            ann_neighbors: 32,
            oversample: 1 << 16, // effectively "all columns" for small n
            leaf_size: 32,
            split: SplitMethod::TwoMeans,
            seed: 7,
        }
    }
}

/// One node of the HSS hierarchy (postorder array, mirrors the cluster
/// tree). Points are stored in *tree order*: node `i` owns the index
/// range `begin..end` of the permuted dataset.
pub struct HssNode {
    pub begin: usize,
    pub end: usize,
    pub left: Option<usize>,
    pub right: Option<usize>,
    /// Leaf: dense diagonal block D_i (unshifted).
    pub d: Option<Mat>,
    /// Row-basis generator.
    /// Leaf: U_i, (end−begin) × r_i.
    /// Internal: stacked transfers [R_left; R_right], (r_l + r_r) × r_i.
    /// Root: `None`.
    pub u: Option<Mat>,
    /// Internal/root: sibling coupling B = K(skel_left, skel_right),
    /// r_l × r_r (the r_r × r_l mirror is Bᵀ by symmetry).
    pub b: Option<Mat>,
    /// Skeleton rows of this node, as positions in the permuted dataset.
    pub skel: Vec<usize>,
}

impl HssNode {
    pub fn len(&self) -> usize {
        self.end - self.begin
    }

    pub fn is_empty(&self) -> bool {
        self.begin == self.end
    }

    pub fn is_leaf(&self) -> bool {
        self.left.is_none()
    }

    /// Rank of this node's basis (0 at the root).
    pub fn rank(&self) -> usize {
        self.skel.len()
    }
}

/// A compressed symmetric HSS kernel matrix.
pub struct Hss {
    /// Postorder node array; root last.
    pub nodes: Vec<HssNode>,
    /// Matrix order (number of training points).
    pub n: usize,
    /// `perm[p]` = original dataset index at permuted position p.
    pub perm: Vec<usize>,
    /// Inverse permutation.
    pub iperm: Vec<usize>,
    /// Parameters the matrix was compressed with.
    pub params: HssParams,
    /// Level schedule of the node array, shared by every traversal
    /// (matvec sweeps, ULV factorization/solves) — see [`plan`].
    pub plan: LevelSchedule,
}

/// Compression statistics (the HSS-Construction columns of Tables 4/5).
#[derive(Clone, Debug, Default)]
pub struct HssStats {
    /// Max rank over all off-diagonal blocks.
    pub max_rank: usize,
    /// Total memory of the representation in bytes.
    pub memory_bytes: usize,
    /// Number of kernel-entry evaluations performed during compression.
    pub kernel_evals: usize,
    /// Compression wall time (filled by callers).
    pub compress_secs: f64,
}

impl Hss {
    pub fn root(&self) -> usize {
        self.nodes.len() - 1
    }

    /// Max HSS rank across nodes.
    pub fn max_rank(&self) -> usize {
        self.nodes.iter().map(|n| n.rank()).max().unwrap_or(0)
    }

    /// Bytes held by all generators (D, U/R, B) — the paper's Memory[MB]
    /// column counts exactly this.
    pub fn memory_bytes(&self) -> usize {
        let mut total = 0;
        for node in &self.nodes {
            if let Some(d) = &node.d {
                total += d.bytes();
            }
            if let Some(u) = &node.u {
                total += u.bytes();
            }
            if let Some(b) = &node.b {
                total += b.bytes();
            }
            total += node.skel.len() * std::mem::size_of::<usize>();
        }
        total
    }

    /// Apply the stored permutation to a vector in original order.
    pub fn permute_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        self.perm.iter().map(|&o| x[o]).collect()
    }

    /// Undo the permutation.
    pub fn unpermute_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        self.iperm.iter().map(|&p| x[p]).collect()
    }
}
