//! HSS-ANN compression (partially matrix-free).
//!
//! Per node, the off-diagonal row block `K(I_node, I_nodeᶜ)` is never
//! formed: a **sample** of its columns — ANN columns (geometry-driven,
//! the [10] idea) plus uniform random columns — is evaluated, a row
//! interpolative decomposition picks skeleton rows, and the sampling
//! adaptively grows when the detected rank saturates the sample. All
//! retained quantities (D, B, skeletons) are exact kernel entries.

use crate::ann::{self, AnnParams, KnnLists};
use crate::cluster::ClusterTree;
use crate::data::Dataset;
use crate::hss::plan::LevelSchedule;
use crate::hss::{Hss, HssNode, HssParams, HssStats};
use crate::kernel::Kernel;
use crate::linalg::cpqr;
use crate::linalg::Mat;
use crate::util::prng::Rng;
use crate::util::threadpool;
use crate::util::timer::Timer;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Output of compression: the HSS matrix, the dataset **in tree order**
/// (callers do all further work in this order), and statistics.
pub struct Compressed {
    pub hss: Hss,
    /// Training set permuted to tree order (row p = original `perm[p]`).
    pub pds: Dataset,
    pub stats: HssStats,
}

/// Kernel-independent preprocessing: cluster tree, permuted dataset,
/// ANN lists. These do NOT depend on the kernel width h, so a grid
/// search over h computes them once (§Perf: 3× redundant ANN removed
/// from the h-grid) — see [`crate::coordinator::cache::KernelCache`].
pub struct Preprocessed {
    pub tree: ClusterTree,
    pub pds: Dataset,
    pub ann: ann::KnnLists,
    /// RNG state to continue sampling from (forked per compression).
    seed: u64,
}

/// Build the h-independent preprocessing state.
pub fn preprocess(ds: &Dataset, params: &HssParams, threads: usize) -> Preprocessed {
    let n = ds.len();
    assert!(n >= 2, "need at least 2 points");
    let mut rng = Rng::new(params.seed);
    let tree = ClusterTree::build(ds, params.leaf_size, params.split, &mut rng);
    let pds = ds.permute(&tree.perm);
    let k_ann = params.ann_neighbors.min(n.saturating_sub(1));
    let ann = if n <= 512 {
        ann::knn_exact(&pds, k_ann, threads)
    } else {
        let bucket = k_ann.clamp(64, 256).min(n);
        ann::knn(&pds, AnnParams { k: k_ann, trees: 3, bucket, refine: 1 }, threads, &mut rng)
    };
    Preprocessed { tree, pds, ann, seed: rng.next_u64() }
}

/// Compress the kernel matrix of `ds` into HSS form (one-call API).
pub fn compress(ds: &Dataset, kernel: &Kernel, params: &HssParams, threads: usize) -> Compressed {
    compress_with(crate::compute::cpu(), ds, kernel, params, threads)
}

/// [`compress`] on an explicit [`crate::compute::ComputeBackend`]: every
/// exact kernel block (leaf diagonals, sibling couplings, ID samples)
/// is evaluated through the backend.
pub fn compress_with(
    backend: &dyn crate::compute::ComputeBackend,
    ds: &Dataset,
    kernel: &Kernel,
    params: &HssParams,
    threads: usize,
) -> Compressed {
    let pre = preprocess(ds, params, threads);
    compress_preprocessed_with(backend, &pre, kernel, params, threads)
}

/// Compress reusing cached preprocessing (the h-grid hot path).
pub fn compress_preprocessed(
    pre: &Preprocessed,
    kernel: &Kernel,
    params: &HssParams,
    threads: usize,
) -> Compressed {
    compress_preprocessed_with(crate::compute::cpu(), pre, kernel, params, threads)
}

/// [`compress_preprocessed`] on an explicit backend.
pub fn compress_preprocessed_with(
    backend: &dyn crate::compute::ComputeBackend,
    pre: &Preprocessed,
    kernel: &Kernel,
    params: &HssParams,
    threads: usize,
) -> Compressed {
    let timer = Timer::start();
    let tree = &pre.tree;
    let pds = &pre.pds;
    let ann_lists = &pre.ann;
    let n = pds.len();
    let mut rng = Rng::new(pre.seed);

    // Bottom-up level-scheduled compression: nodes of a level are
    // independent (an internal node only needs its children's skeletons),
    // so the shared level schedule drives ALL subtree nodes of a level in
    // parallel — leaves and internal merges alike — with one worker-pool
    // spawn for the whole build.
    let plan = LevelSchedule::from_cluster_tree(tree);
    let n_nodes = tree.nodes.len();
    let kernel_evals = AtomicUsize::new(0);
    let mut slots: Vec<Option<HssNode>> = (0..n_nodes).map(|_| None).collect();

    // Per-node RNG forks, drawn from the shared stream in level-major
    // order (deepest level first, ascending ids) so the sampling is
    // deterministic regardless of the thread schedule.
    let bottom_up = plan.bottom_up();
    let mut seeds = vec![0u64; n_nodes];
    for level in &bottom_up {
        for &id in *level {
            seeds[id] = rng.fork(id as u64).next_u64();
        }
    }
    {
        let cells = threadpool::disjoint(&mut slots);
        threadpool::run_levels(threads, &bottom_up, |id| {
            let mut node_rng = Rng::new(seeds[id]);
            let built = compress_node(CompressCtx {
                node_id: id,
                tree,
                pds,
                kernel,
                params,
                backend,
                slots: &cells,
                ann: ann_lists,
                kernel_evals: &kernel_evals,
                rng: &mut node_rng,
            });
            // SAFETY: each node id is written exactly once, by its own task.
            unsafe { *cells.get(id) = Some(built) };
        });
    }

    let nodes: Vec<HssNode> = slots.into_iter().map(|s| s.expect("node built")).collect();

    // Passivity contract (DESIGN.md §14): trace events are emitted only
    // AFTER the level-scheduled worker scope joined, reading the already
    // built nodes — the sampling RNG and the parallel schedule never see
    // the tracer.
    if crate::obs::enabled() {
        for (level, ids) in plan.bottom_up().iter().enumerate() {
            crate::obs::emit(&crate::obs::TraceEvent::CompressLevel {
                level,
                nodes: ids.len(),
            });
            for &id in *ids {
                let nd = &nodes[id];
                crate::obs::emit(&crate::obs::TraceEvent::CompressNode {
                    node: id,
                    level,
                    leaf: tree.nodes[id].is_leaf(),
                    rank: nd.skel.len(),
                    rows: nd.u.as_ref().map(|u| u.rows()).unwrap_or(nd.end - nd.begin),
                    cols: nd.u.as_ref().map(|u| u.cols()).unwrap_or(0),
                });
            }
        }
    }

    let hss = Hss {
        nodes,
        n,
        perm: tree.perm.clone(),
        iperm: tree.iperm.clone(),
        params: *params,
        plan,
    };
    let stats = HssStats {
        max_rank: hss.max_rank(),
        memory_bytes: hss.memory_bytes(),
        // ORDERING: Relaxed — the worker scope already joined; this is a
        // single-threaded read of a statistics counter.
        kernel_evals: kernel_evals.load(Ordering::Relaxed),
        compress_secs: timer.secs(),
    };
    if crate::obs::enabled() {
        crate::obs::emit(&crate::obs::TraceEvent::CompressDone {
            max_rank: stats.max_rank,
            memory_bytes: stats.memory_bytes as u64,
            kernel_evals: stats.kernel_evals as u64,
            secs: stats.compress_secs,
        });
    }
    Compressed { hss, pds: pds.clone(), stats }
}

struct CompressCtx<'a> {
    node_id: usize,
    tree: &'a ClusterTree,
    pds: &'a Dataset,
    kernel: &'a Kernel,
    params: &'a HssParams,
    backend: &'a dyn crate::compute::ComputeBackend,
    /// Per-node output slots; children (built by earlier levels, the
    /// level barrier publishes them) are read through here.
    slots: &'a threadpool::SendCells<'a, Option<HssNode>>,
    ann: &'a KnnLists,
    kernel_evals: &'a AtomicUsize,
    rng: &'a mut Rng,
}

fn compress_node(ctx: CompressCtx<'_>) -> HssNode {
    let CompressCtx { node_id, tree, pds, kernel, params, backend, slots, ann, kernel_evals, rng } =
        ctx;
    let t = &tree.nodes[node_id];
    let n = pds.len();
    let is_root = t.begin == 0 && t.end == n;

    // Row set: leaf → all points of the node; internal → children skeletons.
    let (row_pos, d, b): (Vec<usize>, Option<Mat>, Option<Mat>) = if t.is_leaf() {
        let rows: Vec<usize> = (t.begin..t.end).collect();
        let pts = pds.x.select_rows(&rows);
        // ORDERING: Relaxed — pure statistics counter, read after join.
        kernel_evals.fetch_add(rows.len() * rows.len(), Ordering::Relaxed);
        let d = backend.kernel_block(kernel, &pts, &pts);
        (rows, Some(d), None)
    } else {
        // SAFETY: children were built in a deeper level; no task writes
        // them while this level runs (disjoint per-node ownership).
        let l = unsafe { (*slots.get(t.left.unwrap())).as_ref() }.expect("left child built");
        let r = unsafe { (*slots.get(t.right.unwrap())).as_ref() }.expect("right child built");
        let mut rows = l.skel.clone();
        rows.extend_from_slice(&r.skel);
        // Sibling coupling: exact kernel entries between skeletons.
        let lp = pds.x.select_rows(&l.skel);
        let rp = pds.x.select_rows(&r.skel);
        // ORDERING: Relaxed — pure statistics counter, read after join.
        kernel_evals.fetch_add(l.skel.len() * r.skel.len(), Ordering::Relaxed);
        let b = backend.kernel_block(kernel, &lp, &rp);
        (rows, None, Some(b))
    };

    if is_root {
        // Root has no off-diagonal block: only D (single-node tree) / B.
        return HssNode {
            begin: t.begin,
            end: t.end,
            left: t.left,
            right: t.right,
            d,
            u: None,
            b,
            skel: Vec::new(),
        };
    }

    // ---- column sampling of the complement ----
    let complement = n - t.len();
    let in_node = |p: usize| p >= t.begin && p < t.end;

    // ANN candidates: out-of-node neighbours of the row points, nearest
    // first (these dominate the off-diagonal block for decaying kernels).
    let mut ann_cand: Vec<(usize, f64)> = Vec::new();
    for &rp in &row_pos {
        for &(nb, d2) in &ann.neighbors[rp] {
            if !in_node(nb) {
                ann_cand.push((nb, d2));
            }
        }
    }
    ann_cand.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let mut cols: Vec<usize> = Vec::new();
    let mut seen = vec![false; n];
    let ann_budget = params.ann_neighbors.max(8);
    for (c, _) in ann_cand {
        if !seen[c] {
            seen[c] = true;
            cols.push(c);
            if cols.len() >= ann_budget {
                break;
            }
        }
    }

    // Uniform random complement columns (oversampling, guarantees the
    // sample sees far-field structure too).
    let add_random = |cols: &mut Vec<usize>, seen: &mut Vec<bool>, count: usize, rng: &mut Rng| {
        let mut added = 0;
        let mut guard = 0;
        while added < count && cols.len() < complement && guard < 50 * count + 100 {
            guard += 1;
            let p = rng.below(n);
            if !in_node(p) && !seen[p] {
                seen[p] = true;
                cols.push(p);
                added += 1;
            }
        }
    };
    add_random(&mut cols, &mut seen, params.oversample.min(complement), rng);

    // ---- adaptive row-ID ----
    let row_pts = pds.x.select_rows(&row_pos);
    let mut round = 0;
    #[allow(unused_assignments)]
    let (skel_local, u) = loop {
        let col_pts = pds.x.select_rows(&cols);
        // ORDERING: Relaxed — pure statistics counter, read after join.
        kernel_evals.fetch_add(row_pos.len() * cols.len(), Ordering::Relaxed);
        let sample = backend.kernel_block(kernel, &row_pts, &col_pts);
        let (j, x) = cpqr::row_id(&sample, params.rel_tol, params.abs_tol, params.max_rank);
        let saturated = j.len() == cols.len().min(row_pos.len()) && j.len() < params.max_rank;
        if saturated && cols.len() < complement && round < 3 {
            // rank saturated the sample: double the random columns
            let extra = cols.len().max(16);
            add_random(&mut cols, &mut seen, extra, rng);
            round += 1;
            continue;
        }
        break (j, x);
    };

    let skel: Vec<usize> = skel_local.iter().map(|&j| row_pos[j]).collect();
    HssNode {
        begin: t.begin,
        end: t.end,
        left: t.left,
        right: t.right,
        d,
        u: Some(u),
        b,
        skel,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::hss::matvec::to_dense;

    #[test]
    fn near_exact_compression_reconstructs_kernel() {
        let mut rng = Rng::new(21);
        let ds = synth::blobs(180, 3, 4, 0.3, &mut rng);
        let kernel = Kernel::Gaussian { h: 1.0 };
        let c = compress(&ds, &kernel, &HssParams::near_exact(), 2);
        // dense kernel of the permuted points
        let want = kernel.gram(&c.pds.x);
        let got = to_dense(&c.hss);
        let mut diff = got.clone();
        diff.axpy(-1.0, &want);
        let rel = diff.fro() / want.fro();
        assert!(rel < 1e-6, "near-exact compression rel error {rel}");
    }

    #[test]
    fn loose_tolerance_gives_smaller_memory_and_bounded_error() {
        let mut rng = Rng::new(22);
        let ds = synth::blobs(300, 4, 5, 0.4, &mut rng);
        let kernel = Kernel::Gaussian { h: 2.0 }; // smooth → compressible
        let tight = compress(&ds, &kernel, &HssParams::near_exact(), 2);
        let mut loose_p = HssParams::low_accuracy();
        loose_p.leaf_size = 32;
        let loose = compress(&ds, &kernel, &loose_p, 2);
        assert!(loose.stats.memory_bytes <= tight.stats.memory_bytes);
        let want = kernel.gram(&loose.pds.x);
        let got = to_dense(&loose.hss);
        let mut diff = got;
        diff.axpy(-1.0, &want);
        // rel_tol=1 is the paper's "very rough approximation" regime
        // (Table 4): large Frobenius error is EXPECTED — the surprising
        // finding of the paper is that classification survives it. The
        // approximation must still be finite and not amplified.
        let rel = diff.fro() / want.fro();
        assert!(rel.is_finite() && rel < 1.2, "loose compression diverged: {rel}");
    }

    #[test]
    fn compression_never_forms_full_matrix() {
        // kernel_evals must be o(n²) for a compressible kernel
        let mut rng = Rng::new(23);
        let n = 1200;
        let ds = synth::blobs(n, 3, 6, 0.25, &mut rng);
        let kernel = Kernel::Gaussian { h: 3.0 };
        let mut p = HssParams::low_accuracy();
        p.leaf_size = 64;
        p.ann_neighbors = 16;
        p.oversample = 16;
        let c = compress(&ds, &kernel, &p, 2);
        let full = n * n;
        assert!(
            c.stats.kernel_evals < full / 3,
            "kernel evals {} vs n² {}",
            c.stats.kernel_evals,
            full
        );
        assert!(c.stats.max_rank <= p.max_rank);
    }

    #[test]
    fn stats_are_consistent() {
        let mut rng = Rng::new(24);
        let ds = synth::blobs(150, 2, 3, 0.3, &mut rng);
        let c = compress(&ds, &Kernel::Gaussian { h: 1.0 }, &HssParams::near_exact(), 1);
        assert_eq!(c.hss.n, 150);
        assert_eq!(c.pds.len(), 150);
        assert_eq!(c.stats.memory_bytes, c.hss.memory_bytes());
        assert_eq!(c.stats.max_rank, c.hss.max_rank());
        assert!(c.stats.compress_secs >= 0.0);
        // permutation round-trip
        let x: Vec<f64> = (0..150).map(|i| i as f64).collect();
        let xp = c.hss.permute_vec(&x);
        let back = c.hss.unpermute_vec(&xp);
        assert_eq!(back, x);
    }

    #[test]
    fn miri_compress_threaded_scatter_matches_serial() {
        // Tiny instance for the Miri lane: the level-scheduled node
        // scatter runs with real worker threads and the compression must
        // be bit-for-bit the serial schedule's.
        let mut rng = Rng::new(26);
        let ds = synth::blobs(24, 2, 2, 0.3, &mut rng);
        let mut p = HssParams::near_exact();
        p.leaf_size = 8;
        let k = Kernel::Gaussian { h: 1.0 };
        let a = compress(&ds, &k, &p, 1);
        let b = compress(&ds, &k, &p, 2);
        assert_eq!(a.hss.perm, b.hss.perm);
        assert_eq!(
            to_dense(&a.hss).data(),
            to_dense(&b.hss).data(),
            "thread count must not change bits"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(25);
        let ds = synth::blobs(200, 3, 4, 0.3, &mut rng);
        let k = Kernel::Gaussian { h: 1.5 };
        let p = HssParams { seed: 99, ..HssParams::low_accuracy() };
        let a = compress(&ds, &k, &p, 3);
        let b = compress(&ds, &k, &p, 1); // thread count must not matter
        assert_eq!(a.hss.perm, b.hss.perm);
        assert_eq!(a.stats.max_rank, b.stats.max_rank);
        assert_eq!(a.stats.memory_bytes, b.stats.memory_bytes);
    }
}
