//! ε-insensitive Support Vector Regression through the same ADMM + HSS
//! machinery.
//!
//! The HSS-kernel literature the paper builds on (Chávez et al. [10],
//! Rebrova et al. [36]) targets kernel *ridge regression*; SVR is the
//! natural SVM-side counterpart and reuses every expensive component:
//!
//! dual (in d = α − α*):  min ½ dᵀK d − yᵀd + ε‖d‖₁
//!                        s.t. eᵀd = 0,  −C ≤ d ≤ C.
//!
//! ADMM splitting d − z = 0 gives
//! * d-update: the SAME (K + βI) solve + equality-projection as
//!   classification (with e in place of the labels),
//! * z-update: soft-threshold by ε/β then clip to [−C, C],
//! * multiplier update.
//!
//! One ULV factorization serves every (C, ε) pair of a grid search.

use crate::compute::ComputeBackend;
use crate::data::sparse::Points;
use crate::data::Dataset;
use crate::hss::ulv::UlvFactor;
use crate::hss::HssParams;
use crate::kernel::Kernel;
#[cfg(test)]
use crate::linalg::Mat;
use anyhow::Result;

/// SVR hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct SvrParams {
    pub beta: f64,
    pub max_it: usize,
    /// Insensitive-tube half width ε.
    pub epsilon: f64,
    /// Box bound C.
    pub c: f64,
}

impl Default for SvrParams {
    fn default() -> Self {
        SvrParams { beta: 10.0, max_it: 30, epsilon: 0.05, c: 10.0 }
    }
}

/// Trained regressor: f(t) = Σᵢ dᵢ K(svᵢ, t) + b.
#[derive(Clone)]
pub struct SvrModel {
    pub sv: Points,
    pub coef: Vec<f64>,
    pub bias: f64,
    pub kernel: Kernel,
}

impl SvrModel {
    pub fn n_sv(&self) -> usize {
        self.sv.rows()
    }

    pub fn predict_one(&self, t: &[f64]) -> f64 {
        let mut f = self.bias;
        match &self.sv {
            Points::Dense(m) => {
                for i in 0..m.rows() {
                    f += self.coef[i] * self.kernel.eval(m.row(i), t);
                }
            }
            Points::Sparse(_) => {
                // ‖t‖² hoisted out of the SV loop (see SvmModel::decision_one)
                let nt = crate::linalg::dot(t, t);
                for i in 0..self.n_sv() {
                    let ni = self.sv.dot_row(i, &self.sv, i);
                    let ab = self.sv.dot_dense_vec(i, t);
                    f += self.coef[i] * self.kernel.eval_from_parts(ni, nt, ab);
                }
            }
        }
        f
    }

    /// Predictions for every row of x (dense or CSR).
    pub fn predict(&self, x: &Points) -> Vec<f64> {
        self.predict_backend(crate::compute::cpu(), x)
    }

    /// [`Self::predict`] on an explicit [`ComputeBackend`]. The
    /// all-dense pointwise fast path is backend-independent by design
    /// (it predates the block path and is bitwise-pinned), so the
    /// backend only drives the kernel block of mixed/sparse pairings.
    pub fn predict_backend(&self, backend: &dyn ComputeBackend, x: &Points) -> Vec<f64> {
        if let (Points::Dense(xm), Points::Dense(_)) = (x, &self.sv) {
            // the original pointwise path — all-dense predictions stay
            // bit-for-bit unchanged (and agree with predict_one); any
            // sparse operand uses the block path with hoisted norms
            return (0..xm.rows()).map(|i| self.predict_one(xm.row(i))).collect();
        }
        let sv_norms = self.sv.self_norms();
        let x_norms = x.self_norms();
        let kb = backend.kernel_block_with_norms(&self.kernel, x, &x_norms, &self.sv, &sv_norms);
        (0..x.rows())
            .map(|i| {
                self.bias
                    + kb.row(i).iter().zip(self.coef.iter()).map(|(k, c)| k * c).sum::<f64>()
            })
            .collect()
    }

    /// Mean squared error on labelled data (`targets` real-valued).
    pub fn mse(&self, x: &Points, targets: &[f64]) -> f64 {
        let pred = self.predict(x);
        pred.iter().zip(targets.iter()).map(|(p, t)| (p - t) * (p - t)).sum::<f64>()
            / targets.len().max(1) as f64
    }
}

/// Train SVR on (points, real-valued targets) with an HSS-compressed
/// kernel. `ds.y` is ignored; pass targets separately.
pub fn train_svr(
    points: &Dataset,
    targets: &[f64],
    kernel: Kernel,
    hss_params: &HssParams,
    params: &SvrParams,
    threads: usize,
) -> Result<SvrModel> {
    assert_eq!(points.len(), targets.len());
    let n = points.len();
    let trainer = crate::svm::HssSvmTrainer::compress(points, kernel, hss_params, threads);
    let ulv: UlvFactor = trainer.factor(params.beta)?;
    let hss = &trainer.compressed.hss;
    // permute targets to tree order
    let yt: Vec<f64> = hss.perm.iter().map(|&o| targets[o]).collect();

    let beta = params.beta;
    // w = K_β⁻¹ e, w1 = eᵀw (equality-constraint projection pieces)
    let e = vec![1.0; n];
    let w = ulv.solve(&e);
    let w1: f64 = w.iter().sum();

    let mut z = vec![0.0; n];
    let mut mu = vec![0.0; n];
    let mut d = vec![0.0; n];
    let mut q = vec![0.0; n];
    for _k in 0..params.max_it {
        // d-update: min ½dᵀKd − yᵀd − μᵀ(d−z) + β/2‖d−z‖² s.t. eᵀd=0
        //   ⇒ (K+βI)d = y + μ + βz − λe with λ eliminating eᵀd
        for i in 0..n {
            q[i] = yt[i] + mu[i] + beta * z[i];
        }
        let v = ulv.solve(&q);
        let lam = v.iter().sum::<f64>() / w1;
        for i in 0..n {
            d[i] = v[i] - lam * w[i];
        }
        // z-update: soft-threshold (the ε‖z‖₁ prox) then box clip
        let thr = params.epsilon / beta;
        for i in 0..n {
            let t = d[i] - mu[i] / beta;
            let soft = if t > thr {
                t - thr
            } else if t < -thr {
                t + thr
            } else {
                0.0
            };
            z[i] = soft.clamp(-params.c, params.c);
        }
        // multiplier
        for i in 0..n {
            mu[i] -= beta * (d[i] - z[i]);
        }
    }

    // bias from tube-interior residuals: for |z_i| ∈ (0, C),
    // y_i − f_raw(x_i) = ε·sign(z_i) ⇒ b = mean(y_i − (K z)_i − ε sign)
    let kz = trainer.backend.hss_matvec(hss, &z, 1);
    let mut acc = 0.0;
    let mut cnt = 0.0;
    for i in 0..n {
        let a = z[i].abs();
        if a > 1e-8 * params.c && a < params.c * (1.0 - 1e-6) {
            acc += yt[i] - kz[i] - params.epsilon * z[i].signum();
            cnt += 1.0;
        }
    }
    let bias = if cnt > 0.0 {
        acc / cnt
    } else {
        // fall back: average residual
        (0..n).map(|i| yt[i] - kz[i]).sum::<f64>() / n as f64
    };

    // keep nonzero coefficients
    let idx: Vec<usize> = (0..n).filter(|&i| z[i].abs() > 1e-10).collect();
    let sv = trainer.compressed.pds.x.select_rows(&idx);
    let coef: Vec<f64> = idx.iter().map(|&i| z[i]).collect();
    Ok(SvrModel { sv, coef, bias, kernel })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// 1-D sinc regression set.
    fn sinc(n: usize, noise: f64, rng: &mut Rng) -> (Dataset, Vec<f64>) {
        let mut x = Mat::zeros(n, 1);
        let mut t = Vec::with_capacity(n);
        for i in 0..n {
            let xi = rng.range(-5.0, 5.0);
            x[(i, 0)] = xi;
            let s = if xi.abs() < 1e-9 { 1.0 } else { xi.sin() / xi };
            t.push(s + rng.gauss() * noise);
        }
        let y = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        (Dataset::new("sinc", x, y), t)
    }

    #[test]
    fn fits_sinc_well() {
        let mut rng = Rng::new(701);
        let (train, t_train) = sinc(400, 0.02, &mut rng);
        let (test, t_test) = sinc(200, 0.0, &mut rng);
        let model = train_svr(
            &train,
            &t_train,
            Kernel::Gaussian { h: 0.7 },
            &HssParams::near_exact(),
            &SvrParams { beta: 10.0, max_it: 60, epsilon: 0.02, c: 10.0 },
            1,
        )
        .unwrap();
        let mse = model.mse(&test.x, &t_test);
        assert!(mse < 0.01, "sinc MSE {mse}");
        assert!(model.n_sv() > 0);
    }

    #[test]
    fn epsilon_tube_sparsifies() {
        // larger ε ⇒ more points inside the tube ⇒ fewer SVs
        let mut rng = Rng::new(702);
        let (train, t_train) = sinc(300, 0.02, &mut rng);
        let mk = |eps: f64| {
            train_svr(
                &train,
                &t_train,
                Kernel::Gaussian { h: 0.7 },
                &HssParams::near_exact(),
                &SvrParams { beta: 10.0, max_it: 60, epsilon: eps, c: 10.0 },
                1,
            )
            .unwrap()
        };
        let tight = mk(0.005);
        let loose = mk(0.2);
        assert!(
            loose.n_sv() < tight.n_sv(),
            "ε=0.2 should give fewer SVs: {} vs {}",
            loose.n_sv(),
            tight.n_sv()
        );
    }

    #[test]
    fn constant_function_learned_via_bias() {
        let mut rng = Rng::new(703);
        let (train, _) = sinc(100, 0.0, &mut rng);
        let targets = vec![3.25; 100];
        let model = train_svr(
            &train,
            &targets,
            Kernel::Gaussian { h: 1.0 },
            &HssParams::near_exact(),
            &SvrParams { beta: 10.0, max_it: 40, epsilon: 0.1, c: 5.0 },
            1,
        )
        .unwrap();
        let mse = model.mse(&train.x, &targets);
        assert!(mse < 0.02, "constant fit MSE {mse}");
    }
}
