//! One-vs-one multiclass SVM on top of the binary ADMM + HSS trainer
//! (LIBSVM's multiclass strategy). Each pair of classes gets its own
//! binary classifier; prediction is majority vote.
//!
//! The kernel-reuse story survives: every pairwise subproblem compresses
//! only its own points, and the compressions across pairs are
//! independent, so a C grid per pair still reuses its factorization.

use crate::admm::AdmmParams;
use crate::data::sparse::Points;
use crate::data::Dataset;
use crate::hss::HssParams;
use crate::kernel::Kernel;
#[cfg(test)]
use crate::linalg::Mat;
use crate::svm::{predict, train::train_hss_svm, SvmModel};
use anyhow::{bail, Result};

/// A labelled multiclass dataset (labels are arbitrary integers).
pub struct MulticlassDataset {
    pub x: Points,
    pub labels: Vec<i64>,
}

impl MulticlassDataset {
    pub fn classes(&self) -> Vec<i64> {
        let mut c: Vec<i64> = self.labels.clone();
        c.sort_unstable();
        c.dedup();
        c
    }
}

/// One-vs-one multiclass model.
pub struct OvoModel {
    /// (class_a, class_b, binary model voting a (+1) vs b (−1)).
    pub pairs: Vec<(i64, i64, SvmModel)>,
    pub classes: Vec<i64>,
}

/// Train all k(k−1)/2 pairwise classifiers.
pub fn train_ovo(
    ds: &MulticlassDataset,
    kernel: Kernel,
    hss: &HssParams,
    admm: &AdmmParams,
    c: f64,
    threads: usize,
) -> Result<OvoModel> {
    let classes = ds.classes();
    if classes.len() < 2 {
        bail!("need at least 2 classes, got {:?}", classes);
    }
    let mut pairs = Vec::new();
    for (i, &a) in classes.iter().enumerate() {
        for &b in &classes[i + 1..] {
            let idx: Vec<usize> = (0..ds.labels.len())
                .filter(|&t| ds.labels[t] == a || ds.labels[t] == b)
                .collect();
            let x = ds.x.select_rows(&idx);
            let y: Vec<f64> =
                idx.iter().map(|&t| if ds.labels[t] == a { 1.0 } else { -1.0 }).collect();
            let sub = Dataset::new(format!("{a}-vs-{b}"), x, y);
            let (model, _) = train_hss_svm(&sub, kernel, hss, admm, c, threads)?;
            pairs.push((a, b, model));
        }
    }
    Ok(OvoModel { pairs, classes })
}

impl OvoModel {
    /// Majority-vote prediction for each row of `x`.
    pub fn predict(&self, x: &Points, threads: usize) -> Vec<i64> {
        let n = x.rows();
        let k = self.classes.len();
        let mut votes = vec![vec![0u32; k]; n];
        let class_pos = |c: i64| self.classes.iter().position(|&x| x == c).unwrap();
        for (a, b, model) in &self.pairs {
            let f = predict::decision_function(model, x, threads);
            let (pa, pb) = (class_pos(*a), class_pos(*b));
            for (i, &fi) in f.iter().enumerate() {
                if fi >= 0.0 {
                    votes[i][pa] += 1;
                } else {
                    votes[i][pb] += 1;
                }
            }
        }
        votes
            .into_iter()
            .map(|v| {
                let best = v.iter().enumerate().max_by_key(|&(_, &c)| c).unwrap().0;
                self.classes[best]
            })
            .collect()
    }

    /// Accuracy against integer labels.
    pub fn accuracy(&self, ds: &MulticlassDataset, threads: usize) -> f64 {
        let pred = self.predict(&ds.x, threads);
        let hits = pred.iter().zip(ds.labels.iter()).filter(|(p, l)| p == l).count();
        hits as f64 / ds.labels.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// Three well-separated Gaussian blobs labelled 0/1/2.
    fn three_blobs(n: usize, rng: &mut Rng) -> MulticlassDataset {
        let centers = [[0.0, 0.0], [4.0, 0.0], [0.0, 4.0]];
        let mut x = Mat::zeros(n, 2);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % 3;
            x[(i, 0)] = centers[c][0] + rng.gauss() * 0.4;
            x[(i, 1)] = centers[c][1] + rng.gauss() * 0.4;
            labels.push(c as i64);
        }
        MulticlassDataset { x: x.into(), labels }
    }

    #[test]
    fn three_class_blobs_high_accuracy() {
        let mut rng = Rng::new(501);
        let train = three_blobs(300, &mut rng);
        let test = three_blobs(150, &mut rng);
        let model = train_ovo(
            &train,
            Kernel::Gaussian { h: 1.0 },
            &HssParams::near_exact(),
            &AdmmParams { beta: 10.0, max_it: 15, relax: 1.0, tol: 0.0 },
            10.0,
            1,
        )
        .unwrap();
        assert_eq!(model.pairs.len(), 3);
        assert_eq!(model.classes, vec![0, 1, 2]);
        let acc = model.accuracy(&test, 1);
        assert!(acc > 0.95, "ovo accuracy {acc}");
    }

    #[test]
    fn single_class_is_an_error() {
        let ds = MulticlassDataset { x: Mat::zeros(5, 2).into(), labels: vec![3; 5] };
        assert!(train_ovo(
            &ds,
            Kernel::Linear,
            &HssParams::near_exact(),
            &AdmmParams::default(),
            1.0,
            1
        )
        .is_err());
    }
}
