//! One-vs-one multiclass SVM on top of the binary ADMM + HSS trainer
//! (LIBSVM's multiclass strategy), productionized end-to-end.
//!
//! Training ([`train_ovo_grid`]): the k(k−1)/2 pairwise subproblems are
//! independent, so they run in *outer* parallelism across the worker
//! budget while each subproblem keeps the usual *inner* parallelism for
//! its compression/factorization/ADMM stages. The split is a pure
//! function of `(threads, n_pairs)` and every stage is bit-for-bit
//! thread-invariant (the level-scheduled engine contract), so trained
//! models are bitwise identical for any thread count. Each pair routes
//! its whole C grid through [`HssSvmTrainer::train_grid_with_solver`]:
//! one compression + one ULV factorization per pair serve every C value
//! in one lockstep multi-RHS ADMM sweep.
//!
//! Prediction ([`OvoEngine`]): pairwise models share support vectors
//! heavily (every training point sits in k−1 subproblems), so the
//! engine dedups the SVs of all pairs into one unique-SV pool,
//! evaluates ONE kernel block `K(test tile, pool)` per tile (gemm / CSR
//! dispatch through the selected [`ComputeBackend`]) and reduces each
//! pair's decision as a sparse weighted gather over that block —
//! instead of k(k−1)/2 full kernel blocks per tile. Results agree with
//! the naive per-pair path to ≤ 1e-12 ([`OvoModel::decisions_naive`] is
//! the oracle).
//!
//! Voting follows LIBSVM's deterministic rule: most votes wins; vote
//! ties fall back to the accumulated signed decision-value sums; a full
//! tie goes to the **lowest class index** (classes are kept sorted
//! ascending). The old `max_by_key` tie-break silently preferred the
//! *last* maximal class.

use crate::admm::{AdmmParams, AdmmSolver};
use crate::compute::ComputeBackend;
use crate::data::sparse::{CsrMat, Points};
use crate::data::Dataset;
use crate::hss::HssParams;
use crate::kernel::Kernel;
use crate::linalg::Mat;
use crate::svm::{predict, train::HssSvmTrainer, SvmModel};
use crate::util::threadpool;
use crate::util::timer::Timer;
use anyhow::{bail, Context, Result};

/// A labelled multiclass dataset (labels are arbitrary integers).
#[derive(Clone)]
pub struct MulticlassDataset {
    pub name: String,
    pub x: Points,
    pub labels: Vec<i64>,
}

impl MulticlassDataset {
    pub fn new(name: impl Into<String>, x: impl Into<Points>, labels: Vec<i64>) -> Self {
        let x = x.into();
        assert_eq!(x.rows(), labels.len(), "points/labels length mismatch");
        MulticlassDataset { name: name.into(), x, labels }
    }

    /// Distinct class labels, sorted ascending.
    pub fn classes(&self) -> Vec<i64> {
        let mut c: Vec<i64> = self.labels.clone();
        c.sort_unstable();
        c.dedup();
        c
    }

    pub fn len(&self) -> usize {
        self.x.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    pub fn is_sparse(&self) -> bool {
        self.x.is_sparse()
    }

    /// Subset by index list (in that order).
    pub fn select(&self, idx: &[usize]) -> MulticlassDataset {
        MulticlassDataset {
            name: self.name.clone(),
            x: self.x.select_rows(idx),
            labels: idx.iter().map(|&i| self.labels[i]).collect(),
        }
    }

    /// Split into (train, test) at `train_len` (no shuffling).
    pub fn split_at(&self, train_len: usize) -> (MulticlassDataset, MulticlassDataset) {
        assert!(train_len <= self.len());
        let tr: Vec<usize> = (0..train_len).collect();
        let te: Vec<usize> = (train_len..self.len()).collect();
        (self.select(&tr), self.select(&te))
    }
}

impl std::fmt::Debug for MulticlassDataset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "MulticlassDataset({}: {} pts × {} feats, {} classes{})",
            self.name,
            self.len(),
            self.dim(),
            self.classes().len(),
            if self.is_sparse() { ", sparse" } else { "" }
        )
    }
}

/// One pair's reduction inside the shared-SV engine: decision =
/// `bias + Σ entries (alpha · K(test, pool[row]))`, votes going to
/// class position `a_pos` (decision ≥ 0) or `b_pos` (< 0).
#[derive(Clone)]
struct PairReduce {
    a_pos: usize,
    b_pos: usize,
    bias: f64,
    /// `(pool row, αy)` in the pair model's own SV order, so the gather
    /// accumulates in exactly the per-pair order.
    entries: Vec<(usize, f64)>,
}

/// Shared-SV prediction engine: the unique-SV pool of all pairwise
/// models plus one sparse gather per pair. One kernel block of
/// test-tile × pool per tile serves every pair.
#[derive(Clone)]
pub struct OvoEngine {
    kernel: Kernel,
    classes: Vec<i64>,
    pool: Points,
    pool_norms: Vec<f64>,
    pairs: Vec<PairReduce>,
}

/// Bit-pattern key of one SV row (dense: the f64 bits of every slot;
/// CSR: interleaved column index / value bits). Two rows get the same
/// key iff they are bitwise-identical points, which is exactly the
/// dedup the pool needs (kernels depend on the feature bits only).
fn pool_row_key(x: &Points, i: usize) -> Vec<u64> {
    match x {
        Points::Dense(m) => m.row(i).iter().map(|v| v.to_bits()).collect(),
        Points::Sparse(s) => {
            let (ci, vi) = s.row(i);
            let mut k = Vec::with_capacity(2 * ci.len());
            for (&c, &v) in ci.iter().zip(vi.iter()) {
                k.push(c as u64);
                k.push(v.to_bits());
            }
            k
        }
    }
}

/// LIBSVM-style deterministic vote over one row of pairwise decisions:
/// most votes first, signed decision-value sums second, lowest class
/// index last (strict `>` comparisons walking positions in ascending
/// class order). Returns `(winning class position, its decision sum)`.
fn vote_row(k: usize, pair_pos: &[(usize, usize)], f: &[f64]) -> (usize, f64) {
    debug_assert_eq!(pair_pos.len(), f.len());
    let mut votes = vec![0u32; k];
    let mut sums = vec![0.0f64; k];
    for (p, &(pa, pb)) in pair_pos.iter().enumerate() {
        if f[p] >= 0.0 {
            votes[pa] += 1;
        } else {
            votes[pb] += 1;
        }
        sums[pa] += f[p];
        sums[pb] -= f[p];
    }
    let mut best = 0usize;
    for c in 1..k {
        if votes[c] > votes[best] || (votes[c] == votes[best] && sums[c] > sums[best]) {
            best = c;
        }
    }
    (best, sums[best])
}

impl OvoEngine {
    /// Build the engine from pairwise models (all sharing one kernel
    /// and one SV representation — guaranteed by training/persistence).
    fn build(classes: &[i64], pairs: &[(i64, i64, SvmModel)]) -> OvoEngine {
        let kernel = pairs[0].2.kernel;
        let sparse = pairs[0].2.sv.is_sparse();
        let dim = pairs[0].2.sv.cols();
        for (_, _, m) in pairs {
            assert_eq!(m.kernel, kernel, "OvO pairs must share one kernel");
            assert_eq!(m.sv.is_sparse(), sparse, "OvO pairs must share one SV representation");
            assert_eq!(m.sv.cols(), dim, "OvO pairs must share one feature dimension");
        }
        let pos = |c: i64| classes.iter().position(|&x| x == c).expect("class present");

        // dedup pass: first occurrence (pairs in order, SVs in order)
        // defines the pool row — deterministic and order-preserving
        let mut index: std::collections::HashMap<Vec<u64>, usize> = std::collections::HashMap::new();
        let mut sources: Vec<(usize, usize)> = Vec::new(); // (pair, sv row) of each pool row
        let mut reduces = Vec::with_capacity(pairs.len());
        for (p, (a, b, m)) in pairs.iter().enumerate() {
            let mut entries = Vec::with_capacity(m.n_sv());
            for i in 0..m.n_sv() {
                let key = pool_row_key(&m.sv, i);
                // (first-occurrence order: persistence serializes this
                // exact pool through `pool_points`/`gather`)
                let row = *index.entry(key).or_insert_with(|| {
                    sources.push((p, i));
                    sources.len() - 1
                });
                entries.push((row, m.alpha_y[i]));
            }
            reduces.push(PairReduce { a_pos: pos(*a), b_pos: pos(*b), bias: m.bias, entries });
        }

        // materialize the pool in the pairs' representation
        let pool: Points = if sparse {
            let rows: Vec<Vec<(usize, f64)>> = sources
                .iter()
                .map(|&(p, i)| {
                    let Points::Sparse(s) = &pairs[p].2.sv else { unreachable!() };
                    let (ci, vi) = s.row(i);
                    ci.iter().zip(vi.iter()).map(|(&c, &v)| (c, v)).collect()
                })
                .collect();
            CsrMat::from_rows(dim, &rows).into()
        } else {
            let mut m = Mat::zeros(sources.len(), dim);
            for (r, &(p, i)) in sources.iter().enumerate() {
                m.row_mut(r).copy_from_slice(pairs[p].2.sv.dense_row(i));
            }
            m.into()
        };
        let pool_norms = pool.self_norms();
        OvoEngine { kernel, classes: classes.to_vec(), pool, pool_norms, pairs: reduces }
    }

    /// Unique SVs in the pool.
    pub fn pool_size(&self) -> usize {
        self.pool.rows()
    }

    /// The unique-SV pool itself — persistence writes this verbatim as
    /// the shared-pool file section (so the on-disk layout is always
    /// the layout the engine actually serves).
    pub(crate) fn pool_points(&self) -> &Points {
        &self.pool
    }

    /// Pair `p`'s `(pool row, αy)` gather, in the pair model's own SV
    /// order — the persistence counterpart of [`Self::pool_points`].
    pub(crate) fn gather(&self, p: usize) -> &[(usize, f64)] {
        &self.pairs[p].entries
    }

    pub fn dim(&self) -> usize {
        self.pool.cols()
    }

    pub fn is_sparse(&self) -> bool {
        self.pool.is_sparse()
    }

    /// All pairwise decision values: row i of the result holds
    /// `f_p(x_i)` for every pair p (column order = pair order). One
    /// kernel block per 128-row tile, shared by all pairs; tiles are
    /// farmed across `threads` workers like
    /// [`predict::decision_function`].
    pub fn decisions(&self, x: &Points, threads: usize) -> Mat {
        self.decisions_with(crate::compute::cpu(), x, threads)
    }

    /// [`Self::decisions`] on an explicit [`ComputeBackend`]: the one
    /// kernel block per tile runs on the backend, the per-pair sparse
    /// gathers stay in f64 here. The default backend reproduces the
    /// historical path bit-for-bit.
    pub fn decisions_with(
        &self,
        backend: &dyn ComputeBackend,
        x: &Points,
        threads: usize,
    ) -> Mat {
        assert_eq!(x.cols(), self.dim(), "feature dimension mismatch");
        let n = x.rows();
        let np = self.pairs.len();
        let n_tiles = n.div_ceil(predict::TILE);
        let tiles: Vec<Vec<f64>> = threadpool::parallel_map(threads, n_tiles, 1, |t| {
            let lo = t * predict::TILE;
            let hi = (lo + predict::TILE).min(n);
            let rows: Vec<usize> = (lo..hi).collect();
            let xb = x.select_rows(&rows);
            let xb_norms = xb.self_norms();
            let kb = backend.kernel_block_with_norms(
                &self.kernel,
                &xb,
                &xb_norms,
                &self.pool,
                &self.pool_norms,
            );
            let mut f = vec![0.0; (hi - lo) * np];
            for (p, pr) in self.pairs.iter().enumerate() {
                for i in 0..(hi - lo) {
                    let krow = kb.row(i);
                    let mut acc = 0.0;
                    for &(j, a) in &pr.entries {
                        acc += a * krow[j];
                    }
                    f[i * np + p] = acc + pr.bias;
                }
            }
            f
        });
        Mat::from_vec(n, np, tiles.concat())
    }

    /// Predicted class labels plus the winning class's decision sum
    /// (the serving payload).
    pub fn predict_with_scores(&self, x: &Points, threads: usize) -> Vec<(i64, f64)> {
        self.predict_with_scores_with(crate::compute::cpu(), x, threads)
    }

    /// [`Self::predict_with_scores`] on an explicit [`ComputeBackend`].
    /// Voting and tie-breaks are backend-independent; only the kernel
    /// block numerics change.
    pub fn predict_with_scores_with(
        &self,
        backend: &dyn ComputeBackend,
        x: &Points,
        threads: usize,
    ) -> Vec<(i64, f64)> {
        let f = self.decisions_with(backend, x, threads);
        let pair_pos: Vec<(usize, usize)> =
            self.pairs.iter().map(|p| (p.a_pos, p.b_pos)).collect();
        (0..f.rows())
            .map(|i| {
                let (best, sum) = vote_row(self.classes.len(), &pair_pos, f.row(i));
                (self.classes[best], sum)
            })
            .collect()
    }
}

/// One-vs-one multiclass model: the pairwise binary models plus the
/// shared-SV prediction engine built over them. Construct through
/// [`OvoModel::new`] (training and persistence both do) so the engine
/// always matches the pairs; the fields are private, so a clone's
/// field-copied engine stays consistent with its pairs.
#[derive(Clone)]
pub struct OvoModel {
    /// `(class_a, class_b, binary model voting a (+1) vs b (−1))`,
    /// ordered `(i, j)` with `i < j` over ascending classes.
    pairs: Vec<(i64, i64, SvmModel)>,
    /// Distinct class labels, sorted ascending.
    classes: Vec<i64>,
    /// Penalty C shared by every pair (diagnostics).
    c: f64,
    engine: OvoEngine,
}

impl OvoModel {
    /// Assemble from pairwise models; derives the class set and builds
    /// the shared-SV engine.
    pub fn new(pairs: Vec<(i64, i64, SvmModel)>, c: f64) -> OvoModel {
        assert!(!pairs.is_empty(), "OvO model needs at least one pair");
        let mut classes: Vec<i64> = pairs.iter().flat_map(|&(a, b, _)| [a, b]).collect();
        classes.sort_unstable();
        classes.dedup();
        let engine = OvoEngine::build(&classes, &pairs);
        OvoModel { pairs, classes, c, engine }
    }

    pub fn pairs(&self) -> &[(i64, i64, SvmModel)] {
        &self.pairs
    }

    pub fn classes(&self) -> &[i64] {
        &self.classes
    }

    pub fn c(&self) -> f64 {
        self.c
    }

    pub fn kernel(&self) -> Kernel {
        self.pairs[0].2.kernel
    }

    pub fn engine(&self) -> &OvoEngine {
        &self.engine
    }

    pub fn dim(&self) -> usize {
        self.engine.dim()
    }

    pub fn is_sparse(&self) -> bool {
        self.engine.is_sparse()
    }

    /// Total SV rows across all pairs (what the naive path evaluates).
    pub fn n_sv_total(&self) -> usize {
        self.pairs.iter().map(|(_, _, m)| m.n_sv()).sum()
    }

    /// Unique SVs in the shared pool (what the engine evaluates).
    pub fn n_sv_unique(&self) -> usize {
        self.engine.pool_size()
    }

    /// Predicted class label for each row of `x` (shared-SV engine).
    pub fn predict(&self, x: &Points, threads: usize) -> Vec<i64> {
        self.engine.predict_with_scores(x, threads).into_iter().map(|(c, _)| c).collect()
    }

    /// [`Self::predict`] on an explicit [`ComputeBackend`].
    pub fn predict_with(
        &self,
        backend: &dyn ComputeBackend,
        x: &Points,
        threads: usize,
    ) -> Vec<i64> {
        self.engine
            .predict_with_scores_with(backend, x, threads)
            .into_iter()
            .map(|(c, _)| c)
            .collect()
    }

    /// Pairwise decisions through the engine (n × n_pairs).
    pub fn decisions(&self, x: &Points, threads: usize) -> Mat {
        self.engine.decisions(x, threads)
    }

    /// Pairwise decisions through the naive per-pair path — one full
    /// kernel block per pair per tile. The correctness oracle the
    /// engine is pinned against (≤ 1e-12), and the baseline of the
    /// `ovo_shared_sv_speedup` bench gate.
    pub fn decisions_naive(&self, x: &Points, threads: usize) -> Mat {
        let n = x.rows();
        let np = self.pairs.len();
        let mut out = Mat::zeros(n, np);
        for (p, (_, _, m)) in self.pairs.iter().enumerate() {
            let f = predict::decision_function(m, x, threads);
            for (i, v) in f.into_iter().enumerate() {
                out[(i, p)] = v;
            }
        }
        out
    }

    /// Majority-vote prediction through the naive per-pair path.
    pub fn predict_naive(&self, x: &Points, threads: usize) -> Vec<i64> {
        let f = self.decisions_naive(x, threads);
        let pos = |c: i64| self.classes.iter().position(|&x| x == c).expect("class present");
        let pair_pos: Vec<(usize, usize)> =
            self.pairs.iter().map(|&(a, b, _)| (pos(a), pos(b))).collect();
        (0..f.rows())
            .map(|i| {
                let (best, _) = vote_row(self.classes.len(), &pair_pos, f.row(i));
                self.classes[best]
            })
            .collect()
    }

    /// Accuracy against integer labels (shared-SV engine path).
    pub fn accuracy(&self, ds: &MulticlassDataset, threads: usize) -> f64 {
        let pred = self.predict(&ds.x, threads);
        let hits = pred.iter().zip(ds.labels.iter()).filter(|(p, l)| p == l).count();
        hits as f64 / ds.labels.len().max(1) as f64
    }
}

impl std::fmt::Debug for OvoModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "OvoModel({} classes, {} pairs, {} SVs ({} unique), dim {}, {}{}, C={})",
            self.classes.len(),
            self.pairs.len(),
            self.n_sv_total(),
            self.n_sv_unique(),
            self.dim(),
            self.kernel().label(),
            if self.is_sparse() { ", sparse" } else { "" },
            self.c
        )
    }
}

/// Aggregated per-stage wall time across all pairwise subproblems
/// (CPU-seconds summed over pairs — pairs overlap in wall clock).
/// `compress_secs` includes the h-independent preprocessing when it was
/// paid (the one-shot [`train_ovo_grid`] path; a reused
/// [`OvoPairSet`] amortizes it across h values instead).
#[derive(Clone, Debug, Default)]
pub struct OvoTrainStats {
    pub pairs: usize,
    pub compress_secs: f64,
    pub factor_secs: f64,
    pub admm_secs: f64,
}

struct OvoPairPre {
    a: i64,
    b: i64,
    /// Preprocessing carries the (permuted) pair subset itself in
    /// `pre.pds`, so nothing else needs retaining.
    pre: crate::hss::compress::Preprocessed,
}

/// Per-pair subsets plus their h-INDEPENDENT preprocessing (cluster
/// tree + ANN), built once per dataset — the multiclass counterpart of
/// [`crate::coordinator::cache::KernelCache`]'s preprocessing reuse: a
/// grid over h calls [`OvoPairSet::train_grid`] per h and pays the
/// tree/ANN passes only once per pair instead of once per (pair, h).
pub struct OvoPairSet {
    pairs: Vec<OvoPairPre>,
    prepare_secs: f64,
    outer: usize,
    inner: usize,
}

impl OvoPairSet {
    /// Build the pair subsets and preprocess each (pairs in outer
    /// parallelism; `outer`/`inner` are a pure function of
    /// `(threads, n_pairs)`, reused by every `train_grid` call).
    pub fn prepare(ds: &MulticlassDataset, hss: &HssParams, threads: usize) -> Result<OvoPairSet> {
        let classes = ds.classes();
        if classes.len() < 2 {
            bail!("need at least 2 classes, got {:?}", classes);
        }
        let mut specs: Vec<(i64, i64)> = Vec::new();
        for (i, &a) in classes.iter().enumerate() {
            for &b in &classes[i + 1..] {
                specs.push((a, b));
            }
        }
        let n_pairs = specs.len();
        let outer = threads.max(1).min(n_pairs);
        let inner = (threads.max(1) / outer).max(1);
        let built: Vec<(OvoPairPre, f64)> = threadpool::parallel_map(outer, n_pairs, 1, |p| {
            let (a, b) = specs[p];
            let idx: Vec<usize> = (0..ds.labels.len())
                .filter(|&t| ds.labels[t] == a || ds.labels[t] == b)
                .collect();
            let x = ds.x.select_rows(&idx);
            let y: Vec<f64> =
                idx.iter().map(|&t| if ds.labels[t] == a { 1.0 } else { -1.0 }).collect();
            let sub = Dataset::new(format!("{a}-vs-{b}"), x, y);
            let t = Timer::start();
            let pre = crate::hss::compress::preprocess(&sub, hss, inner);
            (OvoPairPre { a, b, pre }, t.secs())
        });
        let prepare_secs = built.iter().map(|(_, s)| *s).sum();
        let pairs = built.into_iter().map(|(p, _)| p).collect();
        Ok(OvoPairSet { pairs, prepare_secs, outer, inner })
    }

    /// Preprocessing wall time (CPU-seconds summed over pairs).
    pub fn prepare_secs(&self) -> f64 {
        self.prepare_secs
    }

    /// Train every pair for every C at one kernel width: pairs in outer
    /// parallelism, each compressing from its cached preprocessing and
    /// reusing one ULV factorization across the whole C grid through
    /// the batched multi-RHS solver. Returns one [`OvoModel`] per C
    /// (same order as `cs`). Since every stage is bit-for-bit
    /// thread-invariant and the outer/inner split depends only on
    /// `(threads, n_pairs)`, the models are bitwise identical for
    /// every `threads` value.
    pub fn train_grid(
        &self,
        kernel: Kernel,
        hss: &HssParams,
        admm: &AdmmParams,
        cs: &[f64],
    ) -> Result<(Vec<OvoModel>, OvoTrainStats)> {
        if cs.is_empty() {
            bail!("need at least one C value");
        }
        let n_pairs = self.pairs.len();
        type PairOut = Result<(Vec<SvmModel>, [f64; 3])>;
        let results: Vec<PairOut> =
            threadpool::parallel_map(self.outer, n_pairs, 1, |p| {
                let pp = &self.pairs[p];
                let t = Timer::start();
                let trainer =
                    HssSvmTrainer::compress_preprocessed(&pp.pre, kernel, hss, self.inner);
                let compress_secs = t.secs();
                let t = Timer::start();
                let ulv = trainer.factor(admm.beta).with_context(|| {
                    format!("factorization failed for pair {}-vs-{}", pp.a, pp.b)
                })?;
                let factor_secs = t.secs();
                let t = Timer::start();
                let solver = AdmmSolver::new(&ulv, &trainer.y, *admm).with_threads(self.inner);
                let models: Vec<SvmModel> = trainer
                    .train_grid_with_solver(&solver, cs)
                    .into_iter()
                    .map(|(m, _)| m)
                    .collect();
                let admm_secs = t.secs();
                Ok((models, [compress_secs, factor_secs, admm_secs]))
            });

        let mut per_pair: Vec<Vec<SvmModel>> = Vec::with_capacity(n_pairs);
        let mut stats = OvoTrainStats { pairs: n_pairs, ..Default::default() };
        for r in results {
            let (models, [cs_, fs_, as_]) = r?;
            stats.compress_secs += cs_;
            stats.factor_secs += fs_;
            stats.admm_secs += as_;
            per_pair.push(models);
        }
        // regroup: one OvoModel per C, pairs in spec order — transpose
        // by value, the trained models are moved (never cloned)
        let mut grouped: Vec<Vec<(i64, i64, SvmModel)>> =
            (0..cs.len()).map(|_| Vec::with_capacity(n_pairs)).collect();
        for (pp, ms) in self.pairs.iter().zip(per_pair.into_iter()) {
            for (ci, m) in ms.into_iter().enumerate() {
                grouped[ci].push((pp.a, pp.b, m));
            }
        }
        let models = grouped
            .into_iter()
            .zip(cs.iter())
            .map(|(pairs, &c)| OvoModel::new(pairs, c))
            .collect();
        Ok((models, stats))
    }
}

/// Train all k(k−1)/2 pairwise classifiers for every C in `cs` at once
/// (one-shot: prepare + train at a single kernel width — identical,
/// bit for bit, to the pre-split `compress` path, since `compress` IS
/// `preprocess` + `compress_preprocessed`). Grid searches over h keep
/// the [`OvoPairSet`] and call [`OvoPairSet::train_grid`] per width.
pub fn train_ovo_grid(
    ds: &MulticlassDataset,
    kernel: Kernel,
    hss: &HssParams,
    admm: &AdmmParams,
    cs: &[f64],
    threads: usize,
) -> Result<(Vec<OvoModel>, OvoTrainStats)> {
    let set = OvoPairSet::prepare(ds, hss, threads)?;
    let (models, mut stats) = set.train_grid(kernel, hss, admm, cs)?;
    stats.compress_secs += set.prepare_secs();
    Ok((models, stats))
}

/// Train all pairwise classifiers for a single C.
pub fn train_ovo(
    ds: &MulticlassDataset,
    kernel: Kernel,
    hss: &HssParams,
    admm: &AdmmParams,
    c: f64,
    threads: usize,
) -> Result<(OvoModel, OvoTrainStats)> {
    let (mut models, stats) = train_ovo_grid(ds, kernel, hss, admm, &[c], threads)?;
    Ok((models.pop().expect("one model per C"), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DEFAULT_LABEL_PAIR;
    use crate::util::prng::Rng;
    use crate::util::testkit;

    /// Three well-separated Gaussian blobs labelled 0/1/2.
    fn three_blobs(n: usize, rng: &mut Rng) -> MulticlassDataset {
        let centers = [[0.0, 0.0], [4.0, 0.0], [0.0, 4.0]];
        let mut x = Mat::zeros(n, 2);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % 3;
            x[(i, 0)] = centers[c][0] + rng.gauss() * 0.4;
            x[(i, 1)] = centers[c][1] + rng.gauss() * 0.4;
            labels.push(c as i64);
        }
        MulticlassDataset::new("blobs3", x, labels)
    }

    #[test]
    fn three_class_blobs_high_accuracy() {
        let mut rng = Rng::new(501);
        let train = three_blobs(300, &mut rng);
        let test = three_blobs(150, &mut rng);
        let (model, stats) = train_ovo(
            &train,
            Kernel::Gaussian { h: 1.0 },
            &HssParams::near_exact(),
            &AdmmParams { beta: 10.0, max_it: 15, relax: 1.0, tol: 0.0 },
            10.0,
            1,
        )
        .unwrap();
        assert_eq!(model.pairs().len(), 3);
        assert_eq!(model.classes(), &[0, 1, 2]);
        assert_eq!(stats.pairs, 3);
        let acc = model.accuracy(&test, 1);
        assert!(acc > 0.95, "ovo accuracy {acc}");
        // pairs share SVs: the pool must be strictly smaller than the
        // concatenation (every point sits in 2 of the 3 pairs)
        assert!(model.n_sv_unique() <= model.n_sv_total());
    }

    #[test]
    fn single_class_is_an_error() {
        let ds = MulticlassDataset::new("one", Mat::zeros(5, 2), vec![3; 5]);
        assert!(train_ovo(
            &ds,
            Kernel::Linear,
            &HssParams::near_exact(),
            &AdmmParams::default(),
            1.0,
            1
        )
        .is_err());
    }

    #[test]
    fn engine_matches_naive_per_pair_path() {
        let mut rng = Rng::new(502);
        let train = three_blobs(240, &mut rng);
        let test = three_blobs(predict::TILE + 40, &mut rng); // crosses a tile boundary
        let (model, _) = train_ovo(
            &train,
            Kernel::Gaussian { h: 1.0 },
            &HssParams::near_exact(),
            &AdmmParams { beta: 10.0, max_it: 12, relax: 1.0, tol: 0.0 },
            5.0,
            2,
        )
        .unwrap();
        let fast = model.decisions(&test.x, 2);
        let naive = model.decisions_naive(&test.x, 2);
        assert_eq!(fast.shape(), naive.shape());
        for (a, b) in fast.data().iter().zip(naive.data().iter()) {
            assert!(
                (a - b).abs() <= 1e-12 * (1.0 + b.abs()),
                "engine {a} vs naive {b}"
            );
        }
        assert_eq!(model.predict(&test.x, 2), model.predict_naive(&test.x, 2));
    }

    #[test]
    fn grid_models_match_single_c_training() {
        let mut rng = Rng::new(503);
        let train = three_blobs(150, &mut rng);
        let cs = [0.5, 5.0];
        let (grid, _) = train_ovo_grid(
            &train,
            Kernel::Gaussian { h: 1.0 },
            &HssParams::near_exact(),
            &AdmmParams { beta: 10.0, max_it: 10, relax: 1.0, tol: 0.0 },
            &cs,
            2,
        )
        .unwrap();
        assert_eq!(grid.len(), 2);
        for (gi, &c) in grid.iter().zip(cs.iter()) {
            assert_eq!(gi.c(), c);
            let (single, _) = train_ovo(
                &train,
                Kernel::Gaussian { h: 1.0 },
                &HssParams::near_exact(),
                &AdmmParams { beta: 10.0, max_it: 10, relax: 1.0, tol: 0.0 },
                c,
                2,
            )
            .unwrap();
            for ((a1, b1, m1), (a2, b2, m2)) in gi.pairs().iter().zip(single.pairs().iter()) {
                assert_eq!((a1, b1), (a2, b2));
                assert_eq!(m1.alpha_y, m2.alpha_y, "grid vs single-C at C={c}");
                assert_eq!(m1.bias.to_bits(), m2.bias.to_bits());
                assert_eq!(m1.sv, m2.sv);
            }
        }
    }

    /// A pair model with one zero-weight SV: its decision is exactly
    /// `bias` everywhere (α·K = 0·K = 0.0), so vote patterns can be
    /// constructed precisely — and all pairs share the single pool row.
    fn const_pair(a: i64, b: i64, bias: f64) -> (i64, i64, SvmModel) {
        (
            a,
            b,
            SvmModel {
                sv: Mat::from_vec(1, 2, vec![0.5, -0.25]).into(),
                alpha_y: vec![0.0],
                bias,
                kernel: Kernel::Gaussian { h: 1.0 },
                c: 1.0,
                labels: DEFAULT_LABEL_PAIR,
            },
        )
    }

    #[test]
    fn tie_break_is_libsvm_deterministic() {
        let x: Points = Mat::zeros(1, 2).into();
        // all three classes get exactly one vote, all decision sums are
        // exactly 0 → lowest class index must win (the old max_by_key
        // picked the LAST maximal class, i.e. 2)
        let full_tie = OvoModel::new(
            vec![const_pair(0, 1, 1.0), const_pair(0, 2, -1.0), const_pair(1, 2, 1.0)],
            1.0,
        );
        assert_eq!(full_tie.predict(&x, 1), vec![0]);
        assert_eq!(full_tie.predict_naive(&x, 1), vec![0]);
        // one vote each, but the sums favor the MIDDLE class:
        // f01 = −2 (vote 1), f02 = +0.5 (vote 0), f12 = −0.5 (vote 2)
        // sums: c0 = −2 + 0.5 = −1.5, c1 = 2 − 0.5 = 1.5, c2 = 0
        let sum_tie = OvoModel::new(
            vec![const_pair(0, 1, -2.0), const_pair(0, 2, 0.5), const_pair(1, 2, -0.5)],
            1.0,
        );
        assert_eq!(sum_tie.predict(&x, 1), vec![1]);
        assert_eq!(sum_tie.predict_naive(&x, 1), vec![1]);
        // clear majority is untouched by the tie-break machinery
        let majority = OvoModel::new(
            vec![const_pair(0, 1, -1.0), const_pair(0, 2, -1.0), const_pair(1, 2, 1.0)],
            1.0,
        );
        assert_eq!(majority.predict(&x, 1), vec![1]);
        // identical SV row across pairs → one pool row
        assert_eq!(majority.n_sv_unique(), 1);
        assert_eq!(majority.n_sv_total(), 3);
    }

    #[test]
    fn parallel_pairwise_training_is_thread_invariant() {
        let mut rng = Rng::new(504);
        let train = three_blobs(180, &mut rng);
        let kernel = Kernel::Gaussian { h: 1.0 };
        let ap = AdmmParams { beta: 10.0, max_it: 8, relax: 1.0, tol: 0.0 };
        let (base, _) = train_ovo(&train, kernel, &HssParams::near_exact(), &ap, 2.0, 1).unwrap();
        for threads in [2, 8] {
            let (other, _) =
                train_ovo(&train, kernel, &HssParams::near_exact(), &ap, 2.0, threads).unwrap();
            for ((a1, b1, m1), (a2, b2, m2)) in base.pairs().iter().zip(other.pairs().iter()) {
                assert_eq!((a1, b1), (a2, b2), "pair order changed at threads={threads}");
                assert_eq!(m1.alpha_y, m2.alpha_y, "alpha differs at threads={threads}");
                assert_eq!(m1.bias.to_bits(), m2.bias.to_bits(), "bias at threads={threads}");
                assert_eq!(m1.sv, m2.sv, "SVs differ at threads={threads}");
            }
        }
    }

    #[test]
    fn sparse_engine_matches_dense() {
        // CSR training data end-to-end: engine and naive paths agree
        // with each other and with the dense twin
        let mut rng = Rng::new(505);
        let dense = three_blobs(160, &mut rng);
        let test = three_blobs(60, &mut rng);
        let sparse = MulticlassDataset::new(
            "blobs3-csr",
            CsrMat::from_dense(dense.x.dense()),
            dense.labels.clone(),
        );
        let kernel = Kernel::Gaussian { h: 1.0 };
        let ap = AdmmParams { beta: 10.0, max_it: 10, relax: 1.0, tol: 0.0 };
        let (md, _) = train_ovo(&dense, kernel, &HssParams::near_exact(), &ap, 5.0, 2).unwrap();
        let (ms, _) = train_ovo(&sparse, kernel, &HssParams::near_exact(), &ap, 5.0, 2).unwrap();
        assert!(ms.is_sparse());
        let xs: Points = CsrMat::from_dense(test.x.dense()).into();
        let fd = md.decisions(&test.x, 2);
        let fs = ms.decisions(&xs, 2);
        testkit::assert_allclose(fs.data(), fd.data(), 1e-10);
        for (a, b) in ms.decisions(&xs, 2).data().iter().zip(ms.decisions_naive(&xs, 2).data()) {
            assert!((a - b).abs() <= 1e-12 * (1.0 + b.abs()), "sparse engine {a} vs naive {b}");
        }
    }
}
