//! SVM training and prediction built on the ADMM + HSS stack.
//!
//! NOTE on the paper's eq. (2): as printed, b = Σᵢyᵢx̄ᵢK(fᵢ,fⱼ) − yⱼ has
//! the sign flipped relative to the KKT condition yⱼ(f(fⱼ)) = 1; we
//! implement the KKT-consistent version b = yⱼ − Σᵢyᵢx̄ᵢK(fᵢ,fⱼ)
//! (averaged over margin SVs per eq. (7)), which is what LIBSVM computes.

pub mod model;
pub mod multiclass;
pub mod persist;
pub mod predict;
pub mod svr;
pub mod train;

pub use model::SvmModel;
pub use train::{train_hss_svm, HssSvmTrainer, TrainStats};
