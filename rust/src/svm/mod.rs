//! SVM training and prediction built on the ADMM + HSS stack.
//!
//! NOTE on the paper's eq. (2): as printed, b = Σᵢyᵢx̄ᵢK(fᵢ,fⱼ) − yⱼ has
//! the sign flipped relative to the KKT condition yⱼ(f(fⱼ)) = 1; we
//! implement the KKT-consistent version b = yⱼ − Σᵢyᵢx̄ᵢK(fᵢ,fⱼ)
//! (averaged over margin SVs per eq. (7)), which is what LIBSVM computes.

// No raw-pointer tricks belong in this module tree (see DESIGN.md §11).
#![forbid(unsafe_code)]

pub mod model;
pub mod multiclass;
pub mod multilevel;
pub mod persist;
pub mod predict;
pub mod svr;
pub mod train;

pub use model::SvmModel;
pub use multiclass::{MulticlassDataset, OvoModel};
pub use multilevel::{MultilevelContext, MultilevelParams};
pub use train::{train_hss_svm, HssSvmTrainer, TrainStats};

/// A loaded model of either arity: the serving stack (stdin loop, TCP
/// registry/batcher) and `cmd_predict` are generic over this, so binary
/// and one-vs-one multiclass models flow through the same pipelines.
/// [`persist::load_any`] auto-detects the file kind by its magic line.
#[derive(Clone)]
pub enum AnyModel {
    Binary(SvmModel),
    Ovo(OvoModel),
}

impl AnyModel {
    /// Feature dimension expected of request lines.
    pub fn dim(&self) -> usize {
        match self {
            AnyModel::Binary(m) => m.sv.cols(),
            AnyModel::Ovo(m) => m.dim(),
        }
    }

    /// True when the SVs (and therefore request tiles — the tile
    /// representation follows the model) are CSR-stored.
    pub fn is_sparse(&self) -> bool {
        match self {
            AnyModel::Binary(m) => m.sv.is_sparse(),
            AnyModel::Ovo(m) => m.is_sparse(),
        }
    }

    pub fn as_binary(&self) -> Option<&SvmModel> {
        match self {
            AnyModel::Binary(m) => Some(m),
            AnyModel::Ovo(_) => None,
        }
    }

    /// One-line banner description (serve front-ends).
    pub fn describe(&self) -> String {
        match self {
            AnyModel::Binary(m) => format!(
                "{} SVs, dim {}{}",
                m.n_sv(),
                m.sv.cols(),
                if m.sv.is_sparse() { ", CSR" } else { "" }
            ),
            AnyModel::Ovo(m) => format!(
                "OvO {} classes / {} pairs, {} unique SVs, dim {}{}",
                m.classes().len(),
                m.pairs().len(),
                m.n_sv_unique(),
                m.dim(),
                if m.is_sparse() { ", CSR" } else { "" }
            ),
        }
    }
}

impl From<SvmModel> for AnyModel {
    fn from(m: SvmModel) -> Self {
        AnyModel::Binary(m)
    }
}

impl From<OvoModel> for AnyModel {
    fn from(m: OvoModel) -> Self {
        AnyModel::Ovo(m)
    }
}

impl std::fmt::Debug for AnyModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AnyModel::Binary(m) => write!(f, "{m:?}"),
            AnyModel::Ovo(m) => write!(f, "{m:?}"),
        }
    }
}
