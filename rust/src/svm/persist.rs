//! Model persistence: a self-describing text format (versioned, no
//! external serialization crates) compatible in spirit with LIBSVM's
//! model files. Round-trips exactly (f64 bit patterns are preserved via
//! hex encoding).
//!
//! Dense models write the original `sv <rows> <cols>` section; sparse
//! (CSR) models write `svsparse <rows> <cols>` with per-row
//! `<alpha> <index>:<hexval> ...` lines (0-based ascending indices), so
//! a rcv1-class model file stays O(nnz). The loader accepts both.
//!
//! Models trained on a non-±1 label encoding (e.g. a {1,2}-coded
//! LIBSVM file) carry an optional `labels <neg-hex> <pos-hex>` line
//! between `bias` and the SV section; files without it (all pre-v1.1
//! files, and files for ±1-coded data) default to `[-1, +1]`.
//!
//! One-vs-one multiclass models use a separate magic (`hss-svm-ovo v1`)
//! and a **shared SV pool** layout: the unique support vectors of all
//! pairwise models are written once (`pool` / `poolsparse` section,
//! same row encodings as `sv` / `svsparse` minus the alpha prefix), and
//! each pair is two lines — a `pair <a> <b> <bias-hex> <nsv>` header
//! and its `<pool-row>:<alpha-hex>` gather. [`load_any`] dispatches on
//! the magic, so every binary v1/v1.1 file keeps loading unchanged.

use crate::data::dataset::DEFAULT_LABEL_PAIR;
use crate::data::sparse::{CsrMat, Points};
use crate::kernel::Kernel;
use crate::linalg::Mat;
use crate::svm::{AnyModel, OvoModel, SvmModel};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

const MAGIC: &str = "hss-svm-model v1";
const MAGIC_OVO: &str = "hss-svm-ovo v1";

fn write_kernel(w: &mut impl Write, kernel: &Kernel) -> Result<()> {
    match kernel {
        Kernel::Gaussian { h } => writeln!(w, "kernel gaussian {}", hexf(*h))?,
        Kernel::Polynomial { degree, c } => {
            writeln!(w, "kernel polynomial {degree} {}", hexf(*c))?
        }
        Kernel::Linear => writeln!(w, "kernel linear")?,
    }
    Ok(())
}

fn parse_kernel(kline: &str) -> Result<Kernel> {
    let mut kp = kline.split_ascii_whitespace();
    if kp.next() != Some("kernel") {
        bail!("expected kernel line, got {kline:?}");
    }
    Ok(match kp.next() {
        Some("gaussian") => Kernel::Gaussian { h: unhexf(kp.next().context("missing h")?)? },
        Some("polynomial") => Kernel::Polynomial {
            degree: kp.next().context("missing degree")?.parse()?,
            c: unhexf(kp.next().context("missing c")?)?,
        },
        Some("linear") => Kernel::Linear,
        other => bail!("unknown kernel {other:?}"),
    })
}

/// Write a model to a file.
pub fn save(model: &SvmModel, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("cannot create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "{MAGIC}")?;
    write_kernel(&mut w, &model.kernel)?;
    writeln!(w, "c {}", hexf(model.c))?;
    writeln!(w, "bias {}", hexf(model.bias))?;
    if model.labels != DEFAULT_LABEL_PAIR {
        // optional: ±1 models keep the historical byte-identical format
        writeln!(w, "labels {} {}", hexf(model.labels[0]), hexf(model.labels[1]))?;
    }
    match &model.sv {
        Points::Dense(sv) => {
            writeln!(w, "sv {} {}", sv.rows(), sv.cols())?;
            for i in 0..sv.rows() {
                write!(w, "{}", hexf(model.alpha_y[i]))?;
                for &v in sv.row(i) {
                    write!(w, " {}", hexf(v))?;
                }
                writeln!(w)?;
            }
        }
        Points::Sparse(sv) => {
            writeln!(w, "svsparse {} {}", sv.rows(), sv.cols())?;
            for i in 0..sv.rows() {
                write!(w, "{}", hexf(model.alpha_y[i]))?;
                let (ci, vi) = sv.row(i);
                for (&c, &v) in ci.iter().zip(vi.iter()) {
                    write!(w, " {}:{}", c, hexf(v))?;
                }
                writeln!(w)?;
            }
        }
    }
    Ok(())
}

/// Load a model from a file.
pub fn load(path: impl AsRef<Path>) -> Result<SvmModel> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("cannot open {}", path.as_ref().display()))?;
    let mut lines = std::io::BufReader::new(f).lines();
    let mut next = || -> Result<String> {
        lines.next().context("unexpected end of model file")?.context("I/O error")
    };
    let magic = next()?;
    if magic.trim() != MAGIC {
        bail!("not a hss-svm model file (got header {magic:?})");
    }
    let kernel = parse_kernel(&next()?)?;
    let c = parse_kv(&next()?, "c")?;
    let bias = parse_kv(&next()?, "bias")?;
    // optional `labels` line; older files go straight to the SV section
    let mut svline = next()?;
    let mut labels = DEFAULT_LABEL_PAIR;
    if let Some(rest) = svline.strip_prefix("labels ") {
        let mut lp = rest.split_ascii_whitespace();
        labels[0] = unhexf(lp.next().context("missing negative label")?)?;
        labels[1] = unhexf(lp.next().context("missing positive label")?)?;
        svline = next()?;
    }
    let mut sp = svline.split_ascii_whitespace();
    let kind = sp.next();
    if kind != Some("sv") && kind != Some("svsparse") {
        bail!("expected sv/svsparse line, got {svline:?}");
    }
    let rows: usize = sp.next().context("missing sv rows")?.parse()?;
    let cols: usize = sp.next().context("missing sv cols")?.parse()?;
    let mut alpha_y = Vec::with_capacity(rows);
    let sv: Points = if kind == Some("sv") {
        let mut sv = Mat::zeros(rows, cols);
        for i in 0..rows {
            let line = next()?;
            let mut parts = line.split_ascii_whitespace();
            alpha_y.push(unhexf(parts.next().context("missing alpha")?)?);
            for j in 0..cols {
                sv[(i, j)] = unhexf(
                    parts.next().with_context(|| format!("row {i}: missing sv value {j}"))?,
                )?;
            }
        }
        sv.into()
    } else {
        let mut sv_rows: Vec<Vec<(usize, f64)>> = Vec::with_capacity(rows);
        for i in 0..rows {
            let line = next()?;
            let mut parts = line.split_ascii_whitespace();
            alpha_y.push(unhexf(parts.next().context("missing alpha")?)?);
            let mut row: Vec<(usize, f64)> = Vec::new();
            for tok in parts {
                let (c_str, v_str) = tok
                    .split_once(':')
                    .with_context(|| format!("row {i}: bad sparse pair {tok:?}"))?;
                let col: usize = c_str
                    .parse()
                    .with_context(|| format!("row {i}: bad sparse index {c_str:?}"))?;
                if col >= cols {
                    bail!("row {i}: sparse index {col} out of range {cols}");
                }
                // validate here so corrupt files fail with Err like every
                // other loader path, not via CsrMat's construction assert
                if let Some(&(prev, _)) = row.last() {
                    if col <= prev {
                        bail!("row {i}: sparse index {col} not strictly ascending after {prev}");
                    }
                }
                row.push((col, unhexf(v_str)?));
            }
            sv_rows.push(row);
        }
        CsrMat::from_rows(cols, &sv_rows).into()
    };
    Ok(SvmModel { sv, alpha_y, bias, kernel, c, labels })
}

/// Write a one-vs-one multiclass model: shared SV pool once, then one
/// sparse gather per pair. The pool and the gathers are serialized
/// STRAIGHT from the model's engine (`pool_points`/`gather`) — the
/// on-disk layout is by construction the layout the engine serves, so
/// the dedup logic lives in exactly one place.
pub fn save_ovo(model: &OvoModel, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("cannot create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "{MAGIC_OVO}")?;
    write_kernel(&mut w, &model.kernel())?;
    writeln!(w, "c {}", hexf(model.c()))?;
    write!(w, "classes {}", model.classes().len())?;
    for c in model.classes() {
        write!(w, " {c}")?;
    }
    writeln!(w)?;

    let engine = model.engine();
    match engine.pool_points() {
        Points::Sparse(pool) => {
            writeln!(w, "poolsparse {} {}", pool.rows(), pool.cols())?;
            for i in 0..pool.rows() {
                let (ci, vi) = pool.row(i);
                for (j, (&c, &v)) in ci.iter().zip(vi.iter()).enumerate() {
                    if j > 0 {
                        write!(w, " ")?;
                    }
                    write!(w, "{}:{}", c, hexf(v))?;
                }
                writeln!(w)?;
            }
        }
        Points::Dense(pool) => {
            writeln!(w, "pool {} {}", pool.rows(), pool.cols())?;
            for i in 0..pool.rows() {
                for (j, &v) in pool.row(i).iter().enumerate() {
                    if j > 0 {
                        write!(w, " ")?;
                    }
                    write!(w, "{}", hexf(v))?;
                }
                writeln!(w)?;
            }
        }
    }
    writeln!(w, "pairs {}", model.pairs().len())?;
    for (p, (a, b, m)) in model.pairs().iter().enumerate() {
        let gather = engine.gather(p);
        writeln!(w, "pair {a} {b} {} {}", hexf(m.bias), gather.len())?;
        for (j, &(row, alpha)) in gather.iter().enumerate() {
            if j > 0 {
                write!(w, " ")?;
            }
            write!(w, "{}:{}", row, hexf(alpha))?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Load a one-vs-one multiclass model (shared-pool layout). Each pair's
/// [`SvmModel`] is reconstructed by gathering its rows out of the pool,
/// so the per-pair SVs keep the pool's representation.
pub fn load_ovo(path: impl AsRef<Path>) -> Result<OvoModel> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("cannot open {}", path.as_ref().display()))?;
    let mut lines = std::io::BufReader::new(f).lines();
    let mut next = || -> Result<String> {
        lines.next().context("unexpected end of model file")?.context("I/O error")
    };
    let magic = next()?;
    if magic.trim() != MAGIC_OVO {
        bail!("not a hss-svm OvO model file (got header {magic:?})");
    }
    let kernel = parse_kernel(&next()?)?;
    let c = parse_kv(&next()?, "c")?;
    let cline = next()?;
    let mut cp = cline.split_ascii_whitespace();
    if cp.next() != Some("classes") {
        bail!("expected classes line, got {cline:?}");
    }
    let n_classes: usize = cp.next().context("missing class count")?.parse()?;
    let classes: Vec<i64> = cp
        .map(|t| t.parse::<i64>().with_context(|| format!("bad class label {t:?}")))
        .collect::<Result<_>>()?;
    if classes.len() != n_classes || n_classes < 2 {
        bail!("classes line announces {n_classes} labels but lists {}", classes.len());
    }

    let pline = next()?;
    let mut pp = pline.split_ascii_whitespace();
    let kind = pp.next();
    if kind != Some("pool") && kind != Some("poolsparse") {
        bail!("expected pool/poolsparse line, got {pline:?}");
    }
    let rows: usize = pp.next().context("missing pool rows")?.parse()?;
    let cols: usize = pp.next().context("missing pool cols")?.parse()?;
    let pool: Points = if kind == Some("pool") {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            let line = next()?;
            let mut parts = line.split_ascii_whitespace();
            for j in 0..cols {
                m[(i, j)] = unhexf(
                    parts.next().with_context(|| format!("pool row {i}: missing value {j}"))?,
                )?;
            }
        }
        m.into()
    } else {
        let mut sv_rows: Vec<Vec<(usize, f64)>> = Vec::with_capacity(rows);
        for i in 0..rows {
            let line = next()?;
            let mut row: Vec<(usize, f64)> = Vec::new();
            for tok in line.split_ascii_whitespace() {
                let (c_str, v_str) = tok
                    .split_once(':')
                    .with_context(|| format!("pool row {i}: bad sparse pair {tok:?}"))?;
                let col: usize = c_str
                    .parse()
                    .with_context(|| format!("pool row {i}: bad sparse index {c_str:?}"))?;
                if col >= cols {
                    bail!("pool row {i}: sparse index {col} out of range {cols}");
                }
                if let Some(&(prev, _)) = row.last() {
                    if col <= prev {
                        bail!("pool row {i}: sparse index {col} not strictly ascending after {prev}");
                    }
                }
                row.push((col, unhexf(v_str)?));
            }
            sv_rows.push(row);
        }
        CsrMat::from_rows(cols, &sv_rows).into()
    };

    let npline = next()?;
    let mut np = npline.split_ascii_whitespace();
    if np.next() != Some("pairs") {
        bail!("expected pairs line, got {npline:?}");
    }
    let n_pairs: usize = np.next().context("missing pair count")?.parse()?;
    let mut pairs = Vec::with_capacity(n_pairs);
    for p in 0..n_pairs {
        let hline = next()?;
        let mut hp = hline.split_ascii_whitespace();
        if hp.next() != Some("pair") {
            bail!("pair {p}: expected pair line, got {hline:?}");
        }
        let a: i64 = hp.next().context("missing class a")?.parse()?;
        let b: i64 = hp.next().context("missing class b")?.parse()?;
        let bias = unhexf(hp.next().context("missing pair bias")?)?;
        let nsv: usize = hp.next().context("missing pair SV count")?.parse()?;
        if !classes.contains(&a) || !classes.contains(&b) {
            bail!("pair {p}: classes {a}/{b} not in the classes line");
        }
        let gline = next()?;
        let mut idx = Vec::with_capacity(nsv);
        let mut alpha_y = Vec::with_capacity(nsv);
        for tok in gline.split_ascii_whitespace() {
            let (r_str, a_str) = tok
                .split_once(':')
                .with_context(|| format!("pair {p}: bad gather token {tok:?}"))?;
            let row: usize =
                r_str.parse().with_context(|| format!("pair {p}: bad pool row {r_str:?}"))?;
            if row >= pool.rows() {
                bail!("pair {p}: pool row {row} out of range {}", pool.rows());
            }
            idx.push(row);
            alpha_y.push(unhexf(a_str)?);
        }
        if idx.len() != nsv {
            bail!("pair {p}: header announces {nsv} SVs but the gather lists {}", idx.len());
        }
        let sv = pool.select_rows(&idx);
        pairs.push((
            a,
            b,
            SvmModel { sv, alpha_y, bias, kernel, c, labels: DEFAULT_LABEL_PAIR },
        ));
    }
    if pairs.is_empty() {
        bail!("OvO model file has no pairs");
    }
    let model = OvoModel::new(pairs, c);
    if model.classes() != classes {
        bail!(
            "classes line {:?} disagrees with the pair set {:?}",
            classes,
            model.classes()
        );
    }
    Ok(model)
}

/// Load a model file of either kind, dispatching on the magic line:
/// binary (`hss-svm-model v1`, including pre-`labels` files) or
/// one-vs-one multiclass (`hss-svm-ovo v1`).
pub fn load_any(path: impl AsRef<Path>) -> Result<AnyModel> {
    let path = path.as_ref();
    let f = std::fs::File::open(path)
        .with_context(|| format!("cannot open {}", path.display()))?;
    let mut first = String::new();
    std::io::BufReader::new(f).read_line(&mut first).context("I/O error")?;
    if first.trim() == MAGIC_OVO {
        Ok(AnyModel::Ovo(load_ovo(path)?))
    } else {
        Ok(AnyModel::Binary(load(path)?))
    }
}

/// Save a model of either kind (binary or OvO layout by variant).
pub fn save_any(model: &AnyModel, path: impl AsRef<Path>) -> Result<()> {
    match model {
        AnyModel::Binary(m) => save(m, path),
        AnyModel::Ovo(m) => save_ovo(m, path),
    }
}

fn parse_kv(line: &str, key: &str) -> Result<f64> {
    let mut p = line.split_ascii_whitespace();
    if p.next() != Some(key) {
        bail!("expected {key} line, got {line:?}");
    }
    unhexf(p.next().with_context(|| format!("missing {key} value"))?)
}

/// Exact f64 as hex bits. Shared with the shard format (`data/shard`),
/// which stores feature values the same way so a shard→load round-trip
/// is bit-exact.
pub(crate) fn hexf(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

/// Inverse of [`hexf`].
pub(crate) fn unhexf(s: &str) -> Result<f64> {
    let bits = u64::from_str_radix(s, 16).with_context(|| format!("bad f64 hex {s:?}"))?;
    Ok(f64::from_bits(bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn toy_model(rng: &mut Rng) -> SvmModel {
        SvmModel {
            sv: Mat::gauss(7, 3, rng).into(),
            alpha_y: (0..7).map(|_| rng.gauss()).collect(),
            bias: rng.gauss(),
            kernel: Kernel::Gaussian { h: 0.37 },
            c: 2.5,
            labels: DEFAULT_LABEL_PAIR,
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let mut rng = Rng::new(601);
        let model = toy_model(&mut rng);
        let dir = std::env::temp_dir().join("hss_svm_persist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.model");
        save(&model, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.sv, model.sv);
        assert_eq!(back.alpha_y, model.alpha_y);
        assert_eq!(back.bias.to_bits(), model.bias.to_bits());
        assert_eq!(back.kernel, model.kernel);
        assert_eq!(back.c, model.c);
        // identical decisions
        let x = Mat::gauss(10, 3, &mut rng);
        for i in 0..10 {
            assert_eq!(model.decision_one(x.row(i)), back.decision_one(x.row(i)));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sparse_roundtrip_is_bit_exact() {
        let mut rng = Rng::new(603);
        let dense = Mat::from_fn(6, 40, |i, j| {
            if (i * 7 + j) % 9 == 0 { rng.gauss() } else { 0.0 }
        });
        let model = SvmModel {
            sv: CsrMat::from_dense(&dense).into(),
            alpha_y: (0..6).map(|_| rng.gauss()).collect(),
            bias: rng.gauss(),
            kernel: Kernel::Gaussian { h: 1.2 },
            c: 0.5,
            labels: DEFAULT_LABEL_PAIR,
        };
        let dir = std::env::temp_dir()
            .join(format!("hss_svm_persist_sp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("sp.model");
        save(&model, &p).unwrap();
        let back = load(&p).unwrap();
        assert!(back.sv.is_sparse());
        assert_eq!(back.sv, model.sv);
        assert_eq!(back.alpha_y, model.alpha_y);
        assert_eq!(back.bias.to_bits(), model.bias.to_bits());
        // identical decisions through the sparse eval path
        for _ in 0..10 {
            let t: Vec<f64> = (0..40).map(|_| rng.gauss()).collect();
            assert_eq!(model.decision_one(&t), back.decision_one(&t));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn all_kernel_variants_roundtrip() {
        let mut rng = Rng::new(602);
        let dir = std::env::temp_dir().join("hss_svm_persist_test2");
        std::fs::create_dir_all(&dir).unwrap();
        for kernel in [
            Kernel::Gaussian { h: 1.5 },
            Kernel::Polynomial { degree: 3, c: 0.5 },
            Kernel::Linear,
        ] {
            let model = SvmModel { kernel, ..toy_model(&mut rng) };
            let p = dir.join("k.model");
            save(&model, &p).unwrap();
            assert_eq!(load(&p).unwrap().kernel, kernel);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn label_pair_roundtrips_and_defaults() {
        let mut rng = Rng::new(604);
        let dir = std::env::temp_dir()
            .join(format!("hss_svm_persist_lbl_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // non-default pair survives the round-trip bit-exactly
        let model = SvmModel { labels: [1.0, 2.0], ..toy_model(&mut rng) };
        let p = dir.join("lbl.model");
        save(&model, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.labels, [1.0, 2.0]);
        assert_eq!(back.sv, model.sv);
        assert_eq!(back.bias.to_bits(), model.bias.to_bits());
        // a ±1 model writes no labels line (old readers keep working)
        // and an old file without one loads with the default pair
        let dflt = toy_model(&mut rng);
        let p2 = dir.join("dflt.model");
        save(&dflt, &p2).unwrap();
        let text = std::fs::read_to_string(&p2).unwrap();
        assert!(!text.contains("labels "), "{text}");
        assert_eq!(load(&p2).unwrap().labels, DEFAULT_LABEL_PAIR);
        std::fs::remove_dir_all(&dir).ok();
    }

    fn toy_ovo(rng: &mut Rng, sparse: bool) -> OvoModel {
        // three pairs over classes {1, 3, 7} sharing rows of one SV set
        // (the realistic case: each training point sits in k−1 pairs)
        let base = Mat::gauss(6, 4, rng);
        let shared: Points = if sparse {
            let mut thin = base.clone();
            for i in 0..thin.rows() {
                let r = thin.row_mut(i);
                r[(i * 2 + 1) % 4] = 0.0;
            }
            CsrMat::from_dense(&thin).into()
        } else {
            base.into()
        };
        let pair = |a: i64, b: i64, idx: &[usize], rng: &mut Rng| {
            (
                a,
                b,
                SvmModel {
                    sv: shared.select_rows(idx),
                    alpha_y: idx.iter().map(|_| rng.gauss()).collect(),
                    bias: rng.gauss(),
                    kernel: Kernel::Gaussian { h: 0.6 },
                    c: 1.5,
                    labels: DEFAULT_LABEL_PAIR,
                },
            )
        };
        let pairs = vec![
            pair(1, 3, &[0, 1, 2, 3], rng),
            pair(1, 7, &[1, 2, 4], rng),
            pair(3, 7, &[0, 3, 4, 5], rng),
        ];
        OvoModel::new(pairs, 1.5)
    }

    #[test]
    fn ovo_roundtrip_is_bit_exact() {
        for sparse in [false, true] {
            let mut rng = Rng::new(if sparse { 606 } else { 605 });
            let model = toy_ovo(&mut rng, sparse);
            assert!(model.n_sv_unique() < model.n_sv_total(), "pairs must share SVs");
            let dir = std::env::temp_dir()
                .join(format!("hss_svm_persist_ovo_{}_{sparse}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            let p = dir.join("m.ovo");
            save_ovo(&model, &p).unwrap();
            let back = load_ovo(&p).unwrap();
            assert_eq!(back.classes(), model.classes());
            assert_eq!(back.c(), model.c());
            assert_eq!(back.is_sparse(), sparse);
            assert_eq!(back.n_sv_unique(), model.n_sv_unique());
            for ((a1, b1, m1), (a2, b2, m2)) in model.pairs().iter().zip(back.pairs().iter()) {
                assert_eq!((a1, b1), (a2, b2));
                assert_eq!(m1.sv, m2.sv);
                assert_eq!(m1.alpha_y, m2.alpha_y);
                assert_eq!(m1.bias.to_bits(), m2.bias.to_bits());
                assert_eq!(m1.kernel, m2.kernel);
            }
            // identical predictions through the shared-SV engine
            let x: Points = Mat::gauss(9, 4, &mut rng).into();
            let f1 = model.decisions(&x, 1);
            let f2 = back.decisions(&x, 1);
            assert_eq!(f1.data(), f2.data(), "sparse={sparse}");
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn load_any_dispatches_on_magic() {
        let mut rng = Rng::new(607);
        let dir = std::env::temp_dir()
            .join(format!("hss_svm_persist_any_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let pb = dir.join("bin.model");
        save(&toy_model(&mut rng), &pb).unwrap();
        assert!(matches!(load_any(&pb).unwrap(), AnyModel::Binary(_)));
        let po = dir.join("ovo.model");
        save_ovo(&toy_ovo(&mut rng, false), &po).unwrap();
        let AnyModel::Ovo(back) = load_any(&po).unwrap() else {
            panic!("ovo file must load as AnyModel::Ovo");
        };
        assert_eq!(back.classes(), &[1, 3, 7]);
        let pg = dir.join("garbage.model");
        std::fs::write(&pg, "what even is this\n").unwrap();
        assert!(load_any(&pg).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("hss_svm_persist_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.model");
        std::fs::write(&p, "not a model\n").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
