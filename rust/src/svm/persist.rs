//! Model persistence: a self-describing text format (versioned, no
//! external serialization crates) compatible in spirit with LIBSVM's
//! model files. Round-trips exactly (f64 bit patterns are preserved via
//! hex encoding).
//!
//! Dense models write the original `sv <rows> <cols>` section; sparse
//! (CSR) models write `svsparse <rows> <cols>` with per-row
//! `<alpha> <index>:<hexval> ...` lines (0-based ascending indices), so
//! a rcv1-class model file stays O(nnz). The loader accepts both.
//!
//! Models trained on a non-±1 label encoding (e.g. a {1,2}-coded
//! LIBSVM file) carry an optional `labels <neg-hex> <pos-hex>` line
//! between `bias` and the SV section; files without it (all pre-v1.1
//! files, and files for ±1-coded data) default to `[-1, +1]`.

use crate::data::dataset::DEFAULT_LABEL_PAIR;
use crate::data::sparse::{CsrMat, Points};
use crate::kernel::Kernel;
use crate::linalg::Mat;
use crate::svm::SvmModel;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

const MAGIC: &str = "hss-svm-model v1";

/// Write a model to a file.
pub fn save(model: &SvmModel, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("cannot create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "{MAGIC}")?;
    match model.kernel {
        Kernel::Gaussian { h } => writeln!(w, "kernel gaussian {}", hexf(h))?,
        Kernel::Polynomial { degree, c } => writeln!(w, "kernel polynomial {degree} {}", hexf(c))?,
        Kernel::Linear => writeln!(w, "kernel linear")?,
    }
    writeln!(w, "c {}", hexf(model.c))?;
    writeln!(w, "bias {}", hexf(model.bias))?;
    if model.labels != DEFAULT_LABEL_PAIR {
        // optional: ±1 models keep the historical byte-identical format
        writeln!(w, "labels {} {}", hexf(model.labels[0]), hexf(model.labels[1]))?;
    }
    match &model.sv {
        Points::Dense(sv) => {
            writeln!(w, "sv {} {}", sv.rows(), sv.cols())?;
            for i in 0..sv.rows() {
                write!(w, "{}", hexf(model.alpha_y[i]))?;
                for &v in sv.row(i) {
                    write!(w, " {}", hexf(v))?;
                }
                writeln!(w)?;
            }
        }
        Points::Sparse(sv) => {
            writeln!(w, "svsparse {} {}", sv.rows(), sv.cols())?;
            for i in 0..sv.rows() {
                write!(w, "{}", hexf(model.alpha_y[i]))?;
                let (ci, vi) = sv.row(i);
                for (&c, &v) in ci.iter().zip(vi.iter()) {
                    write!(w, " {}:{}", c, hexf(v))?;
                }
                writeln!(w)?;
            }
        }
    }
    Ok(())
}

/// Load a model from a file.
pub fn load(path: impl AsRef<Path>) -> Result<SvmModel> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("cannot open {}", path.as_ref().display()))?;
    let mut lines = std::io::BufReader::new(f).lines();
    let mut next = || -> Result<String> {
        lines.next().context("unexpected end of model file")?.context("I/O error")
    };
    let magic = next()?;
    if magic.trim() != MAGIC {
        bail!("not a hss-svm model file (got header {magic:?})");
    }
    let kline = next()?;
    let mut kp = kline.split_ascii_whitespace();
    if kp.next() != Some("kernel") {
        bail!("expected kernel line, got {kline:?}");
    }
    let kernel = match kp.next() {
        Some("gaussian") => Kernel::Gaussian { h: unhexf(kp.next().context("missing h")?)? },
        Some("polynomial") => Kernel::Polynomial {
            degree: kp.next().context("missing degree")?.parse()?,
            c: unhexf(kp.next().context("missing c")?)?,
        },
        Some("linear") => Kernel::Linear,
        other => bail!("unknown kernel {other:?}"),
    };
    let c = parse_kv(&next()?, "c")?;
    let bias = parse_kv(&next()?, "bias")?;
    // optional `labels` line; older files go straight to the SV section
    let mut svline = next()?;
    let mut labels = DEFAULT_LABEL_PAIR;
    if let Some(rest) = svline.strip_prefix("labels ") {
        let mut lp = rest.split_ascii_whitespace();
        labels[0] = unhexf(lp.next().context("missing negative label")?)?;
        labels[1] = unhexf(lp.next().context("missing positive label")?)?;
        svline = next()?;
    }
    let mut sp = svline.split_ascii_whitespace();
    let kind = sp.next();
    if kind != Some("sv") && kind != Some("svsparse") {
        bail!("expected sv/svsparse line, got {svline:?}");
    }
    let rows: usize = sp.next().context("missing sv rows")?.parse()?;
    let cols: usize = sp.next().context("missing sv cols")?.parse()?;
    let mut alpha_y = Vec::with_capacity(rows);
    let sv: Points = if kind == Some("sv") {
        let mut sv = Mat::zeros(rows, cols);
        for i in 0..rows {
            let line = next()?;
            let mut parts = line.split_ascii_whitespace();
            alpha_y.push(unhexf(parts.next().context("missing alpha")?)?);
            for j in 0..cols {
                sv[(i, j)] = unhexf(
                    parts.next().with_context(|| format!("row {i}: missing sv value {j}"))?,
                )?;
            }
        }
        sv.into()
    } else {
        let mut sv_rows: Vec<Vec<(usize, f64)>> = Vec::with_capacity(rows);
        for i in 0..rows {
            let line = next()?;
            let mut parts = line.split_ascii_whitespace();
            alpha_y.push(unhexf(parts.next().context("missing alpha")?)?);
            let mut row: Vec<(usize, f64)> = Vec::new();
            for tok in parts {
                let (c_str, v_str) = tok
                    .split_once(':')
                    .with_context(|| format!("row {i}: bad sparse pair {tok:?}"))?;
                let col: usize = c_str
                    .parse()
                    .with_context(|| format!("row {i}: bad sparse index {c_str:?}"))?;
                if col >= cols {
                    bail!("row {i}: sparse index {col} out of range {cols}");
                }
                // validate here so corrupt files fail with Err like every
                // other loader path, not via CsrMat's construction assert
                if let Some(&(prev, _)) = row.last() {
                    if col <= prev {
                        bail!("row {i}: sparse index {col} not strictly ascending after {prev}");
                    }
                }
                row.push((col, unhexf(v_str)?));
            }
            sv_rows.push(row);
        }
        CsrMat::from_rows(cols, &sv_rows).into()
    };
    Ok(SvmModel { sv, alpha_y, bias, kernel, c, labels })
}

fn parse_kv(line: &str, key: &str) -> Result<f64> {
    let mut p = line.split_ascii_whitespace();
    if p.next() != Some(key) {
        bail!("expected {key} line, got {line:?}");
    }
    unhexf(p.next().with_context(|| format!("missing {key} value"))?)
}

/// Exact f64 as hex bits.
fn hexf(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn unhexf(s: &str) -> Result<f64> {
    let bits = u64::from_str_radix(s, 16).with_context(|| format!("bad f64 hex {s:?}"))?;
    Ok(f64::from_bits(bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn toy_model(rng: &mut Rng) -> SvmModel {
        SvmModel {
            sv: Mat::gauss(7, 3, rng).into(),
            alpha_y: (0..7).map(|_| rng.gauss()).collect(),
            bias: rng.gauss(),
            kernel: Kernel::Gaussian { h: 0.37 },
            c: 2.5,
            labels: DEFAULT_LABEL_PAIR,
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let mut rng = Rng::new(601);
        let model = toy_model(&mut rng);
        let dir = std::env::temp_dir().join("hss_svm_persist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.model");
        save(&model, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.sv, model.sv);
        assert_eq!(back.alpha_y, model.alpha_y);
        assert_eq!(back.bias.to_bits(), model.bias.to_bits());
        assert_eq!(back.kernel, model.kernel);
        assert_eq!(back.c, model.c);
        // identical decisions
        let x = Mat::gauss(10, 3, &mut rng);
        for i in 0..10 {
            assert_eq!(model.decision_one(x.row(i)), back.decision_one(x.row(i)));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sparse_roundtrip_is_bit_exact() {
        let mut rng = Rng::new(603);
        let dense = Mat::from_fn(6, 40, |i, j| {
            if (i * 7 + j) % 9 == 0 { rng.gauss() } else { 0.0 }
        });
        let model = SvmModel {
            sv: CsrMat::from_dense(&dense).into(),
            alpha_y: (0..6).map(|_| rng.gauss()).collect(),
            bias: rng.gauss(),
            kernel: Kernel::Gaussian { h: 1.2 },
            c: 0.5,
            labels: DEFAULT_LABEL_PAIR,
        };
        let dir = std::env::temp_dir()
            .join(format!("hss_svm_persist_sp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("sp.model");
        save(&model, &p).unwrap();
        let back = load(&p).unwrap();
        assert!(back.sv.is_sparse());
        assert_eq!(back.sv, model.sv);
        assert_eq!(back.alpha_y, model.alpha_y);
        assert_eq!(back.bias.to_bits(), model.bias.to_bits());
        // identical decisions through the sparse eval path
        for _ in 0..10 {
            let t: Vec<f64> = (0..40).map(|_| rng.gauss()).collect();
            assert_eq!(model.decision_one(&t), back.decision_one(&t));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn all_kernel_variants_roundtrip() {
        let mut rng = Rng::new(602);
        let dir = std::env::temp_dir().join("hss_svm_persist_test2");
        std::fs::create_dir_all(&dir).unwrap();
        for kernel in [
            Kernel::Gaussian { h: 1.5 },
            Kernel::Polynomial { degree: 3, c: 0.5 },
            Kernel::Linear,
        ] {
            let model = SvmModel { kernel, ..toy_model(&mut rng) };
            let p = dir.join("k.model");
            save(&model, &p).unwrap();
            assert_eq!(load(&p).unwrap().kernel, kernel);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn label_pair_roundtrips_and_defaults() {
        let mut rng = Rng::new(604);
        let dir = std::env::temp_dir()
            .join(format!("hss_svm_persist_lbl_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // non-default pair survives the round-trip bit-exactly
        let model = SvmModel { labels: [1.0, 2.0], ..toy_model(&mut rng) };
        let p = dir.join("lbl.model");
        save(&model, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.labels, [1.0, 2.0]);
        assert_eq!(back.sv, model.sv);
        assert_eq!(back.bias.to_bits(), model.bias.to_bits());
        // a ±1 model writes no labels line (old readers keep working)
        // and an old file without one loads with the default pair
        let dflt = toy_model(&mut rng);
        let p2 = dir.join("dflt.model");
        save(&dflt, &p2).unwrap();
        let text = std::fs::read_to_string(&p2).unwrap();
        assert!(!text.contains("labels "), "{text}");
        assert_eq!(load(&p2).unwrap().labels, DEFAULT_LABEL_PAIR);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("hss_svm_persist_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.model");
        std::fs::write(&p, "not a model\n").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
