//! Coarse-to-fine multilevel training with support-vector inheritance
//! (ROADMAP item 1; DESIGN.md §15).
//!
//! Two related-work tricks composed over machinery the stack already
//! has:
//!
//! * **AML-SVM-style refinement** (Sadrfaridpour et al.): train on a
//!   coarsened dataset, then refine level by level, warm-starting ADMM
//!   from the coarse iterates and restricting each finer level to the
//!   neighborhoods of the inherited support vectors. Our coarsening is
//!   the existing [`crate::cluster::ClusterTree`] — the frontier of the
//!   tree at level `L` *is* the coarse partition, and one representative
//!   per frontier node (the kept point nearest the node centroid) is the
//!   coarse training set. No new clustering pass runs.
//! * **Approximate-extreme-point screening** (Nandan & Khargonekar):
//!   before any kernel work, drop points that are ε-covered by an
//!   already-selected point of the same class inside their cluster-tree
//!   leaf — a cheap convex-hull proxy that shrinks every level,
//!   including the final one.
//!
//! The per-level dataflow (one `(h, β)` pair, the whole C row at once):
//!
//! ```text
//! level L (coarse)    T_L = representatives(frontier(L)) ∩ kept
//!      │ train (cold, batched run_grid)
//!      ▼
//! level L+1           T = SV_prev ∪ (ANN(SV_prev) ∩ reps(L+1))
//!      │ train (warm: z, μ scattered from level L; run_grid_warm)
//!      ▼
//!     ...
//! final level         T = SV_prev ∪ ANN(SV_prev) over all kept points
//!                     (falls back to ALL kept points only if the SV
//!                      set is still growing faster than `growth_tol`)
//! ```
//!
//! Every level is a plain [`HssSvmTrainer`] run on a
//! [`Dataset::select`]-ed subset — compression, factorization and the
//! batched ADMM are unchanged, so each level inherits the bitwise
//! thread-invariance contract, and therefore the whole multilevel
//! trainer does too (pinned by `tests/multilevel_e2e.rs`).
//!
//! All set bookkeeping uses position-indexed `Vec<bool>` masks and
//! ordered scans (never hash sets), so results are pure functions of
//! `(dataset, HssParams.seed, MultilevelParams)`.

use crate::admm::{AdmmOutput, AdmmParams, AdmmSolver};
use crate::cluster::ClusterTree;
use crate::data::Dataset;
use crate::hss::compress::{preprocess, Preprocessed};
use crate::hss::HssParams;
use crate::kernel::Kernel;
use crate::obs;
use crate::svm::model::SvmModel;
use crate::svm::train::HssSvmTrainer;
use crate::util::timer::Timer;
use anyhow::Result;

/// Knobs of the coarse-to-fine schedule. Everything is deterministic:
/// the trained models are pure functions of `(dataset, HssParams.seed,
/// MultilevelParams)` — thread counts never change a bit.
#[derive(Clone, Copy, Debug)]
pub struct MultilevelParams {
    /// Tree level of the coarsest training set (`--coarse-level`).
    /// Clamped into `[0, depth-1]`; `None` picks the deepest level whose
    /// frontier still has ≲ `n / 8` nodes, so the coarse problem is ~an
    /// order of magnitude smaller than the full one. Levels whose pool
    /// is smaller than [`MultilevelParams::min_level_points`] (L = 0 has
    /// a single representative) are skipped, not trained.
    pub coarse_level: Option<usize>,
    /// Extreme-point screening radius ε (`--screen-eps`): inside each
    /// cluster-tree leaf a point is dropped when an already-selected
    /// point of the same class sits within distance ε. `0` disables
    /// screening (every point kept).
    pub screen_eps: f64,
    /// How many ANN neighbours of each inherited support vector are
    /// admitted into the next level's training set.
    pub sv_neighbors: usize,
    /// Levels whose training set would be smaller than this (or miss a
    /// class) are skipped — they cannot carry a meaningful decision
    /// boundary and would only add noise to the warm start.
    pub min_level_points: usize,
    /// Full-set fallback trigger: the final level trains on ALL kept
    /// points (instead of the SV neighborhood) when the union-SV count
    /// grew by more than this factor between the last two levels —
    /// i.e. the SV set had not stabilized yet.
    pub growth_tol: f64,
}

impl Default for MultilevelParams {
    fn default() -> Self {
        MultilevelParams {
            coarse_level: None,
            screen_eps: 0.0,
            sv_neighbors: 8,
            min_level_points: 32,
            growth_tol: 1.10,
        }
    }
}

/// Per-level report: sizes, timing and the (position-indexed) training /
/// support-vector sets, in pds order. `tests/multilevel_e2e.rs` checks
/// the SV-inheritance contract on these: `sv_idx` of level ℓ is a subset
/// of `t_idx` of level ℓ+1.
#[derive(Clone, Debug)]
pub struct LevelStats {
    /// Cluster-tree level this training set was drawn from
    /// (`usize::MAX` labels the final full-resolution level).
    pub level: usize,
    /// Training-set size |T_ℓ|.
    pub n_points: usize,
    /// Union support-vector count across the C row after this level.
    pub n_sv: usize,
    /// Wall-clock of the level (compress + factor + ADMM).
    pub secs: f64,
    /// Training-set positions (sorted, in full-set pds order).
    pub t_idx: Vec<usize>,
    /// Union-SV positions after the level (sorted, pds order).
    pub sv_idx: Vec<usize>,
    /// Whether the final level fell back to all kept points.
    pub full_fallback: bool,
}

/// Result of a multilevel grid run for one `(h, β)` pair: the final
/// models/outputs (one per C, same shape as
/// [`HssSvmTrainer::train_grid_with_solver`]) plus the level schedule
/// that produced them.
pub struct MultilevelRun {
    /// `(model, admm_output)` per C value, trained at full resolution.
    pub results: Vec<(SvmModel, AdmmOutput)>,
    /// One entry per trained level, coarse → fine.
    pub levels: Vec<LevelStats>,
}

impl MultilevelRun {
    /// Total points trained across all levels (Σ |T_ℓ|) — the multilevel
    /// cost proxy reported by `--multilevel` summaries.
    pub fn points_trained(&self) -> usize {
        self.levels.iter().map(|l| l.n_points).sum()
    }
}

/// Frontier of the cluster tree at `level`: the node set that partitions
/// `0..n` using every node at exactly `level` plus the leaves that
/// bottom out earlier (the tree is ragged — small ranges stop splitting
/// before `level`). Returned sorted by `begin`, so iterating the
/// frontier scans positions in order.
pub fn frontier_nodes(tree: &ClusterTree, level: usize) -> Vec<usize> {
    let mut out: Vec<usize> = (0..tree.nodes.len())
        .filter(|&i| {
            let n = &tree.nodes[i];
            n.level == level || (n.is_leaf() && n.level < level)
        })
        .collect();
    out.sort_by_key(|&i| tree.nodes[i].begin);
    out
}

/// Select one representative per frontier node at `level`: the **kept**
/// point of the node's range nearest the node centroid (of kept points),
/// ties broken toward the smallest position. `pds` must be the dataset
/// in tree order (rows `begin..end` of a node are its points) and `keep`
/// a per-position mask, e.g. from [`screen_extreme_points`]. Nodes with
/// no kept point contribute nothing. The result is sorted, duplicate
/// free, and a pure function of its arguments — no RNG, no threading —
/// which is what makes the whole schedule deterministic
/// (`tests/multilevel_determinism.rs`).
///
/// ```
/// use hss_svm::data::synth;
/// use hss_svm::hss::{compress::preprocess, HssParams};
/// use hss_svm::svm::multilevel::{frontier_nodes, select_representatives};
/// use hss_svm::util::prng::Rng;
///
/// let mut rng = Rng::new(7);
/// let ds = synth::blobs(200, 3, 4, 0.3, &mut rng);
/// let mut hp = HssParams::low_accuracy();
/// hp.leaf_size = 16;
/// let pre = preprocess(&ds, &hp, 1);
/// let keep = vec![true; ds.len()];
/// let reps = select_representatives(&pre.pds, &pre.tree, 2, &keep);
/// // one representative per frontier node, at strictly increasing positions
/// assert_eq!(reps.len(), frontier_nodes(&pre.tree, 2).len());
/// assert!(reps.windows(2).all(|w| w[0] < w[1]));
/// // masking a representative out changes the selection, never panics
/// let mut partial = keep.clone();
/// partial[reps[0]] = false;
/// let reps2 = select_representatives(&pre.pds, &pre.tree, 2, &partial);
/// assert!(!reps2.contains(&reps[0]));
/// ```
pub fn select_representatives(
    pds: &Dataset,
    tree: &ClusterTree,
    level: usize,
    keep: &[bool],
) -> Vec<usize> {
    assert_eq!(keep.len(), pds.len(), "keep mask/dataset length mismatch");
    let dim = pds.dim();
    let mut reps = Vec::new();
    for id in frontier_nodes(tree, level) {
        let node = &tree.nodes[id];
        // centroid of the kept points of the node
        let mut centroid = vec![0.0; dim];
        let mut count = 0usize;
        for p in node.begin..node.end {
            if keep[p] {
                pds.x.add_row_scaled(p, 1.0, &mut centroid);
                count += 1;
            }
        }
        if count == 0 {
            continue;
        }
        for v in &mut centroid {
            *v /= count as f64;
        }
        // kept point nearest the centroid; strict < keeps the first
        // (smallest-position) point on ties
        let mut best = usize::MAX;
        let mut best_d2 = f64::INFINITY;
        for p in node.begin..node.end {
            if keep[p] {
                let d2 = pds.x.dist2_dense_vec(p, &centroid);
                if d2 < best_d2 {
                    best_d2 = d2;
                    best = p;
                }
            }
        }
        reps.push(best);
    }
    reps
}

/// Approximate-extreme-point screening (Nandan & Khargonekar's DeriveRS
/// idea, reduced to the cluster-tree geometry we already have): inside
/// each tree leaf, scan positions in order and keep a point only if no
/// already-kept point of the **same class** sits within distance `eps`.
/// The kept set is a greedy ε-net per (leaf, class) — interior points of
/// dense same-class regions are dropped, boundary geometry survives.
/// Runs on raw coordinates only, **before** any kernel evaluation or
/// compression, which is why it shrinks every downstream cost
/// (DESIGN.md §15). `eps <= 0` keeps everything. Deterministic: the
/// scan order is the tree order.
pub fn screen_extreme_points(pds: &Dataset, tree: &ClusterTree, eps: f64) -> Vec<bool> {
    let n = pds.len();
    if eps <= 0.0 {
        return vec![true; n];
    }
    let eps2 = eps * eps;
    let mut keep = vec![false; n];
    for leaf in tree.leaves() {
        let node = &tree.nodes[leaf];
        // kept positions of the leaf so far, scanned per candidate —
        // leaves are small (≤ leaf_size), so this stays O(leaf²) worst
        // case with tiny constants
        let mut kept_here: Vec<usize> = Vec::new();
        for p in node.begin..node.end {
            let covered = kept_here.iter().any(|&q| {
                pds.y[q] == pds.y[p] && pds.x.dist2_rows(p, &pds.x, q) <= eps2
            });
            if !covered {
                keep[p] = true;
                kept_here.push(p);
            }
        }
    }
    keep
}

/// Shared multilevel preprocessing state: one full-set cluster tree +
/// ANN pass + screening + level schedule, computed **once** and reused
/// across every h of a grid search *and* every C of the row — the same
/// reuse shape as [`crate::coordinator::cache::KernelCache`], one layer
/// up. The per-level subsets are re-preprocessed per call (they are
/// small; that is the point), but the full-set work never repeats.
pub struct MultilevelContext {
    /// Full-set kernel-independent preprocessing (tree, pds, ANN).
    pre: Preprocessed,
    /// Screening mask in pds order (`true` = train on this point).
    keep: Vec<bool>,
    /// Candidate pool per level, coarse → fine, as sorted pds positions.
    /// The final entry is every kept point (full resolution).
    pools: Vec<Vec<usize>>,
    /// Tree level of each pool (`usize::MAX` for the final full pool).
    pool_levels: Vec<usize>,
    hss: HssParams,
    ml: MultilevelParams,
    threads: usize,
}

impl MultilevelContext {
    /// Build the shared state: preprocess the full set, screen it, and
    /// lay out the level schedule from `coarse_level` (auto-picked when
    /// `None`) down to full resolution. Pools smaller than
    /// `min_level_points` or missing a class are dropped here, so edge
    /// cases like `--coarse-level 0` (a single representative) degrade
    /// gracefully to the deepest usable schedule.
    pub fn new(ds: &Dataset, hss: &HssParams, ml: &MultilevelParams, threads: usize) -> Self {
        let threads = threads.max(1);
        let pre = preprocess(ds, hss, threads);
        let keep = screen_extreme_points(&pre.pds, &pre.tree, ml.screen_eps);
        let n = pre.pds.len();
        let depth = pre.tree.depth();

        let coarse = match ml.coarse_level {
            Some(l) => l.min(depth - 1),
            None => auto_coarse_level(&pre.tree, n),
        };

        let mut pools = Vec::new();
        let mut pool_levels = Vec::new();
        for level in coarse..depth {
            let reps = select_representatives(&pre.pds, &pre.tree, level, &keep);
            if usable(&reps, &pre.pds, ml.min_level_points) {
                // a pool identical to the previous one adds a level of
                // pure overhead (happens when the frontier stops
                // growing); skip it
                if pools.last().is_none_or(|prev: &Vec<usize>| prev != &reps) {
                    pools.push(reps);
                    pool_levels.push(level);
                }
            }
        }
        let full: Vec<usize> = (0..n).filter(|&p| keep[p]).collect();
        // drop rep pools as large as the full set — no coarsening left
        while pools.last().is_some_and(|p| p.len() >= full.len()) {
            pools.pop();
            pool_levels.pop();
        }
        pools.push(full);
        pool_levels.push(usize::MAX);

        MultilevelContext { pre, keep, pools, pool_levels, hss: *hss, ml: *ml, threads }
    }

    /// Number of points surviving screening.
    pub fn kept(&self) -> usize {
        self.keep.iter().filter(|&&k| k).count()
    }

    /// Training-set size per scheduled level, coarse → fine (the last
    /// entry is the full-resolution pool ceiling, not necessarily what
    /// the final level trains on — see [`MultilevelParams::growth_tol`]).
    pub fn pool_sizes(&self) -> Vec<usize> {
        self.pools.iter().map(|p| p.len()).collect()
    }

    /// The shared full-set preprocessing (tree + ANN + permuted data).
    pub fn preprocessed(&self) -> &Preprocessed {
        &self.pre
    }

    /// Train the whole C row coarse-to-fine for one `(kernel, β)` pair.
    /// Per level: select the training subset, compress + factor it with
    /// the unchanged [`HssSvmTrainer`], advance every C in lockstep via
    /// [`AdmmSolver::run_grid_warm`] (warm-started from the previous
    /// level's iterates scattered onto the new subset), then inherit the
    /// union of the per-column SV sets — expanded by `sv_neighbors` ANN
    /// neighbours — into the next level. The returned models are
    /// assembled at full resolution on the final level.
    pub fn train_grid(
        &self,
        kernel: Kernel,
        admm: &AdmmParams,
        cs: &[f64],
    ) -> Result<MultilevelRun> {
        let n = self.pre.pds.len();
        let k = cs.len();
        assert!(k > 0, "empty C grid");
        // full-length iterate carriers per C column (pds order)
        let mut z_full = vec![vec![0.0f64; n]; k];
        let mut mu_full = vec![vec![0.0f64; n]; k];
        let mut prev_sv: Option<Vec<bool>> = None;
        let mut prev_sv_count = 0usize;
        let mut levels: Vec<LevelStats> = Vec::new();
        let mut results: Vec<(SvmModel, AdmmOutput)> = Vec::new();

        let n_pools = self.pools.len();
        for (li, pool) in self.pools.iter().enumerate() {
            let t = Timer::start();
            let is_final = li == n_pools - 1;
            let (t_idx, full_fallback) = match &prev_sv {
                None => (pool.clone(), false),
                Some(sv_mask) => {
                    // full-set fallback: SV count still growing too fast
                    // entering the final level
                    let grew = levels.len().checked_sub(2).is_some_and(|i| {
                        prev_sv_count as f64 > self.ml.growth_tol * levels[i].n_sv as f64
                    });
                    if is_final && grew {
                        (pool.clone(), true)
                    } else {
                        let t = self.inherit(sv_mask, pool);
                        // a degenerate inherited set (tiny / one class)
                        // cannot carry the final model — fall back
                        if is_final && !usable(&t, &self.pre.pds, 2) {
                            (pool.clone(), true)
                        } else {
                            (t, false)
                        }
                    }
                }
            };
            // degenerate level (tiny or single-class): skip unless final
            if !is_final && !usable(&t_idx, &self.pre.pds, self.ml.min_level_points) {
                continue;
            }
            let sub = self.pre.pds.select(&t_idx);
            let pre_sub = preprocess(&sub, &self.hss, self.threads);
            let trainer =
                HssSvmTrainer::compress_preprocessed(&pre_sub, kernel, &self.hss, self.threads);
            let ulv = trainer.factor(admm.beta)?;
            let solver = AdmmSolver::new(&ulv, &trainer.y, *admm).with_threads(self.threads);

            // map the subset's tree-order row r back to a full-set pds
            // position: row r is sub's point pre_sub.tree.perm[r], which
            // is t_idx[...] in the full ordering
            let global_of: Vec<usize> =
                pre_sub.tree.perm.iter().map(|&p| t_idx[p]).collect();

            // gather per-column warm starts from the full-length iterates
            let m = t_idx.len();
            let (warm_z, warm_mu): (Vec<Vec<f64>>, Vec<Vec<f64>>) = (0..k)
                .map(|j| {
                    let z: Vec<f64> = (0..m).map(|r| z_full[j][global_of[r]]).collect();
                    let mu: Vec<f64> = (0..m).map(|r| mu_full[j][global_of[r]]).collect();
                    (z, mu)
                })
                .unzip();
            let warms: Vec<Option<(&[f64], &[f64])>> = if levels.is_empty() {
                Vec::new() // coarsest level: cold start
            } else {
                (0..k).map(|j| Some((warm_z[j].as_slice(), warm_mu[j].as_slice()))).collect()
            };

            let outs: Vec<AdmmOutput> = solver.run_grid_warm(cs, &warms);

            // scatter the iterates back and take the union-SV mask
            let mut sv_mask = vec![false; n];
            for (j, out) in outs.iter().enumerate() {
                let sv_tol = 1e-8 * cs[j].max(1.0);
                for zj in z_full[j].iter_mut() {
                    *zj = 0.0;
                }
                for mj in mu_full[j].iter_mut() {
                    *mj = 0.0;
                }
                for r in 0..m {
                    let g = global_of[r];
                    z_full[j][g] = out.z[r];
                    mu_full[j][g] = out.mu[r];
                    if out.z[r] > sv_tol {
                        sv_mask[g] = true;
                    }
                }
            }
            let sv_idx: Vec<usize> = (0..n).filter(|&p| sv_mask[p]).collect();
            prev_sv_count = sv_idx.len();

            if is_final {
                results = outs
                    .iter()
                    .zip(cs.iter())
                    .map(|(out, &c)| (trainer.assemble_model(&out.z, c), out.clone()))
                    .collect();
            }

            let secs = t.secs();
            let level_label = self.pool_levels[li];
            if obs::enabled() {
                let name = if is_final {
                    format!("multilevel-final-{}pts", t_idx.len())
                } else {
                    format!("multilevel-level-{level_label}")
                };
                obs::emit(&obs::TraceEvent::Phase { name, secs });
            }
            levels.push(LevelStats {
                level: level_label,
                n_points: t_idx.len(),
                n_sv: prev_sv_count,
                secs,
                t_idx,
                sv_idx,
                full_fallback,
            });
            prev_sv = Some(sv_mask);
        }

        Ok(MultilevelRun { results, levels })
    }

    /// Single-C convenience wrapper over [`MultilevelContext::train_grid`].
    pub fn train(
        &self,
        kernel: Kernel,
        admm: &AdmmParams,
        c: f64,
    ) -> Result<(SvmModel, AdmmOutput, Vec<LevelStats>)> {
        let mut run = self.train_grid(kernel, admm, &[c])?;
        let (model, out) = run.results.remove(0);
        Ok((model, out, run.levels))
    }

    /// Next-level training set: the inherited SVs themselves plus their
    /// `sv_neighbors` nearest ANN neighbours, intersected with the
    /// level's candidate pool. SVs are always included even when outside
    /// the pool — that is the SV-inheritance monotonicity contract
    /// (`SV_ℓ ⊆ T_{ℓ+1}`, pinned by `tests/multilevel_e2e.rs`).
    fn inherit(&self, sv_mask: &[bool], pool: &[usize]) -> Vec<usize> {
        let n = sv_mask.len();
        let mut in_pool = vec![false; n];
        for &p in pool {
            in_pool[p] = true;
        }
        let mut take = vec![false; n];
        for p in 0..n {
            if sv_mask[p] {
                take[p] = true;
                for &(q, _) in self.pre.ann.neighbors[p].iter().take(self.ml.sv_neighbors) {
                    if in_pool[q] && self.keep[q] {
                        take[q] = true;
                    }
                }
            }
        }
        (0..n).filter(|&p| take[p]).collect()
    }
}

/// Deepest tree level whose frontier has at most `n / 8` nodes — the
/// default coarse level: roughly an order of magnitude fewer training
/// points than the full problem, while staying fine enough to see every
/// well-separated cluster.
fn auto_coarse_level(tree: &ClusterTree, n: usize) -> usize {
    let depth = tree.depth();
    let target = (n / 8).max(2);
    let mut pick = 0;
    for level in 0..depth {
        if frontier_nodes(tree, level).len() <= target {
            pick = level;
        } else {
            break;
        }
    }
    pick
}

/// A training subset is usable when it reaches `min_points` (never below
/// 2 — compression needs that) and carries both classes.
fn usable(idx: &[usize], pds: &Dataset, min_points: usize) -> bool {
    if idx.len() < min_points.max(2) {
        return false;
    }
    let first = pds.y[idx[0]];
    idx.iter().any(|&p| pds.y[p] != first)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::svm::predict;
    use crate::util::prng::Rng;

    fn fixture(n: usize) -> (Dataset, HssParams) {
        let mut rng = Rng::new(4_242);
        let ds = synth::blobs(n, 4, 3, 0.3, &mut rng);
        let mut hp = HssParams::low_accuracy();
        hp.leaf_size = 32;
        (ds, hp)
    }

    #[test]
    fn frontier_partitions_positions() {
        let (ds, hp) = fixture(500);
        let pre = preprocess(&ds, &hp, 1);
        for level in 0..pre.tree.depth() {
            let frontier = frontier_nodes(&pre.tree, level);
            let mut cursor = 0;
            for id in frontier {
                assert_eq!(pre.tree.nodes[id].begin, cursor, "gap at level {level}");
                cursor = pre.tree.nodes[id].end;
            }
            assert_eq!(cursor, ds.len(), "frontier at level {level} does not tile");
        }
    }

    #[test]
    fn representatives_are_kept_and_in_range() {
        let (ds, hp) = fixture(400);
        let pre = preprocess(&ds, &hp, 1);
        let mut keep = vec![true; ds.len()];
        // knock out a band of positions; reps must avoid it
        for k in keep.iter_mut().take(120).skip(40) {
            *k = false;
        }
        for level in 0..pre.tree.depth() {
            let reps = select_representatives(&pre.pds, &pre.tree, level, &keep);
            for &r in &reps {
                assert!(keep[r], "representative {r} was screened out");
            }
            assert!(reps.windows(2).all(|w| w[0] < w[1]), "reps not strictly sorted");
        }
    }

    #[test]
    fn screening_keeps_boundaries_and_thins_interiors() {
        let (ds, hp) = fixture(600);
        let pre = preprocess(&ds, &hp, 1);
        let keep_off = screen_extreme_points(&pre.pds, &pre.tree, 0.0);
        assert!(keep_off.iter().all(|&k| k), "eps = 0 must keep everything");
        let keep_on = screen_extreme_points(&pre.pds, &pre.tree, 0.4);
        let kept = keep_on.iter().filter(|&&k| k).count();
        assert!(kept < ds.len(), "eps = 0.4 should drop interior points");
        assert!(kept > ds.len() / 10, "screening dropped nearly everything");
        // monotone: larger eps keeps a subset-or-equal count
        let keep_big = screen_extreme_points(&pre.pds, &pre.tree, 0.8);
        let kept_big = keep_big.iter().filter(|&&k| k).count();
        assert!(kept_big <= kept, "larger eps kept more points ({kept_big} > {kept})");
    }

    #[test]
    fn multilevel_matches_flat_accuracy_on_blobs() {
        let mut rng = Rng::new(91);
        let ds = synth::xor_blobs(1400, 4, 0.35, &mut rng);
        let (train, test) = ds.split_at(1000);
        let kernel = Kernel::Gaussian { h: 1.2 };
        let mut hp = HssParams::low_accuracy();
        hp.leaf_size = 48;
        let admm = AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 };
        let c = 1.0;

        let (flat_model, _) =
            crate::svm::train::train_hss_svm(&train, kernel, &hp, &admm, c, 2).unwrap();
        let flat_acc = predict::accuracy(&flat_model, &test, 2);

        let ctx = MultilevelContext::new(&train, &hp, &MultilevelParams::default(), 2);
        let (ml_model, _, levels) = ctx.train(kernel, &admm, c).unwrap();
        let ml_acc = predict::accuracy(&ml_model, &test, 2);

        assert!(!levels.is_empty());
        assert!(
            levels[0].n_points < train.len() / 2,
            "coarse level is not coarse: {} of {}",
            levels[0].n_points,
            train.len()
        );
        assert!(
            (flat_acc - ml_acc).abs() <= 0.02,
            "multilevel accuracy {ml_acc} vs flat {flat_acc}"
        );
    }

    #[test]
    fn grid_row_matches_single_c_runs() {
        // the batched multilevel row must agree with per-C multilevel
        // runs — the run_grid_warm contract lifted one layer up (the
        // row inherits the UNION of the columns' SVs, so bitwise
        // equality is not promised; decision signs on separable data
        // are)
        let mut rng = Rng::new(4_243);
        let ds = synth::xor_blobs(700, 4, 0.35, &mut rng);
        let mut hp = HssParams::low_accuracy();
        hp.leaf_size = 32;
        let kernel = Kernel::Gaussian { h: 1.0 };
        let admm = AdmmParams { beta: 100.0, max_it: 8, relax: 1.0, tol: 0.0 };
        let cs = [0.5, 2.0];
        let ctx = MultilevelContext::new(&ds, &hp, &MultilevelParams::default(), 2);
        let run = ctx.train_grid(kernel, &admm, &cs).unwrap();
        assert_eq!(run.results.len(), cs.len());
        assert!(run.points_trained() > 0);
        for (j, &c) in cs.iter().enumerate() {
            let (m_single, out_single, _) = ctx.train(kernel, &admm, c).unwrap();
            let f_row = predict::decision_function(&run.results[j].0, &ds.x, 1);
            let f_single = predict::decision_function(&m_single, &ds.x, 1);
            let mut agree = 0usize;
            for (a, b) in f_row.iter().zip(f_single.iter()) {
                if (a > &0.0) == (b > &0.0) {
                    agree += 1;
                }
            }
            assert!(
                agree as f64 >= 0.97 * ds.len() as f64,
                "C={c}: batched and single-C multilevel models disagree on {} of {} signs",
                ds.len() - agree,
                ds.len()
            );
            assert!(out_single.iterations() > 0);
        }
    }

    #[test]
    fn coarse_level_edge_cases_train() {
        let (ds, hp) = fixture(450);
        let kernel = Kernel::Gaussian { h: 1.0 };
        let admm = AdmmParams { beta: 100.0, max_it: 8, relax: 1.0, tol: 0.0 };
        for coarse in [Some(0), Some(usize::MAX)] {
            let ml = MultilevelParams { coarse_level: coarse, ..Default::default() };
            let ctx = MultilevelContext::new(&ds, &hp, &ml, 1);
            let (model, _, levels) = ctx.train(kernel, &admm, 1.0).unwrap();
            assert!(model.n_sv() > 0, "coarse={coarse:?} produced an empty model");
            assert!(!levels.is_empty());
        }
    }
}
