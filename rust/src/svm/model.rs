//! Trained SVM model: support vectors, coefficients, bias.

use crate::data::dataset::DEFAULT_LABEL_PAIR;
use crate::data::sparse::Points;
use crate::kernel::Kernel;

/// A trained (binary) SVM classifier.
///
/// Stores only the support vectors (points with nonzero dual weight),
/// their combined coefficients αᵢyᵢ, and the bias b. The decision
/// function is  f(t) = Σᵢ (αy)ᵢ K(svᵢ, t) + b. Support vectors keep the
/// representation of the training data: models trained on CSR inputs
/// hold CSR support vectors, so a rcv1-class model does not densify
/// n_sv × 47k slots.
#[derive(Clone)]
pub struct SvmModel {
    /// Support vectors, one per row (dense or CSR).
    pub sv: Points,
    /// Combined coefficients (αy)ᵢ = αᵢ·yᵢ, one per support vector.
    pub alpha_y: Vec<f64>,
    /// Bias term b.
    pub bias: f64,
    /// Kernel the model was trained with.
    pub kernel: Kernel,
    /// Penalty C used at training time (diagnostics).
    pub c: f64,
    /// Original dataset label pair `[negative, positive]`. Predictions
    /// map back through it, so a model trained on a {1,2}-coded file
    /// answers `1`/`2` instead of hardcoded `±1`. Equal to
    /// [`DEFAULT_LABEL_PAIR`] for ±1-coded (or synthetic) training data
    /// and for model files that predate the `labels` line.
    pub labels: [f64; 2],
}

impl SvmModel {
    /// Number of support vectors.
    pub fn n_sv(&self) -> usize {
        self.sv.rows()
    }

    /// Map a decision value onto the model's original label pair.
    pub fn label_of(&self, decision: f64) -> f64 {
        if decision >= 0.0 {
            self.labels[1]
        } else {
            self.labels[0]
        }
    }

    /// The label for a decision value as output text: the default pair
    /// keeps the historical explicit-sign `+1`/`-1` spelling, any other
    /// pair prints the original label value.
    pub fn label_text(&self, decision: f64) -> String {
        if self.labels == DEFAULT_LABEL_PAIR {
            (if decision >= 0.0 { "+1" } else { "-1" }).to_string()
        } else {
            format!("{}", self.label_of(decision))
        }
    }

    /// Decision value for a single (dense) point.
    pub fn decision_one(&self, t: &[f64]) -> f64 {
        let mut f = self.bias;
        match &self.sv {
            Points::Dense(m) => {
                for i in 0..m.rows() {
                    f += self.alpha_y[i] * self.kernel.eval(m.row(i), t);
                }
            }
            Points::Sparse(_) => {
                // hoist ‖t‖² out of the SV loop — it is O(dim) while the
                // per-SV work is O(nnz_row)
                let nt = crate::linalg::dot(t, t);
                for i in 0..self.n_sv() {
                    let ni = self.sv.dot_row(i, &self.sv, i);
                    let ab = self.sv.dot_dense_vec(i, t);
                    f += self.alpha_y[i] * self.kernel.eval_from_parts(ni, nt, ab);
                }
            }
        }
        f
    }

    /// Predicted label for a single point (in the model's original
    /// label pair — ±1 unless trained on another encoding).
    pub fn predict_one(&self, t: &[f64]) -> f64 {
        self.label_of(self.decision_one(t))
    }

    /// Model memory footprint (bytes).
    pub fn memory_bytes(&self) -> usize {
        self.sv.bytes() + self.alpha_y.len() * std::mem::size_of::<f64>()
    }
}

impl std::fmt::Debug for SvmModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SvmModel({} SVs, dim {}, {}{}, C={}, b={:.4})",
            self.n_sv(),
            self.sv.cols(),
            self.kernel.label(),
            if self.sv.is_sparse() { ", sparse" } else { "" },
            self.c,
            self.bias
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::CsrMat;
    use crate::linalg::Mat;

    #[test]
    fn decision_function_hand_computed() {
        // two SVs on a line with linear kernel: f(t) = 1·(1·t) − 0.5·(2·t) + 0.25
        let sv = Mat::from_vec(2, 1, vec![1.0, 2.0]);
        let m = SvmModel {
            sv: sv.into(),
            alpha_y: vec![1.0, -0.5],
            bias: 0.25,
            kernel: Kernel::Linear,
            c: 1.0,
            labels: DEFAULT_LABEL_PAIR,
        };
        let f = m.decision_one(&[3.0]);
        // 1*3 − 0.5*6 + 0.25 = 0.25
        assert!((f - 0.25).abs() < 1e-14);
        assert_eq!(m.predict_one(&[3.0]), 1.0);
        assert_eq!(m.n_sv(), 2);
        assert!(m.memory_bytes() > 0);
    }

    #[test]
    fn sparse_model_decisions_match_dense() {
        let sv = Mat::from_vec(3, 4, vec![
            1.0, 0.0, 2.0, 0.0, //
            0.0, 0.0, 0.0, 0.0, //
            0.5, -1.0, 0.0, 3.0,
        ]);
        let alpha_y = vec![0.7, -0.2, 1.1];
        let dense = SvmModel {
            sv: sv.clone().into(),
            alpha_y: alpha_y.clone(),
            bias: -0.3,
            kernel: Kernel::Gaussian { h: 0.9 },
            c: 1.0,
            labels: DEFAULT_LABEL_PAIR,
        };
        let sparse = SvmModel { sv: CsrMat::from_dense(&sv).into(), ..dense.clone() };
        assert!(sparse.sv.is_sparse());
        for t in [[0.0, 0.0, 0.0, 0.0], [1.0, -1.0, 2.0, 3.0], [0.5, 0.0, 0.0, 0.0]] {
            let (fd, fs) = (dense.decision_one(&t), sparse.decision_one(&t));
            assert!((fd - fs).abs() <= 1e-12 * (1.0 + fd.abs()), "{fd} vs {fs}");
        }
        assert!(sparse.memory_bytes() < dense.memory_bytes() + 200);
    }

    #[test]
    fn label_pair_maps_decisions_back() {
        let sv = Mat::from_vec(1, 1, vec![1.0]);
        let mut m = SvmModel {
            sv: sv.into(),
            alpha_y: vec![1.0],
            bias: 0.0,
            kernel: Kernel::Linear,
            c: 1.0,
            labels: DEFAULT_LABEL_PAIR,
        };
        assert_eq!(m.predict_one(&[2.0]), 1.0);
        assert_eq!(m.predict_one(&[-2.0]), -1.0);
        assert_eq!(m.label_text(3.0), "+1");
        assert_eq!(m.label_text(-3.0), "-1");
        // {1,2}-coded training data: decisions answer in the original pair
        m.labels = [1.0, 2.0];
        assert_eq!(m.predict_one(&[2.0]), 2.0);
        assert_eq!(m.predict_one(&[-2.0]), 1.0);
        assert_eq!(m.label_text(3.0), "2");
        assert_eq!(m.label_text(-3.0), "1");
    }
}
