//! Trained SVM model: support vectors, coefficients, bias.

use crate::kernel::Kernel;
use crate::linalg::Mat;

/// A trained (binary) SVM classifier.
///
/// Stores only the support vectors (points with nonzero dual weight),
/// their combined coefficients αᵢyᵢ, and the bias b. The decision
/// function is  f(t) = Σᵢ (αy)ᵢ K(svᵢ, t) + b.
#[derive(Clone)]
pub struct SvmModel {
    /// Support vectors, one per row.
    pub sv: Mat,
    /// Combined coefficients (αy)ᵢ = αᵢ·yᵢ, one per support vector.
    pub alpha_y: Vec<f64>,
    /// Bias term b.
    pub bias: f64,
    /// Kernel the model was trained with.
    pub kernel: Kernel,
    /// Penalty C used at training time (diagnostics).
    pub c: f64,
}

impl SvmModel {
    /// Number of support vectors.
    pub fn n_sv(&self) -> usize {
        self.sv.rows()
    }

    /// Decision value for a single point.
    pub fn decision_one(&self, t: &[f64]) -> f64 {
        let mut f = self.bias;
        for i in 0..self.n_sv() {
            f += self.alpha_y[i] * self.kernel.eval(self.sv.row(i), t);
        }
        f
    }

    /// Predicted label (±1) for a single point.
    pub fn predict_one(&self, t: &[f64]) -> f64 {
        if self.decision_one(t) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Model memory footprint (bytes).
    pub fn memory_bytes(&self) -> usize {
        self.sv.bytes() + self.alpha_y.len() * std::mem::size_of::<f64>()
    }
}

impl std::fmt::Debug for SvmModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SvmModel({} SVs, dim {}, {}, C={}, b={:.4})",
            self.n_sv(),
            self.sv.cols(),
            self.kernel.label(),
            self.c,
            self.bias
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_function_hand_computed() {
        // two SVs on a line with linear kernel: f(t) = 1·(1·t) − 0.5·(2·t) + 0.25
        let sv = Mat::from_vec(2, 1, vec![1.0, 2.0]);
        let m = SvmModel {
            sv,
            alpha_y: vec![1.0, -0.5],
            bias: 0.25,
            kernel: Kernel::Linear,
            c: 1.0,
        };
        let f = m.decision_one(&[3.0]);
        // 1*3 − 0.5*6 + 0.25 = 0.25
        assert!((f - 0.25).abs() < 1e-14);
        assert_eq!(m.predict_one(&[3.0]), 1.0);
        assert_eq!(m.n_sv(), 2);
        assert!(m.memory_bytes() > 0);
    }
}
