//! The staged training pipeline of Algorithm 3.
//!
//! The stages are deliberately separate API calls because the paper's
//! efficiency claim is exactly about their reuse structure:
//!
//! 1. [`HssSvmTrainer::compress`]   — once per (dataset, h)       [line 1]
//! 2. [`HssSvmTrainer::factor`]     — once per (h, β)             [lines 2–6]
//! 3. [`HssSvmTrainer::train_c`]    — once per C (10 iterations)  [lines 7–17]
//!
//! The grid search over C repeats only stage 3, whose cost is negligible
//! (Tables 4/5: ADMM Time ≪ Compression Time).

use crate::admm::{AdmmHistory, AdmmOutput, AdmmParams, AdmmSolver};
use crate::compute::{self, ComputeBackend};
use crate::data::Dataset;
use crate::hss::compress::{compress, Compressed};
use crate::hss::ulv::UlvFactor;
use crate::hss::HssParams;
use crate::kernel::Kernel;
use crate::obs;
use crate::svm::model::SvmModel;
use crate::util::timer::{PhaseTimer, Timer};
use anyhow::Result;

/// Stage-1 state: compressed kernel + tree-ordered training data.
pub struct HssSvmTrainer {
    pub kernel: Kernel,
    pub compressed: Compressed,
    /// Labels in tree order.
    pub y: Vec<f64>,
    /// Worker threads shared by every downstream stage (ULV
    /// factorization, batched ADMM updates, bias matvec). All of them
    /// are thread-invariant: results are bit-for-bit identical for any
    /// value here.
    pub threads: usize,
    /// Compute backend for the hot primitives (kernel blocks during any
    /// recompression, matvec probes, model-assembly matvecs). Defaults
    /// to the bitwise CPU reference; the consensus/sharded trainer
    /// inherits whatever is set here.
    pub backend: std::sync::Arc<dyn ComputeBackend>,
}

/// Per-run timing/size report (one row of Table 4/5).
#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    pub compress_secs: f64,
    pub factor_secs: f64,
    pub admm_secs: f64,
    pub hss_memory_bytes: usize,
    pub hss_max_rank: usize,
    pub kernel_evals: usize,
    pub n_sv: usize,
    /// `(phase, secs, count)` rows in pipeline order —
    /// `PhaseTimer::report()` shape, feeds `report.json`.
    pub phases: Vec<(String, f64, u64)>,
    /// ADMM convergence summary of the trained column.
    pub history: AdmmHistory,
    /// Per-iteration residual curves of the trained column.
    pub primal: Vec<f64>,
    pub dual: Vec<f64>,
}

impl HssSvmTrainer {
    /// Stage 1: build the HSS approximation of K(train, train).
    pub fn compress(ds: &Dataset, kernel: Kernel, params: &HssParams, threads: usize) -> Self {
        let compressed = compress(ds, &kernel, params, threads);
        let y = compressed.pds.y.clone();
        HssSvmTrainer {
            kernel,
            compressed,
            y,
            threads: threads.max(1),
            backend: compute::cpu_arc(),
        }
    }

    /// Stage 1 on an explicit backend: the compression's kernel blocks
    /// AND all downstream stages run through `backend`.
    pub fn compress_backend(
        backend: std::sync::Arc<dyn ComputeBackend>,
        ds: &Dataset,
        kernel: Kernel,
        params: &HssParams,
        threads: usize,
    ) -> Self {
        let compressed =
            crate::hss::compress::compress_with(&*backend, ds, &kernel, params, threads);
        let y = compressed.pds.y.clone();
        HssSvmTrainer { kernel, compressed, y, threads: threads.max(1), backend }
    }

    /// Swap the compute backend for the downstream stages (builder
    /// style). The default is the bitwise CPU reference.
    pub fn with_backend(mut self, backend: std::sync::Arc<dyn ComputeBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// Stage 1 with cached h-independent preprocessing (cluster tree +
    /// ANN) — the grid-over-h hot path.
    pub fn compress_preprocessed(
        pre: &crate::hss::compress::Preprocessed,
        kernel: Kernel,
        params: &HssParams,
        threads: usize,
    ) -> Self {
        let compressed = crate::hss::compress::compress_preprocessed(pre, &kernel, params, threads);
        let y = compressed.pds.y.clone();
        HssSvmTrainer {
            kernel,
            compressed,
            y,
            threads: threads.max(1),
            backend: compute::cpu_arc(),
        }
    }

    /// Stage 2: ULV-factor K̃ + βI (level-parallel over the trainer's
    /// worker pool; the factor reuses the same pool for its solves).
    pub fn factor(&self, beta: f64) -> Result<UlvFactor> {
        let t = Timer::start();
        let ulv = UlvFactor::new_threaded(&self.compressed.hss, beta, self.threads)?;
        if obs::enabled() {
            obs::emit(&obs::TraceEvent::UlvFactor { n: self.y.len(), beta, secs: t.secs() });
        }
        Ok(ulv)
    }

    /// Stage 3: run ADMM for one C and assemble the model
    /// (bias via one HSS matvec — eq. (7) / line 17).
    pub fn train_c(
        &self,
        ulv: &UlvFactor,
        admm: &AdmmParams,
        c: f64,
    ) -> (SvmModel, AdmmOutput) {
        let solver = AdmmSolver::new(ulv, &self.y, *admm).with_threads(self.threads);
        let out = solver.run(c);
        let model = self.assemble_model(&out.z, c);
        (model, out)
    }

    /// Stage 3 with a prebuilt [`AdmmSolver`] (grid search reuses the
    /// precomputed w, w₁ across all C values).
    pub fn train_c_with_solver(
        &self,
        solver: &AdmmSolver<'_, UlvFactor>,
        c: f64,
    ) -> (SvmModel, AdmmOutput) {
        let out = solver.run(c);
        let model = self.assemble_model(&out.z, c);
        (model, out)
    }

    /// Stage 3, batched: advance the whole C-grid in lockstep through
    /// [`AdmmSolver::run_grid`] — one blocked multi-RHS ULV sweep per
    /// iteration instead of one scalar solve per (C, iteration) — and
    /// assemble one model per C. Results match `train_c_with_solver`
    /// column-for-column (bit-for-bit at `relax = 1`).
    pub fn train_grid_with_solver(
        &self,
        solver: &AdmmSolver<'_, UlvFactor>,
        cs: &[f64],
    ) -> Vec<(SvmModel, AdmmOutput)> {
        solver
            .run_grid(cs)
            .into_iter()
            .zip(cs.iter())
            .map(|(out, &c)| {
                let model = self.assemble_model(&out.z, c);
                (model, out)
            })
            .collect()
    }

    /// Stage 3, batched with per-column warm starts: the multilevel
    /// trainer's refinement step. `warms` follows the
    /// [`AdmmSolver::run_grid_warm`] contract (empty = all cold, else
    /// one `Option<(z0, μ0)>` per C). Cold columns are bit-for-bit
    /// `train_grid_with_solver`'s.
    pub fn train_grid_warm(
        &self,
        solver: &AdmmSolver<'_, UlvFactor>,
        cs: &[f64],
        warms: &[Option<(&[f64], &[f64])>],
    ) -> Vec<(SvmModel, AdmmOutput)> {
        solver
            .run_grid_warm(cs, warms)
            .into_iter()
            .zip(cs.iter())
            .map(|(out, &c)| {
                let model = self.assemble_model(&out.z, c);
                (model, out)
            })
            .collect()
    }

    /// Build the model from the final z (tree order): bias from margin
    /// support vectors through the HSS matvec, SVs = nonzero z.
    pub fn assemble_model(&self, z: &[f64], c: f64) -> SvmModel {
        let n = z.len();
        let y = &self.y;
        let hss = &self.compressed.hss;
        let sv_tol = 1e-8 * c.max(1.0);
        let margin_lo = 1e-6 * c;
        let margin_hi = c * (1.0 - 1e-6);

        // z_y and the margin indicator ē (Algorithm 3, lines 15–16)
        // small problems: one O(n·r) matvec is cheaper than spawning the
        // worker pools (same 8k threshold as UlvFactor::solve_mat)
        let mv_threads = if n >= 8192 { self.threads } else { 1 };
        let zy: Vec<f64> = z.iter().zip(y.iter()).map(|(zi, yi)| zi * yi).collect();
        let ebar: Vec<f64> = z
            .iter()
            .map(|&zi| if zi > margin_lo && zi < margin_hi { 1.0 } else { 0.0 })
            .collect();
        let m_count = ebar.iter().sum::<f64>();

        // bias: b = (Σ_{j∈M} y_j − z_yᵀ K̃ ē) / |M|   (line 17, written in
        // the KKT-consistent orientation: averaging b = y_j − f(x_j) over
        // the margin SVs; the paper's eq. (2) prints the negation — see
        // the note in `crate::svm`. Guarded by the regression test
        // `hss_bias_matches_dense_margin_bias` below.)
        let bias = if m_count > 0.0 {
            let ke = self.backend.hss_matvec(hss, &ebar, mv_threads);
            let zky: f64 = zy.iter().zip(ke.iter()).map(|(a, b)| a * b).sum();
            let ysum: f64 =
                y.iter().zip(ebar.iter()).map(|(yi, ei)| yi * ei).sum();
            -(zky - ysum) / m_count
        } else {
            // no margin SVs (all at bounds): average y − f over the SVs
            let f = self.backend.hss_matvec(hss, &zy, mv_threads);
            let mut acc = 0.0;
            let mut cnt = 0.0;
            for i in 0..n {
                if z[i] > sv_tol {
                    acc += y[i] - f[i];
                    cnt += 1.0;
                }
            }
            if cnt > 0.0 {
                acc / cnt
            } else {
                0.0
            }
        };

        // support vectors = nonzero z (tree order rows of pds)
        let sv_idx: Vec<usize> = (0..n).filter(|&i| z[i] > sv_tol).collect();
        let sv = self.compressed.pds.x.select_rows(&sv_idx);
        let alpha_y: Vec<f64> = sv_idx.iter().map(|&i| zy[i]).collect();

        SvmModel {
            sv,
            alpha_y,
            bias,
            kernel: self.kernel,
            c,
            labels: self.compressed.pds.labels,
        }
    }
}

/// One-call convenience: full pipeline for a single (h, β, C).
pub fn train_hss_svm(
    ds: &Dataset,
    kernel: Kernel,
    hss_params: &HssParams,
    admm_params: &AdmmParams,
    c: f64,
    threads: usize,
) -> Result<(SvmModel, TrainStats)> {
    let pt = PhaseTimer::new();
    let trainer =
        pt.record_val("compression", || HssSvmTrainer::compress(ds, kernel, hss_params, threads));
    let ulv = pt.record_val("factorization", || trainer.factor(admm_params.beta))?;
    let (model, out) = pt.record_val("admm", || trainer.train_c(&ulv, admm_params, c));

    let stats = TrainStats {
        compress_secs: pt.secs("compression"),
        factor_secs: pt.secs("factorization"),
        admm_secs: pt.secs("admm"),
        hss_memory_bytes: trainer.compressed.stats.memory_bytes,
        hss_max_rank: trainer.compressed.stats.max_rank,
        kernel_evals: trainer.compressed.stats.kernel_evals,
        n_sv: model.n_sv(),
        phases: pt.report(),
        history: out.history(),
        primal: out.primal,
        dual: out.dual,
    };
    Ok((model, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::svm::predict;
    use crate::util::prng::Rng;

    #[test]
    fn trains_moons_to_high_accuracy() {
        let mut rng = Rng::new(61);
        let train = synth::two_moons(400, 0.08, &mut rng);
        let test = synth::two_moons(200, 0.08, &mut rng);
        let kernel = Kernel::Gaussian { h: 0.3 };
        let mut hp = HssParams::near_exact();
        hp.leaf_size = 64;
        let (model, stats) = train_hss_svm(
            &train,
            kernel,
            &hp,
            &AdmmParams { beta: 10.0, max_it: 30, relax: 1.0, tol: 0.0 },
            10.0,
            2,
        )
        .unwrap();
        let acc = predict::accuracy(&model, &test, 2);
        assert!(acc > 0.95, "moons accuracy {acc}");
        assert!(stats.n_sv > 0);
        assert!(stats.compress_secs >= 0.0);
    }

    #[test]
    fn staged_api_reuses_compression_across_c() {
        let mut rng = Rng::new(62);
        let train = synth::circles(300, 0.05, &mut rng);
        let test = synth::circles(150, 0.05, &mut rng);
        let kernel = Kernel::Gaussian { h: 0.4 };
        let trainer =
            HssSvmTrainer::compress(&train, kernel, &HssParams::near_exact(), 2);
        let beta = 10.0;
        let ulv = trainer.factor(beta).unwrap();
        let ap = AdmmParams { beta, max_it: 20, relax: 1.0, tol: 0.0 };
        let solver = AdmmSolver::new(&ulv, &trainer.y, ap);
        for c in [0.1, 1.0, 10.0] {
            let (model, out) = trainer.train_c_with_solver(&solver, c);
            assert!(out.z.iter().all(|&v| v <= c + 1e-12));
            let acc = predict::accuracy(&model, &test, 1);
            assert!(acc > 0.85, "circles accuracy at C={c}: {acc}");
        }
    }

    #[test]
    fn paper_iteration_budget_is_enough_on_loose_compression() {
        // MaxIt = 10 and the Table-4 (low accuracy) HSS setting must
        // still classify clusterable data decently — the paper's claim.
        // Train and test are disjoint splits of a single draw: the test
        // set used to be generated from a fresh Rng with the same seed
        // as the training set, so it replayed the same stream and
        // partially duplicated training points (train/test leakage).
        // The threshold is re-tuned for a genuinely held-out test set.
        let mut rng = Rng::new(63);
        let ds = synth::blobs(1200, 6, 4, 0.35, &mut rng);
        let (train, test) = ds.split_at(800);
        let kernel = Kernel::Gaussian { h: 1.0 };
        let mut hp = HssParams::low_accuracy();
        hp.leaf_size = 64;
        let (model, _) = train_hss_svm(
            &train,
            kernel,
            &hp,
            &AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 },
            1.0,
            2,
        )
        .unwrap();
        let acc = predict::accuracy(&model, &test, 2);
        assert!(acc > 0.75, "blobs accuracy with loose HSS {acc}");
    }

    #[test]
    fn hss_bias_matches_dense_margin_bias() {
        // Regression guard for the bias sign (Algorithm 3 line 17): the
        // HSS-path bias must equal the pointwise KKT bias computed from
        // margin SVs through the dense kernel, b = avg_j (y_j − f(x_j)).
        // With the sign flipped, the two differ by 2|b|.
        let mut rng = Rng::new(65);
        let train = synth::two_moons(240, 0.08, &mut rng);
        let kernel = Kernel::Gaussian { h: 0.4 };
        let trainer = HssSvmTrainer::compress(&train, kernel, &HssParams::near_exact(), 1);
        let beta = 5.0;
        let c = 5.0;
        let ulv = trainer.factor(beta).unwrap();
        let solver = AdmmSolver::new(
            &ulv,
            &trainer.y,
            AdmmParams { beta, max_it: 2000, relax: 1.0, tol: 0.0 },
        );
        let out = solver.run(c);
        let model = trainer.assemble_model(&out.z, c);

        // dense pointwise bias over the same margin window as
        // assemble_model (tree order throughout)
        let k = kernel.gram(&trainer.compressed.pds.x);
        let y = &trainer.y;
        let n = out.z.len();
        let (lo, hi) = (1e-6 * c, c * (1.0 - 1e-6));
        let mut acc = 0.0;
        let mut cnt = 0usize;
        for j in 0..n {
            if out.z[j] > lo && out.z[j] < hi {
                let mut f = 0.0;
                for i in 0..n {
                    f += y[i] * out.z[i] * k[(i, j)];
                }
                acc += y[j] - f;
                cnt += 1;
            }
        }
        assert!(cnt > 0, "no margin support vectors in the regression setup");
        let b_dense = acc / cnt as f64;
        // a sign flip would show up as |Δ| = 2|b|; 1e-4 leaves room for
        // the near-exact compression's K̃ ≈ K residual only
        assert!(
            (model.bias - b_dense).abs() < 1e-4 * (1.0 + b_dense.abs()),
            "HSS bias {} vs dense margin bias {b_dense}",
            model.bias
        );
        // and the assembled bias must place well-interior margin SVs on
        // the margin: y_j (f_j + b) ≈ 1 (KKT) — this pins the sign even
        // when |b| itself is small
        for j in 0..n {
            if out.z[j] > 1e-2 * c && out.z[j] < c * (1.0 - 1e-2) {
                let mut f = model.bias;
                for i in 0..n {
                    f += y[i] * out.z[i] * k[(i, j)];
                }
                let margin = y[j] * f;
                assert!(
                    (margin - 1.0).abs() < 0.1,
                    "margin SV {j} off the margin with assembled bias: y·f = {margin}"
                );
            }
        }
    }

    #[test]
    fn grid_trainer_matches_sequential_models() {
        let mut rng = Rng::new(66);
        let train = synth::circles(220, 0.05, &mut rng);
        let kernel = Kernel::Gaussian { h: 0.4 };
        let trainer = HssSvmTrainer::compress(&train, kernel, &HssParams::near_exact(), 1);
        let beta = 10.0;
        let ulv = trainer.factor(beta).unwrap();
        let ap = AdmmParams { beta, max_it: 12, relax: 1.0, tol: 0.0 };
        let solver = AdmmSolver::new(&ulv, &trainer.y, ap);
        let cs = [0.1, 1.0, 10.0];
        let batched = trainer.train_grid_with_solver(&solver, &cs);
        assert_eq!(batched.len(), cs.len());
        for ((model, out), &c) in batched.iter().zip(cs.iter()) {
            let (model_seq, out_seq) = trainer.train_c_with_solver(&solver, c);
            assert_eq!(out.z, out_seq.z, "z mismatch at C={c}");
            assert_eq!(model.bias, model_seq.bias, "bias mismatch at C={c}");
            assert_eq!(model.alpha_y, model_seq.alpha_y, "alpha mismatch at C={c}");
        }
    }
}
