//! Blocked prediction (Algorithm 3, lines 18–20).
//!
//! Test points are processed in row tiles; each tile needs one kernel
//! block K(tile, SV) followed by a matvec against αy — exactly the fused
//! "decision tile" the L2 JAX model lowers to HLO. Tiles and support
//! vectors may each be dense or CSR ([`Points`]); the kernel block
//! dispatches per pairing, so sparse test sets never densify. The native
//! path here is the correctness oracle for (and fallback of) the PJRT
//! path in [`crate::runtime`].

use crate::compute::{self, ComputeBackend};
use crate::data::sparse::Points;
use crate::data::Dataset;
use crate::svm::model::SvmModel;
use crate::util::threadpool;

/// Rows per prediction tile (matches the AOT artifact tile height).
pub const TILE: usize = 128;

/// Decision values f(tⱼ) for every row of `x`.
///
/// Routes through [`compute::cpu`], the bitwise reference backend —
/// identical to the pre-backend code path.
pub fn decision_function(model: &SvmModel, x: &Points, threads: usize) -> Vec<f64> {
    decision_function_with(compute::cpu(), model, x, threads)
}

/// [`decision_function`] on an explicit [`ComputeBackend`]: each tile's
/// kernel block + gemv runs on the backend (`decision_tile`), the bias
/// is added here.
pub fn decision_function_with(
    backend: &dyn ComputeBackend,
    model: &SvmModel,
    x: &Points,
    threads: usize,
) -> Vec<f64> {
    assert_eq!(x.cols(), model.sv.cols(), "feature dimension mismatch");
    let n = x.rows();
    let sv_norms = model.sv.self_norms();
    let n_tiles = n.div_ceil(TILE);
    // chunk = 1: each tile is a full kernel-block GEMV, coarse enough
    // that one atomic fetch per tile is noise
    let tiles: Vec<Vec<f64>> = threadpool::parallel_map(threads, n_tiles, 1, |t| {
        let lo = t * TILE;
        let hi = (lo + TILE).min(n);
        let rows: Vec<usize> = (lo..hi).collect();
        let xb = x.select_rows(&rows);
        let xb_norms = xb.self_norms();
        let mut f =
            backend.decision_tile(&model.kernel, &xb, &xb_norms, &model.sv, &sv_norms, &model.alpha_y);
        for v in &mut f {
            *v += model.bias;
        }
        f
    });
    tiles.concat()
}

/// Predicted labels, mapped back through the model's original label
/// pair (±1 unless the training data used another encoding).
pub fn predict(model: &SvmModel, x: &Points, threads: usize) -> Vec<f64> {
    predict_with(compute::cpu(), model, x, threads)
}

/// [`predict`] on an explicit [`ComputeBackend`].
pub fn predict_with(
    backend: &dyn ComputeBackend,
    model: &SvmModel,
    x: &Points,
    threads: usize,
) -> Vec<f64> {
    decision_function_with(backend, model, x, threads)
        .into_iter()
        .map(|f| model.label_of(f))
        .collect()
}

/// Classification accuracy on a labelled dataset. Compares decision
/// signs against the dataset's ±1 labels, so it is independent of the
/// model's output label pair.
pub fn accuracy(model: &SvmModel, ds: &Dataset, threads: usize) -> f64 {
    if ds.is_empty() {
        return 1.0;
    }
    let f = decision_function(model, &ds.x, threads);
    let hits = f.iter().zip(ds.y.iter()).filter(|(f, y)| (**f >= 0.0) == (**y > 0.0)).count();
    hits as f64 / ds.len() as f64
}

/// Confusion counts (tp, fp, tn, fn), by decision sign vs ±1 labels.
pub fn confusion(model: &SvmModel, ds: &Dataset, threads: usize) -> (usize, usize, usize, usize) {
    let f = decision_function(model, &ds.x, threads);
    let (mut tp, mut fp, mut tn, mut fneg) = (0, 0, 0, 0);
    for (fi, &y) in f.iter().zip(ds.y.iter()) {
        match (*fi >= 0.0, y > 0.0) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, false) => tn += 1,
            (false, true) => fneg += 1,
        }
    }
    (tp, fp, tn, fneg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::CsrMat;
    use crate::kernel::Kernel;
    use crate::linalg::Mat;
    use crate::util::prng::Rng;
    use crate::util::testkit;

    fn toy_model(rng: &mut Rng, n_sv: usize, dim: usize) -> SvmModel {
        SvmModel {
            sv: Mat::gauss(n_sv, dim, rng).into(),
            alpha_y: (0..n_sv).map(|_| rng.gauss()).collect(),
            bias: rng.gauss(),
            kernel: Kernel::Gaussian { h: 0.9 },
            c: 1.0,
            labels: crate::data::DEFAULT_LABEL_PAIR,
        }
    }

    #[test]
    fn blocked_decision_matches_pointwise() {
        let mut rng = Rng::new(71);
        let model = toy_model(&mut rng, 37, 5);
        // n crosses several tile boundaries
        let xm = Mat::gauss(TILE * 2 + 17, 5, &mut rng);
        let x = Points::Dense(xm.clone());
        let got = decision_function(&model, &x, 3);
        for i in 0..xm.rows() {
            let want = model.decision_one(xm.row(i));
            testkit::assert_close(got[i], want, 1e-10);
        }
    }

    #[test]
    fn accuracy_and_confusion_consistent() {
        let mut rng = Rng::new(72);
        let model = toy_model(&mut rng, 20, 3);
        let ds = crate::data::synth::blobs(130, 3, 3, 0.4, &mut rng);
        let acc = accuracy(&model, &ds, 2);
        let (tp, fp, tn, fneg) = confusion(&model, &ds, 2);
        assert_eq!(tp + fp + tn + fneg, 130);
        testkit::assert_close(acc, (tp + tn) as f64 / 130.0, 1e-12);
    }

    #[test]
    fn predict_labels_are_signs() {
        let mut rng = Rng::new(73);
        let model = toy_model(&mut rng, 10, 2);
        let x = Points::Dense(Mat::gauss(50, 2, &mut rng));
        let f = decision_function(&model, &x, 1);
        let p = predict(&model, &x, 1);
        for i in 0..50 {
            assert_eq!(p[i], if f[i] >= 0.0 { 1.0 } else { -1.0 });
        }
    }

    #[test]
    fn nondefault_label_pair_keeps_accuracy_and_maps_predictions() {
        let mut rng = Rng::new(75);
        let base = toy_model(&mut rng, 12, 3);
        let remapped = SvmModel { labels: [1.0, 2.0], ..base.clone() };
        let ds = crate::data::synth::blobs(90, 3, 3, 0.4, &mut rng);
        // accuracy/confusion are label-pair independent (decision signs)
        assert_eq!(accuracy(&base, &ds, 1), accuracy(&remapped, &ds, 1));
        assert_eq!(confusion(&base, &ds, 1), confusion(&remapped, &ds, 1));
        // predictions answer in the original encoding
        let f = decision_function(&remapped, &ds.x, 1);
        let p = predict(&remapped, &ds.x, 1);
        for i in 0..ds.len() {
            assert_eq!(p[i], if f[i] >= 0.0 { 2.0 } else { 1.0 });
        }
    }

    #[test]
    fn sparse_tiles_and_sparse_svs_agree_with_dense() {
        // every (test, SV) representation pairing must agree to ≤1e-12
        let mut rng = Rng::new(74);
        let dense_model = toy_model(&mut rng, 23, 9);
        let sparse_model = SvmModel {
            sv: CsrMat::from_dense(dense_model.sv.dense()).into(),
            ..dense_model.clone()
        };
        let xm = Mat::from_fn(TILE + 31, 9, |i, j| {
            if (i + j) % 3 == 0 { rng.gauss() } else { 0.0 }
        });
        let xd = Points::Dense(xm.clone());
        let xs = Points::Sparse(CsrMat::from_dense(&xm));
        let want = decision_function(&dense_model, &xd, 2);
        for (m, x) in [
            (&dense_model, &xs),
            (&sparse_model, &xd),
            (&sparse_model, &xs),
        ] {
            let got = decision_function(m, x, 2);
            testkit::assert_allclose(&got, &want, 1e-12);
        }
    }
}
