//! Command-line argument parsing (no clap in the offline crate set).
//!
//! Grammar: `hss-svm <subcommand> [--flag value]... [--switch]...`

// No raw-pointer tricks belong in this module tree (see DESIGN.md §11).
#![forbid(unsafe_code)]

use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = HashMap::new();
        let mut switches = Vec::new();
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected positional argument {a:?} (flags are --name value)");
            };
            // `--flag=value` or `--flag value` or bare switch
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                flags.insert(name.to_string(), it.next().unwrap());
            } else {
                switches.push(name.to_string());
            }
        }
        Ok(Args { command, flags, switches })
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} expects a number, got {v:?}")),
            None => Ok(default),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} expects an integer, got {v:?}")),
            None => Ok(default),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    /// Comma-separated list flag: `--h 0.1,1,10`.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.flags.get(name) {
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<f64>()
                        .with_context(|| format!("--{name}: bad number {p:?}"))
                })
                .collect(),
            None => Ok(default.to_vec()),
        }
    }

    pub fn str_list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.flags.get(name) {
            Some(v) => v.split(',').map(|p| p.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = parse(&["train", "--dataset", "ijcnn1", "--scale=0.1", "--verbose", "--c", "1.5"]);
        assert_eq!(a.command, "train");
        assert_eq!(a.str_or("dataset", "?"), "ijcnn1");
        assert_eq!(a.f64_or("scale", 0.0).unwrap(), 0.1);
        assert_eq!(a.f64_or("c", 0.0).unwrap(), 1.5);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse(&["grid", "--h", "0.1, 1,10"]);
        assert_eq!(a.f64_list_or("h", &[]).unwrap(), vec![0.1, 1.0, 10.0]);
        assert_eq!(a.f64_list_or("c", &[5.0]).unwrap(), vec![5.0]);
        assert_eq!(a.str_list_or("datasets", &["all"]), vec!["all"]);
    }

    #[test]
    fn rejects_positionals_and_bad_numbers() {
        assert!(Args::parse(["train".to_string(), "oops".to_string()]).is_err());
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.usize_or("n", 1).is_err());
    }
}
