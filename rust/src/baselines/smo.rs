//! LIBSVM-style SMO solver (the paper's Table 2 baseline).
//!
//! Faithful reimplementation of the C-SVC path of LIBSVM 3.x:
//! * second-order working-set selection (WSS 2 of Fan, Chen & Lin 2005),
//! * gradient maintenance with two kernel rows per iteration,
//! * an LRU kernel-row cache (LIBSVM's `Cache`),
//! * optional shrinking of bound-clamped variables,
//! * stopping rule m(α) − M(α) ≤ ε with ε = 1e-3 (LIBSVM default).
//!
//! This exists so Table 2 can be regenerated end-to-end: the method is
//! exact (true kernel) but touches O(d) kernel entries per iteration and
//! needs many iterations on large/difficult data — the slowness the paper
//! measures is a property of the algorithm, reproduced here.

use crate::compute::ComputeBackend;
use crate::data::Dataset;
use crate::kernel::Kernel;
use crate::svm::SvmModel;
use std::collections::HashMap;

/// SMO parameters (LIBSVM defaults).
#[derive(Clone, Copy, Debug)]
pub struct SmoParams {
    /// Stopping tolerance ε on the max KKT violation.
    pub eps: f64,
    /// Kernel cache budget in bytes (LIBSVM `-m`, default 100 MB).
    pub cache_bytes: usize,
    /// Hard iteration cap (safety; LIBSVM uses 10⁷-ish implicit caps).
    pub max_iter: usize,
    /// Enable shrinking heuristics.
    pub shrinking: bool,
}

impl Default for SmoParams {
    fn default() -> Self {
        SmoParams { eps: 1e-3, cache_bytes: 100 << 20, max_iter: 10_000_000, shrinking: true }
    }
}

/// Solver report.
#[derive(Clone, Debug, Default)]
pub struct SmoStats {
    pub iterations: usize,
    pub kernel_rows_computed: usize,
    pub cache_hits: usize,
    pub final_violation: f64,
    pub n_sv: usize,
}

/// LRU cache of kernel rows.
struct RowCache {
    rows: HashMap<usize, (Vec<f64>, u64)>,
    clock: u64,
    capacity_rows: usize,
    hits: usize,
    misses: usize,
}

impl RowCache {
    fn new(n: usize, budget_bytes: usize) -> Self {
        let row_bytes = n * std::mem::size_of::<f64>();
        let capacity_rows = (budget_bytes / row_bytes.max(1)).clamp(2, n.max(2));
        RowCache { rows: HashMap::new(), clock: 0, capacity_rows, hits: 0, misses: 0 }
    }

    fn get_or_compute(&mut self, i: usize, compute: impl FnOnce() -> Vec<f64>) -> &[f64] {
        self.clock += 1;
        let clock = self.clock;
        if self.rows.contains_key(&i) {
            self.hits += 1;
            let e = self.rows.get_mut(&i).unwrap();
            e.1 = clock;
            return &self.rows[&i].0;
        }
        self.misses += 1;
        if self.rows.len() >= self.capacity_rows {
            // evict least-recently-used
            let (&lru, _) = self.rows.iter().min_by_key(|(_, (_, t))| *t).unwrap();
            self.rows.remove(&lru);
        }
        self.rows.insert(i, (compute(), clock));
        &self.rows[&i].0
    }
}

/// Train a C-SVC with SMO. Returns the model and stats.
pub fn train_smo(
    ds: &Dataset,
    kernel: Kernel,
    c: f64,
    params: &SmoParams,
) -> (SvmModel, SmoStats) {
    train_smo_with(crate::compute::cpu(), ds, kernel, c, params)
}

/// [`train_smo`] on an explicit [`ComputeBackend`]: the per-iteration
/// kernel rows (the solver's only kernel work) run on the backend.
pub fn train_smo_with(
    backend: &dyn ComputeBackend,
    ds: &Dataset,
    kernel: Kernel,
    c: f64,
    params: &SmoParams,
) -> (SvmModel, SmoStats) {
    let n = ds.len();
    let y = &ds.y;
    let norms = ds.x.self_norms();
    // exact kernel diagonal (Gaussian: all ones, but stay kernel-generic);
    // eval_from_parts(n, n, n) equals eval(x, x) bit-for-bit: the distance
    // term cancels to 0 and the inner-product term is the stored norm
    let diag: Vec<f64> = (0..n).map(|i| kernel.eval_from_parts(norms[i], norms[i], norms[i])).collect();
    let mut cache = RowCache::new(n, params.cache_bytes);
    let compute_row = |i: usize, norms: &[f64], out: &mut Vec<f64>| {
        out.resize(n, 0.0);
        backend.kernel_row(&kernel, &ds.x, i, norms[i], &ds.x, norms, out);
    };

    let mut alpha = vec![0.0f64; n];
    // gradient of the dual: G_i = Σ_j y_i y_j K_ij α_j − 1 (starts at −1)
    let mut grad = vec![-1.0f64; n];
    // active set for shrinking
    let mut active: Vec<usize> = (0..n).collect();
    let mut shrink_counter = 0usize;
    let mut unshrunk = false;

    let is_up = |i: usize, alpha: &[f64]| {
        (y[i] > 0.0 && alpha[i] < c) || (y[i] < 0.0 && alpha[i] > 0.0)
    };
    let is_low = |i: usize, alpha: &[f64]| {
        (y[i] > 0.0 && alpha[i] > 0.0) || (y[i] < 0.0 && alpha[i] < c)
    };

    let mut iters = 0usize;
    let mut violation = f64::INFINITY;
    let tau = 1e-12;

    loop {
        if iters >= params.max_iter {
            break;
        }
        // --- working-set selection (second order, Fan-Chen-Lin) ---
        // i: max over I_up of −y_i G_i
        let mut gmax = f64::NEG_INFINITY;
        let mut i_sel = usize::MAX;
        for &i in &active {
            if is_up(i, &alpha) {
                let v = -y[i] * grad[i];
                if v > gmax {
                    gmax = v;
                    i_sel = i;
                }
            }
        }
        if i_sel == usize::MAX {
            break;
        }
        // kernel row for i
        let ki: Vec<f64> = {
            let row = cache.get_or_compute(i_sel, || {
                let mut v = Vec::new();
                compute_row(i_sel, &norms, &mut v);
                v
            });
            row.to_vec()
        };
        // j: best second-order gain among I_low with −y_j G_j < gmax
        let mut gmin = f64::INFINITY;
        let mut best_gain = f64::NEG_INFINITY;
        let mut j_sel = usize::MAX;
        for &j in &active {
            if is_low(j, &alpha) {
                let v = -y[j] * grad[j];
                if v < gmin {
                    gmin = v;
                }
                let b = gmax + y[j] * grad[j]; // gmax − (−y_j G_j)
                if b > 0.0 {
                    let a = diag[i_sel] + diag[j] - 2.0 * ki[j];
                    let a = if a > tau { a } else { tau };
                    let gain = b * b / a;
                    if gain > best_gain {
                        best_gain = gain;
                        j_sel = j;
                    }
                }
            }
        }
        violation = gmax - gmin;
        if violation <= params.eps {
            if params.shrinking && active.len() < n && !unshrunk {
                // reactivate everything, recheck optimality over full set
                active = (0..n).collect();
                reconstruct_gradient(&mut grad, &alpha, y, &mut cache, &compute_row, &norms, n);
                unshrunk = true;
                continue;
            }
            break;
        }
        unshrunk = false;
        if j_sel == usize::MAX {
            break;
        }

        // --- analytic pair update (LIBSVM solve for (i, j)) ---
        let kj: Vec<f64> = {
            let row = cache.get_or_compute(j_sel, || {
                let mut v = Vec::new();
                compute_row(j_sel, &norms, &mut v);
                v
            });
            row.to_vec()
        };
        let (i, j) = (i_sel, j_sel);
        let a = {
            let aij = diag[i] + diag[j] - 2.0 * ki[j];
            if aij > tau {
                aij
            } else {
                tau
            }
        };
        let b = -y[i] * grad[i] + y[j] * grad[j];
        let old_ai = alpha[i];
        let old_aj = alpha[j];
        // update in the yα coordinates
        let delta = b / a;
        // clip to the box
        let mut new_ai = old_ai + y[i] * delta;
        #[allow(unused_assignments)]
        let mut new_aj = old_aj - y[j] * delta;
        // joint feasibility: keep y_i α_i + y_j α_j constant
        let sum = y[i] * old_ai + y[j] * old_aj;
        new_ai = new_ai.clamp(0.0, c);
        new_aj = y[j] * (sum - y[i] * new_ai);
        new_aj = new_aj.clamp(0.0, c);
        new_ai = y[i] * (sum - y[j] * new_aj);
        new_ai = new_ai.clamp(0.0, c);
        let dai = new_ai - old_ai;
        let daj = new_aj - old_aj;
        alpha[i] = new_ai;
        alpha[j] = new_aj;

        // --- gradient update: G += Q_:,i Δα_i + Q_:,j Δα_j ---
        if dai != 0.0 || daj != 0.0 {
            for &t in &active {
                grad[t] += y[t] * (y[i] * ki[t] * dai + y[j] * kj[t] * daj);
            }
        }

        iters += 1;

        // --- shrinking every n iterations (LIBSVM: min(n,1000)) ---
        shrink_counter += 1;
        if params.shrinking && shrink_counter >= n.min(1000) {
            shrink_counter = 0;
            let thresh_up = gmax;
            let thresh_low = gmin;
            active.retain(|&t| {
                let shrinkable = if alpha[t] <= 0.0 + 1e-12 {
                    // at lower bound: shrink if it cannot improve
                    (y[t] > 0.0 && -y[t] * grad[t] < thresh_low)
                        || (y[t] < 0.0 && -y[t] * grad[t] > thresh_up)
                } else if alpha[t] >= c - 1e-12 {
                    (y[t] > 0.0 && -y[t] * grad[t] > thresh_up)
                        || (y[t] < 0.0 && -y[t] * grad[t] < thresh_low)
                } else {
                    false
                };
                !shrinkable
            });
            if active.len() < 2 {
                active = (0..n).collect();
            }
        }
    }

    // --- bias from free SVs (LIBSVM rho with flipped sign) ---
    let mut b_acc = 0.0;
    let mut b_cnt = 0usize;
    let mut lb = f64::NEG_INFINITY;
    let mut ub = f64::INFINITY;
    for i in 0..n {
        let yg = y[i] * grad[i];
        if alpha[i] > 1e-12 && alpha[i] < c - 1e-12 {
            b_acc += -yg;
            b_cnt += 1;
        } else if (y[i] > 0.0 && alpha[i] <= 1e-12) || (y[i] < 0.0 && alpha[i] >= c - 1e-12) {
            // rho upper-bound set ⇒ lower bound on b = −rho
            lb = lb.max(-yg);
        } else {
            ub = ub.min(-yg);
        }
    }
    let bias = if b_cnt > 0 {
        b_acc / b_cnt as f64
    } else {
        (lb + ub) / 2.0
    };

    // assemble model
    let sv_idx: Vec<usize> = (0..n).filter(|&i| alpha[i] > 1e-12).collect();
    let sv = ds.x.select_rows(&sv_idx);
    let alpha_y: Vec<f64> = sv_idx.iter().map(|&i| alpha[i] * y[i]).collect();
    let model = SvmModel { sv, alpha_y, bias, kernel, c, labels: ds.labels };
    let stats = SmoStats {
        iterations: iters,
        kernel_rows_computed: cache.misses,
        cache_hits: cache.hits,
        final_violation: violation,
        n_sv: model.n_sv(),
    };
    (model, stats)
}

#[allow(clippy::too_many_arguments)]
fn reconstruct_gradient(
    grad: &mut [f64],
    alpha: &[f64],
    y: &[f64],
    cache: &mut RowCache,
    compute_row: &impl Fn(usize, &[f64], &mut Vec<f64>),
    norms: &[f64],
    n: usize,
) {
    for g in grad.iter_mut() {
        *g = -1.0;
    }
    for i in 0..n {
        if alpha[i] > 0.0 {
            let row = cache
                .get_or_compute(i, || {
                    let mut v = Vec::new();
                    compute_row(i, norms, &mut v);
                    v
                })
                .to_vec();
            for t in 0..n {
                grad[t] += y[t] * y[i] * row[t] * alpha[i];
            }
        }
    }
}

/// Decision check used in tests (any SV representation).
pub fn dual_objective(ds: &Dataset, kernel: &Kernel, alpha_y: &[f64], sv: &crate::data::Points) -> f64 {
    // ½ Σ_ij (αy)_i (αy)_j K_ij − Σ_i α_i ; α_i = |αy_i|
    let k = crate::kernel::kernel_block_pts(kernel, sv, sv);
    let mut quad = 0.0;
    for i in 0..sv.rows() {
        for j in 0..sv.rows() {
            quad += alpha_y[i] * alpha_y[j] * k[(i, j)];
        }
    }
    let lin: f64 = alpha_y.iter().map(|a| a.abs()).sum();
    let _ = ds;
    0.5 * quad - lin
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::svm::predict;
    use crate::util::prng::Rng;

    #[test]
    fn separable_blobs_reach_full_accuracy() {
        let mut rng = Rng::new(81);
        let train = synth::blobs(300, 2, 2, 0.05, &mut rng);
        let test = synth::blobs(150, 2, 2, 0.05, &mut {
            let mut r = Rng::new(81);
            r
        });
        let (model, stats) = train_smo(&train, Kernel::Gaussian { h: 1.0 }, 10.0, &SmoParams::default());
        assert!(stats.final_violation <= 1e-3 || stats.iterations > 0);
        let acc = predict::accuracy(&model, &test, 1);
        assert!(acc > 0.97, "separable accuracy {acc}");
    }

    #[test]
    fn moons_nonlinear_boundary() {
        let mut rng = Rng::new(82);
        let train = synth::two_moons(400, 0.08, &mut rng);
        let test = synth::two_moons(200, 0.08, &mut rng);
        let (model, _) = train_smo(&train, Kernel::Gaussian { h: 0.3 }, 10.0, &SmoParams::default());
        let acc = predict::accuracy(&model, &test, 1);
        assert!(acc > 0.95, "moons accuracy {acc}");
    }

    #[test]
    fn kkt_conditions_hold_at_solution() {
        let mut rng = Rng::new(83);
        let train = synth::circles(200, 0.04, &mut rng);
        let c = 5.0;
        let kernel = Kernel::Gaussian { h: 0.5 };
        let (model, _) = train_smo(&train, kernel, c, &SmoParams::default());
        // margin SVs must have y f ≈ 1
        let f = predict::decision_function(&model, &train.x, 1);
        // recover alphas: margin SVs are those with 0 < |αy| < C
        // we can't see α directly from the model per-point, so check the
        // weaker dual feasibility: all training points correctly scored
        // within KKT slack: y f >= 1 - eps for non-SVs is not recoverable;
        // instead check training accuracy is near-perfect for circles
        let acc = train
            .y
            .iter()
            .zip(f.iter())
            .filter(|(y, f)| (**y > 0.0) == (**f >= 0.0))
            .count() as f64
            / train.len() as f64;
        assert!(acc > 0.97, "training accuracy {acc}");
    }

    #[test]
    fn tiny_cache_still_converges() {
        let mut rng = Rng::new(84);
        let train = synth::two_moons(150, 0.06, &mut rng);
        let params = SmoParams { cache_bytes: 4096, ..Default::default() }; // ~3 rows
        let (model, stats) = train_smo(&train, Kernel::Gaussian { h: 0.3 }, 5.0, &params);
        assert!(stats.kernel_rows_computed > 0);
        let acc = predict::accuracy(&model, &train, 1);
        assert!(acc > 0.95, "tiny-cache accuracy {acc}");
    }

    #[test]
    fn shrinking_matches_no_shrinking() {
        let mut rng = Rng::new(85);
        let train = synth::blobs(250, 3, 4, 0.3, &mut rng);
        let k = Kernel::Gaussian { h: 1.0 };
        let (m1, _) = train_smo(&train, k, 1.0, &SmoParams { shrinking: true, ..Default::default() });
        let (m2, _) = train_smo(&train, k, 1.0, &SmoParams { shrinking: false, ..Default::default() });
        // same objective value within tolerance
        let o1 = dual_objective(&train, &k, &m1.alpha_y, &m1.sv);
        let o2 = dual_objective(&train, &k, &m2.alpha_y, &m2.sv);
        assert!((o1 - o2).abs() < 1e-2 * (1.0 + o1.abs()), "objectives differ: {o1} vs {o2}");
    }
}
