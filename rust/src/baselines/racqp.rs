//! RACQP-style randomized multi-block ADMM baseline (Table 3).
//!
//! Mihić, Zhu & Ye (Math. Prog. Comp. 2020) solve QPs by cyclically
//! updating random *blocks* of variables within an ADMM/ALM loop; the
//! paper benchmarks their SVM mode against the HSS approach. We rebuild
//! the structure they use for problem (1):
//!
//! * auxiliary z carries the box constraint (same splitting as ours),
//! * the equality yᵀx = 0 is enforced by a multiplier + quadratic penalty,
//! * each sweep draws a random permutation of variable blocks and solves
//!   each block's dense subproblem **with the true kernel** (Cholesky of
//!   K_BB + βI + β y_B y_Bᵀ), which costs O(p²·d) kernel work per sweep —
//!   the exact-kernel cost the paper's Table 3 exposes.

use crate::compute::ComputeBackend;
use crate::data::Dataset;
use crate::kernel::Kernel;
use crate::linalg::blas;
use crate::linalg::chol::Chol;
use crate::svm::SvmModel;
use crate::util::prng::Rng;
use anyhow::Result;

/// RACQP parameters.
#[derive(Clone, Copy, Debug)]
pub struct RacqpParams {
    /// Variable block size p.
    pub block_size: usize,
    /// Augmented-Lagrangian penalty β.
    pub beta: f64,
    /// Number of sweeps (each sweep touches every block once).
    pub sweeps: usize,
    /// RNG seed for the block permutations.
    pub seed: u64,
}

impl Default for RacqpParams {
    fn default() -> Self {
        RacqpParams { block_size: 500, beta: 1.0, sweeps: 20, seed: 0xACC }
    }
}

/// Report.
#[derive(Clone, Debug, Default)]
pub struct RacqpStats {
    pub sweeps: usize,
    pub kernel_evals: usize,
    pub primal_residual: f64,
    pub equality_residual: f64,
    pub n_sv: usize,
}

/// Train with randomized multi-block ADMM on the exact kernel.
pub fn train_racqp(
    ds: &Dataset,
    kernel: Kernel,
    c: f64,
    params: &RacqpParams,
) -> Result<(SvmModel, RacqpStats)> {
    train_racqp_with(crate::compute::cpu(), ds, kernel, c, params)
}

/// [`train_racqp`] on an explicit [`ComputeBackend`]: the per-block
/// kernel columns and the bias kernel block run on the backend.
pub fn train_racqp_with(
    backend: &dyn ComputeBackend,
    ds: &Dataset,
    kernel: Kernel,
    c: f64,
    params: &RacqpParams,
) -> Result<(SvmModel, RacqpStats)> {
    let n = ds.len();
    let y = &ds.y;
    let beta = params.beta;
    let p = params.block_size.clamp(8, n);
    let norms = ds.x.self_norms();
    let mut rng = Rng::new(params.seed);
    let mut kernel_evals = 0usize;

    let mut x = vec![0.0f64; n];
    let mut z = vec![0.0f64; n];
    let mut mu = vec![0.0f64; n]; // multiplier for x − z = 0
    let mut lam = 0.0f64; // multiplier for yᵀx = 0

    // Kx maintained incrementally: Kx = K x (true kernel); O(n·p) update
    // per block via the block's kernel columns.
    let mut kx = vec![0.0f64; n];

    let blocks: Vec<Vec<usize>> = {
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        idx.chunks(p).map(|c| c.to_vec()).collect()
    };

    for _sweep in 0..params.sweeps {
        let mut order: Vec<usize> = (0..blocks.len()).collect();
        rng.shuffle(&mut order);
        for &bi in &order {
            let block = &blocks[bi];
            let m = block.len();
            // kernel columns K(:, B) — the expensive exact-kernel work
            let xb_pts = ds.x.select_rows(block);
            let nb: Vec<f64> = block.iter().map(|&i| norms[i]).collect();
            kernel_evals += n * m;
            let k_cols = backend.kernel_block_with_norms(&kernel, &ds.x, &norms, &xb_pts, &nb); // n×m

            // subproblem over x_B (others fixed):
            //   min ½ x_Bᵀ Q_BB x_B + x_Bᵀ (Q_B,rest x_rest) − e x_B·y...
            // with Q = Y K Y + penalty terms. In x-space with labels folded:
            //   H = Y_B K_BB Y_B + βI + β y_B y_Bᵀ
            //   g = Y_B (K x)_B|rest − e_B − μ_B − β z_B + (β yᵀx|rest − λ) y_B
            // where rest-contributions exclude the block itself.
            let mut h = crate::linalg::Mat::zeros(m, m);
            for (a, &ia) in block.iter().enumerate() {
                for (b_, &ib) in block.iter().enumerate() {
                    h[(a, b_)] = y[ia] * k_cols[(ia, b_)] * y[ib] + beta * y[ia] * y[ib];
                }
                h[(a, a)] += beta;
            }
            // (K x)_B minus the block's own contribution
            let mut ytx_rest = 0.0;
            for i in 0..n {
                ytx_rest += y[i] * x[i];
            }
            for &ib in block {
                ytx_rest -= y[ib] * x[ib];
            }
            let mut g = vec![0.0; m];
            for (a, &ia) in block.iter().enumerate() {
                // kx stores (YKY)x; remove this block's own contribution
                let mut kx_rest = kx[ia];
                for (b_, &ib) in block.iter().enumerate() {
                    kx_rest -= y[ia] * k_cols[(ia, b_)] * y[ib] * x[ib];
                }
                g[a] = kx_rest - 1.0 - mu[ia] - beta * z[ia] + (beta * ytx_rest - lam) * y[ia];
            }
            // solve H xB = −g
            let rhs: Vec<f64> = g.iter().map(|v| -v).collect();
            let xb_new = match Chol::new(&h) {
                Ok(ch) => ch.solve(&rhs),
                Err(_) => {
                    // fall back to LU on (H + tiny shift)
                    let mut h2 = h.clone();
                    h2.shift_diag(1e-8);
                    crate::linalg::lu::Lu::new(&h2)?.solve(&rhs)
                }
            };
            // update (YKY)x incrementally with the changed block
            for (a, &ia) in block.iter().enumerate() {
                let dx = xb_new[a] - x[ia];
                if dx != 0.0 {
                    for i in 0..n {
                        kx[i] += y[i] * k_cols[(i, a)] * y[ia] * dx;
                    }
                    x[ia] = xb_new[a];
                }
            }
        }
        // z and multiplier updates (global, closed form)
        for i in 0..n {
            z[i] = (x[i] - mu[i] / beta).clamp(0.0, c);
        }
        for i in 0..n {
            mu[i] -= beta * (x[i] - z[i]);
        }
        let ytx: f64 = y.iter().zip(x.iter()).map(|(a, b)| a * b).sum();
        lam -= beta * ytx;
    }

    // assemble model from z (box-feasible iterate)
    let primal = {
        let mut s = 0.0;
        for i in 0..n {
            let d = x[i] - z[i];
            s += d * d;
        }
        s.sqrt()
    };
    let ytx: f64 = y.iter().zip(x.iter()).map(|(a, b)| a * b).sum();

    let sv_tol = 1e-8 * c.max(1.0);
    let sv_idx: Vec<usize> = (0..n).filter(|&i| z[i] > sv_tol).collect();
    let sv = ds.x.select_rows(&sv_idx);
    let alpha_y: Vec<f64> = sv_idx.iter().map(|&i| z[i] * y[i]).collect();

    // bias from margin SVs using true kernel rows (capped sample)
    let margin: Vec<usize> = (0..n)
        .filter(|&i| z[i] > 1e-6 * c && z[i] < c * (1.0 - 1e-6))
        .take(256)
        .collect();
    let bias = if margin.is_empty() {
        0.0
    } else {
        let mpts = ds.x.select_rows(&margin);
        let mn: Vec<f64> = margin.iter().map(|&i| norms[i]).collect();
        kernel_evals += margin.len() * sv.rows();
        let svn = sv.self_norms();
        let kb = backend.kernel_block_with_norms(&kernel, &mpts, &mn, &sv, &svn);
        let mut f = vec![0.0; margin.len()];
        blas::gemv(&kb, &alpha_y, &mut f);
        let mut acc = 0.0;
        for (t, &j) in margin.iter().enumerate() {
            acc += y[j] - f[t];
        }
        acc / margin.len() as f64
    };

    let model = SvmModel { sv, alpha_y, bias, kernel, c, labels: ds.labels };
    let stats = RacqpStats {
        sweeps: params.sweeps,
        kernel_evals,
        primal_residual: primal,
        equality_residual: ytx.abs(),
        n_sv: model.n_sv(),
    };
    Ok((model, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::svm::predict;

    #[test]
    fn separable_blobs_classify_well() {
        let mut rng = Rng::new(91);
        let train = synth::blobs(250, 2, 2, 0.08, &mut rng);
        let test = synth::blobs(120, 2, 2, 0.08, &mut {
            let mut r = Rng::new(91);
            r
        });
        let params = RacqpParams { block_size: 64, beta: 1.0, sweeps: 15, seed: 1 };
        let (model, stats) = train_racqp(&train, Kernel::Gaussian { h: 1.0 }, 10.0, &params).unwrap();
        assert!(stats.kernel_evals > 0);
        let acc = predict::accuracy(&model, &test, 1);
        assert!(acc > 0.95, "racqp separable accuracy {acc}");
    }

    #[test]
    fn equality_constraint_converges() {
        let mut rng = Rng::new(92);
        let train = synth::two_moons(200, 0.08, &mut rng);
        let params = RacqpParams { block_size: 50, beta: 2.0, sweeps: 40, seed: 2 };
        let (_, stats) = train_racqp(&train, Kernel::Gaussian { h: 0.4 }, 5.0, &params).unwrap();
        assert!(stats.equality_residual < 0.5, "yᵀx residual {}", stats.equality_residual);
        assert!(stats.primal_residual < 1.0, "x−z residual {}", stats.primal_residual);
    }

    #[test]
    fn agrees_with_smo_on_easy_problem() {
        let mut rng = Rng::new(93);
        let train = synth::blobs(200, 3, 2, 0.1, &mut rng);
        let k = Kernel::Gaussian { h: 1.0 };
        let (racqp, _) = train_racqp(
            &train,
            k,
            1.0,
            &RacqpParams { block_size: 50, beta: 1.0, sweeps: 40, seed: 3 },
        )
        .unwrap();
        let (smo, _) = crate::baselines::smo::train_smo(&train, k, 1.0, &Default::default());
        // both should classify the training set almost identically
        let pr = predict::predict(&racqp, &train.x, 1);
        let ps = predict::predict(&smo, &train.x, 1);
        let agree = pr.iter().zip(ps.iter()).filter(|(a, b)| a == b).count();
        assert!(agree as f64 / 200.0 > 0.95, "agreement {}", agree as f64 / 200.0);
    }
}
