//! Nyström + ADMM baseline.
//!
//! The §1.1/[23] alternative to HSS: a global low-rank approximation
//! K ≈ C M⁻¹ Cᵀ built from m landmark columns (C = K(·, L), M = K(L, L)).
//! The shifted solve (K̃ + βI)⁻¹ is served by the Woodbury identity
//!
//!   (C M⁻¹ Cᵀ + βI)⁻¹ b = b/β − C (βM + CᵀC)⁻¹ Cᵀ b / β,
//!
//! which plugs straight into the same [`crate::admm::AdmmSolver`] the HSS
//! path uses — so the ablation "HSS vs global low rank" (Figure 1's
//! motivation: Gaussian kernels are NOT globally low-rank for small h)
//! compares optimizers with everything else held fixed.

use crate::admm::solver::ShiftedSolve;
use crate::compute::ComputeBackend;
use crate::data::Dataset;
use crate::kernel::Kernel;
use crate::linalg::blas::{self, Trans};
use crate::linalg::chol::Chol;
use crate::linalg::Mat;
use crate::util::prng::Rng;
use anyhow::{Context, Result};

/// Nyström approximation with a Woodbury shifted solver.
pub struct NystromSolver {
    /// C = K(X, L), n×m.
    c: Mat,
    /// Cholesky of (βM + CᵀC), m×m (Woodbury core).
    small: Chol,
    /// Cholesky of M + ridge, m×m (forward product K̃x = C M⁻¹ Cᵀ x).
    m_chol: Chol,
    beta: f64,
    n: usize,
    /// Landmark indices (diagnostics).
    pub landmarks: Vec<usize>,
}

impl NystromSolver {
    /// Build from `m` uniformly sampled landmarks.
    pub fn new(
        ds: &Dataset,
        kernel: &Kernel,
        m: usize,
        beta: f64,
        rng: &mut Rng,
    ) -> Result<Self> {
        Self::new_with(crate::compute::cpu(), ds, kernel, m, beta, rng)
    }

    /// [`Self::new`] on an explicit [`ComputeBackend`]: the landmark
    /// kernel blocks and the CᵀC gemm run on the backend.
    pub fn new_with(
        backend: &dyn ComputeBackend,
        ds: &Dataset,
        kernel: &Kernel,
        m: usize,
        beta: f64,
        rng: &mut Rng,
    ) -> Result<Self> {
        let n = ds.len();
        let m = m.clamp(1, n);
        let landmarks = rng.sample_indices(n, m);
        let norms = ds.x.self_norms();
        let lpts = ds.x.select_rows(&landmarks);
        let lnorms: Vec<f64> = landmarks.iter().map(|&i| norms[i]).collect();
        let c = backend.kernel_block_with_norms(kernel, &ds.x, &norms, &lpts, &lnorms); // n×m
        let mm = backend.kernel_block_with_norms(kernel, &lpts, &lnorms, &lpts, &lnorms); // m×m
        // βM + CᵀC (SPD for β > 0)
        let mut small = backend.gemm(&c, Trans::Yes, &c, Trans::No);
        for i in 0..m {
            for j in 0..m {
                small[(i, j)] += beta * mm[(i, j)];
            }
            small[(i, i)] += 1e-6; // numerical floor (kernel entries are O(1))
        }
        let small = Chol::new(&small).context("Nyström small system not SPD")?;
        let mut m_ridge = mm.clone();
        m_ridge.shift_diag(1e-6);
        let m_chol = Chol::new(&m_ridge).context("Nyström landmark Gram not SPD")?;
        Ok(NystromSolver { c, small, m_chol, beta, n, landmarks })
    }

    /// Memory of the representation (the n×m factor dominates).
    pub fn memory_bytes(&self) -> usize {
        self.c.bytes()
    }

    /// Forward product K̃ x = C (M⁻¹ (Cᵀ x)).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut ctx = vec![0.0; self.c.cols()];
        blas::gemv_t(&self.c, x, &mut ctx);
        let w = self.m_chol.solve(&ctx);
        let mut out = vec![0.0; self.n];
        blas::gemv(&self.c, &w, &mut out);
        out
    }
}

impl ShiftedSolve for NystromSolver {
    fn solve_shifted(&self, b: &[f64]) -> Vec<f64> {
        // x = b/β − C (βM + CᵀC)⁻¹ Cᵀ b / β
        let mut ctb = vec![0.0; self.c.cols()];
        blas::gemv_t(&self.c, b, &mut ctb);
        let z = self.small.solve(&ctb);
        let mut cz = vec![0.0; self.n];
        blas::gemv(&self.c, &z, &mut cz);
        b.iter().zip(cz.iter()).map(|(bi, ci)| (bi - ci) / self.beta).collect()
    }

    fn dim(&self) -> usize {
        self.n
    }
}

/// Train an SVM with Nyström-approximated kernel + the same ADMM loop.
pub fn train_nystrom(
    ds: &Dataset,
    kernel: Kernel,
    c: f64,
    landmarks: usize,
    admm: &crate::admm::AdmmParams,
    seed: u64,
) -> Result<(crate::svm::SvmModel, usize)> {
    let mut rng = Rng::new(seed);
    let solver = NystromSolver::new(ds, &kernel, landmarks, admm.beta, &mut rng)?;
    let mem = solver.memory_bytes();
    let runner = crate::admm::AdmmSolver::new(&solver, &ds.y, *admm);
    let out = runner.run(c);

    // assemble model (same recipe as the HSS path, with the Nyström
    // matvec for the bias)
    let n = ds.len();
    let sv_tol = 1e-8 * c.max(1.0);
    let zy: Vec<f64> = out.z.iter().zip(ds.y.iter()).map(|(z, y)| z * y).collect();
    let ebar: Vec<f64> = out
        .z
        .iter()
        .map(|&z| if z > 1e-6 * c && z < c * (1.0 - 1e-6) { 1.0 } else { 0.0 })
        .collect();
    let mcount: f64 = ebar.iter().sum();
    let bias = if mcount > 0.0 {
        let ke = solver.matvec(&ebar);
        let zky: f64 = zy.iter().zip(ke.iter()).map(|(a, b)| a * b).sum();
        let ysum: f64 = ds.y.iter().zip(ebar.iter()).map(|(y, e)| y * e).sum();
        (ysum - zky) / mcount
    } else {
        0.0
    };
    let sv_idx: Vec<usize> = (0..n).filter(|&i| out.z[i] > sv_tol).collect();
    let sv = ds.x.select_rows(&sv_idx);
    let alpha_y: Vec<f64> = sv_idx.iter().map(|&i| zy[i]).collect();
    Ok((crate::svm::SvmModel { sv, alpha_y, bias, kernel, c, labels: ds.labels }, mem))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::testkit;

    #[test]
    fn woodbury_solve_matches_dense() {
        let mut rng = Rng::new(101);
        let ds = synth::blobs(150, 3, 3, 0.3, &mut rng);
        let kernel = Kernel::Gaussian { h: 2.0 };
        let beta = 5.0;
        // all points as landmarks → K̃ = K exactly (M = K, C = K):
        // C M⁻¹ Cᵀ = K K⁻¹ K = K
        let solver = NystromSolver::new(&ds, &kernel, 150, beta, &mut rng).unwrap();
        let mut kd = kernel.gram(&ds.x);
        kd.shift_diag(beta);
        let chol = Chol::new(&kd).unwrap();
        let b: Vec<f64> = (0..150).map(|_| rng.gauss()).collect();
        let want = chol.solve(&b);
        let got = solver.solve_shifted(&b);
        testkit::assert_allclose(&got, &want, 1e-5);
    }

    #[test]
    fn fewer_landmarks_less_memory() {
        let mut rng = Rng::new(102);
        let ds = synth::blobs(200, 3, 3, 0.3, &mut rng);
        let kernel = Kernel::Gaussian { h: 1.0 };
        let s1 = NystromSolver::new(&ds, &kernel, 20, 1.0, &mut rng).unwrap();
        let s2 = NystromSolver::new(&ds, &kernel, 100, 1.0, &mut rng).unwrap();
        assert!(s1.memory_bytes() < s2.memory_bytes());
        assert_eq!(s1.landmarks.len(), 20);
    }

    #[test]
    fn classifies_smooth_problem() {
        let mut rng = Rng::new(103);
        let train = synth::blobs(400, 4, 3, 0.2, &mut rng);
        let test = synth::blobs(200, 4, 3, 0.2, &mut {
            let mut r = Rng::new(103);
            r
        });
        let (model, _) = train_nystrom(
            &train,
            Kernel::Gaussian { h: 1.5 },
            1.0,
            120,
            &crate::admm::AdmmParams { beta: 10.0, max_it: 20, relax: 1.0, tol: 0.0 },
            7,
        )
        .unwrap();
        // global low-rank is expected to be WEAKER than HSS on clustered
        // data (the paper's Figure-1 motivation) — just require "learned"
        let acc = crate::svm::predict::accuracy(&model, &test, 1);
        assert!(acc > 0.75, "nystrom accuracy {acc}");
    }
}
