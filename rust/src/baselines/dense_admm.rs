//! Exact dense-kernel ADMM (reference baseline).
//!
//! Same ADMM loop as the HSS path but with a dense Cholesky of the true
//! K + βI: O(d²) memory, O(d³) factor. It is the "what would ADMM do with
//! the exact kernel" control used to isolate the effect of the HSS
//! approximation in the ablation benches, and the ground truth the HSS
//! path is compared against in integration tests.

use crate::admm::solver::DenseShifted;
use crate::admm::{AdmmParams, AdmmSolver};
use crate::data::Dataset;
use crate::kernel::Kernel;
use crate::linalg::blas;
use crate::svm::SvmModel;
use anyhow::Result;

/// Train with exact-kernel ADMM. Only viable for d ≲ 10⁴.
pub fn train_dense_admm(
    ds: &Dataset,
    kernel: Kernel,
    c: f64,
    admm: &AdmmParams,
) -> Result<(SvmModel, f64)> {
    let n = ds.len();
    let k = kernel.gram(&ds.x);
    let solver = DenseShifted::new(&k, admm.beta)?;
    let runner = AdmmSolver::new(&solver, &ds.y, *admm);
    let out = runner.run(c);

    // model assembly with the exact kernel
    let sv_tol = 1e-8 * c.max(1.0);
    let zy: Vec<f64> = out.z.iter().zip(ds.y.iter()).map(|(z, y)| z * y).collect();
    let margin: Vec<usize> = (0..n)
        .filter(|&i| out.z[i] > 1e-6 * c && out.z[i] < c * (1.0 - 1e-6))
        .collect();
    let bias = if margin.is_empty() {
        0.0
    } else {
        let mut acc = 0.0;
        for &j in &margin {
            let mut f = 0.0;
            for i in 0..n {
                f += zy[i] * k[(i, j)];
            }
            acc += ds.y[j] - f;
        }
        acc / margin.len() as f64
    };
    let sv_idx: Vec<usize> = (0..n).filter(|&i| out.z[i] > sv_tol).collect();
    let sv = ds.x.select_rows(&sv_idx);
    let alpha_y: Vec<f64> = sv_idx.iter().map(|&i| zy[i]).collect();
    // objective ½ zᵀ(YKY)z − eᵀz for diagnostics
    let obj = {
        let mut kzy = vec![0.0; n];
        blas::gemv(&k, &zy, &mut kzy);
        let quad: f64 = zy.iter().zip(kzy.iter()).map(|(a, b)| a * b).sum();
        let lin: f64 = out.z.iter().sum();
        0.5 * quad - lin
    };
    Ok((SvmModel { sv, alpha_y, bias, kernel, c, labels: ds.labels }, obj))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::svm::predict;
    use crate::util::prng::Rng;

    #[test]
    fn dense_admm_classifies_moons() {
        let mut rng = Rng::new(111);
        let train = synth::two_moons(300, 0.08, &mut rng);
        let test = synth::two_moons(150, 0.08, &mut rng);
        let (model, obj) = train_dense_admm(
            &train,
            Kernel::Gaussian { h: 0.3 },
            10.0,
            &AdmmParams { beta: 10.0, max_it: 30, relax: 1.0, tol: 0.0 },
        )
        .unwrap();
        assert!(obj < 0.0, "dual objective should be negative at a good point: {obj}");
        let acc = predict::accuracy(&model, &test, 1);
        assert!(acc > 0.95, "dense-admm moons accuracy {acc}");
    }

    #[test]
    fn hss_path_matches_dense_path_with_tight_compression() {
        let mut rng = Rng::new(112);
        let train = synth::circles(240, 0.05, &mut rng);
        let test = synth::circles(120, 0.05, &mut rng);
        let kernel = Kernel::Gaussian { h: 0.4 };
        let admm = AdmmParams { beta: 10.0, max_it: 15, relax: 1.0, tol: 0.0 };
        let (dense_model, _) = train_dense_admm(&train, kernel, 5.0, &admm).unwrap();
        let (hss_model, _) = crate::svm::train::train_hss_svm(
            &train,
            kernel,
            &crate::hss::HssParams::near_exact(),
            &admm,
            5.0,
            2,
        )
        .unwrap();
        let fd = predict::decision_function(&dense_model, &test.x, 1);
        let fh = predict::decision_function(&hss_model, &test.x, 1);
        // decision values must agree closely (same algorithm, K̃ ≈ K)
        for i in 0..test.len() {
            assert!(
                (fd[i] - fh[i]).abs() < 1e-3 * (1.0 + fd[i].abs()),
                "decision mismatch at {i}: {} vs {}",
                fd[i],
                fh[i]
            );
        }
    }
}
