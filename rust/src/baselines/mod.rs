//! Baseline solvers the paper compares against (Tables 2 and 3) plus the
//! §1.1 alternatives, all built from scratch on the same substrate so the
//! comparison isolates the algorithms, not the implementations.

// No raw-pointer tricks belong in this module tree (see DESIGN.md §11).
#![forbid(unsafe_code)]

pub mod dense_admm;
pub mod nystrom;
pub mod racqp;
pub mod smo;

pub use dense_admm::train_dense_admm;
pub use nystrom::{train_nystrom, NystromSolver};
pub use racqp::{train_racqp, RacqpParams, RacqpStats};
pub use smo::{train_smo, SmoParams, SmoStats};
