//! (h, C) grid search over the cached kernel hierarchy — the paper's
//! recommended tuning procedure ("the parameter tuning is usually
//! performed by a simple grid-search through the parameter space"),
//! made cheap by the reuse structure.

use crate::admm::{AdmmParams, AdmmSolver};
use crate::coordinator::cache::KernelCache;
use crate::data::Dataset;
use crate::hss::HssParams;
use crate::svm::{predict, SvmModel};
use crate::util::timer::Timer;
use anyhow::Result;

/// Grid specification.
#[derive(Clone, Debug)]
pub struct GridSearch {
    /// Kernel widths to try (paper: {0.1, 1, 10}).
    pub h_values: Vec<f64>,
    /// Penalties to try (paper: {0.1, 1, 10}).
    pub c_values: Vec<f64>,
    pub hss: HssParams,
    pub admm: AdmmParams,
    pub threads: usize,
}

/// One grid cell outcome.
#[derive(Clone, Debug)]
pub struct GridCell {
    pub h: f64,
    pub c: f64,
    pub accuracy: f64,
    /// Amortized ADMM share of this cell: the whole C-row is advanced by
    /// one batched multi-RHS ADMM, and its wall time is split evenly
    /// across the row's cells.
    pub admm_secs: f64,
    pub n_sv: usize,
}

/// Full grid outcome.
#[derive(Clone, Debug)]
pub struct GridResult {
    pub cells: Vec<GridCell>,
    /// Best (h, c, accuracy); ties → all C values sharing the best
    /// accuracy at the best h are reported (the paper's Tables list e.g.
    /// "C = 1,10" when both achieve the maximum).
    pub best_h: f64,
    pub best_cs: Vec<f64>,
    pub best_accuracy: f64,
    pub compress_secs: f64,
    pub factor_secs: f64,
    pub total_admm_secs: f64,
}

impl GridSearch {
    /// Run the grid: compress/factor once per h, then ONE batched ADMM
    /// per h that advances every C in lockstep (a single blocked
    /// multi-RHS ULV sweep per iteration), evaluate on `test`.
    pub fn run(&self, train: &Dataset, test: &Dataset) -> Result<GridResult> {
        let mut cache = KernelCache::new(self.threads);
        let mut cells = Vec::new();
        let mut total_admm = 0.0;

        for &h in &self.h_values {
            // the cache builds trainer+factor with this grid's thread
            // pool; the batched ADMM updates share the same knob
            let (trainer, ulv) = cache.factor(train, h, &self.hss, &self.admm)?;
            let solver = AdmmSolver::new(&*ulv, &trainer.y, self.admm).with_threads(self.threads);
            let t = Timer::start();
            let outs = trainer.train_grid_with_solver(&solver, &self.c_values);
            let batch_secs = t.secs();
            total_admm += batch_secs;
            let per_cell = batch_secs / self.c_values.len().max(1) as f64;
            for (&c, (model, _out)) in self.c_values.iter().zip(outs.into_iter()) {
                let accuracy = predict::accuracy(&model, test, self.threads);
                cells.push(GridCell { h, c, accuracy, admm_secs: per_cell, n_sv: model.n_sv() });
            }
        }

        // best h = argmax over max-accuracy; best Cs = all C achieving it
        let eps = 1e-12;
        let best = cells
            .iter()
            .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
            .expect("non-empty grid");
        let best_h = best.h;
        let best_accuracy = best.accuracy;
        let best_cs: Vec<f64> = cells
            .iter()
            .filter(|c| c.h == best_h && (best_accuracy - c.accuracy).abs() < eps)
            .map(|c| c.c)
            .collect();

        Ok(GridResult {
            cells,
            best_h,
            best_cs,
            best_accuracy,
            compress_secs: cache.timings.compress_secs,
            factor_secs: cache.timings.factor_secs,
            total_admm_secs: total_admm,
        })
    }

    /// Train the final model at the best grid point.
    pub fn train_best(&self, train: &Dataset, result: &GridResult) -> Result<SvmModel> {
        let mut cache = KernelCache::new(self.threads);
        let (trainer, ulv) = cache.factor(train, result.best_h, &self.hss, &self.admm)?;
        let (model, _) = trainer.train_c(&ulv, &self.admm, result.best_cs[0]);
        Ok(model)
    }
}

/// Render the accuracy grid as an ASCII heatmap (Figure 2 regeneration).
pub fn ascii_heatmap(result: &GridResult, h_values: &[f64], c_values: &[f64]) -> String {
    let shades = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let accs: Vec<f64> = result.cells.iter().map(|c| c.accuracy).collect();
    let lo = accs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = accs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut out = String::new();
    out.push_str("        ");
    for &c in c_values {
        out.push_str(&format!("C={c:<8.3}"));
    }
    out.push('\n');
    for &h in h_values {
        out.push_str(&format!("h={h:<6.2}"));
        for &c in c_values {
            let cell = result
                .cells
                .iter()
                .find(|x| x.h == h && x.c == c)
                .expect("cell present");
            let t = if hi > lo { (cell.accuracy - lo) / (hi - lo) } else { 1.0 };
            let ch = shades[(t * (shades.len() - 1) as f64).round() as usize];
            out.push_str(&format!("  {ch}{ch} {:5.1}%", cell.accuracy * 100.0));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::prng::Rng;

    #[test]
    fn grid_finds_a_sensible_optimum_on_moons() {
        let mut rng = Rng::new(311);
        let train = synth::two_moons(300, 0.08, &mut rng);
        let test = synth::two_moons(150, 0.08, &mut rng);
        let grid = GridSearch {
            h_values: vec![0.05, 0.3, 5.0],
            c_values: vec![0.1, 10.0],
            hss: crate::hss::HssParams::near_exact(),
            admm: AdmmParams { beta: 10.0, max_it: 15, relax: 1.0, tol: 0.0 },
            threads: 2,
        };
        let res = grid.run(&train, &test).unwrap();
        assert_eq!(res.cells.len(), 6);
        assert!(res.best_accuracy > 0.9, "best {}", res.best_accuracy);
        // h too small (0.05) overfits badly on moons; the grid should
        // prefer the middle width
        assert_eq!(res.best_h, 0.3, "grid picked h={}", res.best_h);
        assert!(!res.best_cs.is_empty());
        // reuse: exactly |h| compressions even though |h|·|C| cells ran
        assert!(res.total_admm_secs >= 0.0);
        let heat = ascii_heatmap(&res, &grid.h_values, &grid.c_values);
        assert!(heat.contains("h=0.30"));
        assert!(heat.lines().count() >= 4);
    }
}
