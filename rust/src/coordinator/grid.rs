//! (h, C) grid search over the cached kernel hierarchy — the paper's
//! recommended tuning procedure ("the parameter tuning is usually
//! performed by a simple grid-search through the parameter space"),
//! made cheap by the reuse structure.

use crate::admm::{AdmmParams, AdmmSolver, ConsensusTrainer};
use crate::coordinator::cache::KernelCache;
use crate::data::libsvm::Repr;
use crate::data::{Dataset, ShardSet};
use crate::hss::HssParams;
use crate::kernel::Kernel;
use crate::obs;
use crate::svm::multiclass::{MulticlassDataset, OvoModel, OvoPairSet};
use crate::svm::multilevel::{LevelStats, MultilevelContext, MultilevelParams};
use crate::svm::{predict, SvmModel};
use crate::util::timer::Timer;
use anyhow::Result;

/// Grid specification.
#[derive(Clone, Debug)]
pub struct GridSearch {
    /// Kernel widths to try (paper: {0.1, 1, 10}).
    pub h_values: Vec<f64>,
    /// Penalties to try (paper: {0.1, 1, 10}).
    pub c_values: Vec<f64>,
    pub hss: HssParams,
    pub admm: AdmmParams,
    pub threads: usize,
}

/// One grid cell outcome.
#[derive(Clone, Debug)]
pub struct GridCell {
    pub h: f64,
    pub c: f64,
    pub accuracy: f64,
    /// Amortized ADMM share of this cell: the whole C-row is advanced by
    /// one batched multi-RHS ADMM, and its wall time is split evenly
    /// across the row's cells.
    pub admm_secs: f64,
    pub n_sv: usize,
    /// ADMM iterations this column actually ran (0 where per-column
    /// histories are not tracked — multiclass OvO cells aggregate many
    /// pairwise subproblems).
    pub iters: usize,
    pub final_primal: f64,
    pub final_dual: f64,
    /// Per-iteration residual curves (empty for multiclass cells).
    pub primal: Vec<f64>,
    pub dual: Vec<f64>,
}

/// Full grid outcome.
#[derive(Clone, Debug)]
pub struct GridResult {
    pub cells: Vec<GridCell>,
    /// Best (h, c, accuracy); ties → all C values sharing the best
    /// accuracy at the best h are reported (the paper's Tables list e.g.
    /// "C = 1,10" when both achieve the maximum).
    pub best_h: f64,
    pub best_cs: Vec<f64>,
    pub best_accuracy: f64,
    pub compress_secs: f64,
    pub factor_secs: f64,
    pub total_admm_secs: f64,
}

impl GridSearch {
    /// Run the grid: compress/factor once per h, then ONE batched ADMM
    /// per h that advances every C in lockstep (a single blocked
    /// multi-RHS ULV sweep per iteration), evaluate on `test`.
    pub fn run(&self, train: &Dataset, test: &Dataset) -> Result<GridResult> {
        let mut cache = KernelCache::new(self.threads);
        let mut cells = Vec::new();
        let mut total_admm = 0.0;

        for &h in &self.h_values {
            // the cache builds trainer+factor with this grid's thread
            // pool; the batched ADMM updates share the same knob
            let (trainer, ulv) = cache.factor(train, h, &self.hss, &self.admm)?;
            let solver = AdmmSolver::new(&*ulv, &trainer.y, self.admm).with_threads(self.threads);
            let t = Timer::start();
            let outs = trainer.train_grid_with_solver(&solver, &self.c_values);
            let batch_secs = t.secs();
            total_admm += batch_secs;
            let per_cell = batch_secs / self.c_values.len().max(1) as f64;
            for (&c, (model, out)) in self.c_values.iter().zip(outs.into_iter()) {
                let accuracy = predict::accuracy(&model, test, self.threads);
                let hist = out.history();
                if obs::enabled() {
                    obs::emit(&obs::TraceEvent::GridCell {
                        h,
                        c,
                        accuracy,
                        iters: hist.iterations,
                        n_sv: model.n_sv(),
                    });
                }
                cells.push(GridCell {
                    h,
                    c,
                    accuracy,
                    admm_secs: per_cell,
                    n_sv: model.n_sv(),
                    iters: hist.iterations,
                    final_primal: hist.final_primal,
                    final_dual: hist.final_dual,
                    primal: out.primal,
                    dual: out.dual,
                });
            }
        }

        // best h = argmax over max-accuracy; best Cs = all C achieving it
        Ok(Self::summarize(
            cells,
            cache.timings.compress_secs,
            cache.timings.factor_secs,
            total_admm,
        ))
    }

    /// Multilevel grid (`grid --multilevel`): ONE
    /// [`MultilevelContext`] — full-set cluster tree + ANN +
    /// extreme-point screening + level schedule — is built up front and
    /// shared across the whole h row *and* every C (the same reuse shape
    /// as [`KernelCache`], one layer up: the context is h- and
    /// C-independent). Each h then trains its C row coarse-to-fine
    /// through [`MultilevelContext::train_grid`]; no full-set
    /// compression or factorization ever runs. Returns the standard
    /// [`GridResult`] (heatmap/report layer unchanged; `compress_secs`
    /// carries the shared context build, `factor_secs` is folded into
    /// the per-level timings inside the [`LevelStats`]) plus the level
    /// schedule per h.
    pub fn run_multilevel(
        &self,
        train: &Dataset,
        test: &Dataset,
        ml: &MultilevelParams,
    ) -> Result<(GridResult, Vec<(f64, Vec<LevelStats>)>)> {
        let t = Timer::start();
        let ctx = MultilevelContext::new(train, &self.hss, ml, self.threads);
        let prep_secs = t.secs();
        let mut cells = Vec::new();
        let mut total_admm = 0.0;
        let mut per_h = Vec::new();
        for &h in &self.h_values {
            let t = Timer::start();
            let run = ctx.train_grid(Kernel::Gaussian { h }, &self.admm, &self.c_values)?;
            let row_secs = t.secs();
            total_admm += row_secs;
            let per_cell = row_secs / self.c_values.len().max(1) as f64;
            for (&c, (model, out)) in self.c_values.iter().zip(run.results.iter()) {
                let accuracy = predict::accuracy(model, test, self.threads);
                let hist = out.history();
                if obs::enabled() {
                    obs::emit(&obs::TraceEvent::GridCell {
                        h,
                        c,
                        accuracy,
                        iters: hist.iterations,
                        n_sv: model.n_sv(),
                    });
                }
                cells.push(GridCell {
                    h,
                    c,
                    accuracy,
                    admm_secs: per_cell,
                    n_sv: model.n_sv(),
                    iters: hist.iterations,
                    final_primal: hist.final_primal,
                    final_dual: hist.final_dual,
                    primal: out.primal.clone(),
                    dual: out.dual.clone(),
                });
            }
            per_h.push((h, run.levels));
        }
        Ok((Self::summarize(cells, prep_secs, 0.0, total_admm), per_h))
    }

    /// One-vs-one multiclass grid: the per-pair h-INDEPENDENT
    /// preprocessing (cluster tree + ANN) is built once
    /// ([`OvoPairSet::prepare`] — the multiclass counterpart of
    /// [`KernelCache`]'s reuse), then for each h every pairwise
    /// subproblem compresses and factors ONCE and advances all C
    /// values in one batched multi-RHS ADMM sweep, pairs running in
    /// outer parallelism across the thread budget. Accuracy is
    /// evaluated through the shared-SV engine; `n_sv` reports the
    /// unique-SV pool size per cell. The result reuses [`GridResult`]
    /// so the heatmap/report layer is arity-agnostic (per-h stage
    /// seconds are summed over pairs).
    pub fn run_multiclass(
        &self,
        train: &MulticlassDataset,
        test: &MulticlassDataset,
    ) -> Result<GridResult> {
        let mut cells = Vec::new();
        let set = OvoPairSet::prepare(train, &self.hss, self.threads)?;
        let (mut compress_secs, mut factor_secs, mut total_admm) =
            (set.prepare_secs(), 0.0, 0.0);
        for &h in &self.h_values {
            let (models, stats) =
                set.train_grid(Kernel::Gaussian { h }, &self.hss, &self.admm, &self.c_values)?;
            compress_secs += stats.compress_secs;
            factor_secs += stats.factor_secs;
            total_admm += stats.admm_secs;
            let per_cell = stats.admm_secs / self.c_values.len().max(1) as f64;
            for (&c, model) in self.c_values.iter().zip(models.iter()) {
                let accuracy = model.accuracy(test, self.threads);
                if obs::enabled() {
                    obs::emit(&obs::TraceEvent::GridCell {
                        h,
                        c,
                        accuracy,
                        iters: 0,
                        n_sv: model.n_sv_unique(),
                    });
                }
                cells.push(GridCell {
                    h,
                    c,
                    accuracy,
                    admm_secs: per_cell,
                    n_sv: model.n_sv_unique(),
                    iters: 0,
                    final_primal: 0.0,
                    final_dual: 0.0,
                    primal: Vec::new(),
                    dual: Vec::new(),
                });
            }
        }
        Ok(Self::summarize(cells, compress_secs, factor_secs, total_admm))
    }

    /// Sharded out-of-core grid: one [`ConsensusTrainer`] build per h
    /// (compress + factor every shard once, loading raw points one
    /// shard at a time), then ONE consensus ADMM per h advancing every
    /// C in lockstep — the out-of-core analog of [`Self::run`], with
    /// the same reuse structure. `test` is an ordinary in-memory
    /// dataset (evaluation sets are small; only training is sharded).
    pub fn run_sharded(
        &self,
        shards: &ShardSet,
        repr: Repr,
        test: &Dataset,
    ) -> Result<GridResult> {
        let mut cells = Vec::new();
        let (mut compress_secs, mut factor_secs, mut total_admm) = (0.0, 0.0, 0.0);
        for &h in &self.h_values {
            let (trainer, stats) = ConsensusTrainer::build(
                shards,
                repr,
                Kernel::Gaussian { h },
                &self.hss,
                self.admm,
                self.threads,
            )?;
            compress_secs += stats.compress_secs;
            factor_secs += stats.factor_secs;
            let t = Timer::start();
            let outs = trainer.train_grid(&self.c_values);
            let batch_secs = t.secs();
            total_admm += batch_secs;
            let per_cell = batch_secs / self.c_values.len().max(1) as f64;
            for (&c, out) in self.c_values.iter().zip(outs.iter()) {
                let model = trainer.assemble_model(shards, out, c)?;
                let accuracy = predict::accuracy(&model, test, self.threads);
                let iters = out.primal.len();
                if obs::enabled() {
                    obs::emit(&obs::TraceEvent::GridCell {
                        h,
                        c,
                        accuracy,
                        iters,
                        n_sv: model.n_sv(),
                    });
                }
                cells.push(GridCell {
                    h,
                    c,
                    accuracy,
                    admm_secs: per_cell,
                    n_sv: model.n_sv(),
                    iters,
                    final_primal: out.primal.last().copied().unwrap_or(0.0),
                    final_dual: out.dual.last().copied().unwrap_or(0.0),
                    primal: out.primal.clone(),
                    dual: out.dual.clone(),
                });
            }
        }
        Ok(Self::summarize(cells, compress_secs, factor_secs, total_admm))
    }

    /// Pick the best cell(s) and assemble the [`GridResult`].
    fn summarize(
        cells: Vec<GridCell>,
        compress_secs: f64,
        factor_secs: f64,
        total_admm_secs: f64,
    ) -> GridResult {
        let eps = 1e-12;
        let best = cells
            .iter()
            .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
            .expect("non-empty grid");
        let best_h = best.h;
        let best_accuracy = best.accuracy;
        let best_cs: Vec<f64> = cells
            .iter()
            .filter(|c| c.h == best_h && (best_accuracy - c.accuracy).abs() < eps)
            .map(|c| c.c)
            .collect();
        GridResult {
            cells,
            best_h,
            best_cs,
            best_accuracy,
            compress_secs,
            factor_secs,
            total_admm_secs,
        }
    }

    /// Train the final OvO model at the best multiclass grid point.
    pub fn train_best_multiclass(
        &self,
        train: &MulticlassDataset,
        result: &GridResult,
    ) -> Result<OvoModel> {
        let (model, _) = crate::svm::multiclass::train_ovo(
            train,
            Kernel::Gaussian { h: result.best_h },
            &self.hss,
            &self.admm,
            result.best_cs[0],
            self.threads,
        )?;
        Ok(model)
    }

    /// Train the final model at the best grid point.
    pub fn train_best(&self, train: &Dataset, result: &GridResult) -> Result<SvmModel> {
        let mut cache = KernelCache::new(self.threads);
        let (trainer, ulv) = cache.factor(train, result.best_h, &self.hss, &self.admm)?;
        let (model, _) = trainer.train_c(&ulv, &self.admm, result.best_cs[0]);
        Ok(model)
    }
}

/// Render the accuracy grid as an ASCII heatmap (Figure 2 regeneration).
pub fn ascii_heatmap(result: &GridResult, h_values: &[f64], c_values: &[f64]) -> String {
    let shades = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let accs: Vec<f64> = result.cells.iter().map(|c| c.accuracy).collect();
    let lo = accs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = accs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut out = String::new();
    out.push_str("        ");
    for &c in c_values {
        out.push_str(&format!("C={c:<8.3}"));
    }
    out.push('\n');
    for &h in h_values {
        out.push_str(&format!("h={h:<6.2}"));
        for &c in c_values {
            let cell = result
                .cells
                .iter()
                .find(|x| x.h == h && x.c == c)
                .expect("cell present");
            let t = if hi > lo { (cell.accuracy - lo) / (hi - lo) } else { 1.0 };
            let ch = shades[(t * (shades.len() - 1) as f64).round() as usize];
            out.push_str(&format!("  {ch}{ch} {:5.1}%", cell.accuracy * 100.0));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::prng::Rng;

    #[test]
    fn grid_finds_a_sensible_optimum_on_moons() {
        let mut rng = Rng::new(311);
        let train = synth::two_moons(300, 0.08, &mut rng);
        let test = synth::two_moons(150, 0.08, &mut rng);
        let grid = GridSearch {
            h_values: vec![0.05, 0.3, 5.0],
            c_values: vec![0.1, 10.0],
            hss: crate::hss::HssParams::near_exact(),
            admm: AdmmParams { beta: 10.0, max_it: 15, relax: 1.0, tol: 0.0 },
            threads: 2,
        };
        let res = grid.run(&train, &test).unwrap();
        assert_eq!(res.cells.len(), 6);
        assert!(res.best_accuracy > 0.9, "best {}", res.best_accuracy);
        // h too small (0.05) overfits badly on moons; the grid should
        // prefer the middle width
        assert_eq!(res.best_h, 0.3, "grid picked h={}", res.best_h);
        assert!(!res.best_cs.is_empty());
        // reuse: exactly |h| compressions even though |h|·|C| cells ran
        assert!(res.total_admm_secs >= 0.0);
        let heat = ascii_heatmap(&res, &grid.h_values, &grid.c_values);
        assert!(heat.contains("h=0.30"));
        assert!(heat.lines().count() >= 4);
    }

    #[test]
    fn multilevel_grid_matches_flat_grid_on_separable_data() {
        let mut rng = Rng::new(313);
        let train = synth::xor_blobs(900, 4, 0.35, &mut rng);
        let test = synth::xor_blobs(400, 4, 0.35, &mut rng);
        let mut hss = crate::hss::HssParams::low_accuracy();
        hss.leaf_size = 48;
        let grid = GridSearch {
            h_values: vec![1.0, 2.0],
            c_values: vec![0.5, 2.0],
            hss,
            admm: AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 },
            threads: 2,
        };
        let flat = grid.run(&train, &test).unwrap();
        let (ml, per_h) = grid
            .run_multilevel(&train, &test, &MultilevelParams::default())
            .unwrap();
        assert_eq!(ml.cells.len(), flat.cells.len());
        assert_eq!(per_h.len(), grid.h_values.len());
        // every h actually went through a coarse level smaller than n
        for (h, levels) in &per_h {
            assert!(!levels.is_empty(), "h={h}: empty schedule");
            assert!(
                levels[0].n_points < train.len(),
                "h={h}: coarse level is the full set"
            );
        }
        // equal-accuracy contract on trivially separable data
        assert!(
            (flat.best_accuracy - ml.best_accuracy).abs() <= 0.02,
            "multilevel best {} vs flat best {}",
            ml.best_accuracy,
            flat.best_accuracy
        );
    }

    #[test]
    fn multiclass_grid_reuses_batched_c_and_finds_separation() {
        let mut rng = Rng::new(312);
        let train = synth::multiclass_blobs(240, 2, 4, 0.4, &mut rng);
        let test = synth::multiclass_blobs(120, 2, 4, 0.4, &mut rng);
        let grid = GridSearch {
            h_values: vec![0.8, 2.0],
            c_values: vec![1.0, 10.0],
            hss: crate::hss::HssParams::near_exact(),
            admm: AdmmParams { beta: 10.0, max_it: 10, relax: 1.0, tol: 0.0 },
            threads: 2,
        };
        let res = grid.run_multiclass(&train, &test).unwrap();
        assert_eq!(res.cells.len(), 4);
        assert!(res.best_accuracy > 0.9, "best {}", res.best_accuracy);
        assert!(!res.best_cs.is_empty());
        // the report layer is arity-agnostic
        let heat = ascii_heatmap(&res, &grid.h_values, &grid.c_values);
        assert!(heat.lines().count() >= 3);
        let best = grid.train_best_multiclass(&train, &res).unwrap();
        assert!(best.accuracy(&test, 2) > 0.9);
    }
}
