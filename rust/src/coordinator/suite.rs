//! Whole-paper experiment orchestration: one call reproduces a Table-4/5
//! style row (and optionally the Table-2/3 baseline rows) for a dataset.

use crate::admm::AdmmParams;
use crate::baselines::{racqp, smo};
use crate::coordinator::grid::{GridResult, GridSearch};
use crate::data::synth::{self, Table1Spec};
use crate::data::{scale, Dataset};
use crate::hss::HssParams;
use crate::kernel::Kernel;
use crate::svm::predict;
use crate::util::timer::Timer;
use anyhow::{Context, Result};

/// Configuration for a suite run.
#[derive(Clone, Debug)]
pub struct SuiteConfig {
    /// Table-1 dataset names (empty → all ten).
    pub datasets: Vec<String>,
    /// Fraction of the paper's dataset sizes to generate.
    pub scale: f64,
    /// HSS accuracy setting (Table 4 = low, Table 5 = high).
    pub hss: HssParams,
    /// Paper grid: h, C ∈ {0.1, 1, 10}.
    pub h_values: Vec<f64>,
    pub c_values: Vec<f64>,
    /// ADMM iteration budget (paper: 10).
    pub max_it: usize,
    pub threads: usize,
    /// Also run the SMO / RACQP baselines at the grid-selected (h, C).
    pub run_smo: bool,
    pub run_racqp: bool,
    /// Skip baselines above this training size (the paper's †† = 10 h
    /// timeout, scaled to this testbed).
    pub baseline_cap: usize,
    pub seed: u64,
}

impl Default for SuiteConfig {
    fn default() -> Self {
        SuiteConfig {
            datasets: Vec::new(),
            scale: 0.01,
            hss: HssParams::low_accuracy(),
            h_values: vec![0.1, 1.0, 10.0],
            c_values: vec![0.1, 1.0, 10.0],
            max_it: 10,
            threads: crate::util::threadpool::default_threads(),
            run_smo: false,
            run_racqp: false,
            baseline_cap: 20_000,
            seed: 2021,
        }
    }
}

/// One dataset's results (a row of Tables 4/5, plus baseline rows).
#[derive(Clone, Debug)]
pub struct SuiteRow {
    pub dataset: String,
    pub train_size: usize,
    pub test_size: usize,
    pub features: usize,
    pub beta: f64,
    // HSS + ADMM (Tables 4/5 columns)
    pub compress_secs: f64,
    pub factor_secs: f64,
    pub memory_mb: f64,
    /// Amortized ADMM time per C value. The grid now advances all C in
    /// one batched multi-RHS run per h, so this is that run's wall time
    /// divided by the number of C values — a LOWER number than the
    /// paper's per-single-C "ADMM Time" (that is the point: the batched
    /// sweep is what one grid cell effectively costs).
    pub admm_secs: f64,
    pub best_h: f64,
    pub best_cs: Vec<f64>,
    pub accuracy: f64,
    pub hss_max_rank: usize,
    // baselines at (best_h, first best C): (runtime s, accuracy)
    pub smo: Option<(f64, f64)>,
    pub racqp: Option<(f64, f64)>,
    pub grid: GridResult,
}

/// Generate + scale one Table-1 dataset pair.
pub fn prepare_dataset(spec: &Table1Spec, scale_frac: f64, seed: u64) -> (Dataset, Dataset) {
    let (mut train, mut test) = spec.generate(scale_frac, seed);
    scale::scale_pair(&mut train, &mut test);
    (train, test)
}

/// Run the suite over the configured datasets.
pub fn run_suite(cfg: &SuiteConfig) -> Result<Vec<SuiteRow>> {
    let names: Vec<&str> = if cfg.datasets.is_empty() {
        synth::TABLE1.iter().map(|s| s.name).collect()
    } else {
        cfg.datasets.iter().map(|s| s.as_str()).collect()
    };

    let mut rows = Vec::new();
    for name in names {
        let spec = synth::table1_spec(name)
            .with_context(|| format!("unknown Table-1 dataset {name:?}"))?;
        rows.push(run_dataset(spec, cfg)?);
    }
    Ok(rows)
}

/// Run one dataset through grid + optional baselines.
pub fn run_dataset(spec: &Table1Spec, cfg: &SuiteConfig) -> Result<SuiteRow> {
    let (train, test) = prepare_dataset(spec, cfg.scale, cfg.seed);
    let beta = Table1Spec::beta_for(train.len());
    let admm = AdmmParams { beta, max_it: cfg.max_it, relax: 1.0, tol: 0.0 };
    let grid = GridSearch {
        h_values: cfg.h_values.clone(),
        c_values: cfg.c_values.clone(),
        hss: cfg.hss,
        admm,
        threads: cfg.threads,
    };
    let res = grid.run(&train, &test)?;

    // memory + rank from a fresh compression at the best h (cache local
    // to the grid run; recompress once for reporting)
    let trainer = crate::svm::HssSvmTrainer::compress(
        &train,
        Kernel::Gaussian { h: res.best_h },
        &cfg.hss,
        cfg.threads,
    );
    let memory_mb = trainer.compressed.stats.memory_bytes as f64 / 1e6;
    let hss_max_rank = trainer.compressed.stats.max_rank;
    let admm_secs = res.total_admm_secs / res.cells.len() as f64;

    let best_h = res.best_h;
    let best_c = res.best_cs[0];
    let kernel = Kernel::Gaussian { h: best_h };

    let smo_out = if cfg.run_smo && train.len() <= cfg.baseline_cap {
        let t = Timer::start();
        let (model, _) = smo::train_smo(&train, kernel, best_c, &smo::SmoParams::default());
        let secs = t.secs();
        let acc = predict::accuracy(&model, &test, cfg.threads);
        Some((secs, acc))
    } else {
        None
    };

    let racqp_out = if cfg.run_racqp && train.len() <= cfg.baseline_cap {
        let t = Timer::start();
        let params = racqp::RacqpParams {
            block_size: 500.min(train.len()),
            beta: 1.0,
            sweeps: 20,
            seed: cfg.seed,
        };
        let (model, _) = racqp::train_racqp(&train, kernel, best_c, &params)?;
        let secs = t.secs();
        let acc = predict::accuracy(&model, &test, cfg.threads);
        Some((secs, acc))
    } else {
        None
    };

    Ok(SuiteRow {
        dataset: spec.name.to_string(),
        train_size: train.len(),
        test_size: test.len(),
        features: train.dim(),
        beta,
        compress_secs: res.compress_secs / cfg.h_values.len() as f64,
        factor_secs: res.factor_secs / cfg.h_values.len() as f64,
        memory_mb,
        admm_secs,
        best_h,
        best_cs: res.best_cs.clone(),
        accuracy: res.best_accuracy,
        hss_max_rank,
        smo: smo_out,
        racqp: racqp_out,
        grid: res,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miniature_suite_round_trips_one_dataset() {
        let cfg = SuiteConfig {
            datasets: vec!["ijcnn1".into()],
            scale: 0.004, // ~200 points
            hss: HssParams { leaf_size: 64, ..HssParams::low_accuracy() },
            h_values: vec![0.1, 1.0],
            c_values: vec![1.0, 10.0],
            max_it: 10,
            threads: 2,
            run_smo: true,
            run_racqp: false,
            baseline_cap: 10_000,
            seed: 7,
        };
        let rows = run_suite(&cfg).unwrap();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!(r.dataset, "ijcnn1");
        assert!(r.train_size > 100 && r.train_size < 400);
        assert!(r.accuracy > 0.5, "accuracy {}", r.accuracy);
        assert!(r.memory_mb > 0.0);
        assert!(r.smo.is_some());
        let (smo_secs, smo_acc) = r.smo.unwrap();
        assert!(smo_secs >= 0.0 && smo_acc > 0.5);
        assert_eq!(r.grid.cells.len(), 4);
        assert_eq!(r.beta, 1e2);
    }

    #[test]
    fn baseline_cap_skips_large_runs() {
        let cfg = SuiteConfig {
            datasets: vec!["ijcnn1".into()],
            scale: 0.004,
            hss: HssParams { leaf_size: 64, ..HssParams::low_accuracy() },
            h_values: vec![1.0],
            c_values: vec![1.0],
            max_it: 5,
            threads: 1,
            run_smo: true,
            run_racqp: true,
            baseline_cap: 10, // below the generated size
            seed: 7,
        };
        let rows = run_suite(&cfg).unwrap();
        assert!(rows[0].smo.is_none());
        assert!(rows[0].racqp.is_none());
    }
}
