//! The compression/factorization cache (the paper's §3.2 reuse trick:
//! "for a fixed kernel value h the approximation K̃ and the factorization
//! ULV of K̃_β are computed just once and then reused for all the values
//! C in the grid search").

use crate::admm::AdmmParams;
use crate::data::Dataset;
use crate::hss::compress::Preprocessed;
use crate::hss::ulv::UlvFactor;
use crate::hss::HssParams;
use crate::kernel::Kernel;
use crate::svm::HssSvmTrainer;
use crate::util::timer::Timer;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// Cache key: kernel width bits + the HSS fingerprint.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct HKey {
    h_bits: u64,
    params: ParamsFp,
}

#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct ParamsFp {
    rel_bits: u64,
    abs_bits: u64,
    max_rank: usize,
    ann: usize,
    leaf: usize,
    seed: u64,
}

fn fp(p: &HssParams) -> ParamsFp {
    ParamsFp {
        rel_bits: p.rel_tol.to_bits(),
        abs_bits: p.abs_tol.to_bits(),
        max_rank: p.max_rank,
        ann: p.ann_neighbors,
        leaf: p.leaf_size,
        seed: p.seed,
    }
}

/// Timing observed while filling the cache (per entry).
#[derive(Clone, Debug, Default)]
pub struct CacheTimings {
    pub compress_secs: f64,
    pub factor_secs: f64,
    pub compress_count: usize,
    pub factor_count: usize,
}

/// Per-dataset cache of h-independent preprocessing (cluster tree +
/// ANN), trainers (per h) and ULV factors (per h, β).
pub struct KernelCache {
    pre: HashMap<ParamsFp, Arc<Preprocessed>>,
    trainers: HashMap<HKey, Arc<HssSvmTrainer>>,
    factors: HashMap<(HKey, u64), Arc<UlvFactor>>,
    pub timings: CacheTimings,
    threads: usize,
}

impl KernelCache {
    pub fn new(threads: usize) -> Self {
        KernelCache {
            pre: HashMap::new(),
            trainers: HashMap::new(),
            factors: HashMap::new(),
            timings: CacheTimings::default(),
            threads,
        }
    }

    /// Stage-1 (compress) — computed at most once per (h, params).
    pub fn trainer(
        &mut self,
        ds: &Dataset,
        h: f64,
        params: &HssParams,
    ) -> Arc<HssSvmTrainer> {
        let key = HKey { h_bits: h.to_bits(), params: fp(params) };
        if let Some(t) = self.trainers.get(&key) {
            return Arc::clone(t);
        }
        let t = Timer::start();
        // h-independent preprocessing (cluster tree + ANN) shared by all
        // h values of the grid (§Perf: removes redundant ANN passes)
        let pre = match self.pre.get(&key.params) {
            Some(p) => Arc::clone(p),
            None => {
                let p = Arc::new(crate::hss::compress::preprocess(ds, params, self.threads));
                self.pre.insert(key.params.clone(), Arc::clone(&p));
                p
            }
        };
        let trainer = Arc::new(HssSvmTrainer::compress_preprocessed(
            &pre,
            Kernel::Gaussian { h },
            params,
            self.threads,
        ));
        self.timings.compress_secs += t.secs();
        self.timings.compress_count += 1;
        self.trainers.insert(key, Arc::clone(&trainer));
        trainer
    }

    /// Stage-2 (ULV factor) — once per (h, params, β). The factorization
    /// runs level-parallel over this cache's thread pool (the trainer
    /// carries the knob), and the returned factor reuses the same pool
    /// for every blocked solve.
    pub fn factor(
        &mut self,
        ds: &Dataset,
        h: f64,
        params: &HssParams,
        admm: &AdmmParams,
    ) -> Result<(Arc<HssSvmTrainer>, Arc<UlvFactor>)> {
        let key = HKey { h_bits: h.to_bits(), params: fp(params) };
        let trainer = self.trainer(ds, h, params);
        let fkey = (key, admm.beta.to_bits());
        if let Some(f) = self.factors.get(&fkey) {
            return Ok((trainer, Arc::clone(f)));
        }
        let t = Timer::start();
        let factor = Arc::new(trainer.factor(admm.beta)?);
        self.timings.factor_secs += t.secs();
        self.timings.factor_count += 1;
        self.factors.insert(fkey, Arc::clone(&factor));
        Ok((trainer, factor))
    }

    /// Number of cached compressions / factorizations.
    pub fn sizes(&self) -> (usize, usize) {
        (self.trainers.len(), self.factors.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::prng::Rng;

    #[test]
    fn compression_computed_once_per_h() {
        let mut rng = Rng::new(301);
        let ds = synth::blobs(200, 3, 3, 0.3, &mut rng);
        let mut cache = KernelCache::new(1);
        let p = HssParams::near_exact();
        let admm = AdmmParams { beta: 10.0, max_it: 10, relax: 1.0, tol: 0.0 };

        let t1 = cache.trainer(&ds, 1.0, &p);
        let t2 = cache.trainer(&ds, 1.0, &p);
        assert!(Arc::ptr_eq(&t1, &t2), "same h must hit the cache");
        assert_eq!(cache.timings.compress_count, 1);

        let _t3 = cache.trainer(&ds, 2.0, &p);
        assert_eq!(cache.timings.compress_count, 2);

        let (_, f1) = cache.factor(&ds, 1.0, &p, &admm).unwrap();
        let (_, f2) = cache.factor(&ds, 1.0, &p, &admm).unwrap();
        assert!(Arc::ptr_eq(&f1, &f2));
        assert_eq!(cache.timings.factor_count, 1);

        let admm2 = AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 };
        let (_, _f3) = cache.factor(&ds, 1.0, &p, &admm2).unwrap();
        assert_eq!(cache.timings.factor_count, 2);
        assert_eq!(cache.sizes(), (2, 2));
    }

    #[test]
    fn different_hss_params_do_not_collide() {
        let mut rng = Rng::new(302);
        let ds = synth::blobs(150, 2, 3, 0.3, &mut rng);
        let mut cache = KernelCache::new(1);
        let _a = cache.trainer(&ds, 1.0, &HssParams::low_accuracy());
        let _b = cache.trainer(&ds, 1.0, &HssParams::high_accuracy());
        assert_eq!(cache.timings.compress_count, 2);
    }
}
