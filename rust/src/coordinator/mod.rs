//! L3 coordinator: the paper's *systems* contribution.
//!
//! The method's efficiency comes from a reuse hierarchy —
//!
//! ```text
//!   dataset ──► (per h)  HSS compression          expensive, cached
//!                  └──► (per β)  ULV factorization  cheap-ish, cached
//!                          └──► (per C)  10 ADMM iterations  negligible
//! ```
//!
//! [`cache::KernelCache`] owns that hierarchy; [`grid::GridSearch`]
//! drives the (h, C) hyperparameter sweep over it, reproducing the
//! paper's claim that the *total* grid time ≈ one compression per h plus
//! `#C × ADMM-time`; [`suite`] orchestrates whole-paper experiment runs
//! (Tables 2–5) across datasets and solvers.

// No raw-pointer tricks belong in this module tree (see DESIGN.md §11).
#![forbid(unsafe_code)]

pub mod cache;
pub mod grid;
pub mod suite;

pub use cache::KernelCache;
pub use grid::{GridResult, GridSearch};
pub use suite::{run_suite, SuiteConfig, SuiteRow};
