//! Cluster-tree preprocessing (the data-reordering step that makes
//! off-diagonal kernel blocks compressible).

pub mod tree;

pub use tree::{ClusterTree, Node, SplitMethod};
