//! Cluster-tree preprocessing (the data-reordering step that makes
//! off-diagonal kernel blocks compressible).
//!
//! [`ClusterTree`] recursively bisects the training points (2-means or
//! PCA splits, see [`SplitMethod`]) and permutes the dataset so every
//! node owns a contiguous position range `begin..end`. Two consumers
//! rely on that geometry:
//!
//! * **HSS/HODLR compression** — near points share tree nodes, so
//!   off-diagonal blocks between separated nodes are numerically
//!   low-rank (the whole premise of `hss::compress`).
//! * **Multilevel training** ([`crate::svm::multilevel`], DESIGN.md
//!   §15) — the frontier of the tree at a level is a coarse partition
//!   of the dataset, so the tree doubles as the coarsening hierarchy:
//!   one representative per frontier node is a coarse training set,
//!   and no separate clustering pass ever runs.

// No raw-pointer tricks belong in this module tree (see DESIGN.md §11).
#![forbid(unsafe_code)]

pub mod tree;

pub use tree::{ClusterTree, Node, SplitMethod};
