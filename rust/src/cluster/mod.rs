//! Cluster-tree preprocessing (the data-reordering step that makes
//! off-diagonal kernel blocks compressible).

// No raw-pointer tricks belong in this module tree (see DESIGN.md §11).
#![forbid(unsafe_code)]

pub mod tree;

pub use tree::{ClusterTree, Node, SplitMethod};
