//! Binary cluster tree over data points.
//!
//! STRUMPACK's kernel preprocessing reorders points so that groups with
//! small intra-group and large inter-group distances become contiguous —
//! that reordering is what makes the off-diagonal kernel blocks low-rank
//! (Figure 1, right panel). We implement the same idea: a recursive
//! binary partition (2-means or PCA bisection) producing a permutation
//! and a postorder node list, which is exactly the skeleton the HSS
//! hierarchy is built on.
//!
//! All distance/centroid arithmetic goes through the [`Points`]
//! accessors, so the same splits run on dense and CSR datasets; the
//! dense arms are the original slice loops (bit-for-bit unchanged).

use crate::data::Dataset;
use crate::linalg::blas;
#[cfg(test)]
use crate::linalg::Mat;
use crate::util::prng::Rng;

/// Splitting strategy for the recursive bisection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitMethod {
    /// Two-means (Lloyd with farthest-pair seeding) — STRUMPACK's
    /// `kmeans` clustering option, the default in [10].
    TwoMeans,
    /// Bisect along the principal direction (power iteration on the
    /// covariance) — STRUMPACK's `pca` option.
    Pca,
}

/// A node of the cluster tree; covers `perm[begin..end]`.
#[derive(Clone, Debug)]
pub struct Node {
    pub begin: usize,
    pub end: usize,
    /// Indices into `ClusterTree::nodes` (postorder), None for leaves.
    pub left: Option<usize>,
    pub right: Option<usize>,
    pub parent: Option<usize>,
    /// Depth from root (root = 0).
    pub level: usize,
}

impl Node {
    pub fn len(&self) -> usize {
        self.end - self.begin
    }

    pub fn is_empty(&self) -> bool {
        self.begin == self.end
    }

    pub fn is_leaf(&self) -> bool {
        self.left.is_none()
    }
}

/// Binary cluster tree + permutation.
pub struct ClusterTree {
    /// `perm[p]` = original index of the point now at position p.
    pub perm: Vec<usize>,
    /// Inverse: `iperm[original] = position`.
    pub iperm: Vec<usize>,
    /// Nodes in postorder (children precede parents; root is last).
    pub nodes: Vec<Node>,
}

impl ClusterTree {
    /// Build over the points of `ds`. Leaves have ≤ `leaf_size` points.
    pub fn build(ds: &Dataset, leaf_size: usize, method: SplitMethod, rng: &mut Rng) -> Self {
        assert!(leaf_size >= 1);
        let n = ds.len();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut nodes = Vec::new();
        if n > 0 {
            build_rec(ds, &mut perm, 0, n, leaf_size, method, rng, &mut nodes, 0);
        }
        // fix levels: build_rec records depth top-down already
        let mut iperm = vec![0usize; n];
        for (p, &orig) in perm.iter().enumerate() {
            iperm[orig] = p;
        }
        ClusterTree { perm, iperm, nodes }
    }

    /// Root node index (postorder ⇒ last).
    pub fn root(&self) -> usize {
        self.nodes.len() - 1
    }

    /// Leaf node indices in left-to-right order.
    pub fn leaves(&self) -> Vec<usize> {
        let mut ls: Vec<usize> =
            (0..self.nodes.len()).filter(|&i| self.nodes[i].is_leaf()).collect();
        ls.sort_by_key(|&i| self.nodes[i].begin);
        ls
    }

    /// Number of levels (root level 0 inclusive).
    pub fn depth(&self) -> usize {
        self.nodes.iter().map(|n| n.level).max().unwrap_or(0) + 1
    }
}

#[allow(clippy::too_many_arguments)]
fn build_rec(
    ds: &Dataset,
    perm: &mut [usize],
    begin: usize,
    end: usize,
    leaf_size: usize,
    method: SplitMethod,
    rng: &mut Rng,
    nodes: &mut Vec<Node>,
    level: usize,
) -> usize {
    let len = end - begin;
    if len <= leaf_size || len < 4 {
        nodes.push(Node { begin, end, left: None, right: None, parent: None, level });
        return nodes.len() - 1;
    }
    let local = &mut perm[begin..end];
    let mid_local = match method {
        SplitMethod::TwoMeans => split_two_means(ds, local, rng),
        SplitMethod::Pca => split_pca(ds, local, rng),
    };
    // guard against degenerate splits (all points identical): force halves
    let mid_local = if mid_local == 0 || mid_local == len { len / 2 } else { mid_local };
    let mid = begin + mid_local;
    let l = build_rec(ds, perm, begin, mid, leaf_size, method, rng, nodes, level + 1);
    let r = build_rec(ds, perm, mid, end, leaf_size, method, rng, nodes, level + 1);
    nodes.push(Node { begin, end, left: Some(l), right: Some(r), parent: None, level });
    let me = nodes.len() - 1;
    nodes[l].parent = Some(me);
    nodes[r].parent = Some(me);
    me
}

/// 2-means partition of `idx` (original point ids); reorders `idx` so the
/// first cluster is the prefix, returns the split position.
fn split_two_means(ds: &Dataset, idx: &mut [usize], rng: &mut Rng) -> usize {
    let dim = ds.dim();
    let n = idx.len();
    // farthest-pair-ish seeding: random point a, c0 = farthest from a,
    // c1 = farthest from c0 (two cheap sweeps).
    let a = idx[rng.below(n)];
    let c0_id = idx
        .iter()
        .copied()
        .max_by(|&i, &j| {
            let di = ds.x.dist2_rows(i, &ds.x, a);
            let dj = ds.x.dist2_rows(j, &ds.x, a);
            di.partial_cmp(&dj).unwrap()
        })
        .unwrap();
    let c1_id = idx
        .iter()
        .copied()
        .max_by(|&i, &j| {
            let di = ds.x.dist2_rows(i, &ds.x, c0_id);
            let dj = ds.x.dist2_rows(j, &ds.x, c0_id);
            di.partial_cmp(&dj).unwrap()
        })
        .unwrap();
    let row_vec = |i: usize| -> Vec<f64> {
        let mut v = vec![0.0; dim];
        ds.x.add_row_scaled(i, 1.0, &mut v);
        v
    };
    let mut c0: Vec<f64> = row_vec(c0_id);
    let mut c1: Vec<f64> = row_vec(c1_id);
    let mut assign = vec![false; n]; // true → cluster 1

    for _iter in 0..8 {
        let mut changed = false;
        for (t, &i) in idx.iter().enumerate() {
            let d0 = ds.x.dist2_dense_vec(i, &c0);
            let d1 = ds.x.dist2_dense_vec(i, &c1);
            let a1 = d1 < d0;
            if a1 != assign[t] {
                assign[t] = a1;
                changed = true;
            }
        }
        // recompute centers
        let mut n0 = 0usize;
        let mut n1 = 0usize;
        let mut s0 = vec![0.0; dim];
        let mut s1 = vec![0.0; dim];
        for (t, &i) in idx.iter().enumerate() {
            if assign[t] {
                n1 += 1;
                ds.x.add_row_scaled(i, 1.0, &mut s1);
            } else {
                n0 += 1;
                ds.x.add_row_scaled(i, 1.0, &mut s0);
            }
        }
        if n0 == 0 || n1 == 0 {
            break;
        }
        for v in &mut s0 {
            *v /= n0 as f64;
        }
        for v in &mut s1 {
            *v /= n1 as f64;
        }
        c0 = s0;
        c1 = s1;
        if !changed {
            break;
        }
    }
    // stable partition: cluster-0 prefix
    let mut reordered = Vec::with_capacity(n);
    let mut tail = Vec::new();
    for (t, &i) in idx.iter().enumerate() {
        if assign[t] {
            tail.push(i);
        } else {
            reordered.push(i);
        }
    }
    let split = reordered.len();
    reordered.extend(tail);
    idx.copy_from_slice(&reordered);
    split
}

/// PCA bisection: project onto the dominant covariance eigenvector
/// (power iteration) and split at the median projection.
fn split_pca(ds: &Dataset, idx: &mut [usize], rng: &mut Rng) -> usize {
    let dim = ds.dim();
    let n = idx.len();
    // mean
    let mut mean = vec![0.0; dim];
    for &i in idx.iter() {
        ds.x.add_row_scaled(i, 1.0, &mut mean);
    }
    for v in &mut mean {
        *v /= n as f64;
    }
    let sparse = ds.is_sparse();
    // power iteration on covariance implicitly: v ← Σ (x−m)(x−m)ᵀ v
    let mut v: Vec<f64> = (0..dim).map(|_| rng.gauss()).collect();
    let mut w = vec![0.0; dim];
    for _ in 0..12 {
        w.fill(0.0);
        if sparse {
            // sparse rows: proj through nnz dots, one dense mean
            // correction per sweep (w −= (Σ proj) · mean)
            let mv = blas::dot(&mean, &v);
            let mut psum = 0.0;
            for &i in idx.iter() {
                let proj = ds.x.dot_dense_vec(i, &v) - mv;
                ds.x.add_row_scaled(i, proj, &mut w);
                psum += proj;
            }
            blas::axpy(-psum, &mean, &mut w);
        } else {
            for &i in idx.iter() {
                let p = ds.point(i);
                let mut proj = 0.0;
                for j in 0..dim {
                    proj += (p[j] - mean[j]) * v[j];
                }
                for j in 0..dim {
                    w[j] += proj * (p[j] - mean[j]);
                }
            }
        }
        let nw = blas::nrm2(&w);
        if nw < 1e-300 {
            break; // all points identical
        }
        for (vj, wj) in v.iter_mut().zip(w.iter()) {
            *vj = wj / nw;
        }
    }
    // projections and median split
    let mean_v = blas::dot(&mean, &v);
    let mut proj: Vec<(f64, usize)> = idx
        .iter()
        .map(|&i| {
            let s = if sparse {
                ds.x.dot_dense_vec(i, &v) - mean_v
            } else {
                let p = ds.point(i);
                let mut s = 0.0;
                for j in 0..dim {
                    s += (p[j] - mean[j]) * v[j];
                }
                s
            };
            (s, i)
        })
        .collect();
    proj.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (t, &(_, i)) in proj.iter().enumerate() {
        idx[t] = i;
    }
    n / 2
}

/// Mean inter/intra cluster distance ratio at the top split — diagnostic
/// used by Figure 1 (right panel) to show the clustering quality.
pub fn top_split_separation(ds: &Dataset, tree: &ClusterTree) -> f64 {
    let root = &tree.nodes[tree.root()];
    let (Some(l), Some(r)) = (root.left, root.right) else {
        return 0.0;
    };
    let l = &tree.nodes[l];
    let r = &tree.nodes[r];
    let centroid = |begin: usize, end: usize| -> Vec<f64> {
        let mut c = vec![0.0; ds.dim()];
        for p in begin..end {
            ds.x.add_row_scaled(tree.perm[p], 1.0, &mut c);
        }
        for v in &mut c {
            *v /= (end - begin) as f64;
        }
        c
    };
    let cl = centroid(l.begin, l.end);
    let cr = centroid(r.begin, r.end);
    let inter = blas::dist2(&cl, &cr).sqrt();
    let spread = |begin: usize, end: usize, c: &[f64]| -> f64 {
        let mut s = 0.0;
        for p in begin..end {
            s += ds.x.dist2_dense_vec(tree.perm[p], c).sqrt();
        }
        s / (end - begin) as f64
    };
    let intra = 0.5 * (spread(l.begin, l.end, &cl) + spread(r.begin, r.end, &cr));
    if intra > 0.0 {
        inter / intra
    } else {
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn check_tree_invariants(tree: &ClusterTree, n: usize, leaf_size: usize) {
        // permutation is a bijection
        let mut seen = vec![false; n];
        for &p in &tree.perm {
            assert!(!seen[p], "duplicate in perm");
            seen[p] = true;
        }
        assert!(seen.iter().all(|&s| s));
        for (orig, &pos) in tree.iperm.iter().enumerate() {
            assert_eq!(tree.perm[pos], orig);
        }
        // postorder: children precede parent; ranges partition exactly
        for (i, node) in tree.nodes.iter().enumerate() {
            if let (Some(l), Some(r)) = (node.left, node.right) {
                assert!(l < i && r < i, "postorder violated");
                assert_eq!(tree.nodes[l].begin, node.begin);
                assert_eq!(tree.nodes[l].end, tree.nodes[r].begin);
                assert_eq!(tree.nodes[r].end, node.end);
                assert_eq!(tree.nodes[l].parent, Some(i));
            } else {
                assert!(node.len() <= leaf_size.max(3), "oversized leaf {}", node.len());
            }
        }
        // root covers everything
        let root = &tree.nodes[tree.root()];
        assert_eq!((root.begin, root.end), (0, n));
        // leaves tile 0..n
        let leaves = tree.leaves();
        let mut cursor = 0;
        for &l in &leaves {
            assert_eq!(tree.nodes[l].begin, cursor);
            cursor = tree.nodes[l].end;
        }
        assert_eq!(cursor, n);
    }

    #[test]
    fn invariants_hold_for_both_methods() {
        crate::util::testkit::check("cluster-invariants", 8, |rng, case| {
            let n = 10 + rng.below(400);
            let ds = synth::blobs(n, 1 + rng.below(6), 4, 0.2, rng);
            let leaf = 8 + rng.below(32);
            let method = if case % 2 == 0 { SplitMethod::TwoMeans } else { SplitMethod::Pca };
            let tree = ClusterTree::build(&ds, leaf, method, rng);
            check_tree_invariants(&tree, n, leaf);
        });
    }

    #[test]
    fn separates_two_obvious_blobs() {
        let mut rng = crate::util::prng::Rng::new(1);
        // two far-apart blobs along x
        let n = 200;
        let mut x = Mat::zeros(n, 2);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let right = i % 2 == 0;
            x[(i, 0)] = if right { 10.0 } else { -10.0 } + rng.gauss() * 0.1;
            x[(i, 1)] = rng.gauss() * 0.1;
            y[i] = if right { 1.0 } else { -1.0 };
        }
        let ds = Dataset::new("two", x, y);
        for method in [SplitMethod::TwoMeans, SplitMethod::Pca] {
            let tree = ClusterTree::build(&ds, 64, method, &mut rng);
            let root = &tree.nodes[tree.root()];
            let l = &tree.nodes[root.left.unwrap()];
            // left child must be pure one side
            let side0 = ds.point(tree.perm[l.begin])[0] > 0.0;
            for p in l.begin..l.end {
                assert_eq!(ds.point(tree.perm[p])[0] > 0.0, side0, "{method:?} split impure");
            }
            assert!(top_split_separation(&ds, &tree) > 5.0);
        }
    }

    #[test]
    fn identical_points_do_not_hang() {
        let mut rng = crate::util::prng::Rng::new(2);
        let x = Mat::zeros(100, 3);
        let y: Vec<f64> = (0..100).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let ds = Dataset::new("flat", x, y);
        for method in [SplitMethod::TwoMeans, SplitMethod::Pca] {
            let tree = ClusterTree::build(&ds, 16, method, &mut rng);
            check_tree_invariants(&tree, 100, 16);
        }
    }

    #[test]
    fn sparse_datasets_build_valid_trees() {
        let mut rng = crate::util::prng::Rng::new(5);
        let ds = synth::blobs(300, 6, 4, 0.3, &mut rng);
        let sp = Dataset::new(
            "sp",
            crate::data::CsrMat::from_dense(ds.x.dense()),
            ds.y.clone(),
        );
        assert!(sp.is_sparse());
        for method in [SplitMethod::TwoMeans, SplitMethod::Pca] {
            let tree = ClusterTree::build(&sp, 32, method, &mut rng);
            check_tree_invariants(&tree, 300, 32);
        }
        let tree = ClusterTree::build(&sp, 64, SplitMethod::TwoMeans, &mut rng);
        assert!(top_split_separation(&sp, &tree) >= 0.0);
    }

    #[test]
    fn depth_is_logarithmic() {
        let mut rng = crate::util::prng::Rng::new(3);
        let ds = synth::blobs(1024, 4, 6, 0.3, &mut rng);
        let tree = ClusterTree::build(&ds, 32, SplitMethod::TwoMeans, &mut rng);
        // balanced-ish: depth well below n/leaf
        assert!(tree.depth() <= 14, "depth {}", tree.depth());
    }

}
