//! Streaming prediction core (the `hss-svm serve` request loop),
//! extracted from the binary so the batching, label handling and error
//! paths are unit-testable — and shared verbatim by the concurrent TCP
//! server in [`crate::server`], so both front-ends have identical
//! batch-parse / label / error semantics.
//!
//! Protocol: LIBSVM-format lines on the input, one
//! `"<predicted label> <decision value>"` line per request on the
//! output. Lines may be labeled (`+1 1:0.5 ...` — the label is ignored),
//! carry the `0` placeholder label, or be bare feature lists
//! (`1:0.5 3:2 ...`). Requests are micro-batched ([`BATCH`] lines, one
//! prediction tile) for tile efficiency. Binary models answer in their
//! original label pair ([`SvmModel::label_text`]): `±1` for ±1-coded
//! training data, the original encoding (e.g. `1`/`2`) otherwise.
//! One-vs-one multiclass models ([`AnyModel::Ovo`]) answer
//! `"<class> <decision sum>"` — the original integer class label from
//! the training file plus the winning class's accumulated signed
//! decision-value sum (the vote tie-break key), computed through the
//! shared-SV engine: one kernel block per tile serves all pairs.
//!
//! Parsing goes through [`libsvm::read_features`], which skips binary-
//! label normalization entirely — a batch mixing `±1` labels with
//! unlabeled lines used to produce three distinct labels and trip
//! `libsvm::read`'s "not a binary dataset" bail, killing the server on
//! valid input. A malformed line fails only its own batch: the batch
//! is reparsed line-by-line ([`parse_batch`]) to report every offending
//! line — with its global input line number, carried natively by
//! [`libsvm::read_features_offset`] — on the error stream, no
//! predictions are emitted for that batch, and the loop continues with
//! the next one.

// No raw-pointer tricks belong in this module tree (see DESIGN.md §11).
#![forbid(unsafe_code)]

use crate::compute::ComputeBackend;
use crate::data::libsvm::{self, Repr};
use crate::data::sparse::Points;
use crate::svm::{predict, AnyModel, SvmModel};
use anyhow::{Context, Result};
use std::io::{BufRead, Write};

/// Lines per micro-batch (one prediction tile).
pub const BATCH: usize = 128;

/// Counters reported when the input is exhausted.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Micro-batches attempted.
    pub batches: usize,
    /// Request lines consumed (blank and `#`-comment lines are not
    /// requests — they are counted in `skipped`).
    pub lines: usize,
    /// Blank / comment input lines skipped.
    pub skipped: usize,
    /// Predictions emitted.
    pub predicted: usize,
    /// Batches dropped because of malformed lines.
    pub failed_batches: usize,
}

/// Parse one micro-batch of request lines (`(global 1-based line
/// number, text)`) into a feature block of dimension `dim`, CSR when
/// `sparse` (callers pass the model's dimension and representation —
/// [`AnyModel::dim`] / [`AnyModel::is_sparse`]).
///
/// The tile representation follows the MODEL, not the tile's own
/// density: `Repr::Auto` would let the (interleaving-dependent) batch
/// composition flip a dim ≥ 32 tile between CSR and dense — paths that
/// agree only to ≤ 1e-12 — and perturb low-order decision bits between
/// runs. Pinning it makes every line's decision independent of its
/// tile, and bitwise-equal to offline `predict` under the matching
/// `--sparse`/`--dense` choice.
///
/// On failure the batch is re-parsed line-by-line and every offending
/// line is returned as `(index into the batch slice, error message)`;
/// the message carries the line's global input number natively (the
/// single line is parsed with [`libsvm::read_features_offset`] at
/// offset `number − 1`), so callers never rewrite parser output.
pub fn parse_batch(
    lines: &[(usize, &str)],
    dim: usize,
    sparse: bool,
) -> std::result::Result<Points, Vec<(usize, String)>> {
    let repr = if sparse { Repr::Sparse } else { Repr::Dense };
    let text = lines.iter().map(|(_, l)| *l).collect::<Vec<_>>().join("\n");
    if let Ok((x, _labels)) =
        libsvm::read_features_with(std::io::Cursor::new(text), Some(dim), repr)
    {
        return Ok(x);
    }
    let mut bad = Vec::new();
    for (i, (no, l)) in lines.iter().enumerate() {
        if let Err(e) = libsvm::read_features_offset(std::io::Cursor::new(*l), Some(dim), no - 1) {
            bad.push((i, format!("{e:#}")));
        }
    }
    if bad.is_empty() {
        // joined parse failed but every line parses alone — should be
        // impossible for line-oriented input; fail the batch visibly
        bad.push((0, format!("line {}: batch failed to parse", lines[0].0)));
    }
    Err(bad)
}

/// Decision values for one parsed batch on the selected compute
/// backend (`None` = the bitwise CPU reference path — identical to
/// offline `predict`). A PJRT backend falls back to the CPU reference
/// tile-by-tile on runtime errors (see [`crate::runtime`]), so a tile
/// failure never kills the server.
pub fn batch_decisions(
    model: &SvmModel,
    backend: Option<&dyn ComputeBackend>,
    x: &Points,
    threads: usize,
) -> Vec<f64> {
    match backend {
        Some(b) => predict::decision_function_with(b, model, x, threads),
        None => predict::decision_function(model, x, threads),
    }
}

/// One response line for a decision value: `"<label> <decision>"`, the
/// label mapped back through the model's original label pair.
pub fn format_prediction(model: &SvmModel, v: f64) -> String {
    format!("{} {v:.6}", model.label_text(v))
}

/// Response lines for one parsed tile, generic over model arity — the
/// single prediction core behind both serving front-ends (stdin loop
/// and the TCP batcher):
///
/// * binary — [`batch_decisions`] on the selected backend, formatted
///   by [`format_prediction`];
/// * one-vs-one — the shared-SV engine's class label + winning
///   decision sum, `"<class> <sum>"`, with the tile kernel block run
///   on the selected backend.
pub fn predict_lines(
    model: &AnyModel,
    backend: Option<&dyn ComputeBackend>,
    x: &Points,
    threads: usize,
) -> Vec<String> {
    match model {
        AnyModel::Binary(m) => batch_decisions(m, backend, x, threads)
            .into_iter()
            .map(|v| format_prediction(m, v))
            .collect(),
        AnyModel::Ovo(m) => {
            let scores = match backend {
                Some(b) => m.engine().predict_with_scores_with(b, x, threads),
                None => m.engine().predict_with_scores(x, threads),
            };
            scores.into_iter().map(|(class, sum)| format!("{class} {sum:.6}")).collect()
        }
    }
}

/// Run the request loop until EOF. Returns the counters; parse failures
/// are per-batch (reported on `err`), only I/O failures abort the loop.
pub fn serve_loop(
    model: &AnyModel,
    backend: Option<&dyn ComputeBackend>,
    input: impl BufRead,
    mut out: impl Write,
    mut err: impl Write,
    threads: usize,
) -> Result<ServeStats> {
    let mut stats = ServeStats::default();
    let mut batch: Vec<(usize, String)> = Vec::new(); // (1-based line no, text)
    let mut lines = input.lines();
    let mut lineno = 0usize;
    loop {
        batch.clear();
        // micro-batch: drain up to BATCH request lines (one tile).
        // Blank and '#'-comment lines are not requests: the parser
        // would silently drop them mid-batch and desynchronize the
        // one-output-line-per-request protocol, so skip them here.
        for line in lines.by_ref() {
            let line = line.context("I/O error reading serve input")?;
            lineno += 1;
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                stats.skipped += 1;
            } else {
                batch.push((lineno, line));
            }
            if batch.len() >= BATCH {
                break;
            }
        }
        if batch.is_empty() {
            break;
        }
        stats.batches += 1;
        stats.lines += batch.len();
        let refs: Vec<(usize, &str)> = batch.iter().map(|(no, l)| (*no, l.as_str())).collect();
        match parse_batch(&refs, model.dim(), model.is_sparse()) {
            Ok(x) => {
                let responses = predict_lines(model, backend, &x, threads);
                for line in &responses {
                    writeln!(out, "{line}")?;
                }
                out.flush()?;
                stats.predicted += responses.len();
            }
            Err(bad) => {
                // fail this batch only: every bad line is reported with
                // its global input line number, no predictions are
                // emitted, the loop keeps serving
                stats.failed_batches += 1;
                for (_, msg) in &bad {
                    writeln!(err, "serve: input {msg} (batch dropped)")?;
                }
                err.flush()?;
            }
        }
        if batch.len() < BATCH {
            break; // input exhausted
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DEFAULT_LABEL_PAIR;
    use crate::kernel::Kernel;
    use crate::linalg::Mat;
    use crate::util::prng::Rng;

    fn toy(rng: &mut Rng, dim: usize) -> SvmModel {
        SvmModel {
            sv: Mat::gauss(4, dim, rng).into(),
            alpha_y: (0..4).map(|_| rng.gauss()).collect(),
            bias: rng.gauss(),
            kernel: Kernel::Gaussian { h: 0.8 },
            c: 1.0,
            labels: DEFAULT_LABEL_PAIR,
        }
    }

    #[test]
    fn skipped_lines_are_counted_separately() {
        let mut rng = Rng::new(21);
        let model = AnyModel::Binary(toy(&mut rng, 4));
        let input = "# ping\n\n1:0.5\n   \n2:0.25\n# pong\n";
        let mut out = Vec::new();
        let stats = serve_loop(
            &model,
            None,
            std::io::Cursor::new(input),
            &mut out,
            std::io::sink(),
            1,
        )
        .unwrap();
        assert_eq!(stats.lines, 2);
        assert_eq!(stats.skipped, 4);
        assert_eq!(stats.predicted, 2);
    }

    #[test]
    fn parse_batch_attributes_errors_by_index_with_global_numbers() {
        let lines: Vec<(usize, &str)> = vec![
            (7, "1:0.5 2:1.0"),
            (9, "+1 2:2 2:3"), // duplicate index
            (12, "1:abc"),     // bad value
        ];
        let bad = parse_batch(&lines, 4, false).unwrap_err();
        assert_eq!(bad.len(), 2);
        assert_eq!(bad[0].0, 1);
        assert!(bad[0].1.contains("line 9"), "{}", bad[0].1);
        assert_eq!(bad[1].0, 2);
        assert!(bad[1].1.contains("line 12"), "{}", bad[1].1);
        // clean batch parses to the right shape, in the MODEL's
        // representation (dense model => dense tile, sparse => CSR)
        let x = parse_batch(&lines[..1], 4, false).unwrap();
        assert_eq!((x.rows(), x.cols()), (1, 4));
        assert!(!x.is_sparse());
        assert!(parse_batch(&lines[..1], 4, true).unwrap().is_sparse());
    }

    #[test]
    fn predict_lines_serves_ovo_models_with_original_labels() {
        use crate::svm::OvoModel;
        // constant-decision pairs over classes {2, 5, 9}: f25 = +1,
        // f29 = +1, f59 = −1 → class 2 gets 2 votes everywhere
        let pair = |a: i64, b: i64, bias: f64| {
            (
                a,
                b,
                SvmModel {
                    sv: Mat::from_vec(1, 3, vec![1.0, 0.0, -1.0]).into(),
                    alpha_y: vec![0.0],
                    bias,
                    kernel: Kernel::Linear,
                    c: 1.0,
                    labels: DEFAULT_LABEL_PAIR,
                },
            )
        };
        let ovo = AnyModel::Ovo(OvoModel::new(
            vec![pair(2, 5, 1.0), pair(2, 9, 1.0), pair(5, 9, -1.0)],
            1.0,
        ));
        assert_eq!(ovo.dim(), 3);
        let x = parse_batch(&[(1, "1:0.5"), (2, "+1 2:1.0 3:2.0")], ovo.dim(), ovo.is_sparse())
            .unwrap();
        let lines = predict_lines(&ovo, None, &x, 1);
        assert_eq!(lines.len(), 2);
        for l in &lines {
            // sums: class 2 = f25 + f29 = 2.0 (the winner's sum)
            assert_eq!(l, "2 2.000000");
        }
        // the stdin loop carries the same payload end-to-end
        let mut out = Vec::new();
        let stats = serve_loop(
            &ovo,
            None,
            std::io::Cursor::new("1:0.5\n# skip\n2:1.0\n"),
            &mut out,
            std::io::sink(),
            1,
        )
        .unwrap();
        assert_eq!(stats.predicted, 2);
        let text = String::from_utf8(out).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().all(|l| l.starts_with("2 ")), "{text}");
    }

    #[test]
    fn format_prediction_maps_label_pairs() {
        let mut rng = Rng::new(22);
        let mut model = toy(&mut rng, 3);
        assert_eq!(format_prediction(&model, 0.5), "+1 0.500000");
        assert_eq!(format_prediction(&model, -0.5), "-1 -0.500000");
        model.labels = [1.0, 2.0];
        assert_eq!(format_prediction(&model, 0.5), "2 0.500000");
        assert_eq!(format_prediction(&model, -0.5), "1 -0.500000");
    }
}
