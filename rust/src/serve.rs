//! Streaming prediction server (the `hss-svm serve` request loop),
//! extracted from the binary so the batching, label handling and error
//! paths are unit-testable.
//!
//! Protocol: LIBSVM-format lines on the input, one
//! `"<predicted label> <decision value>"` line per request on the
//! output. Lines may be labeled (`+1 1:0.5 ...` — the label is ignored),
//! carry the `0` placeholder label, or be bare feature lists
//! (`1:0.5 3:2 ...`). Requests are micro-batched ([`BATCH`] lines, one
//! prediction tile) for tile efficiency.
//!
//! Parsing goes through [`libsvm::read_features`], which skips binary-
//! label normalization entirely — a batch mixing `±1` labels with
//! unlabeled lines used to produce three distinct labels and trip
//! `libsvm::read`'s "not a binary dataset" bail, killing the server on
//! valid input. A malformed line now fails only its own batch: the batch
//! is reparsed line-by-line to report every offending line (with its
//! global input line number) on the error stream, no predictions are
//! emitted for that batch, and the loop continues with the next one.

use crate::data::libsvm;
use crate::runtime::PjrtRuntime;
use crate::svm::{predict, SvmModel};
use anyhow::{Context, Result};
use std::io::{BufRead, Write};

/// Lines per micro-batch (one prediction tile).
pub const BATCH: usize = 128;

/// Counters reported when the input is exhausted.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Micro-batches attempted.
    pub batches: usize,
    /// Non-empty input lines consumed.
    pub lines: usize,
    /// Predictions emitted.
    pub predicted: usize,
    /// Batches dropped because of malformed lines.
    pub failed_batches: usize,
}

/// Run the request loop until EOF. Returns the counters; parse failures
/// are per-batch (reported on `err`), only I/O failures abort the loop.
pub fn serve_loop(
    model: &SvmModel,
    rt: Option<&PjrtRuntime>,
    input: impl BufRead,
    mut out: impl Write,
    mut err: impl Write,
    threads: usize,
) -> Result<ServeStats> {
    let dim = model.sv.cols();
    let mut stats = ServeStats::default();
    let mut batch: Vec<(usize, String)> = Vec::new(); // (1-based line no, text)
    let mut lines = input.lines();
    let mut lineno = 0usize;
    loop {
        batch.clear();
        // micro-batch: drain up to BATCH request lines (one tile).
        // Blank and '#'-comment lines are not requests: the parser
        // would silently drop them mid-batch and desynchronize the
        // one-output-line-per-request protocol, so skip them here.
        for line in lines.by_ref() {
            let line = line.context("I/O error reading serve input")?;
            lineno += 1;
            let t = line.trim();
            if !t.is_empty() && !t.starts_with('#') {
                batch.push((lineno, line));
            }
            if batch.len() >= BATCH {
                break;
            }
        }
        if batch.is_empty() {
            break;
        }
        stats.batches += 1;
        stats.lines += batch.len();
        let text = batch.iter().map(|(_, l)| l.as_str()).collect::<Vec<_>>().join("\n");
        match libsvm::read_features(std::io::Cursor::new(text), Some(dim)) {
            Ok((x, _labels)) => {
                // a PJRT tile failure must not kill the server either:
                // fall back to the native path for this batch
                let f = match rt {
                    Some(rt) => match crate::runtime::decision_function_pjrt(rt, model, &x) {
                        Ok(f) => f,
                        Err(e) => {
                            writeln!(err, "serve: PJRT batch failed ({e:#}); native fallback")?;
                            predict::decision_function(model, &x, threads)
                        }
                    },
                    None => predict::decision_function(model, &x, threads),
                };
                for v in &f {
                    writeln!(out, "{} {v:.6}", if *v >= 0.0 { "+1" } else { "-1" })?;
                }
                out.flush()?;
                stats.predicted += f.len();
            }
            Err(_) => {
                // fail this batch only: pinpoint every bad line with its
                // global input line number, emit nothing, keep serving
                stats.failed_batches += 1;
                for (no, l) in &batch {
                    if let Err(e) =
                        libsvm::read_features(std::io::Cursor::new(l.as_str()), Some(dim))
                    {
                        // strip the parser's batch-relative "line 1:" prefix
                        let msg = format!("{e:#}").replace("line 1:", "").trim().to_string();
                        writeln!(err, "serve: input line {no}: {msg} (batch dropped)")?;
                    }
                }
                err.flush()?;
            }
        }
        if batch.len() < BATCH {
            break; // input exhausted
        }
    }
    Ok(stats)
}
