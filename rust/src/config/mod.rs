//! Minimal TOML-subset configuration parser (no serde in the offline
//! crate set). Supports the subset experiment configs need: `[sections]`,
//! `key = value` with strings, numbers, booleans, and flat arrays, plus
//! `#` comments.

// No raw-pointer tricks belong in this module tree (see DESIGN.md §11).
#![forbid(unsafe_code)]

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64_array(&self) -> Option<Vec<f64>> {
        match self {
            Value::Array(xs) => xs.iter().map(|x| x.as_f64()).collect(),
            _ => None,
        }
    }

    pub fn as_str_array(&self) -> Option<Vec<String>> {
        match self {
            Value::Array(xs) => {
                xs.iter().map(|x| x.as_str().map(|s| s.to_string())).collect()
            }
            _ => None,
        }
    }
}

/// Parsed config: `section.key` → value (top-level keys use section "").
#[derive(Clone, Debug, Default)]
pub struct Config {
    entries: BTreeMap<(String, String), Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: unterminated section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value {v:?}", lineno + 1))?;
            entries.insert((section.clone(), k.trim().to_string()), value);
        }
        Ok(Config { entries })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("cannot read {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(Value::as_str)
            .map(|s| s.to_string())
            .unwrap_or_else(|| default.to_string())
    }

    pub fn sections(&self) -> Vec<String> {
        let mut s: Vec<String> = self.entries.keys().map(|(sec, _)| sec.clone()).collect();
        s.dedup();
        s
    }
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: `#` outside quotes starts a comment
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<Value> {
    if v.starts_with('"') && v.ends_with('"') && v.len() >= 2 {
        return Ok(Value::Str(v[1..v.len() - 1].to_string()));
    }
    if v == "true" {
        return Ok(Value::Bool(true));
    }
    if v == "false" {
        return Ok(Value::Bool(false));
    }
    if v.starts_with('[') && v.ends_with(']') {
        let inner = &v[1..v.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Ok(n) = v.parse::<f64>() {
        return Ok(Value::Num(n));
    }
    bail!("cannot parse value: {v}")
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, ch) in s.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_featured_config() {
        let text = r#"
# experiment configuration
name = "table4"
scale = 0.02

[hss]
rel_tol = 1.0
abs_tol = 0.1          # STRUMPACK hss_abs_tol
max_rank = 200
split = "kmeans"

[grid]
h_values = [0.1, 1, 10]
c_values = [0.1, 1, 10]
datasets = ["a8a", "ijcnn1"]
run_smo = true
"#;
        let cfg = Config::parse(text).unwrap();
        assert_eq!(cfg.str_or("", "name", "?"), "table4");
        assert_eq!(cfg.f64_or("", "scale", 0.0), 0.02);
        assert_eq!(cfg.f64_or("hss", "rel_tol", 0.0), 1.0);
        assert_eq!(cfg.usize_or("hss", "max_rank", 0), 200);
        assert_eq!(cfg.str_or("hss", "split", "?"), "kmeans");
        assert_eq!(
            cfg.get("grid", "h_values").unwrap().as_f64_array().unwrap(),
            vec![0.1, 1.0, 10.0]
        );
        assert_eq!(
            cfg.get("grid", "datasets").unwrap().as_str_array().unwrap(),
            vec!["a8a", "ijcnn1"]
        );
        assert!(cfg.bool_or("grid", "run_smo", false));
        assert!(!cfg.bool_or("grid", "run_racqp", false));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::parse("[oops").is_err());
        assert!(Config::parse("key value").is_err());
        assert!(Config::parse("key = @nope").is_err());
    }

    #[test]
    fn comments_inside_strings_are_kept() {
        let cfg = Config::parse("k = \"a # b\"").unwrap();
        assert_eq!(cfg.str_or("", "k", ""), "a # b");
    }

    #[test]
    fn usize_rejects_negative_and_fractional() {
        let cfg = Config::parse("a = -3\nb = 1.5\nc = 7").unwrap();
        assert_eq!(cfg.get("", "a").unwrap().as_usize(), None);
        assert_eq!(cfg.get("", "b").unwrap().as_usize(), None);
        assert_eq!(cfg.get("", "c").unwrap().as_usize(), Some(7));
    }
}
