//! BLAS-like numerical kernels over [`Mat`] and `f64` slices.
//!
//! The gemm is a cache-blocked triple loop with an unrolled 4-wide
//! micro-kernel over packed panels; it reaches a few GFLOP/s single-core
//! which is enough to make the dense baselines honest. The hot SVM path
//! itself avoids big gemms by design (that is the paper's point).

use crate::linalg::matrix::Mat;
use crate::util::threadpool;

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4 independent accumulators to expose ILP.
    let mut s = [0.0f64; 4];
    let chunks = a.len() / 4;
    for k in 0..chunks {
        let i = 4 * k;
        s[0] += a[i] * b[i];
        s[1] += a[i + 1] * b[i + 1];
        s[2] += a[i + 2] * b[i + 2];
        s[3] += a[i + 3] * b[i + 3];
    }
    let mut tail = 0.0;
    for i in 4 * chunks..a.len() {
        tail += a[i] * b[i];
    }
    s[0] + s[1] + s[2] + s[3] + tail
}

/// y += a * x
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Squared euclidean distance between two vectors.
#[inline]
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Fast exp for non-positive arguments (the Gaussian kernel exponent is
/// always ≤ 0). Range reduction x = k·ln2 + t with |t| ≤ ln2/2, then a
/// degree-7 Taylor for eᵗ and an exponent-bits 2ᵏ. Relative error
/// ≤ ~5e-9 — far below the f32 precision of the PJRT artifacts, and
/// ~2-3× faster than libm exp (§Perf: kernel_block small-f).
#[inline]
pub fn exp_neg(x: f64) -> f64 {
    debug_assert!(x <= 0.0);
    if x < -708.0 {
        return 0.0; // exp underflows (kernel entry is exactly 0 in f64)
    }
    const LOG2E: f64 = std::f64::consts::LOG2_E;
    const LN2_HI: f64 = 0.693_147_180_369_123_816_49;
    const LN2_LO: f64 = 1.908_214_929_270_587_700_02e-10;
    let kf = (x * LOG2E).round();
    let k = kf as i64;
    // two-part ln2 keeps t accurate after cancellation
    let t = (x - kf * LN2_HI) - kf * LN2_LO;
    // e^t, |t| ≤ 0.3466: degree-7 Taylor (Horner), rel err < 6e-10
    let p = 1.0
        + t * (1.0
            + t * (0.5
                + t * (1.0 / 6.0
                    + t * (1.0 / 24.0
                        + t * (1.0 / 120.0 + t * (1.0 / 720.0 + t * (1.0 / 5040.0)))))));
    // 2^k via exponent bits; the underflow guard above ensures
    // k ∈ [-1022, 0], which is always a normal exponent.
    let two_k = f64::from_bits(((k + 1023) as u64) << 52);
    p * two_k
}

/// y = A x (A row-major) — each output row is a dot product.
pub fn gemv(a: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.cols(), x.len());
    assert_eq!(a.rows(), y.len());
    for i in 0..a.rows() {
        y[i] = dot(a.row(i), x);
    }
}

/// y = Aᵀ x without forming Aᵀ.
pub fn gemv_t(a: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.rows(), x.len());
    assert_eq!(a.cols(), y.len());
    y.fill(0.0);
    for i in 0..a.rows() {
        axpy(x[i], a.row(i), y);
    }
}

/// Operand side transpose marker for [`gemm`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Trans {
    No,
    Yes,
}

/// C = alpha * op(A) op(B) + beta * C.
///
/// Cache-blocked with panel packing; single-threaded (callers parallelize
/// across independent blocks — see `gemm_par`).
pub fn gemm(alpha: f64, a: &Mat, ta: Trans, b: &Mat, tb: Trans, beta: f64, c: &mut Mat) {
    let (m, k1) = if ta == Trans::No { a.shape() } else { (a.cols(), a.rows()) };
    let (k2, n) = if tb == Trans::No { b.shape() } else { (b.cols(), b.rows()) };
    assert_eq!(k1, k2, "gemm inner dimension mismatch");
    assert_eq!(c.shape(), (m, n), "gemm output shape mismatch");
    let k = k1;

    if beta != 1.0 {
        if beta == 0.0 {
            c.data_mut().fill(0.0);
        } else {
            c.scale(beta);
        }
    }
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return;
    }

    // Pack op(A) row-major and op(B) column-panels to make the inner loop
    // stride-1 on both operands.
    //
    // INVARIANT (the batched multi-RHS solvers rely on it): the value of
    // C[i, j] is produced by a fixed sequence of floating-point ops that
    // depends only on row i of op(A), column j of op(B) and the KC depth
    // blocking — never on m or n. Every path below (4×4 micro-kernel and
    // both remainder loops) therefore accumulates its panel contribution
    // with the same single sequential accumulator over p, so adding or
    // removing other RHS columns cannot perturb a column's result.
    const MC: usize = 64; // rows of A per block
    const KC: usize = 256; // depth per block
    const NC: usize = 128; // cols of B per block

    // Right-size the packing buffers: a fixed MC·KC + KC·NC allocation
    // (384 KB zeroed) dwarfs the arithmetic of the small blocked solves
    // in the ULV sweeps (§Perf: dominant cost of 1-RHS gemm delegation).
    let mut a_pack = vec![0.0f64; MC.min(m) * KC.min(k)];
    let mut b_pack = vec![0.0f64; KC.min(k) * NC.min(n)];

    for p0 in (0..k).step_by(KC) {
        let pb = KC.min(k - p0);
        for j0 in (0..n).step_by(NC) {
            let jb = NC.min(n - j0);
            // pack B block: b_pack[jj*pb + pp] = op(B)[p0+pp, j0+jj]
            for jj in 0..jb {
                for pp in 0..pb {
                    let v = match tb {
                        Trans::No => b[(p0 + pp, j0 + jj)],
                        Trans::Yes => b[(j0 + jj, p0 + pp)],
                    };
                    b_pack[jj * pb + pp] = v;
                }
            }
            for i0 in (0..m).step_by(MC) {
                let ib = MC.min(m - i0);
                // pack A block: a_pack[ii*pb + pp] = op(A)[i0+ii, p0+pp]
                for ii in 0..ib {
                    match ta {
                        Trans::No => {
                            let src = &a.row(i0 + ii)[p0..p0 + pb];
                            a_pack[ii * pb..ii * pb + pb].copy_from_slice(src);
                        }
                        Trans::Yes => {
                            for pp in 0..pb {
                                a_pack[ii * pb + pp] = a[(p0 + pp, i0 + ii)];
                            }
                        }
                    }
                }
                // 4×4 register-tiled micro-kernel: 16 independent
                // accumulators per (ii, jj) tile keep the FMA pipeline
                // busy and reuse each load 4×. (§Perf: 2.4× over the
                // dot-per-cell kernel at 512³.)
                let mut ii = 0;
                while ii + 4 <= ib {
                    let a0 = &a_pack[ii * pb..(ii + 1) * pb];
                    let a1 = &a_pack[(ii + 1) * pb..(ii + 2) * pb];
                    let a2 = &a_pack[(ii + 2) * pb..(ii + 3) * pb];
                    let a3 = &a_pack[(ii + 3) * pb..(ii + 4) * pb];
                    let mut jj = 0;
                    while jj + 4 <= jb {
                        let b0 = &b_pack[jj * pb..(jj + 1) * pb];
                        let b1 = &b_pack[(jj + 1) * pb..(jj + 2) * pb];
                        let b2 = &b_pack[(jj + 2) * pb..(jj + 3) * pb];
                        let b3 = &b_pack[(jj + 3) * pb..(jj + 4) * pb];
                        let mut acc = [[0.0f64; 4]; 4];
                        for p in 0..pb {
                            let av = [a0[p], a1[p], a2[p], a3[p]];
                            let bv = [b0[p], b1[p], b2[p], b3[p]];
                            for (r, &a) in av.iter().enumerate() {
                                for (s, &b) in bv.iter().enumerate() {
                                    acc[r][s] += a * b;
                                }
                            }
                        }
                        for r in 0..4 {
                            let crow = c.row_mut(i0 + ii + r);
                            for s in 0..4 {
                                crow[j0 + jj + s] += alpha * acc[r][s];
                            }
                        }
                        jj += 4;
                    }
                    // jb remainder — sequential accumulation, matching
                    // the micro-kernel's per-entry op order exactly
                    while jj < jb {
                        let bcol = &b_pack[jj * pb..jj * pb + pb];
                        for (r, arow) in [a0, a1, a2, a3].into_iter().enumerate() {
                            let mut acc = 0.0;
                            for (&a, &b) in arow.iter().zip(bcol.iter()) {
                                acc += a * b;
                            }
                            c.row_mut(i0 + ii + r)[j0 + jj] += alpha * acc;
                        }
                        jj += 1;
                    }
                    ii += 4;
                }
                // ib remainder — same sequential accumulation
                while ii < ib {
                    let arow = &a_pack[ii * pb..ii * pb + pb];
                    let crow = c.row_mut(i0 + ii);
                    for jj in 0..jb {
                        let bcol = &b_pack[jj * pb..jj * pb + pb];
                        let mut acc = 0.0;
                        for (&a, &b) in arow.iter().zip(bcol.iter()) {
                            acc += a * b;
                        }
                        crow[j0 + jj] += alpha * acc;
                    }
                    ii += 1;
                }
            }
        }
    }
}

/// Convenience: allocate and return op(A)·op(B).
pub fn matmul(a: &Mat, ta: Trans, b: &Mat, tb: Trans) -> Mat {
    let m = if ta == Trans::No { a.rows() } else { a.cols() };
    let n = if tb == Trans::No { b.cols() } else { b.rows() };
    let mut c = Mat::zeros(m, n);
    gemm(1.0, a, ta, b, tb, 0.0, &mut c);
    c
}

/// Multi-threaded matmul: splits rows of the output across threads.
pub fn matmul_par(threads: usize, a: &Mat, ta: Trans, b: &Mat, tb: Trans) -> Mat {
    let m = if ta == Trans::No { a.rows() } else { a.cols() };
    let n = if tb == Trans::No { b.cols() } else { b.rows() };
    let threads = threads.max(1);
    if threads == 1 || m < 128 {
        return matmul(a, ta, b, tb);
    }
    let band = m.div_ceil(threads);
    let bands: Vec<Mat> = threadpool::parallel_map(threads, threads, 1, |t| {
        let r0 = t * band;
        if r0 >= m {
            return Mat::zeros(0, n);
        }
        let nr = band.min(m - r0);
        // extract the row band of op(A)
        let a_band = match ta {
            Trans::No => a.block(r0, 0, nr, a.cols()),
            Trans::Yes => {
                // rows of op(A) are columns of A
                let idx: Vec<usize> = (r0..r0 + nr).collect();
                a.select_cols(&idx).transpose()
            }
        };
        matmul(&a_band, Trans::No, b, tb)
    });
    let mut c = Mat::zeros(m, n);
    let mut r = 0;
    for bnd in bands {
        if bnd.rows() > 0 {
            c.set_block(r, 0, &bnd);
            r += bnd.rows();
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::testkit;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for p in 0..a.cols() {
                    s += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn exp_neg_matches_std_exp() {
        testkit::check("exp-neg", 30, |rng, _| {
            for _ in 0..200 {
                let x = -rng.f64() * 80.0; // typical Gaussian-kernel range
                let got = exp_neg(x);
                let want = x.exp();
                let rel = (got - want).abs() / want.max(1e-300);
                assert!(rel < 1e-8, "exp_neg({x}) rel err {rel}");
            }
        });
        // edges
        assert_eq!(exp_neg(0.0), 1.0);
        assert_eq!(exp_neg(-1000.0), 0.0);
        let near = exp_neg(-707.9);
        assert!(near > 0.0 && near < 1e-300);
    }

    #[test]
    fn dot_axpy_nrm2() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&a, &b), 35.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [7.0, 8.0, 9.0, 10.0, 11.0]);
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert_eq!(dist2(&[1.0, 1.0], &[4.0, 5.0]), 25.0);
    }

    #[test]
    fn gemv_and_transpose() {
        let a = Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f64);
        let x = [1.0, 2.0];
        let mut y = [0.0; 3];
        gemv(&a, &x, &mut y);
        assert_eq!(y, [2.0, 8.0, 14.0]);
        let xt = [1.0, 1.0, 1.0];
        let mut yt = [0.0; 2];
        gemv_t(&a, &xt, &mut yt);
        assert_eq!(yt, [6.0, 9.0]);
    }

    #[test]
    fn gemm_matches_naive_all_transposes() {
        testkit::check("gemm-vs-naive", 20, |rng, _| {
            let m = 1 + rng.below(40);
            let k = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let a = Mat::gauss(m, k, rng);
            let b = Mat::gauss(k, n, rng);
            let want = naive_matmul(&a, &b);

            let got = matmul(&a, Trans::No, &b, Trans::No);
            testkit::assert_allclose(got.data(), want.data(), 1e-11);

            let got_t = matmul(&a.transpose(), Trans::Yes, &b, Trans::No);
            testkit::assert_allclose(got_t.data(), want.data(), 1e-11);

            let got_bt = matmul(&a, Trans::No, &b.transpose(), Trans::Yes);
            testkit::assert_allclose(got_bt.data(), want.data(), 1e-11);

            let got_both = matmul(&a.transpose(), Trans::Yes, &b.transpose(), Trans::Yes);
            testkit::assert_allclose(got_both.data(), want.data(), 1e-11);
        });
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut rng = Rng::new(1);
        let a = Mat::gauss(8, 8, &mut rng);
        let b = Mat::gauss(8, 8, &mut rng);
        let c0 = Mat::gauss(8, 8, &mut rng);
        let mut c = c0.clone();
        gemm(2.0, &a, Trans::No, &b, Trans::No, 3.0, &mut c);
        let mut want = naive_matmul(&a, &b);
        want.scale(2.0);
        let mut c0s = c0.clone();
        c0s.scale(3.0);
        want.axpy(1.0, &c0s);
        testkit::assert_allclose(c.data(), want.data(), 1e-11);
    }

    #[test]
    fn gemm_blocked_sizes_cross_boundaries() {
        // sizes straddling MC/KC/NC boundaries
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(65usize, 257usize, 129usize), (64, 256, 128), (1, 300, 1)] {
            let a = Mat::gauss(m, k, &mut rng);
            let b = Mat::gauss(k, n, &mut rng);
            let got = matmul(&a, Trans::No, &b, Trans::No);
            let want = naive_matmul(&a, &b);
            testkit::assert_allclose(got.data(), want.data(), 1e-10);
        }
    }

    #[test]
    fn gemm_columns_invariant_to_rhs_width() {
        // C[:, j] must be bitwise identical whether B carries 1 or many
        // columns — the batched multi-RHS solve stack depends on this.
        // Sizes straddle the MC/KC/NC blocking boundaries on purpose.
        let mut rng = Rng::new(5);
        for &(m, k) in &[(30usize, 40usize), (70, 300), (129, 513)] {
            let a = Mat::gauss(m, k, &mut rng);
            let b = Mat::gauss(k, 9, &mut rng);
            let full = matmul(&a, Trans::No, &b, Trans::No);
            for j in 0..b.cols() {
                let bj = b.select_cols(&[j]);
                let single = matmul(&a, Trans::No, &bj, Trans::No);
                assert_eq!(full.col(j), single.col(0), "column {j} differs at m={m} k={k}");
            }
        }
    }

    #[test]
    fn matmul_par_matches_serial() {
        let mut rng = Rng::new(3);
        let a = Mat::gauss(300, 50, &mut rng);
        let b = Mat::gauss(50, 70, &mut rng);
        let serial = matmul(&a, Trans::No, &b, Trans::No);
        let par = matmul_par(4, &a, Trans::No, &b, Trans::No);
        testkit::assert_allclose(par.data(), serial.data(), 1e-12);
        // transposed-A path
        let at = a.transpose();
        let par_t = matmul_par(4, &at, Trans::Yes, &b, Trans::No);
        testkit::assert_allclose(par_t.data(), serial.data(), 1e-12);
    }
}
