//! LU factorization with partial pivoting — used by the ULV reduction
//! (the eliminated leading blocks are general square matrices, not SPD)
//! and the top-level dense solve of the HSS hierarchy.

use crate::linalg::matrix::Mat;

/// P A = L U with partial (row) pivoting.
pub struct Lu {
    lu: Mat,
    /// Row permutation: `perm[i]` = original row now at position i.
    perm: Vec<usize>,
    /// Sign of the permutation (for determinants).
    sign: f64,
}

/// Singular-matrix error.
#[derive(Debug, thiserror::Error)]
#[error("singular matrix at pivot {pivot} (|pivot| = {value:.3e})")]
pub struct Singular {
    pub pivot: usize,
    pub value: f64,
}

impl Lu {
    pub fn new(a: &Mat) -> Result<Self, Singular> {
        let n = a.rows();
        assert_eq!(a.rows(), a.cols(), "LU needs a square matrix");
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // pivot search in column k
            let mut pmax = k;
            let mut vmax = lu[(k, k)].abs();
            for i in k + 1..n {
                let v = lu[(i, k)].abs();
                if v > vmax {
                    vmax = v;
                    pmax = i;
                }
            }
            if vmax < 1e-300 {
                return Err(Singular { pivot: k, value: vmax });
            }
            if pmax != k {
                // swap rows
                for j in 0..n {
                    let t = lu[(k, j)];
                    lu[(k, j)] = lu[(pmax, j)];
                    lu[(pmax, j)] = t;
                }
                perm.swap(k, pmax);
                sign = -sign;
            }
            let inv = 1.0 / lu[(k, k)];
            for i in k + 1..n {
                let m = lu[(i, k)] * inv;
                lu[(i, k)] = m;
                if m != 0.0 {
                    // row update: lu[i, k+1..] -= m * lu[k, k+1..]
                    let (top, bot) = lu.data_mut().split_at_mut(i * n);
                    let row_k = &top[k * n + k + 1..k * n + n];
                    let row_i = &mut bot[k + 1..n];
                    for (ri, rk) in row_i.iter_mut().zip(row_k.iter()) {
                        *ri -= m * rk;
                    }
                }
            }
        }
        Ok(Lu { lu, perm, sign })
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows();
        assert_eq!(b.len(), n);
        // apply permutation
        let mut y: Vec<f64> = self.perm.iter().map(|&p| b[p]).collect();
        // forward L (unit diagonal)
        for i in 1..n {
            let row = self.lu.row(i);
            let mut s = y[i];
            for k in 0..i {
                s -= row[k] * y[k];
            }
            y[i] = s;
        }
        // backward U
        for i in (0..n).rev() {
            let row = self.lu.row(i);
            let mut s = y[i];
            for k in i + 1..n {
                s -= row[k] * y[k];
            }
            y[i] = s / row[i];
        }
        y
    }

    /// Solve for a matrix RHS, column-wise.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let mut x = Mat::zeros(b.rows(), b.cols());
        for j in 0..b.cols() {
            let sol = self.solve(&b.col(j));
            for i in 0..b.rows() {
                x[(i, j)] = sol[i];
            }
        }
        x
    }

    /// det(A).
    pub fn det(&self) -> f64 {
        self.sign * (0..self.lu.rows()).map(|i| self.lu[(i, i)]).product::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::testkit;

    #[test]
    fn solve_recovers_solution() {
        testkit::check("lu-solve", 15, |rng, _| {
            let n = 1 + rng.below(40);
            let mut a = Mat::gauss(n, n, rng);
            a.shift_diag(2.0 * (n as f64).sqrt()); // keep well-conditioned
            let want: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let mut b = vec![0.0; n];
            blas::gemv(&a, &want, &mut b);
            let got = Lu::new(&a).unwrap().solve(&b);
            testkit::assert_allclose(&got, &want, 1e-8);
        });
    }

    #[test]
    fn pivoting_handles_zero_leading_entry() {
        let a = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let lu = Lu::new(&a).unwrap();
        let x = lu.solve(&[3.0, 7.0]);
        testkit::assert_allclose(&x, &[7.0, 3.0], 1e-12);
        assert!((lu.det() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(Lu::new(&a).is_err());
    }

    #[test]
    fn det_of_diag() {
        let mut a = Mat::eye(3);
        a[(0, 0)] = 2.0;
        a[(1, 1)] = 3.0;
        a[(2, 2)] = 4.0;
        let lu = Lu::new(&a).unwrap();
        assert!((lu.det() - 24.0).abs() < 1e-12);
    }
}
