//! LU factorization with partial pivoting — used by the ULV reduction
//! (the eliminated leading blocks are general square matrices, not SPD)
//! and the top-level dense solve of the HSS hierarchy.

use crate::linalg::matrix::Mat;

/// P A = L U with partial (row) pivoting.
pub struct Lu {
    lu: Mat,
    /// Row permutation: `perm[i]` = original row now at position i.
    perm: Vec<usize>,
    /// Sign of the permutation (for determinants).
    sign: f64,
}

/// Singular-matrix error.
#[derive(Debug)]
pub struct Singular {
    pub pivot: usize,
    pub value: f64,
}

impl std::fmt::Display for Singular {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "singular matrix at pivot {} (|pivot| = {:.3e})", self.pivot, self.value)
    }
}

impl std::error::Error for Singular {}

impl Lu {
    pub fn new(a: &Mat) -> Result<Self, Singular> {
        let n = a.rows();
        assert_eq!(a.rows(), a.cols(), "LU needs a square matrix");
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // pivot search in column k
            let mut pmax = k;
            let mut vmax = lu[(k, k)].abs();
            for i in k + 1..n {
                let v = lu[(i, k)].abs();
                if v > vmax {
                    vmax = v;
                    pmax = i;
                }
            }
            if vmax < 1e-300 {
                return Err(Singular { pivot: k, value: vmax });
            }
            if pmax != k {
                // swap rows
                for j in 0..n {
                    let t = lu[(k, j)];
                    lu[(k, j)] = lu[(pmax, j)];
                    lu[(pmax, j)] = t;
                }
                perm.swap(k, pmax);
                sign = -sign;
            }
            let inv = 1.0 / lu[(k, k)];
            for i in k + 1..n {
                let m = lu[(i, k)] * inv;
                lu[(i, k)] = m;
                if m != 0.0 {
                    // row update: lu[i, k+1..] -= m * lu[k, k+1..]
                    let (top, bot) = lu.data_mut().split_at_mut(i * n);
                    let row_k = &top[k * n + k + 1..k * n + n];
                    let row_i = &mut bot[k + 1..n];
                    for (ri, rk) in row_i.iter_mut().zip(row_k.iter()) {
                        *ri -= m * rk;
                    }
                }
            }
        }
        Ok(Lu { lu, perm, sign })
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows();
        assert_eq!(b.len(), n);
        // apply permutation
        let mut y: Vec<f64> = self.perm.iter().map(|&p| b[p]).collect();
        // forward L (unit diagonal)
        for i in 1..n {
            let row = self.lu.row(i);
            let mut s = y[i];
            for k in 0..i {
                s -= row[k] * y[k];
            }
            y[i] = s;
        }
        // backward U
        for i in (0..n).rev() {
            let row = self.lu.row(i);
            let mut s = y[i];
            for k in i + 1..n {
                s -= row[k] * y[k];
            }
            y[i] = s / row[i];
        }
        y
    }

    /// Solve A X = B for a whole block of right-hand sides at once.
    ///
    /// Blocked substitution with each row operation vectorized across
    /// the k RHS columns (multi-RHS `dtrsm` style), so L and U stream
    /// through cache once per sweep instead of once per column. Column j
    /// of the result is bit-for-bit identical to `solve(b.col(j))` — the
    /// per-column operation sequence is unchanged, which the batched
    /// ADMM grid relies on.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.lu.rows();
        assert_eq!(b.rows(), n, "solve_mat dimension mismatch");
        let k = b.cols();
        // apply the row permutation
        let mut x = Mat::zeros(n, k);
        for (i, &p) in self.perm.iter().enumerate() {
            x.row_mut(i).copy_from_slice(b.row(p));
        }
        // forward: L Y = P B (unit diagonal)
        for i in 1..n {
            let (head, tail) = x.data_mut().split_at_mut(i * k);
            let xi = &mut tail[..k];
            let lurow = self.lu.row(i);
            for (p, &a) in lurow.iter().enumerate().take(i) {
                let xp = &head[p * k..(p + 1) * k];
                for (v, &w) in xi.iter_mut().zip(xp.iter()) {
                    *v -= a * w;
                }
            }
        }
        // backward: U X = Y
        for i in (0..n).rev() {
            let (head, tail) = x.data_mut().split_at_mut((i + 1) * k);
            let xi = &mut head[i * k..];
            let lurow = self.lu.row(i);
            for p in i + 1..n {
                let a = lurow[p];
                let xp = &tail[(p - i - 1) * k..(p - i) * k];
                for (v, &w) in xi.iter_mut().zip(xp.iter()) {
                    *v -= a * w;
                }
            }
            let d = lurow[i];
            for v in xi.iter_mut() {
                *v /= d;
            }
        }
        x
    }

    /// det(A).
    pub fn det(&self) -> f64 {
        self.sign * (0..self.lu.rows()).map(|i| self.lu[(i, i)]).product::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::testkit;

    #[test]
    fn solve_recovers_solution() {
        testkit::check("lu-solve", 15, |rng, _| {
            let n = 1 + rng.below(40);
            let mut a = Mat::gauss(n, n, rng);
            a.shift_diag(2.0 * (n as f64).sqrt()); // keep well-conditioned
            let want: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let mut b = vec![0.0; n];
            blas::gemv(&a, &want, &mut b);
            let got = Lu::new(&a).unwrap().solve(&b);
            testkit::assert_allclose(&got, &want, 1e-8);
        });
    }

    #[test]
    fn pivoting_handles_zero_leading_entry() {
        let a = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let lu = Lu::new(&a).unwrap();
        let x = lu.solve(&[3.0, 7.0]);
        testkit::assert_allclose(&x, &[7.0, 3.0], 1e-12);
        assert!((lu.det() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(Lu::new(&a).is_err());
    }

    #[test]
    fn solve_mat_matches_columns_bitwise() {
        // multi-RHS substitution must replay the exact per-column
        // arithmetic of the scalar solve (batched ADMM depends on this)
        let mut rng = crate::util::prng::Rng::new(9);
        for ncols in [1usize, 3, 8] {
            let mut a = Mat::gauss(19, 19, &mut rng);
            a.shift_diag(9.0);
            let b = Mat::gauss(19, ncols, &mut rng);
            let lu = Lu::new(&a).unwrap();
            let x = lu.solve_mat(&b);
            for j in 0..ncols {
                let want = lu.solve(&b.col(j));
                assert_eq!(x.col(j), want, "column {j} of {ncols} not bitwise equal");
            }
        }
    }

    #[test]
    fn det_of_diag() {
        let mut a = Mat::eye(3);
        a[(0, 0)] = 2.0;
        a[(1, 1)] = 3.0;
        a[(2, 2)] = 4.0;
        let lu = Lu::new(&a).unwrap();
        assert!((lu.det() - 24.0).abs() < 1e-12);
    }
}
