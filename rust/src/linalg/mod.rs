//! Dense linear-algebra substrate (the paper leans on LAPACK/BLAS inside
//! STRUMPACK; everything is reimplemented here for the offline build).

pub mod blas;
pub mod chol;
pub mod cpqr;
pub mod eig;
pub mod lu;
pub mod matrix;
pub mod qr;

pub use blas::{dot, matmul, matmul_par, Trans};
pub use matrix::Mat;
