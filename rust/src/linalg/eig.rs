//! Symmetric eigensolver (cyclic Jacobi).
//!
//! Needed for Figure 1 (singular-value decay of Gaussian kernel matrices —
//! for a symmetric PSD kernel the singular values are the eigenvalues) and
//! for spectral diagnostics of the HSS approximation error. Jacobi is
//! O(n³) per sweep but rock-solid and accurate; Figure-1-sized matrices
//! (hundreds of rows) converge in a handful of sweeps.

use crate::linalg::matrix::Mat;

/// Eigen-decomposition A = V diag(w) Vᵀ of a symmetric matrix.
pub struct SymEig {
    /// Eigenvalues, descending.
    pub values: Vec<f64>,
    /// Eigenvectors as columns, matching `values` order.
    pub vectors: Mat,
}

/// Cyclic Jacobi with threshold sweeping. `a` must be symmetric.
pub fn sym_eig(a: &Mat) -> SymEig {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols(), "sym_eig needs a square matrix");
    let mut m = a.clone();
    let mut v = Mat::eye(n);

    let off = |m: &Mat| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                s += m[(i, j)] * m[(i, j)];
            }
        }
        s.sqrt()
    };

    let scale = a.fro().max(1e-300);
    let tol = 1e-14 * scale;
    for _sweep in 0..60 {
        if off(&m) <= tol {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() <= tol / (n as f64) {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Jacobi rotation: tan(2θ) = 2 apq / (app − aqq)
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // update rows/cols p and q of A
                for k in 0..n {
                    let akp = m[(k, p)];
                    let akq = m[(k, q)];
                    m[(k, p)] = c * akp - s * akq;
                    m[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[(p, k)];
                    let aqk = m[(q, k)];
                    m[(p, k)] = c * apk - s * aqk;
                    m[(q, k)] = s * apk + c * aqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // sort by descending eigenvalue
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| m[(j, j)].partial_cmp(&m[(i, i)]).unwrap());
    let values: Vec<f64> = order.iter().map(|&i| m[(i, i)]).collect();
    let vectors = v.select_cols(&order);
    SymEig { values, vectors }
}

/// Singular values of a symmetric PSD matrix = |eigenvalues|, descending.
pub fn psd_singular_values(a: &Mat) -> Vec<f64> {
    let mut s: Vec<f64> = sym_eig(a).values.iter().map(|v| v.abs()).collect();
    s.sort_by(|x, y| y.partial_cmp(x).unwrap());
    s
}

/// Largest eigenvalue magnitude via power iteration (cheap spectral-norm
/// estimate for big matrices where Jacobi is too slow).
pub fn spectral_norm_est(a: &Mat, iters: usize, rng: &mut crate::util::prng::Rng) -> f64 {
    let n = a.rows();
    let mut x: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
    let mut y = vec![0.0; n];
    let mut lam = 0.0;
    for _ in 0..iters {
        crate::linalg::blas::gemv(a, &x, &mut y);
        lam = crate::linalg::blas::nrm2(&y);
        if lam == 0.0 {
            return 0.0;
        }
        for (xi, yi) in x.iter_mut().zip(y.iter()) {
            *xi = yi / lam;
        }
    }
    lam
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{matmul, Trans};
    use crate::util::prng::Rng;
    use crate::util::testkit;

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 1.0;
        a[(1, 1)] = 5.0;
        a[(2, 2)] = 3.0;
        let e = sym_eig(&a);
        testkit::assert_allclose(&e.values, &[5.0, 3.0, 1.0], 1e-12);
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        testkit::check("eig-reconstruct", 8, |rng, _| {
            let n = 2 + rng.below(20);
            let g = Mat::gauss(n, n, rng);
            let a = {
                let mut s = matmul(&g, Trans::No, &g, Trans::Yes);
                s.scale(1.0 / n as f64);
                s
            };
            let e = sym_eig(&a);
            // V diag(w) Vᵀ = A
            let mut vd = e.vectors.clone();
            for j in 0..n {
                for i in 0..n {
                    vd[(i, j)] *= e.values[j];
                }
            }
            let back = matmul(&vd, Trans::No, &e.vectors, Trans::Yes);
            testkit::assert_allclose(back.data(), a.data(), 1e-8);
            // VᵀV = I
            let vtv = matmul(&e.vectors, Trans::Yes, &e.vectors, Trans::No);
            testkit::assert_allclose(vtv.data(), Mat::eye(n).data(), 1e-10);
            // descending order
            for w in e.values.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        });
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Rng::new(8);
        let g = Mat::gauss(15, 15, &mut rng);
        let a = matmul(&g, Trans::No, &g, Trans::Yes);
        let tr: f64 = (0..15).map(|i| a[(i, i)]).sum();
        let e = sym_eig(&a);
        let sum: f64 = e.values.iter().sum();
        testkit::assert_close(tr, sum, 1e-9);
    }

    #[test]
    fn power_iteration_close_to_jacobi() {
        let mut rng = Rng::new(9);
        let g = Mat::gauss(25, 25, &mut rng);
        let a = matmul(&g, Trans::No, &g, Trans::Yes);
        let top = sym_eig(&a).values[0];
        let est = spectral_norm_est(&a, 200, &mut rng);
        assert!((est - top).abs() / top < 1e-3, "est {est} vs {top}");
    }
}
