//! Householder QR factorization (thin), used by the randomized range
//! finder and the ULV elimination steps.

use crate::linalg::matrix::Mat;

/// Compact Householder QR of an m×n matrix (m ≥ n not required; for
/// m < n only the first m reflectors exist).
pub struct Qr {
    /// R in the upper triangle; Householder vectors (below diagonal,
    /// implicit leading 1) underneath.
    qr: Mat,
    /// Scalar coefficients tau_j of the reflectors H_j = I − tau v vᵀ.
    tau: Vec<f64>,
}

impl Qr {
    /// Factor A = Q R.
    pub fn new(a: &Mat) -> Self {
        let mut qr = a.clone();
        let (m, n) = qr.shape();
        let p = m.min(n);
        let mut tau = vec![0.0; p];
        for j in 0..p {
            // Build reflector for column j, rows j..m
            let mut norm2 = 0.0;
            for i in j..m {
                norm2 += qr[(i, j)] * qr[(i, j)];
            }
            let norm = norm2.sqrt();
            if norm == 0.0 {
                tau[j] = 0.0;
                continue;
            }
            let a0 = qr[(j, j)];
            let alpha = if a0 >= 0.0 { -norm } else { norm };
            // v = x - alpha e1, normalized so v[0] = 1
            let v0 = a0 - alpha;
            tau[j] = -v0 / alpha; // = 2 / (vᵀv / v0²) standard LAPACK form
            let inv_v0 = 1.0 / v0;
            for i in j + 1..m {
                qr[(i, j)] *= inv_v0;
            }
            qr[(j, j)] = alpha;
            // Apply H to trailing columns: A := (I - tau v vᵀ) A
            for c in j + 1..n {
                let mut s = qr[(j, c)];
                for i in j + 1..m {
                    s += qr[(i, j)] * qr[(i, c)];
                }
                s *= tau[j];
                qr[(j, c)] -= s;
                for i in j + 1..m {
                    let vij = qr[(i, j)];
                    qr[(i, c)] -= s * vij;
                }
            }
        }
        Qr { qr, tau }
    }

    /// Thin Q: m×p with orthonormal columns (p = min(m, n)).
    pub fn thin_q(&self) -> Mat {
        let (m, n) = self.qr.shape();
        let p = m.min(n);
        let mut q = Mat::zeros(m, p);
        for i in 0..p {
            q[(i, i)] = 1.0;
        }
        // Accumulate Q = H_0 H_1 ... H_{p-1} applied to I (back to front).
        for j in (0..p).rev() {
            if self.tau[j] == 0.0 {
                continue;
            }
            for c in 0..p {
                let mut s = q[(j, c)];
                for i in j + 1..m {
                    s += self.qr[(i, j)] * q[(i, c)];
                }
                s *= self.tau[j];
                q[(j, c)] -= s;
                for i in j + 1..m {
                    let vij = self.qr[(i, j)];
                    q[(i, c)] -= s * vij;
                }
            }
        }
        q
    }

    /// Full m×m orthogonal Q (needed by the ULV two-sided rotations).
    pub fn full_q(&self) -> Mat {
        let (m, n) = self.qr.shape();
        let p = m.min(n);
        let mut q = Mat::eye(m);
        for j in (0..p).rev() {
            if self.tau[j] == 0.0 {
                continue;
            }
            for c in 0..m {
                let mut s = q[(j, c)];
                for i in j + 1..m {
                    s += self.qr[(i, j)] * q[(i, c)];
                }
                s *= self.tau[j];
                q[(j, c)] -= s;
                for i in j + 1..m {
                    let vij = self.qr[(i, j)];
                    q[(i, c)] -= s * vij;
                }
            }
        }
        q
    }

    /// R factor: p×n upper triangular (p = min(m,n)).
    pub fn r(&self) -> Mat {
        let (m, n) = self.qr.shape();
        let p = m.min(n);
        let mut r = Mat::zeros(p, n);
        for i in 0..p {
            for j in i..n {
                r[(i, j)] = self.qr[(i, j)];
            }
        }
        r
    }

    /// Apply Qᵀ to a vector in place (length m).
    pub fn qt_vec(&self, x: &mut [f64]) {
        let (m, n) = self.qr.shape();
        assert_eq!(x.len(), m);
        let p = m.min(n);
        for j in 0..p {
            if self.tau[j] == 0.0 {
                continue;
            }
            let mut s = x[j];
            for i in j + 1..m {
                s += self.qr[(i, j)] * x[i];
            }
            s *= self.tau[j];
            x[j] -= s;
            for i in j + 1..m {
                x[i] -= s * self.qr[(i, j)];
            }
        }
    }

    /// Apply Q to a vector in place (length m).
    pub fn q_vec(&self, x: &mut [f64]) {
        let (m, n) = self.qr.shape();
        assert_eq!(x.len(), m);
        let p = m.min(n);
        for j in (0..p).rev() {
            if self.tau[j] == 0.0 {
                continue;
            }
            let mut s = x[j];
            for i in j + 1..m {
                s += self.qr[(i, j)] * x[i];
            }
            s *= self.tau[j];
            x[j] -= s;
            for i in j + 1..m {
                x[i] -= s * self.qr[(i, j)];
            }
        }
    }

    /// Least-squares solve min ‖Ax − b‖ for full-column-rank A (m ≥ n).
    pub fn solve_ls(&self, b: &[f64]) -> Vec<f64> {
        let (m, n) = self.qr.shape();
        assert!(m >= n, "solve_ls requires m >= n");
        assert_eq!(b.len(), m);
        let mut y = b.to_vec();
        self.qt_vec(&mut y);
        // back substitution with R (n×n upper part)
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for j in i + 1..n {
                s -= self.qr[(i, j)] * x[j];
            }
            let d = self.qr[(i, i)];
            assert!(d.abs() > 1e-300, "rank-deficient matrix in solve_ls");
            x[i] = s / d;
        }
        x
    }
}

/// Orthonormalize the columns of A (thin Q of its QR).
pub fn orth(a: &Mat) -> Mat {
    Qr::new(a).thin_q()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{self, matmul, Trans};
    use crate::util::testkit;

    #[test]
    fn qr_reconstructs_a() {
        testkit::check("qr-reconstruct", 15, |rng, _| {
            let m = 2 + rng.below(30);
            let n = 1 + rng.below(30);
            let a = Mat::gauss(m, n, rng);
            let qr = Qr::new(&a);
            let q = qr.thin_q();
            let r = qr.r();
            let back = matmul(&q, Trans::No, &r, Trans::No);
            testkit::assert_allclose(back.data(), a.data(), 1e-10);
        });
    }

    #[test]
    fn q_is_orthonormal() {
        testkit::check("qr-orthonormal", 15, |rng, _| {
            let m = 5 + rng.below(30);
            let n = 1 + rng.below(m.min(20));
            let a = Mat::gauss(m, n, rng);
            let q = orth(&a);
            let qtq = matmul(&q, Trans::Yes, &q, Trans::No);
            let eye = Mat::eye(q.cols());
            testkit::assert_allclose(qtq.data(), eye.data(), 1e-10);
        });
    }

    #[test]
    fn qt_q_vec_roundtrip() {
        testkit::check("qr-qvec", 10, |rng, _| {
            let m = 4 + rng.below(20);
            let n = 1 + rng.below(m);
            let a = Mat::gauss(m, n, rng);
            let qr = Qr::new(&a);
            let x0: Vec<f64> = (0..m).map(|_| rng.gauss()).collect();
            let mut x = x0.clone();
            qr.qt_vec(&mut x);
            qr.q_vec(&mut x);
            testkit::assert_allclose(&x, &x0, 1e-11);
        });
    }

    #[test]
    fn full_q_orthogonal_and_consistent_with_thin() {
        testkit::check("qr-fullq", 10, |rng, _| {
            let m = 3 + rng.below(20);
            let n = 1 + rng.below(m);
            let a = Mat::gauss(m, n, rng);
            let qr = Qr::new(&a);
            let qf = qr.full_q();
            // orthogonal
            let qtq = matmul(&qf, Trans::Yes, &qf, Trans::No);
            testkit::assert_allclose(qtq.data(), Mat::eye(m).data(), 1e-10);
            // first min(m,n) columns match thin Q
            let thin = qr.thin_q();
            let first = qf.block(0, 0, m, thin.cols());
            testkit::assert_allclose(first.data(), thin.data(), 1e-10);
        });
    }

    #[test]
    fn least_squares_solves_square_system() {
        testkit::check("qr-ls", 10, |rng, _| {
            let n = 2 + rng.below(15);
            let a = {
                let mut m = Mat::gauss(n, n, rng);
                m.shift_diag(3.0 * n as f64); // well-conditioned
                m
            };
            let want: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let mut b = vec![0.0; n];
            blas::gemv(&a, &want, &mut b);
            let got = Qr::new(&a).solve_ls(&b);
            testkit::assert_allclose(&got, &want, 1e-9);
        });
    }

    #[test]
    fn ls_overdetermined_residual_orthogonal() {
        testkit::check("qr-ls-over", 10, |rng, _| {
            let m = 20 + rng.below(20);
            let n = 3 + rng.below(8);
            let a = Mat::gauss(m, n, rng);
            let b: Vec<f64> = (0..m).map(|_| rng.gauss()).collect();
            let x = Qr::new(&a).solve_ls(&b);
            // residual r = b - Ax must satisfy Aᵀ r = 0
            let mut ax = vec![0.0; m];
            blas::gemv(&a, &x, &mut ax);
            let r: Vec<f64> = b.iter().zip(ax.iter()).map(|(bi, ai)| bi - ai).collect();
            let mut atr = vec![0.0f64; n];
            blas::gemv_t(&a, &r, &mut atr);
            for v in atr {
                assert!(v.abs() < 1e-8, "normal equations violated: {v}");
            }
        });
    }
}
