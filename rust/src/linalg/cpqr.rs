//! Column-pivoted QR and the interpolative decomposition (ID).
//!
//! The ID is the engine of HSS compression: given a (sample) matrix S
//! with rows indexed by the points of a cluster, a **row ID**
//! `S ≈ X · S[J, :]` picks `|J|` skeleton rows and an interpolation
//! matrix X with an identity sub-block. The HSS generators are
//! U = X and the skeleton index sets J (STRUMPACK does exactly this).

use crate::linalg::matrix::Mat;

/// Result of a rank-revealing column-pivoted QR, truncated at `tol`.
pub struct Cpqr {
    /// Selected (pivot) column indices of the original matrix, in order.
    pub piv: Vec<usize>,
    /// Numerical rank detected.
    pub rank: usize,
    /// R factor, rank×n, columns in *pivoted* order.
    pub r: Mat,
}

/// Column-pivoted Householder QR with early exit once the residual
/// column norms drop below `max(abs_tol, rel_tol * ‖A‖)` or `max_rank`
/// is hit. Returns factors sufficient to build an ID.
pub fn cpqr(a: &Mat, rel_tol: f64, abs_tol: f64, max_rank: usize) -> Cpqr {
    let (m, n) = a.shape();
    let mut work = a.clone();
    let kmax = m.min(n).min(max_rank.max(1));
    let mut piv: Vec<usize> = (0..n).collect();
    // running squared column norms
    let mut cnorm2: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| work[(i, j)] * work[(i, j)]).sum())
        .collect();
    // Relative scale = largest initial column norm (pivot-based semantics,
    // matching STRUMPACK's hss_rel_tol behaviour more closely than a
    // Frobenius-norm scale would).
    let a_norm = cnorm2.iter().cloned().fold(0.0f64, f64::max).sqrt();
    let thresh = (rel_tol * a_norm).max(abs_tol).max(0.0);

    let mut tau = vec![0.0; kmax];
    let mut k = 0;
    while k < kmax {
        // pick pivot among remaining columns
        let (jmax, &nmax) = cnorm2[k..]
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap();
        let jmax = jmax + k;
        // The first pivot is kept whenever it clears abs_tol: STRUMPACK's
        // rel_tol=1 ("very rough") setting yields rank-1, not rank-0,
        // off-diagonal blocks.
        let col_norm = nmax.sqrt();
        if col_norm <= thresh && (k > 0 || col_norm <= abs_tol.max(0.0)) {
            break;
        }
        // swap columns k <-> jmax
        if jmax != k {
            for i in 0..m {
                let t = work[(i, k)];
                work[(i, k)] = work[(i, jmax)];
                work[(i, jmax)] = t;
            }
            piv.swap(k, jmax);
            cnorm2.swap(k, jmax);
        }
        // Householder on column k, rows k..m
        let mut norm2 = 0.0;
        for i in k..m {
            norm2 += work[(i, k)] * work[(i, k)];
        }
        let norm = norm2.sqrt();
        if norm == 0.0 {
            break;
        }
        let a0 = work[(k, k)];
        let alpha = if a0 >= 0.0 { -norm } else { norm };
        let v0 = a0 - alpha;
        tau[k] = -v0 / alpha;
        let inv_v0 = 1.0 / v0;
        for i in k + 1..m {
            work[(i, k)] *= inv_v0;
        }
        work[(k, k)] = alpha;
        // apply reflector to trailing columns + downdate norms
        for c in k + 1..n {
            let mut s = work[(k, c)];
            for i in k + 1..m {
                s += work[(i, k)] * work[(i, c)];
            }
            s *= tau[k];
            work[(k, c)] -= s;
            for i in k + 1..m {
                let v = work[(i, k)];
                work[(i, c)] -= s * v;
            }
            // exact downdate of the remaining norm (recompute guard below)
            cnorm2[c] -= work[(k, c)] * work[(k, c)];
            if cnorm2[c] < 1e-14 * a_norm * a_norm {
                // numerical cancellation: recompute from scratch
                cnorm2[c] = (k + 1..m).map(|i| work[(i, c)] * work[(i, c)]).sum();
            }
        }
        k += 1;
    }

    // Extract R (k×n) in pivoted column order.
    let rank = k;
    let mut r = Mat::zeros(rank, n);
    for i in 0..rank {
        for j in i..n {
            r[(i, j)] = work[(i, j)];
        }
    }
    piv.truncate(n);
    Cpqr { piv, rank, r }
}

/// Column interpolative decomposition: A ≈ A[:, J] · T where
/// T = [I | R11⁻¹R12] in pivoted order, mapped back to original order.
///
/// Returns (J, T) with T of shape rank×n such that A ≈ A[:,J] T.
pub fn column_id(a: &Mat, rel_tol: f64, abs_tol: f64, max_rank: usize) -> (Vec<usize>, Mat) {
    let n = a.cols();
    let f = cpqr(a, rel_tol, abs_tol, max_rank);
    let k = f.rank;
    let j: Vec<usize> = f.piv[..k].to_vec();
    // Solve R11 * W = R12 by back substitution (R11 is k×k upper tri in
    // pivoted order, R12 the remaining n-k columns).
    let mut t_piv = Mat::zeros(k, n);
    for i in 0..k {
        t_piv[(i, i)] = 1.0;
    }
    for c in k..n {
        // solve R11 w = R[:, c]
        let mut w = vec![0.0; k];
        for i in (0..k).rev() {
            let mut s = f.r[(i, c)];
            for p in i + 1..k {
                s -= f.r[(i, p)] * w[p];
            }
            let d = f.r[(i, i)];
            w[i] = if d.abs() > 1e-300 { s / d } else { 0.0 };
        }
        for i in 0..k {
            t_piv[(i, c)] = w[i];
        }
    }
    // un-pivot columns: column piv[c] of T gets t_piv column c
    let mut t = Mat::zeros(k, n);
    for c in 0..n {
        let orig = f.piv[c];
        for i in 0..k {
            t[(i, orig)] = t_piv[(i, c)];
        }
    }
    (j, t)
}

/// Row interpolative decomposition: A ≈ X · A[J, :].
/// Implemented as the column ID of Aᵀ; X has shape m×rank with an
/// identity block on the skeleton rows J.
pub fn row_id(a: &Mat, rel_tol: f64, abs_tol: f64, max_rank: usize) -> (Vec<usize>, Mat) {
    let at = a.transpose();
    let (j, t) = column_id(&at, rel_tol, abs_tol, max_rank);
    (j, t.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{matmul, Trans};
    use crate::util::prng::Rng;
    use crate::util::testkit;

    /// Random m×n matrix of (numerical) rank r, well-scaled.
    fn low_rank(m: usize, n: usize, r: usize, rng: &mut Rng) -> Mat {
        let u = Mat::gauss(m, r, rng);
        let v = Mat::gauss(r, n, rng);
        matmul(&u, Trans::No, &v, Trans::No)
    }

    #[test]
    fn cpqr_detects_rank() {
        testkit::check("cpqr-rank", 12, |rng, _| {
            let m = 10 + rng.below(30);
            let n = 10 + rng.below(30);
            let r = 1 + rng.below(m.min(n).min(8));
            let a = low_rank(m, n, r, rng);
            let f = cpqr(&a, 1e-10, 0.0, usize::MAX);
            assert_eq!(f.rank, r, "rank mismatch {} vs {}", f.rank, r);
        });
    }

    #[test]
    fn cpqr_respects_max_rank() {
        let mut rng = Rng::new(5);
        let a = Mat::gauss(30, 30, &mut rng);
        let f = cpqr(&a, 0.0, 0.0, 7);
        assert_eq!(f.rank, 7);
        assert_eq!(f.r.rows(), 7);
    }

    #[test]
    fn column_id_reconstructs() {
        testkit::check("col-id", 12, |rng, _| {
            let m = 15 + rng.below(25);
            let n = 15 + rng.below(25);
            let r = 1 + rng.below(6);
            let a = low_rank(m, n, r, rng);
            let (j, t) = column_id(&a, 1e-12, 0.0, usize::MAX);
            assert_eq!(j.len(), r);
            let aj = a.select_cols(&j);
            let back = matmul(&aj, Trans::No, &t, Trans::No);
            let denom = a.fro().max(1.0);
            assert!(
                {
                    let mut d = back.clone();
                    d.axpy(-1.0, &a);
                    d.fro() / denom < 1e-8
                },
                "column ID reconstruction error too large"
            );
        });
    }

    #[test]
    fn row_id_reconstructs_and_has_identity_block() {
        testkit::check("row-id", 12, |rng, _| {
            let m = 15 + rng.below(25);
            let n = 10 + rng.below(25);
            let r = 1 + rng.below(5);
            let a = low_rank(m, n, r, rng);
            let (j, x) = row_id(&a, 1e-12, 0.0, usize::MAX);
            assert_eq!(j.len(), r);
            assert_eq!(x.shape(), (m, r));
            // identity block: X[j[k], :] = e_k
            for (k, &row) in j.iter().enumerate() {
                for c in 0..r {
                    let want = if c == k { 1.0 } else { 0.0 };
                    assert!((x[(row, c)] - want).abs() < 1e-10);
                }
            }
            let aj = a.select_rows(&j);
            let back = matmul(&x, Trans::No, &aj, Trans::No);
            let mut d = back;
            d.axpy(-1.0, &a);
            assert!(d.fro() / a.fro().max(1.0) < 1e-8);
        });
    }

    #[test]
    fn id_truncation_error_bounded_by_tolerance() {
        // Matrix with geometrically decaying singular values: truncating at
        // rel_tol should give a comparable reconstruction error.
        let mut rng = Rng::new(42);
        let m = 60;
        let n = 60;
        let mut a = Mat::zeros(m, n);
        for k in 0..20 {
            let u = Mat::gauss(m, 1, &mut rng);
            let v = Mat::gauss(1, n, &mut rng);
            let mut uv = matmul(&u, Trans::No, &v, Trans::No);
            uv.scale(0.5f64.powi(k as i32));
            a.axpy(1.0, &uv);
        }
        let (j, x) = row_id(&a, 1e-4, 0.0, usize::MAX);
        let back = matmul(&x, Trans::No, &a.select_rows(&j), Trans::No);
        let mut d = back;
        d.axpy(-1.0, &a);
        let rel = d.fro() / a.fro();
        assert!(rel < 1e-2, "rel err {rel} too large for tol 1e-4");
        assert!(j.len() < 30, "rank {} should be well below 30", j.len());
    }
}
