//! Dense row-major `f64` matrix.
//!
//! This is the workhorse type under the HSS compression, the ULV solver,
//! the SMO kernel cache and the baselines. It deliberately stays small:
//! storage + views + structural ops here, numerical kernels in
//! [`crate::linalg::blas`] and the factorization modules.

use crate::util::prng::Rng;
use std::fmt;

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// rows×cols matrix of zeros.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity of order n.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Take ownership of a row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { rows, cols, data }
    }

    /// Matrix with i.i.d. N(0,1) entries (randomized sketching probes).
    pub fn gauss(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.gauss()).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows == 0 || self.cols == 0
    }

    /// Raw row-major data.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy column `j` out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        debug_assert!(j < self.cols);
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // block to keep both access patterns cache-friendly
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        t[(j, i)] = self[(i, j)];
                    }
                }
            }
        }
        t
    }

    /// Copy of the contiguous block [r0, r0+nr) × [c0, c0+nc).
    pub fn block(&self, r0: usize, c0: usize, nr: usize, nc: usize) -> Mat {
        assert!(r0 + nr <= self.rows && c0 + nc <= self.cols, "block out of range");
        let mut b = Mat::zeros(nr, nc);
        for i in 0..nr {
            b.row_mut(i).copy_from_slice(&self.row(r0 + i)[c0..c0 + nc]);
        }
        b
    }

    /// Write `b` into the block starting at (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Mat) {
        assert!(r0 + b.rows <= self.rows && c0 + b.cols <= self.cols, "block out of range");
        for i in 0..b.rows {
            let cols = self.cols;
            self.data[(r0 + i) * cols + c0..(r0 + i) * cols + c0 + b.cols]
                .copy_from_slice(b.row(i));
        }
    }

    /// Copy of the rows selected by `idx` (in that order).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut m = Mat::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            m.row_mut(k).copy_from_slice(self.row(i));
        }
        m
    }

    /// Copy of the columns selected by `idx` (in that order).
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        let mut m = Mat::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = m.row_mut(i);
            for (k, &j) in idx.iter().enumerate() {
                dst[k] = src[j];
            }
        }
        m
    }

    /// Stack vertically: [self; other].
    pub fn vstack(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "vstack column mismatch");
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Mat { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Stack horizontally: [self, other].
    pub fn hstack(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "hstack row mismatch");
        let mut m = Mat::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            m.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            m.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        m
    }

    /// Scale all entries in place.
    pub fn scale(&mut self, a: f64) {
        for x in &mut self.data {
            *x *= a;
        }
    }

    /// self += a * other (same shape).
    pub fn axpy(&mut self, a: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape(), "axpy shape mismatch");
        for (x, y) in self.data.iter_mut().zip(other.data.iter()) {
            *x += a * y;
        }
    }

    /// Add `a` to the diagonal (the β-shift of the paper's K_β = K + βI).
    pub fn shift_diag(&mut self, a: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += a;
        }
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Approximate heap bytes held.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f64>()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols, "index ({i},{j}) out of {:?}", self.shape());
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols, "index ({i},{j}) out of {:?}", self.shape());
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(8);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > show_c { "..." } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.shape(), (3, 4));
        assert_eq!(m[(2, 3)], 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0, 22.0]);
    }

    #[test]
    fn eye_and_shift() {
        let mut m = Mat::eye(3);
        m.shift_diag(2.0);
        assert_eq!(m[(0, 0)], 3.0);
        assert_eq!(m[(0, 1)], 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(5, 7, |i, j| (i * 7 + j) as f64);
        let t = m.transpose();
        assert_eq!(t.shape(), (7, 5));
        assert_eq!(t.transpose(), m);
        for i in 0..5 {
            for j in 0..7 {
                assert_eq!(m[(i, j)], t[(j, i)]);
            }
        }
    }

    #[test]
    fn blocks_and_stacks() {
        let m = Mat::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let b = m.block(1, 2, 2, 2);
        assert_eq!(b.data(), &[6.0, 7.0, 10.0, 11.0]);
        let mut m2 = Mat::zeros(4, 4);
        m2.set_block(1, 2, &b);
        assert_eq!(m2[(1, 2)], 6.0);
        assert_eq!(m2[(2, 3)], 11.0);
        assert_eq!(m2[(0, 0)], 0.0);

        let v = b.vstack(&b);
        assert_eq!(v.shape(), (4, 2));
        assert_eq!(v[(2, 0)], 6.0);
        let h = b.hstack(&b);
        assert_eq!(h.shape(), (2, 4));
        assert_eq!(h[(0, 2)], 6.0);
    }

    #[test]
    fn select_rows_cols() {
        let m = Mat::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let r = m.select_rows(&[3, 0]);
        assert_eq!(r.row(0), &[12.0, 13.0, 14.0, 15.0]);
        assert_eq!(r.row(1), &[0.0, 1.0, 2.0, 3.0]);
        let c = m.select_cols(&[1, 1, 2]);
        assert_eq!(c.row(0), &[1.0, 1.0, 2.0]);
    }

    #[test]
    fn norms() {
        let m = Mat::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
        assert!((m.fro() - 5.0).abs() < 1e-12);
        assert_eq!(m.max_abs(), 4.0);
    }

    #[test]
    #[should_panic(expected = "block out of range")]
    fn block_bounds_checked() {
        Mat::zeros(3, 3).block(2, 2, 2, 2);
    }
}
