//! Cholesky factorization for SPD matrices — the dense reference solver
//! the HSS/ULV path is validated against, and the block solver inside the
//! RACQP baseline.

use crate::linalg::matrix::Mat;

/// Lower-triangular Cholesky factor L with A = L Lᵀ.
pub struct Chol {
    l: Mat,
}

/// Error for non-SPD input.
#[derive(Debug)]
pub struct NotSpd {
    pub pivot: usize,
    pub value: f64,
}

impl std::fmt::Display for NotSpd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "matrix not positive definite at pivot {} (value {:.3e})",
            self.pivot, self.value
        )
    }
}

impl std::error::Error for NotSpd {}

impl Chol {
    /// Factor an SPD matrix. O(n³/3).
    pub fn new(a: &Mat) -> Result<Self, NotSpd> {
        let n = a.rows();
        assert_eq!(a.rows(), a.cols(), "Cholesky needs a square matrix");
        let mut l = Mat::zeros(n, n);
        for j in 0..n {
            // diagonal
            let mut d = a[(j, j)];
            {
                let lj = l.row(j);
                for k in 0..j {
                    d -= lj[k] * lj[k];
                }
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(NotSpd { pivot: j, value: d });
            }
            let djs = d.sqrt();
            l[(j, j)] = djs;
            let inv = 1.0 / djs;
            // column below diagonal: L[i,j] = (A[i,j] - dot(L[i,:j], L[j,:j])) / L[j,j]
            for i in j + 1..n {
                let mut s = a[(i, j)];
                let (ri, rj) = (i * n, j * n);
                let data = l.data();
                for k in 0..j {
                    s -= data[ri + k] * data[rj + k];
                }
                l[(i, j)] = s * inv;
            }
        }
        Ok(Chol { l })
    }

    /// The factor L.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        // forward: L y = b
        let mut y = b.to_vec();
        for i in 0..n {
            let row = self.l.row(i);
            let mut s = y[i];
            for k in 0..i {
                s -= row[k] * y[k];
            }
            y[i] = s / row[i];
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.l[(k, i)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        y
    }

    /// Solve A X = B for a whole block of right-hand sides at once.
    ///
    /// Blocked substitution: each row operation is vectorized across all
    /// k columns of the RHS (the multi-RHS analogue of `dtrsm`), so the
    /// triangular factor is streamed through cache once per sweep instead
    /// of once per column. The per-column sequence of floating-point
    /// operations is *identical* to [`Chol::solve`] — column j of the
    /// result is bit-for-bit the single-RHS solve of column j, which the
    /// batched ADMM grid relies on.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.l.rows();
        assert_eq!(b.rows(), n, "solve_mat dimension mismatch");
        let k = b.cols();
        let mut x = b.clone();
        // forward: L Y = B, row i minus L[i, :i] · Y[:i, :]
        for i in 0..n {
            let (head, tail) = x.data_mut().split_at_mut(i * k);
            let xi = &mut tail[..k];
            let lrow = self.l.row(i);
            for (p, &a) in lrow.iter().enumerate().take(i) {
                let xp = &head[p * k..(p + 1) * k];
                for (v, &w) in xi.iter_mut().zip(xp.iter()) {
                    *v -= a * w;
                }
            }
            let d = lrow[i];
            for v in xi.iter_mut() {
                *v /= d;
            }
        }
        // backward: Lᵀ X = Y, row i minus L[i+1.., i]ᵀ · X[i+1.., :]
        for i in (0..n).rev() {
            let (head, tail) = x.data_mut().split_at_mut((i + 1) * k);
            let xi = &mut head[i * k..];
            for p in i + 1..n {
                let a = self.l[(p, i)];
                let xp = &tail[(p - i - 1) * k..(p - i) * k];
                for (v, &w) in xi.iter_mut().zip(xp.iter()) {
                    *v -= a * w;
                }
            }
            let d = self.l[(i, i)];
            for v in xi.iter_mut() {
                *v /= d;
            }
        }
        x
    }

    /// log det(A) = 2 Σ log L_ii.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{self, matmul, Trans};
    use crate::util::testkit;

    fn random_spd(n: usize, rng: &mut crate::util::prng::Rng) -> Mat {
        let g = Mat::gauss(n, n, rng);
        let mut a = matmul(&g, Trans::No, &g, Trans::Yes);
        a.shift_diag(n as f64); // safely SPD
        a
    }

    #[test]
    fn factor_reconstructs() {
        testkit::check("chol-reconstruct", 12, |rng, _| {
            let n = 2 + rng.below(40);
            let a = random_spd(n, rng);
            let ch = Chol::new(&a).unwrap();
            let back = matmul(ch.l(), Trans::No, ch.l(), Trans::Yes);
            testkit::assert_allclose(back.data(), a.data(), 1e-9);
        });
    }

    #[test]
    fn solve_residual_small() {
        testkit::check("chol-solve", 12, |rng, _| {
            let n = 2 + rng.below(40);
            let a = random_spd(n, rng);
            let want: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let mut b = vec![0.0; n];
            blas::gemv(&a, &want, &mut b);
            let got = Chol::new(&a).unwrap().solve(&b);
            testkit::assert_allclose(&got, &want, 1e-8);
        });
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(Chol::new(&a).is_err());
    }

    #[test]
    fn solve_mat_matches_columns_bitwise() {
        // the multi-RHS path must replay the exact per-column arithmetic
        // of the scalar path (the batched ADMM grid depends on this)
        let mut rng = crate::util::prng::Rng::new(3);
        for ncols in [1usize, 2, 5, 17] {
            let a = random_spd(23, &mut rng);
            let b = Mat::gauss(23, ncols, &mut rng);
            let ch = Chol::new(&a).unwrap();
            let x = ch.solve_mat(&b);
            for j in 0..ncols {
                let want = ch.solve(&b.col(j));
                assert_eq!(x.col(j), want, "column {j} of {ncols} not bitwise equal");
            }
        }
    }

    #[test]
    fn logdet_identity_zero() {
        let ch = Chol::new(&Mat::eye(5)).unwrap();
        assert!(ch.logdet().abs() < 1e-12);
    }
}
