//! HODLR (Hierarchically Off-Diagonal Low-Rank) kernel approximation —
//! the ablation counterpart to HSS.
//!
//! HODLR keeps the same cluster tree but stores every off-diagonal block
//! as an *independent* low-rank factorization U·Vᵀ (no nested bases).
//! Construction is simpler; the price is O(r·d·log d) memory instead of
//! O(r·d) and a recursive-Woodbury solve costing O(r²·d·log²d) instead
//! of the ULV's O(r²·d). DESIGN.md lists "HSS vs HODLR" as the format
//! ablation: the bench (`bench_hss`) and the tests here quantify it.

// No raw-pointer tricks belong in this module tree (see DESIGN.md §11).
#![forbid(unsafe_code)]

use crate::cluster::{ClusterTree, SplitMethod};
use crate::data::Dataset;
use crate::kernel::Kernel;
use crate::linalg::blas::{self, matmul, Trans};
use crate::linalg::chol::Chol;
use crate::linalg::cpqr;
use crate::linalg::lu::Lu;
use crate::linalg::Mat;
use crate::util::prng::Rng;
use anyhow::{bail, Result};

/// HODLR parameters (subset of the HSS knobs).
#[derive(Clone, Copy, Debug)]
pub struct HodlrParams {
    pub rel_tol: f64,
    pub abs_tol: f64,
    pub max_rank: usize,
    pub leaf_size: usize,
    /// Random columns sampled per off-diagonal block factorization.
    pub sample_cols: usize,
    pub seed: u64,
}

impl Default for HodlrParams {
    fn default() -> Self {
        HodlrParams {
            rel_tol: 1e-2,
            abs_tol: 1e-8,
            max_rank: 200,
            leaf_size: 128,
            sample_cols: 96,
            seed: 0xD01,
        }
    }
}

/// One node: leaves hold dense D; internal nodes hold the two low-rank
/// off-diagonal factors of this level's 2×2 partition.
struct Node {
    begin: usize,
    end: usize,
    left: Option<usize>,
    right: Option<usize>,
    d: Option<Mat>,
    /// A(left, right) ≈ u12 · v12ᵀ.
    u12: Option<Mat>,
    v12: Option<Mat>,
}

/// A HODLR-compressed symmetric kernel matrix.
pub struct Hodlr {
    nodes: Vec<Node>,
    pub n: usize,
    pub perm: Vec<usize>,
    /// Dataset in tree order.
    pub params: HodlrParams,
}

impl Hodlr {
    /// Compress K(ds, ds) in HODLR form (row-ID on sampled columns per
    /// off-diagonal block — same partially matrix-free recipe as HSS but
    /// without nested bases).
    pub fn compress(ds: &Dataset, kernel: &Kernel, params: &HodlrParams) -> (Hodlr, Dataset) {
        Self::compress_with(crate::compute::cpu(), ds, kernel, params)
    }

    /// [`Self::compress`] on an explicit [`crate::compute::ComputeBackend`]
    /// (every kernel block — leaf diagonals, column samples, skeleton
    /// rows — is evaluated through the backend).
    pub fn compress_with(
        backend: &dyn crate::compute::ComputeBackend,
        ds: &Dataset,
        kernel: &Kernel,
        params: &HodlrParams,
    ) -> (Hodlr, Dataset) {
        let mut rng = Rng::new(params.seed);
        let tree = ClusterTree::build(ds, params.leaf_size, SplitMethod::TwoMeans, &mut rng);
        let pds = ds.permute(&tree.perm);
        let n = pds.len();

        let mut nodes: Vec<Node> = Vec::with_capacity(tree.nodes.len());
        for t in &tree.nodes {
            let mut node = Node {
                begin: t.begin,
                end: t.end,
                left: t.left,
                right: t.right,
                d: None,
                u12: None,
                v12: None,
            };
            if t.is_leaf() {
                let rows: Vec<usize> = (t.begin..t.end).collect();
                let pts = pds.x.select_rows(&rows);
                node.d = Some(backend.kernel_block(kernel, &pts, &pts));
            } else {
                // low-rank A(left, right): rows = left range, cols sampled
                // from right range (plus an exact fallback for small blocks)
                let lt = &tree.nodes[t.left.unwrap()];
                let rt = &tree.nodes[t.right.unwrap()];
                let rows: Vec<usize> = (lt.begin..lt.end).collect();
                let all_cols: Vec<usize> = (rt.begin..rt.end).collect();
                let cols: Vec<usize> = if all_cols.len() <= params.sample_cols {
                    all_cols.clone()
                } else {
                    rng.sample_indices(all_cols.len(), params.sample_cols)
                        .into_iter()
                        .map(|i| all_cols[i])
                        .collect()
                };
                let rpts = pds.x.select_rows(&rows);
                let cpts = pds.x.select_rows(&cols);
                let sample = backend.kernel_block(kernel, &rpts, &cpts);
                // row ID of the sample picks skeleton rows of the block
                let (skel, u) =
                    cpqr::row_id(&sample, params.rel_tol, params.abs_tol, params.max_rank);
                // V = A(right, skel_rows)ᵀ... i.e. vᵀ = A(skel, right)
                let spts = pds.x.select_rows(&skel.iter().map(|&j| rows[j]).collect::<Vec<_>>());
                let apts = pds.x.select_rows(&all_cols);
                let vt = backend.kernel_block(kernel, &spts, &apts); // r × nr
                node.u12 = Some(u);
                node.v12 = Some(vt.transpose()); // nr × r
            }
            nodes.push(node);
        }
        (Hodlr { nodes, n, perm: tree.perm, params: *params }, pds)
    }

    fn root(&self) -> usize {
        self.nodes.len() - 1
    }

    /// Memory of the representation in bytes.
    pub fn memory_bytes(&self) -> usize {
        self.nodes
            .iter()
            .map(|nd| {
                nd.d.as_ref().map_or(0, Mat::bytes)
                    + nd.u12.as_ref().map_or(0, Mat::bytes)
                    + nd.v12.as_ref().map_or(0, Mat::bytes)
            })
            .sum()
    }

    /// Max off-diagonal rank.
    pub fn max_rank(&self) -> usize {
        self.nodes.iter().filter_map(|nd| nd.u12.as_ref().map(Mat::cols)).max().unwrap_or(0)
    }

    /// y = K̃ x (tree order).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0.0; self.n];
        self.matvec_rec(self.root(), x, &mut y);
        y
    }

    fn matvec_rec(&self, id: usize, x: &[f64], y: &mut [f64]) {
        let nd = &self.nodes[id];
        if let Some(d) = &nd.d {
            // accumulate (ancestors already wrote off-diag contributions)
            let xl = &x[nd.begin..nd.end];
            let mut tmp = vec![0.0; xl.len()];
            blas::gemv(d, xl, &mut tmp);
            for (yi, ti) in y[nd.begin..nd.end].iter_mut().zip(tmp.iter()) {
                *yi += ti;
            }
            return;
        }
        let (li, ri) = (nd.left.unwrap(), nd.right.unwrap());
        let (lb, le) = (self.nodes[li].begin, self.nodes[li].end);
        let (rb, re) = (self.nodes[ri].begin, self.nodes[ri].end);
        let u = nd.u12.as_ref().unwrap();
        let v = nd.v12.as_ref().unwrap();
        // y_left += U (Vᵀ x_right); y_right += V (Uᵀ x_left)
        let r = u.cols();
        let mut t = vec![0.0; r];
        blas::gemv_t(v, &x[rb..re], &mut t);
        let mut add = vec![0.0; le - lb];
        blas::gemv(u, &t, &mut add);
        for (yi, ai) in y[lb..le].iter_mut().zip(add.iter()) {
            *yi += ai;
        }
        let mut t2 = vec![0.0; r];
        blas::gemv_t(u, &x[lb..le], &mut t2);
        let mut add2 = vec![0.0; re - rb];
        blas::gemv(v, &t2, &mut add2);
        for (yi, ai) in y[rb..re].iter_mut().zip(add2.iter()) {
            *yi += ai;
        }
        self.matvec_rec(li, x, y);
        self.matvec_rec(ri, x, y);
    }
}

/// Recursive-Woodbury factorization of K̃ + βI (the HODLR solver).
///
/// At each internal node the matrix is D_blk + [U₁V₂ᵀ; V... ] written as
/// Ablk + W Zᵀ with W = diag(U, V), Z = [0 V; U 0]-style rank-2r update;
/// solve via the children and the (2r × 2r) capacitance system.
pub struct HodlrFactor<'a> {
    h: &'a Hodlr,
    shift: f64,
    /// Per-node: leaf Cholesky (or LU fallback) of D + βI.
    leaf: Vec<Option<LeafFactor>>,
    /// Per-internal-node capacitance LU and precomputed A⁻¹W.
    cap: Vec<Option<CapFactor>>,
}

enum LeafFactor {
    Chol(Chol),
    Lu(Lu),
}

impl LeafFactor {
    fn solve(&self, b: &[f64]) -> Vec<f64> {
        match self {
            LeafFactor::Chol(c) => c.solve(b),
            LeafFactor::Lu(l) => l.solve(b),
        }
    }
}

struct CapFactor {
    /// A_blk⁻¹ W (n_node × 2r), columns solved recursively at factor time.
    ainv_w: Mat,
    /// LU of (I + Zᵀ A⁻¹ W).
    cap_lu: Lu,
    /// Z (n_node × 2r).
    z: Mat,
}

impl<'a> HodlrFactor<'a> {
    pub fn new(h: &'a Hodlr, shift: f64) -> Result<Self> {
        let mut f = HodlrFactor {
            h,
            shift,
            leaf: (0..h.nodes.len()).map(|_| None).collect(),
            cap: (0..h.nodes.len()).map(|_| None).collect(),
        };
        f.factor_rec(h.root())?;
        Ok(f)
    }

    fn factor_rec(&mut self, id: usize) -> Result<()> {
        let nd = &self.h.nodes[id];
        if let Some(d) = &nd.d {
            let mut dl = d.clone();
            dl.shift_diag(self.shift);
            let lf = match Chol::new(&dl) {
                Ok(c) => LeafFactor::Chol(c),
                Err(_) => {
                    let mut d2 = dl.clone();
                    d2.shift_diag(1e-10);
                    match Lu::new(&d2) {
                        Ok(l) => LeafFactor::Lu(l),
                        Err(e) => bail!("HODLR leaf factorization failed: {e}"),
                    }
                }
            };
            self.leaf[id] = Some(lf);
            return Ok(());
        }
        let (li, ri) = (nd.left.unwrap(), nd.right.unwrap());
        self.factor_rec(li)?;
        self.factor_rec(ri)?;

        // Build W, Z for the rank-2r correction:
        // [0 UVᵀ; VUᵀ 0] = W Zᵀ with W = [U 0; 0 V], Z = [0 V... ]:
        //   W = [[U, 0], [0, V]],  Z = [[0, V·?]] — concretely:
        //   off = W Zᵀ where W = diag(U, V) (n × 2r),
        //   Z = [ [0, U]ᵀ-block arrangement ]: Zᵀ = [[0, Vᵀ],[Uᵀ, 0]]
        let nd = &self.h.nodes[id];
        let u = nd.u12.as_ref().unwrap();
        let v = nd.v12.as_ref().unwrap();
        let (nl, nr) = (u.rows(), v.rows());
        let r = u.cols();
        let ntot = nl + nr;
        let mut w = Mat::zeros(ntot, 2 * r);
        w.set_block(0, 0, u);
        w.set_block(nl, r, v);
        let mut z = Mat::zeros(ntot, 2 * r);
        z.set_block(nl, 0, v);
        z.set_block(0, r, u);
        // sanity: W Zᵀ == [[0, UVᵀ],[VUᵀ, 0]] (checked in tests)

        // A⁻¹ W column-wise via children solves
        let mut ainv_w = Mat::zeros(ntot, 2 * r);
        for c in 0..2 * r {
            let col = w.col(c);
            let sol = self.solve_block_diag(id, &col);
            for i in 0..ntot {
                ainv_w[(i, c)] = sol[i];
            }
        }
        // capacitance I + Zᵀ A⁻¹ W
        let mut capm = matmul(&z, Trans::Yes, &ainv_w, Trans::No);
        capm.shift_diag(1.0);
        let cap_lu = match Lu::new(&capm) {
            Ok(l) => l,
            Err(e) => bail!("HODLR capacitance singular at node {id}: {e}"),
        };
        self.cap[id] = Some(CapFactor { ainv_w, cap_lu, z });
        Ok(())
    }

    /// Solve with the *block-diagonal* part of node `id` (children solves).
    fn solve_block_diag(&self, id: usize, b: &[f64]) -> Vec<f64> {
        let nd = &self.h.nodes[id];
        if self.leaf[id].is_some() {
            return self.leaf[id].as_ref().unwrap().solve(b);
        }
        let (li, ri) = (nd.left.unwrap(), nd.right.unwrap());
        let nl = self.h.nodes[li].end - self.h.nodes[li].begin;
        let mut out = self.solve_full(li, &b[..nl]);
        out.extend(self.solve_full(ri, &b[nl..]));
        out
    }

    /// Solve (K̃ + βI) restricted to node `id` (full, with off-diagonal).
    fn solve_full(&self, id: usize, b: &[f64]) -> Vec<f64> {
        if self.leaf[id].is_some() {
            return self.leaf[id].as_ref().unwrap().solve(b);
        }
        let cap = self.cap[id].as_ref().unwrap();
        // Woodbury: x = A⁻¹b − A⁻¹W (I + ZᵀA⁻¹W)⁻¹ Zᵀ A⁻¹ b
        let ainv_b = self.solve_block_diag(id, b);
        let mut zt_ainvb = vec![0.0; cap.z.cols()];
        blas::gemv_t(&cap.z, &ainv_b, &mut zt_ainvb);
        let y = cap.cap_lu.solve(&zt_ainvb);
        let mut corr = vec![0.0; b.len()];
        blas::gemv(&cap.ainv_w, &y, &mut corr);
        ainv_b.iter().zip(corr.iter()).map(|(a, c)| a - c).collect()
    }

    /// Solve (K̃ + shift·I) x = b (tree order).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.h.n);
        self.solve_full(self.h.root(), b)
    }
}

impl crate::admm::solver::ShiftedSolve for HodlrFactor<'_> {
    fn solve_shifted(&self, b: &[f64]) -> Vec<f64> {
        self.solve(b)
    }

    fn dim(&self) -> usize {
        self.h.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::hss::matvec as hss_matvec;
    use crate::util::testkit;

    fn tight_params() -> HodlrParams {
        HodlrParams {
            rel_tol: 1e-10,
            abs_tol: 1e-12,
            max_rank: usize::MAX,
            leaf_size: 32,
            sample_cols: 1 << 16,
            seed: 3,
        }
    }

    #[test]
    fn matvec_matches_dense() {
        testkit::check("hodlr-matvec", 5, |rng, _| {
            let n = 60 + rng.below(150);
            let ds = synth::blobs(n, 3, 3, 0.3, rng);
            let kernel = Kernel::Gaussian { h: 1.0 };
            let (h, pds) = Hodlr::compress(&ds, &kernel, &tight_params());
            let kd = kernel.gram(&pds.x);
            let x: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let mut want = vec![0.0; n];
            blas::gemv(&kd, &x, &mut want);
            let got = h.matvec(&x);
            testkit::assert_allclose(&got, &want, 1e-6);
        });
    }

    #[test]
    fn woodbury_solve_roundtrip() {
        testkit::check("hodlr-solve", 5, |rng, _| {
            let n = 60 + rng.below(200);
            let ds = synth::blobs(n, 3, 3, 0.3, rng);
            let kernel = Kernel::Gaussian { h: 1.2 };
            let (h, _) = Hodlr::compress(&ds, &kernel, &tight_params());
            let beta = 1.0 + rng.f64();
            let f = HodlrFactor::new(&h, beta).unwrap();
            let want: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let mut b = h.matvec(&want);
            for (bi, wi) in b.iter_mut().zip(want.iter()) {
                *bi += beta * wi;
            }
            let got = f.solve(&b);
            testkit::assert_allclose(&got, &want, 1e-6);
        });
    }

    #[test]
    fn hodlr_uses_more_memory_than_hss_at_same_tolerance() {
        // the format ablation: nested bases pay off
        let mut rng = Rng::new(91);
        let ds = synth::blobs(1200, 6, 5, 0.3, &mut rng);
        let kernel = Kernel::Gaussian { h: 2.0 };
        let hodlr_p = HodlrParams { rel_tol: 1e-4, leaf_size: 64, sample_cols: 64, ..Default::default() };
        let (hod, _) = Hodlr::compress(&ds, &kernel, &hodlr_p);
        let hss_p = crate::hss::HssParams {
            rel_tol: 1e-4,
            abs_tol: 1e-10,
            max_rank: 200,
            ann_neighbors: 32,
            oversample: 32,
            leaf_size: 64,
            split: SplitMethod::TwoMeans,
            seed: 3,
        };
        let c = crate::hss::compress::compress(&ds, &kernel, &hss_p, 1);
        // HODLR stores one factor pair per level per node: ≥ HSS memory
        assert!(
            hod.memory_bytes() as f64 > 0.8 * c.stats.memory_bytes as f64,
            "hodlr {} vs hss {}",
            hod.memory_bytes(),
            c.stats.memory_bytes
        );
        // both must approximate the same matrix
        let x: Vec<f64> = (0..1200).map(|_| rng.gauss()).collect();
        let yh = hod.matvec(&x);
        // different permutations → compare norms only (same matrix up to perm)
        let ys = hss_matvec::matvec(&c.hss, &x);
        let nh = blas::nrm2(&yh);
        let ns = blas::nrm2(&ys);
        assert!((nh - ns).abs() / ns < 0.2, "matvec norms differ wildly: {nh} vs {ns}");
    }

    #[test]
    fn admm_trains_through_hodlr() {
        let mut rng = Rng::new(92);
        let train = synth::two_moons(300, 0.08, &mut rng);
        let kernel = Kernel::Gaussian { h: 0.3 };
        let (h, pds) = Hodlr::compress(&train, &kernel, &tight_params());
        let f = HodlrFactor::new(&h, 10.0).unwrap();
        let solver = crate::admm::AdmmSolver::new(
            &f,
            &pds.y,
            crate::admm::AdmmParams { beta: 10.0, max_it: 20, relax: 1.0, tol: 0.0 },
        );
        let out = solver.run(10.0);
        assert!(out.z.iter().all(|v| v.is_finite()));
        assert!(*out.primal.last().unwrap() < 1.0);
    }
}
