//! `hss-svm` — train very-large-scale nonlinear SVMs with ADMM + HSS
//! kernel approximations (Cipolla & Gondzio 2021 reproduction).
//!
//! Subcommands:
//!   train       train on a Table-1 synthetic dataset or a LIBSVM file
//!   grid        (h, C) grid search with HSS/ULV caching
//!   experiment  regenerate a paper table/figure (table1..table5, fig1,
//!               fig2, reuse, all)
//!   info        environment, artifacts and dataset inventory
//!   help        this text

use anyhow::{bail, Context, Result};
use hss_svm::admm::{AdmmParams, ConsensusTrainer};
use hss_svm::cli::Args;
use hss_svm::compute::{BackendChoice, ComputeBackend};
use hss_svm::cluster::SplitMethod;
use hss_svm::coordinator::{run_suite, GridSearch, SuiteConfig};
use hss_svm::data::libsvm::{LibsvmData, Repr};
use hss_svm::data::synth::Table1Spec;
use hss_svm::data::{libsvm, scale, synth, Dataset, ShardSet};
use hss_svm::eval::{figures, report, tables};
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::obs::{self, ConvergenceReport, ReportColumn};
use hss_svm::runtime::PjrtRuntime;
use hss_svm::svm::multiclass::{train_ovo, MulticlassDataset};
use hss_svm::svm::multilevel::{LevelStats, MultilevelContext, MultilevelParams};
use hss_svm::svm::{predict, train::train_hss_svm, AnyModel};
use hss_svm::util::threadpool;
use hss_svm::util::timer::Timer;
use std::path::PathBuf;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    // Structured tracing (DESIGN.md §14): `--trace PATH` wins over the
    // HSS_SVM_TRACE env var; both install the process-global JSONL sink
    // before any work starts, so every subcommand is traceable.
    match args.str_opt("trace") {
        Some(path) => obs::trace::init_path(path)
            .with_context(|| format!("--trace: cannot open {path:?}"))?,
        None => obs::trace::init_from_env(),
    }
    let result = match args.command.as_str() {
        "train" => cmd_train(args),
        "predict" => cmd_predict(args),
        "serve" => cmd_serve(args),
        "grid" => cmd_grid(args),
        "experiment" => cmd_experiment(args),
        "info" => cmd_info(args),
        "help" | "" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `hss-svm help`)"),
    };
    obs::trace::flush();
    result
}

/// Persist the convergence report when `--report PATH` was given.
fn write_report(args: &Args, report: &ConvergenceReport) -> Result<()> {
    if let Some(path) = args.str_opt("report") {
        report.write(path).with_context(|| format!("--report: cannot write {path:?}"))?;
        println!("  convergence report written to {path}");
    }
    Ok(())
}

const HELP: &str = r#"hss-svm — nonlinear SVM training via ADMM + HSS kernel approximations

USAGE:
  hss-svm train      --dataset <table1-name> [--scale F] [--h F] [--c F]
                     [--beta F] [--iters N] [--hss low|high|exact]
                     [--threads N] [--pjrt]
                     [--multilevel [--coarse-level L] [--screen-eps E]]
                     [--trace t.jsonl] [--report report.json]
  hss-svm train      --train-file f.libsvm --test-file g.libsvm [...same]
                     [--save-model m.model] [--sparse|--dense] [--binary]
                     [--raw]
                                         # >2 distinct labels auto-train
                                         # one-vs-one multiclass (pairs
                                         # in parallel, C grid batched);
                                         # --binary forces the strict
                                         # 2-class reader; --raw skips
                                         # the min-max feature scaling
  hss-svm train      --train-file f.libsvm --shards K [--shard-dir D]
                     [--test-file g.libsvm] [...same]
                                         # out-of-core: split f into K
                                         # on-disk CSR shards (one
                                         # streaming pass, reused when D
                                         # matches), train block-diagonal
                                         # consensus ADMM with one shard
                                         # resident at a time; features
                                         # stay raw (unscaled); result is
                                         # a plain .model file
  hss-svm predict    --model m.model --test-file g.libsvm [--out pred.txt]
                     [--backend cpu|simd-f32|pjrt] [--pjrt]
                     [--sparse|--dense]
                                         # OvO model files predict via
                                         # the shared-SV engine and
                                         # answer original class labels;
                                         # --backend picks the compute
                                         # backend (default cpu, the
                                         # bitwise f64 reference) and
                                         # fails when unavailable, unlike
                                         # the soft --pjrt fallback
  hss-svm serve      --model m.model [--stdin]
                     [--backend cpu|simd-f32|pjrt]
                                         # LIBSVM lines on stdin ->
                                         # "<label> <decision>" per line;
                                         # labeled, 0-labeled and bare
                                         # feature lines all accepted
  hss-svm serve      --listen HOST:PORT --model m.model
                     [--models name=a.model,name2=b.model]
                     [--batch-wait-ms N] [--max-inflight N]
                     [--batch-max N] [--threads N]
                     [--backend cpu|simd-f32|pjrt]
                                         # concurrent TCP server: same
                                         # line protocol per connection,
                                         # requests micro-batched across
                                         # connections; admin commands
                                         # MODEL <name> | RELOAD [name] |
                                         # STATS | METRICS | SHUTDOWN |
                                         # QUIT
  hss-svm grid       --dataset <name> [--scale F] [--h 0.1,1,10]
                     [--c 0.1,1,10] [--hss low|high] [--threads N]
                     [--multilevel [--coarse-level L] [--screen-eps E]]
                     [--trace t.jsonl] [--report report.json]
  hss-svm grid       --train-file f.libsvm --shards K --test-file g.libsvm
                     [--shard-dir D] [...same]
                                         # out-of-core grid: one consensus
                                         # build per h, all C batched
  hss-svm experiment --id table1|table2|table3|table4|table5|fig1|fig2|reuse|all
                     [--scale F] [--datasets a,b,...] [--out results/]
                     [--baseline-cap N] [--threads N]
  hss-svm info

Datasets: synthetic workloads matched to the paper's Table 1
(a8a w7a rcv1.binary a9a w8a ijcnn1 cod.rna skin.nonskin webspam.uni susy);
--scale F generates F x the paper's sizes (default 0.01).

LIBSVM files load without densifying: wide sparse data (dim >= 32,
density <= 25%) stays in CSR form end-to-end (memory ~ nnz, not
rows x dim); --sparse / --dense force the representation.

Multiclass: a training file with more than two distinct labels trains
LIBSVM-style one-vs-one (k(k-1)/2 pairwise classifiers, trained in
parallel, each reusing one HSS factorization across the whole C grid).
Saved OvO models store a shared support-vector pool; predict and both
serve modes answer the file's original integer class labels.

Multilevel (--multilevel; train and grid, in-memory binary problems
only): coarse-to-fine training over the cluster tree (DESIGN.md
section 15). The coarse problem trains on one representative per tree
node, then each finer level warm-starts ADMM from the previous level's
iterates and restricts itself to the inherited support vectors plus
their ANN neighborhoods; the final level falls back to the full set
only if the SV set is still growing. --coarse-level L pins the
coarsest tree level (default: auto-picked so the coarse problem is
~n/8 points); --screen-eps E drops epsilon-covered same-class points
per leaf before any kernel work (default 0 = screening off). Models
are bitwise independent of --threads, like the flat trainer.

Observability (see DESIGN.md section 14): --trace PATH (or the
HSS_SVM_TRACE env var) streams structured JSONL events — compression
ranks, ADMM residuals per iteration, server batches — on any
subcommand; --report PATH persists a convergence report (phase
breakdown + residual curves) from train/grid; the TCP server's METRICS
admin command answers Prometheus text exposition terminated by a
"# EOF" line. Tracing never perturbs results: models and predictions
are bitwise identical with it on or off.
"#;

fn hss_params_from(args: &Args) -> Result<HssParams> {
    let mut p = match args.str_or("hss", "low").as_str() {
        "low" => HssParams::low_accuracy(),
        "high" => HssParams::high_accuracy(),
        "exact" => HssParams::near_exact(),
        other => bail!("--hss must be low|high|exact, got {other:?}"),
    };
    if let Some(v) = args.str_opt("leaf") {
        p.leaf_size = v.parse().context("--leaf expects an integer")?;
    }
    if let Some(v) = args.str_opt("split") {
        p.split = match v {
            "kmeans" => SplitMethod::TwoMeans,
            "pca" => SplitMethod::Pca,
            other => bail!("--split must be kmeans|pca, got {other:?}"),
        };
    }
    Ok(p)
}

/// `--multilevel [--coarse-level L] [--screen-eps E]` → `Some(params)`;
/// `None` when the switch is absent. Naming a sub-flag without
/// `--multilevel` is almost certainly a typo, so it errors instead of
/// silently training flat.
fn multilevel_params_from(args: &Args) -> Result<Option<MultilevelParams>> {
    if !args.has("multilevel") {
        if args.has("coarse-level") || args.has("screen-eps") {
            bail!("--coarse-level/--screen-eps only apply together with --multilevel");
        }
        return Ok(None);
    }
    let mut ml = MultilevelParams::default();
    if let Some(v) = args.str_opt("coarse-level") {
        ml.coarse_level = Some(v.parse().context("--coarse-level expects an integer")?);
    }
    ml.screen_eps = args.f64_or("screen-eps", ml.screen_eps)?;
    Ok(Some(ml))
}

/// One console row per trained level of a multilevel schedule.
fn print_level_rows(levels: &[LevelStats]) {
    for l in levels {
        let tag = if l.level == usize::MAX {
            if l.full_fallback { "final (full fallback)".to_string() } else { "final".to_string() }
        } else {
            format!("level {}", l.level)
        };
        println!("  {:<22} {:>8} pts -> {:>7} SVs   {:>9.3} s", tag, l.n_points, l.n_sv, l.secs);
    }
}

/// --sparse / --dense override the Auto representation choice.
fn repr_from(args: &Args) -> Result<Repr> {
    match (args.has("sparse"), args.has("dense")) {
        (true, true) => bail!("--sparse and --dense are mutually exclusive"),
        (true, false) => Ok(Repr::Sparse),
        (false, true) => Ok(Repr::Dense),
        (false, false) => Ok(Repr::Auto),
    }
}

/// The test file (or held-out split) must land in the SAME
/// representation as train: the scaler's zero handling differs per
/// representation (dense shifts zeros, CSR keeps them — svm-scale
/// convention), so an Auto split decision would put train and test in
/// different feature spaces.
fn test_repr_for(repr: Repr, train_sparse: bool) -> Repr {
    match repr {
        Repr::Auto if train_sparse => Repr::Sparse,
        Repr::Auto => Repr::Dense,
        forced => forced,
    }
}

/// Binary tail of the loading pipeline: resolve the test set (file or
/// 70/30 split) and fit-on-train scaling.
fn finish_binary_pair(args: &Args, mut train: Dataset, repr: Repr) -> Result<(Dataset, Dataset)> {
    let dim = train.dim();
    let test_repr = test_repr_for(repr, train.is_sparse());
    let mut test = match args.str_opt("test-file") {
        Some(f) => libsvm::read_file_with(f, Some(dim), test_repr)?,
        None => {
            // 70/30 split
            let n = train.len();
            let (tr, te) = train.split_at(n * 7 / 10);
            train = tr;
            te
        }
    };
    // --raw skips the fit-on-train min-max scaling: needed to compare
    // against the sharded path, which streams raw features (a global
    // min/max would need a second pass over the file)
    if !args.has("raw") {
        scale::scale_pair(&mut train, &mut test);
    }
    Ok((train, test))
}

fn load_pair(args: &Args) -> Result<(Dataset, Dataset)> {
    if let Some(train_file) = args.str_opt("train-file") {
        let repr = repr_from(args)?;
        let train = libsvm::read_file_with(train_file, None, repr)?;
        finish_binary_pair(args, train, repr)
    } else {
        let name = args.str_or("dataset", "ijcnn1");
        let spec = synth::table1_spec(&name)
            .with_context(|| format!("unknown dataset {name:?} (see `hss-svm info`)"))?;
        let scale_frac = args.f64_or("scale", 0.01)?;
        let seed = args.usize_or("seed", 2021)? as u64;
        Ok(hss_svm::coordinator::suite::prepare_dataset(spec, scale_frac, seed))
    }
}

/// A loaded (train, test) pair of either arity.
enum LoadedPair {
    Binary(Dataset, Dataset),
    Multi(MulticlassDataset, MulticlassDataset),
}

/// Arity-detecting loader for `train`/`grid`: a `--train-file` with
/// more than two distinct labels routes onto the one-vs-one multiclass
/// path (`--binary` forces the strict binary reader, which rejects > 2
/// classes); synthetic datasets are binary by construction. Multiclass
/// test sets are read strictly (labels required, same classes space as
/// train is NOT enforced — unseen test classes just never match) and
/// scaled with train-fitted min-max like the binary path.
fn load_pair_auto(args: &Args) -> Result<LoadedPair> {
    let Some(train_file) = args.str_opt("train-file") else {
        let (train, test) = load_pair(args)?;
        return Ok(LoadedPair::Binary(train, test));
    };
    if args.has("binary") {
        let (train, test) = load_pair(args)?;
        return Ok(LoadedPair::Binary(train, test));
    }
    let repr = repr_from(args)?;
    match libsvm::read_file_any(train_file, None, repr)? {
        LibsvmData::Binary(train) => {
            let (train, test) = finish_binary_pair(args, train, repr)?;
            Ok(LoadedPair::Binary(train, test))
        }
        LibsvmData::Multi(mut train) => {
            let dim = train.dim();
            let test_repr = test_repr_for(repr, train.is_sparse());
            let mut test = match args.str_opt("test-file") {
                Some(f) => libsvm::read_multiclass_file(f, Some(dim), test_repr)?,
                None => {
                    // deterministic 70/30 INTERLEAVED split (i % 10):
                    // multiclass LIBSVM files are commonly sorted by
                    // class, so a contiguous cut would strand the later
                    // classes entirely in the test set
                    let tr_idx: Vec<usize> = (0..train.len()).filter(|i| i % 10 < 7).collect();
                    let te_idx: Vec<usize> = (0..train.len()).filter(|i| i % 10 >= 7).collect();
                    let te = train.select(&te_idx);
                    train = train.select(&tr_idx);
                    te
                }
            };
            scale::scale_points_pair(&mut train.x, &mut test.x);
            Ok(LoadedPair::Multi(train, test))
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    // the sharded route never loads the full training set — it must
    // branch BEFORE load_pair_auto touches the file
    if args.usize_or("shards", 0)? > 0 {
        if args.has("multilevel") {
            bail!("--multilevel needs the training set in memory (incompatible with --shards)");
        }
        return cmd_train_sharded(args);
    }
    match load_pair_auto(args)? {
        LoadedPair::Binary(train, test) => cmd_train_binary(args, train, test),
        LoadedPair::Multi(train, test) => {
            if args.has("multilevel") {
                bail!(
                    "--multilevel supports binary problems only (the one-vs-one trainer \
                     already decomposes into small pairwise subproblems)"
                );
            }
            cmd_train_multiclass(args, train, test)
        }
    }
}

/// Resolve the shard set for `--shards K`: reuse `--shard-dir` (or the
/// `<train-file>.shards` default) when its manifest matches, re-shard
/// the source file in one streaming pass otherwise. The raw features
/// are NOT min-max scaled on this path (that would need a second pass);
/// compare with the in-memory trainer via `--raw`.
fn open_shards(args: &Args, k: usize) -> Result<ShardSet> {
    let train_file = args
        .str_opt("train-file")
        .context("--shards requires --train-file (synthetic datasets fit in memory)")?;
    let dir = match args.str_opt("shard-dir") {
        Some(d) => PathBuf::from(d),
        None => PathBuf::from(format!("{train_file}.shards")),
    };
    ShardSet::open_or_create(train_file, &dir, k)
}

/// Out-of-core binary training over on-disk CSR shards: block-diagonal
/// consensus ADMM (`hss_svm::admm::consensus`), raw points resident one
/// shard at a time. The test set (if any) is an ordinary in-memory
/// read — evaluation data is small; only training is sharded.
fn cmd_train_sharded(args: &Args) -> Result<()> {
    let k = args.usize_or("shards", 0)?;
    let shards = open_shards(args, k)?;
    let m = shards.manifest().clone();
    let repr = repr_from(args)?;
    let threads = args.usize_or("threads", threadpool::default_threads())?;
    let beta = args.f64_or("beta", Table1Spec::beta_for(m.rows))?;
    let h = args.f64_or("h", 1.0)?;
    let c = args.f64_or("c", 1.0)?;
    let iters = args.usize_or("iters", 10)?;
    let hss = hss_params_from(args)?;
    println!(
        "training out-of-core on {} ({} pts x {} feats, {} nnz, {} shards under {}; raw features)",
        m.name,
        m.rows,
        m.dim,
        m.nnz,
        m.shards,
        shards.dir().display()
    );
    if args.has("pjrt") {
        eprintln!("train: --pjrt ignored for sharded training (prediction only)");
    }
    let admm = AdmmParams { beta, max_it: iters, relax: 1.0, tol: 0.0 };
    let t_train = Timer::start();
    let (trainer, stats) = ConsensusTrainer::build(&shards, repr, Kernel::Gaussian { h }, &hss, admm, threads)?;
    let t = Timer::start();
    let (model, out) = trainer.train_c(&shards, c)?;
    let admm_secs = t.secs();
    let train_wall = t_train.secs();
    println!(
        "  compression   {:>9.3} s   (HSS max rank {}, {:.3} MB across {} resident shards, {} kernel evals)",
        stats.compress_secs,
        stats.hss_max_rank,
        stats.hss_memory_bytes as f64 / 1e6,
        stats.resident_shards,
        stats.kernel_evals
    );
    println!("  factorization {:>9.3} s", stats.factor_secs);
    println!("  ADMM ({iters} it)  {admm_secs:>9.3} s   (consensus across {k} shards)");
    println!("  support vectors: {}", model.n_sv());
    write_report(
        args,
        &ConvergenceReport {
            command: "train".to_string(),
            dataset: m.name.clone(),
            n: m.rows,
            threads,
            wall_secs: train_wall,
            phases: trainer.phases(),
            columns: vec![ReportColumn {
                h,
                c,
                iters: out.primal.len(),
                primal: out.primal.clone(),
                dual: out.dual.clone(),
            }],
            extra: vec![
                ("shards".to_string(), k.to_string()),
                ("hss_max_rank".to_string(), stats.hss_max_rank.to_string()),
                ("n_sv".to_string(), model.n_sv().to_string()),
            ],
        },
    )?;
    if let Some(f) = args.str_opt("test-file") {
        let test_repr = test_repr_for(repr, m.is_sparse_under(repr));
        let test = libsvm::read_file_with(f, Some(m.dim), test_repr)?;
        let t = Timer::start();
        let acc = predict::accuracy(&model, &test, threads);
        println!("  prediction    {:>9.3} s   (native path)", t.secs());
        println!("  test accuracy:   {:.3}%", acc * 100.0);
    }
    if let Some(path) = args.str_opt("save-model") {
        hss_svm::svm::persist::save(&model, path)?;
        println!("  model saved to {path}");
    }
    Ok(())
}

/// One-vs-one multiclass training: parallel pairwise subproblems over
/// the thread budget, shared-SV engine accuracy, OvO model file.
fn cmd_train_multiclass(
    args: &Args,
    train: MulticlassDataset,
    test: MulticlassDataset,
) -> Result<()> {
    let threads = args.usize_or("threads", threadpool::default_threads())?;
    let beta = args.f64_or("beta", Table1Spec::beta_for(train.len()))?;
    let h = args.f64_or("h", 1.0)?;
    let c = args.f64_or("c", 1.0)?;
    let iters = args.usize_or("iters", 10)?;
    let hss = hss_params_from(args)?;
    let classes = train.classes();
    println!(
        "training OvO on {} ({} pts x {} feats, {} classes {:?}{}; test {})",
        train.name,
        train.len(),
        train.dim(),
        classes.len(),
        classes,
        if train.is_sparse() {
            format!(", CSR {} nnz", train.x.nnz())
        } else {
            String::new()
        },
        test.len()
    );
    if args.has("pjrt") {
        eprintln!("train: --pjrt ignored for multiclass (shared-SV engine is native-only)");
    }
    let t_train = Timer::start();
    let (model, stats) = train_ovo(
        &train,
        Kernel::Gaussian { h },
        &hss,
        &AdmmParams { beta, max_it: iters, relax: 1.0, tol: 0.0 },
        c,
        threads,
    )?;
    let train_wall = t_train.secs();
    let t = Timer::start();
    let acc = model.accuracy(&test, threads);
    let predict_secs = t.secs();
    println!(
        "  {} pairwise subproblems (CPU-seconds summed over pairs):",
        stats.pairs
    );
    println!("  compression   {:>9.3} s", stats.compress_secs);
    println!("  factorization {:>9.3} s", stats.factor_secs);
    println!("  ADMM ({iters} it)  {:>9.3} s", stats.admm_secs);
    println!("  prediction    {predict_secs:>9.3} s   (shared-SV engine)");
    println!(
        "  support vectors: {} ({} unique in the shared pool)",
        model.n_sv_total(),
        model.n_sv_unique()
    );
    println!("  test accuracy:   {:.3}%", acc * 100.0);
    // OvO phase rows are CPU-seconds summed across the parallel pairwise
    // subproblems, so their total legitimately exceeds wall_secs.
    write_report(
        args,
        &ConvergenceReport {
            command: "train".to_string(),
            dataset: train.name.clone(),
            n: train.len(),
            threads,
            wall_secs: train_wall,
            phases: vec![
                ("compression".to_string(), stats.compress_secs, stats.pairs as u64),
                ("factorization".to_string(), stats.factor_secs, stats.pairs as u64),
                ("admm".to_string(), stats.admm_secs, stats.pairs as u64),
            ],
            columns: Vec::new(),
            extra: vec![
                ("pairs".to_string(), stats.pairs.to_string()),
                ("n_sv_unique".to_string(), model.n_sv_unique().to_string()),
                ("accuracy".to_string(), format!("{acc:?}")),
            ],
        },
    )?;
    if let Some(path) = args.str_opt("save-model") {
        hss_svm::svm::persist::save_ovo(&model, path)?;
        println!("  model saved to {path}");
    }
    Ok(())
}

fn cmd_train_binary(args: &Args, train: Dataset, test: Dataset) -> Result<()> {
    if let Some(ml) = multilevel_params_from(args)? {
        return cmd_train_binary_multilevel(args, train, test, &ml);
    }
    let threads = args.usize_or("threads", threadpool::default_threads())?;
    let beta = args.f64_or("beta", Table1Spec::beta_for(train.len()))?;
    let h = args.f64_or("h", 1.0)?;
    let c = args.f64_or("c", 1.0)?;
    let iters = args.usize_or("iters", 10)?;
    let hss = hss_params_from(args)?;
    println!(
        "training on {} ({} pts x {} feats, {} positive{}; test {})",
        train.name,
        train.len(),
        train.dim(),
        train.positives(),
        if train.is_sparse() {
            format!(", CSR {} nnz", train.x.nnz())
        } else {
            String::new()
        },
        test.len()
    );
    let t_train = Timer::start();
    let (model, stats) = train_hss_svm(
        &train,
        Kernel::Gaussian { h },
        &hss,
        &AdmmParams { beta, max_it: iters, relax: 1.0, tol: 0.0 },
        c,
        threads,
    )?;
    let train_wall = t_train.secs();
    let t = Timer::start();
    let acc = if args.has("pjrt") {
        let rt = PjrtRuntime::load(PjrtRuntime::default_dir())
            .context("--pjrt requires artifacts (run `make artifacts`)")?;
        let f = hss_svm::runtime::decision_function_pjrt(&rt, &model, &test.x)?;
        // decision signs vs ±1 labels: independent of the model's
        // original label pair (like predict::accuracy)
        let hits =
            f.iter().zip(test.y.iter()).filter(|(f, y)| (**f >= 0.0) == (**y > 0.0)).count();
        hits as f64 / test.len().max(1) as f64
    } else {
        predict::accuracy(&model, &test, threads)
    };
    let predict_secs = t.secs();

    println!(
        "  compression   {:>9.3} s   (HSS max rank {}, {:.3} MB, {} kernel evals)",
        stats.compress_secs,
        stats.hss_max_rank,
        stats.hss_memory_bytes as f64 / 1e6,
        stats.kernel_evals
    );
    println!("  factorization {:>9.3} s", stats.factor_secs);
    println!("  ADMM ({iters} it)  {:>9.3} s", stats.admm_secs);
    println!(
        "  prediction    {predict_secs:>9.3} s   ({} path)",
        if args.has("pjrt") { "PJRT" } else { "native" }
    );
    println!("  support vectors: {}", model.n_sv());
    println!("  test accuracy:   {:.3}%", acc * 100.0);
    write_report(
        args,
        &ConvergenceReport {
            command: "train".to_string(),
            dataset: train.name.clone(),
            n: train.len(),
            threads,
            wall_secs: train_wall,
            phases: stats.phases.clone(),
            columns: vec![ReportColumn {
                h,
                c,
                iters: stats.history.iterations,
                primal: stats.primal.clone(),
                dual: stats.dual.clone(),
            }],
            extra: vec![
                ("hss_max_rank".to_string(), stats.hss_max_rank.to_string()),
                ("n_sv".to_string(), model.n_sv().to_string()),
                ("accuracy".to_string(), format!("{acc:?}")),
            ],
        },
    )?;
    if let Some(path) = args.str_opt("save-model") {
        hss_svm::svm::persist::save(&model, path)?;
        println!("  model saved to {path}");
    }
    Ok(())
}

/// `train --multilevel`: the coarse-to-fine schedule of DESIGN.md §15.
/// Same console/report/save-model surface as the flat path, with the
/// phase table replaced by one row per trained level; the saved model
/// is an ordinary binary `.model` file (predict/serve are unchanged).
fn cmd_train_binary_multilevel(
    args: &Args,
    train: Dataset,
    test: Dataset,
    ml: &MultilevelParams,
) -> Result<()> {
    let threads = args.usize_or("threads", threadpool::default_threads())?;
    let beta = args.f64_or("beta", Table1Spec::beta_for(train.len()))?;
    let h = args.f64_or("h", 1.0)?;
    let c = args.f64_or("c", 1.0)?;
    let iters = args.usize_or("iters", 10)?;
    let hss = hss_params_from(args)?;
    if args.has("pjrt") {
        eprintln!("train: --pjrt ignored with --multilevel (prediction runs the native path)");
    }
    println!(
        "multilevel training on {} ({} pts x {} feats, {} positive{}; test {})",
        train.name,
        train.len(),
        train.dim(),
        train.positives(),
        if train.is_sparse() {
            format!(", CSR {} nnz", train.x.nnz())
        } else {
            String::new()
        },
        test.len()
    );
    let admm = AdmmParams { beta, max_it: iters, relax: 1.0, tol: 0.0 };
    let t_train = Timer::start();
    let t_prep = Timer::start();
    let ctx = MultilevelContext::new(&train, &hss, ml, threads);
    let prep_secs = t_prep.secs();
    let (model, out, levels) = ctx.train(Kernel::Gaussian { h }, &admm, c)?;
    let train_wall = t_train.secs();
    let points_trained: usize = levels.iter().map(|l| l.n_points).sum();
    println!(
        "  preprocessing {prep_secs:>9.3} s   (tree + ANN + screening: {} of {} pts kept, {} levels)",
        ctx.kept(),
        train.len(),
        levels.len()
    );
    print_level_rows(&levels);
    println!("  points trained across levels: {points_trained} (flat would train {})", train.len());
    let t = Timer::start();
    let acc = predict::accuracy(&model, &test, threads);
    println!("  prediction    {:>9.3} s   (native path)", t.secs());
    println!("  support vectors: {}", model.n_sv());
    println!("  test accuracy:   {:.3}%", acc * 100.0);
    let mut phases = vec![("preprocessing".to_string(), prep_secs, 1u64)];
    phases.extend(levels.iter().map(|l| {
        let name = if l.level == usize::MAX {
            "level-final".to_string()
        } else {
            format!("level-{}", l.level)
        };
        (name, l.secs, l.n_points as u64)
    }));
    write_report(
        args,
        &ConvergenceReport {
            command: "train".to_string(),
            dataset: train.name.clone(),
            n: train.len(),
            threads,
            wall_secs: train_wall,
            phases,
            columns: vec![ReportColumn {
                h,
                c,
                iters: out.iterations(),
                primal: out.primal.clone(),
                dual: out.dual.clone(),
            }],
            extra: vec![
                ("multilevel_levels".to_string(), levels.len().to_string()),
                ("multilevel_points_trained".to_string(), points_trained.to_string()),
                ("n_sv".to_string(), model.n_sv().to_string()),
                ("accuracy".to_string(), format!("{acc:?}")),
            ],
        },
    )?;
    if let Some(path) = args.str_opt("save-model") {
        hss_svm::svm::persist::save(&model, path)?;
        println!("  model saved to {path}");
    }
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let model_path = args.str_opt("model").context("--model is required")?;
    match hss_svm::svm::persist::load_any(model_path)? {
        AnyModel::Binary(model) => cmd_predict_binary(args, model),
        AnyModel::Ovo(model) => cmd_predict_multiclass(args, model),
    }
}

/// Resolve the `--backend` flag. `None` when the flag is absent, so the
/// default code paths (and their bitwise-pinned outputs) are untouched;
/// a named backend must resolve or the command fails — unlike the
/// legacy soft `--pjrt` fallback, a typo'd or unavailable `--backend`
/// never silently serves a different numeric path.
fn backend_from_args(args: &Args) -> Result<Option<std::sync::Arc<dyn ComputeBackend>>> {
    match args.str_opt("backend") {
        Some(spec) => {
            let b = BackendChoice::parse(spec)?.resolve()?;
            Ok(Some(b))
        }
        None => Ok(None),
    }
}

/// Multiclass prediction: label-agnostic feature read, shared-SV
/// engine, accuracy over the labeled lines by integer class match,
/// `--out` answering the ORIGINAL class labels of the training file.
fn cmd_predict_multiclass(args: &Args, model: hss_svm::svm::OvoModel) -> Result<()> {
    let threads = args.usize_or("threads", threadpool::default_threads())?;
    let test_path = args.str_opt("test-file").context("--test-file is required")?;
    // Auto follows the MODEL's representation (like serve::parse_batch
    // pins tiles), so offline predict is bitwise-identical to serving
    // the same lines; --sparse/--dense still override explicitly
    let repr = test_repr_for(repr_from(args)?, model.is_sparse());
    let (x, raw_labels) = libsvm::read_features_file(test_path, Some(model.dim()), repr)?;
    if args.has("pjrt") && args.str_opt("backend").is_none() {
        eprintln!(
            "predict: --pjrt ignored for multiclass (use --backend pjrt to run the \
             shared-SV engine's tiles on a backend)"
        );
    }
    let backend = backend_from_args(args)?;
    let t = Timer::start();
    let preds = match &backend {
        Some(b) => model.engine().predict_with_scores_with(&**b, &x, threads),
        None => model.engine().predict_with_scores(&x, threads),
    };
    let secs = t.secs();
    // the serving convention (see `serve`): a literal `0` label is the
    // "no label" placeholder, excluded from accuracy — UNLESS 0 is one
    // of the model's actual classes (a 0-labeled multiclass corpus)
    let zero_is_class = model.classes().contains(&0);
    let is_labeled = |l: f64| l.is_finite() && (zero_is_class || l != 0.0);
    let labeled = raw_labels.iter().filter(|&&l| is_labeled(l)).count();
    let hits = preds
        .iter()
        .zip(raw_labels.iter())
        .filter(|((p, _), l)| is_labeled(**l) && *p == l.round() as i64)
        .count();
    if labeled > 0 {
        println!(
            "predicted {} points in {secs:.3}s (shared-SV engine, {} pairs): accuracy \
             {:.3}% over {labeled} labeled lines",
            x.rows(),
            model.pairs().len(),
            100.0 * hits as f64 / labeled as f64
        );
    } else {
        println!(
            "predicted {} points in {secs:.3}s (shared-SV engine, {} pairs); no labeled lines",
            x.rows(),
            model.pairs().len()
        );
    }
    if let Some(out) = args.str_opt("out") {
        use std::io::Write;
        let mut w = std::io::BufWriter::new(std::fs::File::create(out)?);
        for (class, _) in &preds {
            writeln!(w, "{class}")?;
        }
        println!("predictions written to {out}");
    }
    Ok(())
}

fn cmd_predict_binary(args: &Args, model: hss_svm::svm::SvmModel) -> Result<()> {
    let threads = args.usize_or("threads", threadpool::default_threads())?;
    let test_path = args.str_opt("test-file").context("--test-file is required")?;
    // label-agnostic read: unlabeled / partially labeled files predict
    // fine; accuracy is reported over the labeled lines only
    let (x, raw_labels) =
        libsvm::read_features_file(test_path, Some(model.sv.cols()), repr_from(args)?)?;
    let t = Timer::start();
    let backend = backend_from_args(args)?;
    let (f, path_label) = if let Some(b) = &backend {
        (predict::decision_function_with(&**b, &model, &x, threads), b.name())
    } else if args.has("pjrt") {
        let rt = PjrtRuntime::load(PjrtRuntime::default_dir())
            .context("--pjrt requires artifacts (run `make artifacts`)")?;
        (hss_svm::runtime::decision_function_pjrt(&rt, &model, &x)?, "PJRT")
    } else {
        (predict::decision_function(&model, &x, threads), "native")
    };
    let secs = t.secs();
    let labels = libsvm::normalize_eval_labels(&raw_labels);
    let labeled = labels.iter().filter(|l| l.is_finite()).count();
    // accuracy over decision signs, so models trained on e.g. {1,2}
    // data score correctly against the normalized ±1 labels
    let hits = f
        .iter()
        .zip(labels.iter())
        .filter(|(f, l)| l.is_finite() && (**f >= 0.0) == (**l > 0.0))
        .count();
    if labeled > 0 {
        println!(
            "predicted {} points in {secs:.3}s ({path_label} path): accuracy {:.3}% \
             over {labeled} labeled lines",
            x.rows(),
            100.0 * hits as f64 / labeled as f64
        );
    } else {
        println!(
            "predicted {} points in {secs:.3}s ({path_label} path); no labeled lines",
            x.rows()
        );
    }
    if let Some(out) = args.str_opt("out") {
        use std::io::Write;
        let mut w = std::io::BufWriter::new(std::fs::File::create(out)?);
        for v in &f {
            // the model's original label pair (±1 unless the training
            // data used another encoding, e.g. {1,2})
            writeln!(w, "{}", model.label_text(*v))?;
        }
        println!("predictions written to {out}");
    }
    Ok(())
}

/// Serving front-ends. Default (and `--stdin`): the single-stream
/// request loop — LIBSVM-format feature lines on stdin (labeled,
/// 0-labeled or bare), one "<predicted label> <decision value>" per line
/// on stdout. Requests are micro-batched per read for tile efficiency;
/// this is the L3 "serving" mode — Python never runs here, prediction
/// goes through the AOT artifacts when available. The loop itself lives
/// in [`hss_svm::serve`]: batches parse label-agnostically (a mix of ±1
/// and unlabeled lines no longer kills the server) and a malformed line
/// fails only its own batch, reported per-line on stderr.
///
/// With `--listen HOST:PORT`: the concurrent TCP server
/// ([`hss_svm::server`]) — same per-connection line protocol and batch
/// semantics, requests micro-batched **across** connections, plus a
/// model registry (`--models name=path,...`, `MODEL`/`RELOAD` admin
/// commands, mtime hot reload), `STATS`, Prometheus-style `METRICS`,
/// backpressure and graceful `SHUTDOWN`.
fn cmd_serve(args: &Args) -> Result<()> {
    if args.str_opt("listen").is_some() {
        return cmd_serve_tcp(args);
    }
    let threads = args.usize_or("threads", threadpool::default_threads())?;
    let model_path = args.str_opt("model").context("--model is required")?;
    let model = hss_svm::svm::persist::load_any(model_path)?;
    // --backend resolves hard; legacy bare --pjrt keeps its soft
    // fallback (artifacts absent → native path, with a notice). Either
    // way the backend degrades per tile to the bitwise CPU reference
    // on operands its accelerator cannot serve (CSR, OvO kernels).
    let mut backend = backend_from_args(args)?;
    if backend.is_none() && args.has("pjrt") {
        match PjrtRuntime::try_default() {
            Some(rt) => backend = Some(std::sync::Arc::new(rt)),
            None => eprintln!("serve: PJRT artifacts unavailable, using the native path"),
        }
    }
    eprintln!(
        "serving {} ({}), {} path; send LIBSVM lines, EOF to stop",
        model_path,
        model.describe(),
        backend.as_deref().map_or("native", |b| b.name())
    );
    let stdin = std::io::stdin();
    let stats = hss_svm::serve::serve_loop(
        &model,
        backend.as_deref(),
        stdin.lock(),
        std::io::stdout().lock(),
        std::io::stderr().lock(),
        threads,
    )?;
    eprintln!(
        "served {} predictions in {} batches ({} lines, {} skipped, {} batches dropped)",
        stats.predicted, stats.batches, stats.lines, stats.skipped, stats.failed_batches
    );
    Ok(())
}

/// TCP serving mode (`serve --listen`): bind, build the model registry
/// and run until SHUTDOWN. CLI flags map onto
/// [`hss_svm::server::ServerConfig`] 1:1.
fn cmd_serve_tcp(args: &Args) -> Result<()> {
    use hss_svm::server::{ModelRegistry, Server, ServerConfig};
    let addr = args.str_opt("listen").context("--listen is required")?;
    let threads = args.usize_or("threads", threadpool::default_threads())?;
    let mut entries: Vec<(String, PathBuf)> = Vec::new();
    if let Some(p) = args.str_opt("model") {
        entries.push(("default".to_string(), PathBuf::from(p)));
    }
    if let Some(list) = args.str_opt("models") {
        for part in list.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, path) = part
                .split_once('=')
                .with_context(|| format!("--models entries are name=path, got {part:?}"))?;
            entries.push((name.trim().to_string(), PathBuf::from(path.trim())));
        }
    }
    if entries.is_empty() {
        bail!("serve --listen needs --model <path> and/or --models name=path,...");
    }
    let mut registry = ModelRegistry::from_paths(&entries)?;
    if let Some(b) = backend_from_args(args)? {
        eprintln!("serve: batcher predicting on the {} backend", b.name());
        registry = registry.with_backend(b);
    }
    let defaults = ServerConfig::default();
    let cfg = ServerConfig {
        batch_max: args.usize_or("batch-max", defaults.batch_max)?,
        batch_wait: std::time::Duration::from_millis(
            args.usize_or("batch-wait-ms", defaults.batch_wait.as_millis() as usize)? as u64,
        ),
        max_inflight: args.usize_or("max-inflight", defaults.max_inflight)?,
        threads,
        ..defaults
    };
    let server = Server::bind(addr, registry, cfg)?;
    let handle = server.handle();
    let names: Vec<String> = entries.iter().map(|(n, _)| n.clone()).collect();
    eprintln!(
        "serving on {} (models: {}, default {:?}, {threads} threads); \
         LIBSVM lines per connection, admin: MODEL <name> | RELOAD [name] | \
         STATS | METRICS | SHUTDOWN | QUIT",
        server.local_addr(),
        names.join(", "),
        names[0],
    );
    server.run()?;
    eprintln!("{}", handle.summary());
    Ok(())
}

/// Per-cell ADMM convergence lines of the grid summary: iteration
/// counts and final residuals, where per-column histories exist (binary
/// cells; multiclass OvO cells aggregate many pairwise subproblems and
/// carry no per-cell curve).
fn print_grid_convergence(res: &hss_svm::coordinator::grid::GridResult) {
    let with_hist: Vec<_> = res.cells.iter().filter(|c| c.iters > 0).collect();
    if with_hist.is_empty() {
        return;
    }
    println!("ADMM convergence per cell:");
    for cell in with_hist {
        println!(
            "  h={:<10} C={:<10} {:>3} it   primal {:.3e}   dual {:.3e}   acc {:.3}%",
            cell.h,
            cell.c,
            cell.iters,
            cell.final_primal,
            cell.final_dual,
            cell.accuracy * 100.0
        );
    }
}

/// `report.json` content of a grid run: coarse phase rows (the grid's
/// three sequential stages) plus one residual column per evaluated cell.
fn grid_report(
    dataset: &str,
    n: usize,
    threads: usize,
    wall_secs: f64,
    h_count: usize,
    res: &hss_svm::coordinator::grid::GridResult,
) -> ConvergenceReport {
    ConvergenceReport {
        command: "grid".to_string(),
        dataset: dataset.to_string(),
        n,
        threads,
        wall_secs,
        phases: vec![
            ("compression".to_string(), res.compress_secs, h_count as u64),
            ("factorization".to_string(), res.factor_secs, h_count as u64),
            ("admm".to_string(), res.total_admm_secs, res.cells.len() as u64),
        ],
        columns: res
            .cells
            .iter()
            .map(|c| ReportColumn {
                h: c.h,
                c: c.c,
                iters: c.iters,
                primal: c.primal.clone(),
                dual: c.dual.clone(),
            })
            .collect(),
        extra: vec![
            ("best_h".to_string(), format!("{:?}", res.best_h)),
            ("best_accuracy".to_string(), format!("{:?}", res.best_accuracy)),
        ],
    }
}

fn cmd_grid(args: &Args) -> Result<()> {
    let threads = args.usize_or("threads", threadpool::default_threads())?;
    if args.usize_or("shards", 0)? > 0 {
        if args.has("multilevel") {
            bail!("--multilevel needs the training set in memory (incompatible with --shards)");
        }
        return cmd_grid_sharded(args, threads);
    }
    let ml_params = multilevel_params_from(args)?;
    let pair = load_pair_auto(args)?;
    let (name, n) = match &pair {
        LoadedPair::Binary(train, _) => (train.name.clone(), train.len()),
        LoadedPair::Multi(train, _) => (train.name.clone(), train.len()),
    };
    let beta = args.f64_or("beta", Table1Spec::beta_for(n))?;
    let h_values = args.f64_list_or("h", &[0.1, 1.0, 10.0])?;
    let c_values = args.f64_list_or("c", &[0.1, 1.0, 10.0])?;
    let grid = GridSearch {
        h_values: h_values.clone(),
        c_values: c_values.clone(),
        hss: hss_params_from(args)?,
        admm: AdmmParams { beta, max_it: args.usize_or("iters", 10)?, relax: 1.0, tol: 0.0 },
        threads,
    };
    let t_grid = Timer::start();
    let mut ml_schedules: Vec<(f64, Vec<LevelStats>)> = Vec::new();
    let res = match &pair {
        LoadedPair::Binary(train, test) => match &ml_params {
            Some(ml) => {
                println!("multilevel grid search on {name} ({n} pts), beta = {beta}");
                let (res, per_h) = grid.run_multilevel(train, test, ml)?;
                ml_schedules = per_h;
                res
            }
            None => {
                println!("grid search on {name} ({n} pts), beta = {beta}");
                grid.run(train, test)?
            }
        },
        LoadedPair::Multi(train, test) => {
            if ml_params.is_some() {
                bail!(
                    "--multilevel supports binary problems only (the one-vs-one trainer \
                     already decomposes into small pairwise subproblems)"
                );
            }
            println!(
                "OvO grid search on {name} ({n} pts, {} classes), beta = {beta}",
                train.classes().len()
            );
            grid.run_multiclass(train, test)?
        }
    };
    let grid_wall = t_grid.secs();
    println!("{}", hss_svm::coordinator::grid::ascii_heatmap(&res, &h_values, &c_values));
    print_grid_convergence(&res);
    for (h, levels) in &ml_schedules {
        println!("multilevel schedule for h = {h}:");
        print_level_rows(levels);
    }
    println!(
        "compression {:.3}s ({} h values) | factorization {:.3}s | total ADMM {:.3}s ({} cells)",
        res.compress_secs,
        h_values.len(),
        res.factor_secs,
        res.total_admm_secs,
        res.cells.len()
    );
    println!(
        "best: h = {}, C = {} -> accuracy {:.3}%",
        res.best_h,
        report::c_set(&res.best_cs),
        res.best_accuracy * 100.0
    );
    write_report(args, &grid_report(&name, n, threads, grid_wall, h_values.len(), &res))?;
    Ok(())
}

/// Out-of-core grid search: one consensus build per h, every C batched
/// — the sharded analog of the in-memory reuse structure. Needs an
/// explicit `--test-file` (there is no in-memory corpus to split).
fn cmd_grid_sharded(args: &Args, threads: usize) -> Result<()> {
    let k = args.usize_or("shards", 0)?;
    let shards = open_shards(args, k)?;
    let m = shards.manifest().clone();
    let repr = repr_from(args)?;
    let test_file = args
        .str_opt("test-file")
        .context("grid --shards requires --test-file (no in-memory corpus to split)")?;
    let test_repr = test_repr_for(repr, m.is_sparse_under(repr));
    let test = libsvm::read_file_with(test_file, Some(m.dim), test_repr)?;
    let beta = args.f64_or("beta", Table1Spec::beta_for(m.rows))?;
    let h_values = args.f64_list_or("h", &[0.1, 1.0, 10.0])?;
    let c_values = args.f64_list_or("c", &[0.1, 1.0, 10.0])?;
    let grid = GridSearch {
        h_values: h_values.clone(),
        c_values: c_values.clone(),
        hss: hss_params_from(args)?,
        admm: AdmmParams { beta, max_it: args.usize_or("iters", 10)?, relax: 1.0, tol: 0.0 },
        threads,
    };
    println!(
        "grid search out-of-core on {} ({} pts, {} shards), beta = {beta}",
        m.name, m.rows, m.shards
    );
    let t_grid = Timer::start();
    let res = grid.run_sharded(&shards, repr, &test)?;
    let grid_wall = t_grid.secs();
    println!("{}", hss_svm::coordinator::grid::ascii_heatmap(&res, &h_values, &c_values));
    print_grid_convergence(&res);
    println!(
        "compression {:.3}s ({} h values) | factorization {:.3}s | total ADMM {:.3}s ({} cells)",
        res.compress_secs,
        h_values.len(),
        res.factor_secs,
        res.total_admm_secs,
        res.cells.len()
    );
    println!(
        "best: h = {}, C = {} -> accuracy {:.3}%",
        res.best_h,
        report::c_set(&res.best_cs),
        res.best_accuracy * 100.0
    );
    write_report(args, &grid_report(&m.name, m.rows, threads, grid_wall, h_values.len(), &res))?;
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    // config file first, CLI flags override
    let cfg = match args.str_opt("config") {
        Some(path) => hss_svm::config::Config::load(path)?,
        None => hss_svm::config::Config::default(),
    };
    let id = args.str_opt("id").map(|s| s.to_string()).unwrap_or_else(|| cfg.str_or("", "id", "all"));
    let scale_frac = args.f64_or("scale", cfg.f64_or("", "scale", 0.01))?;
    let seed = args.usize_or("seed", cfg.usize_or("", "seed", 2021))? as u64;
    let threads = args.usize_or("threads", threadpool::default_threads())?;
    let out_dir = PathBuf::from(args.str_or("out", &cfg.str_or("", "out", "results")));
    std::fs::create_dir_all(&out_dir).ok();
    let cfg_datasets: Vec<&str> = Vec::new();
    let mut datasets = args.str_list_or("datasets", &cfg_datasets);
    if datasets.is_empty() {
        if let Some(v) = cfg.get("suite", "datasets").and_then(|v| v.as_str_array()) {
            datasets = v;
        }
    }
    let baseline_cap =
        args.usize_or("baseline-cap", cfg.usize_or("suite", "baseline_cap", 20_000))?;

    let emit = |name: &str, t: &report::Table| -> Result<()> {
        println!("{}", t.render());
        let p = out_dir.join(format!("{name}.csv"));
        t.write_csv(&p)?;
        println!("[csv] {}\n", p.display());
        Ok(())
    };

    let run_tables = |hss: HssParams,
                      label: &str,
                      with_baselines: bool|
     -> Result<Vec<hss_svm::coordinator::SuiteRow>> {
        let cfg = SuiteConfig {
            datasets: datasets.clone(),
            scale: scale_frac,
            hss,
            run_smo: with_baselines,
            run_racqp: with_baselines,
            baseline_cap,
            threads,
            seed,
            ..Default::default()
        };
        println!("running suite [{label}] at scale {scale_frac} ...");
        run_suite(&cfg)
    };

    match id.as_str() {
        "table1" => emit("table1", &tables::table1(scale_frac, seed))?,
        "table2" | "table3" => {
            let rows = run_tables(HssParams::high_accuracy(), "high accuracy + baselines", true)?;
            if id == "table2" {
                emit("table2", &tables::baseline_table("Table 2: LIBSVM-style SMO", &rows, |r| r.smo))?;
            } else {
                emit(
                    "table3",
                    &tables::baseline_table("Table 3: RACQP-style multi-block ADMM", &rows, |r| {
                        r.racqp
                    }),
                )?;
            }
        }
        "table4" => {
            let rows = run_tables(HssParams::low_accuracy(), "Table 4 (low accuracy)", false)?;
            emit("table4", &tables::hss_table("Table 4: Strumpack&ADMM (low accuracy HSS)", &rows))?;
        }
        "table5" => {
            let rows = run_tables(HssParams::high_accuracy(), "Table 5 (high accuracy)", false)?;
            emit("table5", &tables::hss_table("Table 5: Strumpack&ADMM (high accuracy HSS)", &rows))?;
        }
        "fig1" => {
            let (decay, ranks) = figures::fig1(seed);
            emit("fig1_decay", &decay)?;
            emit("fig1_ranks", &ranks)?;
        }
        "fig2" => {
            for (name, heat, table) in figures::fig2(scale_frac, seed, threads)? {
                println!("--- {name} ---\n{heat}");
                emit(&format!("fig2_{name}"), &table)?;
            }
        }
        "reuse" => {
            let rows = run_tables(HssParams::low_accuracy(), "grid-reuse", true)?;
            emit("reuse", &tables::grid_reuse_table(&rows, 3))?;
        }
        "all" => {
            emit("table1", &tables::table1(scale_frac, seed))?;
            let rows4 = run_tables(HssParams::low_accuracy(), "Table 4 (low accuracy)", false)?;
            emit("table4", &tables::hss_table("Table 4: Strumpack&ADMM (low accuracy HSS)", &rows4))?;
            let rows5 = run_tables(HssParams::high_accuracy(), "Table 5 + baselines", true)?;
            emit("table5", &tables::hss_table("Table 5: Strumpack&ADMM (high accuracy HSS)", &rows5))?;
            emit("table2", &tables::baseline_table("Table 2: LIBSVM-style SMO", &rows5, |r| r.smo))?;
            emit(
                "table3",
                &tables::baseline_table("Table 3: RACQP-style multi-block ADMM", &rows5, |r| r.racqp),
            )?;
            emit("reuse", &tables::grid_reuse_table(&rows5, 3))?;
            let (decay, ranks) = figures::fig1(seed);
            emit("fig1_decay", &decay)?;
            emit("fig1_ranks", &ranks)?;
            for (name, heat, table) in figures::fig2(scale_frac, seed, threads)? {
                println!("--- {name} ---\n{heat}");
                emit(&format!("fig2_{name}"), &table)?;
            }
        }
        other => bail!("unknown experiment {other:?}"),
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let _ = args;
    println!("hss-svm {} — ADMM + HSS nonlinear SVM training", env!("CARGO_PKG_VERSION"));
    println!("threads (default): {}", threadpool::default_threads());
    match PjrtRuntime::load(PjrtRuntime::default_dir()) {
        Ok(rt) => {
            let (k, d) = rt.dims();
            println!("PJRT artifacts: kernel tiles f={k:?}, decision tiles f={d:?}");
        }
        Err(e) => println!("PJRT artifacts: unavailable ({e})"),
    }
    println!("\nTable-1 datasets (synthetic; use --scale to size):");
    for s in synth::TABLE1 {
        println!(
            "  {:<14} features {:>6}  train {:>8} (+{:>7})  test {:>8}",
            s.name, s.features, s.train, s.train_pos, s.test
        );
    }
    Ok(())
}
